"""Shared benchmark plumbing: timed runs + CSV emission.

The container is CPU-only, so each benchmark reports BOTH:
  * ``wall_us``    — measured CPU wall time (real execution of the system)
  * ``modeled_ms`` — the transfer-time model with the paper's PCIe-3 GPU
    constants evaluated on the *actual* per-iteration frontier statistics
    of that execution (this is the quantity the paper's tables measure).
"""

from __future__ import annotations

import time

import numpy as np

ROWS: list[tuple] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn, *args, repeats: int = 3, **kw):
    fn(*args, **kw)  # warmup / compile
    times = []
    for _ in range(repeats):
        t0 = time.monotonic()
        out = fn(*args, **kw)
        times.append(time.monotonic() - t0)
    return out, float(np.median(times)) * 1e6
