"""Chaos benchmark + exactness-under-faults gate (repro.resilience).

Replays one multi-tenant serving trace (queries -> update batch ->
queries) against a ``GraphService`` under seeded :class:`FaultPlan`\\ s
and measures what recovery costs:

* ``chaos/replay_clean``  — wall time of the fault-free trace replay
  (the baseline every faulted replay is compared against);
* ``chaos/replay_faulted``— the same trace under injected dispatch
  failures/timeouts with retry (recovery overhead is the difference);
* ``chaos/checkpoint``    — one ``HyTMState`` checkpoint save at a chunk
  boundary (the per-chunk price of crash recoverability);
* ``chaos/resume``        — kill at a seeded chunk boundary + restore +
  converge the remainder.

``--selfcheck`` gates (CI):
  1. **exactness under faults** — under three seeded fault plans
     (dispatch fail/timeout + retry; allocation OOM + tiered load
     shedding; host-spill corruption + promote OOM + update
     drop/duplicate), every *completed* request is bit-identical to the
     fault-free replay of the same trace, ``quota_violations == 0``, and
     the device byte budget holds;
  2. **crash recovery** — a run killed mid-flight by an injected
     dispatch fault resumes from its last checkpoint bit-identically:
     values, iterations, transfer bytes, and per-iteration engine picks
     all equal the uninterrupted run;
  3. **zero overhead** — a service threaded with an *empty* fault plan
     (every guarded path taken, nothing fired) replays the trace
     bit-identically to the plain PR-8 service;
  4. **observability** — fault injections, retries, and degradations
     appear on the ``faults`` obs track and the exported Chrome trace
     validates.

``--trace <path>`` writes the faulted replay's trace for artifact upload.
"""

from __future__ import annotations

import argparse
import os
import tempfile
import time

import numpy as np

from benchmarks.common import emit
from repro.core.hytm import HyTMConfig, run_hytm
from repro.graph.algorithms import SSSP
from repro.graph.generators import rmat_graph
from repro.resilience import (
    CheckpointHook,
    FaultSpec,
    RetriesExhausted,
    RetryPolicy,
    Supervisor,
    deliver_update,
    plan_of,
    resume_run,
    save,
)
from repro.serve import Request, RequestQueue
from repro.stream import GraphService, random_batch

TIERS = {"gold": 2, "silver": 1, "bronze": 0}


def _submit(queue, program, specs):
    for i, (tenant, source) in enumerate(specs):
        queue.submit(Request(tenant=tenant, program=program, source=source,
                             deadline=float(i)))


def _replay(g, cfg, budget, trace, update_seed, *, faults=None,
            supervisor=None, policy=None, obs=None):
    """Replay the canonical trace: pump phase-1 queries, deliver one
    update batch exactly-once, pump phase-2 queries.  Returns completed
    (phase, tenant, source) -> values plus the service for stats."""
    svc = GraphService(g, cfg, max_lanes=4, device_budget_bytes=budget,
                       faults=faults, supervisor=supervisor, obs=obs)
    completed: dict[tuple, np.ndarray] = {}
    shed: list[tuple] = []
    for phase, specs in enumerate(trace):
        q = RequestQueue(quota=2, tenant_quotas={"bronze": 1})
        _submit(q, SSSP, specs)
        for r in svc.scheduler.pump(q):
            key = (phase, r.request.tenant, r.request.source)
            if r.mode == "shed":
                shed.append(key)
            elif r.mode != "rejected":
                completed[key] = np.asarray(r.values)
        assert q.stats.quota_violations == 0, q.stats
        if phase == 0:
            batch = random_batch(svc.dcsr, np.random.default_rng(update_seed),
                                 n_insert=12, n_delete=12)
            deliver_update(svc, batch, batch_id=f"trace-{update_seed}",
                           faults=faults, policy=policy, obs=obs)
    return completed, shed, svc


def _assert_completed_exact(clean, faulted, shed, label):
    assert set(faulted) <= set(clean), (label, set(faulted) - set(clean))
    missing = set(clean) - set(faulted) - set(shed)
    assert not missing, (label, "lost without shed record", missing)
    for key, vals in faulted.items():
        np.testing.assert_array_equal(
            vals, clean[key], err_msg=f"{label}: {key} diverged under faults")


def run(fast: bool = False, selfcheck: bool = False, seed: int = 7,
        trace_path: str | None = None) -> dict:
    n_nodes, n_edges = (300, 2_400) if fast else (800, 6_400)
    g = rmat_graph(n_nodes, n_edges, seed=seed)
    cfg = HyTMConfig(n_partitions=6 if fast else 8, sync_every=2)
    budget = 6 * 9 * n_nodes
    trace = (
        [("gold", 0), ("silver", 3), ("bronze", 77), ("gold", 210),
         ("bronze", 9), ("silver", 15)],
        [("gold", 0), ("bronze", 3), ("silver", 77)],
    )
    policy = RetryPolicy(max_attempts=6, backoff_s=0.0)

    t0 = time.monotonic()
    clean, _, svc_clean = _replay(g, cfg, budget, trace, seed)
    t_clean = time.monotonic() - t0
    emit("chaos/replay_clean", t_clean * 1e6,
         f"requests={len(clean)} version={svc_clean.version}")

    # scenario 1: dispatch failures + timeouts, recovered by retry
    plan1 = plan_of(
        FaultSpec("chunk_dispatch", "fail", p=0.4, max_fires=6),
        FaultSpec("lane_dispatch", "fail", p=0.3, max_fires=6),
        FaultSpec("lane_dispatch", "timeout", p=0.2, max_fires=4),
        seed=seed,
    )
    sup1 = Supervisor(policy=policy, faults=plan1, tenant_tiers=TIERS)
    t0 = time.monotonic()
    faulted1, shed1, svc1 = _replay(
        g, cfg, budget, trace, seed, faults=plan1, supervisor=sup1,
        policy=policy)
    t_faulted = time.monotonic() - t0
    emit("chaos/replay_faulted", t_faulted * 1e6,
         f"injected={sum(plan1.counts().values())} "
         f"retries={sup1.counters['retries']} "
         f"overhead={t_faulted - t_clean:+.3f}s")

    # checkpoint + kill/resume micro-costs (gate asserts bit-identity)
    tmp = tempfile.mkdtemp(prefix="chaos_bench_")
    ckpt = os.path.join(tmp, "run.ckpt.npz")
    base = run_hytm(g, SSSP, source=0, config=cfg)
    hook = CheckpointHook(ckpt, program=SSSP.name, anchor=(0, 0))
    kill_plan = plan_of(FaultSpec("chunk_dispatch", "fail", at=(2,)),
                        seed=seed + 1)
    try:
        run_hytm(g, SSSP, source=0, config=cfg, faults=kill_plan,
                 on_chunk=hook)
        raise AssertionError("injected kill did not fire")
    except RetriesExhausted:
        pass
    t0 = time.monotonic()
    resumed = resume_run(ckpt, g, SSSP, config=cfg, source=0,
                         expect_anchor=(0, 0))
    emit("chaos/resume", (time.monotonic() - t0) * 1e6,
         f"total_iterations={resumed.iterations}")
    t0 = time.monotonic()
    save_ckpt_path = os.path.join(tmp, "timing.ckpt.npz")
    from repro.resilience import RunCheckpoint

    save(RunCheckpoint(program=SSSP.name, iterations=base.iterations,
                       values=np.asarray(base.values),
                       delta=np.asarray(base.delta)), save_ckpt_path)
    emit("chaos/checkpoint", (time.monotonic() - t0) * 1e6,
         f"bytes={os.path.getsize(save_ckpt_path)}")

    rows = {
        "requests": len(clean),
        "injected": sum(plan1.counts().values()),
        "retries": sup1.counters["retries"],
        "resume_iterations": resumed.iterations,
    }
    if selfcheck:
        _selfcheck(g, cfg, budget, trace, seed, policy, clean, svc_clean,
                   faulted1, shed1, svc1, base, resumed, ckpt, rows,
                   trace_path)
    elif trace_path is not None:
        _write_trace(g, cfg, budget, trace, seed, policy, trace_path)
    return rows


def _write_trace(g, cfg, budget, trace, seed, policy, trace_path):
    from repro.obs import TraceRecorder, write_chrome_trace

    rec = TraceRecorder()
    plan = plan_of(FaultSpec("lane_dispatch", "fail", p=0.5, max_fires=4),
                   FaultSpec("lane_alloc", "oom", p=1.0, max_fires=8),
                   seed=seed)
    sup = Supervisor(policy=policy, faults=plan, obs=rec,
                     tenant_tiers=TIERS, shed_after=2)
    _replay(g, cfg, budget, trace, seed, faults=plan, supervisor=sup,
            policy=policy, obs=rec)
    write_chrome_trace(rec, trace_path)
    print(f"# trace: {len(rec)} events -> {trace_path}")
    return rec


def _selfcheck(g, cfg, budget, trace, seed, policy, clean, svc_clean,
               faulted1, shed1, svc1, base, resumed, ckpt, rows,
               trace_path) -> None:
    from repro.core.cost_model import KEY_ENGINES
    from repro.obs import TraceRecorder, to_chrome_trace, validate_chrome_trace
    from repro.resilience import FaultPlan

    # 1a. scenario 1 (dispatch fail/timeout + retry): exactness
    _assert_completed_exact(clean, faulted1, shed1, "dispatch-faults")
    assert svc1.version == svc_clean.version, "update lost or duplicated"
    assert svc1.scheduler.stats.max_device_bytes <= budget

    # 1b. scenario 2: allocation OOM pressure -> narrower batches +
    # tiered shedding; completed answers still exact, budget still holds
    plan2 = plan_of(FaultSpec("lane_alloc", "oom", p=1.0, max_fires=100),
                    FaultSpec("cache_promote", "oom", p=0.5, max_fires=10),
                    seed=seed + 2)
    sup2 = Supervisor(policy=policy, faults=plan2, tenant_tiers=TIERS,
                      shed_after=2)
    faulted2, shed2, svc2 = _replay(
        g, cfg, budget, trace, seed, faults=plan2, supervisor=sup2,
        policy=policy)
    _assert_completed_exact(clean, faulted2, shed2, "alloc-oom")
    assert svc2.version == svc_clean.version
    assert svc2.scheduler.stats.max_device_bytes <= budget
    for phase, tenant, _src in shed2:
        waiting = {t for t, _ in trace[phase]}
        assert TIERS[tenant] < max(TIERS[t] for t in waiting), (
            "shed a top-tier tenant", tenant)

    # 1c. scenario 3: host-spill corruption + update drop/duplicate —
    # corruption is detected (never served), delivery is exactly-once
    plan3 = plan_of(FaultSpec("host_spill", "corrupt", at=(0, 1)),
                    FaultSpec("update_delivery", "drop", at=(0,)),
                    FaultSpec("update_redeliver", "duplicate", at=(0,)),
                    seed=seed + 3)
    tight = 2 * 9 * g.n_nodes  # force spills so corruption has a target
    faulted3, shed3, svc3 = _replay(
        g, cfg, budget=tight, trace=trace, update_seed=seed, faults=plan3,
        policy=policy)
    _assert_completed_exact(clean, faulted3, shed3, "corrupt-spill")
    assert svc3.version == svc_clean.version, "drop/duplicate broke updates"
    counts3 = plan3.counts()
    assert counts3.get(("host_spill", "corrupt"), 0) >= 1, counts3
    assert svc3.cache.stats.corrupt >= 1 or svc3.cache.stats.spills == 0, (
        svc3.cache.stats.as_dict())

    # 2. crash recovery: killed run resumed from checkpoint bit-identical
    np.testing.assert_array_equal(base.values, resumed.values)
    assert resumed.iterations == base.iterations
    assert resumed.total_transfer_bytes == base.total_transfer_bytes
    np.testing.assert_array_equal(
        base.history[KEY_ENGINES], resumed.history[KEY_ENGINES])

    # 3. zero overhead: an empty plan takes every guarded path but fires
    # nothing — the replay must be bit-identical to the plain service
    empty, shed0, svc0 = _replay(g, cfg, budget, trace, seed,
                                 faults=FaultPlan(seed=seed))
    assert not shed0
    _assert_completed_exact(clean, empty, [], "empty-plan")
    assert set(empty) == set(clean)
    assert svc0.version == svc_clean.version

    # 4. observability: injections land on the faults track; trace valid
    rec = _write_trace(g, cfg, budget, trace, seed, policy,
                       trace_path or os.path.join(
                           tempfile.mkdtemp(prefix="chaos_bench_"),
                           "chaos_trace.json"))
    tracks = {e.track for e in rec.events}
    assert "faults" in tracks, tracks
    validate_chrome_trace(to_chrome_trace(rec))

    print(f"# SELFCHECK OK: {len(clean)} completed requests bit-identical "
          f"under 3 fault plans ({rows['injected']}+ injections, "
          f"{len(shed2)} shed, corrupt={svc3.cache.stats.corrupt}); "
          f"kill+resume bit-identical over {base.iterations} iterations; "
          f"empty-plan replay == plain; faults track valid")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller graph (CI mode)")
    ap.add_argument("--selfcheck", action="store_true",
                    help="gate: completed requests bit-identical under "
                         "seeded fault plans, quotas/budgets hold, "
                         "kill+restore resumes bit-identically, empty "
                         "plan is zero-overhead")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write the faulted replay's chrome trace-event "
                         "JSON (with the faults track) to PATH")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(fast=args.fast, selfcheck=args.selfcheck, seed=args.seed,
        trace_path=args.trace)


if __name__ == "__main__":
    main()
