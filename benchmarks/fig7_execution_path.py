"""Fig. 7 analogue: HyTM's per-iteration engine mix (execution path) for
PageRank and SSSP — filter early / zero-copy late for PR, zero-copy ->
filter -> compaction arc for SSSP."""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import emit
from repro.core.constants import PCIE3
from repro.core.cost_model import COMPACT, FILTER, ZEROCOPY
from repro.core.hytm import HyTMConfig, run_hytm
from repro.graph.algorithms import PAGERANK, SSSP
from repro.graph.generators import rmat_graph
from repro.graph.hub_sort import hub_sort


def run(n_nodes: int = 20_000, n_edges: int = 320_000, n_partitions: int = 64):
    g = rmat_graph(n_nodes, n_edges, seed=10)
    hs = hub_sort(g)
    link = PCIE3.with_(mr=4.0)  # avoid transaction-group ties at CPU scale
    shares = {}
    for aname, prog, src in [
        ("pr", dataclasses.replace(PAGERANK, tolerance=1e-5), None),
        ("sssp", SSSP, 0),
    ]:
        cfg = HyTMConfig(n_partitions=n_partitions, link=link, cds_mode="hub")
        res = run_hytm(
            hs.graph, prog, source=int(hs.perm[0]) if src is not None else None,
            config=cfg, n_hubs=hs.n_hubs,
        )
        eng = res.history["engines"]
        for name, eid in [("filter", FILTER), ("compact", COMPACT), ("zerocopy", ZEROCOPY)]:
            share = (eng == eid).sum(axis=1) / eng.shape[1]
            shares[(aname, name)] = share
            emit(
                f"fig7/{aname}/{name}_share", 0.0,
                "|".join(f"{x:.2f}" for x in share[: min(16, len(share))]),
            )
    return shares


if __name__ == "__main__":
    run()
