"""Streaming benchmark: update-throughput and query-throughput of the
repro.stream serving stack against the full-recompute baseline.

Three measurements per run:

* ``update``  — edges/s applied through ``DeltaCSR.apply`` (device
  patches, no rebuild);
* ``inc-vs-full`` — per update batch, incremental warm-start
  recomputation vs from-scratch ``run_hytm`` on the post-update graph
  (wall time + sweep-iteration savings);
* ``query``   — lane-batched query service throughput vs sequential
  single-source runs, plus the cache-hit path.

``run_sharded`` (CLI: ``--devices N``) adds the mesh-serving leg: the
same serving stack with ``HyTMConfig.mesh_axis`` set, run in a
subprocess on N forced-host devices (jax locks the device count at first
init) — lane-batched sharded queries, scatter-patched updates against
the device-sharded (P_pad, B) grid, and warm-started sharded incremental
recomputation vs a cold sharded restart.  ``--selfcheck`` gates the
sharded leg: incremental must converge in strictly fewer sweep
iterations than the cold restart (and match it bit-for-bit) — the CI
acceptance gate for the sharded warm-start path.

``--smoke`` (also ``run(smoke=True)``) shrinks everything to finish in
well under 30 s on CPU — the CI configuration.
"""

from __future__ import annotations

import argparse
import subprocess
import sys
import textwrap
import time

import numpy as np

from benchmarks.common import emit
from repro.core.hytm import HyTMConfig, run_hytm
from repro.graph.algorithms import SSSP
from repro.graph.generators import rmat_graph
from repro.stream import GraphService, random_batch, run_incremental


def run(smoke: bool = False, n_nodes: int | None = None,
        n_edges: int | None = None, n_partitions: int | None = None,
        n_batches: int | None = None, batch_edges: int | None = None,
        n_queries: int | None = None, lanes: int = 4, seed: int = 21,
        trace_path: str | None = None):
    if smoke:
        n_nodes, n_edges, n_partitions = 1000, 8_000, 8
        n_batches, batch_edges, n_queries = 2, 48, 4
    else:
        n_nodes = n_nodes or 8000
        n_edges = n_edges or 128_000
        n_partitions = n_partitions or 32
        n_batches = n_batches or 6
        batch_edges = batch_edges or 256
        n_queries = n_queries or 16

    rec = None
    if trace_path is not None:
        from repro.obs import TraceRecorder

        rec = TraceRecorder()

    g = rmat_graph(n_nodes, n_edges, seed=seed)
    cfg = HyTMConfig(n_partitions=n_partitions)
    svc = GraphService(g, cfg, max_lanes=lanes, obs=rec)
    rng = np.random.default_rng(seed)

    # --- query throughput: lane-batched vs sequential ---------------------
    # vertex 0 (the RMAT hub) leads: it is also the warm-recompute probe,
    # and a hub source gives the convergence loop real depth
    sources = [0] + rng.integers(0, n_nodes, size=n_queries - 1).tolist()
    t0 = time.monotonic()
    batched = svc.query(SSSP, sources)
    t_batched = time.monotonic() - t0
    emit("stream/query_batched", t_batched * 1e6 / max(n_queries, 1),
         f"q_per_s={n_queries / max(t_batched, 1e-9):.1f} lanes={lanes}")

    rt = svc.dcsr.runtime_for(SSSP)
    t0 = time.monotonic()
    for s in sources:
        run_hytm(None, SSSP, source=s, config=cfg, runtime=rt)
    t_seq = time.monotonic() - t0
    emit("stream/query_sequential", t_seq * 1e6 / max(n_queries, 1),
         f"q_per_s={n_queries / max(t_seq, 1e-9):.1f} "
         f"speedup={t_seq / max(t_batched, 1e-9):.2f}x")

    t0 = time.monotonic()
    cached = svc.query(SSSP, sources)
    t_cache = time.monotonic() - t0
    assert all(r.cache_hit for r in cached)
    emit("stream/query_cached", t_cache * 1e6 / max(n_queries, 1),
         f"q_per_s={n_queries / max(t_cache, 1e-9):.0f} sweeps=0")

    # --- update throughput + incremental vs full recompute ----------------
    probe = sources[0]
    warm_vals = batched[0].values
    warm_delta = np.zeros(n_nodes, np.float32)
    t_apply = t_inc = t_full = 0.0
    iters_inc = iters_full = 0
    edges_applied = 0
    reports = []
    for _ in range(n_batches):
        b = random_batch(svc.dcsr, rng, n_insert=batch_edges // 2,
                         n_delete=batch_edges // 2)
        t0 = time.monotonic()
        rep = svc.update(b)
        t_apply += time.monotonic() - t0
        edges_applied += len(b)
        reports.append(rep)

        t0 = time.monotonic()
        inc = run_incremental(svc.dcsr, SSSP, reports, warm_vals, warm_delta,
                              source=probe, config=cfg)
        t_inc += time.monotonic() - t0
        iters_inc += inc.iterations

        t0 = time.monotonic()
        full = run_hytm(svc.dcsr.to_host_graph(), SSSP, source=probe, config=cfg)
        t_full += time.monotonic() - t0
        iters_full += full.iterations

        np.testing.assert_array_equal(inc.values, full.values)
        warm_vals, warm_delta = inc.values, inc.delta
        reports = []

    emit("stream/update_apply", t_apply * 1e6 / max(n_batches, 1),
         f"edges_per_s={edges_applied / max(t_apply, 1e-9):.0f}")
    emit("stream/recompute_incremental", t_inc * 1e6 / max(n_batches, 1),
         f"iters={iters_inc}")
    emit("stream/recompute_full", t_full * 1e6 / max(n_batches, 1),
         f"iters={iters_full} iter_savings="
         f"{(1 - iters_inc / max(iters_full, 1)) * 100:.0f}%")
    if rec is not None:
        from repro.obs import write_chrome_trace

        write_chrome_trace(rec, trace_path)
        print(f"# trace: {len(rec)} events -> {trace_path}")
    return {
        "batched_s": t_batched, "sequential_s": t_seq,
        "iters_inc": iters_inc, "iters_full": iters_full,
    }


_SHARDED_SERVING_SCRIPT = """
    import time
    import numpy as np
    import jax
    from repro.core.hytm import HyTMConfig, run_hytm
    from repro.graph.algorithms import SSSP
    from repro.graph.generators import rmat_graph
    from repro.stream import GraphService, random_batch, run_incremental

    n_dev = len(jax.devices())
    n_nodes = {n_nodes}
    g = rmat_graph(n_nodes, {n_edges}, seed={seed})
    cfg = HyTMConfig(n_partitions={n_partitions}, async_sweep=False,
                     mesh_axis="graph")
    svc = GraphService(g, cfg, max_lanes={lanes})
    rng = np.random.default_rng({seed})

    sources = [0] + rng.integers(0, n_nodes, size={n_queries} - 1).tolist()
    t0 = time.monotonic()
    batched = svc.query(SSSP, sources)
    t_query = time.monotonic() - t0

    # warm-started sharded incremental vs cold sharded restart, per batch
    rt = svc.dcsr.sharded_runtime_for(SSSP, mesh=svc.mesh, axis="graph")
    warm_vals = batched[0].values
    warm_delta = np.zeros(n_nodes, np.float32)
    t_apply = t_inc = t_cold = 0.0
    iters_inc = iters_cold = 0
    edges_applied = 0
    for _ in range({n_batches}):
        b = random_batch(svc.dcsr, rng, n_insert={batch_edges} // 2,
                         n_delete={batch_edges} // 2)
        t0 = time.monotonic()
        rep = svc.update(b)
        t_apply += time.monotonic() - t0
        edges_applied += len(b)

        t0 = time.monotonic()
        inc = run_incremental(svc.dcsr, SSSP, [rep], warm_vals, warm_delta,
                              source=0, config=cfg, mesh=svc.mesh)
        t_inc += time.monotonic() - t0
        iters_inc += inc.iterations

        t0 = time.monotonic()
        cold = run_hytm(None, SSSP, source=0, config=cfg, runtime=rt,
                        mesh=svc.mesh)
        t_cold += time.monotonic() - t0
        iters_cold += cold.iterations

        np.testing.assert_array_equal(inc.values, cold.values)
        warm_vals, warm_delta = inc.values, inc.delta
    print(f"RESULT,{{n_dev}},{{t_query * 1e6:.1f}},{{t_apply * 1e6:.1f}},"
          f"{{edges_applied}},{{t_inc * 1e6:.1f}},{{t_cold * 1e6:.1f}},"
          f"{{iters_inc}},{{iters_cold}}")
"""


def run_sharded(n_devices: int = 4, smoke: bool = False,
                selfcheck: bool = False, seed: int = 23) -> dict:
    """Mesh-serving leg on ``n_devices`` forced-host devices (its own
    subprocess — jax locks the device count at first init).  With
    ``selfcheck`` the run exits non-zero unless sharded incremental
    recomputation beats the cold sharded restart in sweep iterations."""
    if smoke:
        kw = dict(n_nodes=800, n_edges=6_400, n_partitions=8,
                  n_batches=3, batch_edges=32, n_queries=4, lanes=4)
    else:
        kw = dict(n_nodes=4_000, n_edges=64_000, n_partitions=16,
                  n_batches=4, batch_edges=128, n_queries=8, lanes=4)
    kw["seed"] = seed
    from repro.launch.mesh import forced_host_device_env

    out = subprocess.run(
        [sys.executable, "-c",
         textwrap.dedent(_SHARDED_SERVING_SCRIPT.format(**kw))],
        capture_output=True, text=True, timeout=600,
        env=forced_host_device_env(n_devices),
    )
    if out.returncode != 0:
        emit(f"stream/sharded_devices_{n_devices}", 0.0,
             f"FAILED: {out.stderr[-300:]}")
        raise SystemExit(
            f"sharded serving leg failed:\n{out.stderr[-3000:]}")
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT,")][0]
    (_, n_dev, t_query, t_apply, edges, t_inc, t_cold,
     iters_inc, iters_cold) = line.split(",")
    nq, nb = kw["n_queries"], kw["n_batches"]
    emit(f"stream/sharded{n_dev}_query_batched", float(t_query) / nq,
         f"lanes={kw['lanes']} devices={n_dev}")
    emit(f"stream/sharded{n_dev}_update_apply", float(t_apply) / nb,
         f"edges={edges}")
    emit(f"stream/sharded{n_dev}_recompute_incremental",
         float(t_inc) / nb, f"iters={iters_inc}")
    emit(f"stream/sharded{n_dev}_recompute_cold", float(t_cold) / nb,
         f"iters={iters_cold} iter_savings="
         f"{(1 - int(iters_inc) / max(int(iters_cold), 1)) * 100:.0f}%")
    rows = {"iters_inc": int(iters_inc), "iters_cold": int(iters_cold)}
    if selfcheck:
        if not rows["iters_inc"] < rows["iters_cold"]:
            raise SystemExit(
                f"SELFCHECK FAILED: sharded incremental took "
                f"{rows['iters_inc']} iterations vs cold restart "
                f"{rows['iters_cold']}")
        print(f"# SELFCHECK OK: sharded incremental {rows['iters_inc']} "
              f"iters < cold restart {rows['iters_cold']} iters "
              f"on {n_dev} devices")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configuration (<30 s on CPU; CI mode)")
    ap.add_argument("--devices", type=int, default=0, metavar="N",
                    help="also run the sharded serving leg on N "
                         "forced-host devices (subprocess)")
    ap.add_argument("--selfcheck", action="store_true",
                    help="gate the sharded leg: incremental must beat "
                         "the cold sharded restart (requires --devices)")
    ap.add_argument("--seed", type=int, default=None,
                    help="RNG seed for the graph, the query sources and "
                         "the update batches (default: 21 local, 23 sharded)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON (repro.obs) of "
                         "the local serving run to PATH (chrome://tracing "
                         "/ Perfetto); local leg only")
    args = ap.parse_args()
    if args.selfcheck and not args.devices:
        raise SystemExit("--selfcheck needs --devices N")
    print("name,us_per_call,derived")
    t0 = time.monotonic()
    if args.devices:
        out = run_sharded(n_devices=args.devices, smoke=args.smoke,
                          selfcheck=args.selfcheck,
                          **({} if args.seed is None else {"seed": args.seed}))
        emit("stream/sharded_total_wall", (time.monotonic() - t0) * 1e6,
             f"iters_inc={out['iters_inc']} iters_cold={out['iters_cold']}")
        return
    out = run(smoke=args.smoke, trace_path=args.trace,
              **({} if args.seed is None else {"seed": args.seed}))
    emit("stream/total_wall", (time.monotonic() - t0) * 1e6,
         f"iters_inc={out['iters_inc']} iters_full={out['iters_full']}")


if __name__ == "__main__":
    main()
