"""Per-kernel wall timing (interpret mode on CPU — correctness-path cost,
not TPU perf; TPU perf comes from the roofline analysis).

``python -m benchmarks.kernels --selfcheck`` runs the per-engine roofline
gate instead (benchmarks.roofline.engine_gate): kernel-vs-oracle
equivalence for every Algorithm-1 engine plus the achieved-vs-modeled
bandwidth check, in interpret mode on CPU — the CI acceptance leg."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.frontier_compact.ops import frontier_compact
from repro.kernels.grouped_matmul.ops import grouped_matmul
from repro.kernels.hyb_gather.ops import hyb_gather
from repro.kernels.segment_spmm.ops import segment_spmm

RNG = np.random.default_rng(0)


def run(fast: bool = False):
    """``fast``: single timed repeat per kernel (interpret mode dominates
    the cost; the shapes are already small)."""
    repeats = 1 if fast else 3
    msg = jnp.asarray(RNG.standard_normal((4096, 128)), jnp.float32)
    seg = jnp.asarray(RNG.integers(0, 512, 4096), jnp.int32)
    val = jnp.ones(4096, bool)
    _, us = timed(lambda: jax.block_until_ready(segment_spmm(msg, seg, 512, val)), repeats=repeats)
    emit("kernels/segment_spmm_4096x128", us, "interpret")

    vals = jnp.asarray(RNG.standard_normal((4096, 3)), jnp.float32)
    mask = jnp.asarray(RNG.random(4096) < 0.3)
    _, us = timed(lambda: jax.block_until_ready(frontier_compact(vals, mask)[0]), repeats=repeats)
    emit("kernels/frontier_compact_4096x3", us, "interpret")

    edges = jnp.asarray(RNG.standard_normal((8192, 2)), jnp.float32)
    starts = jnp.asarray(RNG.integers(0, 8000, 64), jnp.int32)
    degs = jnp.asarray(RNG.integers(1, 128, 64), jnp.int32)
    _, us = timed(lambda: jax.block_until_ready(hyb_gather(edges, starts, degs)), repeats=repeats)
    emit("kernels/hyb_gather_64v", us, "interpret")

    q = jnp.asarray(RNG.standard_normal((4, 512, 64)), jnp.float32)
    _, us = timed(lambda: jax.block_until_ready(flash_attention(q, q, q, window=128)), repeats=repeats)
    emit("kernels/flash_attention_512", us, "interpret")

    t = jnp.asarray(RNG.standard_normal((1000, 128)), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, 1000, (64, 4)), jnp.int32)
    _, us = timed(lambda: jax.block_until_ready(embedding_bag(t, idx)), repeats=repeats)
    emit("kernels/embedding_bag_64x4", us, "interpret")

    counts = jnp.asarray(RNG.integers(0, 128, 8), jnp.int32)
    st = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1]])
    x = jnp.asarray(RNG.standard_normal((int(counts.sum()) + 8, 64)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((8, 64, 128)), jnp.float32)
    _, us = timed(lambda: jax.block_until_ready(grouped_matmul(x, w, st, counts)), repeats=repeats)
    emit("kernels/grouped_matmul_8e", us, "interpret")


def selfcheck(fast: bool = True) -> None:
    """Run the per-engine roofline gate; raise (non-zero exit) on failure."""
    from benchmarks import roofline

    rows = roofline.engine_gate(fast=fast)
    for r in rows:
        print(
            f"selfcheck/{r['engine']}: achieved={r['achieved_gbs']:.3f} GB/s "
            f"modeled={r['modeled_gbs']:.3f} GB/s ratio={r['ratio']:.2e} "
            f"({r['points']} points)"
        )
    print("kernels --selfcheck OK: engine kernels match oracles; bandwidths sane")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="single timed repeat / smaller gate grid")
    ap.add_argument("--selfcheck", action="store_true",
                    help="run the engine roofline gate instead of the timings")
    args = ap.parse_args()
    if args.selfcheck:
        selfcheck(fast=True)
    else:
        run(fast=args.fast)
