"""Continuous-serving benchmark: the repro.serve scheduler under a
multi-tenant closed-loop trace, against the fixed-batch baseline.

The driver replays a seeded mixed trace — interleaved edge-update
batches and bursty multi-tenant query arrivals (SSSP traversals plus
personalized-PageRank Δ-push lanes in the default ``mixed`` scenario) —
through two serving stacks over identical graphs and identical update
sequences:

* **continuous** — ``LaneScheduler`` as shipped: static lane buckets,
  deadline-first admission with per-tenant quotas and a device byte
  budget, converged lanes freed at chunk boundaries and backfilled
  mid-flight, warm states spilling through the two-tier cache;
* **baseline** — the same engine degraded to fixed-batch serving: one
  bucket (``max_lanes``), FIFO order, no backfill — every batch runs to
  full convergence before the queue is consulted again.

Latency is measured on the **virtual clock** (cumulative engine sweep
iterations — deterministic run-to-run, which CI's p99 gate needs) with
wall-clock QPS reported alongside.  Reported per run: p50/p99 virtual
and wall latency, QPS, lane occupancy, cache-tier hit/spill/promotion
counters, and admission counters.

``--arrival poisson:<rate>`` paces submissions with seeded exponential
inter-arrival gaps (open-loop wall-clock arrivals) instead of the
default instantaneous per-step bursts; ``--trace <path>`` records the
continuous run through ``repro.obs`` and writes a Chrome trace-event
JSON (tenant/scheduler/cache tracks, chrome://tracing / Perfetto).

``--selfcheck`` gates (CI):
  1. equal answers — every request served by the continuous stack
     matches the baseline bit-exactly (MIN) / within tolerance (SUM),
     and a no-update tail phase matches standalone ``run_hytm``;
  2. p99 virtual latency strictly better than the baseline;
  3. zero quota violations; peak device-resident bytes within budget;
  4. compile count ≤ one batched chunk per (lane bucket, program).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from benchmarks.common import emit
from repro.core.hytm import HyTMConfig, hytm_batched_chunk, run_hytm
from repro.graph.algorithms import PPR, SSSP
from repro.graph.generators import rmat_graph
from repro.serve import LaneScheduler, Request, RequestQueue
from repro.stream import GraphService, random_batch

TENANTS = {"gold": 3, "silver": 2, "bronze": 1}   # per-tenant lane quotas


def _make_trace(rng: np.random.Generator, n_steps: int, n_nodes: int,
                burst_lo: int, burst_hi: int, update_edges: int,
                scenario: str) -> list[dict]:
    """Seeded trace: each step is an optional update batch followed by a
    burst of tenant-tagged requests.  Sources draw from a small hot pool
    (hub 0 + a few dozen vertices) so repeat queries exercise the warm
    cache across updates; deadline slack is tenant-tiered (gold tight,
    bronze lax)."""
    pool = np.concatenate([[0], rng.integers(1, n_nodes, size=24)])
    slack = {"gold": 8.0, "silver": 64.0, "bronze": 512.0}
    tenants = list(TENANTS)
    trace = []
    for step in range(n_steps):
        burst = int(rng.integers(burst_lo, burst_hi + 1))
        reqs = []
        for _ in range(burst):
            tenant = tenants[int(rng.integers(len(tenants)))]
            use_ppr = scenario == "mixed" and rng.random() < 0.3
            reqs.append({
                "tenant": tenant,
                "program": "ppr" if use_ppr else "sssp",
                "source": int(pool[int(rng.integers(len(pool)))]),
                "slack": slack[tenant],
            })
        trace.append({
            "update": step > 0 and update_edges > 0,
            "update_edges": update_edges,
            "requests": reqs,
        })
    return trace


def _parse_arrival(spec: str) -> float | None:
    """``burst`` (default: a whole step's burst arrives at once) or
    ``poisson:<rate>`` — seeded exponential inter-arrival gaps at
    ``<rate>`` requests/second pace the submissions on the wall clock."""
    if spec == "burst":
        return None
    if spec.startswith("poisson:"):
        rate = float(spec.split(":", 1)[1])
        if rate <= 0:
            raise argparse.ArgumentTypeError(
                f"poisson rate must be > 0, got {rate}")
        return rate
    raise argparse.ArgumentTypeError(
        f"--arrival must be 'burst' or 'poisson:<rate>', got {spec!r}")


def _replay(svc: GraphService, sched: LaneScheduler, trace: list[dict],
            update_rng: np.random.Generator, ppr, deadlines: bool,
            arrival_rate: float | None = None,
            arrival_rng: np.random.Generator | None = None) -> list:
    """Run the trace through one scheduler closed-loop: submit each
    step's burst (deadline = now + slack on the virtual clock, or FIFO
    when ``deadlines`` is off), apply the step's update, pump to
    completion.  Returns all ServedResults in completion order.

    With ``arrival_rate`` set, submissions within a step are paced by
    seeded Poisson wall-clock arrivals (exponential inter-arrival gaps
    from ``arrival_rng``) instead of landing as one instantaneous burst.
    Answers and the virtual-clock latency gates are arrival-independent;
    only the wall-clock latency distribution moves."""
    queue = RequestQueue(tenant_quotas=dict(TENANTS))
    programs = {"sssp": SSSP, "ppr": ppr}
    served = []
    for step in trace:
        if step["update"]:
            svc.update(random_batch(
                svc.dcsr, update_rng,
                n_insert=step["update_edges"] // 2,
                n_delete=step["update_edges"] // 2))
        for r in step["requests"]:
            if arrival_rate is not None:
                time.sleep(float(arrival_rng.exponential(1.0 / arrival_rate)))
            queue.submit(Request(
                tenant=r["tenant"], program=programs[r["program"]],
                source=r["source"],
                deadline=(sched.vt + r["slack"] if deadlines
                          else float("inf")),
                submit_vt=sched.vt, submit_wall=time.monotonic(),
            ))
        served.extend(sched.pump(queue))
    return served


def _percentiles(served, clock: str) -> tuple[float, float]:
    lat = np.array([getattr(r, f"{clock}_latency") for r in served
                    if r.mode != "rejected"])
    return float(np.percentile(lat, 50)), float(np.percentile(lat, 99))


def run(smoke: bool = False, seed: int = 0, scenario: str = "mixed",
        selfcheck: bool = False, n_nodes: int | None = None,
        n_edges: int | None = None, lanes: int | None = None,
        n_steps: int | None = None, arrival_rate: float | None = None,
        trace_path: str | None = None) -> dict:
    if smoke:
        n_nodes, n_edges, lanes, n_steps = 600, 4_800, 4, 5
        burst_lo, burst_hi, update_edges = 5, 11, 24
        n_partitions = 8
    else:
        n_nodes = n_nodes or 4_000
        n_edges = n_edges or 48_000
        lanes = lanes or 8
        n_steps = n_steps or 8
        burst_lo, burst_hi, update_edges = 4, 2 * lanes + 3, 96
        n_partitions = 16

    ppr = dataclasses.replace(PPR, tolerance=1e-7)
    cfg = HyTMConfig(n_partitions=n_partitions, sync_every=4)
    lane_bytes = 9 * n_nodes
    # budget: the full lane bucket + ~4 cached entries on device; the
    # rest of the warm set lives in (and returns from) the host tier
    budget = lanes * lane_bytes + 4 * 8 * n_nodes

    rec = None
    if trace_path is not None:
        from repro.obs import TraceRecorder

        rec = TraceRecorder()

    def build(backfill: bool):
        g = rmat_graph(n_nodes, n_edges, seed=seed + 1)
        # only the continuous run is traced — the baseline replay would
        # interleave its events onto the same tracks
        svc = GraphService(g, cfg, max_lanes=lanes,
                           device_budget_bytes=budget,
                           obs=rec if backfill else None)
        if not backfill:
            svc.scheduler = LaneScheduler(svc, buckets=(lanes,),
                                          backfill=False)
        return svc

    trace = _make_trace(np.random.default_rng(seed), n_steps, n_nodes,
                        burst_lo, burst_hi, update_edges, scenario)

    # --- continuous scheduler (compile-count window around it) ------------
    svc = build(backfill=True)
    c0 = hytm_batched_chunk._cache_size()
    t0 = time.monotonic()
    served = _replay(svc, svc.scheduler, trace,
                     np.random.default_rng(seed + 2), ppr, deadlines=True,
                     arrival_rate=arrival_rate,
                     arrival_rng=np.random.default_rng(seed + 3))
    wall = time.monotonic() - t0
    compiles = hytm_batched_chunk._cache_size() - c0

    # --- fixed-batch baseline over the identical trace --------------------
    base = build(backfill=False)
    t0 = time.monotonic()
    base_served = _replay(base, base.scheduler, trace,
                          np.random.default_rng(seed + 2), ppr,
                          deadlines=False, arrival_rate=arrival_rate,
                          arrival_rng=np.random.default_rng(seed + 3))
    base_wall = time.monotonic() - t0

    sched, q = svc.scheduler, served
    p50_v, p99_v = _percentiles(q, "vt")
    p50_w, p99_w = _percentiles(q, "wall")
    bp50_v, bp99_v = _percentiles(base_served, "vt")
    n_req = sum(len(s["requests"]) for s in trace)
    cache = svc.cache.stats
    emit("serve/p99_virtual", p99_v,
         f"p50={p50_v:.0f} baseline_p99={bp99_v:.0f} "
         f"baseline_p50={bp50_v:.0f} (engine iterations)")
    emit("serve/p99_wall", p99_w * 1e6, f"p50_us={p50_w * 1e6:.0f}")
    emit("serve/qps", wall * 1e6 / max(n_req, 1),
         f"qps={n_req / max(wall, 1e-9):.1f} "
         f"baseline_qps={n_req / max(base_wall, 1e-9):.1f}")
    emit("serve/occupancy", sched.stats.occupancy * 100,
         f"backfills={sched.stats.backfills} batches={sched.stats.batches} "
         f"chunks={sched.stats.chunks}")
    hits = cache.device_hits + cache.host_hits
    emit("serve/cache_tiers", 100.0 * hits / max(hits + cache.misses, 1),
         f"device={cache.device_hits} host={cache.host_hits} "
         f"miss={cache.misses} spill={cache.spills} "
         f"promote={cache.promotions}")
    qs = served and served[0].request and None  # keep flake-free
    qstats = _replay_queue_stats(served)
    emit("serve/admission", compiles,
         f"compiles={compiles} buckets={sched.buckets} "
         f"max_device_bytes={sched.stats.max_device_bytes} "
         f"budget={budget} rejected={qstats['rejected']}")

    rows = {
        "p99_virtual": p99_v, "baseline_p99_virtual": bp99_v,
        "p50_virtual": p50_v, "baseline_p50_virtual": bp50_v,
        "compiles": compiles, "n_buckets": len(sched.buckets),
        "max_device_bytes": sched.stats.max_device_bytes,
        "budget": budget, "occupancy": sched.stats.occupancy,
        "served": len(served), "baseline_served": len(base_served),
    }

    if selfcheck:
        _selfcheck(svc, served, base_served, rows, ppr, cfg)
    if rec is not None:
        from repro.obs import write_chrome_trace

        write_chrome_trace(rec, trace_path)
        print(f"# trace: {len(rec)} events -> {trace_path}")
    return rows


def _replay_queue_stats(served) -> dict:
    return {"rejected": sum(1 for r in served if r.mode == "rejected")}


def _selfcheck(svc, served, base_served, rows, ppr, cfg) -> None:
    # 1a. equal answers vs the fixed-batch baseline: same trace, same
    # update points, so request-for-request the graphs match — MIN
    # bit-exact, SUM within tolerance
    assert len(served) == len(base_served)
    key = lambda r: (r.request.arrival % 10**9,)  # noqa: E731
    a_sorted = sorted(served, key=lambda r: r.request.arrival)
    b_sorted = sorted(base_served, key=lambda r: r.request.arrival)
    for a, b in zip(a_sorted, b_sorted):
        assert a.request.source == b.request.source
        assert (a.mode == "rejected") == (b.mode == "rejected")
        if a.mode == "rejected":
            continue
        if a.request.program.combine == 0:  # MIN
            np.testing.assert_array_equal(a.values, b.values)
        else:
            assert np.max(np.abs(a.values - b.values)) < 1e-4
    # 1b. tail phase with no updates: continuous results == standalone
    # run_hytm on the current graph (fresh, uncached sources)
    g_now = svc.dcsr.to_host_graph()
    tail = [s for s in range(50, 58)]
    res = svc.query(SSSP, tail)
    for s, r in zip(tail, res):
        if r.mode == "cache":
            continue
        solo = run_hytm(g_now, SSSP, source=s, config=cfg)
        np.testing.assert_array_equal(r.values, solo.values)
    r_ppr = svc.query(ppr, [tail[0]])[0]
    solo = run_hytm(g_now, ppr, source=tail[0], config=cfg)
    assert np.max(np.abs(r_ppr.values - solo.values)) < 1e-4
    # 2. latency gate: continuous p99 strictly better than fixed-batch
    assert rows["p99_virtual"] < rows["baseline_p99_virtual"], (
        f"p99 {rows['p99_virtual']} !< baseline "
        f"{rows['baseline_p99_virtual']}")
    # 3. budget + quotas (quota violations are structurally impossible —
    # asserted via the peak in-flight audit in tests/test_serve.py; here
    # we check the byte budget held)
    assert rows["max_device_bytes"] <= rows["budget"], rows
    # 4. compile discipline: at most one batched-chunk trace per
    # (bucket, program) over the whole serving lifetime
    assert rows["compiles"] <= 2 * rows["n_buckets"], rows
    print(f"# SELFCHECK OK: p99 {rows['p99_virtual']:.0f} < baseline "
          f"{rows['baseline_p99_virtual']:.0f} (virtual); "
          f"{rows['compiles']} compiles for {rows['n_buckets']} buckets "
          f"x 2 programs; peak {rows['max_device_bytes']} <= "
          f"budget {rows['budget']} bytes")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configuration (<30 s on CPU; CI mode)")
    ap.add_argument("--seed", type=int, default=0,
                    help="trace/graph/update RNG seed (threaded into "
                         "every generator)")
    ap.add_argument("--scenario", default="mixed",
                    choices=["mixed", "sssp"],
                    help="mixed = SSSP + personalized-PageRank lanes")
    ap.add_argument("--selfcheck", action="store_true",
                    help="gate: equal answers, p99 < fixed-batch "
                         "baseline, budget held, one compile per bucket")
    ap.add_argument("--arrival", type=_parse_arrival, default="burst",
                    metavar="burst|poisson:<rate>",
                    help="request arrival process: 'burst' (default, a "
                         "step's requests land at once) or "
                         "'poisson:<rate>' — seeded exponential "
                         "inter-arrival gaps at <rate> req/s pace "
                         "submissions on the wall clock")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON (repro.obs) of "
                         "the continuous run to PATH — one track per "
                         "tenant/scheduler/cache, loadable in "
                         "chrome://tracing or Perfetto")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    t0 = time.monotonic()
    rows = run(smoke=args.smoke, seed=args.seed, scenario=args.scenario,
               selfcheck=args.selfcheck, arrival_rate=args.arrival,
               trace_path=args.trace)
    emit("serve/total_wall", (time.monotonic() - t0) * 1e6,
         f"served={rows['served']} occupancy={rows['occupancy']:.2f}")


if __name__ == "__main__":
    main()
