"""Roofline analysis from the dry-run's compiled artifacts.

For every (arch x shape x mesh) JSON produced by repro.launch.dryrun:

  compute term    = HLO_FLOPs(per-device) / peak_FLOP/s
  memory term     = HLO_bytes(per-device) / HBM_bw
  collective term = wire_bytes(per-device, ring-factored) / link_bw

plus MODEL_FLOPS / (HLO_FLOPs * n_devices) — the useful-compute ratio
(catching remat/redundancy waste) — and the dominant bottleneck.

No jax required: this module only reads the JSON records, so it runs in
the 1-device benchmark process.
"""

from __future__ import annotations

import glob
import json
import os

from repro.core.constants import HBM_BANDWIDTH, ICI_BANDWIDTH, PEAK_FLOPS_BF16

from benchmarks.common import emit


def analyze_record(rec: dict) -> dict | None:
    if rec.get("status") != "OK":
        return None
    flops = rec["cost"]["flops"]
    mem_bytes = rec["cost"]["bytes_accessed"]
    wire = sum(c["wire_bytes"] for c in rec["collectives"].values())
    model_flops = rec.get("model_flops", 0.0)
    hlo_total = flops * rec["n_devices"]
    useful = model_flops / hlo_total if hlo_total else 0.0
    # XLA:CPU cost_analysis counts while-loop (scan) bodies approximately:
    # a useful_ratio >> 1 flags trip-count under-attribution.  The compute
    # term therefore uses the ANALYTIC model FLOPs when they exceed the
    # HLO count; memory/collective terms are scaled by the same loop
    # factor (the under-counted loop body contains the bulk of both).
    correction = max(useful, 1.0)
    t_compute = max(flops, model_flops / rec["n_devices"]) / PEAK_FLOPS_BF16
    t_memory = mem_bytes * correction / HBM_BANDWIDTH
    t_coll = wire * correction / ICI_BANDWIDTH
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    # achievable fraction of the compute roofline if perfectly overlapped
    frac = t_compute / bound if bound > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute": t_compute, "t_memory": t_memory, "t_collective": t_coll,
        "dominant": dominant, "useful_ratio": useful,
        "roofline_fraction": frac,
        "peak_gib": rec["memory"]["peak_device_bytes"] / 2**30,
    }


def load_all(dryrun_dir: str = "experiments/dryrun") -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        row = analyze_record(rec)
        if row is None:
            out.append({
                "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
                "skip": rec.get("reason", rec.get("error", ""))[:80],
            })
        else:
            out.append(row)
    return out


def markdown_table(rows: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) | dominant | useful | roofline frac | peak GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "skip" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP: {r['skip']} |||||||")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['t_compute']:.2e} | "
            f"{r['t_memory']:.2e} | {r['t_collective']:.2e} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} | {r['peak_gib']:.2f} |"
        )
    return "\n".join(lines)


def run(dryrun_dir: str = "experiments/dryrun", fast: bool = False):
    """``fast``: cap the per-config rows emitted (the summary row still
    covers everything) — keeps ``--fast`` sweeps short on machines with a
    large accumulated dry-run directory."""
    rows = load_all(dryrun_dir)
    ok = [r for r in rows if "skip" not in r]
    for r in ok[:8] if fast else ok:
        emit(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
            f"dom={r['dominant']};frac={r['roofline_fraction']:.2f};useful={r['useful_ratio']:.2f}",
        )
    if ok:
        worst = sorted(ok, key=lambda r: r["roofline_fraction"])[:5]
        emit("roofline/worst5", 0.0,
             ";".join(f"{r['arch']}/{r['shape']}/{r['mesh']}={r['roofline_fraction']:.2f}" for r in worst))
    return rows


if __name__ == "__main__":
    print(markdown_table(run()))
