"""Roofline analysis from the dry-run's compiled artifacts.

For every (arch x shape x mesh) JSON produced by repro.launch.dryrun:

  compute term    = HLO_FLOPs(per-device) / peak_FLOP/s
  memory term     = HLO_bytes(per-device) / HBM_bw
  collective term = wire_bytes(per-device, ring-factored) / link_bw

plus MODEL_FLOPS / (HLO_FLOPs * n_devices) — the useful-compute ratio
(catching remat/redundancy waste) — and the dominant bottleneck.

The dry-run half needs no jax (it only reads JSON records, so it runs in
the 1-device benchmark process).  The *engine* half
(:func:`engine_rooflines` / :func:`engine_gate`) does import jax: it
wall-probes the kernel-backed Algorithm-1 engines
(``HyTMConfig.use_kernels``) and gates their achieved bytes/second
against the cost model's per-engine bandwidth line
(``cost_model.engine_bandwidths``) — the ``benchmarks.kernels
--selfcheck`` acceptance run in CI.
"""

from __future__ import annotations

import glob
import json
import os

from repro.core.constants import HBM_BANDWIDTH, ICI_BANDWIDTH, PEAK_FLOPS_BF16

from benchmarks.common import emit


def analyze_record(rec: dict) -> dict | None:
    if rec.get("status") != "OK":
        return None
    flops = rec["cost"]["flops"]
    mem_bytes = rec["cost"]["bytes_accessed"]
    wire = sum(c["wire_bytes"] for c in rec["collectives"].values())
    model_flops = rec.get("model_flops", 0.0)
    hlo_total = flops * rec["n_devices"]
    useful = model_flops / hlo_total if hlo_total else 0.0
    # XLA:CPU cost_analysis counts while-loop (scan) bodies approximately:
    # a useful_ratio >> 1 flags trip-count under-attribution.  The compute
    # term therefore uses the ANALYTIC model FLOPs when they exceed the
    # HLO count; memory/collective terms are scaled by the same loop
    # factor (the under-counted loop body contains the bulk of both).
    correction = max(useful, 1.0)
    t_compute = max(flops, model_flops / rec["n_devices"]) / PEAK_FLOPS_BF16
    t_memory = mem_bytes * correction / HBM_BANDWIDTH
    t_coll = wire * correction / ICI_BANDWIDTH
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    # achievable fraction of the compute roofline if perfectly overlapped
    frac = t_compute / bound if bound > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute": t_compute, "t_memory": t_memory, "t_collective": t_coll,
        "dominant": dominant, "useful_ratio": useful,
        "roofline_fraction": frac,
        "peak_gib": rec["memory"]["peak_device_bytes"] / 2**30,
    }


def load_all(dryrun_dir: str = "experiments/dryrun") -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        row = analyze_record(rec)
        if row is None:
            out.append({
                "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
                "skip": rec.get("reason", rec.get("error", ""))[:80],
            })
        else:
            out.append(row)
    return out


def markdown_table(rows: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) | dominant | useful | roofline frac | peak GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "skip" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP: {r['skip']} |||||||")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['t_compute']:.2e} | "
            f"{r['t_memory']:.2e} | {r['t_collective']:.2e} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} | {r['peak_gib']:.2f} |"
        )
    return "\n".join(lines)


def run(dryrun_dir: str = "experiments/dryrun", fast: bool = False):
    """``fast``: cap the per-config rows emitted (the summary row still
    covers everything) — keeps ``--fast`` sweeps short on machines with a
    large accumulated dry-run directory."""
    rows = load_all(dryrun_dir)
    ok = [r for r in rows if "skip" not in r]
    for r in ok[:8] if fast else ok:
        emit(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
            f"dom={r['dominant']};frac={r['roofline_fraction']:.2f};useful={r['useful_ratio']:.2f}",
        )
    if ok:
        worst = sorted(ok, key=lambda r: r["roofline_fraction"])[:5]
        emit("roofline/worst5", 0.0,
             ";".join(f"{r['arch']}/{r['shape']}/{r['mesh']}={r['roofline_fraction']:.2f}" for r in worst))
    return rows


# --------------------------------------------------------------------------
# Per-engine roofline: achieved vs modeled bandwidth of the kernel path
# --------------------------------------------------------------------------

# On TPU the kernel-backed engines must achieve at least this fraction of
# the modeled bandwidth line; interpret mode on CPU emulates the kernels
# lane-by-lane, so there the gate only checks the bandwidths are finite,
# positive, and self-consistent (the correctness half still runs in full).
ENGINE_RATIO_FLOOR = 0.02


def engine_rooflines(
    n_points: int = 3,
    max_edges: int = 4096,
    repeats: int = 2,
    link=None,
    seed: int = 0,
) -> list[dict]:
    """Wall-probe the KERNEL-backed engines and compare achieved vs
    modeled bandwidth, per engine.

    achieved = Table-VI modeled bytes / measured wall seconds (the same
    byte accounting ``HyTMResult`` reports, over the engine's real
    execution), aggregated across the probe grid; modeled = the
    ``cost_model.engine_bandwidths`` line (bytes / Eqs. 1-3 execution
    seconds) over the same materialized partitions.  The ratio is the
    per-engine roofline fraction: how much of the bandwidth the cost
    model *assumes* the engine actually delivers.
    """
    import numpy as np

    from repro.autotune.probe import (
        default_grid,
        observation_matrix,
        stats_for,
        wall_probe,
    )
    from repro.core.constants import PCIE3
    from repro.core.cost_model import (
        COMPACT,
        FILTER,
        ZEROCOPY,
        ENGINE_NAMES,
        engine_bandwidths,
        engine_costs,
    )

    link = link or PCIE3
    grid = default_grid(
        edge_levels=(float(max_edges),), n_ratios=n_points, regimes=("mid",)
    )
    realized, obs = wall_probe(
        grid, max_edges=max_edges, repeats=repeats, seed=seed, use_kernels=True
    )
    meas = observation_matrix(realized, obs).T          # (3, N) seconds
    stats = stats_for(realized, link)
    costs = engine_costs(stats, link)
    byt = np.stack([                                    # (3, N) modeled bytes
        np.asarray(stats.total_edges) * link.d1,
        np.asarray(stats.active_edges) * link.d1
        + np.asarray(stats.active_vertices) * link.d2,
        np.asarray(stats.zc_requests) * link.m,
    ])
    modeled_bw = np.asarray(engine_bandwidths(stats, costs, link))  # (3, N)
    rows = []
    for eng in (FILTER, COMPACT, ZEROCOPY):
        wall = float(meas[eng].sum())
        achieved = float(byt[eng].sum()) / max(wall, 1e-30)
        # byte-weighted modeled bandwidth over the same grid
        modeled = float(byt[eng].sum()) / max(
            float((byt[eng] / np.maximum(modeled_bw[eng], 1e-30)).sum()), 1e-30
        )
        rows.append({
            "engine": ENGINE_NAMES[eng],
            "wall_us": wall * 1e6 / max(len(realized), 1),
            "achieved_gbs": achieved / 1e9,
            "modeled_gbs": modeled / 1e9,
            "ratio": achieved / modeled if modeled > 0 else 0.0,
            "points": len(realized),
        })
    return rows


def engine_gate(fast: bool = True, link=None, seed: int = 0) -> list[dict]:
    """The kernel-path acceptance gate (``benchmarks.kernels --selfcheck``).

    1. Equivalence: each kernel-backed engine must reproduce its pure-JAX
       oracle bit-exactly for the MIN combiner on a materialized probe
       block (the `use_kernels` contract, tests/test_engines.py).
    2. Bandwidths: every per-engine achieved and modeled bandwidth must
       be finite and positive; on TPU the achieved/modeled ratio must
       additionally clear :data:`ENGINE_RATIO_FLOOR` (interpret mode on
       CPU is an emulator — its wall time says nothing about DMA reality).

    Raises ``AssertionError`` on violation; returns the roofline rows.
    """
    import numpy as np

    from repro.autotune.probe import _materialize, ProbePoint
    from repro.core.engines import ENGINE_FNS
    from repro.graph.algorithms import SSSP
    from repro.kernels.runtime import on_tpu

    block, operand, n, _ = _materialize(
        ProbePoint(total_edges=3000.0, active_edges=900.0, active_vertices=120.0),
        max_edges=3000, seed=seed,
    )
    for fn in ENGINE_FNS:
        ref = fn(block, operand, n, SSSP, use_kernels=False)
        ker = fn(block, operand, n, SSSP, use_kernels=True)
        assert np.array_equal(np.asarray(ref.agg), np.asarray(ker.agg)), (
            f"{fn.__name__}: kernel path diverged from oracle (MIN must be bit-exact)")
        assert np.array_equal(np.asarray(ref.touched), np.asarray(ker.touched)), (
            f"{fn.__name__}: kernel path touched-mask diverged from oracle")

    rows = engine_rooflines(
        n_points=2 if fast else 3,
        max_edges=2048 if fast else 8192,
        repeats=1 if fast else 2,
        link=link, seed=seed,
    )
    for r in rows:
        for key in ("achieved_gbs", "modeled_gbs"):
            v = r[key]
            assert np.isfinite(v) and v > 0, f"{r['engine']}: {key}={v}"
        if on_tpu():
            assert r["ratio"] >= ENGINE_RATIO_FLOOR, (
                f"{r['engine']}: achieved/modeled bandwidth ratio "
                f"{r['ratio']:.4f} below floor {ENGINE_RATIO_FLOOR}")
    return rows


def run_engines(fast: bool = False, link=None):
    """Benchmark entry (``benchmarks.run --only kernels-roofline``):
    run the gate and emit one row per engine."""
    rows = engine_gate(fast=fast, link=link)
    for r in rows:
        emit(
            f"roofline/engine/{r['engine']}", r["wall_us"],
            f"achieved_gbs={r['achieved_gbs']:.3f};"
            f"modeled_gbs={r['modeled_gbs']:.3f};ratio={r['ratio']:.2e};"
            f"points={r['points']}",
        )
    return rows


if __name__ == "__main__":
    print(markdown_table(run()))
