"""Observability-layer benchmark + gate (repro.obs).

Measures what tracing costs and proves what it must not change:

* ``obs/emit_cost``   — median cost of one recorder event (span/instant/
  counter), the per-event price every instrumented site pays when a
  recorder is attached;
* ``obs/export``      — Chrome trace-event serialization cost for a
  recorder full of engine events;
* ``obs/overhead``    — traced vs untraced wall time of the same
  ``run_hytm`` sweep (the recorder only consumes already-drained host
  history, so this should be noise).

``--selfcheck`` gates (CI):
  1. **bit-identical** — a traced MIN run (both the chunked
     ``sync_every>1`` driver and the K=1 legacy loop) returns values,
     iterations, and transfer accounting identical to the untraced run;
  2. **exact reconciliation** — the run-summary span totals and the
     per-iteration event count equal the returned ``HyTMResult`` fields
     exactly (``repro.obs.export.reconcile``);
  3. **schema** — the exported Chrome trace-event JSON validates
     (``validate_chrome_trace``) for both the engine trace and a
     serving trace with tenant/cache/scheduler tracks;
  4. **bounded overhead** — the ring honors its capacity (overflow
     increments ``dropped``, never grows the buffer) and the traced
     sweep stays within a generous wall-time ratio of the untraced one.

``--trace <path>`` writes the selfcheck's engine trace for artifact
upload.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit
from repro.core.hytm import HyTMConfig, run_hytm
from repro.graph.algorithms import SSSP
from repro.graph.generators import rmat_graph
from repro.obs import (
    TraceRecorder,
    reconcile,
    to_chrome_trace,
    validate_chrome_trace,
    write_chrome_trace,
)

# generous: the recorder is host-side and off the jit path, but CPU CI
# wall times are noisy at these (sub-second) scales
OVERHEAD_RATIO = 2.0


def _emit_cost_us(n: int = 20_000) -> float:
    rec = TraceRecorder(capacity=n + 16)
    t0 = time.monotonic()
    for i in range(n):
        rec.instant("e", cat="bench", track="t", vt=float(i), k=i)
    per_event = (time.monotonic() - t0) / n
    assert len(rec) == n
    return per_event * 1e6


def _timed_run(g, cfg, obs=None, repeats: int = 3):
    """Median wall seconds of run_hytm (first call pays compile; the
    compiled executable is shared by the traced and untraced calls, so
    the medians compare recorder overhead only)."""
    res = run_hytm(g, SSSP, source=0, config=cfg, obs=obs)
    times = []
    for _ in range(repeats):
        t0 = time.monotonic()
        res = run_hytm(g, SSSP, source=0, config=cfg, obs=obs)
        times.append(time.monotonic() - t0)
    return res, float(np.median(times))


def run(fast: bool = False, selfcheck: bool = False, seed: int = 5,
        trace_path: str | None = None) -> dict:
    n_nodes, n_edges = (800, 6_400) if fast else (3_000, 36_000)
    g = rmat_graph(n_nodes, n_edges, seed=seed)
    cfg = HyTMConfig(n_partitions=8 if fast else 16, sync_every=4)
    cfg1 = HyTMConfig(n_partitions=cfg.n_partitions, sync_every=1)

    # --- cost of the recorder itself -------------------------------------
    emit("obs/emit_cost", _emit_cost_us(), "per instant event (host-side)")

    # --- traced vs untraced engine sweep ---------------------------------
    base, t_base = _timed_run(g, cfg)
    rec = TraceRecorder()
    traced, t_traced = _timed_run(g, cfg, obs=rec)
    ratio = t_traced / max(t_base, 1e-9)
    emit("obs/overhead", (t_traced - t_base) * 1e6,
         f"ratio={ratio:.2f} untraced_us={t_base * 1e6:.0f} "
         f"events={len(rec)}")

    t0 = time.monotonic()
    doc = to_chrome_trace(rec)
    t_export = time.monotonic() - t0
    emit("obs/export", t_export * 1e6,
         f"chrome_events={len(doc['traceEvents'])}")

    # one-run recorder for the reconciliation gate and the artifact (the
    # timing recorder above holds warmup + repeat runs on one track)
    rec_one = TraceRecorder()
    traced_one = run_hytm(g, SSSP, source=0, config=cfg, obs=rec_one)

    rows = {
        "overhead_ratio": ratio, "events": len(rec),
        "emit_us": _emit_cost_us(2_000), "iterations": traced.iterations,
    }
    if selfcheck:
        _selfcheck(g, cfg, cfg1, base, traced, rec_one, traced_one, doc,
                   rows)
    if trace_path is not None:
        write_chrome_trace(rec_one, trace_path)
        print(f"# trace: {len(rec_one)} events -> {trace_path}")
    return rows


def _selfcheck(g, cfg, cfg1, base, traced, rec_one, traced_one, doc,
               rows) -> None:
    # 1. bit-identical: tracing must not perturb the computation —
    # chunked driver (the repeats above) and the K=1 legacy loop
    np.testing.assert_array_equal(base.values, traced.values)
    assert base.iterations == traced.iterations
    assert base.total_transfer_bytes == traced.total_transfer_bytes
    rec1 = TraceRecorder()
    base1 = run_hytm(g, SSSP, source=0, config=cfg1)
    traced1 = run_hytm(g, SSSP, source=0, config=cfg1, obs=rec1)
    np.testing.assert_array_equal(base1.values, traced1.values)
    assert base1.iterations == traced1.iterations

    # 2. exact reconciliation on both drivers: span totals == HyTMResult
    for r, result, tag in ((rec_one, traced_one, "chunked"),
                           (rec1, traced1, "K=1")):
        rep = reconcile(r, result)
        assert rep["ok"], (tag, rep)

    # 3. schema: engine trace + a serving trace (tenant/cache tracks)
    validate_chrome_trace(doc)
    validate_chrome_trace(to_chrome_trace(rec1))
    from repro.stream import GraphService

    rec_s = TraceRecorder()
    svc = GraphService(g, cfg, max_lanes=2, obs=rec_s,
                       device_budget_bytes=3 * 9 * g.n_nodes)
    svc.query(SSSP, [0, 1, 2, 3, 4])
    validate_chrome_trace(to_chrome_trace(rec_s))
    tracks = {e.track for e in rec_s.events}
    assert {"scheduler", "cache"} <= tracks, tracks
    assert any(t.startswith("tenant:") for t in tracks), tracks

    # 4. bounded overhead: ring capacity is a hard bound (overflow is
    # counted, not stored) and the traced sweep stays within ratio
    tiny = TraceRecorder(capacity=8)
    for i in range(50):
        tiny.instant("e", vt=float(i))
    assert len(tiny) == 8 and tiny.dropped == 42, (len(tiny), tiny.dropped)
    assert rows["overhead_ratio"] < OVERHEAD_RATIO, rows
    print(f"# SELFCHECK OK: traced == untraced (both drivers); "
          f"reconcile exact over {rows['iterations']} iterations; "
          f"schema valid ({len(doc['traceEvents'])} chrome events); "
          f"overhead ratio {rows['overhead_ratio']:.2f} < {OVERHEAD_RATIO}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller graph (CI mode)")
    ap.add_argument("--selfcheck", action="store_true",
                    help="gate: bit-identical traced runs, exact "
                         "HyTMResult reconciliation, valid chrome "
                         "schema, bounded overhead")
    ap.add_argument("--seed", type=int, default=5)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write the selfcheck engine trace (chrome "
                         "trace-event JSON) to PATH")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(fast=args.fast, selfcheck=args.selfcheck, seed=args.seed,
        trace_path=args.trace)


if __name__ == "__main__":
    main()
