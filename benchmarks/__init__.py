"""Benchmark harness: one module per paper table/figure + roofline."""
