"""Fig. 9 analogue: RMAT size ladder (CPU-scaled: 0.04M -> 2.5M edges,
64x range like the paper's 0.1B -> 6.4B) — runtime growth of HyTM vs the
single-engine baselines.

``run_devices`` adds the scale-out axis: the same workload swept over
forced-host-platform device counts through the sharded partition sweep
(repro.dist.graph_shard).  Each device count runs in a subprocess because
jax locks the device count at first init.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit, timed
from repro.core.constants import PCIE3
from repro.core.cost_model import COMPACT, FILTER, ZEROCOPY
from repro.core.hytm import HyTMConfig, run_hytm
from repro.graph.algorithms import SSSP
from repro.graph.generators import rmat_graph

LINK = PCIE3.with_(mr=4.0)  # fine transaction groups: avoids ties at CPU scale

SYSTEMS = {"hytm": None, "exptm-f": FILTER, "exptm-c": COMPACT, "imptm-zc": ZEROCOPY}


def run(fast: bool = False):
    sizes = [(2_500, 40_000), (5_000, 160_000), (20_000, 640_000), (40_000, 2_560_000)]
    if fast:
        sizes = sizes[:2]  # 4x edge range instead of 64x
    growth = {}
    for sname, engine in SYSTEMS.items():
        modeled = []
        for n, m in sizes:
            g = rmat_graph(n, m, seed=12)
            cfg = HyTMConfig(link=LINK, n_partitions=max(8, m // 40_000), forced_engine=engine)
            res, wall_us = timed(run_hytm, g, SSSP, source=0, config=cfg, repeats=1)
            modeled.append(res.modeled_seconds)
            emit(f"fig9/{sname}/edges_{m}", wall_us,
                 f"modeled_ms={res.modeled_seconds*1e3:.3f}")
        growth[sname] = modeled[-1] / max(modeled[0], 1e-12)
        span = len(sizes) - 1
        emit(f"fig9/{sname}/growth_{4 ** span}x", 0.0, f"{growth[sname]:.1f}x")
    return growth


_DEVICE_SWEEP_SCRIPT = """
    import time
    import jax
    from repro.core.hytm import HyTMConfig, build_runtime, run_hytm
    from repro.core.constants import PCIE3
    from repro.graph.algorithms import SSSP
    from repro.graph.generators import rmat_graph

    n_dev = len(jax.devices())
    g = rmat_graph({n_nodes}, {n_edges}, seed=12)
    cfg = HyTMConfig(
        link=PCIE3.with_(mr=4.0), n_partitions={n_partitions},
        async_sweep=False, mesh_axis=None if n_dev == 1 else "graph",
    )
    # build the runtime once and reuse it: the warm-up run then leaves a
    # compiled iteration behind for the timed run on both paths
    if cfg.mesh_axis is None:
        rt = build_runtime(g, cfg)
    else:
        from repro.dist.graph_shard import build_sharded_runtime
        from repro.launch.mesh import make_graph_mesh
        rt = build_sharded_runtime(g, cfg, make_graph_mesh())
    run_hytm(g, SSSP, source=0, config=cfg, runtime=rt)   # warm / compile
    t0 = time.monotonic()
    res = run_hytm(g, SSSP, source=0, config=cfg, runtime=rt)
    wall = time.monotonic() - t0
    print(f"RESULT,{{n_dev}},{{wall * 1e6:.1f}},{{res.modeled_seconds * 1e3:.4f}},"
          f"{{res.iterations}},{{res.total_transfer_bytes:.0f}},"
          f"{{res.modeled_ici_seconds * 1e3:.4f}},{{res.total_ici_bytes:.0f}}")

    if n_dev > 1:
        # owner-sharded leg: per-device vertex-state residency drops to
        # the owned slice (+ halo) while the answer stays bit-identical
        import dataclasses
        import numpy as np
        from repro.core.cost_model import vertex_state_bytes
        from repro.dist.graph_shard import _owner_place_state

        cfg_o = dataclasses.replace(cfg, vertex_sharding="owner")
        rt_o = build_sharded_runtime(g, cfg_o, rt.mesh)
        run_hytm(g, SSSP, source=0, config=cfg_o, runtime=rt_o)  # warm
        res_o = run_hytm(g, SSSP, source=0, config=cfg_o, runtime=rt_o)
        np.testing.assert_array_equal(res_o.values, res.values)
        assert res_o.iterations == res.iterations
        assert res_o.total_transfer_bytes == res.total_transfer_bytes
        # measured bytes: what each device actually holds for one placed
        # (values, delta, frontier) triple — peak = the max over devices
        st = _owner_place_state(rt_o, SSSP, *SSSP.init_state(g.n_nodes, 0))
        per_dev = {{}}
        for arr in (st.values, st.delta, st.frontier):
            for sh in arr.addressable_shards:
                d = sh.device.id
                per_dev[d] = per_dev.get(d, 0) + sh.data.nbytes
        measured = max(per_dev.values())
        modeled = vertex_state_bytes(
            g.n_nodes, n_dev, "owner", halo=rt_o.halo.max_halo)
        repl = vertex_state_bytes(g.n_nodes)
        print(f"MEM,{{n_dev}},{{measured}},{{modeled}},{{repl}},"
              f"{{rt_o.halo.max_halo}},{{rt_o.halo.halo_total}}")
"""


def run_devices(device_counts=None, n_nodes=5_000, n_edges=160_000,
                n_partitions=32, fast: bool = False,
                selfcheck: bool = False):
    """Scale-out sweep: one subprocess per forced-host device count, the
    sharded sweep on >1 device (the 1-device row is the single-device
    reference path).  Emits wall time + the modeled transfer metrics,
    which must be device-count-invariant (the model counts bytes, not
    devices) — a cheap end-to-end consistency check on the sharding.

    Multi-device rows also run the owner-sharded leg
    (``vertex_sharding="owner"``): the subprocess asserts bit-identity
    with the replicated run and reports per-device peak vertex-state
    bytes — measured from the placed arrays' addressable shards — plus
    the modeled owned-slice + halo bytes
    (``cost_model.vertex_state_bytes``).  ``selfcheck`` gates the
    ~``n/D`` scaling: each device may hold at most its padded owned
    slice, a D-fold drop from the replicated ``9n``-byte ceiling."""
    if device_counts is None:
        # --fast trims only the *default* sweep; an explicit device list
        # (e.g. the CI 16-device owner-sharding gate) runs as given,
        # still on the shrunken fast graph
        device_counts = (1, 2) if fast else (1, 2, 4, 8)
    if fast:
        n_nodes, n_edges = min(n_nodes, 2_000), min(n_edges, 40_000)
    from repro.launch.mesh import forced_host_device_env

    script = textwrap.dedent(
        _DEVICE_SWEEP_SCRIPT.format(
            n_nodes=n_nodes, n_edges=n_edges, n_partitions=n_partitions
        )
    )
    rows = {}
    mem = {}
    for n_dev in device_counts:
        out = subprocess.run(
            [sys.executable, "-c", script],
            capture_output=True, text=True, timeout=600,
            env=forced_host_device_env(n_dev),
        )
        if out.returncode != 0:
            emit(f"fig9/devices_{n_dev}", 0.0, f"FAILED: {out.stderr[-200:]}")
            continue
        line = [l for l in out.stdout.splitlines() if l.startswith("RESULT,")][0]
        _, dev, wall_us, modeled_ms, iters, bytes_, ici_ms, ici_bytes = line.split(",")
        rows[n_dev] = float(modeled_ms)
        # two-level transfer management: the PCIe/HBM level (modeled_ms,
        # device-count-invariant) + the cross-device merge charged over
        # the ICI link (grows with the device count)
        emit(
            f"fig9/devices_{n_dev}", float(wall_us),
            f"modeled_ms={modeled_ms} iters={iters} bytes={bytes_} "
            f"ici_ms={ici_ms} ici_bytes={ici_bytes}",
        )
        for mline in out.stdout.splitlines():
            if not mline.startswith("MEM,"):
                continue
            _, _, measured, modeled, repl, max_halo, halo_total = \
                mline.split(",")
            mem[n_dev] = (int(measured), int(modeled), int(repl),
                          int(max_halo), int(halo_total))
            emit(
                f"fig9/devices_{n_dev}/owner_state_bytes", 0.0,
                f"measured={measured} modeled={modeled} replicated={repl} "
                f"max_halo={max_halo} halo_total={halo_total}",
            )
    if selfcheck:
        _selfcheck_owner_memory(mem, n_nodes, device_counts)
    return rows


def _selfcheck_owner_memory(mem: dict, n_nodes: int,
                            device_counts) -> None:
    """The owner-sharding memory gate: every multi-device row must have
    produced its MEM record (the subprocess already asserted
    bit-identity before printing it), measured per-device state bytes
    must equal the padded owned slice — a ~D-fold drop from the
    replicated 9n ceiling — and the modeled total must be owned slice +
    halo, with the halo a strict subset of the non-owned vertices."""
    from repro.core.cost_model import STATE_BYTES_PER_VERTEX

    expected = [d for d in device_counts if d > 1]
    missing = [d for d in expected if d not in mem]
    if missing:
        raise AssertionError(
            f"owner-sharding selfcheck: no MEM record for device counts "
            f"{missing} — the owner leg did not run")
    for n_dev, (measured, modeled, repl, max_halo, halo_total) in mem.items():
        n_loc = -(-n_nodes // n_dev)
        owned = STATE_BYTES_PER_VERTEX * n_loc
        if measured != owned:
            raise AssertionError(
                f"devices={n_dev}: measured per-device state bytes "
                f"{measured} != owned-slice bytes {owned} (~n/D scaling "
                f"violated)")
        if measured * n_dev > repl + STATE_BYTES_PER_VERTEX * n_dev:
            raise AssertionError(
                f"devices={n_dev}: owner layout total {measured * n_dev} "
                f"exceeds replicated-per-device {repl} + padding")
        if modeled != owned + STATE_BYTES_PER_VERTEX * max_halo:
            raise AssertionError(
                f"devices={n_dev}: modeled bytes {modeled} != owned "
                f"{owned} + halo {STATE_BYTES_PER_VERTEX * max_halo}")
        if not 0 <= max_halo <= n_loc * n_dev - n_loc:
            raise AssertionError(
                f"devices={n_dev}: max_halo {max_halo} outside "
                f"[0, n_pad - n_loc]")
    print(f"OK fig9-devices owner-memory selfcheck: "
          f"{sorted(mem)} device counts, per-device state bytes = "
          f"9*ceil(n/D) each")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--selfcheck", action="store_true",
                    help="gate the owner-sharded ~n/D per-device "
                         "state-byte scaling (runs the device sweep only)")
    ap.add_argument("--devices", type=int, nargs="*", default=None,
                    help="device counts for the scale-out sweep")
    args = ap.parse_args()
    kw = {}
    if args.devices:
        kw["device_counts"] = tuple(args.devices)
    if args.selfcheck:
        run_devices(fast=args.fast, selfcheck=True, **kw)
    else:
        run(fast=args.fast)
        run_devices(fast=args.fast, **kw)
