"""Fig. 9 analogue: RMAT size ladder (CPU-scaled: 0.04M -> 2.5M edges,
64x range like the paper's 0.1B -> 6.4B) — runtime growth of HyTM vs the
single-engine baselines.

``run_devices`` adds the scale-out axis: the same workload swept over
forced-host-platform device counts through the sharded partition sweep
(repro.dist.graph_shard).  Each device count runs in a subprocess because
jax locks the device count at first init.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit, timed
from repro.core.constants import PCIE3
from repro.core.cost_model import COMPACT, FILTER, ZEROCOPY
from repro.core.hytm import HyTMConfig, run_hytm
from repro.graph.algorithms import SSSP
from repro.graph.generators import rmat_graph

LINK = PCIE3.with_(mr=4.0)  # fine transaction groups: avoids ties at CPU scale

SYSTEMS = {"hytm": None, "exptm-f": FILTER, "exptm-c": COMPACT, "imptm-zc": ZEROCOPY}


def run(fast: bool = False):
    sizes = [(2_500, 40_000), (5_000, 160_000), (20_000, 640_000), (40_000, 2_560_000)]
    if fast:
        sizes = sizes[:2]  # 4x edge range instead of 64x
    growth = {}
    for sname, engine in SYSTEMS.items():
        modeled = []
        for n, m in sizes:
            g = rmat_graph(n, m, seed=12)
            cfg = HyTMConfig(link=LINK, n_partitions=max(8, m // 40_000), forced_engine=engine)
            res, wall_us = timed(run_hytm, g, SSSP, source=0, config=cfg, repeats=1)
            modeled.append(res.modeled_seconds)
            emit(f"fig9/{sname}/edges_{m}", wall_us,
                 f"modeled_ms={res.modeled_seconds*1e3:.3f}")
        growth[sname] = modeled[-1] / max(modeled[0], 1e-12)
        span = len(sizes) - 1
        emit(f"fig9/{sname}/growth_{4 ** span}x", 0.0, f"{growth[sname]:.1f}x")
    return growth


_DEVICE_SWEEP_SCRIPT = """
    import time
    import jax
    from repro.core.hytm import HyTMConfig, build_runtime, run_hytm
    from repro.core.constants import PCIE3
    from repro.graph.algorithms import SSSP
    from repro.graph.generators import rmat_graph

    n_dev = len(jax.devices())
    g = rmat_graph({n_nodes}, {n_edges}, seed=12)
    cfg = HyTMConfig(
        link=PCIE3.with_(mr=4.0), n_partitions={n_partitions},
        async_sweep=False, mesh_axis=None if n_dev == 1 else "graph",
    )
    # build the runtime once and reuse it: the warm-up run then leaves a
    # compiled iteration behind for the timed run on both paths
    if cfg.mesh_axis is None:
        rt = build_runtime(g, cfg)
    else:
        from repro.dist.graph_shard import build_sharded_runtime
        from repro.launch.mesh import make_graph_mesh
        rt = build_sharded_runtime(g, cfg, make_graph_mesh())
    run_hytm(g, SSSP, source=0, config=cfg, runtime=rt)   # warm / compile
    t0 = time.monotonic()
    res = run_hytm(g, SSSP, source=0, config=cfg, runtime=rt)
    wall = time.monotonic() - t0
    print(f"RESULT,{{n_dev}},{{wall * 1e6:.1f}},{{res.modeled_seconds * 1e3:.4f}},"
          f"{{res.iterations}},{{res.total_transfer_bytes:.0f}},"
          f"{{res.modeled_ici_seconds * 1e3:.4f}},{{res.total_ici_bytes:.0f}}")
"""


def run_devices(device_counts=(1, 2, 4, 8), n_nodes=5_000, n_edges=160_000,
                n_partitions=32, fast: bool = False):
    """Scale-out sweep: one subprocess per forced-host device count, the
    sharded sweep on >1 device (the 1-device row is the single-device
    reference path).  Emits wall time + the modeled transfer metrics,
    which must be device-count-invariant (the model counts bytes, not
    devices) — a cheap end-to-end consistency check on the sharding."""
    if fast:
        device_counts = tuple(d for d in device_counts if d <= 2) or (1, 2)
        n_nodes, n_edges = min(n_nodes, 2_000), min(n_edges, 40_000)
    from repro.launch.mesh import forced_host_device_env

    script = textwrap.dedent(
        _DEVICE_SWEEP_SCRIPT.format(
            n_nodes=n_nodes, n_edges=n_edges, n_partitions=n_partitions
        )
    )
    rows = {}
    for n_dev in device_counts:
        out = subprocess.run(
            [sys.executable, "-c", script],
            capture_output=True, text=True, timeout=600,
            env=forced_host_device_env(n_dev),
        )
        if out.returncode != 0:
            emit(f"fig9/devices_{n_dev}", 0.0, f"FAILED: {out.stderr[-200:]}")
            continue
        line = [l for l in out.stdout.splitlines() if l.startswith("RESULT,")][0]
        _, dev, wall_us, modeled_ms, iters, bytes_, ici_ms, ici_bytes = line.split(",")
        rows[n_dev] = float(modeled_ms)
        # two-level transfer management: the PCIe/HBM level (modeled_ms,
        # device-count-invariant) + the cross-device merge charged over
        # the ICI link (grows with the device count)
        emit(
            f"fig9/devices_{n_dev}", float(wall_us),
            f"modeled_ms={modeled_ms} iters={iters} bytes={bytes_} "
            f"ici_ms={ici_ms} ici_bytes={ici_bytes}",
        )
    return rows


if __name__ == "__main__":
    run()
    run_devices()
