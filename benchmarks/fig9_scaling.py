"""Fig. 9 analogue: RMAT size ladder (CPU-scaled: 0.04M -> 2.5M edges,
64x range like the paper's 0.1B -> 6.4B) — runtime growth of HyTM vs the
single-engine baselines."""

from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core.constants import PCIE3
from repro.core.cost_model import COMPACT, FILTER, ZEROCOPY
from repro.core.hytm import HyTMConfig, run_hytm
from repro.graph.algorithms import SSSP
from repro.graph.generators import rmat_graph

LINK = PCIE3.with_(mr=4.0)  # fine transaction groups: avoids ties at CPU scale

SYSTEMS = {"hytm": None, "exptm-f": FILTER, "exptm-c": COMPACT, "imptm-zc": ZEROCOPY}


def run():
    sizes = [(2_500, 40_000), (5_000, 160_000), (20_000, 640_000), (40_000, 2_560_000)]
    growth = {}
    for sname, engine in SYSTEMS.items():
        modeled = []
        for n, m in sizes:
            g = rmat_graph(n, m, seed=12)
            cfg = HyTMConfig(link=LINK, n_partitions=max(8, m // 40_000), forced_engine=engine)
            res, wall_us = timed(run_hytm, g, SSSP, source=0, config=cfg, repeats=1)
            modeled.append(res.modeled_seconds)
            emit(f"fig9/{sname}/edges_{m}", wall_us,
                 f"modeled_ms={res.modeled_seconds*1e3:.3f}")
        growth[sname] = modeled[-1] / max(modeled[0], 1e-12)
        emit(f"fig9/{sname}/growth_64x", 0.0, f"{growth[sname]:.1f}x")
    return growth


if __name__ == "__main__":
    run()
