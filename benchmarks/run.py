"""Benchmark harness entry point: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit);
``--json <path>`` additionally writes the rows machine-readably so perf
trajectories (``BENCH_*.json``) can be recorded across revisions.
``--compare <baseline.json>`` checks the freshly emitted rows against a
prior ``--json`` dump and exits non-zero when any matching row regressed
past ``--compare-ratio`` (default 2.0x — a coarse tripwire for CI, not a
microbenchmark gate)."""

import argparse
import json
import sys


def compare_rows(rows, baseline_doc: dict, ratio: float) -> list[str]:
    """Rows regressed past ``ratio``x their baseline ``us_per_call``.
    Rows are matched by name; names present on only one side are skipped
    (benchmarks come and go across revisions)."""
    base = {r["name"]: float(r["us_per_call"])
            for r in baseline_doc.get("rows", [])}
    failures = []
    for name, us, _ in rows:
        b = base.get(name)
        # sub-ms rows are dominated by dispatch noise on shared CI
        if b is None or b <= 0 or max(us, b) < 1_000:
            continue
        if us > ratio * b:
            failures.append(
                f"{name}: {us:.1f}us > {ratio:.1f}x baseline {b:.1f}us")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="run a single benchmark module")
    ap.add_argument("--fast", action="store_true", help="smaller graphs / fewer repeats")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the emitted rows as JSON to PATH")
    ap.add_argument("--compare", default=None, metavar="BASELINE",
                    help="compare the emitted rows against a prior --json "
                         "dump; exit non-zero on any regression past "
                         "--compare-ratio")
    ap.add_argument("--compare-ratio", type=float, default=2.0,
                    help="regression threshold for --compare "
                         "(default: 2.0x the baseline us_per_call)")
    args = ap.parse_args()

    from benchmarks import (
        autotune_bench,
        chaos_bench,
        common,
        fig3_analysis,
        fig7_execution_path,
        fig8_gains,
        fig9_scaling,
        iterloop,
        kernels,
        obs_bench,
        roofline,
        serve_bench,
        stream_bench,
        table5_runtime,
        table6_transfer,
    )

    # --fast applies to every entry: the table/fig3/7/8 family shrinks its
    # graphs via kw; the rest take an explicit fast flag.
    kw = dict(n_nodes=5000, n_edges=80_000, n_partitions=32) if args.fast else {}
    mods = {
        "table5": lambda: table5_runtime.run(**kw),
        "table6": lambda: table6_transfer.run(**kw),
        "fig3": lambda: fig3_analysis.run(**kw),
        "fig7": lambda: fig7_execution_path.run(**kw),
        "fig8": lambda: fig8_gains.run(**kw),
        "fig9": lambda: fig9_scaling.run(fast=args.fast),
        # selfcheck always on: the owner-sharding ~n/D per-device
        # state-byte gate rides every fig9-devices run
        "fig9-devices": lambda: fig9_scaling.run_devices(
            fast=args.fast, selfcheck=True),
        "kernels": lambda: kernels.run(fast=args.fast),
        "kernels-roofline": lambda: roofline.run_engines(fast=args.fast),
        "roofline": lambda: roofline.run(fast=args.fast),
        "stream": lambda: stream_bench.run(smoke=args.fast),
        "stream-devices": lambda: stream_bench.run_sharded(smoke=args.fast),
        "serve": lambda: serve_bench.run(smoke=args.fast),
        "autotune": lambda: autotune_bench.run(fast=args.fast),
        "iterloop": lambda: iterloop.run(fast=args.fast),
        "obs": lambda: obs_bench.run(fast=args.fast),
        "chaos": lambda: chaos_bench.run(fast=args.fast),
    }
    print("name,us_per_call,derived")
    for name, fn in mods.items():
        if args.only and name != args.only:
            continue
        fn()

    if args.json:
        doc = {
            "fast": args.fast,
            "only": args.only,
            "rows": [
                {"name": n, "us_per_call": us, "derived": d}
                for n, us, d in common.ROWS
            ],
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print(f"# wrote {len(common.ROWS)} rows -> {args.json}")

    if args.compare:
        with open(args.compare) as f:
            baseline = json.load(f)
        failures = compare_rows(common.ROWS, baseline, args.compare_ratio)
        if failures:
            print("# REGRESSIONS vs", args.compare)
            for line in failures:
                print("#   " + line)
            sys.exit(1)
        print(f"# compare OK: no row regressed past "
              f"{args.compare_ratio:.1f}x {args.compare}")


if __name__ == "__main__":
    main()
