"""Benchmark harness entry point: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit)."""

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="run a single benchmark module")
    ap.add_argument("--fast", action="store_true", help="smaller graphs")
    args = ap.parse_args()

    from benchmarks import (
        fig3_analysis,
        fig7_execution_path,
        fig8_gains,
        fig9_scaling,
        kernels,
        roofline,
        stream_bench,
        table5_runtime,
        table6_transfer,
    )

    kw = dict(n_nodes=5000, n_edges=80_000, n_partitions=32) if args.fast else {}
    mods = {
        "table5": lambda: table5_runtime.run(**kw),
        "table6": lambda: table6_transfer.run(**kw),
        "fig3": lambda: fig3_analysis.run(**kw),
        "fig7": lambda: fig7_execution_path.run(**kw),
        "fig8": lambda: fig8_gains.run(**kw),
        "fig9": lambda: fig9_scaling.run(),
        "fig9-devices": lambda: fig9_scaling.run_devices(),
        "kernels": lambda: kernels.run(),
        "roofline": lambda: roofline.run(),
        "stream": lambda: stream_bench.run(smoke=args.fast),
    }
    print("name,us_per_call,derived")
    for name, fn in mods.items():
        if args.only and name != args.only:
            continue
        fn()


if __name__ == "__main__":
    main()
