"""Benchmark harness entry point: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit);
``--json <path>`` additionally writes the rows machine-readably so perf
trajectories (``BENCH_*.json``) can be recorded across revisions."""

import argparse
import json


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="run a single benchmark module")
    ap.add_argument("--fast", action="store_true", help="smaller graphs / fewer repeats")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the emitted rows as JSON to PATH")
    args = ap.parse_args()

    from benchmarks import (
        autotune_bench,
        common,
        fig3_analysis,
        fig7_execution_path,
        fig8_gains,
        fig9_scaling,
        iterloop,
        kernels,
        roofline,
        serve_bench,
        stream_bench,
        table5_runtime,
        table6_transfer,
    )

    # --fast applies to every entry: the table/fig3/7/8 family shrinks its
    # graphs via kw; the rest take an explicit fast flag.
    kw = dict(n_nodes=5000, n_edges=80_000, n_partitions=32) if args.fast else {}
    mods = {
        "table5": lambda: table5_runtime.run(**kw),
        "table6": lambda: table6_transfer.run(**kw),
        "fig3": lambda: fig3_analysis.run(**kw),
        "fig7": lambda: fig7_execution_path.run(**kw),
        "fig8": lambda: fig8_gains.run(**kw),
        "fig9": lambda: fig9_scaling.run(fast=args.fast),
        "fig9-devices": lambda: fig9_scaling.run_devices(fast=args.fast),
        "kernels": lambda: kernels.run(fast=args.fast),
        "kernels-roofline": lambda: roofline.run_engines(fast=args.fast),
        "roofline": lambda: roofline.run(fast=args.fast),
        "stream": lambda: stream_bench.run(smoke=args.fast),
        "stream-devices": lambda: stream_bench.run_sharded(smoke=args.fast),
        "serve": lambda: serve_bench.run(smoke=args.fast),
        "autotune": lambda: autotune_bench.run(fast=args.fast),
        "iterloop": lambda: iterloop.run(fast=args.fast),
    }
    print("name,us_per_call,derived")
    for name, fn in mods.items():
        if args.only and name != args.only:
            continue
        fn()

    if args.json:
        doc = {
            "fast": args.fast,
            "only": args.only,
            "rows": [
                {"name": n, "us_per_call": us, "derived": d}
                for n, us, d in common.ROWS
            ],
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print(f"# wrote {len(common.ROWS)} rows -> {args.json}")


if __name__ == "__main__":
    main()
