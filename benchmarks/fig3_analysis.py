"""Fig. 3 analogue — the motivating study: per-iteration active-edge /
active-partition proportions, per-engine cost curves, and the degree
distribution that drives zero-copy instability (Fig. 3(f))."""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import emit
from repro.core.cost_model import partition_stats, zc_request_counts
from repro.core.hytm import HyTMConfig, build_runtime, run_hytm
from repro.graph.algorithms import PAGERANK, SSSP
from repro.graph.generators import rmat_graph


def run(n_nodes: int = 20_000, n_edges: int = 320_000, n_partitions: int = 64):
    g = rmat_graph(n_nodes, n_edges, seed=9)

    # Fig 3(f): degree distribution — fraction of vertices under 32 / 8 nbrs
    deg = g.out_degrees
    under32 = float((deg < 32).mean())
    under8 = float((deg < 8).mean())
    emit("fig3/degree_lt32", 0.0, f"frac={under32:.3f}")
    emit("fig3/degree_lt8", 0.0, f"frac={under8:.3f}")

    # Fig 3(a): active edges vs active partitions over iterations
    for aname, prog, src in [
        ("sssp", SSSP, 0),
        ("pr", dataclasses.replace(PAGERANK, tolerance=1e-5), None),
    ]:
        res = run_hytm(g, prog, source=src, config=HyTMConfig(n_partitions=n_partitions))
        eng = res.history["engines"]              # (iters, P)
        active_parts = (eng >= 0).mean(axis=1)
        ae = res.history["active_edges"] / g.n_edges
        emit(
            f"fig3/{aname}/proportions", 0.0,
            "active_edges=" + "|".join(f"{x:.3f}" for x in ae[:12])
            + ";active_parts=" + "|".join(f"{x:.3f}" for x in active_parts[:12]),
        )
        # redundancy of filter: active partitions transfer everything
        filter_bytes = float((eng >= 0).sum(axis=1) @ np.full(1, 1.0)) if False else None
        useful = res.history["active_edges"].sum() * 4.0
        emit(
            f"fig3/{aname}/filter_usefulness", 0.0,
            f"useful_frac={useful / max((eng >= 0).sum() * (g.n_edges / n_partitions) * 4.0, 1):.3f}",
        )
    return under32, under8


if __name__ == "__main__":
    run()
