"""Autotune benchmark: regret of static vs calibrated engine selection.

Calibrates the PCIe profile against a TPU-modeled ground truth (the
mis-specified scenario the acceptance contract pins) and reports

  * the total regret of static selection vs the measured-best oracle,
  * the total regret after calibration (and the improvement ratio),
  * the wall cost of one full calibration (probe grid -> fit -> tune),

so ``BENCH_*.json`` trajectories can track both the selection-quality
gain and the calibration overhead across revisions.
"""

from __future__ import annotations

from benchmarks.common import emit, timed
from repro.autotune import calibrate, default_grid, model_probe
from repro.core.constants import PCIE3, TPU_V5E_HBM


def run(fast: bool = False):
    # fast drops edge levels, not ratio resolution — the regret signal
    # lives at the selection boundaries the ratio sweep crosses
    points = default_grid(
        edge_levels=(1.0e6, 1.7e7) if fast else (1.0e6, 4.3e6, 1.7e7, 6.7e7),
    )
    obs = model_probe(points, TPU_V5E_HBM)

    rep, us = timed(calibrate, points, obs, PCIE3, repeats=1 if fast else 3)
    emit("autotune/calibrate_wall", us,
         f"points={rep.n_points};obs={rep.n_observations}")
    emit("autotune/static_regret", 0.0, f"{rep.static_regret:.6e}s")
    emit("autotune/calibrated_regret", 0.0, f"{rep.calibrated_regret:.6e}s")
    ratio = rep.calibrated_regret / max(rep.static_regret, 1e-30)
    emit("autotune/regret_ratio", 0.0, f"{ratio:.4f}")
    emit("autotune/fitted", 0.0,
         f"bw={rep.profile.bandwidth:.3e};gamma={rep.profile.gamma:.3f};"
         f"alpha={rep.profile.alpha:.2f};beta={rep.profile.beta:.2f}")
    return rep


if __name__ == "__main__":
    run()
