"""Table VI analogue: transfer volume normalized to edge-array bytes for
SSSP and PageRank under each system (modeled bytes on real frontiers)."""

from __future__ import annotations

import dataclasses

from benchmarks.common import emit
from repro.core.constants import PCIE3
from repro.core.cost_model import COMPACT, FILTER, ZEROCOPY
from repro.core.hytm import HyTMConfig, run_hytm
from repro.graph.algorithms import PAGERANK, SSSP
from repro.graph.generators import rmat_graph
from repro.graph.hub_sort import hub_sort

LINK = PCIE3.with_(mr=4.0)  # fine transaction groups: avoids ties at CPU scale

SYSTEMS = {"exptm-f": FILTER, "exptm-c": COMPACT, "imptm-zc": ZEROCOPY, "hytm": None}


def run(n_nodes: int = 20_000, n_edges: int = 320_000, n_partitions: int = 64):
    g = rmat_graph(n_nodes, n_edges, seed=8)
    hs = hub_sort(g)
    edge_bytes = g.n_edges * 4.0
    results = {}
    for aname, prog, src in [
        ("sssp", SSSP, 0),
        ("pr", dataclasses.replace(PAGERANK, tolerance=1e-5), None),
    ]:
        for sname, engine in SYSTEMS.items():
            cfg = HyTMConfig(link=LINK,
                n_partitions=n_partitions, forced_engine=engine,
                cds_mode="hub" if engine is None else "none",
                recompute_once=engine is None,
            )
            res = run_hytm(
                hs.graph, prog, source=int(hs.perm[0]) if src is not None else None,
                config=cfg, n_hubs=hs.n_hubs,
            )
            ratio = res.total_transfer_bytes / edge_bytes
            results[(aname, sname)] = ratio
            emit(f"table6/{aname}/{sname}", 0.0, f"transfer_over_edges={ratio:.2f}x")
    return results


if __name__ == "__main__":
    run()
