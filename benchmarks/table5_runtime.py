"""Table V analogue: overall runtime of HyTM vs the single-engine systems
(pure ExpTM-F, Subway-like ExpTM-C, EMOGI-like ImpTM-ZC) across the four
paper algorithms on RMAT graphs.

The paper's headline: HyTGraph ~4.61x over Subway, ~1.74x over EMOGI,
~8.99x over ExpTM-F on average.  Here the modeled transfer time with the
paper's PCIe constants — evaluated on the real execution's per-iteration
frontiers — carries the comparison (wall-clock on CPU also reported).
"""

from __future__ import annotations

import dataclasses

from benchmarks.common import emit, timed
from repro.core.constants import PCIE3
from repro.core.cost_model import COMPACT, FILTER, ZEROCOPY
from repro.core.hytm import HyTMConfig, build_runtime, run_hytm
from repro.graph.algorithms import BFS, CC, PAGERANK, SSSP
from repro.graph.generators import rmat_graph
from repro.graph.hub_sort import hub_sort

LINK = PCIE3.with_(mr=4.0)  # fine transaction groups: avoids ties at CPU scale

SYSTEMS = {
    "hytm": None,           # the paper's hybrid
    "exptm-f": FILTER,      # GraphReduce/Graphie-like
    "exptm-c": COMPACT,     # Subway-like
    "imptm-zc": ZEROCOPY,   # EMOGI-like
}

ALGOS = {
    "sssp": (SSSP, 0),
    "bfs": (BFS, 0),
    "cc": (CC, None),
    "pr": (dataclasses.replace(PAGERANK, tolerance=1e-5), None),
}


def run(n_nodes: int = 20_000, n_edges: int = 320_000, n_partitions: int = 64):
    g = rmat_graph(n_nodes, n_edges, seed=7)
    hs = hub_sort(g)
    gsym = hs.graph.symmetrize()
    speedups = {}
    for aname, (prog, src) in ALGOS.items():
        graph = gsym if aname == "cc" else hs.graph
        source = int(hs.perm[0]) if src is not None else None
        modeled = {}
        for sname, engine in SYSTEMS.items():
            cfg = HyTMConfig(link=LINK,
                n_partitions=n_partitions, forced_engine=engine,
                cds_mode="hub" if engine is None else "none",
                recompute_once=engine is None,
            )
            res, wall_us = timed(
                run_hytm, graph, prog, source=source, config=cfg,
                n_hubs=hs.n_hubs, repeats=1,
            )
            modeled[sname] = res.modeled_seconds
            emit(
                f"table5/{aname}/{sname}", wall_us,
                f"modeled_ms={res.modeled_seconds*1e3:.3f};iters={res.iterations}",
            )
        for sname in ("exptm-f", "exptm-c", "imptm-zc"):
            speedups.setdefault(sname, []).append(modeled[sname] / max(modeled["hytm"], 1e-12))
    for sname, sp in speedups.items():
        avg = sum(sp) / len(sp)
        emit(f"table5/speedup_vs_{sname}", 0.0, f"avg={avg:.2f}x;per_algo={[f'{s:.2f}' for s in sp]}")
    return speedups


if __name__ == "__main__":
    run()
