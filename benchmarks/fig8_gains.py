"""Fig. 8 analogue: ablation of Task Combining (TC) and Contribution-
Driven Scheduling (CDS) over the raw hybrid transfer management."""

from __future__ import annotations

import dataclasses

from benchmarks.common import emit
from repro.core.constants import PCIE3
from repro.core.hytm import HyTMConfig, run_hytm
from repro.graph.algorithms import BFS, CC, PAGERANK, SSSP
from repro.graph.generators import rmat_graph
from repro.graph.hub_sort import hub_sort


LINK = PCIE3.with_(mr=4.0)  # fine transaction groups: avoids ties at CPU scale


def run(n_nodes: int = 20_000, n_edges: int = 320_000, n_partitions: int = 64):
    g = rmat_graph(n_nodes, n_edges, seed=11)
    hs = hub_sort(g)
    gsym = hs.graph.symmetrize()
    gains = {}
    for aname, prog, src in [
        ("pr", dataclasses.replace(PAGERANK, tolerance=1e-5), None),
        ("sssp", SSSP, 0),
        ("cc", CC, None),
        ("bfs", BFS, 0),
    ]:
        graph = gsym if aname == "cc" else hs.graph
        source = int(hs.perm[0]) if src is not None else None
        cds_mode = "delta" if aname == "pr" else "hub"
        variants = {
            "raw": HyTMConfig(link=LINK, n_partitions=n_partitions, cds_mode="none",
                              enable_task_combination=False, recompute_once=False),
            "tc": HyTMConfig(link=LINK, n_partitions=n_partitions, cds_mode="none",
                             enable_task_combination=True, recompute_once=False),
            "tc+cds": HyTMConfig(link=LINK, n_partitions=n_partitions, cds_mode=cds_mode,
                                 enable_task_combination=True, recompute_once=True),
        }
        modeled = {}
        for vname, cfg in variants.items():
            res = run_hytm(graph, prog, source=source, config=cfg, n_hubs=hs.n_hubs)
            modeled[vname] = res.modeled_seconds
            emit(f"fig8/{aname}/{vname}", 0.0,
                 f"modeled_ms={res.modeled_seconds*1e3:.3f};iters={res.iterations}")
        gains[aname] = (
            modeled["raw"] / max(modeled["tc"], 1e-12),
            modeled["raw"] / max(modeled["tc+cds"], 1e-12),
        )
        emit(f"fig8/{aname}/speedup", 0.0,
             f"tc={gains[aname][0]:.2f}x;tc+cds={gains[aname][1]:.2f}x")
    return gains


if __name__ == "__main__":
    run()
