"""Iteration-driver benchmark: chunked vs per-iteration convergence loop.

The workload is deliberately **dispatch-bound**, not transfer-bound: BFS
on a tall, narrow grid graph (diameter ~= height), so the frontier is a
thin wave that needs ~height iterations of almost no per-iteration work.
This is the regime where the per-iteration driver's fixed costs — one
``hytm_iteration`` dispatch plus two device->host syncs (loop condition +
history pull) per iteration — dominate wall time, and where the chunked
``lax.while_loop`` driver (``HyTMConfig.sync_every = K``) wins by paying
those costs once per K iterations instead (the high-diameter BFS/SSSP
tail the ISSUE's EMOGI/Gunrock persistent-kernel comparison targets).

Rows:

* ``iterloop-periter`` — ``sync_every=1`` (legacy one-dispatch-per-
  iteration loop);
* ``iterloop-chunked`` — ``sync_every=K``; ``derived`` records the
  dispatch counts and the wall-clock speedup.

``--selfcheck`` is the CI gate: it monkeypatch-counts driver dispatches
and asserts the chunked run batches for real — chunk dispatches
<= iterations/K + 1 (vs exactly ``iterations`` single-iteration
dispatches for the per-iteration driver), bit-identical values, and a
strictly faster chunked wall time on the smoke graph.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from benchmarks.common import emit
from repro.core.hytm import (
    HyTMConfig,
    build_runtime,
    count_driver_dispatches,
    run_hytm,
)
from repro.graph.algorithms import BFS
from repro.graph.generators import grid_mesh_graph


def _timed_run(g, cfg, runtime, repeats: int = 3):
    """Median wall seconds of ``run_hytm`` over ``repeats`` (after a
    compile warmup), reusing ``runtime`` so partitioning/upload cost is
    out of the measurement — what remains is the convergence loop."""
    res = run_hytm(g, BFS, source=0, config=cfg, runtime=runtime)
    times = []
    for _ in range(repeats):
        t0 = time.monotonic()
        res = run_hytm(g, BFS, source=0, config=cfg, runtime=runtime)
        times.append(time.monotonic() - t0)
    return res, float(np.median(times))


def run(fast: bool = False, height: int | None = None, width: int = 3,
        sync_every: int = 32, repeats: int = 3, selfcheck: bool = False):
    height = height or (300 if fast else 1200)
    g = grid_mesh_graph(height, width, seed=0)
    base = HyTMConfig(n_partitions=8, sync_every=1)
    chunked = dataclasses.replace(base, sync_every=sync_every)
    rt = build_runtime(g, base)

    with count_driver_dispatches() as c1:
        res1, t1 = _timed_run(g, base, rt, repeats=repeats)
    with count_driver_dispatches() as cK:
        resK, tK = _timed_run(g, chunked, rt, repeats=repeats)

    runs = repeats + 1  # + warmup
    emit(
        "iterloop-periter", t1 * 1e6,
        f"iters={res1.iterations};dispatches_per_run={c1['iteration'] // runs}",
    )
    emit(
        "iterloop-chunked", tK * 1e6,
        f"K={sync_every};iters={resK.iterations};"
        f"dispatches_per_run={cK['chunk'] // runs};speedup={t1 / tK:.2f}x",
    )

    np.testing.assert_array_equal(res1.values, resK.values)
    assert res1.iterations == resK.iterations
    if selfcheck:
        # the dispatch-count gate: the chunked loop really batches
        per_run_chunks = cK["chunk"] // runs
        bound = resK.iterations // sync_every + 1
        assert per_run_chunks <= bound, (per_run_chunks, bound)
        assert c1["iteration"] // runs == res1.iterations
        assert cK["iteration"] == 0, "chunked driver dispatched single iterations"
        assert tK < t1, f"chunked {tK:.3f}s not faster than per-iteration {t1:.3f}s"
        print(f"OK iterloop selfcheck: {per_run_chunks} chunk dispatches "
              f"<= {bound} for {resK.iterations} iters (K={sync_every}), "
              f"speedup {t1 / tK:.2f}x")
    return res1, resK


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--height", type=int, default=None)
    ap.add_argument("--sync-every", type=int, default=32)
    ap.add_argument("--selfcheck", action="store_true",
                    help="CI gate: assert dispatch count <= iters/K + 1, "
                    "bit-identical values, and chunked strictly faster "
                    "on the smoke graph")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(fast=args.fast or args.selfcheck, height=args.height,
        sync_every=args.sync_every, selfcheck=args.selfcheck)


if __name__ == "__main__":
    main()
