"""Deterministic synthetic data pipelines (LM / GNN / RecSys)."""

from repro.data.pipeline import GraphBatches, LMBatches, RecSysBatches

__all__ = ["LMBatches", "GraphBatches", "RecSysBatches"]
