"""Deterministic, shardable synthetic data pipelines.

Every pipeline is a pure function of (seed, step, shard) so that:
* fault-tolerant replay after restore reproduces the exact batch stream
  (train/fault_tolerance.py relies on this),
* each host in a multi-host deployment generates only its shard
  (``shard``/``n_shards``), which is how the real data-loading layer
  would be fed from a sharded file set.

RecSys ids are Zipf-distributed — real CTR traffic is heavy-tailed, which
is exactly what makes the HyTM dedup (compaction) engine win on hot rows.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def _rng(seed: int, step: int, shard: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step, shard]))


@dataclass(frozen=True)
class LMBatches:
    vocab: int
    batch: int           # global batch (sequences)
    seq_len: int
    seed: int = 0
    n_shards: int = 1

    def make(self, step: int, shard: int = 0) -> dict:
        b = self.batch // self.n_shards
        rng = _rng(self.seed, step, shard)
        # Markov-ish stream: mixture of uniform + repeated spans so the
        # loss actually decreases during the example runs.
        base = rng.integers(0, self.vocab, size=(b, self.seq_len), dtype=np.int32)
        span = rng.integers(0, self.vocab, size=(b, 1), dtype=np.int32)
        mask = rng.random((b, self.seq_len)) < 0.5
        tokens = np.where(mask, span, base)
        return {"tokens": tokens}


@dataclass(frozen=True)
class GraphBatches:
    """Seed-node stream for sampled GNN training."""

    n_nodes: int
    batch_nodes: int
    n_classes: int
    seed: int = 0
    n_shards: int = 1

    def make(self, step: int, shard: int = 0) -> dict:
        b = self.batch_nodes // self.n_shards
        rng = _rng(self.seed, step, shard)
        seeds = rng.integers(0, self.n_nodes, size=(b,), dtype=np.int64)
        return {"seeds": seeds}


@dataclass(frozen=True)
class RecSysBatches:
    vocab_sizes: tuple
    batch: int
    n_dense: int = 13
    multi_hot: int = 1
    zipf_a: float = 1.2
    seed: int = 0
    n_shards: int = 1

    def make(self, step: int, shard: int = 0) -> dict:
        b = self.batch // self.n_shards
        rng = _rng(self.seed, step, shard)
        dense = rng.standard_normal((b, self.n_dense)).astype(np.float32)
        cols = []
        for v in self.vocab_sizes:
            # Zipf over [1, inf) folded into [0, v): heavy head == hot rows
            z = rng.zipf(self.zipf_a, size=(b, self.multi_hot)) - 1
            cols.append(np.minimum(z, v - 1).astype(np.int32))
        sparse = np.stack(cols, axis=1)  # (b, n_fields, multi_hot)
        if self.multi_hot == 1:
            sparse = sparse[..., 0]
        labels = (rng.random(b) < 0.25).astype(np.float32)
        return {"dense": dense, "sparse": sparse, "labels": labels}
