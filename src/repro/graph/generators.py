"""Synthetic graph generators (host-side numpy).

``rmat_graph`` follows Chakrabarti et al. [arXiv:cs/0412052 / SIAM'04] with
the canonical (a,b,c,d) = (0.57, 0.19, 0.19, 0.05) power-law parameters the
paper's RMAT ladder uses (paper §VII-F).  ``grid_mesh_graph`` builds the
MeshGraphNet-style simulation mesh; ``batched_molecule_graphs`` builds the
`molecule` shape cell (128 graphs x 30 nodes x 64 edges).
"""

from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph, csr_from_edges


def rmat_graph(
    n_nodes: int,
    n_edges: int,
    seed: int = 0,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    weighted: bool = True,
    dedup: bool = False,
) -> CSRGraph:
    """R-MAT generator, vectorized over all edges and bit-levels at once."""
    rng = np.random.default_rng(seed)
    scale = max(1, int(np.ceil(np.log2(max(n_nodes, 2)))))
    d = 1.0 - a - b - c
    assert d >= 0
    src = np.zeros(n_edges, dtype=np.int64)
    dst = np.zeros(n_edges, dtype=np.int64)
    # Quadrant probabilities: [a (0,0), b (0,1), c (1,0), d (1,1)]
    probs = np.cumsum([a, b, c, d])
    for level in range(scale):
        u = rng.random(n_edges)
        quadrant = np.searchsorted(probs, u)
        src_bit = quadrant >= 2
        dst_bit = (quadrant == 1) | (quadrant == 3)
        src = (src << 1) | src_bit
        dst = (dst << 1) | dst_bit
    src %= n_nodes
    dst %= n_nodes
    weights = rng.integers(1, 64, size=n_edges).astype(np.float32) if weighted else None
    return csr_from_edges(n_nodes, src, dst, weights, dedup=dedup)


def uniform_graph(
    n_nodes: int, n_edges: int, seed: int = 0, weighted: bool = True
) -> CSRGraph:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, size=n_edges)
    dst = rng.integers(0, n_nodes, size=n_edges)
    weights = rng.integers(1, 64, size=n_edges).astype(np.float32) if weighted else None
    return csr_from_edges(n_nodes, src, dst, weights)


def grid_mesh_graph(height: int, width: int, seed: int = 0) -> CSRGraph:
    """2-D simulation mesh with 4-neighbourhood + diagonal bracing edges,
    bidirectional (MeshGraphNet processes directed mesh edges both ways)."""
    ids = np.arange(height * width).reshape(height, width)
    pairs = []
    pairs.append((ids[:, :-1].ravel(), ids[:, 1:].ravel()))  # horizontal
    pairs.append((ids[:-1, :].ravel(), ids[1:, :].ravel()))  # vertical
    pairs.append((ids[:-1, :-1].ravel(), ids[1:, 1:].ravel()))  # diagonal
    src = np.concatenate([p[0] for p in pairs])
    dst = np.concatenate([p[1] for p in pairs])
    s = np.concatenate([src, dst])
    d = np.concatenate([dst, src])
    rng = np.random.default_rng(seed)
    w = rng.random(len(s)).astype(np.float32) + 0.5
    return csr_from_edges(height * width, s, d, w)


def batched_molecule_graphs(
    n_graphs: int, n_nodes: int = 30, n_edges: int = 64, seed: int = 0
) -> CSRGraph:
    """A batch of small molecule-like graphs packed into one block-diagonal
    CSR (standard batched-small-graph layout; segment ids recover graphs)."""
    rng = np.random.default_rng(seed)
    srcs, dsts = [], []
    for gidx in range(n_graphs):
        base = gidx * n_nodes
        # a spanning path guarantees connectivity, rest random (bond-like)
        path_s = np.arange(n_nodes - 1)
        path_d = np.arange(1, n_nodes)
        extra = n_edges // 2 - (n_nodes - 1)
        rs = rng.integers(0, n_nodes, size=max(extra, 0))
        rd = rng.integers(0, n_nodes, size=max(extra, 0))
        s = np.concatenate([path_s, rs])
        d = np.concatenate([path_d, rd])
        # undirected
        srcs.append(base + np.concatenate([s, d]))
        dsts.append(base + np.concatenate([d, s]))
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    w = rng.random(len(src)).astype(np.float32)
    return csr_from_edges(n_graphs * n_nodes, src, dst, w)
