"""Vertex-centric push-based programs (paper §II-A) + numpy references.

A ``VertexProgram`` is the generic function the paper's Figure 1
illustrates: each *active* vertex sends a message along its out-edges;
messages combine at the destination with an associative-commutative
combiner; updated destinations become active next iteration.

Two families, matching the paper's two "typical active-vertex change
patterns" (§III):

* traversal / value-replacement (combine=min): SSSP, BFS, CC — active set
  grows then shrinks.
* accumulative (combine=sum): Δ-PageRank, PHP [41] — active set shrinks
  monotonically; vertex carries (value, pending-Δ).

TPU note: destination combining uses ``segment_min``/``segment_sum``
(associative reductions) instead of GPU atomics — semantics identical for
these combiners (DESIGN.md §2).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph

MIN, SUM = 0, 1


@dataclass(frozen=True)
class VertexProgram:
    name: str
    combine: int  # MIN or SUM
    # message emitted along an edge: f(source_operand, edge_weight) where
    # source_operand is `values[src]` (traversal) or `delta[src]` (accum).
    edge_message: Callable
    # per-source normalization operand (accum programs divide by out-degree)
    use_delta: bool = False
    damping: float = 0.85
    tolerance: float = 1e-3
    weighted: bool = True
    # Personalized accumulative programs (PPR): the teleport mass lands on
    # a single source vertex instead of uniformly, so the program is
    # *per-source* like a traversal — it multiplexes into the vmapped
    # lane sweep (many sparse SUM lanes) and caches per source, while the
    # un-personalized family (PageRank) stays global (source=None key).
    personalized: bool = False
    # WCC-family programs are defined on the *underlying undirected*
    # graph: run_hytm / run_hytm_sharded symmetrize the input graph
    # before building the runtime, so the caller can hand the directed
    # graph directly (CC, by contrast, sweeps whatever edges it's given
    # and callers symmetrize explicitly).
    symmetrize: bool = False
    # Peeling programs (k-core): vertices are iteratively *removed* when
    # their remaining degree drops below ``peel_k``; the frontier is the
    # newly-removed set (collapsing monotonically), messages are unit
    # removal counts combined with SUM, and the value is the remaining
    # effective degree.  Integer counts are exact in f32, so peeling runs
    # bit-identical across single-device / sharded / owner-sharded paths
    # (the MIN-family exactness contract).  State is seeded from the
    # runtime's out-degrees by ``run_hytm``/``run_hytm_sharded`` —
    # ``init_state`` has no degree access and must not be used.
    peel_k: float | None = None

    def init_state(self, n: int, source: int | None):
        if self.peel_k is not None:
            raise ValueError(
                f"{self.name}: peeling programs seed from vertex degrees; "
                "use run_hytm/run_hytm_sharded (they special-case the "
                "init), not init_state")
        if self.use_delta and self.personalized and source is not None:
            # Δ-PPR: all (1-d) teleport mass starts as pending delta on
            # the personalization source; fixpoint values solve
            # r = (1-d)·e_s + d·AᵀD⁻¹·r  (reference_ppr).
            values = jnp.zeros(n, dtype=jnp.float32)
            delta = jnp.zeros(n, dtype=jnp.float32).at[source].set(
                1.0 - self.damping)
            frontier = jnp.zeros(n, dtype=bool).at[source].set(True)
        elif self.use_delta:
            values = jnp.zeros(n, dtype=jnp.float32)
            delta = jnp.full(n, 1.0 - self.damping, dtype=jnp.float32)
            frontier = jnp.ones(n, dtype=bool)
        elif self.name in ("cc", "wcc"):
            values = jnp.arange(n, dtype=jnp.float32)
            delta = jnp.zeros(n, dtype=jnp.float32)
            frontier = jnp.ones(n, dtype=bool)
        else:
            values = jnp.full(n, jnp.inf, dtype=jnp.float32)
            values = values.at[source].set(0.0)
            delta = jnp.zeros(n, dtype=jnp.float32)
            frontier = jnp.zeros(n, dtype=bool).at[source].set(True)
        return values, delta, frontier


def _sssp_msg(src_val, w):
    return src_val + w


def _bfs_msg(src_val, w):
    return src_val + 1.0


def _cc_msg(src_val, w):
    return src_val


def _pr_msg(src_delta_over_deg, w):
    return src_delta_over_deg  # damping folded in by the engine


def _php_msg(src_delta_over_deg, w):
    return src_delta_over_deg * w


def _kcore_msg(src_op, w):
    # unit removal count, independent of the source operand (the engines
    # mask inactive lanes to the SUM identity 0.0, so only newly-removed
    # sources contribute)
    return jnp.ones_like(src_op)


SSSP = VertexProgram("sssp", MIN, _sssp_msg, weighted=True)
BFS = VertexProgram("bfs", MIN, _bfs_msg, weighted=False)
CC = VertexProgram("cc", MIN, _cc_msg, weighted=False)
# weakly connected components: the same min-label propagation as CC, but
# over the symmetrized edge set — run directly on the directed graph
# (labels = min vertex id reachable ignoring edge direction)
WCC = VertexProgram("wcc", MIN, _cc_msg, weighted=False, symmetrize=True)
PAGERANK = VertexProgram("pagerank", SUM, _pr_msg, use_delta=True, weighted=False)
PHP = VertexProgram("php", SUM, _php_msg, use_delta=True, weighted=True)
PPR = VertexProgram("ppr", SUM, _pr_msg, use_delta=True, weighted=False,
                    personalized=True)
# k-core decomposition at fixed k (peeling): defined on the undirected
# graph; values = remaining effective degree, Δ = removed flag (0 alive /
# 1 removed), frontier = newly-removed vertices.  The collapsing frontier
# is the stress case for the compacted halo exchange.
KCORE = VertexProgram("kcore", SUM, _kcore_msg, weighted=False,
                      symmetrize=True, damping=1.0, peel_k=2.0)

ALGORITHMS = {p.name: p for p in (SSSP, BFS, CC, WCC, PAGERANK, PHP, PPR,
                                  KCORE)}


# --------------------------------------------------------------------------
# Numpy references (oracles for tests / benchmarks)
# --------------------------------------------------------------------------

def reference_sssp(g: CSRGraph, source: int) -> np.ndarray:
    """Bellman-Ford over CSR (handles arbitrary positive weights)."""
    dist = np.full(g.n_nodes, np.inf, dtype=np.float64)
    dist[source] = 0.0
    src = g.edge_sources()
    w = g.weights if g.weights is not None else np.ones(g.n_edges, dtype=np.float64)
    for _ in range(g.n_nodes):
        cand = dist[src] + w
        new = dist.copy()
        np.minimum.at(new, g.indices, cand)
        if np.allclose(new, dist, equal_nan=True):
            break
        dist = new
    return dist


def reference_bfs(g: CSRGraph, source: int) -> np.ndarray:
    level = np.full(g.n_nodes, np.inf)
    level[source] = 0
    frontier = np.array([source])
    depth = 0
    while len(frontier):
        depth += 1
        nxt = []
        for u in frontier:
            nbrs = g.indices[g.indptr[u]:g.indptr[u + 1]]
            fresh = nbrs[level[nbrs] == np.inf]
            level[fresh] = depth
            nxt.append(np.unique(fresh))
        frontier = np.concatenate(nxt) if nxt else np.array([], dtype=np.int64)
        frontier = np.unique(frontier)
    return level


def reference_cc(g: CSRGraph) -> np.ndarray:
    """Min-label propagation on the symmetrized graph (matches the device
    program's semantics: component id = min vertex id in component)."""
    sym = g.symmetrize()
    label = np.arange(sym.n_nodes, dtype=np.int64)
    src = sym.edge_sources()
    changed = True
    while changed:
        cand = label[src]
        new = label.copy()
        np.minimum.at(new, sym.indices, cand)
        new = np.minimum(new, label)
        changed = not np.array_equal(new, label)
        label = new
    return label


def reference_wcc(g: CSRGraph) -> np.ndarray:
    """Weakly connected components by union-find over the directed edge
    list (direction ignored), roots relabeled to the min vertex id of
    each component so the labels match the device program's min-label
    fixpoint exactly."""
    n = g.n_nodes
    parent = np.arange(n, dtype=np.int64)

    def find(x: int) -> int:
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:   # path compression
            parent[x], x = root, parent[x]
        return root

    for u, v in zip(g.edge_sources(), g.indices):
        ru, rv = find(int(u)), find(int(v))
        if ru != rv:
            parent[max(ru, rv)] = min(ru, rv)

    roots = np.array([find(i) for i in range(n)], dtype=np.int64)
    # min vertex id per component (roots are already component minima
    # given the min-directed unions above, but don't rely on it)
    comp_min = np.full(n, n, dtype=np.int64)
    np.minimum.at(comp_min, roots, np.arange(n, dtype=np.int64))
    return comp_min[roots]


def reference_kcore(g: CSRGraph, k: float = 2.0):
    """Synchronous k-core peeling on the symmetrized graph, mirroring the
    device program round for round: every round the newly-removed set
    pushes one unit along its out-edges, every destination's remaining
    degree drops by its count of newly-removed in-neighbors (removed
    destinations included — the device subtracts unconditionally), and
    alive vertices falling below ``k`` join the next round's removal.
    Returns ``(removed, remaining_degree)``."""
    sym = g.symmetrize()
    n = sym.n_nodes
    deg = sym.out_degrees.astype(np.float64)
    src = sym.edge_sources()
    dst = sym.indices
    removed = deg < k
    newly = removed.copy()
    while newly.any():
        counts = np.zeros(n)
        m = newly[src]
        np.add.at(counts, dst[m], 1.0)
        deg = deg - counts
        nxt = (~removed) & (deg < k)
        removed |= nxt
        newly = nxt
    return removed, deg


def reference_ppr(
    g: CSRGraph, source: int, damping: float = 0.85, iters: int = 500
) -> np.ndarray:
    """Personalized PageRank matching Δ-PPR push semantics:
    r = (1-d)·e_s + d·AᵀD⁻¹r, dangling mass dropped (same as the
    push-based program, which pushes along out-edges only)."""
    n = g.n_nodes
    deg = np.maximum(g.out_degrees.astype(np.float64), 1)
    src = g.edge_sources()
    teleport = np.zeros(n)
    teleport[source] = 1.0 - damping
    r = teleport.copy()
    for _ in range(iters):
        contrib = damping * r[src] / deg[src]
        nxt = teleport.copy()
        np.add.at(nxt, g.indices, contrib)
        if np.max(np.abs(nxt - r)) < 1e-12:
            r = nxt
            break
        r = nxt
    return r


def reference_pagerank(g: CSRGraph, damping: float = 0.85, iters: int = 200) -> np.ndarray:
    """Unnormalized PR matching Δ-PR semantics: r = (1-d)·1 + d·AᵀD⁻¹r,
    dangling mass dropped (same as push-based Δ-PR over out-edges)."""
    n = g.n_nodes
    deg = np.maximum(g.out_degrees.astype(np.float64), 1)
    src = g.edge_sources()
    r = np.full(n, 1.0 - damping)
    for _ in range(iters):
        contrib = damping * r[src] / deg[src]
        nxt = np.full(n, 1.0 - damping)
        np.add.at(nxt, g.indices, contrib)
        if np.max(np.abs(nxt - r)) < 1e-10:
            r = nxt
            break
        r = nxt
    return r
