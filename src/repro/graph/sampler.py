"""Fanout neighbour sampling (GraphSAGE [arXiv:1706.02216] minibatch path).

The `minibatch_lg` shape cell requires a *real* neighbour sampler: given a
CSR graph, seed nodes, and a fanout list (e.g. 15-10), draw a fixed number
of neighbours per layer with replacement (the GraphSAGE estimator).  Static
output shapes make the result directly jittable.

Two implementations with identical semantics:
  * ``sample_neighbors`` — host-side numpy (data-pipeline path).
  * ``sample_neighbors_device`` — jnp/jax.random (in-step path; used when
    the CSR fits on device, e.g. reddit-scale).
Zero-degree vertices sample themselves (self-loop fallback) so downstream
aggregation never sees invalid ids.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph


def sample_neighbors(
    g: CSRGraph,
    seeds: np.ndarray,
    fanouts: Sequence[int],
    seed: int = 0,
) -> list[np.ndarray]:
    """Layered sampling. Returns ``[seeds, hop1, hop2, ...]`` where
    ``hop_k`` has shape ``seeds.shape + (fanouts[0], ..., fanouts[k-1])``
    flattened to ``(n_prev * fanout_k,)`` row-major."""
    rng = np.random.default_rng(seed)
    deg = g.out_degrees
    layers = [np.asarray(seeds, dtype=np.int64)]
    frontier = layers[0]
    for f in fanouts:
        d = deg[frontier]
        offs = rng.integers(0, np.maximum(d, 1)[:, None], size=(len(frontier), f))
        base = g.indptr[frontier][:, None]
        eids = base + offs
        nbrs = g.indices[np.minimum(eids, g.n_edges - 1)].astype(np.int64)
        # self-loop fallback for isolated vertices
        nbrs = np.where(d[:, None] == 0, frontier[:, None], nbrs)
        frontier = nbrs.reshape(-1)
        layers.append(frontier)
    return layers


def sample_neighbors_device(
    key: jax.Array,
    indptr: jax.Array,      # (n+1,) int32
    indices: jax.Array,     # (m,) int32
    seeds: jax.Array,       # (b,) int32
    fanouts: Sequence[int],
) -> list[jax.Array]:
    """Device-side equivalent (uniform with replacement, static shapes)."""
    deg = jnp.diff(indptr)
    layers = [seeds.astype(jnp.int32)]
    frontier = layers[0]
    for i, f in enumerate(fanouts):
        key_i = jax.random.fold_in(key, i)
        d = deg[frontier]
        u = jax.random.uniform(key_i, (frontier.shape[0], f))
        offs = jnp.floor(u * jnp.maximum(d, 1)[:, None]).astype(jnp.int32)
        base = indptr[frontier][:, None].astype(jnp.int32)
        eids = jnp.minimum(base + offs, indices.shape[0] - 1)
        nbrs = indices[eids]
        nbrs = jnp.where(d[:, None] == 0, frontier[:, None], nbrs)
        frontier = nbrs.reshape(-1)
        layers.append(frontier)
    return layers
