"""Graph substrate: CSR containers, generators, partitioning, frontier ops.

Everything the HyTM core (``repro.core``) consumes lives here.  Host-side
preprocessing (generation, hub sorting, partitioning) is numpy; the runtime
structures handed to jitted code are jnp pytrees.
"""

from repro.graph.csr import CSRGraph, DeviceCSR, csr_from_edges
from repro.graph.generators import rmat_graph, uniform_graph, grid_mesh_graph, batched_molecule_graphs
from repro.graph.hub_sort import hub_sort
from repro.graph.sampler import sample_neighbors

__all__ = [
    "CSRGraph",
    "DeviceCSR",
    "csr_from_edges",
    "rmat_graph",
    "uniform_graph",
    "grid_mesh_graph",
    "batched_molecule_graphs",
    "hub_sort",
    "sample_neighbors",
]
