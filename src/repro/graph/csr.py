"""CSR graph containers.

``CSRGraph`` is the host-side (numpy) container used by preprocessing:
generation, hub sorting, partitioning, and reference algorithms.

``DeviceCSR`` is the device-side pytree consumed by jitted HyTM code.  It
carries, in addition to the CSR triplet, the *expanded source array*
(``edge_src``, the COO row index of every edge).  The paper's push-based
engines relax each active edge ``(u -> v, w)`` as ``msg = f(val[u], w)``;
with ``edge_src`` resident this becomes a flat gather over edge blocks,
which is the TPU-friendly layout (contiguous (8,128)-tileable streams)
instead of a per-vertex pointer chase.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class CSRGraph:
    """Host-side CSR graph. ``indptr[v]:indptr[v+1]`` are v's out-edges."""

    indptr: np.ndarray  # (n+1,) int64
    indices: np.ndarray  # (m,)  int32 — destination of each out-edge
    weights: np.ndarray | None = None  # (m,) float32

    def __post_init__(self):
        self.indptr = np.asarray(self.indptr, dtype=np.int64)
        self.indices = np.asarray(self.indices, dtype=np.int32)
        if self.weights is not None:
            self.weights = np.asarray(self.weights, dtype=np.float32)

    # ------------------------------------------------------------------ stats
    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def n_edges(self) -> int:
        return int(self.indptr[-1])

    @property
    def out_degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    @property
    def in_degrees(self) -> np.ndarray:
        return np.bincount(self.indices, minlength=self.n_nodes).astype(np.int64)

    def edge_sources(self) -> np.ndarray:
        """COO row index for every edge ('expanded' indptr)."""
        return np.repeat(
            np.arange(self.n_nodes, dtype=np.int32), self.out_degrees
        )

    # ------------------------------------------------------------- transforms
    def transpose(self) -> "CSRGraph":
        """Reverse every edge (used to derive pull-direction / in-degrees)."""
        src = self.edge_sources()
        return csr_from_edges(
            self.n_nodes, self.indices.astype(np.int64), src.astype(np.int64),
            self.weights,
        )

    def symmetrize(self) -> "CSRGraph":
        """Union of the graph and its transpose (CC runs on this)."""
        src = self.edge_sources().astype(np.int64)
        dst = self.indices.astype(np.int64)
        s = np.concatenate([src, dst])
        d = np.concatenate([dst, src])
        w = None
        if self.weights is not None:
            w = np.concatenate([self.weights, self.weights])
        return csr_from_edges(self.n_nodes, s, d, w, dedup=True)

    def permute(self, perm: np.ndarray) -> "CSRGraph":
        """Relabel vertices: new id of old vertex v is ``perm[v]``.

        Edge (u, v, w) becomes (perm[u], perm[v], w).  Used by hub sorting.
        """
        src = perm[self.edge_sources().astype(np.int64)]
        dst = perm[self.indices.astype(np.int64)]
        return csr_from_edges(self.n_nodes, src, dst, self.weights)

    def validate(self) -> None:
        assert self.indptr[0] == 0
        assert np.all(np.diff(self.indptr) >= 0), "indptr must be monotone"
        assert self.indptr[-1] == len(self.indices)
        if len(self.indices):
            assert self.indices.min() >= 0
            assert self.indices.max() < self.n_nodes
        if self.weights is not None:
            assert len(self.weights) == len(self.indices)


def csr_from_edges(
    n_nodes: int,
    src: np.ndarray,
    dst: np.ndarray,
    weights: np.ndarray | None = None,
    dedup: bool = False,
) -> CSRGraph:
    """Build a CSR graph from COO edge lists (host-side, O(m log m))."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if dedup:
        key = src * n_nodes + dst
        _, uniq_idx = np.unique(key, return_index=True)
        src, dst = src[uniq_idx], dst[uniq_idx]
        if weights is not None:
            weights = np.asarray(weights)[uniq_idx]
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    if weights is not None:
        weights = np.asarray(weights, dtype=np.float32)[order]
    counts = np.bincount(src, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSRGraph(indptr=indptr, indices=dst.astype(np.int32), weights=weights)


# --------------------------------------------------------------------------
# Device-side structure
# --------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class DeviceCSR:
    """Device-resident CSR + expanded COO rows, padded to static shapes.

    Layout mirrors the paper's residency split: the *vertex-associated*
    arrays (``indptr`` analogue: ``out_degree``/``seg_start``, activity and
    values live with the HyTM state) are small; the *edge-associated* arrays
    (``edge_src``, ``edge_dst``, ``edge_weight``) are the large streams whose
    movement HyTM manages.

    Edges are padded to ``capacity`` with self-loops on vertex 0 and weight
    +inf (traversal) so padding never relaxes anything; ``edge_valid`` masks
    them explicitly for sum-combine algorithms.
    """

    edge_src: jax.Array  # (capacity,) int32
    edge_dst: jax.Array  # (capacity,) int32
    edge_weight: jax.Array  # (capacity,) float32
    edge_valid: jax.Array  # (capacity,) bool
    out_degree: jax.Array  # (n,) int32
    seg_start: jax.Array  # (n,) int32 — indptr[:-1]: start of v's edge segment
    n_nodes: int = dataclasses.field(metadata=dict(static=True))
    n_edges: int = dataclasses.field(metadata=dict(static=True))

    @property
    def capacity(self) -> int:
        return self.edge_src.shape[0]


def to_device_csr(g: CSRGraph, capacity: int | None = None, pad_multiple: int = 1024) -> DeviceCSR:
    """Upload a host CSR to a padded device structure."""
    m = g.n_edges
    if capacity is None:
        capacity = max(pad_multiple, -(-m // pad_multiple) * pad_multiple)
    assert capacity >= m
    src = np.zeros(capacity, dtype=np.int32)
    dst = np.zeros(capacity, dtype=np.int32)
    w = np.full(capacity, np.float32(np.inf), dtype=np.float32)
    valid = np.zeros(capacity, dtype=bool)
    src[:m] = g.edge_sources()
    dst[:m] = g.indices
    w[:m] = g.weights if g.weights is not None else 1.0
    valid[:m] = True
    return DeviceCSR(
        edge_src=jnp.asarray(src),
        edge_dst=jnp.asarray(dst),
        edge_weight=jnp.asarray(w),
        edge_valid=jnp.asarray(valid),
        out_degree=jnp.asarray(g.out_degrees, dtype=jnp.int32),
        seg_start=jnp.asarray(g.indptr[:-1], dtype=jnp.int32),
        n_nodes=g.n_nodes,
        n_edges=m,
    )
