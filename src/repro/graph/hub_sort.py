"""Hub sorting (paper §VI-A, following Zhang et al. BigData'17 [42]).

Gathers the top ``hub_fraction`` (paper: 8%) of vertices — ranked by
``H(v) = D_o(v) * D_i(v) / (D_omax * D_imax)`` (Eq. 4) — to the *front* of
the CSR id space, keeping all non-hub vertices in their natural order.

Because hub vertices then occupy the first partitions, hub-vertex-driven
priority scheduling reduces to "schedule low partition ids first", and the
high-in-degree vertices (likely active) are stored together, which sharpens
per-partition cost analysis (paper's stated second benefit).

Done once at preprocessing; every algorithm run reuses it (paper §VI-A).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.graph.csr import CSRGraph


@dataclass(frozen=True)
class HubSortResult:
    graph: CSRGraph
    perm: np.ndarray        # old id -> new id
    inv_perm: np.ndarray    # new id -> old id
    n_hubs: int

    def to_new(self, old_ids: np.ndarray) -> np.ndarray:
        return self.perm[np.asarray(old_ids)]

    def values_to_old(self, new_values: np.ndarray) -> np.ndarray:
        """Reorder a per-vertex result array back to original vertex ids."""
        return np.asarray(new_values)[self.perm]


def hub_scores(g: CSRGraph) -> np.ndarray:
    do = g.out_degrees.astype(np.float64)
    di = g.in_degrees.astype(np.float64)
    do_max = max(do.max(initial=0.0), 1.0)
    di_max = max(di.max(initial=0.0), 1.0)
    return (do * di) / (do_max * di_max)


def hub_sort(g: CSRGraph, hub_fraction: float = 0.08) -> HubSortResult:
    n = g.n_nodes
    n_hubs = int(np.ceil(hub_fraction * n))
    h = hub_scores(g)
    # Top-n_hubs by H(v), sorted by descending score; stable so equal-score
    # vertices keep natural order.
    order = np.argsort(-h, kind="stable")
    hubs = order[:n_hubs]
    hub_mask = np.zeros(n, dtype=bool)
    hub_mask[hubs] = True
    non_hubs = np.nonzero(~hub_mask)[0]  # natural order preserved
    inv_perm = np.concatenate([hubs, non_hubs]).astype(np.int64)
    perm = np.empty(n, dtype=np.int64)
    perm[inv_perm] = np.arange(n)
    return HubSortResult(graph=g.permute(perm), perm=perm, inv_perm=inv_perm, n_hubs=n_hubs)
