"""Fault tolerance for the training loop: checkpoint/restart, simulated
node failure, elastic rescale, straggler mitigation.

On a real 1000+ node deployment the failure signal comes from the
coordinator (missed heartbeat / ICI timeout); here `FaultInjector`
produces the same signal deterministically so the recovery path is
exercised by tests and examples:

  failure -> drop in-flight step -> restore latest checkpoint (possibly
  on a different mesh: elastic re-shard happens inside restore) -> replay
  from the checkpointed step with the deterministic data pipeline.

Straggler mitigation: per-step wall times feed an EWMA; steps slower than
``straggler_factor`` x median trigger the mitigation callback (on real
hardware: re-shard away from the slow host / enable backup execution;
here: recorded + surfaced in metrics so the policy is testable).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from repro.train.checkpoint import latest_steps, restore_checkpoint, save_checkpoint


class SimulatedFault(RuntimeError):
    pass


@dataclass
class FaultInjector:
    """Deterministically raise SimulatedFault at the given steps."""

    fail_at_steps: tuple = ()
    fired: set = field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFault(f"injected node failure at step {step}")


@dataclass
class StragglerMonitor:
    factor: float = 3.0
    window: int = 32
    times: list = field(default_factory=list)
    flagged: list = field(default_factory=list)
    on_straggler: Callable[[int, float], None] | None = None

    def record(self, step: int, seconds: float) -> bool:
        self.times.append(seconds)
        hist = self.times[-self.window :]
        med = float(np.median(hist))
        is_straggler = len(hist) >= 8 and seconds > self.factor * med
        if is_straggler:
            self.flagged.append(step)
            if self.on_straggler:
                self.on_straggler(step, seconds)
        return is_straggler


@dataclass
class FaultTolerantLoop:
    """Drives (state, batch) -> (state, metrics) with checkpoint/restart."""

    step_fn: Callable
    batch_fn: Callable[[int], Any]       # deterministic: step -> batch
    ckpt_dir: str
    ckpt_every: int = 10
    keep: int = 3
    async_ckpt: bool = True
    injector: FaultInjector | None = None
    monitor: StragglerMonitor | None = None
    max_restarts: int = 8

    def run(self, state, n_steps: int, start_step: int = 0):
        metrics_log: list[dict] = []
        restarts = 0
        step = start_step
        pending = None
        while step < n_steps:
            try:
                if self.injector is not None:
                    self.injector.check(step)
                t0 = time.monotonic()
                state, metrics = self.step_fn(state, self.batch_fn(step))
                dt = time.monotonic() - t0
                if self.monitor is not None:
                    self.monitor.record(step, dt)
                metrics_log.append({"step": step, **{k: float(v) for k, v in metrics.items()}})
                step += 1
                if step % self.ckpt_every == 0:
                    pending = save_checkpoint(
                        self.ckpt_dir, step, state,
                        async_write=self.async_ckpt, keep=self.keep,
                    )
            except SimulatedFault:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                if pending is not None:
                    pending.join()
                steps = latest_steps(self.ckpt_dir)
                if steps:
                    step, state = restore_checkpoint(self.ckpt_dir, state)
                else:
                    step = start_step  # no checkpoint yet: replay from scratch
        if pending is not None:
            pending.join()
        return state, metrics_log, restarts
