"""Gradient compression with error feedback (distributed-optimization
substrate for DP gradient exchange at 1000+ node scale).

Two compressors:
* ``int8`` — per-tensor symmetric quantization: 4x fewer bytes on the
  all-reduce wire; error feedback (Seide et al. / EF-SGD) accumulates the
  quantization residual locally so the scheme stays unbiased over time.
* ``topk`` — magnitude sparsification to fraction ``k`` with residual
  accumulation (Deep Gradient Compression).

The compressors are pure pytree transforms usable inside jit; the train
step applies compress -> (wire) -> decompress around the optimizer so the
numerics of a compressed all-reduce are faithfully reproduced even though
XLA's collective itself stays uncompressed on the CPU dry-run target.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"       # 'none' | 'int8' | 'topk'
    topk_fraction: float = 0.01
    error_feedback: bool = True


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _int8_roundtrip(g: jax.Array) -> jax.Array:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def _topk_roundtrip(g: jax.Array, frac: float) -> jax.Array:
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)


def compress_grads(cfg: CompressionConfig, grads, error_state):
    """Returns (wire_grads, new_error_state).  wire_grads is what survives
    the compressed exchange; the residual goes to error feedback."""
    if cfg.kind == "none":
        return grads, error_state

    def one(g, e):
        g32 = g.astype(jnp.float32) + (e if cfg.error_feedback else 0.0)
        if cfg.kind == "int8":
            wire = _int8_roundtrip(g32)
        elif cfg.kind == "topk":
            wire = _topk_roundtrip(g32, cfg.topk_fraction)
        else:
            raise ValueError(cfg.kind)
        new_e = g32 - wire if cfg.error_feedback else e
        return wire.astype(g.dtype), new_e

    out = jax.tree.map(one, grads, error_state)
    isl = lambda x: isinstance(x, tuple)
    return (
        jax.tree.map(lambda o: o[0], out, is_leaf=isl),
        jax.tree.map(lambda o: o[1], out, is_leaf=isl),
    )


def wire_bytes(cfg: CompressionConfig, grads) -> float:
    """Modeled bytes on the all-reduce wire (for EXPERIMENTS.md §Perf)."""
    total = sum(l.size for l in jax.tree.leaves(grads))
    if cfg.kind == "int8":
        return total * 1.0 + len(jax.tree.leaves(grads)) * 4.0
    if cfg.kind == "topk":
        return total * cfg.topk_fraction * 8.0  # value + index
    return total * 4.0
