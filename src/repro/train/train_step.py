"""Generic train/eval stepping: loss -> (microbatched) grads -> clip ->
(optional compression w/ error feedback) -> optimizer update.

``make_train_step`` returns a pure function suitable for jit/pjit; the
microbatch path accumulates gradients with ``lax.scan`` (gradient
accumulation == pipeline-friendly activation memory bound).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.train.compression import CompressionConfig, compress_grads
from repro.train.optimizer import OptimizerConfig, apply_updates, clip_by_global_norm


@dataclass(frozen=True)
class TrainState:
    """Lightweight pytree train state (registered below)."""

    params: dict
    opt_state: dict
    error_state: dict | None
    step: jax.Array


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt_state, s.error_state, s.step), None),
    lambda _, c: TrainState(*c),
)


def init_train_state(params, opt_cfg: OptimizerConfig, comp_cfg: CompressionConfig | None = None):
    from repro.train.compression import init_error_state
    from repro.train.optimizer import init_opt_state

    err = None
    if comp_cfg is not None and comp_cfg.kind != "none":
        err = init_error_state(params)
    return TrainState(
        params=params,
        opt_state=init_opt_state(opt_cfg, params),
        error_state=err,
        step=jnp.zeros((), jnp.int32),
    )


def make_train_step(
    loss_fn: Callable,            # loss_fn(params, batch) -> scalar
    opt_cfg: OptimizerConfig,
    comp_cfg: CompressionConfig | None = None,
    microbatches: int = 1,
    microbatch_constraint: Callable | None = None,
    accum_dtype=jnp.float32,
):
    """``microbatch_constraint`` re-pins the reshaped (mb, B/mb, ...) batch
    sharding: without it GSPMD is free to shard the *microbatch* axis over
    the data mesh axis, which silently turns gradient accumulation back
    into one full-batch step (observed: +13 GiB/device on train_4k)."""

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(state: TrainState, batch):
        if microbatches > 1:
            # batch leading dim splits into microbatches; grads accumulate
            # in fp32 (bounds activation memory for the huge-model cells).
            def micro(carry, mb):
                loss_acc, g_acc = carry
                loss, g = grads_of(state.params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(accum_dtype), g_acc, g)
                return (loss_acc + loss, g_acc), None

            mbs = jax.tree.map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches, *x.shape[1:]),
                batch,
            )
            if microbatch_constraint is not None:
                mbs = microbatch_constraint(mbs)
            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), state.params)
            (loss, grads), _ = jax.lax.scan(micro, (jnp.float32(0.0), zero_g), mbs)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        else:
            loss, grads = grads_of(state.params, batch)

        grads, grad_norm = clip_by_global_norm(grads, opt_cfg.grad_clip)

        error_state = state.error_state
        if comp_cfg is not None and comp_cfg.kind != "none":
            grads, error_state = compress_grads(comp_cfg, grads, error_state)

        params, opt_state = apply_updates(
            opt_cfg, state.params, grads, state.opt_state, state.step
        )
        new_state = TrainState(
            params=params, opt_state=opt_state,
            error_state=error_state, step=state.step + 1,
        )
        metrics = {"loss": loss, "grad_norm": grad_norm}
        return new_state, metrics

    return train_step
