"""Sharded, atomic, async checkpointing with elastic restore.

Layout per step::

    <dir>/step_000123.tmp/   -> written, fsynced, then renamed to
    <dir>/step_000123/
        manifest.json        — step, treedef repr, leaf paths/shapes/dtypes
        arrays.npz           — one entry per leaf (path-keyed)

* **atomic**: the tmp-dir rename is the commit point; a crash mid-write
  leaves only a .tmp dir that restore ignores and cleanup reaps.
* **async**: a snapshot (host copy) is taken synchronously, the write
  happens on a worker thread so training continues (the paper's
  multi-stream overlap philosophy applied to I/O).
* **elastic restore**: arrays are loaded as full host buffers and
  device_put against *whatever sharding the live mesh dictates* — a
  restart on 512 chips restores a 256-chip checkpoint and vice versa
  (re-sharding at load is what makes restart-after-failure topology
  independent at 1000+ node scale).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _leaf_key(path) -> str:
    return jax.tree_util.keystr(path)


# One writer at a time: otherwise an earlier writer's cleanup can reap a
# newer writer's in-progress .tmp directory.
_WRITE_LOCK = threading.Lock()


def save_checkpoint(
    directory: str,
    step: int,
    tree: Any,
    async_write: bool = False,
    keep: int = 3,
) -> threading.Thread | None:
    os.makedirs(directory, exist_ok=True)
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    # Snapshot synchronously (device -> host) so training can mutate state.
    snapshot = {_leaf_key(p): np.asarray(l) for p, l in leaves}

    def write():
        with _WRITE_LOCK:
            _write_locked()

    def _write_locked():
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **snapshot)
        manifest = {
            "step": step,
            "leaves": {
                k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in snapshot.items()
            },
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # commit point
        _cleanup(directory, keep)

    if async_write:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def _cleanup(directory: str, keep: int) -> None:
    steps = sorted(latest_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)
    for name in os.listdir(directory):
        if name.endswith(".tmp"):
            shutil.rmtree(os.path.join(directory, name), ignore_errors=True)


def latest_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                out.append(int(name[5:]))
    return sorted(out)


def restore_checkpoint(
    directory: str,
    target_tree: Any,
    step: int | None = None,
    shardings: Any | None = None,
) -> tuple[int, Any]:
    """Restore into the structure of ``target_tree``.

    ``shardings`` (a matching pytree of jax.sharding.Sharding, or a single
    sharding, or None) controls placement — pass the *new* mesh's
    shardings for elastic restarts.
    """
    steps = latest_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    step = steps[-1] if step is None else step
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as data:
        loaded = {k: data[k] for k in data.files}

    paths, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    shard_leaves = None
    if shardings is not None and not isinstance(shardings, jax.sharding.Sharding):
        shard_leaves = jax.tree_util.tree_leaves(shardings)

    leaves = []
    for i, (p, ref) in enumerate(paths):
        key = _leaf_key(p)
        if key not in loaded:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = loaded[key]
        if isinstance(shardings, jax.sharding.Sharding):
            arr = jax.device_put(arr, shardings)
        elif shard_leaves is not None:
            arr = jax.device_put(arr, shard_leaves[i])
        else:
            arr = jax.device_put(arr)
        leaves.append(arr)
    return step, jax.tree_util.tree_unflatten(treedef, leaves)
