"""Optimizers: AdamW, Adafactor (factored second moments — required to fit
1T-param MoE optimizer state on a 512-chip v5e slice, DESIGN.md §5), SGD.

Plain pytree transforms (no optax dependency): ``init_opt_state`` /
``apply_updates``.  Optimizer state inherits parameter sharding under
GSPMD (fully-sharded optimizer == ZeRO-equivalent for free).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"          # 'adamw' | 'adafactor' | 'sgd'
    learning_rate: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"     # 'cosine' | 'linear' | 'constant'
    # adafactor
    factored_min_dim: int = 32
    decay_rate: float = 0.8

    def replace(self, **kw):
        import dataclasses
        return dataclasses.replace(self, **kw)


def learning_rate(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    if cfg.schedule == "cosine":
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = 1.0
    return cfg.learning_rate * warm * decay


def _is_factored(shape, cfg: OptimizerConfig) -> bool:
    return len(shape) >= 2 and shape[-1] >= cfg.factored_min_dim and shape[-2] >= cfg.factored_min_dim


def init_opt_state(cfg: OptimizerConfig, params) -> dict:
    if cfg.name == "sgd":
        return {"momentum": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}
    if cfg.name == "adamw":
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}
    if cfg.name == "adafactor":
        def fac(p):
            if _is_factored(p.shape, cfg):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),          # row stats
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),  # col stats
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(fac, params, is_leaf=lambda x: hasattr(x, "shape"))}
    raise ValueError(cfg.name)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


_CHUNKED_LEAF_ELEMS = 2**27  # 128M elements (~512 MB fp32 temporaries)


def _leafwise_factored(upd):
    """Adafactor variant: the state leaf is a dict ({vr,vc} or {v});
    lax.map over the leading axis maps each field's leading dim too."""

    def wrapped(p, g, f):
        if p.ndim >= 3 and p.shape[0] >= 4 and p.size >= _CHUNKED_LEAF_ELEMS:
            return jax.lax.map(lambda xs: upd(*xs), (p, g, f))
        return upd(p, g, f)

    return wrapped


def _leafwise(upd):
    """Apply a per-leaf update function, scanning over the leading (layer-
    stack) axis for huge leaves so the fp32 temporaries (g32, vhat, u,
    p32) are bounded per-layer instead of materialized for the whole
    (L, E, D, F) stack — a 1T-param MoE would otherwise hold several
    multi-GiB fp32 copies of each expert leaf at once."""

    def wrapped(p, *rest):
        if p.ndim >= 3 and p.shape[0] >= 4 and p.size >= _CHUNKED_LEAF_ELEMS:
            return jax.lax.map(lambda xs: upd(*xs), (p, *rest))
        return upd(p, *rest)

    return wrapped


def apply_updates(cfg: OptimizerConfig, params, grads, state, step: jax.Array):
    lr = learning_rate(cfg, step)
    count = step.astype(jnp.float32) + 1.0

    if cfg.name == "sgd":
        def upd(p, g, m):
            m = 0.9 * m + g.astype(jnp.float32)
            return (p - lr * m).astype(p.dtype), m
        out = jax.tree.map(upd, params, grads, state["momentum"])
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"momentum": new_m}

    if cfg.name == "adamw":
        bc1 = 1.0 - cfg.b1 ** count
        bc2 = 1.0 - cfg.b2 ** count

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m = cfg.b1 * m + (1 - cfg.b1) * g32
            v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
            u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

        out = jax.tree.map(_leafwise(upd), params, grads, state["m"], state["v"])
        isl = lambda x: isinstance(x, tuple)
        return (
            jax.tree.map(lambda o: o[0], out, is_leaf=isl),
            {
                "m": jax.tree.map(lambda o: o[1], out, is_leaf=isl),
                "v": jax.tree.map(lambda o: o[2], out, is_leaf=isl),
            },
        )

    if cfg.name == "adafactor":
        decay = 1.0 - count ** (-cfg.decay_rate)

        def upd(p, g, f):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + 1e-30
            if "vr" in f:
                vr = decay * f["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
                vc = decay * f["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
                vhat = vr[..., None] * vc[..., None, :] / denom[..., None]
                newf = {"vr": vr, "vc": vc}
            else:
                vhat = decay * f["v"] + (1 - decay) * g2
                newf = {"v": vhat}
            u = g32 / jnp.sqrt(vhat + 1e-30)
            # update clipping (Shazeer & Stern): RMS(u) capped at 1
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms)
            u = u + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), newf

        out = jax.tree.map(_leafwise_factored(upd), params, grads, state["f"])
        # out mirrors params' structure with (p, f) tuples at leaves
        isl = lambda x: isinstance(x, tuple)
        return (
            jax.tree.map(lambda o: o[0], out, is_leaf=isl),
            {"f": jax.tree.map(lambda o: o[1], out, is_leaf=isl)},
        )

    raise ValueError(cfg.name)
