"""Training substrate: optimizers, stepping, checkpointing, fault tolerance."""
