"""Multi-tenant request queue with admission control (repro.serve).

The queue is the front door of the continuous serving scheduler
(``repro.serve.scheduler``): callers ``submit`` :class:`Request` objects
(tenant, program, source, deadline) and the scheduler pulls work through
:meth:`RequestQueue.admit` whenever lane slots free up.  Admission
enforces three policies, in this order:

* **per-tenant quotas** — a tenant never holds more than
  ``quota[tenant]`` in-flight lanes at once, whatever it submitted;
  excess requests stay queued (deferred, not dropped) until one of the
  tenant's lanes converges;
* **device-resident state budget** — each admitted request pins
  ``bytes_per_lane`` of device state (its (values, Δ, frontier) lane
  rows); admission stops as soon as the next admit would exceed the free
  byte budget the scheduler computed from
  ``TierPolicy.device_budget_bytes`` (a request that could *never* fit —
  ``bytes_per_lane`` above the whole budget — is rejected outright
  instead of deferred forever);
* **deadline-aware priority ordering** — among the requests eligible
  under the two constraints above, admission is strictly
  earliest-deadline-first (ties broken by arrival order), so an urgent
  query overtakes a backlog of lax ones.

Deferral is the default failure mode: a request that cannot be admitted
*now* (quota or budget) stays in the queue, keeps its deadline priority,
and is retried at the next chunk boundary.  ``stats`` counts admitted /
deferred / rejected outcomes; ``quota_violations`` stays 0 by
construction and is asserted by the serve_bench ``--selfcheck`` gate.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable

from repro.graph.algorithms import VertexProgram

_SEQ = itertools.count()


@dataclass
class Request:
    """One serving request: run ``program`` from ``source`` for
    ``tenant``, wanted by ``deadline`` (any monotone priority scalar —
    the scheduler uses its virtual iteration clock; smaller = sooner;
    ``inf`` = best-effort).  ``arrival`` is a process-wide sequence
    number breaking deadline ties FIFO."""

    tenant: str
    program: VertexProgram
    source: int | None
    deadline: float = float("inf")
    arrival: int = field(default_factory=lambda: next(_SEQ))
    # filled in by the serving loop
    submit_vt: float = 0.0     # virtual time (engine iterations) at submit
    submit_wall: float = 0.0   # wall clock at submit


@dataclass
class QueueStats:
    submitted: int = 0
    admitted: int = 0
    deferred: int = 0          # admit() passes that left the request queued
    rejected: int = 0          # could never fit the device budget
    shed: int = 0              # withdrawn under sustained pressure
    quota_violations: int = 0  # stays 0 by construction (selfcheck gate)


class RequestQueue:
    """Pending-request pool with quota/budget/deadline admission.

    ``quota`` is the default per-tenant in-flight lane cap;
    ``tenant_quotas`` overrides it per tenant.  ``None`` means unlimited
    (the degenerate single-tenant mode ``GraphService._query_fresh``
    uses)."""

    def __init__(self, quota: int | None = None,
                 tenant_quotas: dict[str, int] | None = None):
        self.quota = quota
        self.tenant_quotas = dict(tenant_quotas or {})
        self._pending: list[Request] = []
        self.stats = QueueStats()

    def __len__(self) -> int:
        return len(self._pending)

    def __bool__(self) -> bool:
        return bool(self._pending)

    def quota_for(self, tenant: str) -> int | None:
        return self.tenant_quotas.get(tenant, self.quota)

    def submit(self, req: Request) -> None:
        self._pending.append(req)
        self.stats.submitted += 1

    def pending(self) -> list[Request]:
        """Snapshot of the queued requests (shedding candidates)."""
        return list(self._pending)

    def withdraw(self, req: Request) -> bool:
        """Remove a pending request without serving it (load shedding —
        the supervisor's last degradation rung).  Counted in
        ``stats.shed``; returns False if ``req`` was not pending."""
        try:
            self._pending.remove(req)
        except ValueError:
            return False
        self.stats.shed += 1
        return True

    def peek_program(self) -> VertexProgram | None:
        """Program of the deadline-first pending request (the scheduler
        forms program-homogeneous lane batches, so the head request picks
        which program the next batch runs)."""
        if not self._pending:
            return None
        head = min(self._pending, key=lambda r: (r.deadline, r.arrival))
        return head.program

    def admit(
        self,
        n_slots: int,
        in_flight: dict[str, int],
        program: VertexProgram | None = None,
        free_bytes: float | None = None,
        bytes_per_lane: float = 0.0,
        total_budget: float | None = None,
        on_reject: Callable[[Request], None] | None = None,
    ) -> list[Request]:
        """Admit up to ``n_slots`` pending requests into lane slots.

        Selection is earliest-deadline-first (ties FIFO by ``arrival``)
        over the pending set, restricted to ``program`` when given (lane
        batches are program-homogeneous — one vmapped sweep traces one
        program).  A candidate is **deferred** (left queued, retried at
        the next chunk boundary) when its tenant is at quota — counting
        both lanes already in flight (``in_flight``) and lanes admitted
        earlier in this same call — or when admitting it would push the
        pinned lane state past the free device byte budget
        (``free_bytes`` / ``bytes_per_lane``, as computed by the
        scheduler from ``TierPolicy.device_budget_bytes`` after warm-
        cache spilling).  It is **rejected** (removed, ``on_reject``
        called) only when it could *never* run: ``bytes_per_lane``
        exceeds ``total_budget``, or its tenant's quota is zero —
        deferral would just spin forever.

        Equivalence guarantee: admission decides *when* a request's lane
        starts, never what it computes — an admitted request's lane is
        seeded exactly as its standalone run (``program.init_state`` or
        the warm-cache replay state) and ``jax.vmap`` keeps lanes
        independent, so deferral/reordering cannot change any result;
        only latency moves.  Invariants enforced here (and property-
        tested in ``tests/test_serve.py``): no tenant ever exceeds its
        quota, admitted sets are deadline-ordered among eligible
        requests, and the pinned byte total never exceeds the budget.
        """
        admitted: list[Request] = []
        counts = dict(in_flight)
        budget_left = free_bytes
        eligible = [r for r in self._pending
                    if program is None or r.program == program]
        eligible.sort(key=lambda r: (r.deadline, r.arrival))
        # reject sweep first (even with n_slots=0): a request that can
        # never run must not sit deferred forever
        never_fits = (total_budget is not None
                      and bytes_per_lane > total_budget)
        doomed = [r for r in eligible
                  if never_fits
                  or (self.quota_for(r.tenant) is not None
                      and self.quota_for(r.tenant) <= 0)]
        for req in doomed:
            self._pending.remove(req)
            eligible.remove(req)
            self.stats.rejected += 1
            if on_reject is not None:
                on_reject(req)
        deferred_this_pass = 0
        for req in eligible:
            if len(admitted) >= n_slots:
                break
            quota = self.quota_for(req.tenant)
            if quota is not None and counts.get(req.tenant, 0) >= quota:
                deferred_this_pass += 1
                continue
            if budget_left is not None and bytes_per_lane > budget_left:
                deferred_this_pass += 1
                continue
            self._pending.remove(req)
            admitted.append(req)
            counts[req.tenant] = counts.get(req.tenant, 0) + 1
            if budget_left is not None:
                budget_left -= bytes_per_lane
        self.stats.admitted += len(admitted)
        self.stats.deferred += deferred_this_pass
        return admitted
