"""Tiered warm-state cache for the serving stack (repro.serve).

Converged (values, Δ) states are the serving stack's working set: a
repeat query at the same graph version is a pure hit, and a stale state
warm-starts incremental recomputation (repro.stream.incremental) instead
of a from-scratch sweep.  At multi-tenant scale the states no longer all
fit on the accelerator, so the cache is **two-tiered**, following
Totem's hybrid host/device state placement (PAPERS.md — demote cold
state to host memory instead of dropping it):

* **device tier** — entries held as device arrays (``jax.Array``),
  immediately usable as warm-start seeds with no transfer.  Bounded by
  ``TierPolicy.device_budget_bytes`` (LRU): inserting or touching past
  the budget *spills* the least-recently-used device entries to...
* **host tier** — the same states demoted to host RAM (``np.ndarray``).
  A query that hits a host entry *promotes* it back to the device tier
  (:meth:`WarmCache.promote`) and, if the graph has moved on since the
  entry's version, replays the retained update reports through the
  incremental path — the tier policy that generalizes the old
  ``GraphService.max_reports`` flat bound;
* entries **too stale to replay** the retained report suffix are evicted
  outright from either tier (their next query recomputes in full), so an
  abandoned entry can never grow the report log without limit.

Per-tier hit/miss/spill/promotion counters live in :class:`CacheStats`
and surface through ``GraphService.stats.extra`` and the serve_bench
report.
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

DEVICE, HOST = "device", "host"


def state_checksum(values, delta) -> int:
    """crc32 over the (values, Δ) byte images — computed at spill time,
    re-verified at promote time, so a host-tier entry corrupted in RAM
    (or by an injected ``host_spill`` fault) is detected instead of
    served as a warm-start seed."""
    crc = zlib.crc32(np.ascontiguousarray(values).tobytes())
    return zlib.crc32(np.ascontiguousarray(delta).tobytes(), crc)


@dataclass(frozen=True)
class OwnerPlacement:
    """Owner-sharded device-tier placement (``HyTMConfig.vertex_sharding
    == "owner"`` with a mesh): device-tier entries are padded to
    ``n_pad = ceil(n/D)*D`` and owner-sharded over the mesh axis, so one
    cached state costs each device only its ``(n_loc,)`` slice — the
    owned-slice granularity the byte budget accounts at.  Host-tier
    entries stay canonical ``(n,)`` numpy arrays (``to_host`` slices the
    pads off), so the spill -> promote round trip remains bit-exact and
    checksums are taken over the canonical bytes."""

    mesh: object
    axis: str
    n_nodes: int

    @property
    def n_dev(self) -> int:
        return int(self.mesh.shape[self.axis])

    @property
    def n_pad(self) -> int:
        return -(-self.n_nodes // self.n_dev) * self.n_dev

    def to_device(self, arr):
        from jax.sharding import NamedSharding, PartitionSpec

        arr = jnp.asarray(arr)
        extra = self.n_pad - arr.shape[0]
        if extra > 0:
            arr = jnp.concatenate([arr, jnp.zeros(extra, arr.dtype)])
        return jax.device_put(
            arr, NamedSharding(self.mesh, PartitionSpec(self.axis)))

    def to_host(self, arr) -> np.ndarray:
        return np.asarray(arr)[:self.n_nodes]

    def device_nbytes(self, values, delta) -> int:
        # .nbytes of a sharded jax.Array is the GLOBAL footprint; the
        # budget bounds what ONE device holds, so charge the per-device
        # share
        return (int(values.nbytes) + int(delta.nbytes)) // self.n_dev


@dataclass(frozen=True)
class TierPolicy:
    """The explicit tier policy (generalizing ``GraphService.max_reports``):

    * ``device_budget_bytes`` — LRU byte budget of the device tier
      (``None`` = unbounded: nothing ever spills, the pre-serve
      single-tier behavior);
    * ``max_reports`` — replay horizon: how many update reports are
      retained for promote-time replay; entries older than the retained
      suffix are evicted from *both* tiers rather than kept unreplayable.
    """

    device_budget_bytes: int | None = None
    max_reports: int = 256


@dataclass
class CacheStats:
    device_hits: int = 0
    host_hits: int = 0
    misses: int = 0
    spills: int = 0        # device -> host demotions
    promotions: int = 0    # host -> device
    evictions: int = 0     # dropped from both tiers (unreplayable / dead)
    corrupt: int = 0       # host entries failing checksum on promote
    promote_failures: int = 0  # promotes refused (corrupt or device OOM)

    def as_dict(self) -> dict:
        return {
            "device_hits": self.device_hits, "host_hits": self.host_hits,
            "misses": self.misses, "spills": self.spills,
            "promotions": self.promotions, "evictions": self.evictions,
            "corrupt": self.corrupt,
            "promote_failures": self.promote_failures,
        }


@dataclass
class WarmEntry:
    version: int
    values: object          # jax.Array (device tier) | np.ndarray (host)
    delta: object
    tier: str = DEVICE
    nbytes: int = 0
    lru: int = 0
    checksum: int | None = None  # set at spill, verified at promote
    n_valid: int = 0        # >0: device arrays are owner-padded; real length

    def host_values(self) -> np.ndarray:
        """Canonical ``(n,)`` host view (owner-mode pads sliced off)."""
        arr = np.asarray(self.values)
        return arr[:self.n_valid] if self.n_valid else arr

    def host_delta(self) -> np.ndarray:
        arr = np.asarray(self.delta)
        return arr[:self.n_valid] if self.n_valid else arr


class WarmCache:
    """Two-tier LRU warm-state cache.  Dict-like over ``(program, source)``
    keys so ``GraphService`` bookkeeping (floor computation, staleness
    eviction) reads it exactly like the flat dict it replaces."""

    def __init__(self, policy: TierPolicy | None = None, obs=None,
                 faults=None, placement: OwnerPlacement | None = None):
        self.policy = policy or TierPolicy()
        # optional OwnerPlacement: device-tier entries are owner-sharded
        # over the mesh and the budget accounts per-device owned-slice
        # bytes; placement=None keeps single-device replicated arrays
        self.placement = placement
        self._entries: dict = {}
        self._clock = 0
        self.stats = CacheStats()
        # optional repro.obs.TraceRecorder: tier transitions (spill /
        # promote / evict) and per-tier hits emit events + counters on the
        # "cache" track; obs=None records nothing
        self.obs = obs
        # optional repro.resilience.FaultPlan: injects host_spill
        # corruption and cache_promote OOM; faults=None is zero-overhead
        self.faults = faults

    def _obs_event(self, name: str, key=None, **args) -> None:
        if self.obs is None:
            return
        self.obs.metrics.counter(f"cache.{name}", "warm-cache tier events").inc(
            1, **({"tier": args["tier"]} if "tier" in args else {}))
        if key is not None:
            args["key"] = repr(key)
        self.obs.instant(name, cat="cache", track="cache",
                         vt=float(self._clock), **args)

    # ------------------------------------------------------------- dict-like
    def __contains__(self, key) -> bool:
        return key in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def __delitem__(self, key) -> None:
        self.evict(key)

    def keys(self):
        return self._entries.keys()

    def values(self):
        return self._entries.values()

    def items(self):
        return self._entries.items()

    def __iter__(self) -> Iterator:
        return iter(self._entries)

    # ------------------------------------------------------------------ core
    @property
    def device_bytes(self) -> int:
        return sum(e.nbytes for e in self._entries.values()
                   if e.tier == DEVICE)

    def _touch(self, entry: WarmEntry) -> None:
        self._clock += 1
        entry.lru = self._clock

    def peek(self, key) -> WarmEntry | None:
        """Counter-free lookup (still bumps LRU): the ``GraphService``
        query front end peeks, so a request that then flows into the
        scheduler is counted exactly once by the scheduler's
        :meth:`get`."""
        entry = self._entries.get(key)
        if entry is not None:
            self._touch(entry)
        return entry

    def check(self, key) -> WarmEntry | None:
        """:meth:`peek` plus integrity verification: a host-tier entry
        whose bytes no longer match its spill-time checksum is counted
        (``stats.corrupt``), evicted, and ``None`` returned — the caller
        recomputes instead of serving damaged state.  The query front
        end uses this for version-current hits, which are served
        straight from the entry without going through :meth:`promote`."""
        entry = self.peek(key)
        if entry is None:
            return None
        if (entry.tier == HOST and entry.checksum is not None
                and state_checksum(entry.values, entry.delta)
                != entry.checksum):
            self.stats.corrupt += 1
            self._obs_event("corrupt", key, nbytes=entry.nbytes)
            self.evict(key)
            return None
        return entry

    def get(self, key) -> WarmEntry | None:
        """Look up without tier movement (no promotion): returns the
        entry whatever its tier, bumping LRU and per-tier hit/miss
        counters.  Callers that need the state device-resident follow up
        with :meth:`promote`."""
        entry = self._entries.get(key)
        if entry is None:
            self.stats.misses += 1
            self._obs_event("miss", key)
            return None
        self._touch(entry)
        if entry.tier == DEVICE:
            self.stats.device_hits += 1
        else:
            self.stats.host_hits += 1
        self._obs_event("hit", key, tier=entry.tier)
        return entry

    def put(self, key, version: int, values, delta,
            reserved_bytes: int = 0) -> WarmEntry:
        """Insert/refresh ``key`` in the *device* tier, then spill LRU
        entries to host until the tier fits the budget minus
        ``reserved_bytes`` (bytes the scheduler has pinned for in-flight
        lane state — warm states yield to live lanes)."""
        n_valid = 0
        if self.placement is not None:
            values = self.placement.to_device(values)
            delta = self.placement.to_device(delta)
            nbytes = self.placement.device_nbytes(values, delta)
            n_valid = self.placement.n_nodes
        else:
            values = jnp.asarray(values)
            delta = jnp.asarray(delta)
            nbytes = int(values.nbytes) + int(delta.nbytes)
        entry = WarmEntry(version=version, values=values, delta=delta,
                          tier=DEVICE, nbytes=nbytes, n_valid=n_valid)
        self._touch(entry)
        self._entries[key] = entry
        self.shrink_to_budget(reserved_bytes)
        return entry

    def promote(self, key, reserved_bytes: int = 0) -> WarmEntry | None:
        """Promote ``key``'s state back to the device tier (host -> device
        ``jax.device_put``), spilling colder entries if the budget
        requires.

        Equivalence guarantee: the spill -> promote round trip is exact —
        ``device_get``/``device_put`` preserve every f32 bit, so the
        promoted (values, Δ) triple is bit-identical to the state that
        was demoted.  A stale promoted entry then replays the update
        reports retained since its version through the incremental path
        (``GraphService._query_incremental``), which is the *same*
        replay the never-evicted device-tier entry would run — hence
        spill -> promote -> replay is bit-identical to never-evicted for
        MIN programs and tolerance-bounded for SUM programs
        (property-tested in ``tests/test_serve.py``).

        Integrity: a host entry whose bytes no longer match the checksum
        taken at spill time is *corrupt* — it is counted
        (``stats.corrupt``), evicted, and ``None`` is returned so the
        caller falls through to a full recompute instead of warm-starting
        from garbage.  An injected ``cache_promote`` OOM likewise returns
        ``None`` (entry stays in the host tier, recompute path taken).
        """
        entry = self._entries.get(key)
        if entry is None:
            return None
        if entry.tier == HOST:
            if entry.checksum is not None and state_checksum(
                    entry.values, entry.delta) != entry.checksum:
                self.stats.corrupt += 1
                self.stats.promote_failures += 1
                self._obs_event("corrupt", key, nbytes=entry.nbytes)
                self.evict(key)
                return None
            if self.faults is not None and self.faults.fire(
                    "cache_promote") == "oom":
                self.stats.promote_failures += 1
                self._obs_event("promote_oom", key, nbytes=entry.nbytes)
                return None
            if self.placement is not None:
                entry.values = self.placement.to_device(entry.values)
                entry.delta = self.placement.to_device(entry.delta)
                entry.nbytes = self.placement.device_nbytes(
                    entry.values, entry.delta)
                entry.n_valid = self.placement.n_nodes
            else:
                entry.values = jax.device_put(jnp.asarray(entry.values))
                entry.delta = jax.device_put(jnp.asarray(entry.delta))
            entry.tier = DEVICE
            entry.checksum = None
            self.stats.promotions += 1
            self._obs_event("promote", key, nbytes=entry.nbytes)
            self._touch(entry)
            self.shrink_to_budget(reserved_bytes, keep=key)
        return entry

    def _spill(self, key) -> None:
        entry = self._entries[key]
        # host tier is always canonical (n,) numpy — owner-mode pads are
        # sliced off so checksums cover exactly the state bytes
        entry.values = entry.host_values()
        entry.delta = entry.host_delta()
        entry.n_valid = 0
        entry.tier = HOST
        entry.nbytes = int(entry.values.nbytes) + int(entry.delta.nbytes)
        entry.checksum = state_checksum(entry.values, entry.delta)
        if self.faults is not None and self.faults.fire(
                "host_spill") == "corrupt":
            # the spilled bytes land damaged; the checksum (taken from
            # the intact state) will catch this at promote time
            entry.values = self.faults.corrupt(entry.values)
        self.stats.spills += 1
        self._obs_event("spill", key, nbytes=entry.nbytes)

    def shrink_to_budget(self, reserved_bytes: int = 0,
                         keep=None) -> None:
        """Spill LRU device entries to host until
        ``device_bytes <= device_budget_bytes - reserved_bytes``.  The
        scheduler calls this before pinning lane state for a new batch,
        so admission never drives the device-resident total (lanes +
        warm tier) past the budget.  ``keep`` marks one key exempt (the
        entry just promoted — spilling it back immediately would
        livelock)."""
        budget = self.policy.device_budget_bytes
        if budget is None:
            return
        limit = max(0, budget - reserved_bytes)
        if self.device_bytes <= limit:
            return
        device_keys = sorted(
            (k for k, e in self._entries.items() if e.tier == DEVICE),
            key=lambda k: self._entries[k].lru,
        )
        for k in device_keys:
            if self.device_bytes <= limit:
                break
            if k == keep:
                continue
            self._spill(k)

    def evict(self, key) -> None:
        del self._entries[key]
        self.stats.evictions += 1
        self._obs_event("evict", key)

    def clear(self) -> None:
        self.stats.evictions += len(self._entries)
        self._entries.clear()
