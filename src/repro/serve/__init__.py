"""repro.serve — continuous multi-tenant serving on top of GraphService.

The serving stack splits into three pieces (one file each):

* :mod:`repro.serve.queue` — request queue with per-tenant quotas,
  deadline-aware ordering, and admission control against the
  device-resident state budget;
* :mod:`repro.serve.scheduler` — continuous lane batching over static
  bucket sizes, freeing converged lanes at chunk boundaries and
  backfilling them mid-flight;
* :mod:`repro.serve.warm_cache` — two-tier (device LRU → host RAM)
  warm-state cache with promote-and-replay.

``GraphService`` owns one :class:`LaneScheduler` and one
:class:`WarmCache`; ``benchmarks/serve_bench.py`` drives the scheduler
closed-loop with a multi-tenant trace.
"""

from repro.serve.queue import QueueStats, Request, RequestQueue
from repro.serve.scheduler import (
    LaneScheduler,
    SchedulerStats,
    ServedResult,
    default_buckets,
)
from repro.serve.warm_cache import CacheStats, TierPolicy, WarmCache, WarmEntry

__all__ = [
    "QueueStats",
    "Request",
    "RequestQueue",
    "LaneScheduler",
    "SchedulerStats",
    "ServedResult",
    "default_buckets",
    "CacheStats",
    "TierPolicy",
    "WarmCache",
    "WarmEntry",
]
