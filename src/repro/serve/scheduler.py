"""Continuous lane-batching scheduler (repro.serve).

HyTGraph picks the cheapest transfer strategy *per iteration* as the
active set evolves; at serving scale the same decision moves up a level
— which queries share a dispatch, and when.  The scheduler keeps the
device busy with whatever work is ready (Gunrock's frontier-centric
batching shape, PAPERS.md) instead of blocking on fixed ``max_lanes``
batches:

* **static lane buckets** — lane counts come from a small static set
  (default ``{1, 2, 4, ..., max_lanes}``), and a partial batch is padded
  up to its bucket with *dead lanes* (``core.hytm.dead_lane_state``:
  empty frontier, zero Δ — no-ops by construction).  Admission therefore
  never changes a traced lane count: the whole serving lifetime compiles
  at most one ``hytm_batched_chunk`` per (bucket, program), however the
  request sizes arrive;
* **continuous backfill** — each chunk dispatch returns the **per-lane**
  ``next_active`` vector (``core.hytm.hytm_batched_chunk``'s carry), so
  a lane that converges frees its slot at the chunk boundary and the
  scheduler immediately backfills it from the queue *mid-flight*, while
  straggler lanes keep relaxing.  The bucket (and hence the compiled
  sweep) never changes while a batch is in flight;
* **admission control** — slots are filled through
  ``RequestQueue.admit`` (per-tenant quotas, deadline-first ordering,
  device byte budget); the warm cache spills to host RAM before a batch
  pins its lane state, so device-resident bytes (in-flight lanes + warm
  tier) never exceed ``TierPolicy.device_budget_bytes``;
* **warm lanes** — a request whose key has a warm (stale) cache entry is
  admitted as an *incremental* lane: its init state is the
  ``incremental_state`` replay seed (promoting the entry from host tier
  first if it was spilled), so warm recomputes ride the same vmapped
  chunk as cold sweeps.

Equivalence: lanes are ``jax.vmap`` elements — they never interact — so
every lane's trajectory is bit-identical to its standalone
``run_hytm`` / ``run_incremental`` execution for MIN programs and
tolerance-bounded for SUM, regardless of bucket padding, backfill
timing, or what other tenants are doing (tests/test_serve.py).  The
scheduler moves *latency* only.

Latency is tracked on two clocks: wall time and a deterministic
**virtual clock** (cumulative engine iterations executed), which is what
the serve_bench ``--selfcheck`` latency gate compares — virtual latency
is reproducible run-to-run, wall latency is not.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import KEY_ICI_BYTES, KEY_ICI_TIME
from repro.core.hytm import (
    HyTMState,
    _consume_warm,
    dead_lane_state,
    hytm_batched_chunk,
    quiet_donation,
)
from repro.graph.algorithms import VertexProgram
from repro.serve.queue import Request, RequestQueue

if TYPE_CHECKING:  # pragma: no cover - type hints only
    from repro.stream.service import GraphService


def default_buckets(max_lanes: int) -> tuple[int, ...]:
    """The static lane-count buckets: powers of two up to ``max_lanes``,
    plus ``max_lanes`` itself — small enough that the whole set stays
    compiled, spaced so padding waste is bounded by 2x."""
    if max_lanes < 1:
        raise ValueError(f"max_lanes must be >= 1, got {max_lanes}")
    buckets = []
    b = 1
    while b < max_lanes:
        buckets.append(b)
        b *= 2
    buckets.append(max_lanes)
    return tuple(buckets)


# state bytes one lane pins on device: values f32 + delta f32 + frontier
# bool, each (n,)
LANE_STATE_BYTES_PER_NODE = 4 + 4 + 1


@dataclass
class ServedResult:
    request: Request
    values: np.ndarray | None
    delta: np.ndarray | None
    iterations: int            # engine iterations this request's lane ran
    mode: str   # 'cache' | 'incremental' | 'batched' | 'rejected' | 'shed'
    submit_vt: float = 0.0
    done_vt: float = 0.0
    submit_wall: float = 0.0
    done_wall: float = 0.0

    @property
    def vt_latency(self) -> float:
        """Deterministic latency: engine iterations between submit and
        completion (queue wait + stragglers included)."""
        return self.done_vt - self.submit_vt

    @property
    def wall_latency(self) -> float:
        return self.done_wall - self.submit_wall


@dataclass
class SchedulerStats:
    chunks: int = 0
    engine_iterations: int = 0   # the virtual clock
    lane_iterations: int = 0     # live-lane iterations (occupancy numerator)
    slot_iterations: int = 0     # bucket-width iterations (denominator)
    backfills: int = 0
    batches: int = 0
    max_device_bytes: int = 0    # peak in-flight lanes + device-tier cache

    @property
    def occupancy(self) -> float:
        """Fraction of dispatched lane-slots that carried live work."""
        return self.lane_iterations / max(self.slot_iterations, 1)


@dataclass
class _LaneJob:
    request: Request
    mode: str                  # 'batched' | 'incremental'
    init: tuple                # (values, delta, frontier)
    iters: int = 0


class LaneScheduler:
    """Continuous scheduler over one :class:`GraphService`'s container.

    ``GraphService._query_fresh`` drives it in degenerate single-tenant
    mode (no deadlines, no quotas); ``benchmarks/serve_bench.py`` drives
    it closed-loop with a multi-tenant trace through :meth:`pump`.
    """

    def __init__(self, service: "GraphService",
                 buckets: tuple[int, ...] | None = None,
                 backfill: bool = True, supervisor=None):
        self.svc = service
        # optional repro.resilience.Supervisor: retry policy for lane
        # dispatches, OOM-streak tracking, and tiered load shedding.
        # None (the default) disables all of it with zero overhead.
        self.supervisor = supervisor
        # backfill=False degrades to the fixed-batch baseline: a batch
        # runs to full convergence before the queue is consulted again
        # (serve_bench's comparison point — answers are identical either
        # way, only latency moves)
        self.backfill = backfill
        self.buckets = tuple(sorted(set(
            buckets if buckets is not None
            else default_buckets(service.max_lanes))))
        if self.buckets[0] < 1:
            raise ValueError(f"lane buckets must be >= 1: {self.buckets}")
        self.stats = SchedulerStats()
        self.in_flight: dict[str, int] = {}   # tenant -> live lanes
        # device bytes pinned by the in-flight batch's lane state — the
        # warm cache reserves around this when entries are stored
        # mid-flight (GraphService._store)
        self.pinned_bytes = 0

    # ------------------------------------------------------------- geometry
    @property
    def vt(self) -> int:
        return self.stats.engine_iterations

    @property
    def lane_bytes(self) -> int:
        """Device bytes one lane pins.  Owner-sharded serving stores each
        lane split across the mesh, so the budgeted (per-device) cost is
        the owned ``(n_loc,)`` slice, not the full ``(n,)`` row — the same
        owned-slice granularity the warm cache accounts at."""
        n = self.svc.dcsr.n_nodes
        if self._owner_mode():
            n_dev = int(self.svc.mesh.shape[self.svc.config.mesh_axis])
            n = -(-n // n_dev)
        return LANE_STATE_BYTES_PER_NODE * n

    def _owner_mode(self) -> bool:
        svc = self.svc
        return (svc.mesh is not None
                and svc.config.vertex_sharding == "owner")

    def bucket_for(self, q: int) -> int:
        for b in self.buckets:
            if b >= q:
                return b
        return self.buckets[-1]

    def _budget_bucket_cap(self) -> int | None:
        """Largest admissible lane count under the device byte budget
        (the warm cache can spill to zero; in-flight lane state cannot)."""
        budget = self.svc.cache.policy.device_budget_bytes
        if budget is None:
            return None
        fit = [b for b in self.buckets if b * self.lane_bytes <= budget]
        return max(fit) if fit else 0

    # ------------------------------------------------------------ admission
    def _resolve_or_job(self, req: Request) -> ServedResult | _LaneJob:
        """Turn an admitted request into a finished result (exact-version
        cache hit) or a lane job seeded fresh / from the warm cache."""
        svc = self.svc
        key = (req.program, svc.key_source(req.program, req.source))
        entry = svc.cache.get(key)
        if entry is not None and entry.version == svc.version:
            svc.stats.n_cache_hits += 1
            return self._finish(req, entry.host_values(),
                                entry.host_delta(), 0, "cache")
        if entry is not None and svc.incremental:
            # import here: repro.stream imports repro.serve (service owns
            # a LaneScheduler), so the reverse edge must stay lazy
            from repro.stream.incremental import incremental_state

            # warm lane: promote the state to the device tier if it was
            # spilled (bit-exact round trip — warm_cache.promote), then
            # seed the replay-from-reports state.  The lane then runs the
            # identical residual convergence a solo run_incremental would.
            # promote() returns None when the entry failed integrity
            # verification (corrupt spill — evicted) or an injected
            # device OOM refused the transfer: the degradation rung is
            # cache-promote -> full recompute, i.e. fall through to the
            # fresh-seed path below instead of serving garbage.
            entry = svc.cache.promote(key)
            if entry is not None:
                state = incremental_state(
                    req.program, entry.host_values(), entry.host_delta(),
                    svc._reports_since(entry.version), svc.dcsr, key[1],
                )
                svc.stats.n_incremental += 1
                return _LaneJob(req, "incremental",
                                (state.values, state.delta, state.frontier))
        values, delta, frontier = req.program.init_state(
            svc.dcsr.n_nodes, key[1])
        svc.stats.n_full += 1
        return _LaneJob(req, "batched", (values, delta, frontier))

    def _finish(self, req: Request, values, delta, iters: int,
                mode: str) -> ServedResult:
        done_wall = time.monotonic()
        res = ServedResult(
            request=req, values=values, delta=delta, iterations=iters,
            mode=mode, submit_vt=req.submit_vt, done_vt=self.vt,
            submit_wall=req.submit_wall, done_wall=done_wall,
        )
        obs = self.svc.obs
        if obs is not None:
            # one span per served request on its tenant's track: wall
            # coordinates are submit->done monotonic stamps, vt rides in
            # args (submit_vt -> the scheduler's virtual clock)
            wall0 = (obs.wall_at(req.submit_wall)
                     if req.submit_wall else obs.wall())
            obs.span(
                f"request:{mode}", cat="serve",
                track=f"tenant:{req.tenant}",
                wall=wall0,
                wall_dur=max(obs.wall_at(done_wall) - wall0, 0.0),
                vt=float(req.submit_vt),
                vt_dur=float(self.vt - req.submit_vt),
                iterations=iters, program=req.program.name,
                source=-1 if req.source is None else int(req.source),
            )
            obs.metrics.counter(
                "serve.requests", "served requests by mode/tenant").inc(
                1, mode=mode, tenant=req.tenant)
        return res

    def _admit_jobs(
        self, queue: RequestQueue, program: VertexProgram, n_slots: int,
        results: list[ServedResult],
    ) -> list[_LaneJob]:
        """Admit up to ``n_slots`` lane jobs for ``program``: requests
        resolved instantly by the cache do not consume a slot, so keep
        admitting until the slots are full or the queue has nothing
        admissible left.  Rejections (could never run) and instant cache
        resolutions land directly in ``results``."""
        budget = self.svc.cache.policy.device_budget_bytes
        obs = self.svc.obs
        qs = queue.stats
        before = (qs.admitted, qs.deferred, qs.rejected)
        jobs: list[_LaneJob] = []
        while True:
            admitted = queue.admit(
                n_slots - len(jobs), self.in_flight, program=program,
                free_bytes=budget, bytes_per_lane=self.lane_bytes,
                total_budget=budget,
                on_reject=lambda r: results.append(
                    self._finish(r, None, None, 0, "rejected")),
            )
            if not admitted:
                break
            for req in admitted:
                out = self._resolve_or_job(req)
                if isinstance(out, ServedResult):
                    results.append(out)
                else:
                    jobs.append(out)
                    self.in_flight[req.tenant] = (
                        self.in_flight.get(req.tenant, 0) + 1)
            if len(jobs) >= n_slots:
                break
        if obs is not None:
            m = obs.metrics
            for name, prev, cur in zip(
                    ("admitted", "deferred", "rejected"), before,
                    (qs.admitted, qs.deferred, qs.rejected)):
                if cur > prev:
                    m.counter(f"admission.{name}",
                              "queue admission outcomes").inc(cur - prev)
        return jobs

    # ------------------------------------------------------------- dispatch
    def _lane_pad(self, program: VertexProgram):
        """Owner-mode lane geometry: ``(n_pad, pad_values, pad_delta)``,
        or ``None`` when lanes run replicated (n,)."""
        if not self._owner_mode():
            return None
        from repro.dist.graph_shard import owner_state_pad_values

        rt = self.svc._runtime_for(program)
        pad_v, pad_d = owner_state_pad_values(program)
        return rt.n_pad, pad_v, pad_d

    @staticmethod
    def _pad_triple(triple, pad):
        """Pad one lane's (n,) init triple to (n_pad,) with the program's
        inert fills (graph_shard.owner_state_pad_values)."""
        n_pad, pad_v, pad_d = pad
        v, d, f = (jnp.asarray(t) for t in triple)
        extra = n_pad - v.shape[0]
        if extra > 0:
            v = jnp.concatenate([v, jnp.full((extra,), pad_v, v.dtype)])
            d = jnp.concatenate([d, jnp.full((extra,), pad_d, d.dtype)])
            f = jnp.concatenate([f, jnp.zeros((extra,), f.dtype)])
        return v, d, f

    def _stack_state(self, program: VertexProgram,
                     jobs: list[_LaneJob | None], bucket: int) -> HyTMState:
        n = self.svc.dcsr.n_nodes
        dead = dead_lane_state(program, n)
        triples = [j.init if j is not None else dead for j in jobs]
        triples += [dead] * (bucket - len(jobs))
        pad = self._lane_pad(program)
        if pad is not None:
            triples = [self._pad_triple(t, pad) for t in triples]
        state = HyTMState(
            values=jnp.stack([t[0] for t in triples]),
            delta=jnp.stack([t[1] for t in triples]),
            frontier=jnp.stack([t[2] for t in triples]),
        )
        if pad is not None:
            # (Q, n_pad) with the vertex dim owner-sharded: each device
            # holds every lane's owned slice — per-device lane state is
            # Q * n_loc, the granularity lane_bytes pins
            from jax.sharding import NamedSharding, PartitionSpec

            lane = NamedSharding(
                self.svc.mesh,
                PartitionSpec(None, self.svc.config.mesh_axis))
            state = HyTMState(
                values=jax.device_put(state.values, lane),
                delta=jax.device_put(state.delta, lane),
                frontier=jax.device_put(state.frontier, lane),
            )
        return state

    def _dispatch(self, program: VertexProgram, state: HyTMState,
                  bucket: int, correction):
        """One chunk dispatch over the bucketed lane batch; returns
        ``(state, n_done, lane_active, correction)`` with the calibrator
        fed exactly as the pre-serve lane sweep fed it."""
        svc = self.svc
        cfg = svc.config
        chunk = max(cfg.sync_every, 1)
        if svc.mesh is not None:
            return self._dispatch_sharded(program, state, bucket,
                                          correction, chunk)
        rt = svc.dcsr.runtime_for(program)
        warm = _consume_warm((
            "serve-lanes", program, cfg, rt.n_hub_partitions,
            bucket, svc.dcsr.n_nodes, rt.csr.edge_src.shape[0],
            rt.parts.n_partitions, rt.parts.block_size,
            chunk, correction is not None,
        ))
        t_chunk = time.monotonic()
        faults = svc.faults
        if faults is None:
            with quiet_donation():
                state, n_done, lane_active, pe_sum, mp_sum = (
                    hytm_batched_chunk(
                        state, rt.csr, rt.parts, rt.zc_req, rt.inv_deg,
                        program, cfg, rt.n_hub_partitions, chunk,
                        correction,
                    ))
        else:
            # faults fire BEFORE the dispatch (donated lane state from
            # the previous chunk intact), so retries are bit-identical
            from repro.resilience.supervisor import guarded_dispatch

            def _attempt(st=state, corr=correction):
                with quiet_donation():
                    return hytm_batched_chunk(
                        st, rt.csr, rt.parts, rt.zc_req, rt.inv_deg,
                        program, cfg, rt.n_hub_partitions, chunk, corr,
                    )

            sup = self.supervisor
            state, n_done, lane_active, pe_sum, mp_sum = guarded_dispatch(
                _attempt, site="lane_dispatch", faults=faults,
                policy=sup.policy if sup is not None else None,
                obs=svc.obs,
                stats=sup.counters if sup is not None else None,
                bucket=bucket,
            )
        correction = self._observe(pe_sum, mp_sum, t_chunk, warm, correction)
        return state, int(n_done), np.asarray(lane_active), correction

    def _dispatch_sharded(self, program: VertexProgram, state: HyTMState,
                          bucket: int, correction, chunk: int):
        from repro.dist.graph_shard import (
            halo_level_cost,
            ici_level_cost,
            make_sharded_batched_chunk,
        )

        svc = self.svc
        rt = svc._runtime_for(program)
        n_dev = int(svc.mesh.shape[svc.config.mesh_axis])
        key = ("lanes", program, svc.config, chunk, bucket)
        cached = rt.iteration_cache.get(key)
        if cached is None:
            cached = {"fn": make_sharded_batched_chunk(
                rt, program, svc.config, chunk), "seen": set()}
            rt.iteration_cache[key] = cached
        warm = _consume_warm(
            (rt.blocks.src.shape, rt.parts.n_partitions,
             rt.parts.block_size, correction is not None),
            registry=cached["seen"],
        )
        t_chunk = time.monotonic()
        with quiet_donation():
            state, n_done, lane_active, pe_sum, mp_sum, merged = \
                cached["fn"](state, rt.blocks, rt.parts, rt.out_degree,
                             rt.zc_req, rt.inv_deg, correction)
        n_done = int(n_done)
        correction = self._observe(pe_sum, mp_sum, t_chunk, warm, correction)
        # second-level accounting: all lanes merge in one batched
        # collective per iteration (lane-summed entries, Q·(n,) dense)
        corr_np = (np.asarray(correction, dtype=float)
                   if correction is not None else None)
        obs = svc.obs
        base = self.stats.engine_iterations
        owner = rt.vertex_sharding == "owner" and rt.halo is not None
        for k, me in enumerate(np.asarray(merged)[:n_done]):
            halo_entries = None
            if owner:
                # each lane's compacted exchange is capped by the same
                # halo plan, so the batched collective caps at Q * halo
                halo_cap = float(bucket) * float(rt.halo.halo_total)
                halo_entries = min(float(me), halo_cap)
                ib, it_, ie = halo_level_cost(
                    bucket * svc.dcsr.n_nodes, float(me), halo_cap,
                    n_dev, svc.config.ici_link, corr_np,
                )
            else:
                ib, it_, ie = ici_level_cost(
                    bucket * svc.dcsr.n_nodes, float(me), n_dev,
                    svc.config.ici_link, corr_np,
                )
            svc.stats.extra[KEY_ICI_BYTES] = (
                svc.stats.extra.get(KEY_ICI_BYTES, 0.0) + ib)
            svc.stats.extra[KEY_ICI_TIME] = (
                svc.stats.extra.get(KEY_ICI_TIME, 0.0) + it_)
            if obs is not None:
                from repro.obs.record import record_ici

                record_ici(obs, track="ici", it=base + k, bytes_=ib,
                           seconds=it_, engine=ie,
                           merged_entries=float(me),
                           halo_entries=halo_entries)
        return state, n_done, np.asarray(lane_active), correction

    def _observe(self, pe_sum, mp_sum, t_chunk, warm, correction):
        svc = self.svc
        if svc._calibrator is None:
            return correction
        refreshed = svc._calibrator.observe_chunk(
            pe_sum, np.asarray(pe_sum, dtype=float), t_chunk, skip=not warm)
        svc._record_feedback(int(mp_sum), refreshed)
        return svc._correction

    def _alloc_pressure(self, queue: RequestQueue, slots: int,
                        results: list, floor: int) -> int:
        """Fire the ``lane_alloc`` fault site for one batch (or backfill)
        formation.  An injected OOM halves the slot count for this round
        — lanes are independent, so a narrower batch defers work without
        changing any lane's answer.  Sustained OOM streaks trip the
        supervisor's load-shed rung: pending requests of tenants below
        the top waiting tier are withdrawn and finished as mode
        ``"shed"``.  No-op (returns ``slots``) without a fault plan."""
        svc = self.svc
        if svc.faults is None:
            return slots
        from repro.resilience.supervisor import record_fault_event

        oom = svc.faults.fire("lane_alloc") == "oom"
        if oom:
            slots = max(slots // 2, floor)
            record_fault_event(svc.obs, "injected", site="lane_alloc",
                               kind="oom")
        sup = self.supervisor
        if sup is not None and sup.note_alloc_pressure(oom):
            for req in sup.shed_candidates(queue.pending()):
                if queue.withdraw(req):
                    sup.record_shed(req)
                    results.append(self._finish(req, None, None, 0, "shed"))
        return slots

    # ------------------------------------------------------------ main loop
    def pump(self, queue: RequestQueue) -> list[ServedResult]:
        """Drain ``queue``: form program-homogeneous bucketed lane
        batches, dispatch chunks, free converged lanes at chunk
        boundaries, and backfill freed slots from the queue mid-flight.
        Returns every request served this call (including instant cache
        resolutions and rejections), in completion order."""
        svc = self.svc
        obs = svc.obs
        results: list[ServedResult] = []
        sup = self.supervisor
        while queue:
            cap = self._budget_bucket_cap()
            max_slots = self.buckets[-1] if cap is None else cap
            max_slots = self._alloc_pressure(queue, max_slots, results,
                                             floor=1)
            if not queue:
                break  # everything pending was shed
            program = queue.peek_program()
            pending_before = len(queue)
            jobs = self._admit_jobs(queue, program, max(max_slots, 0),
                                    results)
            if not jobs:
                if len(queue) == pending_before:
                    # nothing admitted, resolved, or rejected — no lane
                    # in flight either, so no future chunk boundary can
                    # unblock the deferred remainder: stop, don't spin
                    break
                continue  # all resolved/rejected instantly; queue shrank
            bucket = self.bucket_for(len(jobs))
            # warm states yield the device to live lanes: spill the cache
            # until lanes + device tier fit the budget, then record peak
            self.pinned_bytes = bucket * self.lane_bytes
            svc.cache.shrink_to_budget(reserved_bytes=self.pinned_bytes)
            self.stats.max_device_bytes = max(
                self.stats.max_device_bytes,
                self.pinned_bytes + svc.cache.device_bytes)
            self.stats.batches += 1
            if obs is not None:
                obs.metrics.gauge(
                    "serve.device_bytes",
                    "in-flight lanes + device-tier cache bytes").set(
                    float(self.pinned_bytes + svc.cache.device_bytes))
                obs.counter("device_bytes",
                            self.pinned_bytes + svc.cache.device_bytes,
                            cat="serve", track="scheduler",
                            vt=float(self.vt))
            lane_jobs: list[_LaneJob | None] = list(jobs) + [None] * (
                bucket - len(jobs))
            state = self._stack_state(program, lane_jobs, bucket)
            correction = svc._correction
            if svc._calibrator is not None and correction is None:
                correction = jnp.ones(3, jnp.float32)

            while any(j is not None for j in lane_jobs):
                state, n_done, lane_active, correction = self._dispatch(
                    program, state, bucket, correction)
                live = sum(j is not None for j in lane_jobs)
                self.stats.chunks += 1
                self.stats.engine_iterations += n_done
                self.stats.lane_iterations += live * n_done
                self.stats.slot_iterations += bucket * n_done
                if obs is not None:
                    obs.metrics.gauge(
                        "serve.occupancy",
                        "live-lane fraction of dispatched slots").set(
                        self.stats.occupancy)
                    obs.counter("lane_occupancy", live / bucket,
                                cat="serve", track="scheduler",
                                vt=float(self.vt))
                for j in lane_jobs:
                    if j is not None:
                        j.iters += n_done
                # a lane is done when its frontier drained — or it hit
                # the iteration cap (max_iters enforced at chunk
                # granularity, same bound run_hytm's driver applies)
                done_idx = [
                    i for i, j in enumerate(lane_jobs)
                    if j is not None and (
                        lane_active[i] == 0
                        or j.iters >= svc.config.max_iters)
                ]
                if not done_idx:
                    continue
                # owner-mode lanes carry (n_pad,) rows — slice the ghost
                # pads off so stored/served results are canonical (n,)
                values = np.asarray(state.values)[:, :svc.dcsr.n_nodes]
                deltas = np.asarray(state.delta)[:, :svc.dcsr.n_nodes]
                freed = 0
                for i in done_idx:
                    job = lane_jobs[i]
                    key_src = svc.key_source(program, job.request.source)
                    svc._store(program, key_src, values[i], deltas[i])
                    svc.stats.sweep_iterations += job.iters
                    results.append(self._finish(
                        job.request, values[i], deltas[i], job.iters,
                        job.mode))
                    lane_jobs[i] = None
                    self.in_flight[job.request.tenant] -= 1
                    if self.in_flight[job.request.tenant] <= 0:
                        del self.in_flight[job.request.tenant]
                    freed += 1
                # backfill freed slots mid-flight: the bucket (and the
                # compiled chunk) never changes; new jobs drop into the
                # dead rows at the chunk boundary.  Backfill takes any
                # pending same-program request (admit() filters; the
                # freed slots cannot run anything else) — deadline order
                # applies within the program here, and across programs
                # at the next batch formation
                if self.backfill and queue:
                    # backfill is a batch formation too: the fault plane
                    # can refuse the refill allocation (floor 0 — the
                    # outer loop re-forms batches, so admitting nothing
                    # here cannot deadlock)
                    freed = self._alloc_pressure(queue, freed, results,
                                                 floor=0)
                if self.backfill and queue:
                    refill = self._admit_jobs(queue, program, freed, results)
                    slots = [i for i, j in enumerate(lane_jobs) if j is None]
                    pad = self._lane_pad(program) if refill else None
                    for slot, job in zip(slots, refill):
                        lane_jobs[slot] = job
                        v, d, f = (self._pad_triple(job.init, pad)
                                   if pad is not None else job.init)
                        state = HyTMState(
                            values=state.values.at[slot].set(v),
                            delta=state.delta.at[slot].set(d),
                            frontier=state.frontier.at[slot].set(f),
                        )
                        self.stats.backfills += 1
                        if obs is not None:
                            obs.metrics.counter(
                                "serve.backfills",
                                "mid-flight lane refills").inc(1)
                            obs.instant(
                                "backfill", cat="serve", track="scheduler",
                                vt=float(self.vt), slot=slot,
                                tenant=job.request.tenant, mode=job.mode)
            self.pinned_bytes = 0
        return results

    # ------------------------------------------------- service entry point
    def run_batch(self, program: VertexProgram,
                  sources) -> dict:
        """Degenerate single-tenant mode for ``GraphService._query_fresh``:
        wrap ``sources`` as quota-free requests, drain them, and return
        ``{source: ServedResult}``."""
        q = RequestQueue()
        for s in sources:
            q.submit(Request(tenant="_local", program=program, source=s,
                             submit_vt=self.vt,
                             submit_wall=time.monotonic()))
        served = self.pump(q)
        return {r.request.source: r for r in served}
