"""Chunk-based edge-balanced graph partitioning (paper §IV, following
Scaph [44] / Gemini [46]).

Each partition P_i is a set of consecutively-numbered vertices whose edge
segments are contiguous in the CSR edge arrays and hold ~equal edge counts
(the paper's 32 MB default).  HyTGraph *decouples* graph partitioning from
task scheduling (paper §V-B): partitions stay small for fine-grained cost
analysis; the task combiner merges them at schedule time.

``DevicePartitions`` pads every partition's edge range to a common static
``block_size`` so jitted code can ``dynamic_slice`` fixed-size edge blocks
— the JAX analogue of streaming one partition through the transfer engine.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph


@dataclass(frozen=True)
class PartitionTable:
    """Host-side partition boundaries."""

    vertex_start: np.ndarray  # (P+1,) int64
    edge_start: np.ndarray    # (P+1,) int64

    @property
    def n_partitions(self) -> int:
        return len(self.vertex_start) - 1

    @property
    def edges_per_partition(self) -> np.ndarray:
        return np.diff(self.edge_start)

    @property
    def vertices_per_partition(self) -> np.ndarray:
        return np.diff(self.vertex_start)


def partition_graph(
    g: CSRGraph,
    n_partitions: int | None = None,
    partition_bytes: int = 32 * 2**20,
    d1: float = 4.0,
) -> PartitionTable:
    """Edge-balanced chunk partitioning.

    If ``n_partitions`` is None it is derived from the paper's 32 MB
    partition size (``partition_bytes / d1`` edges per partition).
    Boundaries are vertex-aligned: a vertex's whole edge segment stays in
    one partition (required by all three engines).
    """
    m = max(g.n_edges, 1)
    if n_partitions is None:
        epp = max(int(partition_bytes / d1), 1)
        n_partitions = max(1, -(-m // epp))
    n_partitions = min(n_partitions, g.n_nodes)
    targets = np.linspace(0, m, n_partitions + 1)
    # vertex_start[i] = first vertex whose edge segment starts at/after target
    vertex_start = np.searchsorted(g.indptr, targets, side="left").astype(np.int64)
    vertex_start[0], vertex_start[-1] = 0, g.n_nodes
    vertex_start = np.maximum.accumulate(vertex_start)
    edge_start = g.indptr[vertex_start]
    return PartitionTable(vertex_start=vertex_start, edge_start=edge_start)


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class DevicePartitions:
    vertex_start: jax.Array   # (P+1,) int32
    edge_start: jax.Array     # (P+1,) int32
    part_edges: jax.Array     # (P,) int32 — E_i
    vertex_part_id: jax.Array  # (n,) int32
    n_partitions: int = dataclasses.field(metadata=dict(static=True))
    block_size: int = dataclasses.field(metadata=dict(static=True))

    @property
    def max_edge_start(self) -> int:
        return int(self.n_partitions)


def to_device_partitions(
    table: PartitionTable, n_nodes: int, edge_capacity: int, block_multiple: int = 128
) -> DevicePartitions:
    epp = table.edges_per_partition
    block = int(epp.max(initial=1))
    block = max(block_multiple, -(-block // block_multiple) * block_multiple)
    # dynamic_slice clamps the start index; padding edges (>= n_edges) are
    # masked by the in-range test, so block may exceed capacity remainder.
    block = min(block, edge_capacity)
    part_id = np.repeat(
        np.arange(table.n_partitions, dtype=np.int32),
        table.vertices_per_partition,
    )
    assert len(part_id) == n_nodes
    return DevicePartitions(
        vertex_start=jnp.asarray(table.vertex_start, dtype=jnp.int32),
        edge_start=jnp.asarray(table.edge_start, dtype=jnp.int32),
        part_edges=jnp.asarray(epp, dtype=jnp.int32),
        vertex_part_id=jnp.asarray(part_id),
        n_partitions=table.n_partitions,
        block_size=block,
    )
