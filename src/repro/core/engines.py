"""The three transfer/processing engines (paper §II-B/C, Fig. 2).

All three engines relax the *same* active edges and must produce identical
results; they differ in how the edge bytes travel from the big memory to
the compute units:

* ``FILTER``   — stream the whole partition block contiguously (cudaMemcpy
  analogue; on TPU: dense (8,128)-tiled HBM->VMEM DMA, `kernels/segment_spmm`).
  Inactive edges ride along and are masked in compute.
* ``COMPACT``  — first squeeze the active edges to the front of the block
  (prefix-sum stream compaction; the paper's CPU pass becomes an on-device
  pass, `kernels/frontier_compact`), then stream only the dense prefix.
* ``ZEROCOPY`` — fine-grained per-vertex gathers of neighbour segments
  straight from the big memory (`kernels/hyb_gather`): no redundancy, no
  extra pass, but request-granular bandwidth.

Each engine has TWO implementations behind the static ``use_kernels``
flag (threaded from ``HyTMConfig.use_kernels`` — ``"auto"`` resolves via
``kernels.runtime``: on for TPU backends, off elsewhere):

* ``use_kernels=False`` — the pure-JAX *oracles* below: `filter` is a
  masked dense block, `compact` really sorts active edges to the front
  and relaxes the prefix, `zerocopy` gathers edge ids through a take
  (random access).
* ``use_kernels=True`` — the Pallas kernel path: FILTER combines through
  the blocked ``segment_spmm`` (one-hot MXU scatter-add / masked-select
  scatter-min), COMPACT squeezes the active edges through the
  ``frontier_compact`` stream-compaction kernel and relaxes the dense
  prefix, ZEROCOPY re-fetches the block as per-window DMA descriptors
  through ``hyb_gather`` before combining.

Equivalence contract (tests/test_engines.py, tests/test_kernels.py): the
kernel path is **bit-identical** to the oracle for MIN combiners (min is
order-independent; the compaction prefix is stable in both paths) and
tolerance-bounded for SUM (the tiled accumulation reassociates float
addition).  Both paths trace under ``vmap`` (service lanes),
``shard_map`` (the mesh sweep), and ``lax.while_loop`` (the chunked
driver).  ``lax.switch`` executes exactly one engine per partition.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.graph.algorithms import MIN, VertexProgram


class EdgeBlock(NamedTuple):
    """One partition's (padded) edge block."""

    src: jax.Array      # (B,) int32
    dst: jax.Array      # (B,) int32
    weight: jax.Array   # (B,) float32
    active: jax.Array   # (B,) bool — source active AND edge in partition


class RelaxOut(NamedTuple):
    agg: jax.Array       # (n,) combined messages
    touched: jax.Array   # (n,) bool — destinations receiving any message


def _messages(block: EdgeBlock, operand: jax.Array, program: VertexProgram) -> jax.Array:
    """Per-edge messages; inactive lanes emit the combiner identity."""
    src_op = operand[block.src]
    msg = program.edge_message(src_op, block.weight)
    identity = jnp.inf if program.combine == MIN else 0.0
    return jnp.where(block.active, msg, identity)


def _combine(block: EdgeBlock, msg: jax.Array, n: int, program: VertexProgram) -> RelaxOut:
    if program.combine == MIN:
        agg = jax.ops.segment_min(msg, block.dst, num_segments=n)
        touched = jnp.isfinite(agg)
    else:
        agg = jax.ops.segment_sum(msg, block.dst, num_segments=n)
        got = jax.ops.segment_sum(
            block.active.astype(jnp.float32), block.dst, num_segments=n
        )
        touched = got > 0
    return RelaxOut(agg=agg, touched=touched)


def _combine_spmm(block: EdgeBlock, msg: jax.Array, n: int, program: VertexProgram) -> RelaxOut:
    """Destination combine through the blocked ``segment_spmm`` kernel.

    MIN: the scatter-min kernel over the identity-masked messages —
    bit-identical to ``jax.ops.segment_min`` (order-free).  SUM: one
    kernel call over the packed (B, 2) [message, active] columns — the
    value column is tolerance-bounded (tiled reassociation), the 0/1
    activity column sums exactly, so ``touched`` stays bit-exact.
    """
    from repro.kernels.segment_spmm.ops import segment_spmm

    if program.combine == MIN:
        agg = segment_spmm(msg, block.dst, n, combine="min")
        return RelaxOut(agg=agg, touched=jnp.isfinite(agg))
    packed = jnp.stack([msg, block.active.astype(msg.dtype)], axis=-1)
    out = segment_spmm(packed, block.dst, n)
    return RelaxOut(agg=out[:, 0], touched=out[:, 1] > 0)


# ------------------------------------------------------------------ engines

def relax_filter(
    block: EdgeBlock, operand: jax.Array, n: int, program: VertexProgram,
    use_kernels: bool = False,
) -> RelaxOut:
    """Whole-block masked relax (dense stream)."""
    msg = _messages(block, operand, program)
    if use_kernels:
        return _combine_spmm(block, msg, n, program)
    return _combine(block, msg, n, program)


def relax_compact(
    block: EdgeBlock, operand: jax.Array, n: int, program: VertexProgram,
    use_kernels: bool = False,
) -> RelaxOut:
    """Compact active edges to the front (stable), then relax the prefix.

    The compaction is the on-device analogue of the paper's CPU pass:
    after it, the active edges occupy a dense prefix, which is what the
    downstream dense kernel would stream.  Correctness is unaffected by
    the permutation (combiners are commutative).  The kernel path runs
    the real ``frontier_compact`` stream-compaction kernel over the
    packed (src, dst, weight) columns; both paths keep kept lanes in
    original order (stable), so even the SUM summation order matches the
    oracle on the dense prefix.
    """
    if use_kernels:
        from repro.kernels.frontier_compact.ops import frontier_compact

        B = block.src.shape[0]
        # int32 ids ride the kernel's one-hot permutation matmul as exact
        # float32 (ids < 2^24 — partition blocks are far smaller); the
        # matmul multiplies by exact 0/1, so finite values copy bit-exact.
        packed = jnp.stack([
            block.src.astype(jnp.float32),
            block.dst.astype(jnp.float32),
            block.weight,
        ], axis=-1)                                     # (B, 3)
        comp, cnt = frontier_compact(packed, block.active)
        lane_valid = jnp.arange(B, dtype=jnp.int32) < cnt
        compacted = EdgeBlock(
            src=jnp.where(lane_valid, comp[:, 0].astype(jnp.int32), 0),
            dst=jnp.where(lane_valid, comp[:, 1].astype(jnp.int32), 0),
            weight=jnp.where(lane_valid, comp[:, 2], 0.0),
            active=lane_valid,
        )
    else:
        order = jnp.argsort(~block.active, stable=True)
        compacted = EdgeBlock(
            src=block.src[order],
            dst=block.dst[order],
            weight=block.weight[order],
            active=block.active[order],
        )
    return _combine(compacted, _messages(compacted, operand, program), n, program)


def relax_zerocopy(
    block: EdgeBlock, operand: jax.Array, n: int, program: VertexProgram,
    use_kernels: bool = False,
) -> RelaxOut:
    """Fine-grained gather relax: edge fields are re-fetched through
    random access (per-request pattern), then combined.  Semantically
    identical; access pattern is the ZC one.  The kernel path issues the
    block as per-window ``hyb_gather`` DMA descriptors (one descriptor
    per PAD-lane window — the fine-grained request stream Eq. 3 charges)
    instead of the oracle's ``take``; edge ids round-trip through the
    gather as bit-cast float lanes (pure data movement, no arithmetic),
    so reconstruction is exact for any int32 and the relax result is
    bit-identical to the oracle for both combiners.
    """
    if use_kernels:
        from repro.kernels.hyb_gather.hyb_gather import PAD
        from repro.kernels.hyb_gather.ops import hyb_gather

        B = block.src.shape[0]
        as_f32 = lambda a: jax.lax.bitcast_convert_type(a, jnp.float32)
        as_i32 = lambda a: jax.lax.bitcast_convert_type(a, jnp.int32)
        packed = jnp.stack([
            as_f32(block.src),
            as_f32(block.dst),
            block.weight,
            as_f32(block.active.astype(jnp.int32)),
        ], axis=-1)                                     # (B, 4)
        n_win = -(-B // PAD)
        starts = jnp.arange(n_win, dtype=jnp.int32) * PAD
        degs = jnp.minimum(jnp.int32(B) - starts, PAD)
        flat = hyb_gather(packed, starts, degs).reshape(n_win * PAD, 4)[:B]
        gathered = EdgeBlock(
            src=as_i32(flat[:, 0]),
            dst=as_i32(flat[:, 1]),
            weight=flat[:, 2],
            active=as_i32(flat[:, 3]) != 0,
        )
    else:
        idx = jnp.arange(block.src.shape[0], dtype=jnp.int32)
        gathered = EdgeBlock(
            src=jnp.take(block.src, idx),
            dst=jnp.take(block.dst, idx),
            weight=jnp.take(block.weight, idx),
            active=jnp.take(block.active, idx),
        )
    return _combine(gathered, _messages(gathered, operand, program), n, program)


ENGINE_FNS = (relax_filter, relax_compact, relax_zerocopy)


def relax_with_engine(
    engine_id: jax.Array,  # scalar int32: 0 filter / 1 compact / 2 zerocopy
    block: EdgeBlock,
    operand: jax.Array,
    n: int,
    program: VertexProgram,
    use_kernels: bool = False,
) -> RelaxOut:
    return jax.lax.switch(
        jnp.clip(engine_id, 0, 2),
        [lambda b=b: ENGINE_FNS[b](block, operand, n, program, use_kernels)
         for b in range(3)],
    )
