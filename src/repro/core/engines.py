"""The three transfer/processing engines (paper §II-B/C, Fig. 2).

All three engines relax the *same* active edges and must produce identical
results; they differ in how the edge bytes travel from the big memory to
the compute units:

* ``FILTER``   — stream the whole partition block contiguously (cudaMemcpy
  analogue; on TPU: dense (8,128)-tiled HBM->VMEM DMA, `kernels/segment_spmm`).
  Inactive edges ride along and are masked in compute.
* ``COMPACT``  — first squeeze the active edges to the front of the block
  (prefix-sum stream compaction; the paper's CPU pass becomes an on-device
  pass, `kernels/frontier_compact`), then stream only the dense prefix.
* ``ZEROCOPY`` — fine-grained per-vertex gathers of neighbour segments
  straight from the big memory (`kernels/hyb_gather`): no redundancy, no
  extra pass, but request-granular bandwidth.

The pure-JAX implementations below are the semantic oracles: `filter` is a
masked dense block, `compact` really sorts active edges to the front and
relaxes the prefix, `zerocopy` gathers edge ids through a take (random
access).  ``lax.switch`` executes exactly one path per partition.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.graph.algorithms import MIN, VertexProgram


class EdgeBlock(NamedTuple):
    """One partition's (padded) edge block."""

    src: jax.Array      # (B,) int32
    dst: jax.Array      # (B,) int32
    weight: jax.Array   # (B,) float32
    active: jax.Array   # (B,) bool — source active AND edge in partition


class RelaxOut(NamedTuple):
    agg: jax.Array       # (n,) combined messages
    touched: jax.Array   # (n,) bool — destinations receiving any message


def _messages(block: EdgeBlock, operand: jax.Array, program: VertexProgram) -> jax.Array:
    """Per-edge messages; inactive lanes emit the combiner identity."""
    src_op = operand[block.src]
    msg = program.edge_message(src_op, block.weight)
    identity = jnp.inf if program.combine == MIN else 0.0
    return jnp.where(block.active, msg, identity)


def _combine(block: EdgeBlock, msg: jax.Array, n: int, program: VertexProgram) -> RelaxOut:
    if program.combine == MIN:
        agg = jax.ops.segment_min(msg, block.dst, num_segments=n)
        touched = jnp.isfinite(agg)
    else:
        agg = jax.ops.segment_sum(msg, block.dst, num_segments=n)
        got = jax.ops.segment_sum(
            block.active.astype(jnp.float32), block.dst, num_segments=n
        )
        touched = got > 0
    return RelaxOut(agg=agg, touched=touched)


# ------------------------------------------------------------------ engines

def relax_filter(block: EdgeBlock, operand: jax.Array, n: int, program: VertexProgram) -> RelaxOut:
    """Whole-block masked relax (dense stream)."""
    return _combine(block, _messages(block, operand, program), n, program)


def relax_compact(block: EdgeBlock, operand: jax.Array, n: int, program: VertexProgram) -> RelaxOut:
    """Compact active edges to the front (stable), then relax the prefix.

    The sort is the on-device analogue of the paper's CPU compaction pass:
    after it, the active edges occupy a dense prefix, which is what the
    downstream dense kernel would stream.  Correctness is unaffected by
    the permutation (combiners are commutative).
    """
    order = jnp.argsort(~block.active, stable=True)
    compacted = EdgeBlock(
        src=block.src[order],
        dst=block.dst[order],
        weight=block.weight[order],
        active=block.active[order],
    )
    return _combine(compacted, _messages(compacted, operand, program), n, program)


def relax_zerocopy(block: EdgeBlock, operand: jax.Array, n: int, program: VertexProgram) -> RelaxOut:
    """Fine-grained gather relax: edge fields are re-fetched through an
    explicit random-access ``take`` (per-request access pattern), then
    combined.  Semantically identical; access pattern is the ZC one."""
    idx = jnp.arange(block.src.shape[0], dtype=jnp.int32)
    gathered = EdgeBlock(
        src=jnp.take(block.src, idx),
        dst=jnp.take(block.dst, idx),
        weight=jnp.take(block.weight, idx),
        active=jnp.take(block.active, idx),
    )
    return _combine(gathered, _messages(gathered, operand, program), n, program)


ENGINE_FNS = (relax_filter, relax_compact, relax_zerocopy)


def relax_with_engine(
    engine_id: jax.Array,  # scalar int32: 0 filter / 1 compact / 2 zerocopy
    block: EdgeBlock,
    operand: jax.Array,
    n: int,
    program: VertexProgram,
) -> RelaxOut:
    return jax.lax.switch(
        jnp.clip(engine_id, 0, 2),
        [lambda b=b: ENGINE_FNS[b](block, operand, n, program) for b in range(3)],
    )
