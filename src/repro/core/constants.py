"""Hardware / link models for the HyTM cost equations.

The paper's cost model (Eqs. 1-3) is parameterized by the transfer link:
``m`` (max payload of one outstanding memory request), ``MR`` (outstanding
requests per transaction group / TLP), ``RTT`` (round-trip per saturated
group), the zero-copy dumping factor ``gamma``, and the selection
thresholds ``alpha`` / ``beta``.

Two link models ship:

* ``PCIE3`` — the paper's platform (GTX 2080Ti over PCIe 3.0 x16).  Used by
  the reproduction benchmarks so the Fig-3 / Table-V curve *shapes* are
  faithful to the paper.
* ``TPU_V5E_HBM`` — the TPU deployment target.  The HBM->VMEM DMA engine
  replaces the PCIe TLP: the efficient transaction granule is one
  (8,128)-aligned tile row (>=512 B contiguous); fine-grained gathers issue
  one DMA descriptor per neighbour segment.  ``RTT`` is derived from the
  link bandwidth so modeled costs come out in seconds.

``TPU_V5E_ICI`` models the inter-chip level for the distributed (two-level)
HyTM extension (DESIGN.md §2): all-gather of whole value arrays == filter,
compacted frontier exchange == compaction.

Shipped vs calibrated profiles
------------------------------
The constants below are *shipped* profiles: paper-faithful hand-set
values, never validated against the machine actually running the
engines.  ``repro.autotune`` turns them into *calibrated* profiles: it
probes the three engines over synthetic partitions, fits ``bandwidth`` /
``gamma`` / ``compaction_bandwidth`` / ``launch_overhead_s`` by least
squares, and tunes the ``alpha``/``beta`` selection thresholds by regret
minimization against the measured-best engine (the paper itself tunes
alpha/beta empirically per platform, §V-A).  Calibrated profiles live in
a JSON registry keyed by device kind — ``$REPRO_AUTOTUNE_REGISTRY`` or
``~/.cache/repro/autotune/<device_kind>.json`` — created by ``python -m
repro.launch.calibrate`` and loaded via
``repro.autotune.registry.load_profile``.  Hardware-topology constants
(``m``, ``mr``, ``d1``, ``d2``) are never fitted; ``__post_init__``
validates every profile, shipped or loaded.

Since the ``HyTMConfig.use_kernels`` wiring, wall-probe calibration
(``wall_probe(..., use_kernels="auto")``) times the engine
implementations the runtime actually dispatches: on TPU backends the
fitted ``bandwidth`` / ``compaction_bandwidth`` / ``launch_overhead_s``
describe the Pallas kernel path (segment_spmm / frontier_compact /
hyb_gather), not the pure-JAX oracles.  Shipped numbers below predate
that wiring and remain hand-set; a calibrated registry entry supersedes
them per device kind.
"""

from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class LinkModel:
    name: str
    d1: float = 4.0      # bytes per edge entry (neighbour id)
    d2: float = 4.0      # bytes per compaction index entry
    m: float = 128.0     # bytes per outstanding memory request (saturated)
    mr: float = 256.0    # outstanding requests per transaction group (TLP)
    bandwidth: float = 12.3e9  # practical link bytes/s
    gamma: float = 0.625      # zero-copy dumping factor (paper §V-A)
    alpha: float = 0.8        # Tec < alpha*Tef  threshold (Subway's 80%)
    beta: float = 0.4         # Tec < beta*Tiz   threshold
    launch_overhead_s: float = 5e-6  # per-task scheduling overhead (kernel launch)
    compaction_bandwidth: float = 0.0  # >0: model the compaction pass (bytes/s)
    # paper §V-A: selection compares transfer-only Tec (alpha/beta absorb
    # the unmodeled CPU pass); on TPU the on-device pass IS modelable and
    # enters selection directly (DESIGN.md §2).
    selection_uses_full_compaction_cost: bool = False

    def __post_init__(self) -> None:
        for fname in ("d1", "d2", "m", "mr", "bandwidth"):
            v = getattr(self, fname)
            if not v > 0:
                raise ValueError(
                    f"LinkModel {self.name!r}: {fname} must be > 0, got {v}")
        if float(self.m) % float(self.d1) != 0.0:
            # zc_request_counts' alignment test uses the integer granule
            # m // d1; a non-divisor would silently produce wrong request
            # counts for every zero-copy partition.
            raise ValueError(
                f"LinkModel {self.name!r}: d1={self.d1} must divide "
                f"m={self.m} (the Eq. 3 request-alignment granule is m/d1)")
        for fname in ("alpha", "beta", "gamma"):
            v = getattr(self, fname)
            if not 0.0 < v <= 1.0:
                raise ValueError(
                    f"LinkModel {self.name!r}: {fname} must be in (0, 1], "
                    f"got {v}")
        for fname in ("launch_overhead_s", "compaction_bandwidth"):
            v = getattr(self, fname)
            if v < 0:
                raise ValueError(
                    f"LinkModel {self.name!r}: {fname} must be >= 0, got {v}")

    @property
    def rtt(self) -> float:
        """Seconds to move one saturated transaction group (m * mr bytes)."""
        return self.m * self.mr / self.bandwidth

    def with_(self, **kw) -> "LinkModel":
        return replace(self, **kw)


# Paper platform: PCIe 3.0 x16, 12.3 GB/s practical (paper §I), 128 B
# requests, 256 outstanding per TLP (paper §II-C).  CPU compaction modeled
# only through the transfer term, as the paper does (§V-A "In practice, we
# compute Tec_i by considering only the transfer overhead").
# CPU compaction throughput ~6 GB/s calibrates the pass to ~1/3 of a
# Subway-like run (paper Fig. 3(c): 34.5% of runtime).
PCIE3 = LinkModel(name="pcie3", m=128.0, mr=256.0, bandwidth=12.3e9,
                  compaction_bandwidth=6e9)

# TPU v5e HBM->VMEM: 819 GB/s HBM.  m=512 B (efficient DMA granule: one
# float32 (1,128) lane row x4B); mr=64 outstanding descriptors per DMA
# queue batch.  The on-device compaction pass costs an extra HBM
# read+write of the active bytes, captured by compaction_bandwidth.
TPU_V5E_HBM = LinkModel(
    name="tpu_v5e_hbm",
    m=512.0,
    mr=64.0,
    bandwidth=819e9,
    compaction_bandwidth=819e9 / 2,  # read + write pass
    launch_overhead_s=2e-6,
    selection_uses_full_compaction_cost=True,
)

# TPU v5e ICI link (per-direction ~50 GB/s/link): the distributed level.
TPU_V5E_ICI = LinkModel(
    name="tpu_v5e_ici",
    m=512.0,
    mr=64.0,
    bandwidth=50e9,
    launch_overhead_s=1e-6,
)

# Roofline constants (TPU v5e, per chip) — used by benchmarks/roofline.py.
PEAK_FLOPS_BF16 = 197e12   # FLOP/s
HBM_BANDWIDTH = 819e9      # bytes/s
ICI_BANDWIDTH = 50e9       # bytes/s per link
VMEM_BYTES = 128 * 2**20   # ~128 MB VMEM per core
