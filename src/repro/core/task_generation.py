"""Cost-aware task generation — paper Algorithm 1 + §V-B task combination.

Selection runs vectorized on-device (cost_model.py); this module adds the
*task combination* accounting: HyTGraph decouples partition granularity
(small, for fine cost analysis) from scheduling granularity:

* consecutive FILTER partitions merge into tasks of at most ``k`` (k=4),
* all COMPACT partitions merge into ONE task (their active edges are
  written to one contiguous staging buffer),
* all ZEROCOPY partitions merge into ONE kernel (implicit overlap).

The merged task count drives the modeled per-task scheduling overhead
(kernel launches / fragmented transfers) and the Fig-8 "TC" ablation.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.constants import LinkModel
from repro.core.cost_model import (
    COMPACT,
    FILTER,
    ZEROCOPY,
    EngineCosts,
    PartitionStats,
    engine_costs,
    modeled_time_seconds,
    modeled_transfer_bytes,
    select_engines,
)


class TaskPlan(NamedTuple):
    engines: jax.Array        # (P,) int32 engine ids (NONE = skip)
    n_tasks: jax.Array        # scalar — combined task count
    transfer_bytes: jax.Array  # (P,) modeled bytes under chosen engine
    transfer_time: jax.Array   # (P,) modeled seconds under chosen engine
    costs: EngineCosts


def _merged_filter_tasks(is_filter: jax.Array, k: int) -> jax.Array:
    """Number of tasks after merging runs of consecutive FILTER partitions
    into chunks of at most k (Algorithm 1 lines 15-24)."""

    def step(carry, f):
        run_len = carry
        # a new task starts when f is set and run position hits a multiple of k
        starts = f & (run_len % k == 0)
        run_len = jnp.where(f, run_len + 1, 0)
        return run_len, starts

    _, starts = jax.lax.scan(step, jnp.int32(0), is_filter)
    return jnp.sum(starts.astype(jnp.int32))


def generate_tasks(
    stats: PartitionStats,
    link: LinkModel,
    combine_k: int = 4,
    enable_combination: bool = True,
    correction=None,
) -> TaskPlan:
    """``correction``: optional (3,) per-engine cost scaling from the
    online-feedback loop (repro.autotune) — biases *selection* only; the
    transfer_bytes/transfer_time accounting stays in model units."""
    costs = engine_costs(stats, link)
    engines = select_engines(stats, costs, link, correction)
    active = engines >= 0
    if enable_combination:
        n_filter_tasks = _merged_filter_tasks(engines == FILTER, combine_k)
        n_tasks = (
            n_filter_tasks
            + jnp.any(engines == COMPACT).astype(jnp.int32)
            + jnp.any(engines == ZEROCOPY).astype(jnp.int32)
        )
    else:
        n_tasks = jnp.sum(active.astype(jnp.int32))
    return TaskPlan(
        engines=engines,
        n_tasks=n_tasks,
        transfer_bytes=modeled_transfer_bytes(stats, engines, link),
        transfer_time=modeled_time_seconds(costs, engines),
        costs=costs,
    )


def forced_engine_plan(
    stats: PartitionStats,
    link: LinkModel,
    engine: int,
    enable_combination: bool = True,
    combine_k: int = 4,
) -> TaskPlan:
    """Single-engine baseline plan (pure ExpTM-F / ExpTM-C / ImpTM-ZC
    systems the paper compares against in Table V)."""
    costs = engine_costs(stats, link)
    engines = jnp.where(stats.active_edges > 0, engine, -1).astype(jnp.int32)
    if enable_combination:
        n_filter_tasks = _merged_filter_tasks(engines == FILTER, combine_k)
        n_tasks = (
            n_filter_tasks
            + jnp.any(engines == COMPACT).astype(jnp.int32)
            + jnp.any(engines == ZEROCOPY).astype(jnp.int32)
        )
    else:
        n_tasks = jnp.sum((engines >= 0).astype(jnp.int32))
    return TaskPlan(
        engines=engines,
        n_tasks=n_tasks,
        transfer_bytes=modeled_transfer_bytes(stats, engines, link),
        transfer_time=modeled_time_seconds(costs, engines),
        costs=costs,
    )
