"""HyTM cost model — paper §V-A, Eqs. (1)-(3) — vectorized over partitions.

Per iteration, for every partition i the model estimates the cost of the
three engines from the active-vertex statistics, then Algorithm 1's
selection rule picks the cheapest:

  Tef_i = ceil(E_i * d1 / m / MR) * RTT                          (Eq. 1)
  Tec_i = ceil((Ea_i*d1 + |A_i|*d2) / m / MR) * RTT [+ cpt]      (Eq. 2)
  Tiz_i = ceil(REQ_i / MR) * RTT_zc                              (Eq. 3)
  RTT_zc = gamma*RTT + (1-gamma) * (Ea_i/E_i) * RTT

where REQ_i = sum over active v of ceil(deg(v)*d1/m) + am(v) and am(v)
flags a misaligned neighbour segment (one extra memory transaction,
paper footnote 1: computed from the segment's length and physical start).

Selection (Algorithm 1, lines 4-12):
  if Tec < alpha*Tef and Tec < beta*Tiz: COMPACT      (alpha=0.8, beta=0.4)
  elif Tef < Tiz:                         FILTER
  else:                                   ZEROCOPY
Partitions with no active edges are skipped (engine NONE) — all four
engine families skip fully-inactive partitions.

As in the paper, cost computation runs *on the accelerator* (it is a
vectorized O(P) computation inside the jitted iteration; only the
selection result is consumed by the host-side task combiner).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.constants import LinkModel
from repro.core.partition import DevicePartitions

# Engine ids (stable: used by lax.switch and the benchmarks).
NONE, FILTER, COMPACT, ZEROCOPY = -1, 0, 1, 2
ENGINE_NAMES = {NONE: "none", FILTER: "filter", COMPACT: "compact", ZEROCOPY: "zerocopy"}


class PartitionStats(NamedTuple):
    """Per-partition activity statistics for one iteration (all (P,))."""

    active_edges: jax.Array    # Ea_i
    active_vertices: jax.Array  # |A_i|
    zc_requests: jax.Array     # REQ_i
    total_edges: jax.Array     # E_i (static per graph, carried for convenience)


def zc_request_counts(
    out_degree: jax.Array, seg_start: jax.Array, link: LinkModel
) -> jax.Array:
    """Per-vertex zero-copy request count: ceil(deg*d1/m) + am(v).

    Precomputed once per graph (static).  am(v)=1 when the neighbour
    segment's physical start is not m-aligned and the vertex has edges.
    """
    deg = out_degree.astype(jnp.float32)
    base = jnp.ceil(deg * link.d1 / link.m)
    # alignment test: (seg_start * d1) % m != 0; d1 divides m in all link
    # models, so this is seg_start % (m/d1) != 0 (int32-safe at any scale).
    granule = max(int(link.m // link.d1), 1)
    misaligned = seg_start % granule != 0
    am = jnp.where(misaligned & (out_degree > 0), 1.0, 0.0)
    return (base + am).astype(jnp.float32)


def partition_stats(
    frontier: jax.Array,          # (n,) bool
    out_degree: jax.Array,        # (n,) int32
    zc_req_per_vertex: jax.Array,  # (n,) float32
    parts: DevicePartitions,
) -> PartitionStats:
    """Segment-reduce per-vertex activity into per-partition statistics."""
    act = frontier.astype(jnp.float32)
    pid = parts.vertex_part_id
    P = parts.n_partitions
    ea = jax.ops.segment_sum(act * out_degree.astype(jnp.float32), pid, num_segments=P)
    av = jax.ops.segment_sum(act, pid, num_segments=P)
    zr = jax.ops.segment_sum(act * zc_req_per_vertex, pid, num_segments=P)
    return PartitionStats(
        active_edges=ea,
        active_vertices=av,
        zc_requests=zr,
        total_edges=parts.part_edges.astype(jnp.float32),
    )


class EngineCosts(NamedTuple):
    tef: jax.Array       # (P,) seconds
    tec: jax.Array       # selection value (transfer-only, paper §V-A)
    tiz: jax.Array
    tec_full: jax.Array  # + the compaction pass — what execution pays


def engine_costs(stats: PartitionStats, link: LinkModel) -> EngineCosts:
    rtt = link.rtt
    group = link.m * link.mr  # bytes per saturated transaction group

    # Eq. 1 — filter ships the whole partition.
    tef = jnp.ceil(stats.total_edges * link.d1 / group) * rtt

    # Eq. 2 — compaction ships active edges + a fresh index array.  The
    # paper compares transfer-only (CPU compaction is hard to model,
    # §V-A); on TPU the on-device compaction pass IS modelable as one
    # extra read+write of the active bytes (DESIGN.md §2).
    cbytes = stats.active_edges * link.d1 + stats.active_vertices * link.d2
    tec = jnp.ceil(cbytes / group) * rtt
    tec_full = tec
    if link.compaction_bandwidth > 0:
        tec_full = tec + cbytes / link.compaction_bandwidth
    if link.selection_uses_full_compaction_cost:
        tec = tec_full

    # Eq. 3 — zero-copy: fine-grained per-vertex requests, discounted RTT.
    ratio = jnp.where(
        stats.total_edges > 0, stats.active_edges / jnp.maximum(stats.total_edges, 1.0), 0.0
    )
    rtt_zc = link.gamma * rtt + (1.0 - link.gamma) * ratio * rtt
    tiz = jnp.ceil(stats.zc_requests / link.mr) * rtt_zc

    return EngineCosts(tef=tef, tec=tec, tiz=tiz, tec_full=tec_full)


def apply_correction(costs: EngineCosts, correction: jax.Array | None) -> EngineCosts:
    """Scale per-engine costs by a (3,) multiplicative correction vector
    (index == engine id) — the online-feedback hook
    (repro.autotune.feedback).  ``None`` is the identity."""
    if correction is None:
        return costs
    return EngineCosts(
        tef=costs.tef * correction[FILTER],
        tec=costs.tec * correction[COMPACT],
        tiz=costs.tiz * correction[ZEROCOPY],
        tec_full=costs.tec_full * correction[COMPACT],
    )


def algorithm1_engines(tef, tec, tiz, alpha, beta) -> jax.Array:
    """Algorithm 1 lines 4-12 on raw per-engine selection costs.

    The single definition of the threshold rule — ``select_engines``
    (runtime, jitted) and ``repro.autotune``'s alpha/beta tuning both
    call it, so tuned thresholds always optimize the rule the runtime
    executes.  Accepts numpy or jax arrays; ``alpha``/``beta`` may be
    scalars or arrays broadcastable against the costs (the tuner
    evaluates its whole candidate grid in one call).
    """
    pick_compact = (tec < alpha * tef) & (tec < beta * tiz)
    pick_filter = tef < tiz
    return jnp.where(pick_compact, COMPACT, jnp.where(pick_filter, FILTER, ZEROCOPY))


def select_engines(
    stats: PartitionStats,
    costs: EngineCosts,
    link: LinkModel,
    correction: jax.Array | None = None,
) -> jax.Array:
    """Algorithm 1 lines 4-12 → (P,) engine ids (NONE for inactive).

    ``correction`` (optional (3,)) rescales the per-engine costs before
    the threshold comparisons; transfer *accounting* stays uncorrected —
    feedback steers decisions, the model keeps reporting its own units.
    """
    costs = apply_correction(costs, correction)
    eng = algorithm1_engines(costs.tef, costs.tec, costs.tiz, link.alpha, link.beta)
    return jnp.where(stats.active_edges > 0, eng, NONE).astype(jnp.int32)


def modeled_best_engines(
    stats: PartitionStats,
    costs: EngineCosts,
    correction: jax.Array | None = None,
) -> jax.Array:
    """(P,) engine whose (corrected) *execution* cost is minimal — the
    model's own oracle.  Selection vs this oracle defines the per-
    iteration misprediction count: Algorithm 1's thresholds deliberately
    bias away from pure argmin, and the online corrections move the
    argmin itself, so the gap is the quantity autotuning drives down."""
    costs = apply_correction(costs, correction)
    stacked = jnp.stack([costs.tef, costs.tec_full, costs.tiz])  # row idx == engine id
    best = jnp.argmin(stacked, axis=0).astype(jnp.int32)
    return jnp.where(stats.active_edges > 0, best, NONE)


def selection_diagnostics(
    engines: jax.Array,        # (P,) chosen engine ids
    transfer_time: jax.Array,  # (P,) modeled seconds under chosen engine
    stats: PartitionStats,
    costs: EngineCosts,
    correction: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Per-iteration feedback diagnostics, shared by the single-device and
    sharded iterations: (3,) modeled seconds attributed to each engine
    (the online calibrator's regressors) and the count of processed
    partitions where Algorithm 1 diverged from the (corrected)
    modeled-best engine."""
    per_engine_time = jnp.stack([
        jnp.sum(jnp.where(engines == e, transfer_time, 0.0))
        for e in (FILTER, COMPACT, ZEROCOPY)
    ])
    best = modeled_best_engines(stats, costs, correction)
    mispredictions = jnp.sum(
        ((engines != best) & (engines != NONE)).astype(jnp.int32)
    )
    return per_engine_time, mispredictions


# --------------------------------------------------------------------------
# Telemetry key constants
# --------------------------------------------------------------------------

# The single definition of every stringly-typed history / stats key the
# runtime emits and the observability layer (repro.obs) consumes.  Every
# producer (core.hytm, dist.graph_shard, stream.service, serve.scheduler)
# and every consumer (repro.obs, benchmarks, tests) imports these instead
# of re-spelling the literal, so the accounting and the traces cannot
# silently drift apart.

# HyTMResult.history rows (see HISTORY_KEYS below).
KEY_ENGINES = "engines"
KEY_TRANSFER_BYTES = "transfer_bytes"
KEY_TRANSFER_TIME = "transfer_time"
KEY_ACTIVE_VERTICES = "active_vertices"
KEY_ACTIVE_EDGES = "active_edges"
KEY_N_TASKS = "n_tasks"
KEY_MISPREDICTIONS = "mispredictions"
KEY_PER_ENGINE_TIME = "per_engine_time"
# Sharded-run extension: per-iteration ICI exchange accounting
# (dist.graph_shard.run_hytm_sharded / charge_ici).
KEY_MERGED_ENTRIES = "merged_entries"
KEY_ICI_BYTES = "ici_bytes"
KEY_ICI_TIME = "ici_time"
KEY_ICI_ENGINE = "ici_engine"
# Owner-sharded state layout (HyTMConfig.vertex_sharding="owner"):
# per-device halo size (boundary entries a compacted exchange would ship)
# and per-device vertex-state bytes (vertex_state_bytes below).
KEY_HALO_ENTRIES = "halo_entries"
KEY_STATE_BYTES_PER_DEVICE = "state_bytes_per_device"
# ServiceStats.extra side-channel names (stream.service / serve.scheduler).
KEY_WARM_CACHE = "warm_cache"
KEY_ENGINE_CORRECTIONS = "engine_corrections"

# f32 values + f32 delta + bool frontier, each one entry per vertex.
STATE_BYTES_PER_VERTEX = 4 + 4 + 1


def vertex_state_bytes(
    n_nodes: int,
    n_devices: int = 1,
    vertex_sharding: str = "replicated",
    halo: int = 0,
) -> int:
    """Per-device bytes the (values, Δ, frontier) triple pins.

    ``replicated`` (the PR-9 layout): every device holds the full
    ``(n,)`` triple — the memory ceiling the owner layout lifts.
    ``owner``: each device holds its ``ceil(n/D)`` owned slice plus a
    ``halo`` of boundary entries referenced by its local edge blocks, so
    state scales ~n/D with the mesh (fig9_scaling's --selfcheck gate).
    """
    if vertex_sharding == "owner":
        n_loc = -(-n_nodes // max(n_devices, 1))
        return STATE_BYTES_PER_VERTEX * (n_loc + halo)
    return STATE_BYTES_PER_VERTEX * n_nodes


# --------------------------------------------------------------------------
# Per-iteration history layout (shared by the chunked drivers)
# --------------------------------------------------------------------------

# The iteration-info keys that persist into ``HyTMResult.history`` — one
# row per iteration.  The chunked ``lax.while_loop`` drivers
# (core.hytm.hytm_chunk, dist.graph_shard.make_sharded_chunk) preallocate
# an on-device ``(chunk, *shape)`` buffer per key, write row ``i`` inside
# the loop body, and drain the whole buffer to host once per chunk — this
# tuple is the single definition of which keys those buffers carry.
# ``per_engine_time`` rides along because the online calibrator
# (repro.autotune.feedback) regresses its per-chunk sum against measured
# chunk wall time.  Sharded runs extend the set with ``merged_entries``
# (the ICI-level accounting input); ``next_active`` is *not* buffered —
# it lives in the while-loop carry as the early-exit condition and is
# returned separately.
HISTORY_KEYS = (
    KEY_ENGINES, KEY_TRANSFER_BYTES, KEY_TRANSFER_TIME, KEY_ACTIVE_VERTICES,
    KEY_ACTIVE_EDGES, KEY_N_TASKS, KEY_MISPREDICTIONS, KEY_PER_ENGINE_TIME,
)


def init_history_buffers(
    info_shapes: dict, chunk: int, keys: tuple = HISTORY_KEYS
) -> dict:
    """Preallocated on-device history: ``key -> zeros((chunk, *shape))``.

    ``info_shapes`` maps info keys to ``jax.ShapeDtypeStruct``s (usually
    from ``jax.eval_shape`` of the iteration), so buffer layout follows
    the iteration's actual output spec instead of a parallel hand-written
    one that could drift.
    """
    return {
        k: jnp.zeros((chunk,) + tuple(info_shapes[k].shape),
                     info_shapes[k].dtype)
        for k in keys
    }


def modeled_transfer_bytes(stats: PartitionStats, engines: jax.Array, link: LinkModel) -> jax.Array:
    """Modeled host->accelerator bytes each partition moves under its
    chosen engine (Table VI accounting).

    filter:   whole partition               E_i * d1
    compact:  active edges + index array    Ea_i*d1 + |A_i|*d2
    zerocopy: request-granular occupancy    REQ_i * m  (cache-line rounding
              is the paper's 'redundant ZC transfer' — Fig 3(d/e))
    """
    b_f = stats.total_edges * link.d1
    b_c = stats.active_edges * link.d1 + stats.active_vertices * link.d2
    b_z = stats.zc_requests * link.m
    out = jnp.where(engines == FILTER, b_f, 0.0)
    out = jnp.where(engines == COMPACT, b_c, out)
    out = jnp.where(engines == ZEROCOPY, b_z, out)
    return out


def engine_bandwidths(
    stats: PartitionStats, costs: EngineCosts, link: LinkModel
) -> jax.Array:
    """(3, P) modeled effective bandwidth (bytes/second) per engine: the
    Table-VI byte accounting divided by the Eqs. 1-3 *execution* seconds
    (``tec_full`` for compact — the pass is physically paid).  Row index
    == engine id.  This is the "modeled" side of the roofline gate
    (benchmarks.roofline.engine_rooflines): a wall-probed engine whose
    achieved bytes/second collapses far below this line signals the
    kernel path stopped saturating the transfer the model charges for.
    Partitions whose modeled time is zero report zero bandwidth."""
    bytes_ = jnp.stack([
        stats.total_edges * link.d1,
        stats.active_edges * link.d1 + stats.active_vertices * link.d2,
        stats.zc_requests * link.m,
    ])  # (3, P) — same accounting as modeled_transfer_bytes, all engines
    secs = jnp.stack([costs.tef, costs.tec_full, costs.tiz])
    return jnp.where(secs > 0, bytes_ / jnp.maximum(secs, 1e-30), 0.0)


def modeled_time_seconds(costs: EngineCosts, engines: jax.Array) -> jax.Array:
    """Reported (execution) time — charges the compaction pass the
    selection rule deliberately omits (paper Fig. 3(c): the pass is
    ~34.5% of Subway's runtime; alpha/beta compensate at selection)."""
    t = jnp.where(engines == FILTER, costs.tef, 0.0)
    t = jnp.where(engines == COMPACT, costs.tec_full, t)
    t = jnp.where(engines == ZEROCOPY, costs.tiz, t)
    return t
