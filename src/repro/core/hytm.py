"""HyTM engine orchestration — ties cost model, task generation, and
asynchronous scheduling into the iterate-until-convergence loop (paper
Fig. 5: cost-aware task generation <-> asynchronous task scheduling).

One *iteration* is a single jitted function:

  1. per-partition activity stats      (segment reductions, on device)
  2. cost model + engine selection     (Eqs. 1-3, Algorithm 1)
  3. task combination                  (merged task count -> launch overhead)
  4. priority schedule                 (hub / delta contribution-driven order)
  5. asynchronous sweep                (scan over partitions in priority
     order; each partition relaxes through its selected engine against the
     *current* values — later partitions see earlier updates)
  6. recompute-once second pass        (loaded priority partitions, no
     additional transfer)

The convergence loop is **device-resident and chunked**
(``HyTMConfig.sync_every = K``): ``hytm_chunk`` runs up to K iterations
inside one compiled ``jax.lax.while_loop`` dispatch, with the state and
the preallocated on-device history buffers donated so values/Δ/frontier
update in place instead of round-tripping through host.  The chunk's
while-condition checks the *previous* iteration's frontier population
(``next_active == 0``), so a converged run early-exits inside the chunk
and never executes a single iteration past convergence; the host only
syncs once per chunk — to drain the ``(K, ...)`` history rows actually
written and to read the loop-exit flag — instead of twice per iteration.
``K = 1`` keeps the legacy one-dispatch-per-iteration loop (whose
per-iteration device->host sync on the frontier population is the same
sync real GPU frameworks pay), reproducing the pre-chunk dataflow
bit-for-bit; ``K > 1`` is bit-identical for min-combine programs and
tolerance-bounded for sum-combine (XLA may fuse the loop body
differently than the standalone iteration).  The drained history feeds
the Fig-7 execution path, Table-VI transfer volume, and Table-V runtime
analyses exactly as before — chunking changes *when* history reaches the
host, never what it records.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
import warnings
from dataclasses import dataclass, field
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.constants import PCIE3, TPU_V5E_ICI, LinkModel
from repro.core.cost_model import (
    COMPACT,
    FILTER,
    HISTORY_KEYS,
    KEY_ACTIVE_EDGES,
    KEY_ACTIVE_VERTICES,
    KEY_ENGINES,
    KEY_MISPREDICTIONS,
    KEY_N_TASKS,
    KEY_PER_ENGINE_TIME,
    KEY_TRANSFER_BYTES,
    KEY_TRANSFER_TIME,
    NONE,
    ZEROCOPY,
    init_history_buffers,
    partition_stats,
    selection_diagnostics,
    zc_request_counts,
)
from repro.core.engines import EdgeBlock, relax_with_engine
from repro.kernels.runtime import resolve_use_kernels
from repro.core.partition import (
    DevicePartitions,
    PartitionTable,
    partition_graph,
    to_device_partitions,
)
from repro.core.scheduler import make_schedule
from repro.core.task_generation import TaskPlan, forced_engine_plan, generate_tasks
from repro.graph.algorithms import MIN, SUM, VertexProgram
from repro.graph.csr import CSRGraph, DeviceCSR, to_device_csr


@dataclass(frozen=True)
class HyTMConfig:
    link: LinkModel = PCIE3
    n_partitions: int | None = None
    partition_bytes: int = 32 * 2**20  # paper default: 32 MB partitions
    async_sweep: bool = True
    cds_mode: str = "hub"  # 'hub' | 'delta' | 'none'
    enable_task_combination: bool = True
    recompute_once: bool = True
    combine_k: int = 4
    max_iters: int = 10_000
    # Convergence-loop chunk size K: each device dispatch runs up to K
    # iterations inside one compiled lax.while_loop (early-exiting the
    # moment the frontier drains), and the host syncs once per chunk
    # instead of twice per iteration.  K=1 keeps the legacy
    # one-dispatch-per-iteration loop (bit-for-bit the pre-chunk
    # dataflow); the default is tuned for dispatch-bound many-iteration
    # workloads (benchmarks/iterloop.py) — large enough to amortize
    # dispatch+sync, small enough that history draining and the online
    # calibrator keep a useful cadence.
    sync_every: int = 8
    # Engine implementation dispatch: route the FILTER/COMPACT/ZEROCOPY
    # relaxations through the Pallas kernels (kernels/segment_spmm,
    # kernels/frontier_compact, kernels/hyb_gather) instead of the
    # pure-JAX oracle engines.  Tri-state: "auto" (default) resolves via
    # kernels.runtime.on_tpu() — compiled kernels on TPU backends, the
    # oracles elsewhere (interpret mode would only add overhead); True
    # forces the kernel path (interpret mode off-TPU: how the equivalence
    # tests and the CI roofline gate execute the kernel bodies on CPU);
    # False forces the oracles.  Contract: the kernel path is
    # bit-identical for MIN programs (values, iterations, transfer bytes,
    # engine picks) and tolerance-bounded for SUM, on the single-device,
    # sharded, chunked, and GraphService paths alike — engine *selection*
    # and transfer accounting never depend on the flag.
    use_kernels: bool | str = "auto"
    forced_engine: int | None = None  # force a single engine (baselines)
    hub_fraction: float = 0.08
    # Second transfer-management level (DESIGN.md §2): the link model used
    # to charge the cross-device merge of the sharded sweep.  Only read on
    # the mesh_axis path; the single-device run reports zero ICI traffic.
    ici_link: LinkModel = TPU_V5E_ICI
    # Online autotuning (repro.autotune.feedback): per-iteration measured
    # sweep times feed an EWMA per-engine correction factor that rescales
    # the Algorithm-1 selection costs (and the sharded path's ICI-level
    # exchange choice).  Transfer *accounting* stays in model units; the
    # engines are semantically interchangeable, so results are unchanged
    # — only which engine pays for each partition moves.
    autotune: bool = False
    autotune_decay: float = 0.25  # EWMA forgetting factor of the calibrator
    # Name of a 1-D mesh axis to shard the partition edge blocks over
    # (repro.dist.graph_shard).  None = the single-device path below
    # (note: the sync-sweep SUM consumption fix in ``_sweep`` changed
    # async_sweep=False results relative to older revisions; the default
    # async path is untouched).  The sharded sweep is bulk-synchronous
    # across devices, so it reproduces the single-device
    # ``async_sweep=False`` dataflow exactly.
    mesh_axis: str | None = None
    # Vertex-state layout of the sharded path (read only when mesh_axis
    # is set).  "replicated" (default): every device holds the full (n,)
    # values/Δ/frontier triple — byte-identical to the pre-owner-sharding
    # behavior.  "owner": each device owns the ceil(n/D) vertices of its
    # partition rows and holds only that slice (plus the boundary halo
    # its local edge blocks reference), exchanging boundary contributions
    # per iteration — per-device vertex-state bytes drop ~D-fold
    # (cost_model.vertex_state_bytes) while results stay bit-identical to
    # the single-device ``async_sweep=False`` oracle for min-combine
    # programs and tolerance-bounded for sum-combine
    # (dist.graph_shard).
    vertex_sharding: str = "replicated"


@jax.tree_util.register_dataclass
@dataclass
class HyTMState:
    values: jax.Array   # (n,) f32
    delta: jax.Array    # (n,) f32 (accumulative programs)
    frontier: jax.Array  # (n,) bool


@dataclass
class Runtime:
    """Device-resident inputs shared by every iteration."""

    csr: DeviceCSR
    parts: DevicePartitions
    zc_req: jax.Array          # (n,) float32
    inv_deg: jax.Array         # (n,) float32 — 1/max(deg,1) (or 1/sum(w)
                               # for weighted accumulative programs: PHP)
    n_hub_partitions: int
    # (program, config, shapes) -> iteration info ShapeDtypeStructs;
    # reusing a runtime across run_hytm calls — or sharing this dict
    # across runtime views, as DeltaCSR.runtime_for does — skips the
    # per-call jax.eval_shape re-trace of the iteration body.  Keys
    # include the specializing shapes, so a shared dict stays correct
    # when the underlying buffers are re-blocked (merge-compaction).
    info_shape_cache: dict = field(default_factory=dict, repr=False)


def build_runtime(
    g: CSRGraph, config: HyTMConfig, n_hubs: int = 0, weighted_norm: bool = False
) -> Runtime:
    table: PartitionTable = partition_graph(
        g, n_partitions=config.n_partitions,
        partition_bytes=config.partition_bytes, d1=config.link.d1,
    )
    block = int(table.edges_per_partition.max(initial=1))
    block = max(128, -(-block // 128) * 128)
    capacity = -(-(g.n_edges + block) // 128) * 128
    csr = to_device_csr(g, capacity=capacity)
    parts = to_device_partitions(table, g.n_nodes, capacity)
    assert parts.block_size <= block
    zc_req = zc_request_counts(csr.out_degree, csr.seg_start, config.link)
    if weighted_norm:
        # accumulative programs over weighted edges (PHP) push
        # delta * w_ij / sum_j w_ij — normalize by weighted out-degree so
        # total mass is non-expanding.
        wsum = jax.ops.segment_sum(
            jnp.where(csr.edge_valid, csr.edge_weight, 0.0),
            csr.edge_src, num_segments=g.n_nodes,
        )
        inv_deg = 1.0 / jnp.maximum(wsum, 1e-30)
    else:
        inv_deg = 1.0 / jnp.maximum(csr.out_degree.astype(jnp.float32), 1.0)
    n_hub_parts = int(np.searchsorted(np.asarray(table.vertex_start), n_hubs, side="left"))
    n_hub_parts = max(n_hub_parts, 1) if n_hubs > 0 else 0
    return Runtime(
        csr=csr, parts=parts, zc_req=zc_req, inv_deg=inv_deg,
        n_hub_partitions=n_hub_parts,
    )


# --------------------------------------------------------------------------
# One iteration (jitted)
# --------------------------------------------------------------------------

def _slice_block(arr: jax.Array, start: jax.Array, size: int) -> jax.Array:
    return jax.lax.dynamic_slice_in_dim(arr, start, size)


def _sweep(
    state: HyTMState,
    rt: Runtime,
    program: VertexProgram,
    engines: jax.Array,       # (P,) — NONE entries are skipped
    order: jax.Array,         # (P,) processing order
    frontier: jax.Array,      # (n,) sources active for this sweep
    async_sweep: bool,
    consume: str,             # 'all' (pass 1: every partition is visited)
                              # | 'processed' (pass 2: only loaded ones)
    use_kernels: bool = False,
) -> tuple[HyTMState, jax.Array]:
    """Scan partitions in priority order; returns new state + activated."""
    n = rt.csr.n_nodes
    B = rt.parts.block_size
    values0, delta0 = state.values, state.delta

    def body(carry, p):
        values, delta, activated = carry
        eng = engines[p]
        start = rt.parts.edge_start[p]
        local = jnp.arange(B, dtype=jnp.int32)
        in_range = local < rt.parts.part_edges[p]
        src = _slice_block(rt.csr.edge_src, start, B)
        dst = _slice_block(rt.csr.edge_dst, start, B)
        w = _slice_block(rt.csr.edge_weight, start, B)
        processed = eng != NONE
        active_lane = frontier[src] & in_range & processed
        block = EdgeBlock(src=src, dst=dst, weight=w, active=active_lane)

        if program.combine == SUM:
            dsrc = delta if async_sweep else delta0
            operand = program.damping * dsrc * rt.inv_deg
        else:
            operand = values if async_sweep else values0

        out = relax_with_engine(eng, block, operand, n, program, use_kernels)

        if program.peel_k is not None:
            # peeling (k-core): the aggregate is each destination's count
            # of newly-removed in-neighbors — its remaining degree drops
            # by that much.  Δ (the removed flag) is not consumed here;
            # removal updates happen once per iteration in
            # ``_iteration_impl``.  Counts are additive, so the async and
            # sync sweeps are identical.
            values = values - out.agg
            activated = activated | out.touched
        elif program.combine == MIN:
            improved = out.touched & (out.agg < values)
            values = jnp.where(improved, out.agg, values)
            activated = activated | improved
        else:
            # consumption (rank += delta) is vertex-local compute on
            # accelerator-resident vertex data — it happens for every
            # active vertex of the partition even when the partition has
            # no active *edges* to transfer (deg-0 vertices would
            # otherwise hold their delta forever and never converge).
            in_part = rt.parts.vertex_part_id == p
            if consume == "all":
                consumed = frontier & in_part
            else:  # pass 2 touches only the re-processed partitions
                consumed = frontier & in_part & processed
            # value absorbs the consumed delta; pending delta resets, then
            # accumulates fresh contributions from this partition's edges.
            if async_sweep:
                values = values + jnp.where(consumed, delta, 0.0)
                delta = jnp.where(consumed, 0.0, delta) + out.agg
            else:
                # synchronous dataflow: only the iteration-start delta0 is
                # consumed, so subtract exactly that — zeroing the running
                # delta would drop contributions already delivered by
                # earlier partitions (order-dependent mass loss).  This
                # makes the sync sweep partition-order invariant, which is
                # the single-device oracle the sharded sweep
                # (repro.dist.graph_shard) must match bit-for-bit.
                values = values + jnp.where(consumed, delta0, 0.0)
                delta = jnp.where(consumed, delta - delta0, delta) + out.agg
            activated = activated | out.touched
        return (values, delta, activated), None

    init = (values0, delta0, jnp.zeros(n, dtype=bool))
    (values, delta, activated), _ = jax.lax.scan(body, init, order)
    return HyTMState(values=values, delta=delta, frontier=state.frontier), activated


def _iteration_impl(
    state: HyTMState,
    csr: DeviceCSR,
    parts: DevicePartitions,
    zc_req: jax.Array,
    inv_deg: jax.Array,
    program: VertexProgram,
    config: HyTMConfig,
    n_hub_partitions: int,
    correction: jax.Array | None = None,
) -> tuple[HyTMState, dict[str, Any]]:
    """Untraced single-iteration body.  ``hytm_iteration`` jits it as the
    public per-dispatch entry; ``hytm_chunk`` inlines it inside the
    chunked ``lax.while_loop`` so K iterations share one dispatch."""
    rt = Runtime(csr=csr, parts=parts, zc_req=zc_req, inv_deg=inv_deg,
                 n_hub_partitions=n_hub_partitions)
    n = csr.n_nodes
    frontier = state.frontier
    # trace-time resolution: config is static under jit, so the kernel
    # dispatch is a Python-level branch — no runtime cost either way
    use_kernels = resolve_use_kernels(config.use_kernels)

    # (1-3) stats -> costs -> engines -> combined tasks
    stats = partition_stats(frontier, csr.out_degree, zc_req, parts)
    if config.forced_engine is None:
        plan: TaskPlan = generate_tasks(
            stats, config.link, combine_k=config.combine_k,
            enable_combination=config.enable_task_combination,
            correction=correction,
        )
    else:
        plan = forced_engine_plan(
            stats, config.link, config.forced_engine,
            enable_combination=config.enable_task_combination,
            combine_k=config.combine_k,
        )

    # (4) contribution-driven priority schedule.  Only the 'delta' CDS
    # mode reads the per-partition |Δ| mass, and min-combine programs
    # carry an identically-zero Δ — in both cases the (n,)->(P,)
    # segment-sum would reduce zeros (or feed a schedule that ignores
    # it), so skip it.
    if program.combine == MIN or config.cds_mode != "delta":
        delta_mass = jnp.zeros(parts.n_partitions, jnp.float32)
    else:
        delta_mass = jax.ops.segment_sum(
            jnp.abs(state.delta) * frontier, parts.vertex_part_id,
            num_segments=parts.n_partitions,
        )
    mode = config.cds_mode
    sched = make_schedule(
        plan.engines, delta_mass, n_hub_partitions, mode, config.recompute_once,
    )

    # (5) asynchronous sweep in priority order
    state1, activated = _sweep(
        state, rt, program, plan.engines, sched.order, frontier,
        config.async_sweep, consume="all", use_kernels=use_kernels,
    )

    # (6) recompute-once: loaded priority partitions, zero extra transfer.
    engines2 = jnp.where(sched.second_pass, plan.engines, NONE)
    if program.peel_k is not None:
        # peeling re-relaxation would re-subtract the same removal counts
        # (double-count); an empty frontier makes pass 2 a harmless no-op
        frontier2 = jnp.zeros_like(frontier)
    elif program.combine == MIN:
        frontier2 = frontier | activated
    else:
        # |Δ|: pending deltas are non-negative on a cold start, but the
        # incremental path (repro.stream) injects *signed* correction
        # deltas after edge deletions — negative mass must propagate too.
        frontier2 = jnp.abs(state1.delta) > program.tolerance
    state2, activated2 = _sweep(
        state1, rt, program, engines2, sched.order, frontier2,
        config.async_sweep, consume="processed", use_kernels=use_kernels,
    )
    activated = activated | activated2

    # next frontier
    if program.peel_k is not None:
        # removal update: alive vertices whose remaining degree fell
        # below k are removed now and become the next round's frontier
        alive = state2.delta < 0.5
        newly = alive & (state2.values < program.peel_k)
        next_frontier = newly
        new_state = HyTMState(
            values=state2.values,
            delta=state2.delta + newly.astype(jnp.float32),
            frontier=next_frontier,
        )
    else:
        if program.combine == MIN:
            next_frontier = activated
        else:
            next_frontier = jnp.abs(state2.delta) > program.tolerance
        new_state = HyTMState(values=state2.values, delta=state2.delta,
                              frontier=next_frontier)

    per_engine_time, mispredictions = selection_diagnostics(
        plan.engines, plan.transfer_time, stats, plan.costs, correction,
    )

    info = {
        KEY_ENGINES: plan.engines,
        KEY_TRANSFER_BYTES: plan.transfer_bytes,
        KEY_TRANSFER_TIME: jnp.sum(plan.transfer_time)
        + plan.n_tasks.astype(jnp.float32) * config.link.launch_overhead_s,
        KEY_N_TASKS: plan.n_tasks,
        KEY_ACTIVE_VERTICES: jnp.sum(frontier.astype(jnp.int32)),
        KEY_ACTIVE_EDGES: jnp.sum(stats.active_edges),
        "next_active": jnp.sum(next_frontier.astype(jnp.int32)),
        KEY_PER_ENGINE_TIME: per_engine_time,
        KEY_MISPREDICTIONS: mispredictions,
    }
    return new_state, info


# Public per-dispatch entry: one jitted iteration (the K=1 driver and the
# vmapped service lanes dispatch through this).
hytm_iteration = partial(
    jax.jit, static_argnames=("program", "config", "n_hub_partitions"),
)(_iteration_impl)


# --------------------------------------------------------------------------
# Chunked device-resident driver
# --------------------------------------------------------------------------

@contextlib.contextmanager
def quiet_donation():
    """Scoped filter for jax's 'Some donated buffers were not usable'
    warning around a chunk dispatch: CPU backends cannot alias donated
    buffers, so on this container the donation (a device-side
    optimization — state/history update in place on GPU/TPU) would warn
    on every first dispatch.  Scoped, not global: other code's donation
    diagnostics stay visible."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        yield


def chunked_while(iter_fn, state: HyTMState, history: dict, chunk: int):
    """The shared ``lax.while_loop`` skeleton of every chunked driver
    (``hytm_chunk``, ``graph_shard.make_sharded_chunk``): run up to
    ``chunk`` iterations of ``iter_fn(state) -> (state, info)``, writing
    iteration ``i``'s info rows into ``history[k][i]`` and accumulating
    the (3,) per-engine modeled seconds, with the early-exit condition on
    the *previous* iteration's ``next_active`` (sentinel 1: the first
    iteration of a chunk always runs, matching the K=1 loop, which runs
    one iteration even on an empty frontier).

    Returns ``(state, history, n_done, last_next_active,
    per_engine_sum)``.  ``per_engine_sum`` rides in the carry so the
    online calibrator can observe the chunk *before* the history drain —
    the measured wall window then covers dispatch + execution only.
    """
    def cond(carry):
        _state, _hist, i, prev_active, _pe = carry
        return (i < chunk) & (prev_active != 0)

    def body(carry):
        st, hist, i, _prev, pe = carry
        new_st, info = iter_fn(st)
        hist = {k: hist[k].at[i].set(info[k]) for k in hist}
        return (new_st, hist, i + 1, info["next_active"],
                pe + info["per_engine_time"])

    init = (state, history, jnp.int32(0), jnp.int32(1),
            jnp.zeros(3, jnp.float32))
    state, history, n_done, last_active, pe_sum = jax.lax.while_loop(
        cond, body, init)
    return state, history, n_done, last_active, pe_sum


@partial(
    jax.jit,
    static_argnames=("program", "config", "n_hub_partitions", "chunk"),
    donate_argnames=("state", "history"),
)
def hytm_chunk(
    state: HyTMState,
    history: dict[str, jax.Array],   # key -> (chunk, ...) preallocated
    csr: DeviceCSR,
    parts: DevicePartitions,
    zc_req: jax.Array,
    inv_deg: jax.Array,
    program: VertexProgram,
    config: HyTMConfig,
    n_hub_partitions: int,
    chunk: int,
    correction: jax.Array | None = None,
) -> tuple[HyTMState, dict[str, jax.Array], jax.Array, jax.Array, jax.Array]:
    """Run up to ``chunk`` iterations inside one ``lax.while_loop``.

    Contract (the chunk/early-exit contract the chunked drivers share):

    * the loop body is exactly ``_iteration_impl`` — chunking changes how
      many iterations share a dispatch, never what an iteration computes;
    * the while-condition tests the *previous* iteration's
      ``next_active``, so the loop stops immediately after the converging
      iteration — a converged run never executes an iteration past
      convergence, and the iteration count is identical to the K=1 loop;
    * iteration ``i``'s info rows land in ``history[k][i]``; rows at
      index >= the returned ``n_done`` are stale garbage (possibly from a
      previous chunk through the same donated buffer) and must be sliced
      off when draining;
    * ``state`` and ``history`` are donated: on accelerators the
      values/Δ/frontier and history buffers update in place across
      chunks.  Callers must drain (``jax.device_get``) a returned history
      before feeding it back to the next chunk, which invalidates it.

    Returns ``(state, history, n_done, last_next_active,
    per_engine_sum)``; the host reads the scalars (one sync per chunk) to
    decide whether to dispatch another chunk and to feed the calibrator.
    """
    return chunked_while(
        lambda st: _iteration_impl(
            st, csr, parts, zc_req, inv_deg, program, config,
            n_hub_partitions, correction,
        ),
        state, history, chunk,
    )


@partial(
    jax.jit,
    static_argnames=("program", "config", "n_hub_partitions", "chunk"),
    donate_argnames=("state",),
)
def hytm_batched_chunk(
    state: HyTMState,        # (Q, n) lane-stacked
    csr: DeviceCSR,
    parts: DevicePartitions,
    zc_req: jax.Array,
    inv_deg: jax.Array,
    program: VertexProgram,
    config: HyTMConfig,
    n_hub_partitions: int,
    chunk: int,
    correction: jax.Array | None = None,
) -> tuple[HyTMState, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Chunked *lane-batched* sweep: up to ``chunk`` vmapped iterations of
    ``_iteration_impl`` inside one ``lax.while_loop`` dispatch, over a
    state whose leading dimension stacks Q independent source lanes.

    This is the dispatch unit of the serving stack (``repro.serve``):
    the carry holds the **per-lane** ``next_active`` vector, so the chunk
    returns ``lane_active`` — a ``(Q,)`` count of each lane's frontier
    population after its last executed iteration — instead of collapsing
    it to a batch total.  A lane whose entry is 0 has converged (its
    values are already its fixpoint; further iterations are no-ops for
    it), which is exactly the signal the continuous scheduler uses to
    free the lane's slot at the chunk boundary and backfill it from the
    request queue.  The while-condition sums the vector, preserving the
    chunk/early-exit contract of ``hytm_chunk``: the batch runs while any
    lane is still active, and stops the moment every frontier drains.

    Lanes never interact — ``jax.vmap`` evaluates the cost model, engine
    selection, schedule, and sweep per lane — so each lane's trajectory
    is bit-identical to its standalone ``run_hytm`` run for min-combine
    programs (tolerance-bounded for sum-combine), whatever the other
    lanes (including dead, all-``False``-frontier padding lanes) are
    doing.  The loop carries running reductions instead of history:
    summed per-engine modeled seconds and mispredictions, the
    calibrator's chunk-granular observation inputs.

    Returns ``(state, n_done, lane_active, per_engine_sum,
    mispred_sum)``.
    """
    def one(s):
        return _iteration_impl(
            s, csr, parts, zc_req, inv_deg, program, config,
            n_hub_partitions, correction,
        )

    def cond(carry):
        _s, i, lane_active, _pe, _mp = carry
        return (i < chunk) & (jnp.sum(lane_active) != 0)

    def body(carry):
        s, i, _prev, pe, mp = carry
        s2, info = jax.vmap(one)(s)
        return (
            s2,
            i + 1,
            info["next_active"],
            pe + jnp.sum(info["per_engine_time"], axis=0),
            mp + jnp.sum(info["mispredictions"]),
        )

    n_lanes = state.values.shape[0]
    # sentinel ones: the first iteration always runs, matching the K=1
    # loop (which runs one iteration even on an empty frontier)
    init = (state, jnp.int32(0), jnp.ones(n_lanes, jnp.int32),
            jnp.zeros(3, jnp.float32), jnp.int32(0))
    state, n_done, lane_active, pe_sum, mp_sum = jax.lax.while_loop(
        cond, body, init)
    return state, n_done, lane_active, pe_sum, mp_sum


def dead_lane_state(program: VertexProgram, n: int) -> tuple:
    """The (values, delta, frontier) triple of a *dead* padding lane: an
    all-``False`` frontier and zero pending Δ, so every iteration is a
    no-op for it — zero active edges, all engines NONE, no consumption,
    and a ``next_active`` of 0 from the first chunk on.  Used to pad a
    partial request batch up to the next static lane bucket
    (``repro.serve.scheduler``) so admission never changes the traced
    lane count."""
    return (
        jnp.zeros(n, jnp.float32) if program.use_delta
        else jnp.full(n, jnp.inf, jnp.float32),
        jnp.zeros(n, jnp.float32),
        jnp.zeros(n, dtype=bool),
    )


@contextlib.contextmanager
def count_driver_dispatches():
    """Count convergence-driver dispatches by swapping the module-global
    entry points (``run_hytm`` resolves both at call time, so the swap
    sees every dispatch).  Yields a live ``{"iteration": n, "chunk": n}``
    dict — the regression seam ``tests/test_chunked.py`` and
    ``benchmarks/iterloop.py --selfcheck`` share to prove the chunked
    loop really batches (chunk dispatches ≤ iterations/K + 1)."""
    mod = __import__("repro.core.hytm", fromlist=["hytm"])
    counts = {"iteration": 0, "chunk": 0}
    orig_iter, orig_chunk = mod.hytm_iteration, mod.hytm_chunk

    def count_iter(*a, **kw):
        counts["iteration"] += 1
        return orig_iter(*a, **kw)

    def count_chunk(*a, **kw):
        counts["chunk"] += 1
        return orig_chunk(*a, **kw)

    mod.hytm_iteration, mod.hytm_chunk = count_iter, count_chunk
    try:
        yield counts
    finally:
        mod.hytm_iteration, mod.hytm_chunk = orig_iter, orig_chunk


# Host-side registry of dispatch signatures that have already compiled:
# the first dispatch of a given (shapes, program, config) signature pays
# trace+compile, so its wall time must not feed the online calibrator.
# Mirrors the jit cache closely enough (module-level jits persist for the
# process lifetime) without reaching into private jax state.
_WARM_SIGNATURES: set = set()


def _consume_warm(signature, registry: set | None = None) -> bool:
    """True if ``signature`` was already dispatched (compiled) in this
    process; marks it warm either way.  ``registry`` overrides the
    module-level set — callers whose compiled function does NOT live for
    the process lifetime (the sharded drivers: a DeltaCSR
    merge-compaction rebuilds the jitted chunk with a fresh compile
    cache) scope the warm signatures to the function's own lifetime, so
    a rebuilt function's first dispatch is correctly cold even when its
    shapes were seen before."""
    reg = _WARM_SIGNATURES if registry is None else registry
    warm = signature in reg
    reg.add(signature)
    return warm


# --------------------------------------------------------------------------
# Convergence loop
# --------------------------------------------------------------------------

@dataclass
class HyTMResult:
    values: np.ndarray
    delta: np.ndarray
    iterations: int
    wall_seconds: float
    modeled_seconds: float
    total_transfer_bytes: float
    history: dict[str, np.ndarray]  # per-iteration arrays
    # second transfer-management level (sharded sweep only): modeled
    # cross-device merge traffic over config.ici_link.  Zero on the
    # single-device path.
    total_ici_bytes: float = 0.0
    modeled_ici_seconds: float = 0.0
    # autotune diagnostics: partitions where Algorithm 1 diverged from the
    # (corrected) modeled-best engine, summed over iterations, and the
    # final per-engine correction vector (None without config.autotune).
    total_mispredictions: int = 0
    engine_corrections: np.ndarray | None = None


def run_hytm(
    g: CSRGraph,
    program: VertexProgram,
    source: int | None = 0,
    config: HyTMConfig = HyTMConfig(),
    n_hubs: int = 0,
    runtime: Runtime | None = None,
    mesh=None,
    initial_state: HyTMState | None = None,
    calibrator=None,
    obs=None,
    faults=None,
    retry=None,
    on_chunk=None,
) -> HyTMResult:
    """``runtime`` lets callers amortize preprocessing across runs; with
    ``config.mesh_axis`` set it must be a ``graph_shard.ShardedRuntime``
    (reuse also keeps the compiled sharded sweep warm).

    ``config.vertex_sharding`` selects the sharded path's vertex-state
    layout: ``"replicated"`` (default, full ``(n,)`` triple per device,
    byte-identical to previous behavior) or ``"owner"`` (each device
    holds only its ``ceil(n/D)`` owned slice; boundary contributions are
    exchanged per iteration, charged on the ICI track via the halo-aware
    cost model).  Results, iteration counts, transfer bytes, and engine
    picks are identical between the two layouts — bit-identical for
    min-combine programs, tolerance-bounded for sum-combine.  Ignored on
    the single-device path.

    ``initial_state`` warm-starts the convergence loop from an arbitrary
    (values, Δ, frontier) triple instead of ``program.init_state`` — the
    entry point of the incremental path (repro.stream.incremental).  With
    both ``runtime`` and ``initial_state`` given, ``g`` may be ``None``.
    With ``config.sync_every > 1`` the state is *donated* to the chunked
    driver (``hytm_chunk``): on accelerator backends the caller's
    ``initial_state`` buffers are invalidated by the first chunk — pass a
    copy if they must survive the run.  Warm-start composes with
    ``config.mesh_axis``: the sharded driver replicates the triple over
    the mesh and resumes the shard_mapped chunk from it, bit-identical to
    the single-device ``async_sweep=False`` warm run for min-combine
    programs (``run_hytm_sharded``).

    ``calibrator``: an external ``repro.autotune.OnlineCalibrator`` to
    learn into (and start from) instead of a fresh per-run one — how
    ``GraphService`` keeps one feedback loop across queries.  Only read
    when ``config.autotune`` is set.

    ``obs``: an optional ``repro.obs.TraceRecorder``.  Per-iteration
    events and per-chunk spans are emitted host-side from the drained
    history rows (after the existing ``device_get`` syncs) plus one
    run-summary span whose totals equal the returned ``HyTMResult``
    fields exactly.  ``obs=None`` (the default) records nothing and runs
    the identical jit programs — the traced and untraced paths are
    bit-identical.

    ``faults``/``retry``: an optional ``repro.resilience.FaultPlan`` and
    ``RetryPolicy``.  Injected chunk-dispatch faults (site
    ``"chunk_dispatch"``) fire *before* the jit dispatch — donated
    buffers are still intact, so a retried dispatch is bit-identical.
    ``faults=None`` (the default) takes the unhooked code path exactly,
    mirroring the ``obs=None`` zero-overhead contract.

    ``on_chunk``: called at every chunk boundary (after the history
    drain, before the convergence check) with ``state`` (live device
    state), ``iterations``, ``rows`` (drained host history so far),
    ``calibrator``, and ``last_active`` — the attachment point for
    ``repro.resilience.CheckpointHook``.  Chunked driver only
    (``sync_every > 1``).
    """
    if config.mesh_axis is not None:
        # late import: graph_shard depends on this module's dataclasses
        from repro.dist.graph_shard import run_hytm_sharded

        return run_hytm_sharded(
            g, program, source=source, config=config, n_hubs=n_hubs,
            mesh=mesh, runtime=runtime, calibrator=calibrator,
            initial_state=initial_state, obs=obs, faults=faults,
            retry=retry, on_chunk=on_chunk,
        )
    if g is None and runtime is None:
        raise ValueError("run_hytm needs a graph or a prebuilt runtime")
    if runtime is None and program.symmetrize:
        # WCC-family programs are defined on the underlying undirected
        # graph; a prebuilt runtime is assumed already symmetrized
        g = g.symmetrize()
    rt = runtime if runtime is not None else build_runtime(
        g, config, n_hubs=n_hubs,
        weighted_norm=program.use_delta and program.weighted,
    )
    if initial_state is None:
        if program.peel_k is not None:
            # peeling seeds from the runtime's (symmetrized) out-degrees,
            # which init_state cannot see: values = remaining degree,
            # Δ = removed flag, frontier = the initially-removed set
            deg = rt.csr.out_degree.astype(jnp.float32)
            removed = deg < program.peel_k
            state = HyTMState(values=deg, delta=removed.astype(jnp.float32),
                              frontier=removed)
        else:
            values, delta, frontier = program.init_state(
                rt.csr.n_nodes, source)
            state = HyTMState(values=values, delta=delta, frontier=frontier)
    else:
        state = initial_state

    calib = None
    correction = None
    if config.autotune:
        from repro.autotune.feedback import OnlineCalibrator

        calib = (calibrator if calibrator is not None
                 else OnlineCalibrator(decay=config.autotune_decay))
        # start from the calibrator's current knowledge (identity when
        # fresh); always an array so the iteration traces once, not
        # twice (None -> array would retrace on iteration 2)
        correction = jnp.asarray(calib.correction(), jnp.float32)

    # raised (not asserted): under ``python -O`` an assert vanishes and a
    # zero/negative chunk size would silently run the wrong driver
    if config.sync_every < 1:
        raise ValueError(f"sync_every must be >= 1, got {config.sync_every}")
    if on_chunk is not None and config.sync_every == 1:
        raise ValueError(
            "on_chunk (checkpointing) requires the chunked driver — "
            "set sync_every >= 2")
    rows: dict[str, list] = {k: [] for k in HISTORY_KEYS}
    t0 = time.monotonic()
    iters = 0
    if config.sync_every > 1:
        # Chunked device-resident driver: one hytm_chunk dispatch per K
        # iterations, one host sync per chunk (n_done + history drain).
        shape_key = (
            program, config, rt.n_hub_partitions, rt.csr.n_nodes,
            rt.csr.edge_src.shape[0], rt.parts.n_partitions,
            rt.parts.block_size,
        )
        info_shapes = rt.info_shape_cache.get(shape_key)
        if info_shapes is None:
            info_shapes = jax.eval_shape(
                lambda s: _iteration_impl(
                    s, rt.csr, rt.parts, rt.zc_req, rt.inv_deg, program,
                    config, rt.n_hub_partitions, correction,
                ),
                state,
            )[1]
            rt.info_shape_cache[shape_key] = info_shapes
        history, cur_chunk = None, -1
        while iters < config.max_iters:
            chunk = min(config.sync_every, config.max_iters - iters)
            if chunk != cur_chunk:
                # allocated once (and for the rare max_iters tail);
                # otherwise the drained buffers cycle back in, so on
                # accelerators the donated memory is reused across chunks
                history = init_history_buffers(info_shapes, chunk)
                cur_chunk = chunk
            # the warm signature mirrors the jit cache key: statics +
            # every shape the trace specializes on (node/edge capacity,
            # partition grid) — a dispatch not seen here compiles, and
            # its wall time must not feed the calibrator
            warm = _consume_warm((
                "chunk", program, config, rt.n_hub_partitions, chunk,
                rt.csr.n_nodes, rt.csr.edge_src.shape[0],
                rt.parts.n_partitions, rt.parts.block_size,
                correction is not None,
            ))
            t_chunk = time.monotonic()
            if faults is None:
                with quiet_donation():
                    state, history, n_done, last_active, pe_sum = hytm_chunk(
                        state, history, rt.csr, rt.parts, rt.zc_req,
                        rt.inv_deg, program, config, rt.n_hub_partitions,
                        chunk, correction,
                    )
            else:
                # injected faults fire BEFORE the dispatch (see
                # resilience.supervisor) so the donated buffers of the
                # previous chunk are intact and a retry is bit-identical
                from repro.kernels.runtime import resolve_use_kernels
                from repro.resilience.supervisor import guarded_dispatch

                def _attempt(st=state, h=history, corr=correction):
                    with quiet_donation():
                        return hytm_chunk(
                            st, h, rt.csr, rt.parts, rt.zc_req,
                            rt.inv_deg, program, config,
                            rt.n_hub_partitions, chunk, corr,
                        )

                state, history, n_done, last_active, pe_sum = (
                    guarded_dispatch(
                        _attempt, site="chunk_dispatch", faults=faults,
                        policy=retry, obs=obs, mesh=False,
                        kernels=resolve_use_kernels(config.use_kernels),
                    ))
            n_done = int(n_done)
            iters += n_done
            if calib is not None:
                # observe BEFORE the history drain so the measured wall
                # window covers dispatch + execution only
                correction = calib.observe_chunk(
                    state.values, np.asarray(pe_sum, dtype=float),
                    t_chunk,
                    skip=not warm,  # a compiling chunk measures compile
                )
            # drain before the next dispatch donates these buffers; rows
            # past n_done are stale (early exit) and sliced off
            drained = jax.device_get(history)
            for k in rows:
                rows[k].append(drained[k][:n_done])
            if obs is not None:
                from repro.obs.record import record_chunk, record_history_rows

                record_history_rows(obs, drained, n_done, iters - n_done)
                record_chunk(
                    obs, track="device0",
                    wall_start=obs.wall_at(t_chunk),
                    wall_dur=obs.wall() - obs.wall_at(t_chunk),
                    start_iter=iters - n_done, n_done=n_done, warm=warm,
                )
            if on_chunk is not None:
                # chunk boundary: the drained rows are on host and the
                # next dispatch has not donated the state yet — the one
                # point a checkpoint can capture a resumable snapshot
                on_chunk(state=state, iterations=iters, rows=rows,
                         calibrator=calib, last_active=int(last_active))
            if int(last_active) == 0:
                break
        history = {k: np.concatenate(v) for k, v in rows.items()}
    else:
        # Legacy per-iteration driver (sync_every == 1): bit-for-bit the
        # pre-chunk dataflow.  History is staged as device references and
        # pulled once after convergence — the only per-iteration sync
        # left is the loop condition itself.
        for _ in range(config.max_iters):
            t_iter = time.monotonic()
            if faults is None:
                state, info = hytm_iteration(
                    state, rt.csr, rt.parts, rt.zc_req, rt.inv_deg,
                    program, config, rt.n_hub_partitions, correction,
                )
            else:
                from repro.kernels.runtime import resolve_use_kernels
                from repro.resilience.supervisor import guarded_dispatch

                def _attempt(st=state, corr=correction):
                    return hytm_iteration(
                        st, rt.csr, rt.parts, rt.zc_req, rt.inv_deg,
                        program, config, rt.n_hub_partitions, corr,
                    )

                state, info = guarded_dispatch(
                    _attempt, site="chunk_dispatch", faults=faults,
                    policy=retry, obs=obs, mesh=False,
                    kernels=resolve_use_kernels(config.use_kernels),
                )
            iters += 1
            if calib is not None:
                correction = calib.observe_iteration(
                    state.values, info["per_engine_time"], t_iter,
                    skip=iters == 1,  # iteration 1 measures compile
                )
            for k in rows:
                rows[k].append(info[k])
            if int(info["next_active"]) == 0:
                break
        staged = jax.device_get(rows)  # one host conversion, post-hoc
        history = {k: np.stack(v) for k, v in staged.items()}
        if obs is not None:
            from repro.obs.record import record_history_rows

            record_history_rows(obs, history, iters, 0)
    jax.block_until_ready(state.values)
    wall = time.monotonic() - t0
    result = HyTMResult(
        values=np.asarray(state.values),
        delta=np.asarray(state.delta),
        iterations=iters,
        wall_seconds=wall,
        modeled_seconds=float(np.sum(history[KEY_TRANSFER_TIME])),
        total_transfer_bytes=float(np.sum(history[KEY_TRANSFER_BYTES])),
        history=history,
        total_mispredictions=int(np.sum(history[KEY_MISPREDICTIONS])),
        engine_corrections=(
            calib.correction() if calib is not None else None
        ),
    )
    if obs is not None:
        from repro.obs.record import record_run

        record_run(
            obs, result, track="device0", wall_start=obs.wall_at(t0),
            wall_dur=wall, program=program.name,
        )
    return result
