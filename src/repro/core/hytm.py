"""HyTM engine orchestration — ties cost model, task generation, and
asynchronous scheduling into the iterate-until-convergence loop (paper
Fig. 5: cost-aware task generation <-> asynchronous task scheduling).

One *iteration* is a single jitted function:

  1. per-partition activity stats      (segment reductions, on device)
  2. cost model + engine selection     (Eqs. 1-3, Algorithm 1)
  3. task combination                  (merged task count -> launch overhead)
  4. priority schedule                 (hub / delta contribution-driven order)
  5. asynchronous sweep                (scan over partitions in priority
     order; each partition relaxes through its selected engine against the
     *current* values — later partitions see earlier updates)
  6. recompute-once second pass        (loaded priority partitions, no
     additional transfer)

The convergence loop runs on host (the per-iteration frontier population
is the loop condition — the same device->host sync real GPU frameworks
do), collecting the per-iteration history that feeds the Fig-7 execution
path, Table-VI transfer volume, and Table-V runtime analyses.
"""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.constants import PCIE3, TPU_V5E_ICI, LinkModel
from repro.core.cost_model import (
    COMPACT,
    FILTER,
    NONE,
    ZEROCOPY,
    partition_stats,
    selection_diagnostics,
    zc_request_counts,
)
from repro.core.engines import EdgeBlock, relax_with_engine
from repro.core.partition import (
    DevicePartitions,
    PartitionTable,
    partition_graph,
    to_device_partitions,
)
from repro.core.scheduler import make_schedule
from repro.core.task_generation import TaskPlan, forced_engine_plan, generate_tasks
from repro.graph.algorithms import MIN, SUM, VertexProgram
from repro.graph.csr import CSRGraph, DeviceCSR, to_device_csr


@dataclass(frozen=True)
class HyTMConfig:
    link: LinkModel = PCIE3
    n_partitions: int | None = None
    partition_bytes: int = 32 * 2**20  # paper default: 32 MB partitions
    async_sweep: bool = True
    cds_mode: str = "hub"  # 'hub' | 'delta' | 'none'
    enable_task_combination: bool = True
    recompute_once: bool = True
    combine_k: int = 4
    max_iters: int = 10_000
    forced_engine: int | None = None  # force a single engine (baselines)
    hub_fraction: float = 0.08
    # Second transfer-management level (DESIGN.md §2): the link model used
    # to charge the cross-device merge of the sharded sweep.  Only read on
    # the mesh_axis path; the single-device run reports zero ICI traffic.
    ici_link: LinkModel = TPU_V5E_ICI
    # Online autotuning (repro.autotune.feedback): per-iteration measured
    # sweep times feed an EWMA per-engine correction factor that rescales
    # the Algorithm-1 selection costs (and the sharded path's ICI-level
    # exchange choice).  Transfer *accounting* stays in model units; the
    # engines are semantically interchangeable, so results are unchanged
    # — only which engine pays for each partition moves.
    autotune: bool = False
    autotune_decay: float = 0.25  # EWMA forgetting factor of the calibrator
    # Name of a 1-D mesh axis to shard the partition edge blocks over
    # (repro.dist.graph_shard).  None = the single-device path below
    # (note: the sync-sweep SUM consumption fix in ``_sweep`` changed
    # async_sweep=False results relative to older revisions; the default
    # async path is untouched).  The sharded sweep is bulk-synchronous
    # across devices, so it reproduces the single-device
    # ``async_sweep=False`` dataflow exactly.
    mesh_axis: str | None = None


@jax.tree_util.register_dataclass
@dataclass
class HyTMState:
    values: jax.Array   # (n,) f32
    delta: jax.Array    # (n,) f32 (accumulative programs)
    frontier: jax.Array  # (n,) bool


@dataclass
class Runtime:
    """Device-resident inputs shared by every iteration."""

    csr: DeviceCSR
    parts: DevicePartitions
    zc_req: jax.Array          # (n,) float32
    inv_deg: jax.Array         # (n,) float32 — 1/max(deg,1) (or 1/sum(w)
                               # for weighted accumulative programs: PHP)
    n_hub_partitions: int


def build_runtime(
    g: CSRGraph, config: HyTMConfig, n_hubs: int = 0, weighted_norm: bool = False
) -> Runtime:
    table: PartitionTable = partition_graph(
        g, n_partitions=config.n_partitions,
        partition_bytes=config.partition_bytes, d1=config.link.d1,
    )
    block = int(table.edges_per_partition.max(initial=1))
    block = max(128, -(-block // 128) * 128)
    capacity = -(-(g.n_edges + block) // 128) * 128
    csr = to_device_csr(g, capacity=capacity)
    parts = to_device_partitions(table, g.n_nodes, capacity)
    assert parts.block_size <= block
    zc_req = zc_request_counts(csr.out_degree, csr.seg_start, config.link)
    if weighted_norm:
        # accumulative programs over weighted edges (PHP) push
        # delta * w_ij / sum_j w_ij — normalize by weighted out-degree so
        # total mass is non-expanding.
        wsum = jax.ops.segment_sum(
            jnp.where(csr.edge_valid, csr.edge_weight, 0.0),
            csr.edge_src, num_segments=g.n_nodes,
        )
        inv_deg = 1.0 / jnp.maximum(wsum, 1e-30)
    else:
        inv_deg = 1.0 / jnp.maximum(csr.out_degree.astype(jnp.float32), 1.0)
    n_hub_parts = int(np.searchsorted(np.asarray(table.vertex_start), n_hubs, side="left"))
    n_hub_parts = max(n_hub_parts, 1) if n_hubs > 0 else 0
    return Runtime(
        csr=csr, parts=parts, zc_req=zc_req, inv_deg=inv_deg,
        n_hub_partitions=n_hub_parts,
    )


# --------------------------------------------------------------------------
# One iteration (jitted)
# --------------------------------------------------------------------------

def _slice_block(arr: jax.Array, start: jax.Array, size: int) -> jax.Array:
    return jax.lax.dynamic_slice_in_dim(arr, start, size)


def _sweep(
    state: HyTMState,
    rt: Runtime,
    program: VertexProgram,
    engines: jax.Array,       # (P,) — NONE entries are skipped
    order: jax.Array,         # (P,) processing order
    frontier: jax.Array,      # (n,) sources active for this sweep
    async_sweep: bool,
    consume: str,             # 'all' (pass 1: every partition is visited)
                              # | 'processed' (pass 2: only loaded ones)
) -> tuple[HyTMState, jax.Array]:
    """Scan partitions in priority order; returns new state + activated."""
    n = rt.csr.n_nodes
    B = rt.parts.block_size
    values0, delta0 = state.values, state.delta

    def body(carry, p):
        values, delta, activated = carry
        eng = engines[p]
        start = rt.parts.edge_start[p]
        local = jnp.arange(B, dtype=jnp.int32)
        in_range = local < rt.parts.part_edges[p]
        src = _slice_block(rt.csr.edge_src, start, B)
        dst = _slice_block(rt.csr.edge_dst, start, B)
        w = _slice_block(rt.csr.edge_weight, start, B)
        processed = eng != NONE
        active_lane = frontier[src] & in_range & processed
        block = EdgeBlock(src=src, dst=dst, weight=w, active=active_lane)

        if program.combine == SUM:
            dsrc = delta if async_sweep else delta0
            operand = program.damping * dsrc * rt.inv_deg
        else:
            operand = values if async_sweep else values0

        out = relax_with_engine(eng, block, operand, n, program)

        if program.combine == MIN:
            improved = out.touched & (out.agg < values)
            values = jnp.where(improved, out.agg, values)
            activated = activated | improved
        else:
            # consumption (rank += delta) is vertex-local compute on
            # accelerator-resident vertex data — it happens for every
            # active vertex of the partition even when the partition has
            # no active *edges* to transfer (deg-0 vertices would
            # otherwise hold their delta forever and never converge).
            in_part = rt.parts.vertex_part_id == p
            if consume == "all":
                consumed = frontier & in_part
            else:  # pass 2 touches only the re-processed partitions
                consumed = frontier & in_part & processed
            # value absorbs the consumed delta; pending delta resets, then
            # accumulates fresh contributions from this partition's edges.
            if async_sweep:
                values = values + jnp.where(consumed, delta, 0.0)
                delta = jnp.where(consumed, 0.0, delta) + out.agg
            else:
                # synchronous dataflow: only the iteration-start delta0 is
                # consumed, so subtract exactly that — zeroing the running
                # delta would drop contributions already delivered by
                # earlier partitions (order-dependent mass loss).  This
                # makes the sync sweep partition-order invariant, which is
                # the single-device oracle the sharded sweep
                # (repro.dist.graph_shard) must match bit-for-bit.
                values = values + jnp.where(consumed, delta0, 0.0)
                delta = jnp.where(consumed, delta - delta0, delta) + out.agg
            activated = activated | out.touched
        return (values, delta, activated), None

    init = (values0, delta0, jnp.zeros(n, dtype=bool))
    (values, delta, activated), _ = jax.lax.scan(body, init, order)
    return HyTMState(values=values, delta=delta, frontier=state.frontier), activated


@partial(
    jax.jit,
    static_argnames=("program", "config", "n_hub_partitions"),
)
def hytm_iteration(
    state: HyTMState,
    csr: DeviceCSR,
    parts: DevicePartitions,
    zc_req: jax.Array,
    inv_deg: jax.Array,
    program: VertexProgram,
    config: HyTMConfig,
    n_hub_partitions: int,
    correction: jax.Array | None = None,
) -> tuple[HyTMState, dict[str, Any]]:
    rt = Runtime(csr=csr, parts=parts, zc_req=zc_req, inv_deg=inv_deg,
                 n_hub_partitions=n_hub_partitions)
    n = csr.n_nodes
    frontier = state.frontier

    # (1-3) stats -> costs -> engines -> combined tasks
    stats = partition_stats(frontier, csr.out_degree, zc_req, parts)
    if config.forced_engine is None:
        plan: TaskPlan = generate_tasks(
            stats, config.link, combine_k=config.combine_k,
            enable_combination=config.enable_task_combination,
            correction=correction,
        )
    else:
        plan = forced_engine_plan(
            stats, config.link, config.forced_engine,
            enable_combination=config.enable_task_combination,
            combine_k=config.combine_k,
        )

    # (4) contribution-driven priority schedule
    delta_mass = jax.ops.segment_sum(
        jnp.abs(state.delta) * frontier, parts.vertex_part_id,
        num_segments=parts.n_partitions,
    )
    mode = config.cds_mode
    sched = make_schedule(
        plan.engines, delta_mass, n_hub_partitions, mode, config.recompute_once,
    )

    # (5) asynchronous sweep in priority order
    state1, activated = _sweep(
        state, rt, program, plan.engines, sched.order, frontier,
        config.async_sweep, consume="all",
    )

    # (6) recompute-once: loaded priority partitions, zero extra transfer.
    engines2 = jnp.where(sched.second_pass, plan.engines, NONE)
    if program.combine == MIN:
        frontier2 = frontier | activated
    else:
        # |Δ|: pending deltas are non-negative on a cold start, but the
        # incremental path (repro.stream) injects *signed* correction
        # deltas after edge deletions — negative mass must propagate too.
        frontier2 = jnp.abs(state1.delta) > program.tolerance
    state2, activated2 = _sweep(
        state1, rt, program, engines2, sched.order, frontier2,
        config.async_sweep, consume="processed",
    )
    activated = activated | activated2

    # next frontier
    if program.combine == MIN:
        next_frontier = activated
    else:
        next_frontier = jnp.abs(state2.delta) > program.tolerance
    new_state = HyTMState(values=state2.values, delta=state2.delta, frontier=next_frontier)

    per_engine_time, mispredictions = selection_diagnostics(
        plan.engines, plan.transfer_time, stats, plan.costs, correction,
    )

    info = {
        "engines": plan.engines,
        "transfer_bytes": plan.transfer_bytes,
        "transfer_time": jnp.sum(plan.transfer_time)
        + plan.n_tasks.astype(jnp.float32) * config.link.launch_overhead_s,
        "n_tasks": plan.n_tasks,
        "active_vertices": jnp.sum(frontier.astype(jnp.int32)),
        "active_edges": jnp.sum(stats.active_edges),
        "next_active": jnp.sum(next_frontier.astype(jnp.int32)),
        "per_engine_time": per_engine_time,
        "mispredictions": mispredictions,
    }
    return new_state, info


# --------------------------------------------------------------------------
# Convergence loop
# --------------------------------------------------------------------------

@dataclass
class HyTMResult:
    values: np.ndarray
    delta: np.ndarray
    iterations: int
    wall_seconds: float
    modeled_seconds: float
    total_transfer_bytes: float
    history: dict[str, np.ndarray]  # per-iteration arrays
    # second transfer-management level (sharded sweep only): modeled
    # cross-device merge traffic over config.ici_link.  Zero on the
    # single-device path.
    total_ici_bytes: float = 0.0
    modeled_ici_seconds: float = 0.0
    # autotune diagnostics: partitions where Algorithm 1 diverged from the
    # (corrected) modeled-best engine, summed over iterations, and the
    # final per-engine correction vector (None without config.autotune).
    total_mispredictions: int = 0
    engine_corrections: np.ndarray | None = None


def run_hytm(
    g: CSRGraph,
    program: VertexProgram,
    source: int | None = 0,
    config: HyTMConfig = HyTMConfig(),
    n_hubs: int = 0,
    runtime: Runtime | None = None,
    mesh=None,
    initial_state: HyTMState | None = None,
    calibrator=None,
) -> HyTMResult:
    """``runtime`` lets callers amortize preprocessing across runs; with
    ``config.mesh_axis`` set it must be a ``graph_shard.ShardedRuntime``
    (reuse also keeps the compiled sharded sweep warm).

    ``initial_state`` warm-starts the convergence loop from an arbitrary
    (values, Δ, frontier) triple instead of ``program.init_state`` — the
    entry point of the incremental path (repro.stream.incremental).  With
    both ``runtime`` and ``initial_state`` given, ``g`` may be ``None``.

    ``calibrator``: an external ``repro.autotune.OnlineCalibrator`` to
    learn into (and start from) instead of a fresh per-run one — how
    ``GraphService`` keeps one feedback loop across queries.  Only read
    when ``config.autotune`` is set.
    """
    if config.mesh_axis is not None:
        assert initial_state is None, "sharded path has no warm-start yet"
        # late import: graph_shard depends on this module's dataclasses
        from repro.dist.graph_shard import run_hytm_sharded

        return run_hytm_sharded(
            g, program, source=source, config=config, n_hubs=n_hubs,
            mesh=mesh, runtime=runtime, calibrator=calibrator,
        )
    rt = runtime if runtime is not None else build_runtime(
        g, config, n_hubs=n_hubs,
        weighted_norm=program.use_delta and program.weighted,
    )
    if initial_state is None:
        values, delta, frontier = program.init_state(rt.csr.n_nodes, source)
        state = HyTMState(values=values, delta=delta, frontier=frontier)
    else:
        state = initial_state

    calib = None
    correction = None
    if config.autotune:
        from repro.autotune.feedback import OnlineCalibrator

        calib = (calibrator if calibrator is not None
                 else OnlineCalibrator(decay=config.autotune_decay))
        # start from the calibrator's current knowledge (identity when
        # fresh); always an array so the iteration traces once, not
        # twice (None -> array would retrace on iteration 2)
        correction = jnp.asarray(calib.correction(), jnp.float32)

    hist: dict[str, list] = {
        "engines": [], "transfer_bytes": [], "transfer_time": [],
        "active_vertices": [], "active_edges": [], "n_tasks": [],
        "mispredictions": [],
    }
    t0 = time.monotonic()
    iters = 0
    for _ in range(config.max_iters):
        t_iter = time.monotonic()
        state, info = hytm_iteration(
            state, rt.csr, rt.parts, rt.zc_req, rt.inv_deg,
            program, config, rt.n_hub_partitions, correction,
        )
        iters += 1
        if calib is not None:
            correction = calib.observe_iteration(
                state.values, info["per_engine_time"], t_iter,
                skip=iters == 1,  # iteration 1 measures compile, not sweep
            )
        for k in hist:
            hist[k].append(np.asarray(info[k]))
        if int(info["next_active"]) == 0:
            break
    jax.block_until_ready(state.values)
    wall = time.monotonic() - t0

    history = {k: np.stack(v) if np.ndim(v[0]) else np.asarray(v) for k, v in hist.items()}
    return HyTMResult(
        values=np.asarray(state.values),
        delta=np.asarray(state.delta),
        iterations=iters,
        wall_seconds=wall,
        modeled_seconds=float(np.sum(history["transfer_time"])),
        total_transfer_bytes=float(np.sum(history["transfer_bytes"])),
        history=history,
        total_mispredictions=int(np.sum(history["mispredictions"])),
        engine_corrections=(
            calib.correction() if calib is not None else None
        ),
    )
