"""HyTM core — the paper's contribution (cost model, engines, scheduling)."""

from repro.core.constants import PCIE3, TPU_V5E_HBM, TPU_V5E_ICI, LinkModel
from repro.core.cost_model import COMPACT, FILTER, NONE, ZEROCOPY
from repro.core.hytm import HyTMConfig, HyTMResult, build_runtime, run_hytm

__all__ = [
    "PCIE3", "TPU_V5E_HBM", "TPU_V5E_ICI", "LinkModel",
    "COMPACT", "FILTER", "NONE", "ZEROCOPY",
    "HyTMConfig", "HyTMResult", "build_runtime", "run_hytm",
]
