"""Asynchronous task scheduling — paper §VI.

Two pieces:

1. **Contribution-driven priority** (§VI-A).  The processing *order* of
   partitions within an iteration matters because the sweep is
   asynchronous (later partitions read values already improved by earlier
   ones).  Priorities:
     * ``hub``  — hub-vertex-driven: after hub sorting, hub vertices live
       in the lowest partition ids, so "hubs first" == ascending id.
     * ``delta`` — Δ-driven (for accumulative programs): partitions with
       the largest pending |Δ| mass first.
   The paper schedules FILTER tasks first (they carry the priority), then
   ZC / COMPACT tasks (§VI-B).

2. **Recompute-once** (§VI-A): loaded (FILTER/COMPACT) priority partitions
   are processed one extra time per iteration — data is already resident,
   so the second pass costs no transfer (ZC partitions are excluded:
   zero-copy has no reuse, §II-C).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.cost_model import COMPACT, FILTER


class Schedule(NamedTuple):
    order: jax.Array          # (P,) permutation: processing order
    second_pass: jax.Array    # (P,) bool — partitions re-processed once


def _rank(keys: jax.Array) -> jax.Array:
    """Dense rank of each element under ascending sort (stable)."""
    order = jnp.argsort(keys, stable=True)
    ranks = jnp.zeros_like(order)
    return ranks.at[order].set(jnp.arange(order.shape[0], dtype=order.dtype))


def make_schedule(
    engines: jax.Array,        # (P,)
    delta_mass: jax.Array,     # (P,) pending |delta| per partition
    n_hub_partitions: int,
    mode: str,                 # 'hub' | 'delta' | 'none'
    recompute_once: bool,
    second_pass_fraction: float = 0.125,
    pid_offset: jax.Array | int = 0,
    priority_mask: jax.Array | None = None,
) -> Schedule:
    """``pid_offset`` shifts local partition indices to global ids so a
    device scheduling its shard of the partition space (graph_shard)
    ranks hubs consistently with the single-device schedule.  The
    delta-mode priority mask is a *global* top-fraction rank a device
    cannot derive from its local |Δ| slice alone — the sharded path
    precomputes it on the replicated state and passes it in via
    ``priority_mask`` (which then overrides the locally computed one)."""
    P = engines.shape[0]
    pid = pid_offset + jnp.arange(P, dtype=jnp.int32)

    if mode == "delta":
        score = delta_mass
        if priority_mask is None:
            priority_mask = _rank(-delta_mass) < max(1, int(P * second_pass_fraction))
    elif mode == "hub":
        score = -pid.astype(jnp.float32)  # low id == hub partitions first
        if priority_mask is None:
            priority_mask = pid < n_hub_partitions
    else:
        score = jnp.zeros(P, dtype=jnp.float32)
        if priority_mask is None:
            priority_mask = jnp.zeros(P, dtype=bool)

    # Engine tier: FILTER first (paper §VI-B), then ZC/COMPACT, skips last.
    tier = jnp.where(engines == FILTER, 0, jnp.where(engines >= 0, 1, 2))
    key = tier.astype(jnp.int32) * (2 * P) + _rank(-score).astype(jnp.int32)
    order = jnp.argsort(key, stable=True).astype(jnp.int32)

    loaded = (engines == FILTER) | (engines == COMPACT)
    second = priority_mask & loaded if recompute_once else jnp.zeros(P, dtype=bool)
    return Schedule(order=order, second_pass=second)
