from repro.kernels.hyb_gather.ops import hyb_gather

__all__ = ["hyb_gather"]
