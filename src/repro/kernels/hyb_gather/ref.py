"""Pure-jnp oracle for the per-vertex neighbour gather."""

import jax
import jax.numpy as jnp

from repro.kernels.hyb_gather.hyb_gather import PAD


def hyb_gather_ref(edges: jax.Array, seg_start: jax.Array, degree: jax.Array):
    e = jnp.pad(edges, ((0, PAD), (0, 0)))
    idx = seg_start[:, None] + jnp.arange(PAD)[None, :]
    out = e[idx]                                        # (a, PAD, c)
    lane = jnp.arange(PAD)[None, :, None]
    return jnp.where(lane < degree[:, None, None], out, 0).astype(edges.dtype)
