from __future__ import annotations

import jax

from repro.kernels.hyb_gather.hyb_gather import PAD, hyb_gather_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def hyb_gather(edges: jax.Array, seg_start: jax.Array, degree: jax.Array):
    """Gather each active vertex's neighbour window (zero-copy engine).
    Returns (a, PAD, c); lanes past the vertex degree are zeroed.
    Vertices with degree > PAD are split by the scheduler upstream."""
    squeeze = False
    if edges.ndim == 1:
        edges, squeeze = edges[:, None], True
    out = hyb_gather_pallas(edges, seg_start, degree, interpret=not _on_tpu())
    return out[..., 0] if squeeze else out
