from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.hyb_gather.hyb_gather import PAD, hyb_gather_pallas
from repro.kernels.runtime import interpret_mode


def hyb_gather(edges: jax.Array, seg_start: jax.Array, degree: jax.Array):
    """Gather each active vertex's neighbour window (zero-copy engine).
    Returns (a, PAD, c); lanes past the vertex degree are zeroed.
    Vertices with degree > PAD are split by the scheduler upstream.
    An empty frontier (``a == 0``) returns the empty (0, PAD, c) tensor
    without launching the kernel (a 0-step grid has nothing to DMA)."""
    squeeze = False
    if edges.ndim == 1:
        edges, squeeze = edges[:, None], True
    if seg_start.shape[0] == 0:
        out = jnp.zeros((0, PAD, edges.shape[1]), edges.dtype)
    else:
        out = hyb_gather_pallas(
            edges, seg_start, degree, interpret=interpret_mode())
    return out[..., 0] if squeeze else out
