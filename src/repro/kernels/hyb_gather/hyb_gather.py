"""Per-vertex neighbour-segment gather Pallas kernel — the ZEROCOPY engine.

EMOGI's zero-copy issues one fine-grained memory request per (vertex,
cache line); the TPU analogue is one DMA descriptor per neighbour
segment, issued straight against the HBM-resident edge array (DESIGN.md
§2).  The kernel:

* scalar-prefetches the active vertices' segment starts/degrees (the
  compacted frontier produced by `frontier_compact` or the scheduler),
* per grid step, DMAs one vertex's neighbour window
  ``edges[start : start + PAD]`` into a VMEM block (`pl.load` with a
  dynamic slice == one descriptor; misaligned starts cost the extra
  transaction the cost model's am(v) term charges),
* masks lanes past the vertex's true degree.

Output is the (n_active, PAD, c) padded neighbour tensor the downstream
relax kernel consumes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

PAD = 128  # neighbour window per vertex (one (8,128) tile row group)


def _kernel(starts_ref, degs_ref, edges_ref, out_ref):
    vi = pl.program_id(0)
    start = starts_ref[vi]
    deg = degs_ref[vi]
    window = pl.load(edges_ref, (pl.ds(start, PAD), slice(None)))  # one DMA
    lane = jax.lax.broadcasted_iota(jnp.int32, window.shape, 0)
    out_ref[0] = jnp.where(lane < deg, window, 0).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def hyb_gather_pallas(
    edges: jax.Array,       # (m_pad, c) edge fields, HBM resident
    seg_start: jax.Array,   # (a,) int32 segment starts of active vertices
    degree: jax.Array,      # (a,) int32
    interpret: bool = True,
) -> jax.Array:
    a = seg_start.shape[0]
    c = edges.shape[1]
    # stay in-bounds for the fixed-size window DMA
    edges = jnp.pad(edges, ((0, PAD), (0, 0)))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(a,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((1, PAD, c), lambda vi, starts, degs: (vi, 0, 0)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((a, PAD, c), edges.dtype),
        interpret=interpret,
    )(seg_start.astype(jnp.int32), degree.astype(jnp.int32), edges)
    return out
