"""Capacity-grouped expert GEMM Pallas kernel — the MoE hot spot.

After the sorted (compaction-engine) dispatch, tokens for expert e occupy
rows ``[starts[e], starts[e] + counts[e])`` of the sorted activation
buffer.  The kernel runs a (n_experts, n_row_tiles) grid: each step DMAs
one (TILE_T, D) token tile from a *dynamic* row offset (scalar-prefetched
group starts), multiplies by that expert's (D, F) weight block on the
MXU, and masks rows past the group count.  Empty tiles are skipped with
``pl.when`` — the paper's "skip inactive partitions" applied to experts.

max_rows_per_expert bounds the per-expert tile count (== capacity).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE_T = 128


def _kernel(starts_ref, counts_ref, x_ref, zero_ref, w_ref, out_ref, *, n_tiles):
    del zero_ref  # aliased to out_ref: guarantees untouched rows are zero
    e = pl.program_id(0)
    ti = pl.program_id(1)
    start = starts_ref[e]
    count = counts_ref[e]

    @pl.when(ti * TILE_T < count)
    def _work():
        x = pl.load(x_ref, (pl.ds(start + ti * TILE_T, TILE_T), slice(None)))
        lane = jax.lax.broadcasted_iota(jnp.int32, (TILE_T, 1), 0)
        x = jnp.where(lane + ti * TILE_T < count, x, 0)
        y = jax.lax.dot_general(
            x.astype(jnp.float32), w_ref[0].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        pl.store(
            out_ref, (pl.ds(start + ti * TILE_T, TILE_T), slice(None)),
            y.astype(out_ref.dtype),
        )

    del n_tiles


@functools.partial(jax.jit, static_argnames=("interpret",))
def grouped_matmul_pallas(
    x_sorted: jax.Array,   # (T, D) tokens sorted by expert
    weights: jax.Array,    # (E, D, F)
    starts: jax.Array,     # (E,) int32 group starts
    counts: jax.Array,     # (E,) int32 group sizes
    interpret: bool = True,
) -> jax.Array:
    T, D = x_sorted.shape
    E, _, F = weights.shape
    t_pad = -(-T // TILE_T) * TILE_T
    x = jnp.pad(x_sorted, ((0, t_pad - T + TILE_T), (0, 0)))
    n_tiles = t_pad // TILE_T

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(E, n_tiles),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((1, D, F), lambda e, ti, starts, counts: (e, 0, 0)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
    )
    zeros = jnp.zeros((t_pad + TILE_T, F), x_sorted.dtype)
    out = pl.pallas_call(
        functools.partial(_kernel, n_tiles=n_tiles),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t_pad + TILE_T, F), x_sorted.dtype),
        # rows outside every group keep the zero initialization
        input_output_aliases={3: 0},
        interpret=interpret,
    )(starts.astype(jnp.int32), counts.astype(jnp.int32), x, zeros, weights)
    return out[:T]
