"""Oracle: per-expert dense matmul over sorted groups."""

import jax.numpy as jnp


def grouped_matmul_ref(x_sorted, weights, starts, counts):
    T, D = x_sorted.shape
    E, _, F = weights.shape
    rows = jnp.arange(T)
    # expert id per row from group ranges
    eid = jnp.sum(rows[:, None] >= (starts + counts)[None, :], axis=1)
    eid = jnp.clip(eid, 0, E - 1)
    in_group = (rows >= starts[eid]) & (rows < starts[eid] + counts[eid])
    w_rows = weights[eid]                      # (T, D, F)
    y = jnp.einsum("td,tdf->tf", x_sorted.astype(jnp.float32), w_rows.astype(jnp.float32))
    return jnp.where(in_group[:, None], y, 0.0).astype(x_sorted.dtype)
