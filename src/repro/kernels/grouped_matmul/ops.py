from __future__ import annotations

from repro.kernels.grouped_matmul.grouped_matmul import grouped_matmul_pallas
from repro.kernels.runtime import interpret_mode


def grouped_matmul(x_sorted, weights, starts, counts):
    """Megablocks-style grouped GEMM over expert-sorted tokens."""
    return grouped_matmul_pallas(
        x_sorted, weights, starts, counts, interpret=interpret_mode()
    )
