from __future__ import annotations

import jax

from repro.kernels.grouped_matmul.grouped_matmul import grouped_matmul_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def grouped_matmul(x_sorted, weights, starts, counts):
    """Megablocks-style grouped GEMM over expert-sorted tokens."""
    return grouped_matmul_pallas(
        x_sorted, weights, starts, counts, interpret=not _on_tpu()
    )
