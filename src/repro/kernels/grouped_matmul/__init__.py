from repro.kernels.grouped_matmul.ops import grouped_matmul

__all__ = ["grouped_matmul"]
