from __future__ import annotations

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.runtime import interpret_mode


def flash_attention(q, k, v, scale=None, window=0, causal=True):
    """Fused attention over (BH, S, dh) tensors (heads pre-flattened)."""
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    return flash_attention_pallas(
        q, k, v, float(scale), int(window), bool(causal),
        interpret=interpret_mode(),
    )
