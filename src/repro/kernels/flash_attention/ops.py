from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, scale=None, window=0, causal=True):
    """Fused attention over (BH, S, dh) tensors (heads pre-flattened)."""
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    return flash_attention_pallas(
        q, k, v, float(scale), int(window), bool(causal), interpret=not _on_tpu()
    )
