"""Pure-jnp oracle: dense masked attention."""

import jax.numpy as jnp


def flash_attention_ref(q, k, v, scale, window=0, causal=True):
    s = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    S, L = s.shape[1], s.shape[2]
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(L)[None, :]
    mask = jnp.ones((S, L), bool)
    if causal:
        mask &= qp >= kp
    if window > 0:
        mask &= qp - kp < window
    s = jnp.where(mask[None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask[None], p, 0.0)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bst,btd->bsd", p, v.astype(jnp.float32)).astype(q.dtype)
