"""Fused attention Pallas kernel (FlashAttention-2 forward) with causal +
sliding-window masking — the LM-family compute hot spot.

Grid: (batch*heads, n_q_blocks, n_kv_blocks); TPU executes the kv axis
sequentially, so the online-softmax state (m, l) and the output
accumulator live in VMEM scratch and flush on the last kv step.  Blocks
are (TILE_Q, dh) / (TILE_K, dh) with dh lane-padded to 128.

Training uses the pure-jnp custom-VJP oracle in models/attention.py (the
same recurrence); this kernel is the serving/prefill fast path and the
allclose target for the tests' shape x dtype sweep.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE_Q = 256
TILE_K = 256
NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, window, causal):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale       # (TILE_Q, dh)
    k = k_ref[0].astype(jnp.float32)               # (TILE_K, dh)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                              # (TILE_Q, TILE_K)

    q_pos = qi * TILE_Q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * TILE_K + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones(s.shape, dtype=jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG)

    m_prev = m_ref[...]                            # (TILE_Q, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "window", "causal", "interpret")
)
def flash_attention_pallas(
    q: jax.Array,   # (BH, S, dh)
    k: jax.Array,   # (BH, L, dh)
    v: jax.Array,   # (BH, L, dh)
    scale: float,
    window: int = 0,
    causal: bool = True,
    interpret: bool = True,
) -> jax.Array:
    BH, S, dh = q.shape
    L = k.shape[1]
    s_pad = -(-S // TILE_Q) * TILE_Q
    l_pad = -(-L // TILE_K) * TILE_K
    d_pad = -(-dh // 128) * 128
    qp = jnp.pad(q, ((0, 0), (0, s_pad - S), (0, d_pad - dh)))
    kp = jnp.pad(k, ((0, 0), (0, l_pad - L), (0, d_pad - dh)))
    vp = jnp.pad(v, ((0, 0), (0, l_pad - L), (0, d_pad - dh)))

    grid = (BH, s_pad // TILE_Q, l_pad // TILE_K)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, window=window, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, TILE_Q, d_pad), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, TILE_K, d_pad), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, TILE_K, d_pad), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, TILE_Q, d_pad), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, s_pad, d_pad), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((TILE_Q, 1), jnp.float32),
            pltpu.VMEM((TILE_Q, 1), jnp.float32),
            pltpu.VMEM((TILE_Q, d_pad), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :S, :dh]
