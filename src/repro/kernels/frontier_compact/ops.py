from __future__ import annotations

import jax

from repro.kernels.frontier_compact.frontier_compact import frontier_compact_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def frontier_compact(values: jax.Array, mask: jax.Array):
    """Compact rows of ``values`` where ``mask`` is set to a dense prefix.
    Returns (compacted (m, c), count)."""
    squeeze = False
    if values.ndim == 1:
        values, squeeze = values[:, None], True
    out, cnt = frontier_compact_pallas(values, mask, interpret=not _on_tpu())
    return (out[:, 0] if squeeze else out), cnt
