from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.frontier_compact.frontier_compact import frontier_compact_pallas
from repro.kernels.runtime import interpret_mode


def frontier_compact(values: jax.Array, mask: jax.Array):
    """Compact rows of ``values`` where ``mask`` is set to a dense prefix.
    Returns (compacted (m, c), count).  ``count == 0`` (empty frontier)
    is well-defined: the output tail is unspecified, the count is 0."""
    squeeze = False
    if values.ndim == 1:
        values, squeeze = values[:, None], True
    if values.shape[0] == 0:
        # zero rows: the (TILE,)-blocked grid cannot slice an empty
        # operand, and a 0-step grid would leave the count uninitialized.
        out, cnt = values, jnp.int32(0)
    else:
        out, cnt = frontier_compact_pallas(
            values, mask, interpret=interpret_mode())
    return (out[:, 0] if squeeze else out), cnt
