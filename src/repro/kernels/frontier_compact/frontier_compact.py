"""Stream-compaction Pallas kernel — the COMPACTION engine.

The paper's ExpTM-compaction removes inactive edges on the CPU before the
PCIe transfer.  On TPU the pass runs on-device (DESIGN.md §2): a single
sequential sweep over (TILE, c) edge tiles that

  1. computes each kept lane's local rank with an in-tile cumsum,
  2. permutes kept lanes to the tile front with a one-hot matmul
     (gather/scatter as MXU compute — no atomics needed),
  3. appends the dense prefix at the running offset via a dynamic store
     (HBM DMA with data-dependent destination), carrying the offset in
     SMEM across grid steps (TPU grids are sequential).

Because later tiles overwrite earlier tiles' padding, the output is the
dense compacted stream; the total count lands in the (1,) count output.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE = 512


def _kernel(mask_ref, val_ref, out_ref, cnt_ref, off_ref):
    bi = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(bi == 0)
    def _init():
        off_ref[0] = 0

    mask = mask_ref[...]                       # (TILE,)
    vals = val_ref[...]                        # (TILE, c)
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1
    kept = pos[TILE - 1] + 1
    # one-hot permutation: lane i -> output lane pos[i] (kept lanes only)
    onehot = (
        (pos[:, None] == jax.lax.broadcasted_iota(jnp.int32, (TILE, TILE), 1))
        & mask[:, None]
    ).astype(vals.dtype)
    tile = jax.lax.dot_general(
        onehot, vals, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(vals.dtype)                       # (TILE, c) dense prefix

    off = off_ref[0]
    pl.store(out_ref, (pl.ds(off, TILE), slice(None)), tile)
    off_ref[0] = off + kept

    @pl.when(bi == nb - 1)
    def _fin():
        cnt_ref[0] = off_ref[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def frontier_compact_pallas(
    values: jax.Array,   # (m, c) packed edge fields
    mask: jax.Array,     # (m,) bool
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    m, c = values.shape
    m_pad = -(-m // TILE) * TILE
    vals = jnp.pad(values, ((0, m_pad - m), (0, 0)))
    msk = jnp.pad(mask, (0, m_pad - m), constant_values=False)

    out, cnt = pl.pallas_call(
        _kernel,
        grid=(m_pad // TILE,),
        in_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE, c), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),   # whole output: dynamic stores
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_pad + TILE, c), values.dtype),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(msk, vals)
    return out[:m], cnt[0]
