from repro.kernels.frontier_compact.ops import frontier_compact

__all__ = ["frontier_compact"]
