"""Pure-jnp oracle for stream compaction."""

import jax
import jax.numpy as jnp


def frontier_compact_ref(values: jax.Array, mask: jax.Array):
    """Stable compaction: kept rows move to the front (original order),
    the tail is unspecified (compared only up to `count` in tests)."""
    order = jnp.argsort(~mask, stable=True)
    return values[order], jnp.sum(mask.astype(jnp.int32))
