"""Shared kernel-runtime policy: one backend check, one `use_kernels`
contract.

Every kernel package's public wrapper (``kernels/*/ops.py``) dispatches
the same way — compiled Pallas on TPU backends, interpret mode elsewhere
— and ``HyTMConfig.use_kernels``'s ``"auto"`` mode consults the *same*
backend check, so a backend-detection fix lands exactly once.  (The six
wrappers used to carry copy-pasted private ``_on_tpu`` helpers; any fix
had to be applied in six places and the copies could drift.)

The ``use_kernels`` tri-state:

* ``"auto"`` (default) — kernels on iff the default backend is TPU: the
  compiled Pallas path is where the raw speed lives (GraphCage-style
  tiled kernels), while on CPU/GPU backends interpret mode would only
  add overhead to the pure-JAX oracles.
* ``True``  — force the kernel path (interpret mode off-TPU): the
  equivalence tests and the CI roofline gate run the real kernel bodies
  on CPU this way.
* ``False`` — force the pure-JAX oracle engines.
"""

from __future__ import annotations

import jax


def on_tpu() -> bool:
    """True when the default jax backend is a TPU — the one place the
    kernel wrappers and ``use_kernels="auto"`` check the backend."""
    return jax.default_backend() == "tpu"


def interpret_mode() -> bool:
    """Pallas ``interpret=`` default for the current backend."""
    return not on_tpu()


def resolve_use_kernels(setting: bool | str) -> bool:
    """Resolve ``HyTMConfig.use_kernels`` to a concrete (trace-time) bool.

    ``"auto"`` -> :func:`on_tpu`; booleans pass through.  Raises on any
    other string so a typo ('atuo') cannot silently disable the kernels.
    """
    if isinstance(setting, str):
        if setting != "auto":
            raise ValueError(
                f"use_kernels must be True, False, or 'auto', got {setting!r}")
        return on_tpu()
    return bool(setting)
