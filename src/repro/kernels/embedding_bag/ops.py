from __future__ import annotations

from repro.kernels.embedding_bag.embedding_bag import embedding_bag_pallas
from repro.kernels.runtime import interpret_mode


def embedding_bag(table, indices, mode="sum"):
    """(V,D) table x (B,L) bags -> (B,D) reduced embeddings, fused."""
    return embedding_bag_pallas(
        table, indices, mode=mode, interpret=interpret_mode())
