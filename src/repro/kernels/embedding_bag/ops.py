from __future__ import annotations

import jax

from repro.kernels.embedding_bag.embedding_bag import embedding_bag_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def embedding_bag(table, indices, mode="sum"):
    """(V,D) table x (B,L) bags -> (B,D) reduced embeddings, fused."""
    return embedding_bag_pallas(table, indices, mode=mode, interpret=not _on_tpu())
