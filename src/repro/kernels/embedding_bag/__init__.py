from repro.kernels.embedding_bag.ops import embedding_bag

__all__ = ["embedding_bag"]
