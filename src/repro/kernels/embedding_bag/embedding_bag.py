"""Fused embedding-bag Pallas kernel — the DLRM lookup hot spot.

JAX has no ``nn.EmbeddingBag``; the jnp substrate builds it from take +
segment_sum (models/embedding.py).  This kernel fuses the two against the
HBM-resident table: per grid step it processes one bag tile, issuing one
row-DMA per (bag, slot) lookup (``pl.load`` with a dynamic row slice —
the zero-copy access pattern) and reducing in a VMEM accumulator, so the
gathered rows never round-trip through HBM.

Indices are scalar-prefetched (they drive the DMA descriptors).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE_B = 8  # bags per grid step


def _kernel(idx_ref, table_ref, out_ref, *, bag_size, mode):
    bi = pl.program_id(0)

    def bag_body(b, _):
        def slot_body(s, acc):
            row_id = idx_ref[(bi * TILE_B + b) * bag_size + s]
            row = pl.load(table_ref, (pl.ds(row_id, 1), slice(None)))  # one DMA
            row = row.astype(jnp.float32)
            if mode == "max":
                return jnp.maximum(acc, row)
            return acc + row

        init = jnp.full((1, table_ref.shape[1]), -jnp.inf if mode == "max" else 0.0, jnp.float32)
        acc = jax.lax.fori_loop(0, bag_size, slot_body, init)
        if mode == "mean":
            acc = acc / bag_size
        pl.store(out_ref, (pl.ds(b, 1), slice(None)), acc.astype(out_ref.dtype))
        return _

    jax.lax.fori_loop(0, TILE_B, bag_body, 0)


@functools.partial(jax.jit, static_argnames=("mode", "interpret"))
def embedding_bag_pallas(
    table: jax.Array,     # (V, D)
    indices: jax.Array,   # (B, L) int32
    mode: str = "sum",
    interpret: bool = True,
) -> jax.Array:
    B, L = indices.shape
    V, D = table.shape
    b_pad = -(-B // TILE_B) * TILE_B
    d_pad = -(-D // 128) * 128
    idx = jnp.pad(indices, ((0, b_pad - B), (0, 0))).reshape(-1)
    tbl = jnp.pad(table, ((0, 0), (0, d_pad - D)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b_pad // TILE_B,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((TILE_B, d_pad), lambda bi, idx: (bi, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, bag_size=L, mode=mode),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b_pad, d_pad), table.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), tbl)
    return out[:B, :D]
