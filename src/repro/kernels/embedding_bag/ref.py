"""Oracle: models/embedding.py gather engine."""

from repro.models.embedding import embedding_bag as _bag


def embedding_bag_ref(table, indices, mode="sum"):
    return _bag(table, indices, mode=mode, engine="gather")
