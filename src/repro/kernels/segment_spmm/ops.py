"""Public wrapper: interpret=True on CPU (this container), compiled
Pallas on TPU backends (backend policy: ``repro.kernels.runtime``)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.runtime import interpret_mode
from repro.kernels.segment_spmm.segment_spmm import segment_spmm_pallas


def segment_spmm(
    messages: jax.Array,
    seg_ids: jax.Array,
    n_segments: int,
    valid: jax.Array | None = None,
    combine: str = "sum",
) -> jax.Array:
    """Segment-combine (m, d) messages into (n_segments, d) — the filter
    engine's blocked aggregation.

    ``combine``: ``"sum"`` (scatter-add as MXU matmul) or ``"min"``
    (traversal combiners; segments receiving no valid message hold
    ``+inf``, the min identity, exactly like ``jax.ops.segment_min``).
    ``n_segments`` may exceed every observed ``seg_ids`` entry — the
    extra segments come back as the combiner identity.
    """
    if valid is None:
        valid = jnp.ones(messages.shape[0], dtype=bool)
    squeeze = False
    if messages.ndim == 1:
        messages, squeeze = messages[:, None], True
    if messages.shape[0] == 0:
        # zero edges: the tiled grid would need a 0-row block slice
        # (degenerate BlockSpec); the combine identity is the answer.
        identity = jnp.inf if combine == "min" else 0.0
        out = jnp.full((n_segments, messages.shape[1]), identity,
                       messages.dtype)
    else:
        out = segment_spmm_pallas(
            messages, seg_ids, valid, n_segments, combine=combine,
            interpret=interpret_mode(),
        )
    return out[:, 0] if squeeze else out
