"""Public wrapper: interpret=True on CPU (this container), compiled
Pallas on TPU backends."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.segment_spmm.segment_spmm import segment_spmm_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def segment_spmm(
    messages: jax.Array,
    seg_ids: jax.Array,
    n_segments: int,
    valid: jax.Array | None = None,
) -> jax.Array:
    """Segment-sum (m, d) messages into (n_segments, d) — the filter
    engine's blocked aggregation."""
    if valid is None:
        valid = jnp.ones(messages.shape[0], dtype=bool)
    squeeze = False
    if messages.ndim == 1:
        messages, squeeze = messages[:, None], True
    out = segment_spmm_pallas(
        messages, seg_ids, valid, n_segments, interpret=not _on_tpu()
    )
    return out[:, 0] if squeeze else out
