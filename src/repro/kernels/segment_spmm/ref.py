"""Pure-jnp oracle for the blocked segment-SpMM kernel."""

import jax
import jax.numpy as jnp


def segment_spmm_ref(
    messages: jax.Array,  # (m, d) per-edge messages (dst-sorted NOT required)
    seg_ids: jax.Array,   # (m,) destination ids
    n_segments: int,
    valid: jax.Array | None = None,  # (m,) bool
    combine: str = "sum",
) -> jax.Array:
    if combine == "min":
        if valid is not None:
            messages = jnp.where(valid[:, None], messages, jnp.inf)
        return jax.ops.segment_min(messages, seg_ids, num_segments=n_segments)
    if valid is not None:
        messages = jnp.where(valid[:, None], messages, 0.0)
    return jax.ops.segment_sum(messages, seg_ids, num_segments=n_segments)
