from repro.kernels.segment_spmm.ops import segment_spmm

__all__ = ["segment_spmm"]
