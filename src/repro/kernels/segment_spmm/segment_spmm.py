"""Blocked segment-SpMM Pallas kernel — the FILTER engine's compute core.

The paper's filter engine streams whole partitions over the slow link and
masks inactive edges in compute.  TPU adaptation (DESIGN.md §2):

* edge messages arrive as an (m, d) stream, tiled (TILE_E, d) through
  VMEM with lane-aligned blocks — the ``cudaMemcpy``-style saturated
  contiguous DMA;
* destination combining cannot use atomics (TPU has none); instead each
  tile builds a one-hot (TILE_E, TILE_N) routing matrix and reduces with
  ONE MXU matmul: ``partial = onehot^T @ messages`` — scatter-add
  re-expressed as systolic compute, the TPU-native idiom;
* the grid is (n_out_blocks, n_edge_tiles); TPU grids execute
  sequentially, so each output block accumulates across edge tiles in a
  fp32 VMEM scratch accumulator and flushes on the last tile.

Inactive lanes (``valid=False``: filter-engine masked edges / padding)
contribute zero rows through the same matmul.

Traversal combiners (``combine="min"``) cannot ride the matmul (a sum),
so the min variant routes each tile through an explicit masked
select-and-reduce over a (TILE_E_MIN, TILE_N, d) broadcast — VPU, not
MXU, with a smaller edge tile bounding the 3-D intermediate in VMEM —
and accumulates with ``minimum`` into a ``+inf``-initialized scratch.
``min`` of a fixed value multiset is order-independent, which is what
makes the kernel-backed FILTER engine *bit-identical* to
``jax.ops.segment_min`` (the engine oracle): segments receiving no valid
message flush the ``+inf`` identity, exactly like the oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE_E = 512      # edges per tile (sum mode: one-hot MXU matmul)
TILE_E_MIN = 128  # edges per tile (min mode: 3-D select bound to VMEM)
TILE_N = 128      # output segments per block (lane-aligned)


def _kernel_sum(seg_ref, valid_ref, msg_ref, out_ref, acc_ref):
    oi = pl.program_id(0)   # output block index
    ei = pl.program_id(1)   # edge tile index
    n_edge_tiles = pl.num_programs(1)

    @pl.when(ei == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seg = seg_ref[...]        # (TILE_E,)
    valid = valid_ref[...]    # (TILE_E,)
    msg = msg_ref[...]        # (TILE_E, d)

    base = oi * TILE_N
    local = seg - base
    in_block = (local >= 0) & (local < TILE_N) & valid
    # one-hot routing matrix (TILE_E, TILE_N): scatter-add as MXU matmul
    onehot = (
        (local[:, None] == jax.lax.broadcasted_iota(jnp.int32, (TILE_E, TILE_N), 1))
        & in_block[:, None]
    ).astype(msg.dtype)
    acc_ref[...] += jax.lax.dot_general(
        onehot, msg,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(acc_ref.dtype)

    @pl.when(ei == n_edge_tiles - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def _kernel_min(seg_ref, valid_ref, msg_ref, out_ref, acc_ref):
    oi = pl.program_id(0)
    ei = pl.program_id(1)
    n_edge_tiles = pl.num_programs(1)

    @pl.when(ei == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, jnp.inf)

    seg = seg_ref[...]        # (TILE_E_MIN,)
    valid = valid_ref[...]    # (TILE_E_MIN,)
    msg = msg_ref[...]        # (TILE_E_MIN, d)

    base = oi * TILE_N
    local = seg - base
    in_block = (local >= 0) & (local < TILE_N) & valid
    route = (
        (local[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (TILE_E_MIN, TILE_N), 1))
        & in_block[:, None]
    )
    # masked select keeps ±inf messages intact (0 * inf = NaN rules the
    # matmul idiom out for min); non-routed lanes contribute the identity
    contrib = jnp.min(
        jnp.where(route[:, :, None], msg[:, None, :].astype(jnp.float32),
                  jnp.inf),
        axis=0,
    )  # (TILE_N, d)
    acc_ref[...] = jnp.minimum(acc_ref[...], contrib.astype(acc_ref.dtype))

    @pl.when(ei == n_edge_tiles - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_segments", "combine", "interpret"))
def segment_spmm_pallas(
    messages: jax.Array,   # (m, d)
    seg_ids: jax.Array,    # (m,) int32
    valid: jax.Array,      # (m,) bool
    n_segments: int,
    combine: str = "sum",
    interpret: bool = True,
) -> jax.Array:
    if combine not in ("sum", "min"):
        raise ValueError(f"combine must be 'sum' or 'min', got {combine!r}")
    tile_e = TILE_E if combine == "sum" else TILE_E_MIN
    kernel = _kernel_sum if combine == "sum" else _kernel_min
    m, d = messages.shape
    m_pad = -(-m // tile_e) * tile_e
    n_pad = -(-n_segments // TILE_N) * TILE_N
    d_pad = -(-d // 128) * 128
    msg = jnp.pad(messages, ((0, m_pad - m), (0, d_pad - d)))
    seg = jnp.pad(seg_ids.astype(jnp.int32), (0, m_pad - m), constant_values=-1)
    val = jnp.pad(valid, (0, m_pad - m), constant_values=False)

    grid = (n_pad // TILE_N, m_pad // tile_e)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_e,), lambda oi, ei: (ei,)),
            pl.BlockSpec((tile_e,), lambda oi, ei: (ei,)),
            pl.BlockSpec((tile_e, d_pad), lambda oi, ei: (ei, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_N, d_pad), lambda oi, ei: (oi, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d_pad), messages.dtype),
        scratch_shapes=[pltpu.VMEM((TILE_N, d_pad), jnp.float32)],
        interpret=interpret,
    )(seg, val, msg)
    return out[:n_segments, :d]
