"""Pallas TPU kernels for the HyTM engines' compute hot spots.

Each kernel directory ships three files:
  <name>.py — pl.pallas_call + BlockSpec VMEM tiling (the TPU target)
  ops.py    — jit'd public wrapper (interpret=True on CPU)
  ref.py    — pure-jnp oracle the tests sweep against

Kernel -> engine map (DESIGN.md §2):
  segment_spmm     — FILTER engine compute core: dense (8,128)-tiled edge
                     streaming + one-hot-matmul segment reduction (the
                     TPU-native replacement for GPU atomics)
  frontier_compact — COMPACTION engine: sequential-grid stream compaction
                     with an SMEM running offset (the paper's CPU pass,
                     on-device)
  hyb_gather       — ZEROCOPY engine: per-vertex neighbour-segment DMA
                     (EMOGI's merged/aligned accesses, as DMA descriptors)
  flash_attention  — LM hot spot (causal + sliding window fwd)
  embedding_bag    — DLRM hot spot (fused gather + bag reduce)
  grouped_matmul   — MoE hot spot (capacity-grouped expert GEMM)
"""
