"""Distributed execution: sharding rule DSL + multi-device HyTM sweep.

``repro.dist.sharding``    — regex/path PartitionSpec rules -> NamedSharding
                             pytrees for the model/optimizer/cache trees.
``repro.dist.graph_shard`` — the HyTM edge-block sweep shard_mapped over a
                             1-D ``graph`` mesh axis (see HyTMConfig.mesh_axis).
"""

from repro.dist.sharding import (
    batch_axes,
    dlrm_rule,
    fit_spec,
    gnn_data_spec,
    gnn_rule,
    lm_batch_spec,
    lm_cache_rule,
    lm_rule,
    spec_for,
    tree_shardings,
)

__all__ = [
    "batch_axes",
    "dlrm_rule",
    "fit_spec",
    "gnn_data_spec",
    "gnn_rule",
    "lm_batch_spec",
    "lm_cache_rule",
    "lm_rule",
    "spec_for",
    "tree_shardings",
]
