"""Multi-device HyTM: the partition sweep shard_mapped over a 1-D mesh.

Scale-out story (Totem / Garaph lineage): HyTGraph's unit of transfer
management — the edge-balanced partition — is also the natural unit of
*distribution*.  Each device owns a contiguous shard of the partition
space as a ``(P_local, B)`` blocked edge array; vertex state (values,
pending Δ, frontier) is replicated, the per-iteration flow is:

  1. partition activity stats + Δ mass        (replicated, O(P))
  2. per-device cost model + engine selection (Algorithm 1 on the local
     stats shard — selection is per-partition, so the local result equals
     the single-device one)
  3. per-device priority schedule over its local partitions (hub ids are
     globalized with the device's partition offset; the Δ-mode top-K
     second-pass mask is a global rank, precomputed on replicated state)
  4. local sweep over the local blocks, then one collective merge:
     ``pmin`` for traversal combiners, ``psum`` for accumulative ones —
     the frontier/Δ exchange of the two-level HyTM
  5. recompute-once second pass over loaded priority partitions, merged
     the same way.

The cross-device sweep is **bulk-synchronous**: every device relaxes
against the iteration-start state and updates merge once per pass.  That
makes the sharded run reproduce the single-device ``async_sweep=False``
dataflow exactly — bit-for-bit for min-combiners, up to float-summation
order for sum-combiners — which is the equivalence contract
``tests/test_distributed.py`` checks on forced-host meshes.

Engine semantics are unchanged: each local partition still relaxes
through its selected FILTER/COMPACT/ZEROCOPY engine via ``lax.switch``,
so the cost model's per-partition decisions (and the modeled transfer
accounting) are identical to the single-device run.

Second level (DESIGN.md §2): the cross-device merge is itself
transfer-managed *in the model* — ``ici_level_cost`` selects per
iteration between a dense all-reduce (filter analogue) and a compacted
active-entry exchange (compact analogue) over ``HyTMConfig.ici_link``,
optionally reweighed by the online-feedback correction
(``HyTMConfig.autotune``, repro.autotune).  The executed collective
stays the bulk-synchronous pmin/psum merge, preserving the oracle
equivalence contract.

Vertex-state layout (``HyTMConfig.vertex_sharding``): by default the
(values, Δ, frontier) triple is **replicated** — every device holds the
full ``(n,)`` vectors, the per-device memory ceiling.  With ``"owner"``
the triple is **owner-sharded** (Totem's owner/halo split): the node
count pads to ``n_pad = ceil(n/D)*D``, device ``d`` owns the contiguous
slice ``[d*n_loc, (d+1)*n_loc)`` and holds only it, and each sweep pass
(a) all-gathers the frontier/operand shards into the full view its local
edge blocks read (the halo fill), (b) relaxes locally exactly as before,
and (c) merges back to owned slices — ``pmin`` + owned-slice extraction
for min-combiners (bit-exact: the same elementwise pmin, sliced), a
tiled ``psum_scatter`` for sum-combiners.  Per-device state drops
~D-fold (``cost_model.vertex_state_bytes``); the boundary-vertex counts
a compacted exchange would actually ship are precomputed host-side as a
:class:`HaloPlan`, and ``halo_level_cost`` caps the ICI level's
compacted candidate at the halo size so the two-level cost model (and
the autotune corrections steering it) charge the owner layout's real
exchange.  Results stay bit-identical to the single-device
``async_sweep=False`` oracle for min-combiners and tolerance-bounded for
sum-combiners, exactly like the replicated layout.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.cost_model import (
    COMPACT,
    FILTER,
    HISTORY_KEYS,
    KEY_ACTIVE_EDGES,
    KEY_ACTIVE_VERTICES,
    KEY_ENGINES,
    KEY_ICI_BYTES,
    KEY_ICI_ENGINE,
    KEY_ICI_TIME,
    KEY_MERGED_ENTRIES,
    KEY_MISPREDICTIONS,
    KEY_N_TASKS,
    KEY_PER_ENGINE_TIME,
    KEY_TRANSFER_BYTES,
    KEY_TRANSFER_TIME,
    NONE,
    engine_costs,
    init_history_buffers,
    partition_stats,
    select_engines,
    selection_diagnostics,
    zc_request_counts,
)
from repro.core.engines import EdgeBlock, relax_with_engine
from repro.kernels.runtime import resolve_use_kernels
from repro.core.hytm import (
    HyTMConfig,
    HyTMResult,
    HyTMState,
    _consume_warm,
    chunked_while,
    quiet_donation,
)
from repro.core.partition import (
    DevicePartitions,
    PartitionTable,
    partition_graph,
)
from repro.core.scheduler import make_schedule
from repro.core.task_generation import forced_engine_plan, generate_tasks
from repro.graph.algorithms import MIN, SUM, VertexProgram
from repro.graph.csr import CSRGraph


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class BlockedEdges:
    """Partition-blocked COO edges, padded to a static (P, B) grid.

    Row ``p`` holds partition ``p``'s edge segment; lanes past
    ``part_edges[p]`` are padding (masked via ``in_range``).  This is the
    array that shards over the graph mesh axis.
    """

    src: jax.Array       # (P, B) int32
    dst: jax.Array       # (P, B) int32
    weight: jax.Array    # (P, B) float32
    in_range: jax.Array  # (P, B) bool


@dataclass(frozen=True)
class HaloPlan:
    """Host-side owner/halo layout of one sharded runtime.

    Device ``d`` owns the contiguous vertex slice
    ``[d*n_loc, (d+1)*n_loc)`` of the ``n_pad = n_loc*D``-padded id
    space; its *halo* is the set of vertices outside that slice which
    its local edge blocks reference (as source or destination) — the
    boundary entries a compacted owner-layout exchange would ship.
    Rebuilt whenever the edge-block grid changes (build, refill, patch,
    merge-compaction / ``layout_version`` bumps)."""

    n_pad: int
    n_loc: int
    halo_counts: tuple     # (D,) ints: unique boundary vertices per device
    halo_total: int

    @property
    def max_halo(self) -> int:
        return max(self.halo_counts) if self.halo_counts else 0


def build_halo_plan(
    src: np.ndarray, dst: np.ndarray, valid: np.ndarray,
    n_nodes: int, n_devices: int,
) -> HaloPlan:
    """Build the owner/halo plan from the host-side ``(P_total, B)``
    blocked-edge grids (rows ``[d*P_local, (d+1)*P_local)`` live on
    device ``d``)."""
    n_loc = -(-n_nodes // n_devices)
    n_pad = n_loc * n_devices
    P_total = src.shape[0]
    P_local = P_total // n_devices
    counts = []
    for d in range(n_devices):
        rows = slice(d * P_local, (d + 1) * P_local)
        v = np.asarray(valid[rows], bool)
        refs = np.unique(np.concatenate(
            [np.asarray(src[rows])[v], np.asarray(dst[rows])[v]]
        )) if v.any() else np.empty(0, np.int64)
        lo, hi = d * n_loc, (d + 1) * n_loc
        counts.append(int(np.count_nonzero((refs < lo) | (refs >= hi))))
    return HaloPlan(n_pad=n_pad, n_loc=n_loc, halo_counts=tuple(counts),
                    halo_total=int(sum(counts)))


@dataclass
class ShardedRuntime:
    """Device-placed inputs shared by every sharded iteration."""

    mesh: jax.sharding.Mesh
    axis: str
    blocks: BlockedEdges       # sharded: P(axis, None)
    parts: DevicePartitions    # replicated (vertex_part_id drives stats)
    out_degree: jax.Array      # (n,) int32, replicated
    zc_req: jax.Array          # (n,) float32, replicated
    inv_deg: jax.Array         # (n,) float32, replicated
    n_nodes: int
    n_partitions: int          # padded: multiple of mesh.shape[axis]
    n_hub_partitions: int
    # Vertex-state layout (HyTMConfig.vertex_sharding).  "owner": state
    # vectors are logically (n_pad,) and owner-sharded P(axis) — each
    # device stores its (n_loc,) owned slice — and the per-vertex runtime
    # vectors above are replicated but padded to (n_pad,) with inert
    # values (out_degree 0, zc_req 0, inv_deg 1, vertex_part_id P-1).
    # "replicated" keeps today's (n,) layout byte-identical; n_pad ==
    # n_nodes and halo is None.
    vertex_sharding: str = "replicated"
    n_pad: int = 0
    halo: HaloPlan | None = None
    # (program, config[, chunk]) -> jitted iteration/chunk; reusing a
    # runtime across run_hytm_sharded calls reuses the compiled sweep
    # instead of retracing a fresh shard_map closure every run.  The
    # device buffers above are *arguments* of the compiled functions, not
    # baked-in constants, so a holder (DeltaCSR's sharded view) may swap
    # them between calls — same shapes reuse the compiled sweep, changed
    # shapes (merge-compaction) re-specialize through the jit cache.
    iteration_cache: dict = field(default_factory=dict, repr=False)


def _pad_table(table: PartitionTable, n_dev: int) -> PartitionTable:
    """Append empty partitions so the partition count divides the mesh."""
    P_real = table.n_partitions
    P_pad = -(-P_real // n_dev) * n_dev
    if P_pad == P_real:
        return table
    extra = P_pad - P_real
    vs = np.concatenate([table.vertex_start, np.full(extra, table.vertex_start[-1])])
    es = np.concatenate([table.edge_start, np.full(extra, table.edge_start[-1])])
    return PartitionTable(vertex_start=vs.astype(np.int64), edge_start=es.astype(np.int64))


def build_sharded_runtime(
    g: CSRGraph,
    config: HyTMConfig,
    mesh: jax.sharding.Mesh,
    n_hubs: int = 0,
    weighted_norm: bool = False,
) -> ShardedRuntime:
    axis = config.mesh_axis
    if axis not in mesh.axis_names:
        # a raised guard, not an assert: under ``python -O`` an assert
        # vanishes and the sweep would shard over a nonexistent axis
        raise ValueError(
            f"config.mesh_axis={axis!r} is not an axis of the mesh "
            f"(axes: {mesh.axis_names})")
    n_dev = int(mesh.shape[axis])

    table = _pad_table(
        partition_graph(
            g, n_partitions=config.n_partitions,
            partition_bytes=config.partition_bytes, d1=config.link.d1,
        ),
        n_dev,
    )
    P_total = table.n_partitions
    epp = table.edges_per_partition
    B = int(epp.max(initial=1))
    B = max(128, -(-B // 128) * 128)

    # host-side blocking: copy each partition's edge slice into its row
    src_all = g.edge_sources()
    dst_all = g.indices
    w_all = g.weights if g.weights is not None else np.ones(g.n_edges, np.float32)
    src = np.zeros((P_total, B), np.int32)
    dst = np.zeros((P_total, B), np.int32)
    w = np.full((P_total, B), np.float32(np.inf), np.float32)
    in_range = np.zeros((P_total, B), bool)
    for p in range(P_total):
        e0, e1 = int(table.edge_start[p]), int(table.edge_start[p + 1])
        k = e1 - e0
        src[p, :k] = src_all[e0:e1]
        dst[p, :k] = dst_all[e0:e1]
        w[p, :k] = w_all[e0:e1]
        in_range[p, :k] = True

    part_id = np.repeat(
        np.arange(P_total, dtype=np.int32), table.vertices_per_partition
    )

    row = NamedSharding(mesh, P(axis, None))
    rep = NamedSharding(mesh, P())
    blocks = BlockedEdges(
        src=jax.device_put(src, row),
        dst=jax.device_put(dst, row),
        weight=jax.device_put(w, row),
        in_range=jax.device_put(in_range, row),
    )

    out_degree = jnp.asarray(g.out_degrees, jnp.int32)
    seg_start = jnp.asarray(g.indptr[:-1], jnp.int32)
    zc_req = zc_request_counts(out_degree, seg_start, config.link)
    if weighted_norm:
        wsum = np.zeros(g.n_nodes, np.float64)
        np.add.at(wsum, src_all, w_all)
        inv_deg = jnp.asarray(1.0 / np.maximum(wsum, 1e-30), jnp.float32)
    else:
        inv_deg = 1.0 / jnp.maximum(out_degree.astype(jnp.float32), 1.0)

    n_hub_parts = int(np.searchsorted(np.asarray(table.vertex_start), n_hubs, side="left"))
    n_hub_parts = max(n_hub_parts, 1) if n_hubs > 0 else 0

    sharding = _check_vertex_sharding(config.vertex_sharding)
    halo = None
    n_pad = g.n_nodes
    if sharding == "owner":
        halo = build_halo_plan(src, dst, in_range, g.n_nodes, n_dev)
        n_pad = halo.n_pad
        out_degree = _pad_vertex_vec(out_degree, n_pad, 0)
        zc_req = _pad_vertex_vec(zc_req, n_pad, 0.0)
        inv_deg = _pad_vertex_vec(inv_deg, n_pad, 1.0)
        part_id = np.concatenate(
            [part_id, np.full(n_pad - g.n_nodes, P_total - 1, np.int32)])

    parts = DevicePartitions(
        vertex_start=jnp.asarray(table.vertex_start, jnp.int32),
        edge_start=jnp.asarray(table.edge_start, jnp.int32),
        part_edges=jnp.asarray(epp, jnp.int32),
        vertex_part_id=jnp.asarray(part_id),
        n_partitions=P_total,
        block_size=B,
    )

    return ShardedRuntime(
        mesh=mesh,
        axis=axis,
        blocks=blocks,
        parts=parts,
        out_degree=jax.device_put(out_degree, rep),
        zc_req=jax.device_put(zc_req, rep),
        inv_deg=jax.device_put(inv_deg, rep),
        n_nodes=g.n_nodes,
        n_partitions=P_total,
        n_hub_partitions=n_hub_parts,
        vertex_sharding=sharding,
        n_pad=n_pad,
        halo=halo,
    )


def _check_vertex_sharding(sharding: str) -> str:
    if sharding not in ("replicated", "owner"):
        raise ValueError(
            f"vertex_sharding must be 'replicated' or 'owner', "
            f"got {sharding!r}")
    return sharding


def _pad_vertex_vec(vec: jax.Array, n_pad: int, fill) -> jax.Array:
    """Pad a per-vertex runtime vector from (n,) to (n_pad,) with an
    inert fill value (padded ids carry no edges and never activate)."""
    extra = n_pad - vec.shape[0]
    if extra <= 0:
        return vec
    return jnp.concatenate([vec, jnp.full(extra, fill, vec.dtype)])


# --------------------------------------------------------------------------
# One sharded iteration
# --------------------------------------------------------------------------

def _local_sweep(
    blocks: BlockedEdges,      # (P_local, B) — this device's shard
    engines: jax.Array,        # (P_local,) — NONE entries are skipped
    order: jax.Array,          # (P_local,) local processing order
    frontier: jax.Array,       # (n,) full per-device view (halo-filled)
    operand: jax.Array,        # (n,) full message operand view
    n: int,
    program: VertexProgram,
    axis: str,
    use_kernels: bool = False,
    owner: bool = False,
    n_loc: int = 0,
):
    """Relax this device's partitions, then merge across the mesh.

    Replicated layout: returns the globally merged (n,) (agg, touched) —
    ``pmin`` for traversal (min) combiners, ``psum`` for accumulative
    (sum) combiners — one collective exchange of the contribution vector
    per pass.  Owner layout: returns this device's **owned (n_loc,)
    slice** of the same merge — the pmin result sliced at the owner
    offset (bit-exact: the identical elementwise pmin, restricted), a
    tiled ``psum_scatter`` for sum combiners.
    """
    identity = jnp.inf if program.combine == MIN else 0.0

    def body(carry, p):
        agg, touched = carry
        eng = engines[p]
        src, dst = blocks.src[p], blocks.dst[p]
        weight, in_range = blocks.weight[p], blocks.in_range[p]
        active = frontier[src] & in_range & (eng != NONE)
        block = EdgeBlock(src=src, dst=dst, weight=weight, active=active)
        out = relax_with_engine(eng, block, operand, n, program, use_kernels)
        if program.combine == MIN:
            agg = jnp.minimum(agg, out.agg)
        else:
            agg = agg + out.agg
        return (agg, touched | out.touched), None

    init = (jnp.full(n, identity, jnp.float32), jnp.zeros(n, bool))
    (agg, touched), _ = jax.lax.scan(body, init, order)
    if program.combine == MIN:
        agg = jax.lax.pmin(agg, axis)
        touched = jax.lax.psum(touched.astype(jnp.int32), axis) > 0
        if owner:
            dev = jax.lax.axis_index(axis)
            agg = jax.lax.dynamic_slice_in_dim(agg, dev * n_loc, n_loc)
            touched = jax.lax.dynamic_slice_in_dim(touched, dev * n_loc, n_loc)
    else:
        if owner:
            agg = jax.lax.psum_scatter(agg, axis, scatter_dimension=0,
                                       tiled=True)
            touched = jax.lax.psum_scatter(
                touched.astype(jnp.int32), axis, scatter_dimension=0,
                tiled=True) > 0
        else:
            agg = jax.lax.psum(agg, axis)
            touched = jax.lax.psum(touched.astype(jnp.int32), axis) > 0
    return agg, touched


def _apply_merged(
    values: jax.Array,
    delta: jax.Array,
    consumed: jax.Array,   # (n,) bool — frontier vertices absorbing delta
    agg: jax.Array,
    touched: jax.Array,
    program: VertexProgram,
):
    """Synchronous state update from a globally merged contribution vector
    (the shard_map analogue of core.hytm._sweep's sync branch)."""
    if program.combine == MIN:
        improved = touched & (agg < values)
        values = jnp.where(improved, agg, values)
        return values, delta, improved
    values = values + jnp.where(consumed, delta, 0.0)
    delta = jnp.where(consumed, 0.0, delta) + agg
    return values, delta, touched


def _make_iteration_impl(
    rt: ShardedRuntime, program: VertexProgram, config: HyTMConfig
):
    """Build the untraced per-iteration body for one runtime/program.
    ``make_sharded_iteration`` jits it directly (the sync_every=1 driver);
    ``make_sharded_chunk`` inlines it in a ``lax.while_loop`` so K
    shard_mapped iterations share one dispatch; ``vmap`` lifts it over a
    lane dimension (``make_sharded_batched_chunk``).

    ``rt`` contributes only the *static* structure (mesh, axis, node and
    partition counts) — the device buffers are traced **arguments** of
    the returned ``iteration(state, blocks, parts, out_degree, zc_req,
    inv_deg, correction)``, never baked-in constants.  That is what lets
    ``DeltaCSR``'s sharded view patch the (P, B) edge-block grid between
    calls while the compiled sweep survives: same shapes hit the jit
    cache, a merge-compaction's new shapes re-specialize through it."""
    mesh, axis = rt.mesh, rt.axis
    n_dev = int(mesh.shape[axis])
    owner = rt.vertex_sharding == "owner"
    # owner layout: state vectors are (n_pad,) owner-sharded; each sweep
    # pass all-gathers the (n_loc,) shards into the full view the local
    # edge blocks read, then merges back to owned slices (_local_sweep)
    n = rt.n_pad if owner else rt.n_nodes
    n_loc = n // n_dev if owner else 0
    P_total = rt.n_partitions
    P_local = P_total // n_dev
    mode = config.cds_mode
    # resolved once at trace time, like the single-device sweep; the
    # shard_mapped local sweep then routes through the same kernel or
    # oracle engines as every other consumer
    use_kernels = resolve_use_kernels(config.use_kernels)

    def select_local(stats_slice, correction):
        """Algorithm 1 on a (P_local,) stats shard — identical result to
        slicing the global selection (selection is per-partition)."""
        if config.forced_engine is None:
            costs = engine_costs(stats_slice, config.link)
            return select_engines(stats_slice, costs, config.link, correction)
        return jnp.where(
            stats_slice.active_edges > 0, config.forced_engine, NONE
        ).astype(jnp.int32)

    def sweep_pass(blocks, stats, second_mask, frontier, operand, delta_mass,
                   correction, pass_two: bool):
        """One shard_mapped sweep pass; returns merged (agg, touched) plus
        the engines each device selected (for the second pass mask)."""

        def local(blocks_l, stats_l, mask_l, dmass_l, frontier_, operand_,
                  corr_):
            dev = jax.lax.axis_index(axis)
            engines_l = select_local(stats_l, corr_)
            if pass_two:
                engines_l = jnp.where(mask_l, engines_l, NONE)
            sched = make_schedule(
                engines_l, dmass_l, rt.n_hub_partitions, mode,
                config.recompute_once, pid_offset=dev * P_local,
                priority_mask=mask_l,
            )
            if owner:
                # halo fill: gather the owned shards into the full view
                # the local edge blocks read (dense exchange; the cost
                # model charges the compacted halo candidate against it)
                frontier_ = jax.lax.all_gather(frontier_, axis, tiled=True)
                operand_ = jax.lax.all_gather(operand_, axis, tiled=True)
            agg, touched = _local_sweep(
                blocks_l, engines_l, sched.order, frontier_, operand_,
                n, program, axis, use_kernels,
                owner=owner, n_loc=n_loc,
            )
            return agg, touched

        shard = P(axis)
        rep = P()
        state_spec = shard if owner else rep
        fn = shard_map(
            local,
            mesh=mesh,
            in_specs=(
                BlockedEdges(src=P(axis, None), dst=P(axis, None),
                             weight=P(axis, None), in_range=P(axis, None)),
                jax.tree.map(lambda _: shard, stats),
                shard, shard, state_spec, state_spec, rep,
            ),
            out_specs=(state_spec, state_spec),
            check_rep=False,
        )
        return fn(blocks, stats, second_mask, delta_mass, frontier,
                  operand, correction)

    def iteration(
        state: HyTMState,
        blocks: BlockedEdges,
        parts: DevicePartitions,
        out_degree: jax.Array,
        zc_req: jax.Array,
        inv_deg: jax.Array,
        correction: jax.Array | None = None,
    ):
        if correction is None:
            # identity correction: float multiply by 1.0 is exact, so the
            # uncorrected path stays bit-identical to the oracle contract
            correction = jnp.ones(3, jnp.float32)
        frontier = state.frontier
        values, delta = state.values, state.delta

        # (1) global stats + Δ mass on the replicated vertex state.  As in
        # core.hytm: only the 'delta' CDS mode reads the Δ mass, and
        # min-combine programs carry an identically-zero Δ — skip the
        # segment-sum in both cases.
        stats = partition_stats(frontier, out_degree, zc_req, parts)
        if program.combine == MIN or mode != "delta":
            delta_mass = jnp.zeros(P_total, jnp.float32)
        else:
            delta_mass = jax.ops.segment_sum(
                jnp.abs(delta) * frontier, parts.vertex_part_id,
                num_segments=P_total,
            )

        # (2) global plan for the transfer accounting (identical to the
        # per-device selections — selection is per-partition)
        if config.forced_engine is None:
            plan = generate_tasks(
                stats, config.link, combine_k=config.combine_k,
                enable_combination=config.enable_task_combination,
                correction=correction,
            )
        else:
            plan = forced_engine_plan(
                stats, config.link, config.forced_engine,
                enable_combination=config.enable_task_combination,
                combine_k=config.combine_k,
            )

        # (3) global second-pass mask (Δ-mode top-K is a global rank)
        sched_global = make_schedule(
            plan.engines, delta_mass, rt.n_hub_partitions, mode,
            config.recompute_once,
        )
        second_mask = sched_global.second_pass

        # (4) pass 1: every active partition, synchronous merge
        if program.combine == SUM:
            operand = program.damping * delta * inv_deg
        else:
            operand = values
        agg, touched = sweep_pass(
            blocks, stats, second_mask, frontier, operand, delta_mass,
            correction, pass_two=False,
        )
        if program.peel_k is not None:
            # peeling: merged agg is the per-vertex count of newly-removed
            # in-neighbors; subtract from the remaining degree (additive,
            # so async == sync == sharded — core.hytm._sweep's peel branch)
            values1, delta1, activated = values - agg, delta, touched
        else:
            values1, delta1, activated = _apply_merged(
                values, delta, frontier, agg, touched, program,
            )

        # (5) pass 2: recompute-once over loaded priority partitions
        if program.peel_k is not None:
            # a second peel pass would double-subtract the removal counts
            frontier2 = jnp.zeros_like(frontier)
        elif program.combine == MIN:
            frontier2 = frontier | activated
        else:
            # |Δ| matches core.hytm: signed correction deltas (the
            # incremental repro.stream path) must keep propagating.
            frontier2 = jnp.abs(delta1) > program.tolerance
        if program.combine == SUM:
            operand2 = program.damping * delta1 * inv_deg
        else:
            operand2 = values1
        agg2, touched2 = sweep_pass(
            blocks, stats, second_mask, frontier2, operand2, delta_mass,
            correction, pass_two=True,
        )
        # pass-2 consumption only touches re-processed partitions
        processed2 = second_mask[parts.vertex_part_id] & (
            plan.engines[parts.vertex_part_id] != NONE
        )
        if program.peel_k is not None:
            values2, delta2, activated2 = values1 - agg2, delta1, touched2
        else:
            values2, delta2, activated2 = _apply_merged(
                values1, delta1, frontier2 & processed2, agg2, touched2,
                program,
            )
        activated = activated | activated2
        # entries a compacted ICI exchange would ship: destinations any
        # device touched this iteration (both passes) — NOT the source
        # frontier, which undercounts by the fan-out in hub regimes
        merged_entries = jnp.sum((touched | touched2).astype(jnp.int32))

        if program.peel_k is not None:
            # newly-removed: alive vertices whose remaining degree fell
            # below k this round (matches core.hytm's peel post-pass)
            alive = delta2 < 0.5
            newly = alive & (values2 < program.peel_k)
            next_frontier = newly
            delta2 = delta2 + newly.astype(jnp.float32)
        elif program.combine == MIN:
            next_frontier = activated
        else:
            next_frontier = jnp.abs(delta2) > program.tolerance

        new_state = HyTMState(values=values2, delta=delta2, frontier=next_frontier)
        per_engine_time, mispredictions = selection_diagnostics(
            plan.engines, plan.transfer_time, stats, plan.costs, correction,
        )
        info = {
            KEY_ENGINES: plan.engines,
            KEY_TRANSFER_BYTES: plan.transfer_bytes,
            KEY_TRANSFER_TIME: jnp.sum(plan.transfer_time)
            + plan.n_tasks.astype(jnp.float32) * config.link.launch_overhead_s,
            KEY_N_TASKS: plan.n_tasks,
            KEY_ACTIVE_VERTICES: jnp.sum(frontier.astype(jnp.int32)),
            KEY_ACTIVE_EDGES: jnp.sum(stats.active_edges),
            "next_active": jnp.sum(next_frontier.astype(jnp.int32)),
            KEY_PER_ENGINE_TIME: per_engine_time,
            KEY_MISPREDICTIONS: mispredictions,
            KEY_MERGED_ENTRIES: merged_entries,
        }
        return new_state, info

    return iteration


def _runtime_args(rt: ShardedRuntime) -> tuple:
    """The traced device-buffer arguments every compiled sharded driver
    takes, read fresh from the runtime at each dispatch (so a patched
    view — DeltaCSR's sharded grid — is always what executes)."""
    return rt.blocks, rt.parts, rt.out_degree, rt.zc_req, rt.inv_deg


def make_sharded_iteration(
    rt: ShardedRuntime, program: VertexProgram, config: HyTMConfig
):
    """Build the jitted per-iteration function for one runtime/program:
    ``iteration(state, blocks, parts, out_degree, zc_req, inv_deg,
    correction)``."""
    return jax.jit(_make_iteration_impl(rt, program, config))


def make_sharded_chunk(
    rt: ShardedRuntime, program: VertexProgram, config: HyTMConfig,
    chunk: int,
):
    """Chunked sharded driver: up to ``chunk`` shard_mapped iterations
    inside one ``lax.while_loop`` dispatch, same chunk/early-exit and
    history-draining contract as ``core.hytm.hytm_chunk`` (state and
    history buffers donated; the while-condition tests the previous
    iteration's ``next_active``, so convergence never overshoots).  The
    history buffers additionally carry ``merged_entries`` — the
    per-iteration input of the host-side ICI-level accounting
    (``ici_level_cost``), which runs over the drained rows once per
    chunk.  The edge blocks and vertex vectors are traced arguments (see
    ``_make_iteration_impl``), so warm-started reruns over a patched
    ``DeltaCSR`` view reuse this compiled chunk."""
    impl = _make_iteration_impl(rt, program, config)
    keys = HISTORY_KEYS + (KEY_MERGED_ENTRIES,)

    @partial(jax.jit, donate_argnames=("state", "history"))
    def chunk_fn(state: HyTMState, history: dict, blocks, parts, out_degree,
                 zc_req, inv_deg, correction: jax.Array):
        return chunked_while(
            lambda st: impl(st, blocks, parts, out_degree, zc_req, inv_deg,
                            correction),
            state, history, chunk)

    shapes_cell: dict = {}  # eval_shape once per shape signature

    def init_history(state: HyTMState, correction: jax.Array) -> dict:
        shape_key = (rt.blocks.src.shape, rt.parts.n_partitions,
                     rt.parts.block_size)
        if shape_key not in shapes_cell:
            shapes_cell[shape_key] = jax.eval_shape(
                impl, state, *_runtime_args(rt), correction)[1]
        return init_history_buffers(shapes_cell[shape_key], chunk, keys=keys)

    return chunk_fn, init_history


def make_sharded_batched_chunk(
    rt: ShardedRuntime, program: VertexProgram, config: HyTMConfig,
    chunk: int,
):
    """Service lane sweep over the mesh (``GraphService`` with
    ``config.mesh_axis`` set): up to ``chunk`` iterations of the sharded
    iteration, ``vmap``ped over the leading lane dimension of ``state``
    — each lane runs its own cost model / engine selection / schedule
    over its own frontier, while the edge blocks stay sharded over the
    mesh axis and every relaxation merges with the same bulk-synchronous
    pmin/psum collectives as the single-lane sweep (one batched
    collective carries all lanes).  The carry holds the **per-lane**
    ``next_active`` vector (the early-exit condition sums it, matching
    ``core.hytm.hytm_batched_chunk``): converged lanes idle as no-ops
    only while a straggler is still inside the chunk, and the returned
    ``lane_active`` is the signal the continuous scheduler
    (``repro.serve``) uses to free converged lanes at the chunk boundary
    and backfill their slots on the mesh path.

    The service reads no per-iteration history; the loop carries running
    reductions (summed per-engine modeled seconds + mispredictions — the
    calibrator's chunk-granular observation inputs) plus a ``(chunk,)``
    row of lane-summed ``merged_entries`` for the host-side ICI-level
    accounting.  Returns ``(state, n_done, lane_active,
    per_engine_sum, mispred_sum, merged_rows)`` with ``lane_active`` of
    shape ``(Q,)``."""
    impl = _make_iteration_impl(rt, program, config)

    @partial(jax.jit, donate_argnames=("state",))
    def chunk_fn(state: HyTMState, blocks, parts, out_degree, zc_req,
                 inv_deg, correction):
        def one(s):
            return impl(s, blocks, parts, out_degree, zc_req, inv_deg,
                        correction)

        def cond(carry):
            _s, i, lane_active, _pe, _mp, _me = carry
            return (i < chunk) & (jnp.sum(lane_active) != 0)

        def body(carry):
            s, i, _prev, pe, mp, me = carry
            s2, info = jax.vmap(one)(s)
            return (
                s2,
                i + 1,
                info["next_active"],
                pe + jnp.sum(info[KEY_PER_ENGINE_TIME], axis=0),
                mp + jnp.sum(info[KEY_MISPREDICTIONS]),
                me.at[i].set(jnp.sum(info[KEY_MERGED_ENTRIES])),
            )

        n_lanes = state.values.shape[0]
        # sentinel ones: the first iteration always runs, matching the
        # K=1 loop (which runs one iteration even on an empty frontier)
        init = (state, jnp.int32(0), jnp.ones(n_lanes, jnp.int32),
                jnp.zeros(3, jnp.float32), jnp.int32(0),
                jnp.zeros(chunk, jnp.int32))
        return jax.lax.while_loop(cond, body, init)

    return chunk_fn


# --------------------------------------------------------------------------
# Second transfer-management level: the cross-device merge
# --------------------------------------------------------------------------

def _ring_per_dev_bytes(payload_bytes: float, n_devices: int) -> float:
    """Bytes one device moves for a ring all-reduce of ``payload_bytes``."""
    return 2.0 * (n_devices - 1) / n_devices * payload_bytes


def _collective_charge(per_dev_bytes: float, link) -> float:
    """Seconds for one collective, through the Eq-1 transaction-group
    model (shared by the dense and compacted ICI candidates — they must
    never diverge, or the second-level engine comparison is corrupted)."""
    group = link.m * link.mr
    return float(np.ceil(per_dev_bytes / group)) * link.rtt + link.launch_overhead_s


def ici_merge_cost(
    n_nodes: int, n_devices: int, link, n_collectives: int = 4
) -> tuple[float, float]:
    """Modeled (bytes, seconds) of one iteration's cross-device merges.

    Each sweep pass all-reduces two dense (n,) vectors — the contribution
    aggregate (f32) and the touched mask (i32) — and an iteration runs two
    passes, so ``n_collectives`` = 4.  A ring all-reduce moves
    ``2*(D-1)/D * n * 4`` bytes per device per collective; bytes are the
    all-device total (what the fabric carries), time is the per-device
    critical path through the same transaction-group model as Eqs. 1-3
    (DESIGN.md §2: all-gather of whole value arrays == the filter engine
    of the ICI level).
    """
    if n_devices <= 1:
        return 0.0, 0.0
    per_dev = _ring_per_dev_bytes(n_nodes * 4.0, n_devices)
    total_bytes = per_dev * n_devices * n_collectives
    return total_bytes, n_collectives * _collective_charge(per_dev, link)


def ici_level_cost(
    n_nodes: int,
    merged_entries: float,
    n_devices: int,
    link,
    correction: np.ndarray | None = None,
    n_collectives: int = 4,
) -> tuple[float, float, int]:
    """Per-iteration ICI-level *engine selection* (Algorithm 1 at the
    second transfer-management level): dense all-reduce of the whole
    (n,) contribution vectors (the FILTER analogue) vs a compacted
    exchange of only the ``merged_entries`` destinations the sweep
    touched — (index, payload) pairs, 8 B — (the COMPACT analogue).
    Returns (bytes, seconds, engine).

    ``correction`` is the same (3,) online-feedback vector the HBM level
    uses (repro.autotune.feedback); it rescales the two candidate costs
    before *comparison* only — the returned charge is the chosen
    engine's uncorrected model time, matching the HBM level's
    select-corrected / account-uncorrected contract.  Accounting-level
    selection: the executed collective stays the bulk-synchronous
    pmin/psum merge (oracle equivalence); what moves is the modeled
    charge, exactly as the HBM level's accounting does.
    """
    if n_devices <= 1:
        return 0.0, 0.0, NONE
    c = np.ones(3) if correction is None else np.asarray(correction, float)
    per_dev_comp = _ring_per_dev_bytes(float(merged_entries) * 8.0, n_devices)
    t_comp = n_collectives * _collective_charge(per_dev_comp, link)
    dense_bytes, t_dense = ici_merge_cost(
        n_nodes, n_devices, link, n_collectives=n_collectives)
    if t_comp * c[COMPACT] < t_dense * c[FILTER]:
        return per_dev_comp * n_devices * n_collectives, t_comp, COMPACT
    return dense_bytes, t_dense, FILTER


def halo_level_cost(
    n_nodes: int,
    merged_entries: float,
    halo_total: int,
    n_devices: int,
    link,
    correction: np.ndarray | None = None,
    n_collectives: int = 4,
) -> tuple[float, float, int]:
    """``ici_level_cost`` generalized to the owner/halo layout: a
    compacted exchange never ships more than the boundary vertices the
    edge blocks actually reference, so the compacted candidate's entry
    count is capped at ``HaloPlan.halo_total`` — the halo is the
    owner-layout analogue of the touched-destination set.  The dense
    candidate (all-gather + merge of whole vectors) is unchanged, and the
    select-corrected / account-uncorrected contract carries over."""
    return ici_level_cost(
        n_nodes, min(float(merged_entries), float(halo_total)), n_devices,
        link, correction, n_collectives,
    )


# --------------------------------------------------------------------------
# Convergence loop
# --------------------------------------------------------------------------

def owner_state_pad_values(program: VertexProgram) -> tuple[float, float]:
    """(values, delta) fill for the ``[n, n_pad)`` ghost vertices of the
    owner layout.  Pads carry no edges, so the fills only need to keep
    them *inert* in the next-frontier rules: Δ-pads 0 would re-activate
    under a peel (alive with degree < k), so peels pad Δ=1 (removed);
    min-combiners pad values=inf (unreachable); frontier pads are always
    False."""
    if program.peel_k is not None:
        return 0.0, 1.0
    if program.use_delta:
        return 0.0, 0.0
    return float(np.inf), 0.0


def _owner_place_state(
    rt: ShardedRuntime, program: VertexProgram,
    values: jax.Array, delta: jax.Array, frontier: jax.Array,
) -> HyTMState:
    """Pad an (n,) state triple to (n_pad,) and owner-shard it P(axis) —
    the placement every owner-mode dispatch (cold, warm, incremental,
    resumed) takes."""
    pad_v, pad_d = owner_state_pad_values(program)
    values = _pad_vertex_vec(jnp.asarray(values, jnp.float32), rt.n_pad,
                             pad_v)
    delta = _pad_vertex_vec(jnp.asarray(delta, jnp.float32), rt.n_pad, pad_d)
    frontier = _pad_vertex_vec(jnp.asarray(frontier, bool), rt.n_pad, False)
    shard = NamedSharding(rt.mesh, P(rt.axis))
    return HyTMState(
        values=jax.device_put(values, shard),
        delta=jax.device_put(delta, shard),
        frontier=jax.device_put(frontier, shard),
    )


def run_hytm_sharded(
    g: CSRGraph,
    program: VertexProgram,
    source: int | None = 0,
    config: HyTMConfig = HyTMConfig(mesh_axis="graph"),
    n_hubs: int = 0,
    mesh: jax.sharding.Mesh | None = None,
    runtime: ShardedRuntime | None = None,
    calibrator=None,
    initial_state: HyTMState | None = None,
    obs=None,
    faults=None,
    retry=None,
    on_chunk=None,
) -> HyTMResult:
    """Drop-in ``run_hytm`` over a 1-D device mesh.

    Equivalence contract: identical per-partition engine selections and
    modeled transfer accounting as single-device, and state trajectories
    matching the single-device ``async_sweep=False`` run (exact for
    min-combine programs; up to FP summation order for sum-combine).

    ``config.vertex_sharding`` picks the vertex-state layout.
    ``"replicated"`` (default) keeps the full (n,) triple on every
    device — byte-identical to the historical path.  ``"owner"``
    owner-shards the triple: each device stores only its contiguous
    ``(n_loc,) = (ceil(n/D),)`` owned slice plus the halo view its edge
    blocks gather per pass, cutting per-device vertex-state bytes
    ~D-fold (``cost_model.vertex_state_bytes``); the ICI level then
    charges ``halo_level_cost`` — the compacted candidate capped at the
    runtime's :class:`HaloPlan` boundary count.  Both layouts satisfy
    the same oracle contract above; ``HyTMResult.values``/``delta`` are
    always returned as host (n,) arrays regardless of layout.

    ``initial_state`` warm-starts the sharded convergence loop from an
    arbitrary (values, Δ, frontier) triple — the entry point of the
    sharded incremental path (repro.stream.incremental with
    ``config.mesh_axis`` set).  The warm state is re-placed replicated
    over the mesh (the same sharding the cold start's init state takes),
    so it re-enters the compiled chunk under identical layout; the warm
    equivalence contract mirrors the cold one (warm sharded ==
    single-device ``async_sweep=False`` warm, bit-for-bit for
    min-combine).  With ``runtime`` and ``initial_state`` both given,
    ``g`` may be ``None``.

    ``faults``/``retry``/``on_chunk`` mirror ``run_hytm``: injected
    ``"chunk_dispatch"`` faults fire before the shard_mapped dispatch
    (donated buffers intact, retries bit-identical), and ``on_chunk``
    observes every chunk boundary for checkpointing — all zero-overhead
    when absent.
    """
    if runtime is not None:
        rt = runtime
        mesh = rt.mesh if mesh is None else mesh
    else:
        if g is None:
            raise ValueError(
                "run_hytm_sharded needs a graph or a prebuilt runtime")
        if mesh is None:
            from repro.launch.mesh import make_graph_mesh

            mesh = make_graph_mesh(axis=config.mesh_axis)
        if program.symmetrize:
            # WCC-family programs sweep the underlying undirected graph
            g = g.symmetrize()
        rt = build_sharded_runtime(
            g, config, mesh, n_hubs=n_hubs,
            weighted_norm=program.use_delta and program.weighted,
        )
    owner = _check_vertex_sharding(config.vertex_sharding) == "owner"
    if rt.vertex_sharding != config.vertex_sharding:
        raise ValueError(
            f"runtime was built with vertex_sharding="
            f"{rt.vertex_sharding!r} but config requests "
            f"{config.vertex_sharding!r}; rebuild the runtime")
    if initial_state is None:
        if program.peel_k is not None:
            # peeling programs seed from vertex degrees (init_state has no
            # degree access); rt.out_degree is padded in owner mode —
            # slice to the real vertices so pads never enter the frontier
            deg = np.asarray(rt.out_degree)[:rt.n_nodes].astype(np.float32)
            removed = deg < program.peel_k
            values, delta, frontier = (
                jnp.asarray(deg), jnp.asarray(removed, jnp.float32),
                jnp.asarray(removed))
        else:
            values, delta, frontier = program.init_state(rt.n_nodes, source)
        if owner:
            state = _owner_place_state(rt, program, values, delta, frontier)
        else:
            state = HyTMState(values=values, delta=delta, frontier=frontier)
    elif owner:
        state = _owner_place_state(
            rt, program, jnp.asarray(initial_state.values),
            jnp.asarray(initial_state.delta),
            jnp.asarray(initial_state.frontier))
    else:
        # replicate the warm triple over the mesh — identical placement to
        # the cold start, so the compiled sweep sees one layout either way
        rep = NamedSharding(mesh, P())
        state = HyTMState(
            values=jax.device_put(jnp.asarray(initial_state.values), rep),
            delta=jax.device_put(jnp.asarray(initial_state.delta), rep),
            frontier=jax.device_put(jnp.asarray(initial_state.frontier), rep),
        )

    n_dev = int(mesh.shape[config.mesh_axis])

    calib = None
    correction = None
    corr_np = None
    if config.autotune:
        from repro.autotune.feedback import OnlineCalibrator

        calib = (calibrator if calibrator is not None
                 else OnlineCalibrator(decay=config.autotune_decay))
        correction = jnp.asarray(calib.correction(), jnp.float32)
        corr_np = np.asarray(correction, dtype=float)

    if config.sync_every < 1:
        raise ValueError(f"sync_every must be >= 1, got {config.sync_every}")
    if on_chunk is not None and config.sync_every == 1:
        raise ValueError(
            "on_chunk (checkpointing) requires the chunked driver — "
            "set sync_every >= 2")
    rows: dict[str, list] = {k: [] for k in HISTORY_KEYS}
    # second-level accounting (per iteration: the exchange mode depends on
    # the live active-vertex count, and feedback can reweigh the choice)
    ici_hist: dict[str, list] = {
        KEY_ICI_BYTES: [], KEY_ICI_TIME: [], KEY_ICI_ENGINE: []}

    def charge_ici(merged_entries: float) -> None:
        if owner and rt.halo is not None:
            # owner layout: a compacted exchange ships at most the halo
            halo_entries = min(float(merged_entries),
                               float(rt.halo.halo_total))
            ib, it_, ie = halo_level_cost(
                rt.n_nodes, float(merged_entries), rt.halo.halo_total,
                n_dev, config.ici_link, corr_np,
            )
        else:
            halo_entries = None
            ib, it_, ie = ici_level_cost(
                rt.n_nodes, float(merged_entries), n_dev, config.ici_link,
                corr_np,
            )
        it = len(ici_hist[KEY_ICI_BYTES])  # global iteration index
        ici_hist[KEY_ICI_BYTES].append(ib)
        ici_hist[KEY_ICI_TIME].append(it_)
        ici_hist[KEY_ICI_ENGINE].append(ie)
        if obs is not None:
            from repro.obs.record import record_ici

            record_ici(
                obs, track="ici", it=it, bytes_=ib, seconds=it_, engine=ie,
                merged_entries=float(merged_entries),
                halo_entries=halo_entries,
            )

    t0 = time.monotonic()
    iters = 0
    if config.sync_every > 1:
        # Chunked driver: one shard_mapped lax.while_loop dispatch per K
        # iterations (same contract as core.hytm.hytm_chunk); the ICI
        # level is charged per executed iteration from the drained
        # merged_entries rows, under the SAME correction the chunk's
        # HBM-level selections ran with.
        corr_arr = (correction if correction is not None
                    else jnp.ones(3, jnp.float32))
        history, cur_chunk = None, -1
        while iters < config.max_iters:
            chunk = min(config.sync_every, config.max_iters - iters)
            key = ("chunk", program, config, chunk)
            cached = rt.iteration_cache.get(key)
            if cached is None:
                chunk_fn, init_history = make_sharded_chunk(
                    rt, program, config, chunk)
                cached = {"fn": chunk_fn, "init": init_history,
                          "seen": set()}
                rt.iteration_cache[key] = cached
            if chunk != cur_chunk:
                # allocated once per chunk size; afterwards the drained
                # buffers cycle back in (donated reuse on accelerators)
                history = cached["init"](state, corr_arr)
                cur_chunk = chunk
            # warm iff THIS chunk_fn already dispatched THESE shapes: the
            # seen-set lives on the cached entry, so when a DeltaCSR
            # merge-compaction drops the entry (fresh jit cache) or moves
            # the block grid, the recompiling dispatch is cold and its
            # wall time never feeds the calibrator
            warm = _consume_warm(
                (rt.blocks.src.shape, rt.parts.n_partitions,
                 rt.parts.block_size),
                registry=cached["seen"],
            )
            t_chunk = time.monotonic()
            if faults is None:
                with quiet_donation():
                    state, history, n_done, last_active, pe_sum = (
                        cached["fn"](
                            state, history, *_runtime_args(rt), corr_arr))
            else:
                # faults fire BEFORE the shard_mapped dispatch — donated
                # buffers from the previous chunk stay intact, so a
                # retried dispatch is bit-identical
                from repro.kernels.runtime import resolve_use_kernels
                from repro.resilience.supervisor import guarded_dispatch

                def _attempt(st=state, h=history, ca=corr_arr,
                             fn=cached["fn"]):
                    with quiet_donation():
                        return fn(st, h, *_runtime_args(rt), ca)

                state, history, n_done, last_active, pe_sum = (
                    guarded_dispatch(
                        _attempt, site="chunk_dispatch", faults=faults,
                        policy=retry, obs=obs, mesh=True,
                        kernels=resolve_use_kernels(config.use_kernels),
                    ))
            n_done = int(n_done)
            iters += n_done
            if calib is not None:
                # observe BEFORE the drain + ICI loop: the measured wall
                # window covers dispatch + execution only
                corr_arr = calib.observe_chunk(
                    state.values, np.asarray(pe_sum, dtype=float),
                    t_chunk, skip=not warm,
                )
            # drain BEFORE the next dispatch donates these buffers
            drained = jax.device_get(history)
            for me in drained[KEY_MERGED_ENTRIES][:n_done]:
                charge_ici(me)  # charged under the chunk's correction
            if calib is not None:
                corr_np = np.asarray(corr_arr, dtype=float)
            for k in rows:
                rows[k].append(drained[k][:n_done])
            if obs is not None:
                from repro.obs.record import record_chunk, record_history_rows

                record_history_rows(
                    obs, drained, n_done, iters - n_done, track="mesh")
                record_chunk(
                    obs, track="mesh", wall_start=obs.wall_at(t_chunk),
                    wall_dur=obs.wall() - obs.wall_at(t_chunk),
                    start_iter=iters - n_done, n_done=n_done, warm=warm,
                )
            if on_chunk is not None:
                on_chunk(state=state, iterations=iters, rows=rows,
                         calibrator=calib, last_active=int(last_active))
            if int(last_active) == 0:
                break
        history = {k: np.concatenate(v) for k, v in rows.items()}
    else:
        cache_key = (program, config)
        iteration = rt.iteration_cache.get(cache_key)
        if iteration is None:
            iteration = make_sharded_iteration(rt, program, config)
            rt.iteration_cache[cache_key] = iteration
        for _ in range(config.max_iters):
            t_iter = time.monotonic()
            if faults is None:
                state, info = iteration(
                    state, *_runtime_args(rt), correction)
            else:
                from repro.kernels.runtime import resolve_use_kernels
                from repro.resilience.supervisor import guarded_dispatch

                def _attempt(st=state, corr=correction):
                    return iteration(st, *_runtime_args(rt), corr)

                state, info = guarded_dispatch(
                    _attempt, site="chunk_dispatch", faults=faults,
                    policy=retry, obs=obs, mesh=True,
                    kernels=resolve_use_kernels(config.use_kernels),
                )
            iters += 1
            # charge the ICI level under the SAME correction this
            # iteration's HBM-level selection ran with (the update below
            # only steers the next iteration, exactly as on the
            # single-device path)
            charge_ici(info[KEY_MERGED_ENTRIES])
            if calib is not None:
                correction = calib.observe_iteration(
                    state.values, info[KEY_PER_ENGINE_TIME], t_iter,
                    skip=iters == 1,  # iteration 1 measures compile
                )
                corr_np = np.asarray(correction, dtype=float)
            for k in rows:
                rows[k].append(info[k])
            if int(info["next_active"]) == 0:
                break
        # history stayed on device during the loop; one pull post-hoc
        staged = jax.device_get(rows)
        history = {k: np.stack(v) for k, v in staged.items()}
        if obs is not None:
            from repro.obs.record import record_history_rows

            record_history_rows(obs, history, iters, 0, track="mesh")
    jax.block_until_ready(state.values)
    wall = time.monotonic() - t0

    for k, v in ici_hist.items():
        history[k] = np.asarray(v)
    result = HyTMResult(
        # owner mode: gather the sharded (n_pad,) vectors and drop the
        # ghost pads so callers always see host (n,) arrays
        values=np.asarray(state.values)[:rt.n_nodes],
        delta=np.asarray(state.delta)[:rt.n_nodes],
        iterations=iters,
        wall_seconds=wall,
        modeled_seconds=float(np.sum(history[KEY_TRANSFER_TIME])),
        total_transfer_bytes=float(np.sum(history[KEY_TRANSFER_BYTES])),
        history=history,
        total_ici_bytes=float(np.sum(history[KEY_ICI_BYTES])),
        modeled_ici_seconds=float(np.sum(history[KEY_ICI_TIME])),
        total_mispredictions=int(np.sum(history[KEY_MISPREDICTIONS])),
        engine_corrections=(
            calib.correction() if calib is not None else None
        ),
    )
    if obs is not None:
        from repro.obs.record import record_run

        record_run(
            obs, result, track="mesh", wall_start=obs.wall_at(t0),
            wall_dur=wall, program=program.name, label=f"run[{n_dev}dev]",
        )
    return result
