"""Path-rule sharding DSL: regex rules over pytree paths -> NamedShardings.

The dry-run/serving cells (configs/common.py) describe *what* to shard
with small per-family rule lists; this module turns a rule list into a
``NamedSharding`` pytree for any parameter/optimizer/cache tree:

  rule      = [(path_regex, PartitionSpec), ...]   # first match wins
  shardings = tree_shardings(tree, mesh, rule)

Matching conventions that keep the rules tiny:

* A leaf's path is the "/"-joined key path (dict keys, list indices,
  registered-dataclass fields, or flat indices for opaque pytree nodes
  like ``TrainState``).  Rules use ``re.search``, so a rule written for
  ``.../attn/wq`` also matches the mirrored AdamW moment trees
  (``.../m/layers/attn/wq``) for free.
* Specs are **right-aligned** onto the leaf's trailing dims: stacked
  scan-layer params carry a leading ``(n_layers, ...)`` axis and inherit
  the same rule as their unstacked ``prefix`` twins.
* Every spec entry is validated against the mesh: a dim whose size does
  not divide the product of its assigned mesh axes falls back to
  replicated (``None``) for that dim — tiny smoke configs and debug
  meshes degrade gracefully instead of erroring.
* Unmatched leaves replicate (``P()``).

``batch_axes`` names the mesh axes batch dims shard over (``('pod',
'data')`` on multi-pod meshes), and the ``*_spec`` helpers give the
input-batch PartitionSpecs the cells place on tokens / graph data.
"""

from __future__ import annotations

import re
from typing import Any, Sequence

from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P
from jax.tree_util import (
    DictKey,
    FlattenedIndexKey,
    GetAttrKey,
    SequenceKey,
    tree_flatten_with_path,
    tree_unflatten,
)

Rule = Sequence[tuple[str, P]]

# Mesh axes a batch dimension may shard over, outermost first.
_BATCH_AXIS_ORDER = ("pod", "data")


def batch_axes(mesh) -> tuple[str, ...]:
    """Mesh axes the batch dimension shards over (present-axis subset of
    ('pod', 'data'), outermost first).  Empty tuple == replicated batch."""
    return tuple(a for a in _BATCH_AXIS_ORDER if a in mesh.axis_names)


def _key_name(k) -> str:
    if isinstance(k, DictKey):
        return str(k.key)
    if isinstance(k, SequenceKey):
        return str(k.idx)
    if isinstance(k, GetAttrKey):
        return str(k.name)
    if isinstance(k, FlattenedIndexKey):
        return str(k.key)
    return str(k)


def path_str(path) -> str:
    """'/'-joined readable key path for one tree_flatten_with_path entry."""
    return "/".join(_key_name(k) for k in path)


def _axes_size(mesh, entry) -> int:
    names = entry if isinstance(entry, tuple) else (entry,)
    size = 1
    for a in names:
        size *= int(mesh.shape[a])
    return size


def fit_spec(spec: P, shape: tuple[int, ...], mesh) -> P:
    """Right-align ``spec`` onto ``shape`` and drop non-dividing entries.

    Leading spec entries are discarded when the spec is longer than the
    leaf rank (a rank-2 rule hitting a bias vector keeps only its last
    entry); leading dims beyond the spec replicate.
    """
    entries = list(spec)
    if len(entries) > len(shape):
        entries = entries[len(entries) - len(shape):]
    pad = len(shape) - len(entries)
    entries = [None] * pad + entries
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None or entry == ():
            out.append(None)
            continue
        size = _axes_size(mesh, entry)
        out.append(entry if size > 0 and dim % size == 0 else None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def spec_for(path: str, shape: tuple[int, ...], mesh, rule: Rule) -> P:
    """Resolve the PartitionSpec for one leaf (first matching rule wins)."""
    for pattern, spec in rule:
        if re.search(pattern, path):
            return fit_spec(spec, shape, mesh)
    return P()


def tree_shardings(tree: Any, mesh, rule: Rule):
    """Map a rule list over a pytree -> same-structure NamedSharding tree."""
    flat, treedef = tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        shape = tuple(getattr(leaf, "shape", ()) or ())
        spec = spec_for(path_str(path), shape, mesh, rule)
        out.append(NamedSharding(mesh, spec))
    return tree_unflatten(treedef, out)


# ------------------------------------------------------------------- LM

def lm_rule(mesh) -> Rule:
    """Megatron-style tensor parallelism over the ``model`` axis.

    Column-parallel into attention/FFN (shard the output-feature dim),
    row-parallel out of them (shard the input-feature dim); embeddings
    shard the vocab dim.  MoE expert banks additionally shard the expert
    axis over the batch axes (EP width == DP width — matches the
    ``moe_ffn`` shard_map specs so no resharding happens at dispatch).
    """
    ba = batch_axes(mesh)
    expert = ba if len(ba) > 1 else (ba[0] if ba else None)
    return [
        (r"(^|/)(embed|unembed)$", P("model", None)),
        (r"/attn/(wq|wk|wv|w_uq|w_uk|w_uv|w_dq|w_dkv|w_kr)$", P(None, "model")),
        (r"/attn/wo$", P("model", None)),
        (r"/moe/router$", P()),
        (r"/moe/(w_gate|w_up)$", P(expert, None, "model")),
        (r"/moe/w_down$", P(expert, "model", None)),
        (r"/moe/(shared_gate|shared_up)$", P(None, "model")),
        (r"/moe/shared_down$", P("model", None)),
        (r"/ffn/(w_gate|w_up|w_in)$", P(None, "model")),
        (r"/ffn/w_down$", P("model", None)),
    ]


def lm_cache_rule(mesh, n_kv_heads: int) -> Rule:
    """KV-cache shardings for serving cells.

    When the KV-head count divides the ``model`` axis the heads shard
    over it (standard TP serving); otherwise (MQA's kv=1, MLA's headless
    latent cache) the *sequence* dim shards instead — that is what makes
    the 500k-token single-sequence decode cell fit (batch replicates, the
    cache length spreads across the model axis).
    """
    ba = batch_axes(mesh)
    n_model = int(mesh.shape["model"]) if "model" in mesh.axis_names else 1
    if n_model > 1 and n_kv_heads % n_model == 0:
        kv = P(ba, None, "model", None)
    else:
        kv = P(ba, "model", None, None)
    return [
        (r"(^|/)[kv]$", kv),
        (r"(^|/)(ckv|kr)$", P(ba, "model", None)),
    ]


def lm_batch_spec(mesh) -> P:
    """(B, S) token batches: batch dim over the batch axes."""
    return P(batch_axes(mesh), None)


# ------------------------------------------------------------------ GNN

def gnn_rule(mesh) -> Rule:
    """GNN training is data-parallel over nodes/edges; dense kernels
    column-shard their output features over ``model`` (they are small —
    the divisibility guard replicates the ones that do not divide)."""
    return [
        (r"(^|/)(w_self|w_nbr|w_msg|w_upd|A|B|C|U|V|out|embed_h|embed_e)$",
         P(None, "model")),
        (r"/(edge_mlp|node_mlp|enc_node|enc_edge|dec)/w/\d+$", P(None, "model")),
    ]


def gnn_data_spec(mesh, kind: str) -> P:
    """Graph-data batch specs: 1-D per-node/per-edge arrays ('vector') and
    2-D feature matrices ('matrix') shard their leading dim over the
    batch axes."""
    ba = batch_axes(mesh)
    if kind == "vector":
        return P(ba)
    if kind == "matrix":
        return P(ba, None)
    raise ValueError(f"unknown gnn data kind: {kind!r}")


# ----------------------------------------------------------------- DLRM

def dlrm_rule(mesh) -> Rule:
    """Row-shard the embedding tables over ``model`` (the tables dominate
    DLRM bytes); MLP towers column-shard like the LM FFN."""
    return [
        (r"(^|/)tables/\d+$", P("model", None)),
        (r"/(bot|top)/w/\d+$", P(None, "model")),
    ]
