"""Trace export + reconciliation for ``repro.obs``.

Three output forms:

* :func:`to_chrome_trace` / :func:`write_chrome_trace` — Chrome
  trace-event JSON (the ``{"traceEvents": [...]}`` object format)
  loadable in ``chrome://tracing`` and Perfetto.  Each distinct recorder
  track (device, lane, tenant) becomes its own thread row; spans lay out
  on the wall clock (microseconds) and carry the virtual clock in
  ``args``.
* :func:`write_jsonl` — one JSON object per event, for streaming
  consumers.
* :func:`summary` / :func:`reconcile` — host-side rollups.
  ``reconcile`` cross-checks the trace's run-span totals against the
  ``HyTMResult`` accounting (iterations, transfer bytes, modeled
  seconds, ICI bytes) and is the heart of the ``obs_bench --selfcheck``
  gate: the two views are computed from the same drained history rows by
  the same reductions, so they must agree *exactly*.

:func:`validate_chrome_trace` is the schema check shared by
``tests/test_obs.py`` and the selfcheck.
"""

from __future__ import annotations

import json
import math
from typing import Any

from repro.obs.trace import PH_COUNTER, PH_INSTANT, PH_SPAN, TraceRecorder

# Event names/categories the instrumentation sites and the reconciler
# agree on (producers: core.hytm, dist.graph_shard, serve.scheduler).
CAT_ITERATION = "iteration"
CAT_RUN = "run"
CAT_ICI = "ici"
CAT_FAULTS = "faults"  # resilience plane: injections/retries/degrades
EV_ITERATION = "iteration"
EV_RUN = "hytm_run"
EV_ICI_MERGE = "ici_merge"

PID = 1


def to_chrome_trace(rec: TraceRecorder) -> dict[str, Any]:
    """Render the recorder's event ring as a Chrome trace-event object."""
    tids: dict[str, int] = {}
    events: list[dict[str, Any]] = [{
        "name": "process_name", "ph": "M", "pid": PID, "tid": 0,
        "args": {"name": "repro"},
    }]

    def tid_of(track: str) -> int:
        t = tids.get(track)
        if t is None:
            t = tids[track] = len(tids) + 1
            events.append({
                "name": "thread_name", "ph": "M", "pid": PID, "tid": t,
                "args": {"name": track},
            })
        return t

    for ev in rec.events:
        out: dict[str, Any] = {
            "name": ev.name,
            "cat": ev.cat,
            "ph": ev.ph,
            "ts": ev.wall * 1e6,          # Chrome expects microseconds
            "pid": PID,
            "tid": tid_of(ev.track),
            "args": dict(ev.args),
        }
        out["args"]["vt"] = ev.vt
        if ev.ph == PH_SPAN:
            out["dur"] = ev.wall_dur * 1e6
            out["args"]["vt_dur"] = ev.vt_dur
        elif ev.ph == PH_INSTANT:
            out["s"] = "t"                # thread-scoped instant
        elif ev.ph == PH_COUNTER:
            out["args"] = {"value": ev.args.get("value", 0.0)}
        events.append(out)

    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"dropped_events": rec.dropped},
    }


def validate_chrome_trace(doc: dict[str, Any]) -> int:
    """Raise ``ValueError`` unless ``doc`` is valid Chrome trace-event
    JSON (object format); returns the number of trace events.  Shared by
    ``tests/test_obs.py`` and ``obs_bench --selfcheck``."""
    if not isinstance(doc, dict) or not isinstance(doc.get("traceEvents"), list):
        raise ValueError("trace must be an object with a traceEvents list")
    for i, ev in enumerate(doc["traceEvents"]):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            raise ValueError(f"{where} is not an object")
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            raise ValueError(f"{where} needs a non-empty string name")
        ph = ev.get("ph")
        if ph not in ("X", "i", "C", "M"):
            raise ValueError(f"{where} has unsupported phase {ph!r}")
        if not isinstance(ev.get("pid"), int) or not isinstance(ev.get("tid"), int):
            raise ValueError(f"{where} needs integer pid/tid")
        if ph == "M":
            if ev["name"] not in ("process_name", "thread_name"):
                raise ValueError(f"{where}: unknown metadata {ev['name']!r}")
            if not isinstance(ev.get("args", {}).get("name"), str):
                raise ValueError(f"{where}: metadata needs args.name")
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or not math.isfinite(ts) or ts < 0:
            raise ValueError(f"{where} needs a finite non-negative ts")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or not math.isfinite(dur) or dur < 0:
                raise ValueError(f"{where} (span) needs a finite non-negative dur")
        if ph == "i" and ev.get("s") not in ("t", "p", "g"):
            raise ValueError(f"{where} (instant) needs scope s in t/p/g")
        if ph == "C" and not all(
            isinstance(v, (int, float)) for v in ev.get("args", {}).values()
        ):
            raise ValueError(f"{where} (counter) args must be numeric")
        if not isinstance(ev.get("args", {}), dict):
            raise ValueError(f"{where} args must be an object")
    return len(doc["traceEvents"])


def write_chrome_trace(rec: TraceRecorder, path: str) -> dict[str, Any]:
    """Validate + write the Chrome trace JSON; returns the document."""
    doc = to_chrome_trace(rec)
    validate_chrome_trace(doc)
    with open(path, "w") as f:
        json.dump(doc, f)
        f.write("\n")
    return doc


def write_jsonl(rec: TraceRecorder, path: str) -> int:
    """One JSON object per recorded event (the streaming form); returns
    the number of lines written."""
    n = 0
    with open(path, "w") as f:
        for ev in rec.events:
            f.write(json.dumps({
                "name": ev.name, "ph": ev.ph, "cat": ev.cat,
                "track": ev.track, "wall": ev.wall, "wall_dur": ev.wall_dur,
                "vt": ev.vt, "vt_dur": ev.vt_dur, "args": ev.args,
            }))
            f.write("\n")
            n += 1
    return n


def summary(rec: TraceRecorder) -> dict[str, Any]:
    """Host-side rollup: event counts per category/phase + the metrics
    snapshot.  JSON-serializable."""
    by_cat: dict[str, int] = {}
    by_ph: dict[str, int] = {}
    tracks: set[str] = set()
    for ev in rec.events:
        by_cat[ev.cat] = by_cat.get(ev.cat, 0) + 1
        by_ph[ev.ph] = by_ph.get(ev.ph, 0) + 1
        tracks.add(ev.track)
    return {
        "events": len(rec.events),
        "dropped": rec.dropped,
        "tracks": sorted(tracks),
        "by_cat": dict(sorted(by_cat.items())),
        "by_ph": dict(sorted(by_ph.items())),
        "metrics": rec.metrics.snapshot(),
    }


def reconcile(rec: TraceRecorder, result: Any, track: str | None = None) -> dict[str, Any]:
    """Cross-check the trace against a ``HyTMResult``.

    Finds the run span(s) (``EV_RUN``) emitted by ``record_run`` —
    optionally restricted to ``track`` — and compares their summed totals
    against the result's fields, plus the per-iteration event count
    against ``result.iterations``.  Both sides are computed from the same
    drained history rows by the same reductions, so every comparison is
    **exact** (``==``), not approximate.

    Returns ``{"ok": bool, "checks": {name: {"trace", "result", "ok"}}}``.
    """
    runs = [ev for ev in rec.events
            if ev.name == EV_RUN and ev.ph == PH_SPAN
            and (track is None or ev.track == track)]
    iter_events = [ev for ev in rec.events
                   if ev.cat == CAT_ITERATION and ev.ph == PH_INSTANT
                   and (track is None or ev.track == track)]

    def tot(key: str) -> float:
        return sum(ev.args.get(key, 0.0) for ev in runs)

    checks = {
        "iterations": {
            "trace": int(tot("iterations")), "result": int(result.iterations)},
        "iteration_events": {
            "trace": len(iter_events), "result": int(result.iterations)},
        "transfer_bytes": {
            "trace": tot("transfer_bytes"),
            "result": float(result.total_transfer_bytes)},
        "modeled_seconds": {
            "trace": tot("modeled_seconds"),
            "result": float(result.modeled_seconds)},
        "mispredictions": {
            "trace": int(tot("mispredictions")),
            "result": int(result.total_mispredictions)},
        "ici_bytes": {
            "trace": tot("ici_bytes"),
            "result": float(getattr(result, "total_ici_bytes", 0.0))},
    }
    for c in checks.values():
        c["ok"] = c["trace"] == c["result"]
    return {"ok": all(c["ok"] for c in checks.values()), "checks": checks}
