"""Engine-side recording helpers shared by the instrumented drivers
(``core.hytm.run_hytm``, ``dist.graph_shard.run_hytm_sharded``).

Everything here consumes *drained* (host-side numpy) history rows — the
drivers call these helpers strictly outside jit, after their existing
``jax.device_get`` syncs, under an ``if obs is not None`` guard.  The
helpers therefore add zero work to the untraced path and never perturb
the traced computation.

The run-summary span (:func:`record_run`) copies its totals directly
from the finished ``HyTMResult`` — the same drained rows reduced by the
same ``np.sum`` calls — which is what lets ``export.reconcile`` demand
exact equality rather than tolerance.
"""

from __future__ import annotations

from typing import Any

import numpy as np

from repro.core.cost_model import (
    COMPACT,
    ENGINE_NAMES,
    FILTER,
    ZEROCOPY,
    KEY_ACTIVE_EDGES,
    KEY_ACTIVE_VERTICES,
    KEY_ENGINES,
    KEY_MISPREDICTIONS,
    KEY_N_TASKS,
    KEY_PER_ENGINE_TIME,
    KEY_TRANSFER_BYTES,
    KEY_TRANSFER_TIME,
)
from repro.obs.export import CAT_ICI, CAT_ITERATION, CAT_RUN, EV_ICI_MERGE, EV_ITERATION, EV_RUN

_REAL_ENGINES = (FILTER, COMPACT, ZEROCOPY)


def record_history_rows(
    obs: Any, drained: dict[str, np.ndarray], n_done: int, start_iter: int,
    track: str = "device0",
) -> None:
    """Emit one per-iteration instant (+ metric updates) per drained
    history row ``[0:n_done)``.  ``start_iter`` is the global iteration
    index of row 0 (the virtual-clock timestamp)."""
    m = obs.metrics
    picks = m.counter("engine.picks", "Algorithm-1 engine selections")
    bytes_c = m.counter("engine.bytes", "modeled host->device transfer bytes")
    secs_c = m.counter("engine.modeled_seconds", "modeled per-engine seconds")
    iters_c = m.counter("engine.iterations", "executed sweep iterations")
    mis_c = m.counter("engine.mispredictions",
                      "selections diverging from modeled-best")
    frontier_h = m.histogram("engine.frontier", "active vertices per iteration")

    engines = np.asarray(drained[KEY_ENGINES][:n_done])
    tbytes = np.asarray(drained[KEY_TRANSFER_BYTES][:n_done], dtype=np.float64)
    ttime = np.asarray(drained[KEY_TRANSFER_TIME][:n_done], dtype=np.float64)
    pet = np.asarray(drained[KEY_PER_ENGINE_TIME][:n_done], dtype=np.float64)
    av = np.asarray(drained[KEY_ACTIVE_VERTICES][:n_done])
    ae = np.asarray(drained[KEY_ACTIVE_EDGES][:n_done], dtype=np.float64)
    nt = np.asarray(drained[KEY_N_TASKS][:n_done])
    mis = np.asarray(drained[KEY_MISPREDICTIONS][:n_done])

    for k in range(int(n_done)):
        vt = float(start_iter + k)
        eng_row, byte_row = engines[k], tbytes[k]
        pick_counts = {}
        for e in _REAL_ENGINES:
            sel = eng_row == e
            n_sel = int(np.sum(sel))
            if n_sel:
                name = ENGINE_NAMES[e]
                pick_counts[name] = n_sel
                picks.inc(n_sel, engine=name)
                bytes_c.inc(float(np.sum(byte_row[sel])), engine=name)
            secs_c.inc(float(pet[k][e]), engine=ENGINE_NAMES[e])
        iters_c.inc(1)
        mis_c.inc(int(mis[k]))
        frontier_h.observe(float(av[k]))
        obs.instant(
            EV_ITERATION, cat=CAT_ITERATION, track=track, vt=vt,
            bytes=float(np.sum(byte_row)),
            modeled_seconds=float(ttime[k]),
            active_vertices=int(av[k]),
            active_edges=float(ae[k]),
            n_tasks=int(nt[k]),
            mispredictions=int(mis[k]),
            picks=pick_counts,
        )
        obs.counter("frontier", float(av[k]), track=track, vt=vt)


def record_chunk(
    obs: Any, *, track: str, wall_start: float, wall_dur: float,
    start_iter: int, n_done: int, warm: bool,
) -> None:
    """One span per chunk dispatch: wall window = dispatch + execution +
    drain, virtual window = the iterations the chunk executed."""
    obs.span(
        "chunk", cat=CAT_RUN, track=track, wall=wall_start,
        wall_dur=wall_dur, vt=float(start_iter), vt_dur=float(n_done),
        n_done=int(n_done), warm=bool(warm),
    )


def record_ici(
    obs: Any, *, track: str, it: int, bytes_: float, seconds: float,
    engine: int, merged_entries: float, wall: float | None = None,
    halo_entries: float | None = None,
) -> None:
    """One instant per sharded-iteration ICI exchange (dense vs compact
    all-reduce pick), plus the unified ICI metrics.  ``halo_entries`` is
    set on owner-sharded runs: the boundary entries a compacted exchange
    would actually ship (``merged_entries`` capped at the runtime's
    ``HaloPlan.halo_total``), surfaced as the ``ici.halo_bytes``
    counter (8 B per entry, matching ``halo_level_cost``)."""
    name = ENGINE_NAMES.get(int(engine), str(int(engine)))
    m = obs.metrics
    m.counter("ici.bytes", "modeled cross-device merge bytes").inc(
        float(bytes_), engine=name)
    m.counter("ici.picks", "ICI exchange-level engine picks").inc(
        1, engine=name)
    m.counter("ici.modeled_seconds", "modeled ICI merge seconds").inc(
        float(seconds), engine=name)
    extra = {}
    if halo_entries is not None:
        m.counter(
            "ici.halo_bytes",
            "compacted owner-halo exchange bytes (8 B/boundary entry)",
        ).inc(float(halo_entries) * 8.0, engine=name)
        extra["halo_entries"] = float(halo_entries)
    obs.instant(
        EV_ICI_MERGE, cat=CAT_ICI, track=track, vt=float(it), wall=wall,
        bytes=float(bytes_), modeled_seconds=float(seconds), engine=name,
        merged_entries=float(merged_entries), **extra,
    )


def record_run(
    obs: Any, result: Any, *, track: str = "device0", wall_start: float,
    wall_dur: float, program: str = "", label: str = "run",
) -> None:
    """The run-summary span: totals copied verbatim from the finished
    ``HyTMResult`` (exact-reconciliation anchor for ``export.reconcile``)."""
    obs.span(
        EV_RUN, cat=CAT_RUN, track=track, wall=wall_start,
        wall_dur=wall_dur, vt=0.0, vt_dur=float(result.iterations),
        label=label, program=program,
        iterations=int(result.iterations),
        transfer_bytes=float(result.total_transfer_bytes),
        modeled_seconds=float(result.modeled_seconds),
        mispredictions=int(result.total_mispredictions),
        ici_bytes=float(result.total_ici_bytes),
        ici_modeled_seconds=float(result.modeled_ici_seconds),
        wall_seconds=float(result.wall_seconds),
    )
