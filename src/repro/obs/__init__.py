"""repro.obs — unified tracing, metrics, and trace export.

One observability layer across the engine (``core.hytm``), mesh
(``dist.graph_shard``), streaming (``stream.service``), and serving
(``serve.scheduler`` / ``serve.warm_cache``) stacks:

* :class:`TraceRecorder` — host-side span/event ring with virtual-clock
  *and* wall-clock timestamps (``trace.py``);
* :class:`MetricsRegistry` — labeled counter/gauge/histogram registry
  unifying the per-engine bytes/time, ICI pick, misprediction,
  admission, cache-tier and lane-occupancy counters (``metrics.py``);
* ``export`` — Chrome trace-event JSON (``chrome://tracing`` /
  Perfetto), JSONL streaming, and a ``summary()``/``reconcile()`` that
  cross-checks the trace against ``HyTMResult`` totals exactly.

Contract: host-side only (events come from drained chunk history and
scheduler/cache callbacks, never from inside jit-traced code);
zero-overhead when disabled (every instrumentation site guards on
``obs is not None``, so the untraced path is bit-identical); every event
carries both clocks.  Gated by ``benchmarks/obs_bench.py --selfcheck``.
"""

from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import NullRecorder, TraceEvent, TraceRecorder
from repro.obs.export import (
    reconcile,
    summary,
    to_chrome_trace,
    validate_chrome_trace,
    write_chrome_trace,
    write_jsonl,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NullRecorder",
    "TraceEvent",
    "TraceRecorder",
    "reconcile",
    "summary",
    "to_chrome_trace",
    "validate_chrome_trace",
    "write_chrome_trace",
    "write_jsonl",
]
