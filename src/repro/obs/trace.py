"""Host-side span/event recorder — the core of ``repro.obs``.

Contract (ROADMAP module map):

* **host-side only** — events are emitted from drained chunk history and
  scheduler/cache callbacks, never inside jit-traced code.  Nothing in
  this module ever touches a ``jax.Array`` that has not already been
  fetched to host, so recording cannot perturb compilation, donation, or
  dispatch of the runs it observes.
* **zero-overhead disabled** — every instrumentation site threads an
  ``obs`` parameter that defaults to ``None`` and guards emission with
  ``if obs is not None``; the untraced path executes the exact same jit
  programs and is bit-identical by construction (``benchmarks/obs_bench
  --selfcheck`` proves it anyway).  ``NullRecorder`` exists for callers
  that prefer an always-valid object over a ``None`` guard.
* **virtual + wall clocks** — every event carries both a virtual-clock
  timestamp (engine iterations, the serving stack's deterministic time
  base) and a wall-clock timestamp (seconds since the recorder's
  creation).  The Chrome export lays spans out on the wall clock and
  keeps the virtual clock in ``args``.

The event buffer is a bounded ring (``capacity`` events): a runaway
producer overwrites the oldest events and increments ``dropped`` instead
of growing without bound.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Any, Iterator

from repro.obs.metrics import MetricsRegistry

# Event phases, mirroring the Chrome trace-event vocabulary the export
# layer targets: complete span, instant, counter sample.
PH_SPAN = "X"
PH_INSTANT = "i"
PH_COUNTER = "C"

DEFAULT_CAPACITY = 1 << 16


@dataclasses.dataclass
class TraceEvent:
    """One recorded event.  ``wall``/``wall_dur`` are seconds relative to
    the recorder's creation; ``vt``/``vt_dur`` are virtual-clock units
    (engine iterations).  ``track`` names the timeline the event belongs
    to (a device, a lane, a tenant) — the export layer maps each distinct
    track to its own thread row."""

    name: str
    ph: str
    cat: str
    track: str
    wall: float
    vt: float
    wall_dur: float = 0.0
    vt_dur: float = 0.0
    args: dict[str, Any] = dataclasses.field(default_factory=dict)


class TraceRecorder:
    """Bounded-ring recorder with an attached metrics registry.

    All emission helpers are plain host Python — cheap enough to call
    from drain loops (one call per iteration row, not per vertex), and
    never called from inside traced code.
    """

    enabled = True

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self.capacity = int(capacity)
        self.events: collections.deque[TraceEvent] = collections.deque(
            maxlen=self.capacity
        )
        self.dropped = 0
        self.metrics = MetricsRegistry()
        self._wall0 = time.monotonic()

    # -- clocks ----------------------------------------------------------
    def wall(self) -> float:
        """Seconds since the recorder was created (the trace's wall origin)."""
        return time.monotonic() - self._wall0

    def wall_at(self, t_monotonic: float) -> float:
        """Convert a caller-captured ``time.monotonic()`` stamp into the
        trace's wall coordinates (instrumentation sites already take
        these stamps for their own accounting — reuse, don't re-read)."""
        return t_monotonic - self._wall0

    # -- emission --------------------------------------------------------
    def _push(self, ev: TraceEvent) -> None:
        if len(self.events) == self.capacity:
            self.dropped += 1
        self.events.append(ev)

    def span(
        self, name: str, *, cat: str = "host", track: str = "main",
        wall: float, wall_dur: float = 0.0, vt: float = 0.0,
        vt_dur: float = 0.0, **args: Any,
    ) -> None:
        """Record a completed span (explicit start + duration)."""
        self._push(TraceEvent(name, PH_SPAN, cat, track, wall, vt,
                              wall_dur, vt_dur, args))

    def instant(
        self, name: str, *, cat: str = "event", track: str = "main",
        vt: float = 0.0, wall: float | None = None, **args: Any,
    ) -> None:
        """Record an instantaneous event (defaults to 'now' on the wall)."""
        w = self.wall() if wall is None else wall
        self._push(TraceEvent(name, PH_INSTANT, cat, track, w, vt, args=args))

    def counter(
        self, name: str, value: float, *, cat: str = "counter",
        track: str = "main", vt: float = 0.0, wall: float | None = None,
    ) -> None:
        """Record a counter sample (renders as a counter track in Chrome)."""
        w = self.wall() if wall is None else wall
        self._push(TraceEvent(name, PH_COUNTER, cat, track, w, vt,
                              args={"value": float(value)}))

    @contextlib.contextmanager
    def timed(
        self, name: str, *, cat: str = "host", track: str = "main",
        vt: float = 0.0, vt_dur: float = 0.0, **args: Any,
    ) -> Iterator[dict[str, Any]]:
        """Context manager recording a wall-timed span around its body.

        Yields the span's ``args`` dict so the body can attach results
        (bytes moved, iterations run) discovered while the span is open.
        """
        t0 = self.wall()
        try:
            yield args
        finally:
            self.span(name, cat=cat, track=track, wall=t0,
                      wall_dur=self.wall() - t0, vt=vt, vt_dur=vt_dur, **args)

    # -- views -----------------------------------------------------------
    def drain(self) -> list[TraceEvent]:
        """Snapshot-and-clear the event ring (for streaming JSONL export)."""
        out = list(self.events)
        self.events.clear()
        return out

    def __len__(self) -> int:
        return len(self.events)


class NullRecorder:
    """API-compatible no-op recorder.  Instrumentation sites normally
    guard with ``if obs is not None`` (so the disabled path pays nothing,
    not even a method call); this class exists for callers that want to
    pass a recorder unconditionally."""

    enabled = False
    dropped = 0
    capacity = 0

    def __init__(self):
        self.events: collections.deque[TraceEvent] = collections.deque(maxlen=0)
        self.metrics = MetricsRegistry()

    def wall(self) -> float:
        return 0.0

    def wall_at(self, t_monotonic: float) -> float:
        return 0.0

    def span(self, name: str, **kw: Any) -> None:
        pass

    def instant(self, name: str, **kw: Any) -> None:
        pass

    def counter(self, name: str, value: float, **kw: Any) -> None:
        pass

    @contextlib.contextmanager
    def timed(self, name: str, **kw: Any) -> Iterator[dict[str, Any]]:
        yield {}

    def drain(self) -> list[TraceEvent]:
        return []

    def __len__(self) -> int:
        return 0
