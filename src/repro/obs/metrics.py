"""Labeled counter/gauge/histogram registry for ``repro.obs``.

Unifies the counters today scattered across ``HyTMResult`` fields,
``ServiceStats.extra``, ``SchedulerStats``, ``QueueStats`` and
``CacheStats`` into one queryable namespace: per-engine bytes/time, ICI
exchange picks, mispredictions, admission defer/reject, cache tier
hit/spill/promote, lane occupancy.

Deliberately tiny and dependency-free: metrics are plain host-side
Python accumulators keyed by ``(name, sorted label items)``.  They are
*derived* views — the runtime's own accounting (``HyTMResult``,
``*Stats``) stays authoritative, and ``repro.obs.export.reconcile``
checks the two agree exactly.
"""

from __future__ import annotations

import bisect
from typing import Any, Iterator

LabelKey = tuple[tuple[str, str], ...]


def _label_key(labels: dict[str, Any]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class Counter:
    """Monotonically increasing sum per label set."""

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._values: dict[LabelKey, float] = {}

    def inc(self, value: float = 1.0, **labels: Any) -> None:
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0.0) + float(value)

    def value(self, **labels: Any) -> float:
        return self._values.get(_label_key(labels), 0.0)

    def total(self) -> float:
        return sum(self._values.values())

    def items(self) -> Iterator[tuple[LabelKey, float]]:
        return iter(sorted(self._values.items()))


class Gauge:
    """Last-written value per label set (plus the observed max)."""

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._values: dict[LabelKey, float] = {}
        self._max: dict[LabelKey, float] = {}

    def set(self, value: float, **labels: Any) -> None:
        key = _label_key(labels)
        v = float(value)
        self._values[key] = v
        self._max[key] = max(self._max.get(key, v), v)

    def value(self, **labels: Any) -> float:
        return self._values.get(_label_key(labels), 0.0)

    def max(self, **labels: Any) -> float:
        return self._max.get(_label_key(labels), 0.0)

    def items(self) -> Iterator[tuple[LabelKey, float]]:
        return iter(sorted(self._values.items()))


# Default histogram buckets: wide log-spaced range that covers both byte
# counts and (modeled or wall) second durations without configuration.
DEFAULT_BUCKETS = tuple(10.0 ** e for e in range(-9, 13))


class Histogram:
    """Cumulative bucket counts + sum/count per label set."""

    def __init__(self, name: str, help: str = "",
                 buckets: tuple[float, ...] = DEFAULT_BUCKETS):
        self.name, self.help = name, help
        self.buckets = tuple(sorted(buckets))
        self._counts: dict[LabelKey, list[int]] = {}
        self._sum: dict[LabelKey, float] = {}
        self._n: dict[LabelKey, int] = {}

    def observe(self, value: float, **labels: Any) -> None:
        key = _label_key(labels)
        if key not in self._counts:
            self._counts[key] = [0] * (len(self.buckets) + 1)
        v = float(value)
        self._counts[key][bisect.bisect_left(self.buckets, v)] += 1
        self._sum[key] = self._sum.get(key, 0.0) + v
        self._n[key] = self._n.get(key, 0) + 1

    def count(self, **labels: Any) -> int:
        return self._n.get(_label_key(labels), 0)

    def sum(self, **labels: Any) -> float:
        return self._sum.get(_label_key(labels), 0.0)

    def items(self) -> Iterator[tuple[LabelKey, dict[str, Any]]]:
        for key in sorted(self._n):
            yield key, {"count": self._n[key], "sum": self._sum[key],
                        "buckets": list(self._counts[key])}


class MetricsRegistry:
    """Get-or-create registry of named metrics.

    Re-registering a name returns the existing instance (so independent
    instrumentation sites can share a metric without coordination);
    re-registering under a different type raises.
    """

    def __init__(self):
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}

    def _get(self, cls, name: str, help: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help, **kw)
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {type(m).__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def get(self, name: str):
        return self._metrics.get(name)

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def snapshot(self) -> dict[str, Any]:
        """Plain-dict dump of every metric, for ``export.summary`` and
        JSON serialization.  Label keys flatten to ``k=v,k2=v2`` strings
        (empty label set → ``""``)."""
        out: dict[str, Any] = {}
        for name, m in sorted(self._metrics.items()):
            if isinstance(m, Counter):
                out[name] = {
                    "type": "counter",
                    "values": {_fmt(k): v for k, v in m.items()},
                    "total": m.total(),
                }
            elif isinstance(m, Gauge):
                out[name] = {
                    "type": "gauge",
                    "values": {_fmt(k): v for k, v in m.items()},
                    "max": {_fmt(k): m._max[k] for k in sorted(m._max)},
                }
            else:
                out[name] = {
                    "type": "histogram",
                    "values": {_fmt(k): v for k, v in m.items()},
                }
        return out


def _fmt(key: LabelKey) -> str:
    return ",".join(f"{k}={v}" for k, v in key)
