"""Cost-model calibration launcher: probe -> fit -> persist.

    # calibrate this machine (wall-clock probes) and save to the registry
    PYTHONPATH=src python -m repro.launch.calibrate

    # simulate a platform: fit the PCIe profile against a TPU-modeled
    # ground truth (deterministic; what CI and tests exercise)
    PYTHONPATH=src python -m repro.launch.calibrate --mode model \\
        --initial pcie3 --truth tpu_v5e_hbm

    PYTHONPATH=src python -m repro.launch.calibrate --selfcheck

``--selfcheck`` runs the calibration acceptance contract and exits
non-zero on any violation:

  1. mis-specified profile (PCIe constants, TPU-modeled hardware): the
     calibrated selection's total regret vs the measured-best oracle is
     *strictly* lower than the static selection's;
  2. correctly-specified profile (TPU on TPU): calibration is a no-op —
     selection decisions unchanged across the probe grid.  (The PCIe
     profile is excluded by design: its selection deliberately omits the
     CPU compaction pass that measurement pays — paper §V-A — so its
     thresholds are always fair game for tuning.);
  3. registry round-trip: save -> load reproduces identical selection;
  4. regret never worse, with and without measurement noise;
  5. online loop: ``HyTMConfig.autotune`` leaves traversal results
     bit-identical while recording corrections and mispredictions.
"""

from __future__ import annotations

import argparse
import sys

import numpy as np


def _profiles():
    from repro.core.constants import PCIE3, TPU_V5E_HBM, TPU_V5E_ICI

    return {"pcie3": PCIE3, "tpu_v5e_hbm": TPU_V5E_HBM, "tpu_v5e_ici": TPU_V5E_ICI}


def selfcheck() -> None:
    import dataclasses
    import tempfile

    from repro.autotune import (
        calibrate,
        default_grid,
        load_profile,
        model_probe,
        save_profile,
        selection_on_grid,
    )
    from repro.core.constants import PCIE3, TPU_V5E_HBM

    points = default_grid()

    # 1. mis-specified initial profile: strictly lower regret
    obs = model_probe(points, TPU_V5E_HBM)
    rep = calibrate(points, obs, PCIE3)
    assert rep.calibrated_regret < rep.static_regret, (
        f"calibration did not improve a mis-specified profile: "
        f"{rep.calibrated_regret} !< {rep.static_regret}")
    assert rep.improved
    print(f"  mis-specified: regret {rep.static_regret:.3e} -> "
          f"{rep.calibrated_regret:.3e} "
          f"(oracle total {rep.oracle_seconds:.3e} s)")

    # 2. correctly-specified profile: selection is a no-op on the grid
    rep_ok = calibrate(points, model_probe(points, TPU_V5E_HBM), TPU_V5E_HBM)
    before = selection_on_grid(points, TPU_V5E_HBM)
    after = selection_on_grid(points, rep_ok.profile)
    changed = int(np.sum(before != after))
    assert changed == 0, f"correct profile: {changed} selection decisions changed"
    print(f"  correctly-specified: no-op (0/{len(points)} decisions changed)")

    # 3. registry round-trip preserves selection exactly
    with tempfile.TemporaryDirectory() as tmp:
        save_profile(rep.profile, device_kind="selfcheck", base=tmp,
                     meta={"static_regret": rep.static_regret})
        loaded = load_profile(device_kind="selfcheck", base=tmp)
    assert loaded == rep.profile, "round-trip changed the profile"
    np.testing.assert_array_equal(
        selection_on_grid(points, loaded), selection_on_grid(points, rep.profile))
    print("  registry round-trip: identical profile + selection")

    # 4. regret never worse, incl. under measurement noise
    for initial, truth, noise in [
        (PCIE3, TPU_V5E_HBM, 0.05),
        (TPU_V5E_HBM, PCIE3, 0.0),
        (TPU_V5E_HBM, TPU_V5E_HBM, 0.1),
    ]:
        o = model_probe(points, truth, noise=noise, seed=7)
        r = calibrate(points, o, initial)
        assert r.calibrated_regret <= r.static_regret + 1e-12, (
            initial.name, truth.name, noise, r)
    print("  regret-never-worse: held across profile pairs and noise")

    # 5. online feedback: results unchanged, diagnostics recorded
    from repro.core.hytm import HyTMConfig, run_hytm
    from repro.graph.algorithms import SSSP
    from repro.graph.generators import rmat_graph

    g = rmat_graph(1000, 12_000, seed=3)
    cfg = HyTMConfig(n_partitions=8)
    base = run_hytm(g, SSSP, source=0, config=cfg)
    tuned = run_hytm(g, SSSP, source=0,
                     config=dataclasses.replace(cfg, autotune=True))
    np.testing.assert_array_equal(base.values, tuned.values)
    assert tuned.engine_corrections is not None
    assert tuned.engine_corrections.shape == (3,)
    assert "mispredictions" in tuned.history
    print(f"  online loop: SSSP bit-identical, corrections="
          f"{np.round(tuned.engine_corrections, 3)}")

    print("SELFCHECK OK")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--selfcheck", action="store_true")
    ap.add_argument("--mode", choices=["wall", "model"], default="wall",
                    help="wall: time the engines on this machine; "
                         "model: simulate a ground-truth link")
    ap.add_argument("--initial", default=None,
                    help="initial profile name (default: by jax platform)")
    ap.add_argument("--truth", default="tpu_v5e_hbm",
                    help="ground-truth profile for --mode model")
    ap.add_argument("--noise", type=float, default=0.0,
                    help="multiplicative measurement noise for --mode model")
    ap.add_argument("--max-edges", type=int, default=200_000,
                    help="cap on materialized edges per wall probe point")
    ap.add_argument("--use-kernels", choices=["auto", "on", "off"],
                    default="auto",
                    help="wall-probe the Pallas-kernel-backed engines "
                         "(mirrors HyTMConfig.use_kernels; 'auto' follows "
                         "the backend, so the probes time the same path "
                         "the runtime will dispatch)")
    ap.add_argument("--device-kind", default=None,
                    help="registry key (default: detected device kind)")
    ap.add_argument("--registry", default=None,
                    help="registry directory (default: "
                         "$REPRO_AUTOTUNE_REGISTRY or ~/.cache/repro/autotune)")
    ap.add_argument("--dry-run", action="store_true",
                    help="calibrate and report, but do not save")
    args = ap.parse_args()

    if args.selfcheck:
        try:
            selfcheck()
        except AssertionError as e:
            print(f"SELFCHECK FAILED: {e}", file=sys.stderr)
            sys.exit(1)
        return

    import jax

    from repro.autotune import (
        calibrate,
        default_grid,
        model_probe,
        save_profile,
        wall_probe,
    )

    profiles = _profiles()
    if args.initial is not None:
        initial = profiles[args.initial]
    else:
        initial = (profiles["tpu_v5e_hbm"]
                   if jax.devices()[0].platform == "tpu" else profiles["pcie3"])

    if args.mode == "model":
        points = default_grid()
        obs = model_probe(points, profiles[args.truth], noise=args.noise)
    else:
        # wall probes materialize edges: keep E levels machine-sized.
        # calibrate against the materialized grid the probe reports —
        # capped points are measured (and fitted) at their real size
        points = default_grid(edge_levels=(3.1e4, 1.1e5, 4.1e5), n_ratios=7)
        uk = {"auto": "auto", "on": True, "off": False}[args.use_kernels]
        points, obs = wall_probe(points, max_edges=args.max_edges,
                                 use_kernels=uk)

    # wall measurements pay real per-call dispatch -> refit the overhead
    rep = calibrate(points, obs, initial, fit_overhead=args.mode == "wall")
    print(f"calibrated from {initial.name!r} over {rep.n_points} probe points "
          f"({rep.n_observations} observations, mode={args.mode})")
    print(f"  regret: static {rep.static_regret:.3e} s -> "
          f"calibrated {rep.calibrated_regret:.3e} s "
          f"(oracle {rep.oracle_seconds:.3e} s)")
    for k, v in rep.fitted.items():
        print(f"  {k:>22}: {v:.6g}")

    device_kind = args.device_kind
    if args.mode == "model" and device_kind is None:
        # a simulated-truth fit must never overwrite this machine's real
        # wall-calibrated entry by default — key it by the simulation
        device_kind = f"model-{args.truth}"
        print(f"(model mode: saving under device kind {device_kind!r}; "
              f"pass --device-kind to override)")
    if not args.dry_run:
        path = save_profile(
            rep.profile, device_kind=device_kind, base=args.registry,
            meta={
                "initial": initial.name,
                "mode": args.mode,
                "static_regret": rep.static_regret,
                "calibrated_regret": rep.calibrated_regret,
                "n_observations": rep.n_observations,
            },
        )
        print(f"saved -> {path}")


if __name__ == "__main__":
    main()
