"""Training launcher: ``--arch <id>`` selects the architecture config;
runs the fault-tolerant training loop on the local device set (the
production path jit-shards the same step functions over the mesh — see
launch/dryrun.py for the mesh lowering of every arch x shape cell).

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --steps 50 --scale tiny
"""

from __future__ import annotations

import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    from repro.configs import get_arch
    from repro.data.pipeline import LMBatches
    from repro.models import transformer as tf
    from repro.train.fault_tolerance import FaultTolerantLoop
    from repro.train.optimizer import OptimizerConfig
    from repro.train.train_step import init_train_state, make_train_step

    arch = get_arch(args.arch)
    if arch.family != "lm":
        raise SystemExit(
            f"{args.arch} is a {arch.family} arch; use examples/train_gnn.py "
            "or examples/ for non-LM training drivers."
        )

    # reduced config of the same family (full configs are mesh-scale:
    # exercise them via repro.launch.dryrun)
    from repro.configs.common import reduce_lm_config
    cfg = reduce_lm_config(arch.model_config)
    print(f"arch={args.arch} (reduced: {cfg.n_layers}L d={cfg.d_model} "
          f"moe={'yes' if cfg.moe else 'no'} attn={cfg.attention})")

    oc = OptimizerConfig(learning_rate=1e-3, warmup_steps=10, total_steps=args.steps)
    params = tf.init_transformer(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params, oc)
    pipe = LMBatches(vocab=cfg.vocab, batch=args.batch, seq_len=args.seq)
    step = jax.jit(make_train_step(lambda p, b: tf.lm_loss(p, b["tokens"], cfg), oc))

    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="repro-ckpt-")
    loop = FaultTolerantLoop(
        step_fn=step, batch_fn=lambda s: {"tokens": pipe.make(s)["tokens"]},
        ckpt_dir=ckpt, ckpt_every=max(args.steps // 4, 1),
    )
    state, log, _ = loop.run(state, args.steps)
    print(f"loss {log[0]['loss']:.4f} -> {log[-1]['loss']:.4f} "
          f"(checkpoints in {ckpt})")


if __name__ == "__main__":
    main()
