"""Graph-query serving launcher: a ``repro.stream.GraphService`` driven
by a synthetic query/update trace.

    PYTHONPATH=src python -m repro.launch.serve_graph --nodes 5000 \\
        --edges 80000 --algorithm sssp --queries 32 --update-batches 4

    PYTHONPATH=src python -m repro.launch.serve_graph --selfcheck

``--selfcheck`` runs the serving equivalence contract on a small graph
(batched == independent runs, cached repeat == zero sweeps, incremental
after updates == from-scratch) and exits non-zero on any violation —
CI runs it on 8 forced-host CPU devices.

``--trace <path>`` threads a ``repro.obs.TraceRecorder`` through the
service (engine iterations, cache tier transitions, scheduler spans)
and writes a Chrome trace-event JSON viewable in chrome://tracing or
Perfetto.  ``--algorithm wcc`` runs weakly connected components — the
graph is symmetrized up front (``VertexProgram.symmetrize``).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np


def selfcheck() -> None:
    import jax

    from repro.core.hytm import HyTMConfig, run_hytm
    from repro.graph.algorithms import PAGERANK, SSSP
    from repro.graph.generators import rmat_graph
    from repro.stream import GraphService, random_batch

    g = rmat_graph(500, 4000, seed=17)
    cfg = HyTMConfig(n_partitions=8)
    svc = GraphService(g, cfg, max_lanes=4)
    rng = np.random.default_rng(17)

    # 1. batched lanes == independent single-source runs (bit-exact)
    sources = [0, 3, 77, 210]
    batched = svc.query(SSSP, sources)
    for s, r in zip(sources, batched):
        solo = run_hytm(g, SSSP, source=s, config=cfg)
        np.testing.assert_array_equal(r.values, solo.values)
    assert all(r.mode == "batched" for r in batched)

    # 2. cached repeat: zero sweep iterations
    again = svc.query(SSSP, sources)
    assert all(r.cache_hit and r.iterations == 0 for r in again)

    # 3. update invalidates the cache; incremental matches from-scratch
    svc.update(random_batch(svc.dcsr, rng, n_insert=16, n_delete=16))
    post = svc.query(SSSP, sources)
    assert all(r.mode == "incremental" for r in post)
    g2 = svc.dcsr.to_host_graph()
    for s, r in zip(sources, post):
        fs = run_hytm(g2, SSSP, source=s, config=cfg)
        np.testing.assert_array_equal(r.values, fs.values)

    # 4. accumulative program: tolerance-bounded incremental equivalence
    pr = dataclasses.replace(PAGERANK, tolerance=1e-7)
    svc.query(pr, None)
    svc.update(random_batch(svc.dcsr, rng, n_insert=8, n_delete=8))
    inc = svc.query(pr, None)[0]
    assert inc.mode == "incremental"
    fs = run_hytm(svc.dcsr.to_host_graph(), pr, source=None, config=cfg)
    assert np.max(np.abs(inc.values - fs.values)) < 1e-3

    # 5. the serving path coexists with the sharded sweep (multi-device
    # hosts): a fresh query equals a mesh-sharded run of the same graph
    if len(jax.devices()) > 1:
        sharded = run_hytm(
            g2, SSSP, source=0,
            config=dataclasses.replace(cfg, async_sweep=False, mesh_axis="graph"),
        )
        np.testing.assert_array_equal(
            sharded.values, run_hytm(g2, SSSP, source=0, config=cfg).values
        )

    # 6. multi-tenant scheduler contract (repro.serve): EDF admission
    # under per-tenant quotas + a device byte budget small enough to
    # force cache spills — answers must still equal solo runs, the
    # budget must hold, and no quota may be exceeded mid-flight
    from repro.graph.algorithms import PPR
    from repro.serve import Request, RequestQueue

    n = svc.dcsr.n_nodes
    tiny = GraphService(svc.dcsr.to_host_graph(), cfg, max_lanes=2,
                        device_budget_bytes=2 * 9 * n)
    q = RequestQueue(quota=2, tenant_quotas={"bronze": 1})
    for i, s in enumerate([0, 3, 77, 210, 3, 9]):
        tenant = ["gold", "silver", "bronze"][i % 3]
        q.submit(Request(tenant=tenant, program=SSSP, source=s,
                         deadline=float(i)))
    served = tiny.scheduler.pump(q)
    assert len(served) == 6 and q.stats.rejected == 0
    g3 = tiny.dcsr.to_host_graph()
    for r in served:
        solo = run_hytm(g3, SSSP, source=r.request.source, config=cfg)
        np.testing.assert_array_equal(r.values, solo.values)
    assert tiny.scheduler.stats.max_device_bytes <= 2 * 9 * n

    # personalized PageRank serves through the same lanes (tolerance
    # program: oracle comparison lives in tests/test_serve.py)
    ppr = dataclasses.replace(PPR, tolerance=1e-7)
    r = tiny.query(ppr, [0])[0]
    assert r.mode == "batched" and r.iterations > 0

    print(f"SELFCHECK OK ({len(jax.devices())} device(s)) — "
          f"stats: {svc.stats}; serve: {tiny.scheduler.stats} "
          f"cache: {tiny.cache.stats.as_dict()}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--selfcheck", action="store_true")
    ap.add_argument("--nodes", type=int, default=5000)
    ap.add_argument("--edges", type=int, default=80_000)
    ap.add_argument("--partitions", type=int, default=32)
    ap.add_argument("--algorithm", default="sssp",
                    choices=["sssp", "bfs", "cc", "wcc", "pagerank", "php",
                             "ppr"])
    ap.add_argument("--queries", type=int, default=16)
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--update-batches", type=int, default=4)
    ap.add_argument("--update-size", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--device-budget-bytes", type=int, default=None,
                    help="device byte budget for in-flight lane state + "
                         "the warm cache's device tier (overflow spills "
                         "to host RAM; default: unbounded)")
    ap.add_argument("--lane-buckets", default=None,
                    help="comma-separated static lane bucket sizes for "
                         "the serving scheduler (default: powers of two "
                         "up to --lanes); admission never recompiles")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record the run through repro.obs and write a "
                         "Chrome trace-event JSON to PATH "
                         "(chrome://tracing / Perfetto)")
    ap.add_argument("--calibrated", action="store_true",
                    help="use the calibrated LinkModel profile from the "
                         "autotune registry if one exists; a corrupt "
                         "profile warns and falls back to the shipped "
                         "constants (never fatal)")
    args = ap.parse_args()

    if args.selfcheck:
        selfcheck()
        return

    from repro.core.hytm import HyTMConfig
    from repro.graph.algorithms import ALGORITHMS
    from repro.graph.generators import rmat_graph
    from repro.stream import GraphService, random_batch

    program = ALGORITHMS[args.algorithm]
    g = rmat_graph(args.nodes, args.edges, seed=args.seed)
    if program.symmetrize:
        # WCC sweeps the undirected edge set; the streaming runtime is
        # built straight from this graph, so symmetrize before serving
        g = g.symmetrize()
    cfg = HyTMConfig(n_partitions=args.partitions)
    if args.calibrated:
        from repro.autotune.registry import load_profile_or_default

        cfg = dataclasses.replace(cfg, link=load_profile_or_default())
    buckets = (tuple(int(b) for b in args.lane_buckets.split(","))
               if args.lane_buckets else None)
    rec = None
    if args.trace:
        from repro.obs import TraceRecorder

        rec = TraceRecorder()
    svc = GraphService(g, cfg, max_lanes=args.lanes,
                       device_budget_bytes=args.device_budget_bytes,
                       lane_buckets=buckets, obs=rec)
    rng = np.random.default_rng(args.seed)

    sources = rng.integers(0, args.nodes, size=args.queries).tolist()
    t0 = time.monotonic()
    svc.query(program, sources)
    t_cold = time.monotonic() - t0

    t0 = time.monotonic()
    for _ in range(args.update_batches):
        svc.update(random_batch(
            svc.dcsr, rng,
            n_insert=args.update_size // 2, n_delete=args.update_size // 2,
        ))
        svc.query(program, sources[: max(1, args.lanes)])
    t_stream = time.monotonic() - t0

    s = svc.stats
    print(f"{args.algorithm}: {args.queries} cold queries in {t_cold:.2f}s "
          f"({args.queries / max(t_cold, 1e-9):.1f} q/s)")
    print(f"streaming: {args.update_batches} update batches "
          f"(x{args.update_size} edges) + warm queries in {t_stream:.2f}s")
    print(f"stats: hits={s.n_cache_hits} incremental={s.n_incremental} "
          f"full={s.n_full} sweeps={s.sweep_iterations} "
          f"updated_edges={s.update_edges} version={svc.version}")
    print(f"cache tiers: {svc.cache.stats.as_dict()} "
          f"(device_bytes={svc.cache.device_bytes})")
    if rec is not None:
        from repro.obs import write_chrome_trace

        write_chrome_trace(rec, args.trace)
        print(f"trace: {len(rec)} events -> {args.trace}")


if __name__ == "__main__":
    main()
