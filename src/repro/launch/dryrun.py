import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the
device count at first init).  For every cell this produces:

  * ``memory_analysis()``  — proves the program fits per-device HBM,
  * ``cost_analysis()``    — per-device HLO FLOPs / bytes,
  * collective byte census — parsed from the post-SPMD HLO text,

which benchmarks/roofline.py turns into the three roofline terms.
Results land in ``experiments/dryrun/<arch>__<shape>__<mesh>.json``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                   # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-12b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single     # 16x16 only
"""

import argparse
import json
import re
import time
import traceback
from collections import defaultdict

import jax

from repro.configs import get_arch, list_archs
from repro.launch.mesh import make_production_mesh

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

# result shapes like: bf16[8,128,2048]{2,1,0} or tuple results "(f32[..], ..)"
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_census(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in the per-device HLO, and
    estimate per-device ICI wire bytes with ring-algorithm factors."""
    per_op = defaultdict(lambda: {"count": 0, "result_bytes": 0.0, "wire_bytes": 0.0})
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\(?[a-z0-9\[\],{}\s/]*\)?)\s*([a-z0-9\-]+)\(", stripped)
        if not m:
            continue
        op = m.group(2)
        base = op.replace("-start", "").replace("-done", "")
        if base not in _COLLECTIVES or op.endswith("-done"):
            continue
        result_bytes = _shape_bytes(m.group(1))
        gm = _GROUPS_RE.search(stripped)
        if gm:
            group = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(stripped)
            group = int(gi.group(2)) if gi else 2
        n = max(group, 2)
        if base == "all-reduce":
            wire = 2.0 * result_bytes * (n - 1) / n
        elif base == "all-gather":
            wire = result_bytes * (n - 1) / n
        elif base == "reduce-scatter":
            wire = result_bytes * (n - 1)       # result is the scattered shard
        elif base == "all-to-all":
            wire = result_bytes * (n - 1) / n
        else:  # collective-permute
            wire = result_bytes
        rec = per_op[base]
        rec["count"] += 1
        rec["result_bytes"] += result_bytes
        rec["wire_bytes"] += wire
    return dict(per_op)


def run_cell(arch_name: str, shape: str, multi_pod: bool) -> dict:
    arch = get_arch(arch_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    record = {
        "arch": arch_name, "shape": shape, "mesh": mesh_name,
        "n_devices": 512 if multi_pod else 256,
    }
    if shape in arch.skips:
        record["status"] = "SKIP"
        record["reason"] = arch.skips[shape]
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    build = arch.cells[shape](mesh)

    t0 = time.time()
    with mesh:
        jitted = jax.jit(
            build.fn,
            in_shardings=build.in_shardings,
            out_shardings=build.out_shardings,
            donate_argnums=build.donate_argnums,
        )
        lowered = jitted.lower(*build.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    census = collective_census(compiled.as_text())

    record.update({
        "status": "OK",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_device_bytes": (
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes
            ),
        },
        "cost": {
            "flops": ca.get("flops", 0.0),
            "bytes_accessed": ca.get("bytes accessed", 0.0),
        },
        "collectives": census,
        "model_flops": build.model_flops,
        "note": build.note,
    })
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch (default: all)")
    ap.add_argument("--shape", default=None, help="single shape (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, "dry-run requires 512 host devices"

    archs = [args.arch] if args.arch else [a for a in list_archs() if a != "hytgraph"]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    failures = []
    for arch_name in archs:
        arch = get_arch(arch_name)
        shapes = [args.shape] if args.shape else arch.shapes()
        for shape in shapes:
            for multi_pod in meshes:
                mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
                tag = f"{arch_name}__{shape}__{mesh_name}"
                try:
                    rec = run_cell(arch_name, shape, multi_pod)
                except Exception as e:  # a failure here is a bug in the system
                    rec = {
                        "arch": arch_name, "shape": shape, "mesh": mesh_name,
                        "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:],
                    }
                    failures.append(tag)
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "OK":
                    peak = rec["memory"]["peak_device_bytes"] / 2**30
                    extra = (
                        f"peak {peak:.2f} GiB/dev | {rec['cost']['flops']:.3g} flops/dev"
                        f" | compile {rec['compile_s']}s"
                    )
                elif status == "FAIL":
                    extra = rec["error"][:160]
                print(f"[{status:4s}] {tag}: {extra}", flush=True)

    if failures:
        print(f"\n{len(failures)} FAILURES: {failures}")
        raise SystemExit(1)
    print("\nall dry-run cells passed")


if __name__ == "__main__":
    main()
