"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_debug_mesh(n_data: int = 2, n_model: int = 2, pods: int = 0):
    """Small mesh over however many (possibly fake) devices exist — used by
    the distributed-correctness tests."""
    if pods:
        shape, axes = (pods, n_data, n_model), ("pod", "data", "model")
    else:
        shape, axes = (n_data, n_model), ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )
