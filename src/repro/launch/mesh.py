"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.

``_mesh`` papers over the jax API skew around mesh axis types:
``jax.make_mesh(..., axis_types=...)`` (and ``jax.sharding.AxisType``)
only exist on newer jax releases; on older ones the plain call is the
same Auto-typed mesh.
"""

from __future__ import annotations

import jax


def _mesh(shape, axes):
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(axis_type.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, pods: int = 0):
    """Small mesh over however many (possibly fake) devices exist — used by
    the distributed-correctness tests."""
    if pods:
        shape, axes = (pods, n_data, n_model), ("pod", "data", "model")
    else:
        shape, axes = (n_data, n_model), ("data", "model")
    return _mesh(shape, axes)


def make_graph_mesh(n_devices: int | None = None, axis: str = "graph"):
    """1-D mesh for the sharded HyTM sweep (repro.dist.graph_shard): the
    partition edge blocks shard over ``axis``.  Defaults to every visible
    device (forced-host devices included)."""
    if n_devices is None:
        n_devices = len(jax.devices())
    return _mesh((n_devices,), (axis,))


def forced_host_device_env(n_devices: int) -> dict:
    """Environment for a subprocess that must see ``n_devices`` fake CPU
    devices — jax locks the device count at first backend init, so
    multi-device tests and benchmarks re-exec with this env instead of
    reconfiguring the parent.  The single definition of the recipe
    (``tests/_forced_devices.py`` and the device-sweep benchmarks both
    build on it), so an environment change lands in one place:

    * ``XLA_FLAGS=--xla_force_host_platform_device_count=N``;
    * ``JAX_PLATFORMS=cpu`` — forced counts only exist on the CPU
      backend; without the pin, a machine with an accelerator would run
      everything on 1 real device;
    * ``PYTHONPATH`` led by this checkout's ``src`` (derived from the
      installed ``repro`` package, so it works from any cwd).
    """
    import os

    import repro

    # repro is a namespace package (no __init__.py): locate src via
    # __path__, not __file__ (which is None)
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["JAX_PLATFORMS"] = "cpu"
    prior = env.get("PYTHONPATH")
    env["PYTHONPATH"] = src + (os.pathsep + prior if prior else "")
    return env
