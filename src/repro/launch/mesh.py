"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.

``_mesh`` papers over the jax API skew around mesh axis types:
``jax.make_mesh(..., axis_types=...)`` (and ``jax.sharding.AxisType``)
only exist on newer jax releases; on older ones the plain call is the
same Auto-typed mesh.
"""

from __future__ import annotations

import jax


def _mesh(shape, axes):
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(axis_type.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, pods: int = 0):
    """Small mesh over however many (possibly fake) devices exist — used by
    the distributed-correctness tests."""
    if pods:
        shape, axes = (pods, n_data, n_model), ("pod", "data", "model")
    else:
        shape, axes = (n_data, n_model), ("data", "model")
    return _mesh(shape, axes)


def make_graph_mesh(n_devices: int | None = None, axis: str = "graph"):
    """1-D mesh for the sharded HyTM sweep (repro.dist.graph_shard): the
    partition edge blocks shard over ``axis``.  Defaults to every visible
    device (forced-host devices included)."""
    if n_devices is None:
        n_devices = len(jax.devices())
    return _mesh((n_devices,), (axis,))
