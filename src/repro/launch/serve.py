"""Serving launcher: batched prefill+decode for an LM arch (reduced
config locally; the full-mesh serving cells lower via launch/dryrun.py).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    from repro.configs import get_arch
    from repro.models import transformer as tf
    from repro.configs.common import reduce_lm_config

    arch = get_arch(args.arch)
    if arch.family != "lm":
        raise SystemExit(f"{args.arch} is not an LM arch")
    cfg = reduce_lm_config(arch.model_config).replace(remat=False)
    params = tf.init_transformer(jax.random.PRNGKey(0), cfg)

    B, P, G = args.requests, args.prompt_len, args.gen
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab)
    caches = tf.init_cache(cfg, B, P + G)
    jit_prefill = jax.jit(lambda p, t, c: tf.prefill(p, t, cfg, c))
    jit_decode = jax.jit(lambda p, t, c, i: tf.decode_step(p, t, cfg, c, i))

    logits, caches = jit_prefill(params, prompts, caches)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.monotonic()
    for s in range(G - 1):
        logits, caches = jit_decode(params, tok, caches, jnp.int32(P + s))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    dt = time.monotonic() - t0
    print(f"{args.arch} (reduced): {B} requests, {G-1} decode steps, "
          f"{B*(G-1)/dt:.0f} tok/s")


if __name__ == "__main__":
    main()
