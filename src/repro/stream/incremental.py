"""Warm-start incremental recomputation after edge-update batches.

Instead of re-running ``run_hytm`` from ``program.init_state`` on the
post-update graph, seed the frontier from update-affected vertices only
and let the unchanged Algorithm-1 machinery (cost model, engine
selection, priority sweep) converge the *residual* work.  The evolving
frontier this produces — small, shifting, re-activated per batch — is
exactly the regime HyTM's per-iteration engine re-selection targets.

Seeding rules by program family:

* **MIN (traversal)** — monotone relaxation can absorb improvements but
  never un-derive a value, so:
    - insertions (and reweights to a smaller weight) activate the edge's
      source: the new edge relaxes on the next sweep;
    - deletions (and reweights to a larger weight) conservatively
      invalidate every vertex whose current value *routed through* a
      removed edge — ``values[v] == edge_message(values[u], w_old)`` —
      then propagate invalidation along the same routed-through relation
      over the live edges to a fixpoint.  Invalidated vertices reset to
      their init values; their live in-neighbors (and, for programs with
      finite init values like CC, the reset vertices themselves) seed the
      frontier.  Over-invalidation is safe: alternative routes re-derive
      the value during the sweep.
* **SUM (accumulative)** — the Δ-invariant says every consumed δ at u
  pushed ``damping * δ * w/W(u)`` along each out-edge, so after u's
  out-distribution changes from p_old to p_new the already-distributed
  mass ``values[u]`` is corrected by injecting *signed* deltas
  ``damping * values[u] * (p_new(x) - p_old(x))`` at each neighbor x
  (pending residual δ follows the new distribution automatically).  The
  core frontiers propagate ``|Δ| > tolerance`` so negative corrections
  travel like positive ones.

Equivalence contract (tests/test_stream.py): the warm-started run matches
a from-scratch ``run_hytm`` on the post-update graph — bit-exact for MIN
programs (the converged fixpoint is unique and each value is the same
f32 path sum), within tolerance-bounded residual for SUM programs.
"""

from __future__ import annotations

from typing import Iterable, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.hytm import HyTMConfig, HyTMResult, HyTMState, run_hytm
from repro.graph.algorithms import MIN, VertexProgram
from repro.stream.delta_csr import DeltaCSR, UpdateReport


def _routed_through(
    program: VertexProgram,
    values: np.ndarray,   # (n,) f32 — pre-update converged values
    src: np.ndarray,
    dst: np.ndarray,
    w: np.ndarray,
) -> np.ndarray:
    """Mask of edges whose destination value equals the edge's message —
    i.e. the destination's value may have been derived via this edge.
    Evaluated in f32 to match the device sweep bit-for-bit."""
    if len(src) == 0:
        return np.zeros(0, bool)
    vs = values[src].astype(np.float32)
    msg = np.asarray(
        program.edge_message(vs, w.astype(np.float32)), dtype=np.float32
    )
    return np.isfinite(vs) & (values[dst].astype(np.float32) == msg)


def seed_min(
    program: VertexProgram,
    values: np.ndarray,
    reports: Sequence[UpdateReport],
    dcsr: DeltaCSR,
    source: int | None,
) -> HyTMState:
    """Frontier/state seed for traversal programs (see module docstring)."""
    n = dcsr.n_nodes
    values = np.asarray(values, np.float32).copy()
    init_vals = np.asarray(program.init_state(n, source)[0])

    ins_src = np.concatenate([r.ins_src for r in reports]) if reports else np.zeros(0, np.int64)
    del_src = np.concatenate([r.del_src for r in reports]) if reports else np.zeros(0, np.int64)
    del_dst = np.concatenate([r.del_dst for r in reports]) if reports else np.zeros(0, np.int64)
    del_w = np.concatenate([r.del_w for r in reports]) if reports else np.zeros(0, np.float32)

    suspect = np.zeros(n, bool)
    routed = _routed_through(program, values, del_src, del_dst, del_w)
    suspect[del_dst[routed]] = True
    if source is not None:
        suspect[source] = False

    ls, ld, lw = dcsr.live_edges()
    routed_live = _routed_through(program, values, ls, ld, lw)
    while True:
        grow = routed_live & suspect[ls] & ~suspect[ld]
        if not grow.any():
            break
        suspect[ld[grow]] = True
        if source is not None:
            suspect[source] = False

    new_vals = values.copy()
    new_vals[suspect] = init_vals[suspect]

    frontier = np.zeros(n, bool)
    if len(ins_src):
        fin = np.isfinite(new_vals[ins_src])
        frontier[ins_src[fin]] = True
    feeds = suspect[ld] & np.isfinite(new_vals[ls])
    frontier[ls[feeds]] = True
    # programs with finite init values (CC) must push the reset labels out
    frontier[suspect & np.isfinite(new_vals)] = True

    return HyTMState(
        values=jnp.asarray(new_vals),
        delta=jnp.zeros(n, jnp.float32),
        frontier=jnp.asarray(frontier),
    )


def seed_sum(
    program: VertexProgram,
    values: np.ndarray,
    delta: np.ndarray,
    reports: Sequence[UpdateReport],
    dcsr: DeltaCSR,
) -> HyTMState:
    """Correction-delta seed for accumulative programs."""
    n = dcsr.n_nodes
    values = np.asarray(values, np.float32)
    new_delta = np.asarray(delta, np.float64).copy()
    damping = program.damping
    weighted = program.weighted

    for rep in reports:
        for u, (pre_d, pre_w) in rep.pre_adj.items():
            post_d, post_w = rep.post_adj[u]
            v_u = float(values[u])
            if v_u == 0.0:
                continue
            if weighted:
                w_old = float(pre_w.sum())
                w_new = float(post_w.sum())
                p_old = pre_w / w_old if w_old > 0 else pre_w
                p_new = post_w / w_new if w_new > 0 else post_w
            else:
                p_old = np.full(len(pre_d), 1.0 / max(len(pre_d), 1))
                p_new = np.full(len(post_d), 1.0 / max(len(post_d), 1))
            if len(pre_d):
                np.subtract.at(new_delta, pre_d, damping * v_u * p_old)
            if len(post_d):
                np.add.at(new_delta, post_d, damping * v_u * p_new)

    new_delta = new_delta.astype(np.float32)
    frontier = np.abs(new_delta) > program.tolerance
    return HyTMState(
        values=jnp.asarray(values),
        delta=jnp.asarray(new_delta),
        frontier=jnp.asarray(frontier),
    )


def incremental_state(
    program: VertexProgram,
    values: np.ndarray,
    delta: np.ndarray,
    reports: Iterable[UpdateReport],
    dcsr: DeltaCSR,
    source: int | None,
) -> HyTMState:
    reports = list(reports)
    if program.combine == MIN:
        return seed_min(program, values, reports, dcsr, source)
    return seed_sum(program, values, delta, reports, dcsr)


def run_incremental(
    dcsr: DeltaCSR,
    program: VertexProgram,
    reports: Iterable[UpdateReport],
    values: np.ndarray,
    delta: np.ndarray,
    source: int | None = 0,
    config: HyTMConfig | None = None,
    calibrator=None,
    mesh=None,
    obs=None,
    faults=None,
    retry=None,
) -> HyTMResult:
    """Converge the post-update graph from the warm (values, Δ) state of a
    previous converged run, seeding only update-affected vertices.

    ``reports`` are the ``DeltaCSR.apply`` reports for every batch applied
    since ``values``/``delta`` were computed, in order.

    With ``config.mesh_axis`` set the residual convergence runs *on the
    mesh*: the same host-side seeding builds the warm (values, Δ,
    frontier) triple, which re-enters the shard_mapped chunked driver
    replicated over the devices, sweeping ``dcsr``'s device-sharded
    (P_pad, B) grid (``DeltaCSR.sharded_runtime_for``).  Sharded
    equivalence guarantee: because seeding is identical and the sharded
    sweep reproduces the single-device ``async_sweep=False`` dataflow,
    the sharded incremental run is bit-identical to the single-device
    incremental run for MIN programs — values, iterations, transfer
    accounting, engine picks — and tolerance-bounded for SUM programs
    (tests/test_stream_sharded.py).  ``mesh`` optionally pins the device
    mesh (defaults to every visible device).

    The run inherits ``config.sync_every``: with K > 1 the residual
    convergence runs through the chunked device-resident driver
    (``core.hytm.hytm_chunk``, or ``graph_shard.make_sharded_chunk`` on
    the mesh).  Incremental runs are exactly where the chunk's early exit
    matters — warm starts converge in a handful of iterations, and the
    while-loop condition stops the chunk the moment the residual frontier
    drains, so a short run never pays for K iterations.  The seeded state
    is materialized fresh per run (``incremental_state`` builds new
    device arrays), so the chunked driver's state donation never
    invalidates the caller's cached warm (values, Δ) buffers."""
    config = config if config is not None else dcsr.config
    state = incremental_state(program, values, delta, reports, dcsr, source)
    if config.mesh_axis is not None:
        runtime = dcsr.sharded_runtime_for(
            program, mesh=mesh, axis=config.mesh_axis)
        return run_hytm(
            None, program, source=source, config=config,
            runtime=runtime, mesh=runtime.mesh, initial_state=state,
            calibrator=calibrator, obs=obs, faults=faults, retry=retry,
        )
    return run_hytm(
        None, program, source=source, config=config,
        runtime=dcsr.runtime_for(program), initial_state=state,
        calibrator=calibrator, obs=obs, faults=faults, retry=retry,
    )
