"""Batched graph-query serving over a live ``DeltaCSR``.

``GraphService`` multiplexes concurrent vertex queries (SSSP / BFS / CC /
Δ-PR) over one graph container:

* **source-lane batching** — up to ``max_lanes`` pending single-source
  queries stack into a (Q, n) state and run through ``hytm_iteration``
  under ``jax.vmap``: each lane carries its own frontier, so the cost
  model, engine selection, and priority schedule are evaluated *per
  lane*, making every lane's dataflow identical to its standalone run
  (bit-exact for MIN programs — converged lanes are no-ops while the
  stragglers finish).  With ``HyTMConfig.sync_every > 1`` the sweep is
  chunked (``_batched_chunk``): K vmapped iterations share one
  ``lax.while_loop`` dispatch, and the host syncs once per chunk instead
  of once per iteration — the same device-resident driver ``run_hytm``
  uses, lifted over the lane dimension;
* **result cache** — converged (values, Δ) keyed by
  ``(graph_version, program, source)``.  A repeat query at the same
  version is a pure cache hit: zero sweep iterations.  An update batch
  invalidates direct hits (the version key moves on) but the stale entry
  is retained as the *warm state* for incremental recomputation
  (repro.stream.incremental) against the reports applied since;
* **updates** — ``update(batch)`` applies an ``EdgeBatch`` through the
  container (device buffers patched in place) and logs the report for
  later warm-starts (bounded by ``max_reports``: overflow evicts the
  cache entries too stale to replay the retained suffix);
* **mesh serving** — with ``HyTMConfig.mesh_axis`` set, lane sweeps run
  the vmapped sharded chunk over the container's device-sharded
  (P_pad, B) edge grid and incremental recomputes warm-start the
  shard_mapped driver; every lane / warm run stays bit-identical to its
  single-device ``async_sweep=False`` counterpart for MIN programs.

Accumulative programs (``use_delta``) are global — their cache key uses
``source=None`` whatever the caller passed.

With ``HyTMConfig.autotune`` the service carries one
``repro.autotune.OnlineCalibrator`` for its whole lifetime: every
multiplexed lane sweep contributes a measured-vs-modeled observation,
and the resulting per-engine correction biases each lane's engine
selection (and hence the priority schedule) on subsequent iterations and
queries.  ``stats.extra`` reports the live correction vector and the
accumulated misprediction count.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hytm import (
    HyTMConfig,
    HyTMState,
    _consume_warm,
    _iteration_impl,
    hytm_iteration,
    quiet_donation,
    run_hytm,
)
from repro.graph.algorithms import VertexProgram
from repro.graph.csr import CSRGraph
from repro.stream.delta_csr import DeltaCSR, EdgeBatch, UpdateReport
from repro.stream.incremental import run_incremental


@partial(jax.jit, static_argnames=("program", "config", "nhp"))
def _batched_iteration(state, csr, parts, zc_req, inv_deg, program, config, nhp,
                       correction=None):
    """One HyTM iteration vmapped over the source-lane dimension.

    ``correction`` (optional (3,)) is shared across lanes — one
    machine, one set of per-engine corrections — while each lane still
    runs its own cost model and selection over its own frontier."""
    return jax.vmap(
        lambda s: hytm_iteration(
            s, csr, parts, zc_req, inv_deg, program, config, nhp, correction
        )
    )(state)


@partial(
    jax.jit,
    static_argnames=("program", "config", "nhp", "chunk"),
    donate_argnames=("state",),
)
def _batched_chunk(state, csr, parts, zc_req, inv_deg, program, config, nhp,
                   chunk, correction=None):
    """Chunked lane sweep (``config.sync_every > 1``): up to ``chunk``
    vmapped iterations inside one ``lax.while_loop`` dispatch, early-
    exiting once every lane's frontier drains (``core.hytm.hytm_chunk``'s
    chunk/early-exit contract, lifted over the lane dimension: the
    while-condition sums ``next_active`` across lanes, so converged lanes
    idle as no-ops only while a straggler is still inside the chunk).
    The service never reads per-iteration history, so instead of (K, ...)
    buffers the loop carries running reductions: summed per-engine
    modeled seconds and mispredictions (the calibrator's chunk-granular
    observation inputs).  Returns
    ``(state, n_done, last_active_total, per_engine_sum, mispred_sum)``.
    """
    def one(s):
        return _iteration_impl(
            s, csr, parts, zc_req, inv_deg, program, config, nhp, correction
        )

    def cond(carry):
        _s, i, prev_active, _pe, _mp = carry
        return (i < chunk) & (prev_active != 0)

    def body(carry):
        s, i, _prev, pe, mp = carry
        s2, info = jax.vmap(one)(s)
        return (
            s2,
            i + 1,
            jnp.sum(info["next_active"]),
            pe + jnp.sum(info["per_engine_time"], axis=0),
            mp + jnp.sum(info["mispredictions"]),
        )

    init = (state, jnp.int32(0), jnp.int32(1),
            jnp.zeros(3, jnp.float32), jnp.int32(0))
    state, n_done, last_active, pe_sum, mp_sum = jax.lax.while_loop(
        cond, body, init)
    return state, n_done, last_active, pe_sum, mp_sum


@dataclass
class QueryResult:
    source: int | None
    values: np.ndarray
    iterations: int        # sweep iterations this query paid for
    cache_hit: bool
    mode: str              # 'cache' | 'incremental' | 'batched'


@dataclass
class _CacheEntry:
    version: int
    values: np.ndarray
    delta: np.ndarray


@dataclass
class ServiceStats:
    n_queries: int = 0
    n_cache_hits: int = 0
    n_incremental: int = 0
    n_full: int = 0
    n_updates: int = 0
    sweep_iterations: int = 0
    update_edges: int = 0
    extra: dict = field(default_factory=dict)


class GraphService:
    def __init__(
        self,
        graph: CSRGraph,
        config: HyTMConfig | None = None,
        max_lanes: int = 8,
        incremental: bool = True,
        max_reports: int = 256,
        mesh=None,
        **delta_kw,
    ):
        self.config = config if config is not None else HyTMConfig()
        self.dcsr = DeltaCSR(graph, self.config, **delta_kw)
        # With config.mesh_axis set, the service serves *from the mesh*:
        # lane sweeps run the vmapped sharded chunk
        # (graph_shard.make_sharded_batched_chunk) over the container's
        # device-sharded (P_pad, B) grid, and incremental recomputes
        # warm-start the shard_mapped driver — each lane / warm run
        # bit-identical to its single-device async_sweep=False
        # counterpart for MIN programs.
        self.mesh = None
        if self.config.mesh_axis is not None:
            if mesh is None:
                from repro.launch.mesh import make_graph_mesh

                mesh = make_graph_mesh(axis=self.config.mesh_axis)
            self.mesh = mesh
        self.max_lanes = max_lanes
        self.incremental = incremental
        # upper bound on retained UpdateReports: a stale cache entry that
        # is never re-queried would otherwise pin the prune floor and let
        # report memory grow without limit (one abandoned entry = every
        # later report retained forever).  Overflow drops the oldest
        # reports and evicts the cache entries that would have needed
        # them (their next query falls back to a full recompute).
        self.max_reports = max_reports
        # keyed by the (frozen, hashable) program itself, not its name:
        # variants like dataclasses.replace(PAGERANK, tolerance=1e-8)
        # must not collide with each other's converged results
        self._cache: dict[tuple[VertexProgram, int | None], _CacheEntry] = {}
        self._reports: list[UpdateReport] = []
        self.stats = ServiceStats()
        # online feedback (repro.autotune): one calibrator for the whole
        # service lifetime — measured lane-sweep times keep correcting the
        # per-engine selection costs across queries and update batches
        self._calibrator = None
        self._correction = None
        if self.config.autotune:
            from repro.autotune.feedback import OnlineCalibrator

            self._calibrator = OnlineCalibrator(decay=self.config.autotune_decay)

    # ----------------------------------------------------------------- update
    @property
    def version(self) -> int:
        return self.dcsr.version

    def update(self, batch: EdgeBatch) -> UpdateReport:
        """Apply an edge-update batch.  All cached results become stale for
        direct hits (version bump) and turn into warm states."""
        rep = self.dcsr.apply(batch)
        self._reports.append(rep)
        self._prune_reports()
        self.stats.n_updates += 1
        self.stats.update_edges += len(batch)
        return rep

    def _prune_reports(self) -> None:
        """Drop reports no warm state can need: every cached entry only
        ever replays reports *newer* than its own version, so anything at
        or below the oldest cached version (or everything, with no cache
        or incremental disabled) is dead weight.

        Age bound (``max_reports``): a stale entry that is never
        re-queried pins the floor forever, so past the bound the oldest
        overflow reports are dropped *and* every cache entry too old to
        replay the retained suffix is evicted — correctness first: an
        entry must never warm-start against a gappy report list, so
        eviction forces its next query onto the full-recompute path."""
        if not self.incremental or not self._cache:
            self._reports.clear()
            return
        floor = min(e.version for e in self._cache.values())
        self._reports = [r for r in self._reports if r.version > floor]
        if len(self._reports) > self.max_reports:
            # explicit drop count, not a [-max:] slice — max_reports=0
            # (retain nothing) must really drop everything
            drop = len(self._reports) - self.max_reports
            self._reports = self._reports[drop:]
            # versions are consecutive (one report per apply): an entry
            # at version v needs every report with version > v, so it
            # survives only if v >= retained_first - 1
            min_replayable = (self._reports[0].version - 1
                              if self._reports else self.version)
            for k in [k for k, e in self._cache.items()
                      if e.version < min_replayable]:
                del self._cache[k]

    def _reports_since(self, version: int) -> list[UpdateReport]:
        return [r for r in self._reports if r.version > version]

    # ------------------------------------------------------------------ query
    def query(
        self, program: VertexProgram, sources: Sequence[int | None] | int | None
    ) -> list[QueryResult]:
        """Answer a batch of queries; one ``QueryResult`` per requested
        source, in order.  Duplicate sources share one computation."""
        if sources is None or isinstance(sources, int):
            sources = [sources]
        keyed = [
            (None if program.use_delta else s) for s in sources
        ]
        results: dict[int | None, QueryResult] = {}
        fresh: list[int | None] = []
        for s in dict.fromkeys(keyed):  # dedupe, keep order
            entry = self._cache.get((program, s))
            if entry is not None and entry.version == self.version:
                results[s] = QueryResult(
                    source=s, values=entry.values, iterations=0,
                    cache_hit=True, mode="cache",
                )
                self.stats.n_cache_hits += 1
            elif entry is not None and self.incremental:
                results[s] = self._query_incremental(program, s, entry)
            else:
                fresh.append(s)
        if fresh:
            results.update(self._query_fresh(program, fresh))
        self.stats.n_queries += len(sources)
        return [results[k] for k in keyed]

    def _store(self, program, s, values, delta) -> None:
        self._cache[(program, s)] = _CacheEntry(
            version=self.version,
            values=np.asarray(values),
            delta=np.asarray(delta),
        )
        self._prune_reports()  # refreshed entries may raise the floor

    def _record_feedback(self, mispredictions, correction=None) -> None:
        """Single bookkeeping point for every feedback source (lane
        sweeps, incremental runs, full accumulative runs): refresh the
        cached correction and accumulate the misprediction count into
        ``stats.extra``.  ``correction`` skips re-solving when the caller
        already holds the refreshed vector (observe_iteration's return)."""
        if self._calibrator is None:
            return
        if correction is None:
            correction = jnp.asarray(
                self._calibrator.correction(), jnp.float32)
        self._correction = correction
        self.stats.extra["engine_corrections"] = (
            np.asarray(self._correction).tolist())
        self.stats.extra["mispredictions"] = (
            self.stats.extra.get("mispredictions", 0) + int(mispredictions))

    def _absorb_run(self, res) -> None:
        self._record_feedback(res.total_mispredictions)

    def _query_incremental(self, program, s, entry: _CacheEntry) -> QueryResult:
        res = run_incremental(
            self.dcsr, program, self._reports_since(entry.version),
            entry.values, entry.delta, source=s, config=self.config,
            calibrator=self._calibrator, mesh=self.mesh,
        )
        self._absorb_run(res)
        self._store(program, s, res.values, res.delta)
        self.stats.n_incremental += 1
        self.stats.sweep_iterations += res.iterations
        return QueryResult(
            source=s, values=res.values, iterations=res.iterations,
            cache_hit=False, mode="incremental",
        )

    def _runtime_for(self, program):
        """The container view matching the configured execution path:
        the device-sharded (P_pad, B) grid on the mesh, or the
        single-device blocked log."""
        if self.mesh is not None:
            return self.dcsr.sharded_runtime_for(
                program, mesh=self.mesh, axis=self.config.mesh_axis)
        return self.dcsr.runtime_for(program)

    def _query_fresh(self, program, sources) -> dict:
        out: dict[int | None, QueryResult] = {}
        if program.use_delta:
            # accumulative programs are global: a single full run
            for s in sources:
                res = run_hytm(
                    None, program, source=s, config=self.config,
                    runtime=self._runtime_for(program), mesh=self.mesh,
                    calibrator=self._calibrator,
                )
                self._absorb_run(res)
                self._store(program, s, res.values, res.delta)
                self.stats.n_full += 1
                self.stats.sweep_iterations += res.iterations
                out[s] = QueryResult(
                    source=s, values=res.values, iterations=res.iterations,
                    cache_hit=False, mode="batched",
                )
            return out
        for i in range(0, len(sources), self.max_lanes):
            chunk = sources[i:i + self.max_lanes]
            values, deltas, iters = self._run_lanes(program, chunk)
            for j, s in enumerate(chunk):
                self._store(program, s, values[j], deltas[j])
                out[s] = QueryResult(
                    source=s, values=values[j], iterations=iters,
                    cache_hit=False, mode="batched",
                )
            self.stats.n_full += len(chunk)
            self.stats.sweep_iterations += iters
        return out

    def _run_lanes(
        self, program: VertexProgram, sources: Sequence[int]
    ) -> tuple[np.ndarray, np.ndarray, int]:
        """One multiplexed sweep: stack Q per-source init states along a
        lane dimension and iterate until every lane's frontier drains.
        With ``config.mesh_axis`` set, the whole lane stack runs on the
        mesh (``_run_lanes_sharded``)."""
        inits = [program.init_state(self.dcsr.n_nodes, s) for s in sources]
        state = HyTMState(
            values=jnp.stack([v for v, _, _ in inits]),
            delta=jnp.stack([d for _, d, _ in inits]),
            frontier=jnp.stack([f for _, _, f in inits]),
        )
        correction = self._correction
        if self._calibrator is not None and correction is None:
            correction = jnp.ones(3, jnp.float32)
        if self.mesh is not None:
            return self._run_lanes_sharded(program, state, len(sources),
                                           correction)
        rt = self.dcsr.runtime_for(program)
        iters = 0
        if self.config.sync_every > 1:
            # chunked lane sweep: one _batched_chunk dispatch per K
            # iterations; converged lanes idle inside the chunk only
            # while a straggler lane is still relaxing (early exit the
            # moment the summed frontier drains)
            Q = len(sources)
            while iters < self.config.max_iters:
                chunk = min(self.config.sync_every,
                            self.config.max_iters - iters)
                # the warm signature mirrors the jit cache key: statics +
                # every shape the trace specializes on — lane count and
                # the runtime's node/edge/partition capacities (which move
                # on merge-compaction), so a recompiling dispatch is never
                # fed to the calibrator as a measurement
                warm = _consume_warm((
                    "lanes", program, self.config, rt.n_hub_partitions,
                    Q, self.dcsr.n_nodes, rt.csr.edge_src.shape[0],
                    rt.parts.n_partitions, rt.parts.block_size,
                    chunk, correction is not None,
                ))
                t_chunk = time.monotonic()
                with quiet_donation():
                    state, n_done, last_active, pe_sum, mp_sum = \
                        _batched_chunk(
                            state, rt.csr, rt.parts, rt.zc_req, rt.inv_deg,
                            program, self.config, rt.n_hub_partitions,
                            chunk, correction,
                        )
                iters += int(n_done)
                if self._calibrator is not None:
                    # lanes share the machine: the chunk's summed modeled
                    # per-engine times form one observation (skipped when
                    # this dispatch signature compiled)
                    refreshed = self._calibrator.observe_chunk(
                        state.values, np.asarray(pe_sum, dtype=float),
                        t_chunk, skip=not warm,
                    )
                    self._record_feedback(int(mp_sum), refreshed)
                    correction = self._correction
                if int(last_active) == 0:
                    break
        else:
            for _ in range(self.config.max_iters):
                t_iter = time.monotonic()
                state, info = _batched_iteration(
                    state, rt.csr, rt.parts, rt.zc_req, rt.inv_deg,
                    program, self.config, rt.n_hub_partitions, correction,
                )
                iters += 1
                if self._calibrator is not None:
                    # lanes share the machine: their modeled per-engine
                    # times sum into one observation per multiplexed
                    # sweep.  Each sweep's first iteration may pay a
                    # retrace (new lane count or program), so never count
                    # it as a measurement.
                    refreshed = self._calibrator.observe_iteration(
                        state.values,
                        np.asarray(info["per_engine_time"], dtype=float).sum(axis=0),
                        t_iter, skip=iters == 1,
                    )
                    self._record_feedback(
                        np.asarray(info["mispredictions"]).sum(), refreshed)
                    correction = self._correction
                if int(np.asarray(info["next_active"]).sum()) == 0:
                    break
        return np.asarray(state.values), np.asarray(state.delta), iters

    def _run_lanes_sharded(
        self, program: VertexProgram, state: HyTMState, n_lanes: int,
        correction,
    ) -> tuple[np.ndarray, np.ndarray, int]:
        """Multiplexed lane sweep on the mesh: the sharded iteration
        (per-lane cost model / engine selection / schedule, edge blocks
        sharded over the mesh axis, bulk-synchronous pmin/psum merges)
        vmapped over the lane dimension inside one chunked
        ``lax.while_loop`` dispatch
        (``graph_shard.make_sharded_batched_chunk``).  Each lane is
        bit-identical to its standalone single-device
        ``async_sweep=False`` run for MIN programs.  The cross-device
        merge is charged per executed iteration over ``config.ici_link``
        (lane-summed entries, Q·(n,) dense payload) into
        ``stats.extra['ici_bytes'/'ici_time']``."""
        from repro.dist.graph_shard import (
            ici_level_cost,
            make_sharded_batched_chunk,
        )

        rt = self._runtime_for(program)
        n_dev = int(self.mesh.shape[self.config.mesh_axis])
        iters = 0
        while iters < self.config.max_iters:
            chunk = min(max(self.config.sync_every, 1),
                        self.config.max_iters - iters)
            key = ("lanes", program, self.config, chunk, n_lanes)
            cached = rt.iteration_cache.get(key)
            if cached is None:
                cached = {"fn": make_sharded_batched_chunk(
                    rt, program, self.config, chunk), "seen": set()}
                rt.iteration_cache[key] = cached
            # warm iff THIS chunk_fn already dispatched THESE shapes —
            # scoped to the cached entry, which a DeltaCSR
            # merge-compaction drops (see graph_shard: a rebuilt fn's
            # recompile must never feed the calibrator)
            warm = _consume_warm(
                (rt.blocks.src.shape, rt.parts.n_partitions,
                 rt.parts.block_size, correction is not None),
                registry=cached["seen"],
            )
            t_chunk = time.monotonic()
            with quiet_donation():
                state, n_done, last_active, pe_sum, mp_sum, merged = \
                    cached["fn"](state, rt.blocks, rt.parts, rt.out_degree,
                                 rt.zc_req, rt.inv_deg, correction)
            n_done = int(n_done)
            iters += n_done
            if self._calibrator is not None:
                refreshed = self._calibrator.observe_chunk(
                    state.values, np.asarray(pe_sum, dtype=float),
                    t_chunk, skip=not warm,
                )
                self._record_feedback(int(mp_sum), refreshed)
                correction = self._correction
            # second-level accounting: all lanes merge in one batched
            # collective, so the dense candidate payload is Q stacked
            # (n,) vectors and the compacted one the lane-summed entries
            corr_np = (np.asarray(correction, dtype=float)
                       if correction is not None else None)
            for me in np.asarray(merged)[:n_done]:
                ib, it_, _ie = ici_level_cost(
                    n_lanes * self.dcsr.n_nodes, float(me), n_dev,
                    self.config.ici_link, corr_np,
                )
                self.stats.extra["ici_bytes"] = (
                    self.stats.extra.get("ici_bytes", 0.0) + ib)
                self.stats.extra["ici_time"] = (
                    self.stats.extra.get("ici_time", 0.0) + it_)
            if int(last_active) == 0:
                break
        return np.asarray(state.values), np.asarray(state.delta), iters
