"""Batched graph-query serving over a live ``DeltaCSR``.

``GraphService`` multiplexes concurrent vertex queries (SSSP / BFS / CC /
Δ-PR / Δ-PPR) over one graph container:

* **source-lane batching** — pending single-source queries run through
  the continuous lane scheduler (``repro.serve.scheduler``): sources
  stack into a (Q, n) state padded to a *static lane bucket* and sweep
  through ``core.hytm.hytm_batched_chunk`` under ``jax.vmap``.  Each
  lane carries its own frontier, so the cost model, engine selection,
  and priority schedule are evaluated *per lane*, making every lane's
  dataflow identical to its standalone run (bit-exact for MIN programs).
  Converged lanes free their slot at chunk boundaries and the scheduler
  backfills them from the pending queue mid-flight — the device never
  waits for the straggler before starting the next source;
* **tiered result cache** — converged (values, Δ) keyed by
  ``(program, source)`` in a two-tier warm cache
  (``repro.serve.warm_cache``): a device tier bounded by
  ``device_budget_bytes`` (LRU) spilling to a host-RAM tier.  A repeat
  query at the same version is a pure hit: zero sweep iterations.  An
  update batch invalidates direct hits (the version key moves on) but
  the stale entry is retained as the *warm state* for incremental
  recomputation (repro.stream.incremental) against the reports applied
  since — promoted back to the device tier first if it was spilled;
* **updates** — ``update(batch)`` applies an ``EdgeBatch`` through the
  container (device buffers patched in place) and logs the report for
  later warm-starts (bounded by ``max_reports``: overflow evicts the
  cache entries too stale to replay the retained suffix);
* **mesh serving** — with ``HyTMConfig.mesh_axis`` set, lane sweeps run
  the vmapped sharded chunk over the container's device-sharded
  (P_pad, B) edge grid and incremental recomputes warm-start the
  shard_mapped driver; every lane / warm run stays bit-identical to its
  single-device ``async_sweep=False`` counterpart for MIN programs.

Accumulative programs (``use_delta``) are global — their cache key uses
``source=None`` whatever the caller passed — *except* personalized ones
(Δ-PPR), which key per source and multiplex into the lane sweep like
traversals.

With ``HyTMConfig.autotune`` the service carries one
``repro.autotune.OnlineCalibrator`` for its whole lifetime: every
multiplexed lane sweep contributes a measured-vs-modeled observation,
and the resulting per-engine correction biases each lane's engine
selection (and hence the priority schedule) on subsequent iterations and
queries.  ``stats.extra`` reports the live correction vector, the
accumulated misprediction count, and the warm-cache tier counters.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import (
    KEY_ENGINE_CORRECTIONS,
    KEY_MISPREDICTIONS,
    KEY_WARM_CACHE,
)
from repro.core.hytm import HyTMConfig, run_hytm
from repro.graph.algorithms import VertexProgram
from repro.graph.csr import CSRGraph
from repro.serve.scheduler import LaneScheduler
from repro.serve.warm_cache import OwnerPlacement, TierPolicy, WarmCache
from repro.stream.delta_csr import DeltaCSR, EdgeBatch, UpdateReport
from repro.stream.incremental import run_incremental


@dataclass
class QueryResult:
    source: int | None
    values: np.ndarray
    iterations: int        # sweep iterations this query paid for
    cache_hit: bool
    mode: str              # 'cache' | 'incremental' | 'batched'


@dataclass
class ServiceStats:
    n_queries: int = 0
    n_cache_hits: int = 0
    n_incremental: int = 0
    n_full: int = 0
    n_updates: int = 0
    sweep_iterations: int = 0
    update_edges: int = 0
    extra: dict = field(default_factory=dict)


class GraphService:
    def __init__(
        self,
        graph: CSRGraph,
        config: HyTMConfig | None = None,
        max_lanes: int = 8,
        incremental: bool = True,
        max_reports: int = 256,
        mesh=None,
        device_budget_bytes: int | None = None,
        lane_buckets: Sequence[int] | None = None,
        obs=None,
        faults=None,
        supervisor=None,
        **delta_kw,
    ):
        # optional repro.obs.TraceRecorder threaded through every consumer
        # the service owns: lane sweeps (scheduler), warm-cache tier
        # transitions, calibrator correction updates, and the
        # run_hytm/run_incremental dispatches.  obs=None (default) records
        # nothing anywhere — the untraced service is bit-identical.
        self.obs = obs
        # optional repro.resilience hooks, threaded the same way: a
        # FaultPlan reaches the warm cache (spill corruption, promote
        # OOM), the scheduler (lane alloc/dispatch), and the engine
        # dispatches; a Supervisor supplies the retry policy and the
        # load-shed rung.  Both None (default) = zero overhead, the
        # exact PR-8 code path.
        self.faults = faults
        self.supervisor = supervisor
        self.config = config if config is not None else HyTMConfig()
        self.dcsr = DeltaCSR(graph, self.config, **delta_kw)
        # With config.mesh_axis set, the service serves *from the mesh*:
        # lane sweeps run the vmapped sharded chunk
        # (graph_shard.make_sharded_batched_chunk) over the container's
        # device-sharded (P_pad, B) grid, and incremental recomputes
        # warm-start the shard_mapped driver — each lane / warm run
        # bit-identical to its single-device async_sweep=False
        # counterpart for MIN programs.
        self.mesh = None
        if self.config.mesh_axis is not None:
            if mesh is None:
                from repro.launch.mesh import make_graph_mesh

                mesh = make_graph_mesh(axis=self.config.mesh_axis)
            self.mesh = mesh
        self.max_lanes = max_lanes
        self.incremental = incremental
        # upper bound on retained UpdateReports: a stale cache entry that
        # is never re-queried would otherwise pin the prune floor and let
        # report memory grow without limit (one abandoned entry = every
        # later report retained forever).  Overflow drops the oldest
        # reports and evicts the cache entries that would have needed
        # them (their next query falls back to a full recompute).
        self.max_reports = max_reports
        # keyed by the (frozen, hashable) program itself, not its name:
        # variants like dataclasses.replace(PAGERANK, tolerance=1e-8)
        # must not collide with each other's converged results.  The
        # tier policy makes the old flat ``max_reports`` bound explicit
        # and adds the device-tier LRU byte budget (warm_cache docstring).
        # owner-sharded serving holds cache entries (and counts the byte
        # budget) at owned-slice granularity — see warm_cache.OwnerPlacement
        placement = None
        if self.mesh is not None and self.config.vertex_sharding == "owner":
            placement = OwnerPlacement(
                self.mesh, self.config.mesh_axis, graph.n_nodes)
        self.cache = WarmCache(TierPolicy(
            device_budget_bytes=device_budget_bytes,
            max_reports=max_reports,
        ), obs=obs, faults=faults, placement=placement)
        self._cache = self.cache  # dict-like; historical alias
        self._reports: list[UpdateReport] = []
        self.stats = ServiceStats()
        # online feedback (repro.autotune): one calibrator for the whole
        # service lifetime — measured lane-sweep times keep correcting the
        # per-engine selection costs across queries and update batches
        self._calibrator = None
        self._correction = None
        if self.config.autotune:
            from repro.autotune.feedback import OnlineCalibrator

            self._calibrator = OnlineCalibrator(
                decay=self.config.autotune_decay, obs=obs)
        # the continuous lane scheduler owns every multiplexed sweep
        # (degenerate single-tenant mode here; multi-tenant closed-loop
        # serving drives LaneScheduler.pump directly — serve_bench)
        self.scheduler = LaneScheduler(
            self, buckets=tuple(lane_buckets) if lane_buckets else None,
            supervisor=supervisor)

    # ----------------------------------------------------------------- update
    @property
    def version(self) -> int:
        return self.dcsr.version

    def update(self, batch: EdgeBatch, batch_id=None,
               faults=None) -> UpdateReport:
        """Apply an edge-update batch.  All cached results become stale for
        direct hits (version bump) and turn into warm states.

        ``batch_id`` opts into exactly-once delivery: a redelivered id
        returns the original report without re-applying (no version
        bump, no duplicate report in the log) — the dedup contract
        ``resilience.supervisor.deliver_update`` relies on.  ``faults``
        forwards to ``DeltaCSR.apply`` (injected delivery drops)."""
        v0 = self.dcsr.version
        rep = self.dcsr.apply(batch, batch_id=batch_id, faults=faults)
        if self.dcsr.version == v0:
            # deduplicated redelivery: the container returned the cached
            # report without applying — keep the log and stats exact
            return rep
        self._reports.append(rep)
        self._prune_reports()
        self.stats.n_updates += 1
        self.stats.update_edges += len(batch)
        return rep

    def _prune_reports(self) -> None:
        """Drop reports no warm state can need: every cached entry only
        ever replays reports *newer* than its own version, so anything at
        or below the oldest cached version (or everything, with no cache
        or incremental disabled) is dead weight.

        Age bound (``TierPolicy.max_reports``): a stale entry that is
        never re-queried pins the floor forever, so past the bound the
        oldest overflow reports are dropped *and* every cache entry too
        old to replay the retained suffix is evicted — correctness
        first: an entry must never warm-start against a gappy report
        list, so eviction forces its next query onto the full-recompute
        path.  This applies to *both* tiers: a host-spilled entry is as
        replayable as a device one right up until its reports drop."""
        if not self.incremental or not len(self._cache):
            self._reports.clear()
            return
        floor = min(e.version for e in self._cache.values())
        self._reports = [r for r in self._reports if r.version > floor]
        if len(self._reports) > self.max_reports:
            # explicit drop count, not a [-max:] slice — max_reports=0
            # (retain nothing) must really drop everything
            drop = len(self._reports) - self.max_reports
            self._reports = self._reports[drop:]
            # versions are consecutive (one report per apply): an entry
            # at version v needs every report with version > v, so it
            # survives only if v >= retained_first - 1
            min_replayable = (self._reports[0].version - 1
                              if self._reports else self.version)
            for k in [k for k, e in self._cache.items()
                      if e.version < min_replayable]:
                del self._cache[k]

    def _reports_since(self, version: int) -> list[UpdateReport]:
        return [r for r in self._reports if r.version > version]

    # ------------------------------------------------------------------ query
    def key_source(self, program: VertexProgram, s: int | None) -> int | None:
        """Cache-key source: global accumulative programs — and peeling
        programs (k-core), which have no source at all — collapse to
        ``None`` (one answer per graph version); traversals and
        personalized accumulative programs (Δ-PPR) key per source."""
        if program.peel_k is not None:
            return None
        if program.use_delta and not program.personalized:
            return None
        return s

    def query(
        self, program: VertexProgram, sources: Sequence[int | None] | int | None
    ) -> list[QueryResult]:
        """Answer a batch of queries; one ``QueryResult`` per requested
        source, in order.  Duplicate sources share one computation."""
        if sources is None or isinstance(sources, int):
            sources = [sources]
        keyed = [self.key_source(program, s) for s in sources]
        results: dict[int | None, QueryResult] = {}
        fresh: list[int | None] = []
        for s in dict.fromkeys(keyed):  # dedupe, keep order
            entry = self.cache.check((program, s))
            if entry is not None and entry.version == self.version:
                results[s] = QueryResult(
                    source=s, values=entry.host_values(), iterations=0,
                    cache_hit=True, mode="cache",
                )
                self.stats.n_cache_hits += 1
            elif entry is not None and self.incremental:
                results[s] = self._query_incremental(program, s)
            else:
                fresh.append(s)
        if fresh:
            results.update(self._query_fresh(program, fresh))
        self.stats.n_queries += len(sources)
        self.stats.extra[KEY_WARM_CACHE] = self.cache.stats.as_dict()
        return [results[k] for k in keyed]

    def _store(self, program, s, values, delta) -> None:
        self.cache.put(
            (program, s), self.version, values, delta,
            reserved_bytes=self.scheduler.pinned_bytes,
        )
        self._prune_reports()  # refreshed entries may raise the floor

    def _record_feedback(self, mispredictions, correction=None) -> None:
        """Single bookkeeping point for every feedback source (lane
        sweeps, incremental runs, full accumulative runs): refresh the
        cached correction and accumulate the misprediction count into
        ``stats.extra``.  ``correction`` skips re-solving when the caller
        already holds the refreshed vector (observe_iteration's return)."""
        if self._calibrator is None:
            return
        if correction is None:
            correction = jnp.asarray(
                self._calibrator.correction(), jnp.float32)
        self._correction = correction
        self.stats.extra[KEY_ENGINE_CORRECTIONS] = (
            np.asarray(self._correction).tolist())
        self.stats.extra[KEY_MISPREDICTIONS] = (
            self.stats.extra.get(KEY_MISPREDICTIONS, 0) + int(mispredictions))

    def _absorb_run(self, res) -> None:
        self._record_feedback(res.total_mispredictions)

    def _query_incremental(self, program, s) -> QueryResult:
        # spilled warm states come back through the device tier first
        # (bit-exact round trip — warm_cache.promote), then replay the
        # reports applied since their version.  promote() returns None
        # when the entry failed its integrity checksum (corrupt spill —
        # detected, counted, evicted) or an injected promote OOM refused
        # the transfer: degrade to the full-recompute path rather than
        # warm-start from garbage.
        entry = self.cache.promote((program, s))
        if entry is None:
            return self._query_fresh(program, [s])[s]
        res = run_incremental(
            self.dcsr, program, self._reports_since(entry.version),
            entry.host_values(), entry.host_delta(),
            source=s, config=self.config,
            calibrator=self._calibrator, mesh=self.mesh, obs=self.obs,
            faults=self.faults, retry=self._retry_policy(),
        )
        self._absorb_run(res)
        self._store(program, s, res.values, res.delta)
        self.stats.n_incremental += 1
        self.stats.sweep_iterations += res.iterations
        return QueryResult(
            source=s, values=res.values, iterations=res.iterations,
            cache_hit=False, mode="incremental",
        )

    def _retry_policy(self):
        return self.supervisor.policy if self.supervisor is not None else None

    def _runtime_for(self, program):
        """The container view matching the configured execution path:
        the device-sharded (P_pad, B) grid on the mesh, or the
        single-device blocked log."""
        if self.mesh is not None:
            return self.dcsr.sharded_runtime_for(
                program, mesh=self.mesh, axis=self.config.mesh_axis)
        return self.dcsr.runtime_for(program)

    def _query_fresh(self, program, sources) -> dict:
        out: dict[int | None, QueryResult] = {}
        if program.peel_k is not None or (
                program.use_delta and not program.personalized):
            # global programs (accumulative, and peeling programs whose
            # init comes from the runtime degree vector — they cannot be
            # seeded per-lane): a single full run
            for s in sources:
                res = run_hytm(
                    None, program, source=s, config=self.config,
                    runtime=self._runtime_for(program), mesh=self.mesh,
                    calibrator=self._calibrator, obs=self.obs,
                    faults=self.faults, retry=self._retry_policy(),
                )
                self._absorb_run(res)
                self._store(program, s, res.values, res.delta)
                self.stats.n_full += 1
                self.stats.sweep_iterations += res.iterations
                out[s] = QueryResult(
                    source=s, values=res.values, iterations=res.iterations,
                    cache_hit=False, mode="batched",
                )
            return out
        # per-source programs (traversals + personalized accumulative):
        # the continuous scheduler stacks them into bucketed lanes —
        # admission pads partial batches with dead lanes up to a static
        # bucket (never a recompile), converged lanes free their slot at
        # chunk boundaries, and freed slots backfill from the remaining
        # sources mid-flight
        served = self.scheduler.run_batch(program, sources)
        for s in sources:
            r = served[s]
            if r.mode == "rejected":
                # only possible when device_budget_bytes cannot hold even
                # one lane — a misconfiguration, not a serving decision
                raise RuntimeError(
                    f"device_budget_bytes={self.cache.policy.device_budget_bytes} "
                    f"cannot fit one lane "
                    f"({self.scheduler.lane_bytes} bytes) — query rejected")
            out[s] = QueryResult(
                source=s, values=r.values, iterations=r.iterations,
                cache_hit=False, mode=r.mode,
            )
        return out
