"""repro.stream — dynamic-graph updates, incremental HyTM recomputation,
and a batched graph-query serving front-end.

Layers:
  delta_csr   — versioned graph container: per-partition edge log,
                device-buffer patching, merge-compaction, dirty tracking
  incremental — warm-start recomputation seeded from update-affected
                vertices (routed-through invalidation / correction Δs)
  service     — source-lane-batched query serving with a
                (graph_version, program, source)-keyed result cache
"""

from repro.stream.delta_csr import (
    OP_DELETE,
    OP_INSERT,
    OP_REWEIGHT,
    DeltaCSR,
    EdgeBatch,
    InvalidBatchError,
    UpdateReport,
    random_batch,
)
from repro.stream.incremental import incremental_state, run_incremental
from repro.stream.service import GraphService, QueryResult

__all__ = [
    "OP_DELETE", "OP_INSERT", "OP_REWEIGHT",
    "DeltaCSR", "EdgeBatch", "InvalidBatchError", "UpdateReport",
    "random_batch",
    "incremental_state", "run_incremental",
    "GraphService", "QueryResult",
]
