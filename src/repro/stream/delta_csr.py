"""Versioned dynamic-graph container over ``CSRGraph``/``DeviceCSR``.

Streaming workloads mutate the edge set in small batches; rebuilding the
CSR + partition layout + device buffers per batch would cost more than
the recomputation it unlocks.  ``DeltaCSR`` instead keeps the partition
edge-block layout (core/partition.py) *fixed between merges* and treats
each partition's edge range as a log-structured segment:

* every partition gets ``slack`` spare lanes at build time — its live
  edges occupy a dense prefix of a fixed-capacity block (the per-partition
  edge log);
* **insert** appends into the partition of the edge's source vertex
  (partition boundaries are vertex-aligned, so the source's partition is
  the only legal home);
* **delete** swap-removes within the block (combiners are commutative, so
  intra-partition edge order is free) — the live prefix stays dense and
  the sweep's ``local < part_edges[p]`` masking needs no tombstones;
* **reweight** patches the weight lane in place.

Device buffers are *patched* (one scatter over the touched lanes + the
(P,) live-count and (n,) degree vectors), never rebuilt — shapes are
static between merges so ``hytm_iteration`` keeps its compiled sweep.
When a partition's block overflows, a **merge-compaction** folds the log
into a fresh CSR, re-partitions, and re-uploads (``layout_version`` bump).

Versioning contract (consumed by repro.stream.service's result cache):
``version`` bumps once per applied batch; a result computed at version v
is valid iff the container is still at v.  ``dirty_partitions`` in each
``UpdateReport`` names the blocks a batch touched — the granularity at
which Totem-style hybrid systems track what an update dirties.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import zc_request_counts
from repro.core.hytm import HyTMConfig, Runtime
from repro.core.partition import DevicePartitions, PartitionTable, partition_graph
from repro.graph.algorithms import VertexProgram
from repro.graph.csr import CSRGraph, DeviceCSR, csr_from_edges

OP_INSERT, OP_DELETE, OP_REWEIGHT = 0, 1, 2


class InvalidBatchError(ValueError):
    """An ``EdgeBatch`` failed validation; the whole batch was rejected
    atomically — no host-log or device-buffer mutation happened and
    ``version`` did not move.  ``index`` is the offending entry."""

    def __init__(self, msg: str, index: int | None = None):
        super().__init__(msg if index is None
                         else f"batch entry {index}: {msg}")
        self.index = index


@dataclass
class EdgeBatch:
    """One update batch: parallel arrays of (op, src, dst, weight).

    ``weight`` is the new weight for INSERT/REWEIGHT and ignored for
    DELETE.  Ops apply in order (multigraph semantics: INSERT always adds
    a parallel edge; DELETE/REWEIGHT match the first live (src, dst))."""

    op: np.ndarray
    src: np.ndarray
    dst: np.ndarray
    weight: np.ndarray

    def __post_init__(self):
        self.op = np.asarray(self.op, dtype=np.int32)
        self.src = np.asarray(self.src, dtype=np.int64)
        self.dst = np.asarray(self.dst, dtype=np.int64)
        self.weight = np.asarray(self.weight, dtype=np.float32)
        if not (self.op.shape == self.src.shape == self.dst.shape
                == self.weight.shape):
            # raised (not asserted): a ragged batch under ``python -O``
            # would silently pair ops with the wrong endpoints
            raise ValueError(
                "EdgeBatch fields must be parallel arrays; got shapes "
                f"op={self.op.shape} src={self.src.shape} "
                f"dst={self.dst.shape} weight={self.weight.shape}")

    def __len__(self) -> int:
        return len(self.op)

    @classmethod
    def inserts(cls, src, dst, weight) -> "EdgeBatch":
        src = np.asarray(src)
        return cls(np.full(len(src), OP_INSERT), src, dst, weight)

    @classmethod
    def deletes(cls, src, dst) -> "EdgeBatch":
        src = np.asarray(src)
        return cls(
            np.full(len(src), OP_DELETE), src, dst, np.zeros(len(src), np.float32)
        )


@dataclass
class UpdateReport:
    """What one ``apply`` did — everything the incremental layer needs.

    REWEIGHT is reported as delete(old weight) + insert(new weight) so the
    seeding rules (repro.stream.incremental) see one uniform op algebra.
    ``pre_adj``/``post_adj`` snapshot the out-adjacency (dsts, weights) of
    every affected source vertex before/after the batch — the SUM-program
    correction deltas are computed from exactly these."""

    version: int
    dirty_partitions: np.ndarray
    merged: bool
    ins_src: np.ndarray
    ins_dst: np.ndarray
    ins_w: np.ndarray
    del_src: np.ndarray
    del_dst: np.ndarray
    del_w: np.ndarray
    pre_adj: dict[int, tuple[np.ndarray, np.ndarray]] = field(default_factory=dict)
    post_adj: dict[int, tuple[np.ndarray, np.ndarray]] = field(default_factory=dict)

    @property
    def affected_vertices(self) -> np.ndarray:
        """Sources/destinations of changed edges (frontier seed set)."""
        return np.unique(
            np.concatenate([self.ins_src, self.ins_dst, self.del_src, self.del_dst])
        )


class DeltaCSR:
    """Mutable, versioned graph with a ``hytm_iteration``-compatible runtime.

    The vertex set is fixed at construction (updates are edge-only).
    Invariants between merge-compactions:

      * partition p's live edges are ``_src/_dst/_w[p*B : p*B + counts[p]]``
        (B = ``block_size``, uniform block capacity);
      * device arrays mirror the host log exactly (patched per batch) —
        including every registered *sharded* view
        (``sharded_runtime_for``): the same lanes scatter into the
        device-sharded (P_pad, B) grid, so the mesh sees the same edge
        multiset as the single device at every version;
      * ``seg_start`` (per-vertex segment starts, feeding the zero-copy
        alignment term of Eq. 3): with ``refresh_seg_start=True`` (the
        default) dirty partitions re-derive it on every patch from the
        live-degree prefix-sum, tracking the layout the next merge will
        realize; ``refresh_seg_start=False`` keeps the historical
        frozen-at-last-merge approximation, whose alignment term drifts
        as deletes accumulate (the request-count base uses the live
        out-degrees and stays exact either way).
    """

    def __init__(self, g: CSRGraph, config: HyTMConfig | None = None,
                 slack: float = 0.5, min_slack: int = 128,
                 refresh_seg_start: bool = True):
        self.config = config if config is not None else HyTMConfig()
        self.n_nodes = g.n_nodes
        self.slack = slack
        self.min_slack = min_slack
        # True (default): recompute the per-vertex ``seg_start`` of dirty
        # partitions on every patch (a prefix-sum over live degrees), so
        # the Eq. 3 zero-copy alignment term tracks the layout the next
        # merge-compaction will realize instead of drifting as deletes
        # accumulate.  False keeps the historical frozen-at-last-merge
        # approximation (tests/test_stream.py quantifies the drift).
        self.refresh_seg_start = refresh_seg_start
        self.version = 0
        self.layout_version = 0
        self.dirty: set[int] = set()  # dirty partitions since last merge
        # bounded batch_id -> UpdateReport memory for idempotent
        # redelivery (exactly-once apply under at-least-once delivery)
        self._applied: dict = {}
        self.dedup_window = 64
        self._inv_deg_cache: dict[bool, jnp.ndarray] = {}
        # shared across the Runtime views runtime_for builds, so the
        # chunked driver's per-(program, config, shapes) eval_shape
        # results survive across queries (keys carry the shapes — safe
        # through merge-compaction re-blocking)
        self._info_shape_cache: dict = {}
        # sharded (P, B) grid views (graph_shard.ShardedRuntime), keyed by
        # (axis, device ids, weighted-norm flag); patched in lock-step
        # with the single-device buffers and rebuilt on merge-compaction
        self._sharded_views: dict[tuple, Any] = {}
        self._build_layout(g)

    # ------------------------------------------------------------ construction
    @classmethod
    def from_graph(cls, g: CSRGraph, config: HyTMConfig | None = None,
                   **kw) -> "DeltaCSR":
        return cls(g, config, **kw)

    def _build_layout(self, g: CSRGraph) -> None:
        cfg = self.config
        table: PartitionTable = partition_graph(
            g, n_partitions=cfg.n_partitions,
            partition_bytes=cfg.partition_bytes, d1=cfg.link.d1,
        )
        P = table.n_partitions
        epp = table.edges_per_partition
        max_epp = int(epp.max(initial=1))
        B = max_epp + max(self.min_slack, int(np.ceil(max_epp * self.slack)))
        B = max(128, -(-B // 128) * 128)
        cap = P * B

        src = np.zeros(cap, np.int32)
        dst = np.zeros(cap, np.int32)
        w = np.full(cap, np.float32(np.inf), np.float32)
        valid = np.zeros(cap, bool)
        src_all = g.edge_sources()
        dst_all = g.indices
        w_all = g.weights if g.weights is not None else np.ones(g.n_edges, np.float32)
        counts = epp.astype(np.int64)
        for p in range(P):
            e0, e1 = int(table.edge_start[p]), int(table.edge_start[p + 1])
            k = e1 - e0
            src[p * B:p * B + k] = src_all[e0:e1]
            dst[p * B:p * B + k] = dst_all[e0:e1]
            w[p * B:p * B + k] = w_all[e0:e1]
            valid[p * B:p * B + k] = True

        part_id = np.repeat(
            np.arange(P, dtype=np.int32), table.vertices_per_partition
        )
        # per-vertex segment start relocated into the blocked layout
        seg_start = (
            part_id.astype(np.int64) * B
            + g.indptr[:-1] - table.edge_start[part_id]
        )

        self._src, self._dst, self._w, self._valid = src, dst, w, valid
        self.counts = counts
        self.block_size = B
        self.n_partitions = P
        self.vertex_start = table.vertex_start
        self.vertex_part = part_id
        self.out_deg = g.out_degrees.copy()
        self._seg_start_host = seg_start

        cap_start = np.arange(P + 1, dtype=np.int64) * B
        self.parts = DevicePartitions(
            vertex_start=jnp.asarray(table.vertex_start, jnp.int32),
            edge_start=jnp.asarray(cap_start, jnp.int32),
            part_edges=jnp.asarray(counts, jnp.int32),
            vertex_part_id=jnp.asarray(part_id),
            n_partitions=P,
            block_size=B,
        )
        self.csr = DeviceCSR(
            edge_src=jnp.asarray(src),
            edge_dst=jnp.asarray(dst),
            edge_weight=jnp.asarray(w),
            edge_valid=jnp.asarray(valid),
            out_degree=jnp.asarray(self.out_deg, jnp.int32),
            seg_start=jnp.asarray(seg_start, jnp.int32),
            n_nodes=self.n_nodes,
            n_edges=int(counts.sum()),  # live count at last merge
        )
        self.zc_req = zc_request_counts(
            self.csr.out_degree, self.csr.seg_start, self.config.link
        )
        self._inv_deg_cache.clear()
        # merge-compaction re-blocks the grid: re-upload every sharded
        # view from the fresh layout (per device, via the row sharding)
        # and drop its compiled sweeps — the static partition grid the
        # cached closures were built around may have moved.  Owner-layout
        # views also rebuild their halo plan here (the layout_version
        # bump moved the edge blocks, so the boundary sets moved too).
        for key, rt in self._sharded_views.items():
            self._refill_sharded_view(rt, key[2])
            rt.iteration_cache.clear()

    # ------------------------------------------------------------- inspection
    @property
    def n_edges(self) -> int:
        return int(self.counts.sum())

    def live_edges(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(src, dst, weight) of the current edge multiset (host views)."""
        mask = self._valid
        return self._src[mask], self._dst[mask], self._w[mask]

    def to_host_graph(self) -> CSRGraph:
        """Materialize the current edge set as a fresh ``CSRGraph`` (the
        from-scratch oracle the equivalence tests recompute on)."""
        s, d, w = self.live_edges()
        return csr_from_edges(self.n_nodes, s.astype(np.int64),
                              d.astype(np.int64), w)

    def _out_edges(self, u: int, extra=None) -> tuple[np.ndarray, np.ndarray]:
        p = int(self.vertex_part[u])
        lo = p * self.block_size
        hi = lo + int(self.counts[p])
        m = self._src[lo:hi] == u
        dsts, ws = self._dst[lo:hi][m].copy(), self._w[lo:hi][m].copy()
        if extra and extra.get(p):
            ex = [(v, ew) for (eu, v, ew) in extra[p] if eu == u]
            if ex:
                dsts = np.concatenate([dsts, np.array([v for v, _ in ex], dsts.dtype)])
                ws = np.concatenate([ws, np.array([ew for _, ew in ex], np.float32)])
        return dsts, ws

    # ---------------------------------------------------------------- updates
    def validate_batch(self, batch: EdgeBatch) -> None:
        """Reject a malformed batch *before any mutation*: unknown ops,
        negative/out-of-range endpoints, non-finite weights on
        INSERT/REWEIGHT, and delete-of-absent-edge (checked against the
        live multiset with the batch's own earlier entries applied, so
        insert-then-delete within one batch is legal).  Raises
        :class:`InvalidBatchError`; on return ``apply`` is guaranteed to
        succeed without partial effects."""
        n = self.n_nodes
        if len(batch) == 0:
            return
        bad = np.nonzero(~np.isin(batch.op, (OP_INSERT, OP_DELETE,
                                             OP_REWEIGHT)))[0]
        if bad.size:
            i = int(bad[0])
            raise InvalidBatchError(f"unknown op {int(batch.op[i])}", i)
        bad = np.nonzero((batch.src < 0) | (batch.src >= n)
                         | (batch.dst < 0) | (batch.dst >= n))[0]
        if bad.size:
            i = int(bad[0])
            raise InvalidBatchError(
                f"edge endpoint out of range: ({int(batch.src[i])}, "
                f"{int(batch.dst[i])}) with n_nodes={n} (vertex set is "
                "fixed)", i)
        writes = (batch.op == OP_INSERT) | (batch.op == OP_REWEIGHT)
        bad = np.nonzero(writes & ~np.isfinite(batch.weight))[0]
        if bad.size:
            i = int(bad[0])
            raise InvalidBatchError(
                f"non-finite weight {float(batch.weight[i])}", i)
        # delete-of-absent: walk the batch against lazily-seeded live
        # (u, v) multiset counts — mirrors apply's multigraph semantics
        # (DELETE matches one live parallel copy; REWEIGHT of an absent
        # edge degenerates to an insert)
        counts: dict[tuple[int, int], int] = {}
        seeded: set[int] = set()
        for i in range(len(batch)):
            u, v = int(batch.src[i]), int(batch.dst[i])
            if u not in seeded:
                seeded.add(u)
                dsts, _ = self._out_edges(u)
                for d in dsts:
                    key = (u, int(d))
                    counts[key] = counts.get(key, 0) + 1
            o = int(batch.op[i])
            if o == OP_INSERT:
                counts[(u, v)] = counts.get((u, v), 0) + 1
            elif o == OP_DELETE:
                c = counts.get((u, v), 0)
                if c <= 0:
                    raise InvalidBatchError(
                        f"delete of absent edge ({u}, {v})", i)
                counts[(u, v)] = c - 1
            elif counts.get((u, v), 0) == 0:
                counts[(u, v)] = 1  # reweight-of-absent inserts

    def apply(self, batch: EdgeBatch, batch_id=None,
              faults=None) -> UpdateReport:
        """Apply one batch; patch device buffers (or merge-compact on
        overflow); bump ``version``; return the report.

        Sharded equivalence guarantee: registered sharded views
        (``sharded_runtime_for``) are patched in the same step — inserts,
        deletes, and reweights scatter into the device-sharded (P_pad, B)
        grid without re-blocking, and a merge-compaction re-partitions
        and re-uploads them per device.  After any sequence of ``apply``
        calls, a warm-started sharded run over the view is bit-identical
        to the warm-started single-device ``async_sweep=False`` run for
        min-combine programs (values, iterations, transfer accounting,
        engine picks) and tolerance-bounded for sum-combine — the
        contract ``tests/test_stream_sharded.py`` enforces.

        Atomicity: :meth:`validate_batch` runs first, so a batch that
        would fail (bad op, out-of-range endpoint, NaN weight,
        delete-of-absent) raises :class:`InvalidBatchError` with **zero
        side effects** — no host-log entry, no device patch, no version
        bump.

        ``batch_id`` (optional) makes delivery idempotent: an id seen
        before returns the original :class:`UpdateReport` without
        re-applying (redelivered batches must not double-apply — the
        ``resilience.supervisor.deliver_update`` contract).  ``faults``
        injects delivery drops (site ``update_delivery``): a dropped
        batch raises ``UpdateLost`` before validation, exactly as if it
        never arrived."""
        if batch_id is not None and batch_id in self._applied:
            return self._applied[batch_id]
        if faults is not None and faults.fire("update_delivery") == "drop":
            from repro.resilience.faults import UpdateLost

            raise UpdateLost("update_delivery", 0,
                             f"injected drop of batch {batch_id!r}")
        self.validate_batch(batch)

        affected = np.unique(batch.src)
        pre_adj = {int(u): self._out_edges(int(u)) for u in affected}

        touched: set[int] = set()
        dirty: set[int] = set()
        extra: dict[int, list] = defaultdict(list)
        ins_rec: list[tuple] = []
        del_rec: list[tuple] = []

        for i in range(len(batch)):
            o = int(batch.op[i])
            u, v = int(batch.src[i]), int(batch.dst[i])
            wt = float(batch.weight[i])
            p = int(self.vertex_part[u])
            dirty.add(p)
            if o == OP_INSERT:
                self._insert(u, v, wt, p, touched, extra)
                ins_rec.append((u, v, wt))
            elif o == OP_DELETE:
                old = self._delete(u, v, p, touched, extra)
                if old is not None:
                    del_rec.append((u, v, old))
            elif o == OP_REWEIGHT:
                old = self._reweight(u, v, wt, p, touched, extra)
                if old is None:  # absent edge: reweight degenerates to insert
                    self._insert(u, v, wt, p, touched, extra)
                else:
                    del_rec.append((u, v, old))
                ins_rec.append((u, v, wt))
            else:
                raise ValueError(f"unknown op {o}")

        post_adj = {int(u): self._out_edges(int(u), extra) for u in affected}

        merged = any(extra.values())
        if merged:
            s, d, w = self.live_edges()
            for p, lst in extra.items():
                if not lst:
                    continue
                es = np.array([e[0] for e in lst], np.int64)
                ed = np.array([e[1] for e in lst], np.int64)
                ew = np.array([e[2] for e in lst], np.float32)
                s = np.concatenate([s.astype(np.int64), es])
                d = np.concatenate([d.astype(np.int64), ed])
                w = np.concatenate([w, ew])
            self._build_layout(csr_from_edges(self.n_nodes, s, d, w))
            self.layout_version += 1
            self.dirty = set()
            dirty = set(range(self.n_partitions))
        else:
            self._patch_device(touched, dirty)
            self.dirty |= dirty

        self.version += 1

        def _cols(rec, j, dt):
            return np.array([r[j] for r in rec], dtype=dt)

        report = UpdateReport(
            version=self.version,
            dirty_partitions=np.array(sorted(dirty), np.int64),
            merged=merged,
            ins_src=_cols(ins_rec, 0, np.int64),
            ins_dst=_cols(ins_rec, 1, np.int64),
            ins_w=_cols(ins_rec, 2, np.float32),
            del_src=_cols(del_rec, 0, np.int64),
            del_dst=_cols(del_rec, 1, np.int64),
            del_w=_cols(del_rec, 2, np.float32),
            pre_adj=pre_adj,
            post_adj=post_adj,
        )
        if batch_id is not None:
            self._applied[batch_id] = report
            while len(self._applied) > self.dedup_window:
                self._applied.pop(next(iter(self._applied)))
        return report

    def _insert(self, u, v, wt, p, touched, extra):
        B = self.block_size
        if int(self.counts[p]) < B and not extra.get(p):
            slot = p * B + int(self.counts[p])
            self._src[slot], self._dst[slot] = u, v
            self._w[slot], self._valid[slot] = wt, True
            self.counts[p] += 1
            touched.add(slot)
        else:
            # block full (or already spilling): spill to the merge log
            extra[p].append((u, v, wt))
        self.out_deg[u] += 1

    def _find_slot(self, u, v, p) -> int | None:
        lo = p * self.block_size
        hi = lo + int(self.counts[p])
        hits = np.nonzero((self._src[lo:hi] == u) & (self._dst[lo:hi] == v))[0]
        return int(lo + hits[0]) if len(hits) else None

    def _delete(self, u, v, p, touched, extra) -> float | None:
        slot = self._find_slot(u, v, p)
        if slot is None:
            for j, (eu, ev, ew) in enumerate(extra.get(p, ())):
                if eu == u and ev == v:
                    extra[p].pop(j)
                    self.out_deg[u] -= 1
                    return float(ew)
            # unreachable after validate_batch (delete-of-absent is
            # rejected up front); kept as a defensive no-op
            return None
        old = float(self._w[slot])
        last = p * self.block_size + int(self.counts[p]) - 1
        # swap-remove keeps the live prefix dense (edge order is free)
        self._src[slot], self._dst[slot] = self._src[last], self._dst[last]
        self._w[slot] = self._w[last]
        self._src[last], self._dst[last] = 0, 0
        self._w[last], self._valid[last] = np.float32(np.inf), False
        self.counts[p] -= 1
        touched.add(slot)
        touched.add(last)
        self.out_deg[u] -= 1
        return old

    def _reweight(self, u, v, wt, p, touched, extra) -> float | None:
        slot = self._find_slot(u, v, p)
        if slot is None:
            for j, (eu, ev, ew) in enumerate(extra.get(p, ())):
                if eu == u and ev == v:
                    extra[p][j] = (u, v, wt)
                    return float(ew)
            return None
        old = float(self._w[slot])
        self._w[slot] = wt
        touched.add(slot)
        return old

    def _patch_device(self, touched: set[int], dirty: set[int] = frozenset()) -> None:
        """Scatter the touched lanes + refresh the (P,)/(n,) vectors —
        the 'patched, not rebuilt' contract (shapes never change here).
        Registered sharded views are patched in the same step, so the
        (P, B) grid on the mesh mirrors the single-device buffers at
        every version."""
        idx = None
        if touched:
            idx = np.fromiter(sorted(touched), np.int64, len(touched))
            # pad the scatter index to a power-of-two bucket (repeating the
            # last lane — idempotent for .set) so successive batches of
            # similar size reuse one compiled scatter instead of retracing
            bucket = 1 << int(np.ceil(np.log2(len(idx))))
            idx = np.pad(idx, (0, bucket - len(idx)), mode="edge")
            self.csr = dataclasses.replace(
                self.csr,
                edge_src=self.csr.edge_src.at[idx].set(self._src[idx]),
                edge_dst=self.csr.edge_dst.at[idx].set(self._dst[idx]),
                edge_weight=self.csr.edge_weight.at[idx].set(self._w[idx]),
                edge_valid=self.csr.edge_valid.at[idx].set(self._valid[idx]),
                out_degree=jnp.asarray(self.out_deg, jnp.int32),
            )
        else:
            self.csr = dataclasses.replace(
                self.csr, out_degree=jnp.asarray(self.out_deg, jnp.int32)
            )
        self.parts = dataclasses.replace(
            self.parts, part_edges=jnp.asarray(self.counts, jnp.int32)
        )
        if self.refresh_seg_start:
            # re-derive the ZC alignment base of dirty partitions from the
            # live degree prefix-sum (what the next merge will realize)
            self._refresh_seg_start(dirty)
        # request-count base tracks the live degrees; the alignment term
        # uses the refreshed seg_start (or, with refresh_seg_start=False,
        # the last-merge snapshot — the historical approximation)
        self.zc_req = zc_request_counts(
            self.csr.out_degree, self.csr.seg_start, self.config.link
        )
        self._inv_deg_cache.clear()
        for key, rt in self._sharded_views.items():
            self._patch_sharded_view(rt, key[2], idx)

    def _refresh_seg_start(self, dirty) -> None:
        """Recompute ``seg_start`` for ``dirty`` partitions: vertex v's
        segment starts at the partition base plus the summed live degrees
        of the vertices before it — exactly the dense layout the next
        merge-compaction materializes, so the Eq. 3 alignment flags stop
        drifting as swap-removes scramble the block interior.  O(vertices
        of the dirty partitions) on host; uploaded as one (n,) vector."""
        changed = False
        B = self.block_size
        for p in sorted(dirty):
            v0, v1 = int(self.vertex_start[p]), int(self.vertex_start[p + 1])
            if v1 <= v0:
                continue
            deg = self.out_deg[v0:v1].astype(np.int64)
            seg = p * B + np.concatenate(([0], np.cumsum(deg[:-1])))
            if not np.array_equal(seg, self._seg_start_host[v0:v1]):
                self._seg_start_host[v0:v1] = seg
                changed = True
        if changed:
            self.csr = dataclasses.replace(
                self.csr,
                seg_start=jnp.asarray(self._seg_start_host, jnp.int32),
            )

    # ---------------------------------------------------------------- runtime
    def _inv_deg(self, weighted: bool) -> jnp.ndarray:
        inv = self._inv_deg_cache.get(weighted)
        if inv is None:
            if weighted:
                wsum = np.zeros(self.n_nodes, np.float64)
                s, _, w = self.live_edges()
                np.add.at(wsum, s, w.astype(np.float64))
                inv = jnp.asarray(1.0 / np.maximum(wsum, 1e-30), jnp.float32)
            else:
                inv = 1.0 / jnp.maximum(
                    self.csr.out_degree.astype(jnp.float32), 1.0
                )
            self._inv_deg_cache[weighted] = inv
        return inv

    def runtime_for(self, program: VertexProgram) -> Runtime:
        """A ``core.hytm.Runtime`` view of the current version (shared
        device buffers — do not mutate between ``apply`` calls)."""
        weighted = bool(program.use_delta and program.weighted)
        return Runtime(
            csr=self.csr, parts=self.parts, zc_req=self.zc_req,
            inv_deg=self._inv_deg(weighted), n_hub_partitions=0,
            info_shape_cache=self._info_shape_cache,
        )

    # --------------------------------------------------------- sharded runtime
    def sharded_runtime_for(self, program: VertexProgram, mesh=None,
                            axis: str | None = None):
        """A ``graph_shard.ShardedRuntime`` view of the current version:
        the blocked edge log as a (P_pad, B) grid sharded over
        ``config.mesh_axis`` (P_pad pads the partition count up to a
        multiple of the mesh size with empty, accounting-neutral rows).

        The view is registered: every subsequent ``apply`` patches its
        device-sharded buffers by scatter in lock-step with the
        single-device buffers (insert/delete/reweight land without
        re-blocking), and a merge-compaction re-partitions and re-uploads
        it per device (``layout_version`` bump, compiled sweeps dropped).
        Because the partition structure, live counts, and ``seg_start``
        base are *shared* with ``runtime_for``'s view, a sharded run over
        this grid selects the same engines and charges the same transfer
        bytes as the single-device run at every version — the sharded
        warm-start equivalence contract (tests/test_stream_sharded.py).
        """
        axis = axis if axis is not None else self.config.mesh_axis
        if axis is None:
            raise ValueError(
                "no mesh axis: set config.mesh_axis or pass axis= — use "
                "runtime_for() for the single-device view")
        if mesh is None:
            from repro.launch.mesh import make_graph_mesh

            mesh = make_graph_mesh(axis=axis)
        if axis not in mesh.axis_names:
            raise ValueError(
                f"config.mesh_axis={axis!r} is not an axis of the mesh "
                f"(axes: {mesh.axis_names})")
        weighted = bool(program.use_delta and program.weighted)
        # the layout is part of the view identity: owner and replicated
        # views of the same mesh hold differently-padded vectors and
        # differently-placed state, so they specialize separately
        key = (axis, tuple(int(d.id) for d in mesh.devices.flat), weighted,
               self.config.vertex_sharding)
        rt = self._sharded_views.get(key)
        if rt is None:
            from repro.dist.graph_shard import (
                ShardedRuntime, _check_vertex_sharding)

            rt = ShardedRuntime(
                mesh=mesh, axis=axis, blocks=None, parts=None,
                out_degree=None, zc_req=None, inv_deg=None,
                n_nodes=self.n_nodes, n_partitions=0, n_hub_partitions=0,
                vertex_sharding=_check_vertex_sharding(
                    self.config.vertex_sharding),
            )
            self._refill_sharded_view(rt, weighted)
            self._sharded_views[key] = rt
        return rt

    def _padded_vertex_vecs(self, rt, weighted: bool):
        """(out_degree, zc_req, inv_deg) for a sharded view — padded from
        (n,) to (n_pad,) with inert fills under the owner layout (pads
        carry no edges: degree 0, zc 0, inv_deg 1)."""
        out_degree = self.csr.out_degree
        zc_req = self.zc_req
        inv_deg = self._inv_deg(weighted)
        if rt.vertex_sharding == "owner":
            from repro.dist.graph_shard import _pad_vertex_vec

            out_degree = _pad_vertex_vec(out_degree, rt.n_pad, 0)
            zc_req = _pad_vertex_vec(zc_req, rt.n_pad, 0.0)
            inv_deg = _pad_vertex_vec(inv_deg, rt.n_pad, 1.0)
        return out_degree, zc_req, inv_deg

    def _padded_part_id(self, rt, P_pad: int) -> jnp.ndarray:
        """Per-vertex partition ids for a sharded view, padded to
        (n_pad,) under the owner layout (pads park in the last padded
        partition — empty, so stats never count them)."""
        part_id = self.vertex_part
        if rt.vertex_sharding == "owner" and rt.n_pad > self.n_nodes:
            part_id = np.concatenate(
                [part_id,
                 np.full(rt.n_pad - self.n_nodes, P_pad - 1, np.int32)])
        return jnp.asarray(part_id)

    def _grid_arrays(self, n_dev: int):
        """Padded (P_pad, B) host grids of the blocked edge log."""
        P_real, B = self.n_partitions, self.block_size
        P_pad = -(-P_real // n_dev) * n_dev

        def grid(a: np.ndarray, fill) -> np.ndarray:
            out = a.reshape(P_real, B)
            if P_pad != P_real:
                out = np.concatenate(
                    [out, np.full((P_pad - P_real, B), fill, a.dtype)])
            return out

        return P_pad, grid

    def _refill_sharded_view(self, rt, weighted: bool) -> None:
        """(Re-)upload a sharded view from the current host layout — the
        build path and the merge-compaction path (full re-upload per
        device; between merges ``_patch_sharded_view`` scatters)."""
        from jax.sharding import NamedSharding, PartitionSpec

        from repro.dist.graph_shard import BlockedEdges, build_halo_plan

        n_dev = int(rt.mesh.shape[rt.axis])
        P_pad, grid = self._grid_arrays(n_dev)
        src_g, dst_g = grid(self._src, 0), grid(self._dst, 0)
        valid_g = grid(self._valid, False)
        row = NamedSharding(rt.mesh, PartitionSpec(rt.axis, None))
        rep = NamedSharding(rt.mesh, PartitionSpec())
        rt.blocks = BlockedEdges(
            src=jax.device_put(src_g, row),
            dst=jax.device_put(dst_g, row),
            weight=jax.device_put(grid(self._w, np.float32(np.inf)), row),
            in_range=jax.device_put(valid_g, row),
        )
        owner = rt.vertex_sharding == "owner"
        if owner:
            rt.halo = build_halo_plan(src_g, dst_g, valid_g, self.n_nodes,
                                      n_dev)
            rt.n_pad = rt.halo.n_pad
        else:
            rt.halo, rt.n_pad = None, self.n_nodes
        pad = P_pad - self.n_partitions
        vstart = np.concatenate(
            [self.vertex_start, np.full(pad, self.vertex_start[-1])])
        counts = np.concatenate([self.counts, np.zeros(pad, np.int64)])
        cap_start = np.arange(P_pad + 1, dtype=np.int64) * self.block_size
        rt.parts = DevicePartitions(
            vertex_start=jax.device_put(
                jnp.asarray(vstart, jnp.int32), rep),
            edge_start=jax.device_put(jnp.asarray(cap_start, jnp.int32), rep),
            part_edges=jax.device_put(jnp.asarray(counts, jnp.int32), rep),
            vertex_part_id=jax.device_put(
                self._padded_part_id(rt, P_pad), rep),
            n_partitions=P_pad,
            block_size=self.block_size,
        )
        rt.out_degree, rt.zc_req, rt.inv_deg = (
            jax.device_put(v, rep)
            for v in self._padded_vertex_vecs(rt, weighted))
        rt.n_partitions = P_pad

    def _patch_sharded_view(self, rt, weighted: bool,
                            idx: np.ndarray | None) -> None:
        """Scatter the touched lanes into the device-sharded (P_pad, B)
        grid and refresh the replicated (P,)/(n,) vectors — no
        re-blocking, no re-upload of untouched rows.  ``idx`` is the
        (bucket-padded) flat lane index ``_patch_device`` used.  An
        owner-layout view also refreshes its halo plan from the host log
        (moved lanes can add/remove boundary vertices — the plan only
        steers the ICI cost accounting, but it must track the live edge
        set for ``halo_level_cost`` to charge the real boundary)."""
        from jax.sharding import NamedSharding, PartitionSpec

        from repro.dist.graph_shard import BlockedEdges, build_halo_plan

        row = NamedSharding(rt.mesh, PartitionSpec(rt.axis, None))
        rep = NamedSharding(rt.mesh, PartitionSpec())
        if idx is not None:
            B = self.block_size
            rows_, cols_ = idx // B, idx % B
            b = rt.blocks
            rt.blocks = BlockedEdges(
                src=jax.device_put(
                    b.src.at[rows_, cols_].set(self._src[idx]), row),
                dst=jax.device_put(
                    b.dst.at[rows_, cols_].set(self._dst[idx]), row),
                weight=jax.device_put(
                    b.weight.at[rows_, cols_].set(self._w[idx]), row),
                in_range=jax.device_put(
                    b.in_range.at[rows_, cols_].set(self._valid[idx]), row),
            )
        pad = rt.n_partitions - self.n_partitions
        counts = np.concatenate([self.counts, np.zeros(pad, np.int64)])
        rt.parts = dataclasses.replace(
            rt.parts,
            part_edges=jax.device_put(jnp.asarray(counts, jnp.int32), rep),
        )
        if rt.vertex_sharding == "owner" and idx is not None:
            n_dev = int(rt.mesh.shape[rt.axis])
            _, grid = self._grid_arrays(n_dev)
            rt.halo = build_halo_plan(
                grid(self._src, 0), grid(self._dst, 0),
                grid(self._valid, False), self.n_nodes, n_dev)
        rt.out_degree, rt.zc_req, rt.inv_deg = (
            jax.device_put(v, rep)
            for v in self._padded_vertex_vecs(rt, weighted))


def random_batch(
    dcsr: DeltaCSR,
    rng: np.random.Generator,
    n_insert: int = 0,
    n_delete: int = 0,
    n_reweight: int = 0,
    max_weight: float = 64.0,
) -> EdgeBatch:
    """Sample a plausible batch against the current edge set: deletions and
    reweights pick live edges, insertions pick uniform endpoints."""
    ls, ld, _ = dcsr.live_edges()
    ops, src, dst, w = [], [], [], []
    if n_delete or n_reweight:
        k = min(n_delete + n_reweight, len(ls))
        pick = rng.choice(len(ls), size=k, replace=False) if k else []
        for j, e in enumerate(pick):
            is_del = j < min(n_delete, k)
            ops.append(OP_DELETE if is_del else OP_REWEIGHT)
            src.append(int(ls[e]))
            dst.append(int(ld[e]))
            w.append(float(rng.integers(1, max_weight)))
    for _ in range(n_insert):
        ops.append(OP_INSERT)
        src.append(int(rng.integers(0, dcsr.n_nodes)))
        dst.append(int(rng.integers(0, dcsr.n_nodes)))
        w.append(float(rng.integers(1, max_weight)))
    return EdgeBatch(np.array(ops), np.array(src), np.array(dst),
                     np.array(w, np.float32))
