"""granite-20b [arXiv:2405.04324; hf] — dense code model, MQA (kv=1).

52L d_model=6144 48H (kv=1) d_ff=24576 vocab=49152.  GPT-BigCode family:
non-gated GELU MLP (a gated SwiGLU at these dims would be ~27B params,
not 20B — see DESIGN.md §4).
"""

from repro.configs.common import standard_lm_arch
from repro.models.transformer import TransformerConfig
from repro.train.optimizer import OptimizerConfig

CONFIG = TransformerConfig(
    name="granite-20b",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_head=128,
    d_ff=24576,
    vocab=49152,
    ffn_act="gelu",
    tie_embeddings=True,
)

OPT = OptimizerConfig(name="adamw", learning_rate=2e-4, warmup_steps=2000)

ARCH = standard_lm_arch("granite-20b", CONFIG, OPT, microbatches=8)
