"""kimi-k2-1t-a32b [arXiv:2501.kimi2; unverified] — trillion-param MoE.

61L d_model=7168 64H (GQA kv=8) expert d_ff=2048 vocab=163840,
MoE 384 routed experts top-8; first layer dense (d_ff=18432, as in the
DeepSeek-V3/K2 family).  ~1.04T total params, ~32B active.

Memory plan (DESIGN.md §5): bf16 params + Adafactor (factored second
moments) keep params+opt+grads within a 512-chip v5e slice; activations
bound by layer remat + token-chunked MoE dispatch.
"""

from repro.configs.common import standard_lm_arch
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig
from repro.train.optimizer import OptimizerConfig

CONFIG = TransformerConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=2048,
    vocab=163840,
    moe=MoEConfig(
        n_experts=384, top_k=8, d_ff=2048, n_shared=0,
        capacity_factor=1.25, dispatch="sorted", chunk_tokens=4096,
    ),
    first_dense_layers=1,
    d_ff_dense=18432,
    tie_embeddings=False,
    param_dtype="bfloat16",
)

OPT = OptimizerConfig(name="adafactor", learning_rate=2e-4, warmup_steps=2000)

ARCH = standard_lm_arch(
    "kimi-k2-1t-a32b", CONFIG, OPT, microbatches=8, grad_accum_dtype="bfloat16"
)
