"""meshgraphnet [arXiv:2010.03409; unverified] — 15 message-passing steps,
d_hidden=128, sum aggregator, 2-layer MLPs, encode-process-decode.
Regression head (per-node dynamics), mesh-edge features."""

from repro.configs.common import standard_gnn_arch
from repro.models.gnn import GNNConfig
from repro.train.optimizer import OptimizerConfig

CONFIG = GNNConfig(
    name="meshgraphnet",
    arch="meshgraphnet",
    n_layers=15,
    d_hidden=128,
    d_in=12,
    d_out=3,
    aggregator="sum",
    mlp_layers=2,
    d_edge_in=8,
    task="regression",
)

OPT = OptimizerConfig(name="adamw", learning_rate=1e-3, warmup_steps=100)

ARCH = standard_gnn_arch("meshgraphnet", CONFIG, OPT)
