"""pna [arXiv:2004.05718; paper] — 4L d_hidden=75,
aggregators mean-max-min-std x scalers id-amp-atten (12 combinations)."""

from repro.configs.common import standard_gnn_arch
from repro.models.gnn import GNNConfig
from repro.train.optimizer import OptimizerConfig

CONFIG = GNNConfig(
    name="pna",
    arch="pna",
    n_layers=4,
    d_hidden=75,
    d_in=75,
    d_out=10,
)

OPT = OptimizerConfig(name="adamw", learning_rate=1e-3, warmup_steps=100)

ARCH = standard_gnn_arch("pna", CONFIG, OPT)
