"""Cell builders: everything the dry-run / smoke tests need per
(architecture x input-shape) pair.

A *cell* resolves to a ``CellBuild``: the step function, abstract input
specs (ShapeDtypeStruct — no allocation), in/out shardings for the given
mesh, and the analytic MODEL_FLOPS used by the roofline's useful-compute
ratio.  ``skip`` cells (e.g. long_500k on pure full-attention archs)
carry the reason instead.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (
    batch_axes,
    dlrm_rule,
    gnn_data_spec,
    gnn_rule,
    lm_batch_spec,
    lm_cache_rule,
    lm_rule,
    tree_shardings,
)
from repro.models import dlrm as dlrm_mod
from repro.models import gnn as gnn_mod
from repro.models import transformer as tf_mod
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import init_train_state, make_train_step


@dataclass
class CellBuild:
    fn: Callable
    args: tuple                 # abstract ShapeDtypeStructs
    in_shardings: Any
    out_shardings: Any
    donate_argnums: tuple = ()
    model_flops: float = 0.0    # 6*N*D (train) / 2*N*D (serve) useful FLOPs
    note: str = ""


@dataclass
class ArchSpec:
    name: str
    family: str                                 # 'lm' | 'gnn' | 'recsys' | 'graph'
    cells: dict = field(default_factory=dict)   # shape -> builder(mesh) -> CellBuild
    skips: dict = field(default_factory=dict)   # shape -> reason
    smoke: Callable | None = None               # () -> reduced-config smoke callable
    model_config: Any = None

    def shapes(self) -> list[str]:
        return list(self.cells) + list(self.skips)


# ---------------------------------------------------------------- LM cells

def lm_param_count(cfg: tf_mod.TransformerConfig) -> tuple[float, float]:
    """(total, active) parameter counts, analytic."""
    abstract = tf_mod.abstract_params(cfg)
    total = sum(l.size for l in jax.tree.leaves(abstract))
    active = total
    if cfg.moe is not None:
        m = cfg.moe
        per_expert = 3 * cfg.d_model * m.d_ff
        inactive = (m.n_experts - m.top_k) * per_expert
        active = total - cfg.n_scan_layers * inactive
    return float(total), float(active)


def _lm_state_abstract(cfg, opt_cfg):
    return jax.eval_shape(
        lambda: init_train_state(
            tf_mod.init_transformer(jax.random.PRNGKey(0), cfg), opt_cfg
        )
    )


def lm_train_cell(
    cfg: tf_mod.TransformerConfig,
    opt_cfg: OptimizerConfig,
    global_batch: int,
    seq_len: int,
    microbatches: int = 1,
    grad_accum_dtype: str = "float32",
):
    def build(mesh) -> CellBuild:
        ba = batch_axes(mesh)
        loss_fn = lambda p, b: tf_mod.lm_loss(
            p, b["tokens"], cfg, mesh=mesh, batch_axes=ba
        )

        def pin_micro(mbs):
            return jax.tree.map(
                lambda x: jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(None, ba, *([None] * (x.ndim - 2))))
                ),
                mbs,
            )

        step = make_train_step(
            loss_fn, opt_cfg, microbatches=microbatches,
            microbatch_constraint=pin_micro if microbatches > 1 else None,
            accum_dtype=jnp.dtype(grad_accum_dtype),
        )
        state = _lm_state_abstract(cfg, opt_cfg)
        batch = {"tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32)}
        rule = lm_rule(mesh)
        state_sh = tree_shardings(state, mesh, rule)
        batch_sh = {"tokens": NamedSharding(mesh, lm_batch_spec(mesh))}
        scalar = NamedSharding(mesh, P())
        _, active = lm_param_count(cfg)
        tokens = global_batch * (seq_len - 1)
        return CellBuild(
            fn=step,
            args=(state, batch),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, {"loss": scalar, "grad_norm": scalar}),
            donate_argnums=(0,),
            model_flops=6.0 * active * tokens,
        )

    return build


def lm_prefill_cell(cfg: tf_mod.TransformerConfig, batch: int, seq_len: int):
    serve_cfg = cfg.replace(remat=False, param_dtype="bfloat16")

    def build(mesh) -> CellBuild:
        ba = batch_axes(mesh)

        def fn(params, tokens, caches):
            return tf_mod.prefill(params, tokens, serve_cfg, caches, mesh=mesh, batch_axes=ba)

        params = tf_mod.abstract_params(serve_cfg)
        caches = jax.eval_shape(lambda: tf_mod.init_cache(serve_cfg, batch, seq_len))
        tokens = jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)
        rule = lm_rule(mesh)
        cache_rule = lm_cache_rule(mesh, serve_cfg.n_kv_heads)
        p_sh = tree_shardings(params, mesh, rule)
        c_sh = tree_shardings(caches, mesh, cache_rule)
        t_sh = NamedSharding(mesh, lm_batch_spec(mesh))
        logits_sh = NamedSharding(mesh, P(ba, "model"))
        _, active = lm_param_count(serve_cfg)
        return CellBuild(
            fn=fn,
            args=(params, tokens, caches),
            in_shardings=(p_sh, t_sh, c_sh),
            out_shardings=(logits_sh, c_sh),
            donate_argnums=(2,),
            model_flops=2.0 * active * batch * seq_len,
        )

    return build


def lm_decode_cell(cfg: tf_mod.TransformerConfig, batch: int, cache_len: int):
    serve_cfg = cfg.replace(remat=False, param_dtype="bfloat16")

    def build(mesh) -> CellBuild:
        ba = batch_axes(mesh)
        ba_size = 1
        for a in ba:
            ba_size *= mesh.shape[a]
        # tiny-batch long-context decode: batch dim replicated (the cache
        # rule shards the sequence dim instead)
        ba_eff = ba if batch % ba_size == 0 else None

        def fn(params, token, caches, index):
            return tf_mod.decode_step(
                params, token, serve_cfg, caches, index, mesh=mesh, batch_axes=ba
            )

        params = tf_mod.abstract_params(serve_cfg)
        caches = jax.eval_shape(lambda: tf_mod.init_cache(serve_cfg, batch, cache_len))
        token = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
        index = jax.ShapeDtypeStruct((), jnp.int32)
        rule = lm_rule(mesh)
        cache_rule = lm_cache_rule(mesh, serve_cfg.n_kv_heads)
        p_sh = tree_shardings(params, mesh, rule)
        c_sh = tree_shardings(caches, mesh, cache_rule)
        t_sh = NamedSharding(mesh, P(ba_eff, None))
        logits_sh = NamedSharding(mesh, P(ba_eff, "model"))
        _, active = lm_param_count(serve_cfg)
        return CellBuild(
            fn=fn,
            args=(params, token, caches, index),
            in_shardings=(p_sh, t_sh, c_sh, NamedSharding(mesh, P())),
            out_shardings=(logits_sh, c_sh),
            donate_argnums=(2,),
            model_flops=2.0 * active * batch,
        )

    return build


LM_SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


def standard_lm_arch(
    name: str,
    cfg: tf_mod.TransformerConfig,
    opt_cfg: OptimizerConfig,
    microbatches: int = 1,
    grad_accum_dtype: str = "float32",
) -> ArchSpec:
    cells = {
        "train_4k": lm_train_cell(cfg, opt_cfg, 256, 4096, microbatches, grad_accum_dtype),
        "prefill_32k": lm_prefill_cell(cfg, 32, 32768),
        "decode_32k": lm_decode_cell(cfg, 128, 32768),
    }
    skips = {}
    if cfg.sub_quadratic:
        cells["long_500k"] = lm_decode_cell(cfg, 1, 524288)
    else:
        skips["long_500k"] = (
            "pure full-attention arch: 500k-token context requires "
            "sub-quadratic attention (DESIGN.md §4)"
        )
    return ArchSpec(name=name, family="lm", cells=cells, skips=skips, model_config=cfg)


# --------------------------------------------------------------- GNN cells

def gnn_flops_per_edge(cfg: gnn_mod.GNNConfig) -> float:
    """Analytic useful FLOPs per edge per layer (message + aggregation)."""
    d = cfg.d_hidden
    per_edge = {
        "graphsage": 2 * d,               # gather+reduce; linears are per-node
        "pna": 2 * (2 * d) * d + 8 * d,   # message MLP + 4 aggregators
        "gatedgcn": 3 * 2 * d * d + 6 * d,
        "meshgraphnet": (3 * d) * d * 2 * cfg.mlp_layers,
    }[cfg.arch]
    return float(per_edge)


def gnn_node_flops(cfg: gnn_mod.GNNConfig) -> float:
    d = cfg.d_hidden
    per_node = {
        "graphsage": 2 * 2 * cfg.d_in * d + (cfg.n_layers - 1) * 4 * d * d,
        "pna": 2 * (13 * d) * d * cfg.n_layers,
        "gatedgcn": 3 * 2 * d * d * cfg.n_layers,
        "meshgraphnet": (2 * d) * d * 2 * cfg.mlp_layers * cfg.n_layers,
    }[cfg.arch]
    return float(per_node)


def _pad_to(n: int, m: int = 512) -> int:
    """Round a node/edge count up to a shardable multiple (padding rows
    are masked in real runs: self-loop edges / zero-weight labels)."""
    return -(-n // m) * m


def gnn_train_cell(
    cfg: gnn_mod.GNNConfig,
    opt_cfg: OptimizerConfig,
    n_nodes: int,
    n_edges: int,
    d_feat: int,
    n_graphs: int = 0,
):
    cell_cfg = cfg.replace(d_in=d_feat)
    n_nodes_orig, n_edges_orig = n_nodes, n_edges
    n_nodes, n_edges = _pad_to(n_nodes), _pad_to(n_edges)

    def build(mesh) -> CellBuild:
        needs_edge_feats = cell_cfg.arch in ("gatedgcn", "meshgraphnet")

        def loss_fn(params, b):
            ef = b.get("edge_feats")
            if cell_cfg.task == "graph":
                return gnn_mod.gnn_loss(
                    params, cell_cfg, b["feats"], b["src"], b["dst"], b["labels"],
                    edge_feats=ef, graph_ids=b["graph_ids"], n_graphs=n_graphs,
                )
            return gnn_mod.gnn_loss(
                params, cell_cfg, b["feats"], b["src"], b["dst"], b["labels"],
                edge_feats=ef,
            )

        step = make_train_step(loss_fn, opt_cfg)
        state = jax.eval_shape(
            lambda: init_train_state(
                gnn_mod.init_gnn(jax.random.PRNGKey(0), cell_cfg), opt_cfg
            )
        )
        batch = {
            "feats": jax.ShapeDtypeStruct((n_nodes, d_feat), jnp.float32),
            "src": jax.ShapeDtypeStruct((n_edges,), jnp.int32),
            "dst": jax.ShapeDtypeStruct((n_edges,), jnp.int32),
        }
        vec = NamedSharding(mesh, gnn_data_spec(mesh, "vector"))
        mat = NamedSharding(mesh, gnn_data_spec(mesh, "matrix"))
        batch_sh = {"feats": mat, "src": vec, "dst": vec}
        if needs_edge_feats:
            batch["edge_feats"] = jax.ShapeDtypeStruct((n_edges, cell_cfg.d_edge_in), jnp.float32)
            batch_sh["edge_feats"] = mat
        if cell_cfg.task == "graph":
            batch["graph_ids"] = jax.ShapeDtypeStruct((n_nodes,), jnp.int32)
            batch["labels"] = jax.ShapeDtypeStruct((n_graphs,), jnp.int32)
            batch_sh["graph_ids"] = vec
            batch_sh["labels"] = vec
        elif cell_cfg.task == "regression":
            batch["labels"] = jax.ShapeDtypeStruct((n_nodes, cell_cfg.d_out), jnp.float32)
            batch_sh["labels"] = mat
        else:
            batch["labels"] = jax.ShapeDtypeStruct((n_nodes,), jnp.int32)
            batch_sh["labels"] = vec
        state_sh = tree_shardings(state, mesh, gnn_rule(mesh))
        scalar = NamedSharding(mesh, P())
        flops = 3.0 * (
            gnn_flops_per_edge(cell_cfg) * n_edges_orig * cell_cfg.n_layers
            + gnn_node_flops(cell_cfg) * n_nodes_orig
        )
        return CellBuild(
            fn=step,
            args=(state, batch),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, {"loss": scalar, "grad_norm": scalar}),
            donate_argnums=(0,),
            model_flops=flops,
        )

    return build


def gnn_minibatch_cell(
    cfg: gnn_mod.GNNConfig,
    opt_cfg: OptimizerConfig,
    n_nodes: int,
    d_feat: int,
    batch_nodes: int,
    fanouts: tuple,
    n_classes: int,
):
    """Sampled-training cell: the sampler output (layered vertex ids) is
    the batch; the resident feature table is gathered on device — the
    sparse-frontier regime of HyTM (gather engine)."""
    cell_cfg = cfg.replace(d_in=d_feat, sample_sizes=fanouts, d_out=n_classes)
    n_nodes = _pad_to(n_nodes)

    def build(mesh) -> CellBuild:
        def loss_fn(params, b):
            sizes = [batch_nodes]
            for f in fanouts:
                sizes.append(sizes[-1] * f)
            layer_feats = [b["feats"][b[f"hop{k}"]] for k in range(len(sizes))]
            logits = gnn_mod.graphsage_minibatch_forward(params, layer_feats, cell_cfg)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            return -jnp.mean(jnp.take_along_axis(logp, b["labels"][:, None], axis=-1))

        step = make_train_step(loss_fn, opt_cfg)
        state = jax.eval_shape(
            lambda: init_train_state(
                gnn_mod.init_gnn(jax.random.PRNGKey(0), cell_cfg), opt_cfg
            )
        )
        batch = {"feats": jax.ShapeDtypeStruct((n_nodes, d_feat), jnp.float32)}
        batch_sh = {"feats": NamedSharding(mesh, gnn_data_spec(mesh, "matrix"))}
        size = batch_nodes
        vec = NamedSharding(mesh, gnn_data_spec(mesh, "vector"))
        batch["hop0"] = jax.ShapeDtypeStruct((size,), jnp.int32)
        batch_sh["hop0"] = vec
        for k, f in enumerate(fanouts):
            size *= f
            batch[f"hop{k + 1}"] = jax.ShapeDtypeStruct((size,), jnp.int32)
            batch_sh[f"hop{k + 1}"] = vec
        batch["labels"] = jax.ShapeDtypeStruct((batch_nodes,), jnp.int32)
        batch_sh["labels"] = vec
        state_sh = tree_shardings(state, mesh, gnn_rule(mesh))
        scalar = NamedSharding(mesh, P())
        total_gathered = sum(
            batch_nodes * int(jnp.prod(jnp.asarray(fanouts[:k] or (1,))))
            for k in range(len(fanouts) + 1)
        )
        flops = 3.0 * total_gathered * 4 * cell_cfg.d_hidden * max(d_feat, cell_cfg.d_hidden)
        return CellBuild(
            fn=step,
            args=(state, batch),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, {"loss": scalar, "grad_norm": scalar}),
            donate_argnums=(0,),
            model_flops=flops,
        )

    return build


GNN_SHAPES = {
    "full_graph_sm": dict(n_nodes=2708, n_edges=10556, d_feat=1433),
    "minibatch_lg": dict(n_nodes=232965, n_edges=114615892, batch_nodes=1024, fanout=(15, 10)),
    "ogb_products": dict(n_nodes=2449029, n_edges=61859140, d_feat=100),
    "molecule": dict(n_nodes=30, n_edges=64, batch=128),
}


def standard_gnn_arch(name: str, cfg: gnn_mod.GNNConfig, opt_cfg: OptimizerConfig) -> ArchSpec:
    """The four GNN shape cells.  minibatch_lg uses the real neighbour
    sampler for all archs (fanout sampling is aggregation-agnostic); the
    GraphSAGE estimator path is exercised arch-natively, other archs
    train on the sampled block as an edge-list subgraph."""
    s = GNN_SHAPES
    mol_nodes = s["molecule"]["batch"] * s["molecule"]["n_nodes"]
    mol_edges = s["molecule"]["batch"] * s["molecule"]["n_edges"] * 2  # undirected
    if cfg.task == "regression":
        mol_cfg = cfg.replace(d_out=3)
    else:
        mol_cfg = cfg.replace(task="graph", d_out=2)

    cells = {
        "full_graph_sm": gnn_train_cell(
            cfg.replace(d_out=7), opt_cfg,
            s["full_graph_sm"]["n_nodes"], s["full_graph_sm"]["n_edges"],
            s["full_graph_sm"]["d_feat"],
        ),
        "ogb_products": gnn_train_cell(
            cfg.replace(d_out=47), opt_cfg,
            s["ogb_products"]["n_nodes"], s["ogb_products"]["n_edges"],
            s["ogb_products"]["d_feat"],
        ),
        "molecule": gnn_train_cell(
            mol_cfg, opt_cfg, mol_nodes, mol_edges, 16,
            n_graphs=s["molecule"]["batch"],
        ),
    }
    if cfg.arch == "graphsage":
        cells["minibatch_lg"] = gnn_minibatch_cell(
            cfg, opt_cfg, s["minibatch_lg"]["n_nodes"], 602,
            s["minibatch_lg"]["batch_nodes"], s["minibatch_lg"]["fanout"], 41,
        )
    else:
        # sampled subgraph as an edge list: batch_nodes seeds + full fanout
        # closure => 1024 * (1 + 15 + 150) nodes, edges = sampled arcs
        nodes = s["minibatch_lg"]["batch_nodes"] * (1 + 15 + 15 * 10)
        edges = s["minibatch_lg"]["batch_nodes"] * (15 + 15 * 10)
        mb_cfg = cfg.replace(d_out=41) if cfg.task != "regression" else cfg.replace(d_out=3)
        cells["minibatch_lg"] = gnn_train_cell(mb_cfg, opt_cfg, nodes, edges, 602)
    return ArchSpec(name=name, family="gnn", cells=cells, model_config=cfg)


# -------------------------------------------------------------- DLRM cells

def dlrm_train_cell(cfg, opt_cfg: OptimizerConfig, batch: int):
    def build(mesh) -> CellBuild:
        loss_fn = lambda p, b: dlrm_mod.dlrm_loss(p, b["dense"], b["sparse"], b["labels"], cfg)
        step = make_train_step(loss_fn, opt_cfg)
        state = jax.eval_shape(
            lambda: init_train_state(dlrm_mod.init_dlrm(jax.random.PRNGKey(0), cfg), opt_cfg)
        )
        ba = batch_axes(mesh)
        batch_specs = {
            "dense": jax.ShapeDtypeStruct((batch, cfg.n_dense), jnp.float32),
            "sparse": jax.ShapeDtypeStruct((batch, cfg.n_sparse), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch,), jnp.float32),
        }
        bsh = {
            "dense": NamedSharding(mesh, P(ba, None)),
            "sparse": NamedSharding(mesh, P(ba, None)),
            "labels": NamedSharding(mesh, P(ba)),
        }
        state_sh = tree_shardings(state, mesh, dlrm_rule(mesh))
        scalar = NamedSharding(mesh, P())
        return CellBuild(
            fn=step,
            args=(state, batch_specs),
            in_shardings=(state_sh, bsh),
            out_shardings=(state_sh, {"loss": scalar, "grad_norm": scalar}),
            donate_argnums=(0,),
            model_flops=3.0 * batch * _dlrm_fwd_flops(cfg),
        )

    return build


def _dlrm_fwd_flops(cfg) -> float:
    f = 0.0
    dims = [cfg.n_dense, *cfg.bot_mlp]
    f += sum(2 * dims[i] * dims[i + 1] for i in range(len(dims) - 1))
    f += cfg.n_sparse * cfg.multi_hot * cfg.embed_dim          # bag reduce
    nf = cfg.n_sparse + 1
    f += 2 * nf * nf * cfg.embed_dim                            # interaction
    dims = [cfg.embed_dim + cfg.n_interact_features, *cfg.top_mlp]
    f += sum(2 * dims[i] * dims[i + 1] for i in range(len(dims) - 1))
    return f


def dlrm_serve_cell(cfg, batch: int):
    def build(mesh) -> CellBuild:
        fn = lambda p, d, s: dlrm_mod.dlrm_forward(p, d, s, cfg)
        params = dlrm_mod.abstract_dlrm_params(cfg)
        ba = batch_axes(mesh)
        args = (
            params,
            jax.ShapeDtypeStruct((batch, cfg.n_dense), jnp.float32),
            jax.ShapeDtypeStruct((batch, cfg.n_sparse), jnp.int32),
        )
        in_sh = (
            tree_shardings(params, mesh, dlrm_rule(mesh)),
            NamedSharding(mesh, P(ba, None)),
            NamedSharding(mesh, P(ba, None)),
        )
        return CellBuild(
            fn=fn, args=args, in_shardings=in_sh,
            out_shardings=NamedSharding(mesh, P(ba)),
            model_flops=batch * _dlrm_fwd_flops(cfg),
        )

    return build


def dlrm_retrieval_cell(cfg, batch: int, n_candidates: int, top_k: int = 100):
    def build(mesh) -> CellBuild:
        fn = lambda p, d, c: dlrm_mod.retrieval_score(p, d, c, top_k=top_k)
        params = dlrm_mod.abstract_dlrm_params(cfg)
        args = (
            params,
            jax.ShapeDtypeStruct((batch, cfg.n_dense), jnp.float32),
            jax.ShapeDtypeStruct((n_candidates, cfg.embed_dim), jnp.float32),
        )
        ba = batch_axes(mesh)
        in_sh = (
            tree_shardings(params, mesh, dlrm_rule(mesh)),
            NamedSharding(mesh, P(None, None)),
            NamedSharding(mesh, P(ba, None)),   # candidates sharded
        )
        out_sh = NamedSharding(mesh, P())  # single spec broadcast to (scores, ids)
        return CellBuild(
            fn=fn, args=args, in_shardings=in_sh, out_shardings=out_sh,
            model_flops=2.0 * batch * n_candidates * cfg.embed_dim,
        )

    return build


# ------------------------------------------------------- smoke reduction

import dataclasses


def reduce_lm_config(cfg: tf_mod.TransformerConfig) -> tf_mod.TransformerConfig:
    """Reduced smoke config: shrink dims, keep the family's structure
    (MQA/MLA/MoE/windows) — used by per-arch smoke tests and the local
    train/serve launchers."""
    kw = dict(
        n_layers=min(cfg.n_layers, 3 if cfg.moe else 4),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        d_head=16,
        d_ff=128,
        vocab=211,
        dtype="float32",
        param_dtype="float32",
        d_ff_dense=128 if cfg.d_ff_dense else 0,
    )
    if cfg.window_pattern != (0,):
        kw["window_pattern"] = (4, 4, 0)
    if cfg.mla is not None:
        kw["mla"] = dataclasses.replace(cfg.mla, kv_lora=32, d_nope=16, d_rope=8, d_v=16)
    if cfg.moe is not None:
        kw["moe"] = cfg.moe.replace(
            n_experts=8, top_k=min(cfg.moe.top_k, 2), d_ff=32,
            d_ff_shared=0, capacity_factor=4.0, chunk_tokens=0,
        )
        kw["first_dense_layers"] = min(cfg.first_dense_layers, 1)
    return cfg.replace(**kw)


