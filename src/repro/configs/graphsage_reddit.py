"""graphsage-reddit [arXiv:1706.02216; paper] — 2L d_hidden=128 mean
aggregator, sample_sizes 25-10 (training estimator; the `minibatch_lg`
cell uses the assigned 15-10 fanout)."""

from repro.configs.common import standard_gnn_arch
from repro.models.gnn import GNNConfig
from repro.train.optimizer import OptimizerConfig

CONFIG = GNNConfig(
    name="graphsage-reddit",
    arch="graphsage",
    n_layers=2,
    d_hidden=128,
    d_in=602,
    d_out=41,
    aggregator="mean",
    sample_sizes=(25, 10),
)

OPT = OptimizerConfig(name="adamw", learning_rate=1e-3, warmup_steps=100)

ARCH = standard_gnn_arch("graphsage-reddit", CONFIG, OPT)
