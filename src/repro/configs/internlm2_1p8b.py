"""internlm2-1.8b [arXiv:2403.17297; hf] — dense, GQA kv=8, SwiGLU.

24L d_model=2048 16H (kv=8) d_ff=8192 vocab=92544.
"""

from repro.configs.common import standard_lm_arch
from repro.models.transformer import TransformerConfig
from repro.train.optimizer import OptimizerConfig

CONFIG = TransformerConfig(
    name="internlm2-1.8b",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab=92544,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

OPT = OptimizerConfig(name="adamw", learning_rate=3e-4, warmup_steps=2000)

ARCH = standard_lm_arch("internlm2-1.8b", CONFIG, OPT, microbatches=2)
