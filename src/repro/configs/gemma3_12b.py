"""gemma3-12b [hf:google/gemma-3-12b-pt; unverified] — 5:1 local:global.

48L d_model=3840 16H (kv=8) d_ff=15360 vocab=262144, head_dim=256.
Sliding window 1024 on local layers; every 6th layer global.  The hybrid
pattern makes this the one assigned LM arch that runs `long_500k`
(sub-quadratic local layers; global layers linear-per-step at decode).
"""

from repro.configs.common import standard_lm_arch
from repro.models.transformer import TransformerConfig
from repro.train.optimizer import OptimizerConfig

CONFIG = TransformerConfig(
    name="gemma3-12b",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_head=256,
    d_ff=15360,
    vocab=262144,
    window_pattern=(1024, 1024, 1024, 1024, 1024, 0),  # 5 local : 1 global
    tie_embeddings=True,
    sub_quadratic=True,
)

OPT = OptimizerConfig(name="adamw", learning_rate=2e-4, warmup_steps=2000)

ARCH = standard_lm_arch("gemma3-12b", CONFIG, OPT, microbatches=8)
