"""deepseek-v2-lite-16b [arXiv:2405.04434; hf] — MLA + fine-grained MoE.

27L d_model=2048 16H, MLA kv_lora=512 (d_nope=128, d_rope=64, d_v=128),
MoE: 64 routed experts d_ff=1408 top-6 + 2 shared, first layer dense
(d_ff=10944), vocab=102400.
"""

from repro.configs.common import standard_lm_arch
from repro.models.attention import MLAConfig
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig
from repro.train.optimizer import OptimizerConfig

CONFIG = TransformerConfig(
    name="deepseek-v2-lite-16b",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1408,
    vocab=102400,
    attention="mla",
    mla=MLAConfig(kv_lora=512, q_lora=0, d_nope=128, d_rope=64, d_v=128),
    moe=MoEConfig(
        n_experts=64, top_k=6, d_ff=1408, n_shared=2,
        capacity_factor=1.25, dispatch="sorted", chunk_tokens=8192,
    ),
    first_dense_layers=1,
    d_ff_dense=10944,
    tie_embeddings=False,
)

OPT = OptimizerConfig(name="adamw", learning_rate=3e-4, warmup_steps=2000)

ARCH = standard_lm_arch("deepseek-v2-lite-16b", CONFIG, OPT, microbatches=4)
