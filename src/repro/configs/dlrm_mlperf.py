"""dlrm-mlperf [arXiv:1906.00091; paper] — MLPerf DLRM (Criteo 1TB).

13 dense + 26 sparse features, embed_dim=128, bottom 13-512-256-128,
top 1024-1024-512-256-1, dot interaction.  ~188M embedding rows,
row-sharded over ('data','model') (dist/sharding.py)."""

from repro.configs.common import (
    ArchSpec,
    dlrm_retrieval_cell,
    dlrm_serve_cell,
    dlrm_train_cell,
)
from repro.models.dlrm import MLPERF_VOCAB_SIZES, DLRMConfig
from repro.train.optimizer import OptimizerConfig

# Row-sharded tables are padded to a shardable multiple (512 covers every
# mesh: 16x16 and 2x16x16); small tables stay replicated and unpadded.
_PADDED_VOCABS = tuple(
    (-(-v // 512) * 512) if v >= 4096 else v for v in MLPERF_VOCAB_SIZES
)

CONFIG = DLRMConfig(vocab_sizes=_PADDED_VOCABS)

OPT = OptimizerConfig(name="adamw", learning_rate=1e-3, warmup_steps=100)

ARCH = ArchSpec(
    name="dlrm-mlperf",
    family="recsys",
    cells={
        "train_batch": dlrm_train_cell(CONFIG, OPT, 65536),
        "serve_p99": dlrm_serve_cell(CONFIG, 512),
        "serve_bulk": dlrm_serve_cell(CONFIG, 262144),
        "retrieval_cand": dlrm_retrieval_cell(CONFIG, 1, 1_000_000),
    },
    model_config=CONFIG,
)
