"""gatedgcn [arXiv:2003.00982; paper] — 16L d_hidden=70, gated edge
aggregation (Bresson & Laurent residual gated graph convnets)."""

from repro.configs.common import standard_gnn_arch
from repro.models.gnn import GNNConfig
from repro.train.optimizer import OptimizerConfig

CONFIG = GNNConfig(
    name="gatedgcn",
    arch="gatedgcn",
    n_layers=16,
    d_hidden=70,
    d_in=70,
    d_out=10,
    d_edge_in=8,
)

OPT = OptimizerConfig(name="adamw", learning_rate=1e-3, warmup_steps=100)

ARCH = standard_gnn_arch("gatedgcn", CONFIG, OPT)
