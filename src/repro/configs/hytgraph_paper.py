"""The paper's own workload as an arch: the HyTM graph-analytics engine.

Not one of the 40 assigned cells — a bonus config so ``--arch hytgraph``
drives the reproduction itself (SSSP / BFS / CC / PageRank over RMAT)
through the same launcher.
"""

from dataclasses import dataclass

from repro.configs.common import ArchSpec
from repro.core.hytm import HyTMConfig


@dataclass(frozen=True)
class HyTGraphWorkload:
    algorithm: str = "sssp"
    n_nodes: int = 100_000
    n_edges: int = 1_600_000
    n_partitions: int = 64
    hytm: HyTMConfig = HyTMConfig(n_partitions=64)


CONFIG = HyTGraphWorkload()

ARCH = ArchSpec(
    name="hytgraph",
    family="graph",
    cells={},  # driven by examples/quickstart.py + benchmarks, not dryrun
    model_config=CONFIG,
)
