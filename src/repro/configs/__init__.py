"""Architecture registry: ``--arch <id>`` -> ArchSpec with per-shape cells.

10 assigned architectures + the paper's own graph-analytics engine.
"""

from __future__ import annotations

import importlib

ARCHS = {
    # LM family (5)
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "internlm2-1.8b": "repro.configs.internlm2_1p8b",
    "granite-20b": "repro.configs.granite_20b",
    "gemma3-12b": "repro.configs.gemma3_12b",
    # GNN family (4)
    "graphsage-reddit": "repro.configs.graphsage_reddit",
    "pna": "repro.configs.pna",
    "gatedgcn": "repro.configs.gatedgcn",
    "meshgraphnet": "repro.configs.meshgraphnet",
    # RecSys (1)
    "dlrm-mlperf": "repro.configs.dlrm_mlperf",
    # The paper's own system (bonus arch: graph analytics engine)
    "hytgraph": "repro.configs.hytgraph_paper",
}


def get_arch(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(ARCHS[name]).ARCH


def list_archs() -> list[str]:
    return list(ARCHS)
