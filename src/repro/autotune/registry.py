"""Calibrated-profile persistence: one JSON file per device kind.

Layout: ``<registry>/<device_kind>.json`` where ``<registry>`` is the
``REPRO_AUTOTUNE_REGISTRY`` env var or ``~/.cache/repro/autotune``.  Each
file carries the full :class:`LinkModel` field set plus free-form
calibration metadata (regret numbers, probe mode, observation count), so
a profile is self-describing:

    {"schema": 1, "device_kind": "cpu",
     "profile": {"name": "...", "bandwidth": ..., ...},
     "meta": {"static_regret": ..., ...}}

Loading round-trips through the :class:`LinkModel` constructor, so the
``__post_init__`` validation rejects corrupt or hand-edited profiles with
a clear error instead of silently mis-costing.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
from pathlib import Path

from repro.core.constants import LinkModel

SCHEMA_VERSION = 1
_ENV_VAR = "REPRO_AUTOTUNE_REGISTRY"


def registry_dir(base: str | os.PathLike | None = None) -> Path:
    if base is not None:
        return Path(base)
    env = os.environ.get(_ENV_VAR)
    if env:
        return Path(env)
    return Path("~/.cache/repro/autotune").expanduser()


def default_device_kind() -> str:
    """Sanitized device kind of the first visible accelerator."""
    import jax

    dev = jax.devices()[0]
    kind = getattr(dev, "device_kind", "") or dev.platform
    return re.sub(r"[^a-z0-9_.-]+", "-", str(kind).strip().lower()).strip("-") or "unknown"


def profile_path(device_kind: str | None = None,
                 base: str | os.PathLike | None = None) -> Path:
    kind = device_kind if device_kind is not None else default_device_kind()
    # an explicit kind is a filename token, never a path: reject
    # separators / dot-dirs so profiles cannot escape the registry
    if not re.fullmatch(r"[A-Za-z0-9_.-]+", kind) or set(kind) == {"."}:
        raise ValueError(
            f"invalid device kind {kind!r}: expected a plain name "
            f"(letters, digits, '_', '.', '-')")
    return registry_dir(base) / f"{kind}.json"


def profile_to_dict(link: LinkModel) -> dict:
    return dataclasses.asdict(link)


def profile_from_dict(d: dict) -> LinkModel:
    fields = {f.name for f in dataclasses.fields(LinkModel)}
    unknown = set(d) - fields
    if unknown:
        raise ValueError(f"unknown LinkModel fields in profile: {sorted(unknown)}")
    # save_profile always writes the full field set; a truncated profile
    # must fail loudly rather than silently inherit shipped defaults
    missing = fields - set(d)
    if missing:
        raise ValueError(f"profile is missing LinkModel fields: {sorted(missing)}")
    return LinkModel(**d)  # __post_init__ validates


def save_profile(
    link: LinkModel,
    device_kind: str | None = None,
    base: str | os.PathLike | None = None,
    meta: dict | None = None,
) -> Path:
    path = profile_path(device_kind, base)
    path.parent.mkdir(parents=True, exist_ok=True)
    doc = {
        "schema": SCHEMA_VERSION,
        "device_kind": device_kind if device_kind is not None else default_device_kind(),
        "profile": profile_to_dict(link),
        "meta": meta or {},
    }
    tmp = path.with_suffix(".json.tmp")
    tmp.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    tmp.replace(path)
    return path


def load_profile(
    device_kind: str | None = None,
    base: str | os.PathLike | None = None,
    with_meta: bool = False,
) -> LinkModel | tuple[LinkModel, dict]:
    path = profile_path(device_kind, base)
    if not path.exists():
        raise FileNotFoundError(
            f"no calibrated profile for device kind "
            f"{device_kind or default_device_kind()!r} at {path} — run "
            f"`python -m repro.launch.calibrate` to create one"
        )
    doc = json.loads(path.read_text())
    if doc.get("schema") != SCHEMA_VERSION:
        raise ValueError(f"{path}: unsupported profile schema {doc.get('schema')!r}")
    link = profile_from_dict(doc["profile"])
    return (link, doc.get("meta", {})) if with_meta else link


def load_profile_or_default(
    device_kind: str | None = None,
    base: str | os.PathLike | None = None,
    default: LinkModel | None = None,
) -> LinkModel:
    """Load the calibrated profile, falling back to shipped constants.

    Degradation contract (repro.resilience satellite): a *missing*
    profile is the normal cold-start case and falls back silently; a
    *corrupt* one — invalid JSON, wrong schema, truncated or alien field
    set, values rejected by ``LinkModel.__post_init__`` — emits a
    ``RuntimeWarning`` naming the file and falls back, so a damaged
    registry degrades the cost model to the shipped ``PCIE3`` constants
    instead of taking the launcher down."""
    import warnings

    from repro.core.constants import PCIE3

    fallback = default if default is not None else PCIE3
    try:
        return load_profile(device_kind, base)
    except FileNotFoundError:
        return fallback
    except (ValueError, KeyError, TypeError, AttributeError) as exc:
        warnings.warn(
            f"ignoring corrupt autotune profile "
            f"({profile_path(device_kind, base)}): {exc}; "
            f"falling back to shipped {fallback.name!r} constants",
            RuntimeWarning,
            stacklevel=2,
        )
        return fallback


def has_profile(device_kind: str | None = None,
                base: str | os.PathLike | None = None) -> bool:
    return profile_path(device_kind, base).exists()


def list_profiles(base: str | os.PathLike | None = None) -> dict[str, LinkModel]:
    root = registry_dir(base)
    out = {}
    if root.is_dir():
        for p in sorted(root.glob("*.json")):
            try:
                doc = json.loads(p.read_text())
                out[p.stem] = profile_from_dict(doc["profile"])
            except (ValueError, TypeError, KeyError, json.JSONDecodeError):
                continue  # skip corrupt entries; load_profile reports them
    return out
