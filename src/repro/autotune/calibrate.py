"""Fit a :class:`LinkModel` to measured engine costs (the "fit" half).

Two stages, mirroring how the paper tunes its platform constants (§V-A
"alpha and beta are tuned empirically per platform"):

1. **Parameter fit** (:func:`fit_link`) — least squares on the smooth
   (de-ceiled) forms of Eqs. 1-3:

   * FILTER observations are affine in the partition bytes:
     ``t = E*d1 / bandwidth + intercept`` -> fits ``bandwidth`` (the
     intercept refits ``launch_overhead_s`` only for wall probes, which
     actually pay per-call dispatch — see :func:`fit_link`);
   * COMPACT observations are affine in the compacted bytes with slope
     ``1/bandwidth + 1/compaction_bandwidth`` -> given the FILTER fit,
     recovers ``compaction_bandwidth`` (0 when the pass is unmeasurable);
   * ZEROCOPY observations divide out the request-group term, leaving
     ``gamma + (1-gamma)*ratio`` — a 1-D regression for ``gamma``.

   Hardware-topology constants (``m``, ``mr``, ``d1``, ``d2``) and the
   selection-semantics flag are *not* fitted: they come from the initial
   profile.  Mis-specified granules are absorbed by ``gamma`` /
   ``bandwidth`` (the transaction-group size ``m*mr`` is what enters the
   equations).

2. **Threshold tuning** (:func:`tune_thresholds`) — grid search over
   ``alpha`` / ``beta`` minimizing total *regret*: the summed gap between
   the measured time of the engine Algorithm 1 selects and the measured
   best engine, over the probe grid.  The tuned pair is adopted only when
   it beats the fitted-but-untuned profile by more than ``min_gain`` of
   the oracle's total time — so a correctly-specified profile calibrates
   to a no-op (selection decisions unchanged) instead of chasing noise.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.autotune.probe import (
    ENGINES,
    Observation,
    ProbePoint,
    observation_matrix,
    stats_for,
)
from repro.core.constants import LinkModel
from repro.core.cost_model import engine_costs, select_engines


def selection_on_grid(points: list[ProbePoint], link: LinkModel) -> np.ndarray:
    """Algorithm-1 engine choice per probe point under ``link``."""
    stats = stats_for(points, link)
    return np.asarray(select_engines(stats, engine_costs(stats, link), link))


def _regret_rows(engines2d: np.ndarray, measured: np.ndarray) -> np.ndarray:
    """(K, N) engine choices -> (K,) total regrets vs the measured best.

    NONE (-1) entries — zero-active partitions the selection skips —
    contribute zero regret (nothing is transferred for them)."""
    idx = np.asarray(engines2d, int)
    best = np.nanmin(measured, axis=1)
    picked = measured[np.arange(measured.shape[0])[None, :], np.clip(idx, 0, 2)]
    return np.nansum(np.where(idx >= 0, picked - best[None, :], 0.0), axis=1)


def total_regret(engines: np.ndarray, measured: np.ndarray) -> float:
    """Sum over points of measured[selected] - measured[best]."""
    return float(_regret_rows(np.asarray(engines)[None, :], measured)[0])


def _affine_fit(x: np.ndarray, y: np.ndarray) -> tuple[float, float]:
    """y ~= slope * x + intercept (least squares, slope floor at 0)."""
    A = np.stack([x, np.ones_like(x)], axis=1)
    (slope, intercept), *_ = np.linalg.lstsq(A, y, rcond=None)
    return max(float(slope), 0.0), max(float(intercept), 0.0)


def fit_link(
    points: list[ProbePoint],
    observations: list[Observation],
    initial: LinkModel,
    fit_overhead: bool = False,
) -> LinkModel:
    """Least-squares fit of (bandwidth, compaction_bandwidth, gamma) from
    per-engine observations; every other field is inherited from
    ``initial``.

    ``launch_overhead_s`` is refit only when ``fit_overhead`` is set:
    per-*task* dispatch cost is charged by the scheduler, not by
    ``engine_costs``, so model-probe observations carry no overhead
    signal (their affine intercept is pure ceil-rounding bias, ~rtt/2) —
    blindly adopting it would silently zero a correct profile's
    overhead.  Wall probes DO pay real per-call dispatch, so the wall
    path opts in and the rounding bias is subtracted out.
    """
    from repro.core.cost_model import COMPACT, FILTER, ZEROCOPY

    by_engine: dict[int, list[Observation]] = {e: [] for e in ENGINES}
    for o in observations:
        by_engine[o.engine].append(o)

    bandwidth = initial.bandwidth
    overhead = initial.launch_overhead_s
    if by_engine[FILTER]:
        x = np.array([o.point.total_edges * initial.d1 for o in by_engine[FILTER]])
        y = np.array([o.seconds for o in by_engine[FILTER]])
        slope, intercept = _affine_fit(x, y)
        if slope > 0:
            bandwidth = 1.0 / slope
        if fit_overhead:
            rtt_fit = initial.m * initial.mr / bandwidth
            overhead = max(intercept - 0.5 * rtt_fit, 0.0)

    compaction_bw = initial.compaction_bandwidth
    if by_engine[COMPACT]:
        x = np.array([
            o.point.active_edges * initial.d1 + o.point.active_vertices * initial.d2
            for o in by_engine[COMPACT]
        ])
        y = np.array([o.seconds for o in by_engine[COMPACT]])
        slope, _ = _affine_fit(x, y)
        extra = slope - 1.0 / bandwidth
        # a pass FASTER than ~1000x the link contributes nothing
        # measurable — model it as free (compaction_bandwidth = 0 means
        # "no modeled pass" per engine_costs' > 0 guard)
        compaction_bw = 1.0 / extra if extra > 1e-3 / bandwidth else 0.0

    gamma = initial.gamma
    if by_engine[ZEROCOPY]:
        rtt = initial.m * initial.mr / bandwidth
        num = den = 0.0
        for o in by_engine[ZEROCOPY]:
            groups = np.ceil(o.point.zc_requests(initial) / initial.mr)
            if groups <= 0:
                continue
            yy = o.seconds / (groups * rtt)     # == gamma + (1-gamma)*ratio
            r = o.point.ratio
            num += (yy - r) * (1.0 - r)
            den += (1.0 - r) ** 2
        if den > 0:
            gamma = float(np.clip(num / den, 1e-3, 1.0))

    return initial.with_(
        bandwidth=bandwidth,
        launch_overhead_s=overhead,
        compaction_bandwidth=compaction_bw,
        gamma=gamma,
    )


def tune_thresholds(
    points: list[ProbePoint],
    measured: np.ndarray,
    profile: LinkModel,
    min_gain: float = 0.01,
    grid: int = 20,
) -> tuple[LinkModel, float]:
    """Regret-minimizing (alpha, beta) grid search.

    Returns ``(profile', regret)``.  The incumbent (``profile``'s own
    thresholds) is always a candidate and wins unless a challenger beats
    it by more than ``min_gain * sum(measured best)`` — the stability
    margin that makes calibration of a correct profile a no-op.
    """
    from repro.core.cost_model import NONE, algorithm1_engines

    stats = stats_for(points, profile)
    costs = engine_costs(stats, profile)
    tef = np.asarray(costs.tef, float)
    tec = np.asarray(costs.tec, float)
    tiz = np.asarray(costs.tiz, float)
    active = np.asarray(stats.active_edges, float) > 0

    def regrets_for(alphas: np.ndarray, betas: np.ndarray) -> np.ndarray:
        """(K,) candidate thresholds -> (K,) regrets, one broadcast call
        through the SAME Algorithm-1 rule the runtime executes."""
        eng = np.asarray(algorithm1_engines(
            tef[None, :], tec[None, :], tiz[None, :],
            alphas[:, None], betas[:, None],
        ))
        eng = np.where(active[None, :], eng, NONE)
        return _regret_rows(eng, measured)

    incumbent = float(regrets_for(
        np.array([profile.alpha]), np.array([profile.beta]))[0])
    oracle = float(np.nansum(np.nanmin(measured, axis=1)))
    cand = np.linspace(0.05, 1.0, grid)
    aa, bb = np.meshgrid(cand, cand, indexing="ij")
    regrets = regrets_for(aa.ravel(), bb.ravel())
    k = int(np.argmin(regrets))  # first minimum: same tie-break as a scan
    if regrets[k] < incumbent - min_gain * oracle:
        return (profile.with_(alpha=float(aa.ravel()[k]), beta=float(bb.ravel()[k])),
                float(regrets[k]))
    return profile, incumbent


@dataclass(frozen=True)
class CalibrationReport:
    profile: LinkModel             # calibrated profile
    initial: LinkModel
    static_regret: float           # regret of the *initial* profile's selection
    calibrated_regret: float       # regret of the calibrated selection
    oracle_seconds: float          # sum of measured-best times (scale)
    n_observations: int
    n_points: int
    fitted: dict = field(default_factory=dict)

    @property
    def improved(self) -> bool:
        return self.calibrated_regret < self.static_regret


def calibrate(
    points: list[ProbePoint],
    observations: list[Observation],
    initial: LinkModel,
    fit_params: bool = True,
    tune: bool = True,
    min_gain: float = 0.01,
    fit_overhead: bool = False,
) -> CalibrationReport:
    """Full calibration: parameter fit, then threshold tuning, then the
    static-vs-calibrated regret comparison on the probe grid.
    ``fit_overhead``: see :func:`fit_link` — set it for wall-probe
    observations only."""
    measured = observation_matrix(points, observations)
    static_regret = total_regret(selection_on_grid(points, initial), measured)

    profile = (fit_link(points, observations, initial, fit_overhead=fit_overhead)
               if fit_params else initial)
    if tune:
        profile, regret = tune_thresholds(points, measured, profile, min_gain=min_gain)
    else:
        regret = total_regret(selection_on_grid(points, profile), measured)
    if regret > static_regret:
        # never ship a profile that is worse than the initial one on the
        # very probe set it was fitted on (degenerate fits under noise)
        profile, regret = initial, static_regret

    return CalibrationReport(
        profile=profile,
        initial=initial,
        static_regret=static_regret,
        calibrated_regret=regret,
        oracle_seconds=float(np.nansum(np.nanmin(measured, axis=1))),
        n_observations=len(observations),
        n_points=len(points),
        fitted={
            "bandwidth": profile.bandwidth,
            "gamma": profile.gamma,
            "compaction_bandwidth": profile.compaction_bandwidth,
            "launch_overhead_s": profile.launch_overhead_s,
            "alpha": profile.alpha,
            "beta": profile.beta,
        },
    )
