"""Online feedback: per-engine cost corrections from measured sweep times.

Offline calibration (calibrate.py) fixes the *profile*; this module
closes the loop at run time.  Each HyTM iteration yields one noisy linear
observation

    measured_iteration_seconds ~= sum_e  c_e * modeled_e

where ``modeled_e`` is the modeled time the plan attributed to engine
``e`` this iteration.  :class:`OnlineCalibrator` maintains the
exponentially-forgotten normal equations of that regression (EWMA
recursive least squares) and solves for the correction vector ``c``.

Because absolute wall time on the measuring host need not match the
modeled link's units (CPU oracles vs modeled PCIe seconds), the solved
vector is normalized to geometric-mean 1 over the engines that have
actually been observed: only the *relative* corrections matter to
Algorithm 1, which compares engines against each other.  Engines with no
accumulated evidence stay at 1.0.

The correction multiplies the per-engine selection costs
(``cost_model.apply_correction``) inside ``hytm_iteration``, steers the
sharded path's ICI-level exchange choice (``graph_shard.ici_level_cost``),
and persists across queries in ``stream.service.GraphService`` so lane
scheduling keeps learning over a service's lifetime.
"""

from __future__ import annotations

import time

import numpy as np

N_ENGINES = 3  # FILTER, COMPACT, ZEROCOPY


class OnlineCalibrator:
    """EWMA recursive least squares for per-engine correction factors."""

    def __init__(self, decay: float = 0.25, ridge: float = 0.05,
                 clip: tuple[float, float] = (0.05, 20.0), obs=None):
        assert 0.0 < decay <= 1.0, decay
        self.decay = decay
        self.ridge = ridge
        self.clip = clip
        self._A = np.zeros((N_ENGINES, N_ENGINES))
        self._b = np.zeros(N_ENGINES)
        self.n_updates = 0
        # optional repro.obs.TraceRecorder: each folded observation emits
        # one correction-update event (host-side; obs=None records nothing
        # and skips even the correction re-solve)
        self.obs = obs

    def update(self, modeled: np.ndarray, measured_seconds: float) -> None:
        """Fold in one iteration: (3,) modeled per-engine seconds + the
        measured wall time of that iteration.  Each sample is normalized
        by its modeled magnitude so iterations contribute comparable
        weight regardless of frontier size."""
        t = np.asarray(modeled, dtype=float).reshape(-1)
        if t.shape != (N_ENGINES,):
            raise ValueError(f"expected ({N_ENGINES},) modeled times, got {t.shape}")
        norm = float(np.linalg.norm(t))
        if not np.isfinite(measured_seconds) or measured_seconds <= 0 or norm <= 0:
            return
        u = t / norm
        f = 1.0 - self.decay
        self._A = f * self._A + np.outer(u, u)
        self._b = f * self._b + u * (measured_seconds / norm)
        self.n_updates += 1
        if self.obs is not None:
            c = self.correction()
            m = self.obs.metrics
            m.counter("autotune.updates", "calibrator observations").inc(1)
            for e, name in enumerate(("filter", "compact", "zerocopy")):
                m.gauge("autotune.correction",
                        "per-engine cost correction").set(float(c[e]),
                                                          engine=name)
            self.obs.instant(
                "correction_update", cat="autotune", track="autotune",
                vt=float(self.n_updates), measured_seconds=float(measured_seconds),
                modeled=[float(x) for x in t], correction=[float(x) for x in c],
            )

    def observed(self) -> np.ndarray:
        """(3,) bool — engines with accumulated evidence."""
        return np.diag(self._A) > 1e-9

    def correction(self) -> np.ndarray:
        """(3,) multiplicative per-engine correction, geo-mean-1 over the
        observed engines; all-ones until the first update."""
        if self.n_updates == 0:
            return np.ones(N_ENGINES)
        # ridge prior toward the (scale-free) uncorrected model
        A = self._A + self.ridge * np.eye(N_ENGINES)
        b = self._b + self.ridge * np.ones(N_ENGINES)
        try:
            c = np.linalg.solve(A, b)
        except np.linalg.LinAlgError:
            return np.ones(N_ENGINES)
        c = np.clip(c, 1e-6, None)
        obs = self.observed()
        if obs.any():
            gm = float(np.exp(np.mean(np.log(c[obs]))))
            if gm > 0:
                c = c / gm
        c = np.where(obs, np.clip(c, *self.clip), 1.0)
        return c.astype(float)

    def observe_iteration(self, sync_ref, per_engine_modeled, t_start: float,
                          skip: bool = False):
        """The per-iteration wiring shared by ``run_hytm``,
        ``run_hytm_sharded`` and ``GraphService``: block on ``sync_ref``
        (so the elapsed wall time covers the whole iteration), fold the
        measurement against the (3,) modeled per-engine seconds — unless
        ``skip``, for first iterations whose wall time is compile, not
        sweep — and return the refreshed correction as a (3,) float32
        jax array ready to feed the next iteration."""
        import jax
        import jax.numpy as jnp

        jax.block_until_ready(sync_ref)
        if not skip:
            self.update(
                np.asarray(per_engine_modeled, dtype=float),
                time.monotonic() - t_start,
            )
        return jnp.asarray(self.correction(), jnp.float32)

    def observe_chunk(self, sync_ref, per_engine_modeled_sum, t_start: float,
                      skip: bool = False):
        """Chunk-granularity observation for the device-resident drivers
        (``HyTMConfig.sync_every > 1``): the regression target moves from
        one iteration to one chunk —

            measured_chunk_seconds ~= sum_e c_e * (sum over the chunk's
                                      iterations of modeled_e)

        which identifies the same correction vector (the model is linear
        in the per-engine regressors; summing iterations just aggregates
        observations) while costing one measurement per dispatch instead
        of per iteration.  ``per_engine_modeled_sum`` is the (3,)
        per-engine modeled seconds summed over the chunk's *executed*
        iterations (drained history rows ``[:n_done]``); ``skip`` marks
        chunks whose dispatch compiled (wall time measures XLA, not the
        sweep).  Returns the refreshed (3,) float32 correction for the
        next chunk."""
        return self.observe_iteration(
            sync_ref, per_engine_modeled_sum, t_start, skip=skip,
        )
