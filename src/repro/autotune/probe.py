"""Engine micro-benchmark probes (the "measure" half of calibration).

A :class:`ProbePoint` is one synthetic partition described by the same
activity statistics the cost model consumes (Eqs. 1-3): total edges
``E``, active edges ``Ea``, active vertices ``|A|``, and the fraction of
active vertices whose neighbour segment is misaligned.  The default grid
spans the activity-ratio spectrum (the x-axis of the paper's Fig. 3
"Prefer" analysis) crossed with the degree regimes that separate the
three engines: few high-degree hubs (EMOGI's zero-copy regime), a
mid-degree band, and a flat deg~1 frontier (compaction's regime).

Two measurement backends produce ``(point, engine, seconds)``
observations:

* :func:`model_probe` — evaluates a *ground-truth* :class:`LinkModel` as
  a hardware simulator.  Deterministic (optionally noised), arbitrarily
  large ``E``; this is what CI and the ``--selfcheck`` acceptance run
  use: calibrating profile X against ``model_probe(truth=Y)`` must
  recover Y-shaped selection.
* :func:`wall_probe` — materializes each point as a real edge block and
  wall-times the three engine relaxations (``relax_with_engine``) on the
  current backend.  This is the path a real deployment calibrates with;
  points are capped to sizes that fit comfortably in memory.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.core.constants import LinkModel
from repro.core.cost_model import (
    COMPACT,
    FILTER,
    ZEROCOPY,
    PartitionStats,
    engine_costs,
)

ENGINES = (FILTER, COMPACT, ZEROCOPY)


@dataclass(frozen=True)
class ProbePoint:
    """One synthetic partition, described by its activity statistics.

    Active vertices share a uniform out-degree ``Ea / |A|`` so the
    zero-copy request count (Eq. 3) is computable under *any* candidate
    link model — the request granule ``m/d1`` differs per profile, so
    requests are re-derived from the degree rather than stored.
    """

    total_edges: float      # E_i
    active_edges: float     # Ea_i
    active_vertices: float  # |A_i|
    mis_frac: float = 0.5   # fraction of active vertices with a misaligned segment

    @property
    def ratio(self) -> float:
        return self.active_edges / max(self.total_edges, 1.0)

    @property
    def degree(self) -> float:
        return self.active_edges / max(self.active_vertices, 1.0)

    def zc_requests(self, link: LinkModel) -> float:
        """Eq. 3's REQ_i under ``link``: |A| * (ceil(deg*d1/m) + am)."""
        per_vertex = math.ceil(self.degree * link.d1 / link.m) + self.mis_frac
        return self.active_vertices * per_vertex


def stats_for(points: list[ProbePoint], link: LinkModel) -> PartitionStats:
    """Stack a probe grid into one (P,) :class:`PartitionStats` under
    ``link`` (the request counts are link-dependent)."""
    import jax.numpy as jnp

    return PartitionStats(
        active_edges=jnp.asarray([p.active_edges for p in points], jnp.float32),
        active_vertices=jnp.asarray([p.active_vertices for p in points], jnp.float32),
        zc_requests=jnp.asarray([p.zc_requests(link) for p in points], jnp.float32),
        total_edges=jnp.asarray([p.total_edges for p in points], jnp.float32),
    )


# Degree regimes: |A| as a function of Ea.  Hub = few high-degree sources
# (Table III / EMOGI's sweet spot), flat = deg~1 frontier (compaction's).
_REGIMES = {
    "hub": lambda ea: max(1.0, ea / 128.0),
    "mid": lambda ea: max(1.0, ea / 8.0),
    "flat": lambda ea: ea,
}


def default_grid(
    edge_levels: tuple[float, ...] = (1.0e6, 4.3e6, 1.7e7, 6.7e7),
    n_ratios: int = 9,
    regimes: tuple[str, ...] = ("hub", "mid", "flat"),
    mis_frac: float = 0.5,
) -> list[ProbePoint]:
    """Probe grid spanning the activity spectrum x degree regimes.

    Ratio endpoints are deliberately non-round so grid points do not land
    on exact cost ties (Algorithm 1 uses strict comparisons; a tie would
    make "selection unchanged" checks flaky under infinitesimal fits).
    """
    ratios = np.geomspace(1.07e-3, 0.93, n_ratios)
    points = []
    for E in edge_levels:
        for r in ratios:
            ea = max(1.0, float(round(E * r)))
            for name in regimes:
                a = min(float(round(_REGIMES[name](ea))), ea)
                points.append(ProbePoint(
                    total_edges=float(E), active_edges=ea,
                    active_vertices=a, mis_frac=mis_frac,
                ))
    return points


@dataclass(frozen=True)
class Observation:
    point: ProbePoint
    engine: int
    seconds: float


def model_probe(
    points: list[ProbePoint],
    truth: LinkModel,
    noise: float = 0.0,
    seed: int = 0,
) -> list[Observation]:
    """Simulate measurements by evaluating ``truth`` as the hardware.

    Per point the three engines cost what the ground-truth model says
    *execution* pays — ``tef`` / ``tec_full`` (the compaction pass is
    physically paid whether or not selection models it) / ``tiz`` —
    optionally perturbed by multiplicative gaussian noise.
    """
    costs = engine_costs(stats_for(points, truth), truth)
    per_engine = {
        FILTER: np.asarray(costs.tef, float),
        COMPACT: np.asarray(costs.tec_full, float),
        ZEROCOPY: np.asarray(costs.tiz, float),
    }
    rng = np.random.default_rng(seed)
    obs = []
    for eng in ENGINES:
        t = per_engine[eng]
        if noise > 0:
            t = t * np.clip(1.0 + noise * rng.standard_normal(len(points)), 0.05, None)
        for i, p in enumerate(points):
            obs.append(Observation(point=p, engine=eng, seconds=float(t[i])))
    return obs


def _materialize(point: ProbePoint, max_edges: int, seed: int):
    """Build a real edge block realizing (a capped version of) ``point``;
    also returns the ProbePoint describing what was *actually* built."""
    import jax.numpy as jnp

    from repro.core.engines import EdgeBlock

    scale = min(1.0, max_edges / max(point.total_edges, 1.0))
    E = max(int(point.total_edges * scale), 4)
    Ea = min(max(int(point.active_edges * scale), 1), E)
    A = min(max(int(point.active_vertices * scale), 1), Ea)
    deg = max(Ea // A, 1)
    rng = np.random.default_rng(seed)
    n = E  # enough vertices that inactive edges have distinct sources
    src = np.empty(E, np.int32)
    # active sources 0..A-1, `deg` consecutive edges each (CSR-contiguous)
    n_act = min(A * deg, E)
    src[:n_act] = np.repeat(np.arange(A, dtype=np.int32), deg)[:n_act]
    src[n_act:] = rng.integers(A, n, size=E - n_act)
    dst = rng.integers(0, n, size=E).astype(np.int32)
    w = rng.random(E).astype(np.float32) + 0.5
    frontier = np.zeros(n, bool)
    frontier[:A] = True
    active = frontier[src]
    block = EdgeBlock(
        src=jnp.asarray(src), dst=jnp.asarray(dst),
        weight=jnp.asarray(w), active=jnp.asarray(active),
    )
    operand = jnp.asarray(rng.random(n).astype(np.float32))
    realized = ProbePoint(
        total_edges=float(E), active_edges=float(n_act),
        active_vertices=float(A), mis_frac=point.mis_frac,
    )
    return block, operand, n, realized


def wall_probe(
    points: list[ProbePoint],
    max_edges: int = 200_000,
    repeats: int = 3,
    seed: int = 0,
    use_kernels: bool | str = "auto",
) -> tuple[list[ProbePoint], list[Observation]]:
    """Wall-time the three engines over materialized probe partitions.

    Each requested point is scaled (preserving its activity ratio and
    degree regime) to at most ``max_edges`` edges and the observations
    describe the *materialized* grid with UNSCALED measured seconds —
    rescaling capped points would also multiply the constant per-call
    dispatch component and bias the ``fit_overhead`` intercept upward.
    Returns ``(materialized_points, observations)``; calibrate against
    the returned points, not the requested ones.  Compile time is
    excluded (one warmup call per shape/engine).

    ``use_kernels`` mirrors :class:`HyTMConfig.use_kernels` ("auto"
    resolves via ``kernels.runtime``): calibration must time the SAME
    engine implementations the runtime will dispatch, or the fitted
    profile describes a path that never executes.
    """
    import time as _time

    import jax

    from repro.core.engines import ENGINE_FNS
    from repro.graph.algorithms import SSSP
    from repro.kernels.runtime import resolve_use_kernels

    uk = resolve_use_kernels(use_kernels)
    # one jitted wrapper per engine (n static): points sharing a block
    # shape reuse the compile instead of retracing per (point, engine)
    fns = {
        eng: jax.jit(
            lambda b, o, n, f=ENGINE_FNS[eng]: f(b, o, n, SSSP, use_kernels=uk),
            static_argnums=2,
        )
        for eng in ENGINES
    }
    realized_points = []
    obs = []
    for i, p in enumerate(points):
        block, operand, n, realized = _materialize(p, max_edges, seed + i)
        realized_points.append(realized)
        for eng in ENGINES:
            fn = fns[eng]
            jax.block_until_ready(fn(block, operand, n))  # warmup / compile
            times = []
            for _ in range(repeats):
                t0 = _time.monotonic()
                jax.block_until_ready(fn(block, operand, n))
                times.append(_time.monotonic() - t0)
            obs.append(Observation(
                point=realized, engine=eng,
                seconds=float(np.median(times)),
            ))
    return realized_points, obs


def observation_matrix(
    points: list[ProbePoint], observations: list[Observation]
) -> np.ndarray:
    """(N, 3) measured seconds, column index == engine id; NaN = missing."""
    index = {id(p): i for i, p in enumerate(points)}
    out = np.full((len(points), 3), np.nan)
    for o in observations:
        i = index.get(id(o.point))
        if i is None:  # fall back to value identity (deserialized points)
            i = points.index(o.point)
        out[i, o.engine] = o.seconds
    return out
