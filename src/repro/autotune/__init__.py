"""repro.autotune — measured-cost calibration for the HyTM cost model.

The Eq. 1-3 cost model ships with hand-set platform constants
(``core.constants.PCIE3`` / ``TPU_V5E_HBM``); this subsystem validates
and corrects them against what the engines actually cost on the machine
running them:

  probe     — timed micro-benchmarks of FILTER/COMPACT/ZEROCOPY over
              synthetic partitions spanning the activity-ratio spectrum
              (wall-clock, or a ground-truth model as hardware simulator)
  calibrate — least-squares LinkModel fit + regret-minimizing
              alpha/beta threshold tuning against the measured-best oracle
  registry  — JSON profile persistence keyed by device kind
  feedback  — OnlineCalibrator: EWMA per-engine corrections from
              per-iteration measured sweep times (HyTMConfig.autotune)

CLI: ``python -m repro.launch.calibrate`` (``--selfcheck`` for CI).
"""

from repro.autotune.calibrate import (
    CalibrationReport,
    calibrate,
    fit_link,
    selection_on_grid,
    total_regret,
    tune_thresholds,
)
from repro.autotune.feedback import OnlineCalibrator
from repro.autotune.probe import (
    Observation,
    ProbePoint,
    default_grid,
    model_probe,
    observation_matrix,
    stats_for,
    wall_probe,
)
from repro.autotune.registry import (
    default_device_kind,
    has_profile,
    list_profiles,
    load_profile,
    load_profile_or_default,
    profile_from_dict,
    profile_path,
    profile_to_dict,
    registry_dir,
    save_profile,
)

__all__ = [
    "CalibrationReport", "calibrate", "fit_link", "selection_on_grid",
    "total_regret", "tune_thresholds",
    "OnlineCalibrator",
    "Observation", "ProbePoint", "default_grid", "model_probe",
    "observation_matrix", "stats_for", "wall_probe",
    "default_device_kind", "has_profile", "list_profiles", "load_profile",
    "load_profile_or_default",
    "profile_from_dict", "profile_path", "profile_to_dict", "registry_dir",
    "save_profile",
]
