"""Model zoo: LM transformers (dense / MoE / MLA / local:global), GNNs,
and DLRM — every assigned architecture is a config over these modules."""
