"""EmbeddingBag with HyTM row engines — the DLRM hot path.

JAX has no native ``nn.EmbeddingBag``; this builds it from ``jnp.take`` +
``jax.ops.segment_sum`` (kernel_taxonomy §B.6), and maps the paper's
transfer engines onto embedding-row movement (DESIGN.md §4):

* ``gather`` (≙ ImpTM-zero-copy): direct row gather per lookup — one
  fine-grained access per index, duplicate ids fetched repeatedly (zero-
  copy's "no reuse" property, paper §II-C).
* ``dedup``  (≙ ExpTM-compaction): ``jnp.unique``-compact the batch's ids
  first, gather each hot row once, scatter back through the inverse map —
  the compaction pass buys transfer reduction exactly when the batch has
  many duplicate ids (hot rows == the paper's hub vertices).
* ``onehot`` (≙ ExpTM-filter): stream the whole table through a one-hot
  matmul — wins only when the batch covers most rows (tiny vocab fields:
  Criteo has fields with |V| = 3..27).

``select_row_engine`` is the cost model: expected transferred rows per
engine, same tier structure as Algorithm 1.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def select_row_engine(vocab: int, n_lookups: int, expected_unique: float | None = None) -> str:
    """Static cost-model choice (per table, from batch shape statistics).

    rows_gather = n_lookups
    rows_dedup  = E[unique] + compaction pass over n_lookups indices
    rows_onehot = vocab (stream the whole table)
    """
    if expected_unique is None:
        # balls-in-bins expectation: V * (1 - (1 - 1/V)^n)
        expected_unique = vocab * (1.0 - (1.0 - 1.0 / max(vocab, 1)) ** n_lookups)
    if vocab <= min(n_lookups, 512):
        return "onehot"
    if expected_unique < 0.5 * n_lookups:
        return "dedup"
    return "gather"


def _bag_reduce(rows: jax.Array, bags: int, bag_size: int, mode: str) -> jax.Array:
    rows = rows.reshape(bags, bag_size, rows.shape[-1])
    if mode == "sum":
        return jnp.sum(rows, axis=1)
    if mode == "mean":
        return jnp.mean(rows, axis=1)
    if mode == "max":
        return jnp.max(rows, axis=1)
    raise ValueError(mode)


def embedding_bag(
    table: jax.Array,      # (V, D)
    indices: jax.Array,    # (B, L) int32 — L-hot bags
    mode: str = "sum",
    engine: str = "auto",
) -> jax.Array:
    """(B, L) indices -> (B, D) reduced embeddings."""
    B, L = indices.shape
    V, D = table.shape
    if engine == "auto":
        engine = select_row_engine(V, B * L)
    flat = indices.reshape(-1)

    if engine == "gather":
        rows = jnp.take(table, flat, axis=0)
    elif engine == "dedup":
        # compaction pass: unique ids (static-size padded), single gather of
        # hot rows, inverse-map expansion.  size=B*L is the worst case; the
        # win is in *transfer* (each hot row moves once), which the modeled
        # bytes in benchmarks/table6 account for.
        uniq, inv = jnp.unique(flat, size=B * L, fill_value=0, return_inverse=True)
        hot = jnp.take(table, uniq, axis=0)
        rows = jnp.take(hot, inv.reshape(-1), axis=0)
    elif engine == "onehot":
        onehot = jax.nn.one_hot(flat, V, dtype=table.dtype)
        rows = onehot @ table
    else:
        raise ValueError(engine)
    return _bag_reduce(rows, B, L, mode)


def embedding_bag_grad_rows(vocab: int, indices: jax.Array) -> jax.Array:
    """Number of distinct rows touched by the backward scatter (used by the
    table-placement cost model in benchmarks)."""
    flat = indices.reshape(-1)
    marks = jnp.zeros(vocab, dtype=jnp.int32).at[flat].set(1)
    return jnp.sum(marks)
