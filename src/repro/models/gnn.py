"""GNN architectures: GraphSAGE, PNA, GatedGCN, MeshGraphNet.

Message passing is ``jax.ops.segment_sum/max/min`` over an edge-index
(src, dst) scatter — JAX has no CSR/CSC sparse, so this IS the system's
SpMM layer (kernel_taxonomy §B.3).  The blocked Pallas path for the same
computation is ``kernels/segment_spmm`` (the HyTM filter engine's compute
core); full-batch training is the all-active regime where the HyTM cost
model always picks the filter engine, while sampled minibatches
(GraphSAGE fanout) are the sparse-frontier regime served by the gather
engine (DESIGN.md §4).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, layer_norm, mlp_apply, mlp_init


@dataclass(frozen=True)
class GNNConfig:
    name: str
    arch: str                   # 'graphsage' | 'pna' | 'gatedgcn' | 'meshgraphnet'
    n_layers: int
    d_hidden: int
    d_in: int
    d_out: int
    aggregator: str = "mean"
    sample_sizes: tuple = ()    # GraphSAGE minibatch fanouts
    mlp_layers: int = 2         # MeshGraphNet MLP depth
    d_edge_in: int = 1          # edge feature dim (gatedgcn / meshgraphnet)
    task: str = "node"          # 'node' | 'graph' | 'regression'
    dtype: str = "float32"

    def replace(self, **kw):
        import dataclasses
        return dataclasses.replace(self, **kw)


# ------------------------------------------------------------ aggregation

def aggregate(messages: jax.Array, dst: jax.Array, n: int, how: str) -> jax.Array:
    """The message-passing primitive (scatter-combine by destination)."""
    if how == "sum":
        return jax.ops.segment_sum(messages, dst, num_segments=n)
    if how == "mean":
        s = jax.ops.segment_sum(messages, dst, num_segments=n)
        c = jax.ops.segment_sum(jnp.ones_like(messages[:, :1]), dst, num_segments=n)
        return s / jnp.maximum(c, 1.0)
    if how == "max":
        out = jax.ops.segment_max(messages, dst, num_segments=n)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    if how == "min":
        out = jax.ops.segment_min(messages, dst, num_segments=n)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    if how == "std":
        mean = aggregate(messages, dst, n, "mean")
        sq = aggregate(jnp.square(messages), dst, n, "mean")
        return jnp.sqrt(jnp.maximum(sq - jnp.square(mean), 0.0) + 1e-6)
    raise ValueError(how)


# -------------------------------------------------------------- GraphSAGE

def init_graphsage(key, cfg: GNNConfig):
    dims = [cfg.d_in] + [cfg.d_hidden] * cfg.n_layers
    ks = jax.random.split(key, cfg.n_layers + 1)
    layers = [
        {
            "w_self": dense_init(jax.random.fold_in(ks[i], 0), dims[i], dims[i + 1]),
            "w_nbr": dense_init(jax.random.fold_in(ks[i], 1), dims[i], dims[i + 1]),
            "b": jnp.zeros((dims[i + 1],)),
        }
        for i in range(cfg.n_layers)
    ]
    return {"layers": layers, "out": dense_init(ks[-1], cfg.d_hidden, cfg.d_out)}


def graphsage_forward(params, feats, edge_src, edge_dst, cfg: GNNConfig):
    """Full-graph forward."""
    h = feats
    n = feats.shape[0]
    for lp in params["layers"]:
        h_n = aggregate(h[edge_src], edge_dst, n, cfg.aggregator)
        h = jax.nn.relu(h @ lp["w_self"] + h_n @ lp["w_nbr"] + lp["b"])
        h = h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6)
    return h @ params["out"]


def graphsage_minibatch_forward(params, layer_feats: list[jax.Array], cfg: GNNConfig):
    """Sampled forward: ``layer_feats[k]`` are features of hop-k vertices
    (hop-0 = seeds), shaped (b * prod(fanouts[:k]), d_in).  Aggregation is
    a reshape-mean over the fanout axis — the static-shape GraphSAGE
    estimator (fine-grained gather regime of HyTM)."""
    fan = cfg.sample_sizes
    hs = list(layer_feats)
    for li, lp in enumerate(params["layers"]):
        depth = len(fan) - li  # hops available this round
        new_hs = []
        for k in range(depth):
            parent = hs[k]
            child = hs[k + 1]
            agg = child.reshape(parent.shape[0], fan[k], child.shape[-1])
            agg = jnp.mean(agg, axis=1) if cfg.aggregator == "mean" else jnp.max(agg, axis=1)
            h = jax.nn.relu(parent @ lp["w_self"] + agg @ lp["w_nbr"] + lp["b"])
            h = h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6)
            new_hs.append(h)
        hs = new_hs
    return hs[0] @ params["out"]


# ------------------------------------------------------------------- PNA

PNA_AGGREGATORS = ("mean", "max", "min", "std")


def init_pna(key, cfg: GNNConfig, avg_log_deg: float = 1.0):
    dims = [cfg.d_in] + [cfg.d_hidden] * cfg.n_layers
    ks = jax.random.split(key, cfg.n_layers + 1)
    layers = []
    for i in range(cfg.n_layers):
        k1, k2, k3 = jax.random.split(ks[i], 3)
        layers.append({
            "w_msg": dense_init(k1, 2 * dims[i], dims[i]),
            "w_upd": dense_init(k2, dims[i] + 12 * dims[i], dims[i + 1]),
            "b_upd": jnp.zeros((dims[i + 1],)),
        })
    return {
        "layers": layers,
        "out": dense_init(ks[-1], cfg.d_hidden, cfg.d_out),
        "avg_log_deg": jnp.float32(avg_log_deg),
    }


def pna_forward(params, feats, edge_src, edge_dst, cfg: GNNConfig):
    h = feats
    n = feats.shape[0]
    deg = jax.ops.segment_sum(jnp.ones_like(edge_dst, dtype=jnp.float32), edge_dst, num_segments=n)
    log_deg = jnp.log(deg + 1.0)[:, None]
    delta = jnp.maximum(params["avg_log_deg"], 1e-3)
    scalers = (
        jnp.ones_like(log_deg),            # identity
        log_deg / delta,                   # amplification
        delta / jnp.maximum(log_deg, 1e-3),  # attenuation
    )
    for lp in params["layers"]:
        msg = jax.nn.relu(
            jnp.concatenate([h[edge_src], h[edge_dst]], axis=-1) @ lp["w_msg"]
        )
        aggs = [aggregate(msg, edge_dst, n, a) for a in PNA_AGGREGATORS]
        scaled = [a * s for a in aggs for s in scalers]  # 4 x 3 = 12
        h = jax.nn.relu(
            jnp.concatenate([h] + scaled, axis=-1) @ lp["w_upd"] + lp["b_upd"]
        )
    return h @ params["out"]


# -------------------------------------------------------------- GatedGCN

def init_gatedgcn(key, cfg: GNNConfig):
    ks = jax.random.split(key, cfg.n_layers + 3)
    layers = []
    for i in range(cfg.n_layers):
        kk = jax.random.split(ks[i], 5)
        d = cfg.d_hidden
        layers.append({
            "A": dense_init(kk[0], d, d), "B": dense_init(kk[1], d, d),
            "C": dense_init(kk[2], d, d), "U": dense_init(kk[3], d, d),
            "V": dense_init(kk[4], d, d),
            "ln_h": jnp.ones((d,)), "ln_h_b": jnp.zeros((d,)),
            "ln_e": jnp.ones((d,)), "ln_e_b": jnp.zeros((d,)),
        })
    return {
        "embed_h": dense_init(ks[-3], cfg.d_in, cfg.d_hidden),
        "embed_e": dense_init(ks[-2], cfg.d_edge_in, cfg.d_hidden),
        "layers": layers,
        "out": dense_init(ks[-1], cfg.d_hidden, cfg.d_out),
    }


def gatedgcn_forward(params, feats, edge_src, edge_dst, edge_feats, cfg: GNNConfig):
    """Bresson & Laurent residual gated graph convnets [arXiv:1711.07553]
    (LayerNorm replaces BatchNorm — TPU-friendly, noted in DESIGN.md)."""
    n = feats.shape[0]
    h = feats @ params["embed_h"]
    e = edge_feats @ params["embed_e"]
    for lp in params["layers"]:
        e_new = h[edge_src] @ lp["A"] + h[edge_dst] @ lp["B"] + e @ lp["C"]
        eta = jax.nn.sigmoid(e_new)
        num = aggregate(eta * (h[edge_src] @ lp["V"]), edge_dst, n, "sum")
        den = aggregate(eta, edge_dst, n, "sum")
        h_new = h @ lp["U"] + num / (den + 1e-6)
        h = h + jax.nn.relu(layer_norm(h_new, lp["ln_h"], lp["ln_h_b"]))
        e = e + jax.nn.relu(layer_norm(e_new, lp["ln_e"], lp["ln_e_b"]))
    return h @ params["out"]


# ---------------------------------------------------------- MeshGraphNet

def init_meshgraphnet(key, cfg: GNNConfig):
    d = cfg.d_hidden
    hidden = [d] * cfg.mlp_layers
    ks = jax.random.split(key, 2 * cfg.n_layers + 3)
    proc = []
    for i in range(cfg.n_layers):
        proc.append({
            "edge_mlp": mlp_init(ks[2 * i], [3 * d] + hidden + [d]),
            "node_mlp": mlp_init(ks[2 * i + 1], [2 * d] + hidden + [d]),
            "ln_e": jnp.ones((d,)), "ln_e_b": jnp.zeros((d,)),
            "ln_h": jnp.ones((d,)), "ln_h_b": jnp.zeros((d,)),
        })
    return {
        "enc_node": mlp_init(ks[-3], [cfg.d_in] + hidden + [d]),
        "enc_edge": mlp_init(ks[-2], [cfg.d_edge_in] + hidden + [d]),
        "processor": proc,
        "dec": mlp_init(ks[-1], [d] + hidden + [cfg.d_out]),
    }


def meshgraphnet_forward(params, feats, edge_src, edge_dst, edge_feats, cfg: GNNConfig):
    """Encode-process-decode [arXiv:2010.03409]; sum aggregator."""
    n = feats.shape[0]
    h = mlp_apply(params["enc_node"], feats)
    e = mlp_apply(params["enc_edge"], edge_feats)
    for lp in params["processor"]:
        e_in = jnp.concatenate([e, h[edge_src], h[edge_dst]], axis=-1)
        e = e + layer_norm(mlp_apply(lp["edge_mlp"], e_in), lp["ln_e"], lp["ln_e_b"])
        agg = aggregate(e, edge_dst, n, "sum")
        h_in = jnp.concatenate([h, agg], axis=-1)
        h = h + layer_norm(mlp_apply(lp["node_mlp"], h_in), lp["ln_h"], lp["ln_h_b"])
    return mlp_apply(params["dec"], h)


# ------------------------------------------------------------- dispatch

def init_gnn(key, cfg: GNNConfig):
    return {
        "graphsage": init_graphsage,
        "pna": init_pna,
        "gatedgcn": init_gatedgcn,
        "meshgraphnet": init_meshgraphnet,
    }[cfg.arch](key, cfg)


def gnn_forward(params, cfg: GNNConfig, feats, edge_src, edge_dst, edge_feats=None):
    if cfg.arch == "graphsage":
        return graphsage_forward(params, feats, edge_src, edge_dst, cfg)
    if cfg.arch == "pna":
        return pna_forward(params, feats, edge_src, edge_dst, cfg)
    if cfg.arch == "gatedgcn":
        if edge_feats is None:
            edge_feats = jnp.ones((edge_src.shape[0], cfg.d_edge_in), feats.dtype)
        return gatedgcn_forward(params, feats, edge_src, edge_dst, edge_feats, cfg)
    if cfg.arch == "meshgraphnet":
        if edge_feats is None:
            edge_feats = jnp.ones((edge_src.shape[0], cfg.d_edge_in), feats.dtype)
        return meshgraphnet_forward(params, feats, edge_src, edge_dst, edge_feats, cfg)
    raise ValueError(cfg.arch)


def gnn_loss(params, cfg: GNNConfig, feats, edge_src, edge_dst, labels,
             label_mask=None, edge_feats=None, graph_ids=None, n_graphs=0):
    out = gnn_forward(params, cfg, feats, edge_src, edge_dst, edge_feats)
    if cfg.task == "graph":
        # batched-small-graph cell: mean-pool per graph then classify
        pooled = jax.ops.segment_sum(out, graph_ids, num_segments=n_graphs)
        counts = jax.ops.segment_sum(jnp.ones_like(out[:, :1]), graph_ids, num_segments=n_graphs)
        logits = pooled / jnp.maximum(counts, 1.0)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))
    if cfg.task == "regression":
        err = jnp.square(out - labels)
        if label_mask is not None:
            return jnp.sum(err * label_mask[:, None]) / jnp.maximum(jnp.sum(label_mask), 1.0)
        return jnp.mean(err)
    logp = jax.nn.log_softmax(out.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    if label_mask is not None:
        return -jnp.sum(ll * label_mask) / jnp.maximum(jnp.sum(label_mask), 1.0)
    return -jnp.mean(ll)
