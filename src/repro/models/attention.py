"""Attention variants: GQA/MQA (grouped KV), MLA (DeepSeek-V2 latent KV),
with causal + sliding-window masking and decode-time KV caches.

Masking uses absolute positions so the same code path serves training
(full sequence), chunked prefill, and single-token decode.  The sliding
window size is a *traced* scalar per layer, so a single scan-over-layers
supports gemma-style 5:1 local:global patterns (window=0 means global).

The jnp implementation here is the oracle; `kernels/flash_attention`
provides the fused Pallas path for the TPU target (selected via
``use_flash``).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense_init, rms_norm


@dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    q_lora: int = 0        # 0 = direct q projection (V2-Lite)
    d_nope: int = 128      # non-rotary head dim
    d_rope: int = 64       # shared rotary dim
    d_v: int = 128         # value head dim


# ------------------------------------------------------------------ masks

def attention_mask(
    q_pos: jax.Array,   # (S,) absolute positions of queries
    kv_pos: jax.Array,  # (L,) absolute positions of keys
    kv_valid: jax.Array | None,  # (B, L) or None
    window: jax.Array | int,     # 0 = global
) -> jax.Array:
    causal = q_pos[:, None] >= kv_pos[None, :]
    w = jnp.asarray(window, dtype=jnp.int32)
    in_window = jnp.where(
        w > 0, q_pos[:, None] - kv_pos[None, :] < w, True
    )
    mask = causal & in_window  # (S, L)
    if kv_valid is not None:
        mask = mask[None] & kv_valid[:, None, :]  # (B, S, L)... broadcast later
        return mask[:, None]  # (B, 1, S, L)
    return mask[None, None]   # (1, 1, S, L)


def _sdpa(q, k, v, mask, scale):
    """q: (B,S,KV,G,dh) k/v: (B,L,KV,dh) -> (B,S,KV,G,dv)."""
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32) * scale
    # mask: (B|1, 1, S, L) -> (B|1, 1, 1, S, L) broadcasts over (B,KV,G,S,L)
    scores = jnp.where(mask[:, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgst,btkd->bskgd", probs, v)


# Above this many score elements per (q_len x kv_len) tile, attention runs
# blocked with an online softmax (never materializing the S x L matrix).
_FLASH_THRESHOLD = 2048 * 2048
_Q_BLOCK = 512
_KV_BLOCK = 1024


def _pad_dim(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def _block_mask(qpb, kpb, kv_last, w):
    """(qb, kb) validity from absolute positions."""
    return (
        (qpb[:, None] >= kpb[None, :])
        & (kpb[None, :] <= kv_last)
        & jnp.where(w > 0, qpb[:, None] - kpb[None, :] < w, True)
    )


def _flash_fwd_blocks(q_blocks, k_blocks, v_blocks, qp_blocks, kp_blocks, kv_last, w):
    """Returns out (B,nq*qb,KV,G,dv) and lse (B,KV,G,nq*qb)."""
    B, nq, QB, KV, G, dh = q_blocks.shape
    nk, KB = kp_blocks.shape
    dv = v_blocks.shape[-1]

    def q_step(_, qi):
        qb = q_blocks[:, qi]
        qpb = qp_blocks[qi]

        def kv_step(carry, ki):
            m, l, acc = carry
            s = jnp.einsum("bskgd,btkd->bkgst", qb, k_blocks[:, ki].astype(jnp.float32))
            valid = _block_mask(qpb, kp_blocks[ki], kv_last, w)
            s = jnp.where(valid[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_safe[..., None]), 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgst,btkd->bkgsd", p, v_blocks[:, ki].astype(jnp.float32)
            )
            return (m_new, l, acc), None

        m0 = jnp.full((B, KV, G, QB), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, G, QB), jnp.float32)
        a0 = jnp.zeros((B, KV, G, QB, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        lse = m_safe + jnp.log(jnp.maximum(l, 1e-30))
        return None, (jnp.transpose(out, (0, 3, 1, 2, 4)), lse)

    _, (outs, lses) = jax.lax.scan(q_step, None, jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * QB, KV, G, dv)
    lse = jnp.moveaxis(lses, 0, 3).reshape(B, KV, G, nq * QB)  # (B,KV,G,S)
    return out, lse


@partial(jax.custom_vjp, nondiff_argnums=())
def _flash_core(q_pad, k_pad, v_pad, qp_pad, kp_pad, kv_last, w):
    out, _ = _flash_fwd_blocks(*_to_blocks(q_pad, k_pad, v_pad, qp_pad, kp_pad), kv_last, w)
    return out


def _to_blocks(q_pad, k_pad, v_pad, qp_pad, kp_pad):
    B, Sq, KV, G, dh = q_pad.shape
    L = k_pad.shape[1]
    nq, nk = Sq // _Q_BLOCK, L // _KV_BLOCK
    return (
        q_pad.reshape(B, nq, _Q_BLOCK, KV, G, dh),
        k_pad.reshape(B, nk, _KV_BLOCK, KV, dh),
        v_pad.reshape(B, nk, _KV_BLOCK, KV, v_pad.shape[-1]),
        qp_pad.reshape(nq, _Q_BLOCK),
        kp_pad.reshape(nk, _KV_BLOCK),
    )


def _flash_core_fwd(q_pad, k_pad, v_pad, qp_pad, kp_pad, kv_last, w):
    blocks = _to_blocks(q_pad, k_pad, v_pad, qp_pad, kp_pad)
    out, lse = _flash_fwd_blocks(*blocks, kv_last, w)
    return out, (q_pad, k_pad, v_pad, qp_pad, kp_pad, kv_last, w, out, lse)


def _flash_core_bwd(res, dout):
    """FlashAttention-2 backward: recompute p per (q,kv) block — nothing
    tile-sized survives the forward (the 20 GB/device difference on the
    train_4k dry-run cells; see EXPERIMENTS.md §Perf)."""
    q_pad, k_pad, v_pad, qp_pad, kp_pad, kv_last, w, out, lse = res
    q_blocks, k_blocks, v_blocks, qpb_all, kpb_all = _to_blocks(
        q_pad, k_pad, v_pad, qp_pad, kp_pad
    )
    B, nq, QB, KV, G, dh = q_blocks.shape
    nk = kpb_all.shape[0]
    KB = kpb_all.shape[1]
    dv = v_blocks.shape[-1]
    dout = dout.astype(jnp.float32)
    # D_i = rowsum(dout * out)  (B,KV,G,S)
    delta = jnp.einsum("bskgd,bskgd->bkgs", dout, out.astype(jnp.float32))
    lse_blocks = lse.reshape(B, KV, G, nq, QB)
    delta_blocks = delta.reshape(B, KV, G, nq, QB)
    dout_blocks = dout.reshape(B, nq, QB, KV, G, dv)

    def q_step(carry, qi):
        dk_acc, dv_acc = carry
        qb = q_blocks[:, qi]
        qpb = qpb_all[qi]
        lse_b = lse_blocks[:, :, :, qi]
        delta_b = delta_blocks[:, :, :, qi]
        dob = dout_blocks[:, qi]

        def kv_step(carry2, ki):
            dq_b, dk_a, dv_a = carry2
            kb = k_blocks[:, ki].astype(jnp.float32)
            vb = v_blocks[:, ki].astype(jnp.float32)
            s = jnp.einsum("bskgd,btkd->bkgst", qb, kb)
            valid = _block_mask(qpb, kpb_all[ki], kv_last, w)
            s = jnp.where(valid[None, None, None], s, -jnp.inf)
            p = jnp.where(jnp.isfinite(s), jnp.exp(s - lse_b[..., None]), 0.0)
            dp = jnp.einsum("bskgd,btkd->bkgst", dob, vb)
            ds = p * (dp - delta_b[..., None])
            dq_b = dq_b + jnp.einsum("bkgst,btkd->bskgd", ds, kb)
            dk_a = dk_a.at[:, ki].add(jnp.einsum("bkgst,bskgd->btkd", ds, qb))
            dv_a = dv_a.at[:, ki].add(jnp.einsum("bkgst,bskgd->btkd", p, dob))
            return (dq_b, dk_a, dv_a), None

        dq0 = jnp.zeros((B, QB, KV, G, dh), jnp.float32)
        (dq_b, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc), jnp.arange(nk)
        )
        return (dk_acc, dv_acc), dq_b

    dk0 = jnp.zeros((B, nk, KB, KV, dh), jnp.float32)
    dv0 = jnp.zeros((B, nk, KB, KV, dv), jnp.float32)
    (dk, dvv), dqs = jax.lax.scan(q_step, (dk0, dv0), jnp.arange(nq))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(q_pad.shape)
    dk = dk.reshape(k_pad.shape).astype(k_pad.dtype)
    dvv = dvv.reshape(v_pad.shape).astype(v_pad.dtype)
    return dq.astype(q_pad.dtype), dk, dvv, None, None, None, None


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


def _flash_sdpa(q, k, v, q_pos, kv_pos, kv_last, window, scale):
    """Blocked attention with online softmax (FlashAttention-2 fwd+bwd,
    pure-jnp oracle; the Pallas kernel `kernels/flash_attention` is the
    fused TPU path).  Never materializes more than a (qb x kb) tile.

    q: (B,S,KV,G,dh); k: (B,L,KV,dh); v: (B,L,KV,dv)
    q_pos: (S,) absolute positions; kv_pos: (L,); kv_last: scalar — last
    valid cache position (huge when no cache); window: 0 = global.
    """
    B, S, KV, G, dh = q.shape
    dv = v.shape[-1]
    qf = q.astype(jnp.float32) * scale
    q_pad, S0 = _pad_dim(qf, 1, _Q_BLOCK)
    qp_pad, _ = _pad_dim(q_pos.astype(jnp.int32), 0, _Q_BLOCK)
    k_pad, L0 = _pad_dim(k, 1, _KV_BLOCK)
    v_pad, _ = _pad_dim(v, 1, _KV_BLOCK)
    kp_pad, _ = _pad_dim(kv_pos.astype(jnp.int32), 0, _KV_BLOCK)
    # padded kv positions never attend: push them past every query
    pad_mask = jnp.arange(k_pad.shape[1]) < L0
    kp_pad = jnp.where(pad_mask, kp_pad, jnp.int32(2**30))
    out = _flash_core(
        q_pad, k_pad, v_pad, qp_pad, kp_pad,
        jnp.asarray(kv_last, jnp.int32), jnp.asarray(window, jnp.int32),
    )
    return out[:, :S0].astype(v.dtype)


# ------------------------------------------------------------------- GQA

def init_gqa(key, d_model, n_heads, n_kv, d_head, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d_model, n_heads * d_head, dtype),
        "wk": dense_init(ks[1], d_model, n_kv * d_head, dtype),
        "wv": dense_init(ks[2], d_model, n_kv * d_head, dtype),
        "wo": dense_init(ks[3], n_heads * d_head, d_model, dtype),
    }


def gqa_attention(
    p: dict,
    x: jax.Array,            # (B, S, D)
    positions: jax.Array,    # (S,)
    n_heads: int,
    n_kv: int,
    d_head: int,
    rope_theta: float,
    window: jax.Array | int = 0,
    cache: dict | None = None,      # {'k': (B,L,KV,dh), 'v': ...}
    cache_index: jax.Array | None = None,
    shard_fn=None,
):
    B, S, D = x.shape
    G = n_heads // n_kv
    dt = x.dtype
    sc = shard_fn or (lambda a, kind: a)

    q = (x @ p["wq"].astype(dt)).reshape(B, S, n_kv, G, d_head)
    k = (x @ p["wk"].astype(dt)).reshape(B, S, n_kv, d_head)
    v = (x @ p["wv"].astype(dt)).reshape(B, S, n_kv, d_head)
    q = apply_rope(q.reshape(B, S, n_kv * G, d_head), positions, rope_theta)
    q = sc(q.reshape(B, S, n_kv, G, d_head), "qheads")
    k = sc(apply_rope(k, positions, rope_theta), "kvheads")
    v = sc(v, "kvheads")

    scale = 1.0 / jnp.sqrt(jnp.float32(d_head))
    if cache is not None:
        L = cache["k"].shape[1]
        k_all = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache_index, axis=1)
        v_all = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache_index, axis=1)
        kv_pos = jnp.arange(L, dtype=jnp.int32)
        kv_last = cache_index + S - 1
        new_cache = {"k": k_all, "v": v_all}
    else:
        k_all, v_all = k, v
        kv_pos = positions
        kv_last = jnp.int32(2**30)
        new_cache = None

    L = k_all.shape[1]
    if S * L > _FLASH_THRESHOLD:
        out = _flash_sdpa(q, k_all, v_all, positions, kv_pos, kv_last, window, scale)
    else:
        kv_valid = None
        if cache is not None:
            kv_valid = (kv_pos[None, :] <= kv_last) * jnp.ones((B, 1), bool)
        mask = attention_mask(positions, kv_pos, kv_valid, window)
        out = _sdpa(q, k_all, v_all, mask, scale)
    out = out.reshape(B, S, n_heads * d_head)
    return out @ p["wo"].astype(dt), new_cache


# ------------------------------------------------------------------- MLA

def init_mla(key, d_model, n_heads, mla: MLAConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    p = {
        "w_dkv": dense_init(ks[1], d_model, mla.kv_lora, dtype),
        "kv_norm": jnp.zeros((mla.kv_lora,), dtype),
        "w_uk": dense_init(ks[2], mla.kv_lora, n_heads * mla.d_nope, dtype),
        "w_uv": dense_init(ks[3], mla.kv_lora, n_heads * mla.d_v, dtype),
        "w_kr": dense_init(ks[4], d_model, mla.d_rope, dtype),
        "wo": dense_init(ks[5], n_heads * mla.d_v, d_model, dtype),
    }
    if mla.q_lora:
        kq = jax.random.split(ks[0])
        p["w_dq"] = dense_init(kq[0], d_model, mla.q_lora, dtype)
        p["q_norm"] = jnp.zeros((mla.q_lora,), dtype)
        p["w_uq"] = dense_init(kq[1], mla.q_lora, n_heads * (mla.d_nope + mla.d_rope), dtype)
    else:
        p["wq"] = dense_init(ks[0], d_model, n_heads * (mla.d_nope + mla.d_rope), dtype)
    return p


def mla_attention(
    p: dict,
    x: jax.Array,           # (B, S, D)
    positions: jax.Array,   # (S,)
    n_heads: int,
    mla: MLAConfig,
    rope_theta: float,
    window: jax.Array | int = 0,
    cache: dict | None = None,   # {'ckv': (B,L,kv_lora), 'kr': (B,L,d_rope)}
    cache_index: jax.Array | None = None,
    shard_fn=None,
):
    """Multi-head Latent Attention.  The KV cache stores only the latent
    ``c_kv`` (kv_lora) + the shared rotary key (d_rope) — the paper-family
    compression that makes 32k-500k decode caches feasible."""
    B, S, D = x.shape
    dt = x.dtype
    H, dn, dr, dv = n_heads, mla.d_nope, mla.d_rope, mla.d_v

    if mla.q_lora:
        cq = rms_norm(x @ p["w_dq"].astype(dt), p["q_norm"])
        q = (cq @ p["w_uq"].astype(dt)).reshape(B, S, H, dn + dr)
    else:
        q = (x @ p["wq"].astype(dt)).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, rope_theta)

    c_kv = rms_norm(x @ p["w_dkv"].astype(dt), p["kv_norm"])  # (B,S,kvl)
    k_rope = apply_rope((x @ p["w_kr"].astype(dt))[:, :, None, :], positions, rope_theta)[:, :, 0]

    if cache is not None:
        L = cache["ckv"].shape[1]
        ckv_all = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], c_kv, cache_index, axis=1)
        kr_all = jax.lax.dynamic_update_slice_in_dim(cache["kr"], k_rope, cache_index, axis=1)
        kv_pos = jnp.arange(L, dtype=jnp.int32)
        kv_last = cache_index + S - 1
        new_cache = {"ckv": ckv_all, "kr": kr_all}
    else:
        ckv_all, kr_all = c_kv, k_rope
        kv_pos = positions
        kv_last = jnp.int32(2**30)
        new_cache = None

    # Expand latent -> per-head keys/values (decode recomputes from latent;
    # the 'absorbed' matmul variant is a §Perf optimization).
    L = ckv_all.shape[1]
    k_nope = (ckv_all @ p["w_uk"].astype(dt)).reshape(B, L, H, dn)
    v = (ckv_all @ p["w_uv"].astype(dt)).reshape(B, L, H, dv)

    scale = 1.0 / jnp.sqrt(jnp.float32(dn + dr))
    if S * L > _FLASH_THRESHOLD:
        # fold the shared rotary key into per-head keys; flash-blocked path
        q_all = jnp.concatenate([q_nope, q_rope], axis=-1)[:, :, :, None, :]
        k_eff = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_all[:, :, None, :], (B, L, H, dr))], axis=-1
        )
        out = _flash_sdpa(
            q_all, k_eff, v, positions, kv_pos, kv_last, window, scale
        ).reshape(B, S, H * dv)
    else:
        kv_valid = None
        if cache is not None:
            kv_valid = (kv_pos[None, :] <= kv_last) * jnp.ones((B, 1), bool)
        mask = attention_mask(positions, kv_pos, kv_valid, window)
        scores = (
            jnp.einsum("bshd,bthd->bhst", q_nope, k_nope)
            + jnp.einsum("bshd,btd->bhst", q_rope, kr_all)
        ).astype(jnp.float32) * scale
        scores = jnp.where(mask, scores, -1e30)  # (B|1,1,S,L) broadcasts
        probs = jax.nn.softmax(scores, axis=-1).astype(dt)
        out = jnp.einsum("bhst,bthd->bshd", probs, v).reshape(B, S, H * dv)
    return out @ p["wo"].astype(dt), new_cache
