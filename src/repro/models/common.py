"""Shared building blocks: norms, activations, RoPE, initializers.

Pure-function style: params are plain dict pytrees, every module is
``init(key, ...) -> params`` + ``apply(params, x, ...)``.  Abstract
initialization (for the dry-run's ShapeDtypeStruct path) reuses the same
init functions under ``jax.eval_shape``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias
    return out.astype(dtype)


def dense_init(key: jax.Array, d_in: int, d_out: int, dtype=jnp.float32) -> jax.Array:
    scale = 1.0 / jnp.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)


def embed_init(key: jax.Array, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02).astype(dtype)


def mlp_init(key: jax.Array, dims: list[int], dtype=jnp.float32) -> dict:
    """Simple biased MLP used by GNN/DLRM heads."""
    keys = jax.random.split(key, len(dims) - 1)
    return {
        "w": [dense_init(k, dims[i], dims[i + 1], dtype) for i, k in enumerate(keys)],
        "b": [jnp.zeros((dims[i + 1],), dtype) for i in range(len(dims) - 1)],
    }


def mlp_apply(params: dict, x: jax.Array, act=jax.nn.relu, final_act=None) -> jax.Array:
    n = len(params["w"])
    for i, (w, b) in enumerate(zip(params["w"], params["b"])):
        x = x @ w.astype(x.dtype) + b.astype(x.dtype)
        if i < n - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


# ----------------------------------------------------------------- RoPE

def rope_frequencies(d_head: int, theta: float = 10_000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0) -> jax.Array:
    """x: (..., S, H, Dh); positions: (..., S). Rotates pairs (even, odd)."""
    freqs = rope_frequencies(x.shape[-1], theta)  # (Dh/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,Dh/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.stack([out1, out2], axis=-1).reshape(x.shape).astype(x.dtype)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


def cross_entropy_loss(logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None) -> jax.Array:
    """Mean token-level cross entropy in fp32.

    Sharding-aware formulation: the label logit is extracted with a masked
    reduction over the vocab axis instead of ``take_along_axis`` — a
    gather along a model-sharded vocab dimension makes GSPMD all-gather
    the full (T, V) logits per device (~24 GB at 64k tokens x 92k vocab),
    while partial-reduce + small all-reduce keeps everything sharded.
    """
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    label_logit = jnp.sum(
        jnp.where(vocab_iota == labels[..., None], logits, 0.0), axis=-1
    )
    ll = label_logit - lse
    if mask is None:
        return -jnp.mean(ll)
    mask = mask.astype(jnp.float32)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
