"""Decoder-only LM stack covering all five assigned LM architectures.

One config space expresses:
  * internlm2-1.8b  — dense, GQA, SwiGLU
  * granite-20b     — dense, MQA (kv=1), non-gated GELU MLP (GPT-BigCode
                      family; gated SwiGLU would put it at ~27B, not 20B)
  * gemma3-12b      — dense, GQA, 5:1 local:global sliding-window pattern
  * deepseek-v2-lite— MLA + MoE (64 routed top-6, 2 shared, first layer dense)
  * kimi-k2-1t-a32b — GQA + MoE (384 routed top-8)

Layers are homogeneous after the optional ``first_dense_layers`` prefix,
so the body runs as ONE ``lax.scan`` over stacked params — keeping the
lowered HLO small enough that 61-layer 1T-param programs compile in
seconds on the 512-device dry-run.  Per-layer sliding windows ride the
scan as a traced (n_layers,) array, which is how a single scan serves the
gemma local:global pattern.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.attention import (
    MLAConfig,
    gqa_attention,
    init_gqa,
    init_mla,
    mla_attention,
)
from repro.models.common import cross_entropy_loss, dense_init, embed_init, rms_norm
from repro.models.moe import MoEConfig, init_moe, moe_ffn


@dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    ffn_act: str = "swiglu"            # 'swiglu' | 'gelu' (non-gated)
    window_pattern: tuple = (0,)       # cycled over layers; 0 = global attn
    attention: str = "gqa"             # 'gqa' | 'mla'
    mla: MLAConfig | None = None
    moe: MoEConfig | None = None
    first_dense_layers: int = 0        # dense-FFN prefix when moe is set
    d_ff_dense: int = 0                # hidden dim of that prefix (0 -> d_ff)
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    sub_quadratic: bool = False        # True iff long-context decode is runnable

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def n_scan_layers(self) -> int:
        return self.n_layers - (self.first_dense_layers if self.moe else 0)

    def windows(self) -> jnp.ndarray:
        pat = self.window_pattern or (0,)
        w = [pat[i % len(pat)] for i in range(self.n_layers)]
        return jnp.asarray(w, dtype=jnp.int32)

    def replace(self, **kw) -> "TransformerConfig":
        return dataclasses.replace(self, **kw)


# ----------------------------------------------------------------- params

def _init_layer(key, cfg: TransformerConfig, moe_layer: bool, dtype):
    ks = jax.random.split(key, 6)
    if cfg.attention == "mla":
        attn = init_mla(ks[0], cfg.d_model, cfg.n_heads, cfg.mla, dtype)
    else:
        attn = init_gqa(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head, dtype)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "attn": attn,
    }
    if moe_layer:
        p["moe"] = init_moe(ks[1], cfg.d_model, cfg.moe, dtype)
    else:
        d_ff = cfg.d_ff_dense or cfg.d_ff
        if cfg.ffn_act == "swiglu":
            p["ffn"] = {
                "w_gate": dense_init(ks[2], cfg.d_model, d_ff, dtype),
                "w_up": dense_init(ks[3], cfg.d_model, d_ff, dtype),
                "w_down": dense_init(ks[4], d_ff, cfg.d_model, dtype),
            }
        else:
            p["ffn"] = {
                "w_in": dense_init(ks[2], cfg.d_model, d_ff, dtype),
                "w_down": dense_init(ks[4], d_ff, cfg.d_model, dtype),
            }
    return p


def init_transformer(key, cfg: TransformerConfig) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    n_prefix = cfg.first_dense_layers if cfg.moe else 0
    ks = jax.random.split(key, 3 + n_prefix)
    stacked = jax.vmap(
        lambda k: _init_layer(k, cfg, moe_layer=cfg.moe is not None, dtype=dtype)
    )(jax.random.split(ks[0], cfg.n_scan_layers))
    params = {
        "embed": embed_init(ks[1], cfg.vocab, cfg.d_model, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "layers": stacked,
        "prefix": [
            _init_layer(ks[3 + i], cfg, moe_layer=False, dtype=dtype)
            for i in range(n_prefix)
        ],
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(ks[2], cfg.vocab, cfg.d_model, dtype)
    return params


def abstract_params(cfg: TransformerConfig) -> dict:
    """ShapeDtypeStruct pytree for the dry-run (no allocation)."""
    return jax.eval_shape(lambda: init_transformer(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------- forward

def _ffn_apply(p: dict, x: jax.Array, cfg: TransformerConfig):
    dt = x.dtype
    if "w_gate" in p:
        h = jax.nn.silu(x @ p["w_gate"].astype(dt)) * (x @ p["w_up"].astype(dt))
    else:
        h = jax.nn.gelu(x @ p["w_in"].astype(dt))
    return h @ p["w_down"].astype(dt)


def _layer_apply(
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    window,
    cfg: TransformerConfig,
    moe_layer: bool,
    mesh=None,
    batch_axes=("data",),
    cache=None,
    cache_index=None,
    shard_fn=None,
):
    sc = shard_fn or (lambda a, kind: a)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.attention == "mla":
        attn_out, new_cache = mla_attention(
            p["attn"], h, positions, cfg.n_heads, cfg.mla, cfg.rope_theta,
            window=window, cache=cache, cache_index=cache_index, shard_fn=shard_fn,
        )
    else:
        attn_out, new_cache = gqa_attention(
            p["attn"], h, positions, cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
            cfg.rope_theta, window=window, cache=cache, cache_index=cache_index,
            shard_fn=shard_fn,
        )
    x = sc(x + attn_out, "residual")
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if moe_layer:
        B, S, D = h.shape
        y, aux = moe_ffn(
            p["moe"], h.reshape(B * S, D), cfg.moe, mesh=mesh, batch_axes=batch_axes,
        )
        y = y.reshape(B, S, D)
    else:
        y, aux = _ffn_apply(p["ffn"], h, cfg), jnp.float32(0.0)
    x = sc(x + y, "residual")
    return x, new_cache, aux


def forward(
    params: dict,
    tokens: jax.Array,          # (B, S) int32
    cfg: TransformerConfig,
    mesh=None,
    batch_axes=("data",),
    caches: dict | None = None,     # stacked per-layer caches for decode
    cache_index: jax.Array | None = None,
    shard_fn=None,
):
    """Returns (logits, new_caches, aux_loss).  ``caches`` is a pytree with
    leading layer axes: {'prefix': [...], 'layers': stacked (n_scan, ...)}."""
    dt = cfg.act_dtype
    sc = shard_fn or (lambda a, kind: a)
    B, S = tokens.shape
    x = sc(params["embed"].astype(dt)[tokens], "residual")
    if cache_index is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    else:
        positions = cache_index + jnp.arange(S, dtype=jnp.int32)

    windows = cfg.windows()
    n_prefix = cfg.first_dense_layers if cfg.moe else 0
    aux_total = jnp.float32(0.0)

    new_prefix_caches = []
    for i in range(n_prefix):
        c = caches["prefix"][i] if caches is not None else None
        x, nc, aux = _layer_apply(
            params["prefix"][i], x, positions, windows[i], cfg, moe_layer=False,
            mesh=mesh, batch_axes=batch_axes, cache=c, cache_index=cache_index,
            shard_fn=shard_fn,
        )
        aux_total = aux_total + aux
        new_prefix_caches.append(nc)

    def body(carry, xs):
        x, aux_acc = carry
        layer_params, window, cache = xs
        x, new_cache, aux = _layer_apply(
            layer_params, x, positions, window, cfg,
            moe_layer=cfg.moe is not None, mesh=mesh, batch_axes=batch_axes,
            cache=cache, cache_index=cache_index, shard_fn=shard_fn,
        )
        return (x, aux_acc + aux), new_cache

    body_fn = jax.checkpoint(body) if (cfg.remat and caches is None) else body
    scan_caches = caches["layers"] if caches is not None else None
    (x, aux_total), new_layer_caches = jax.lax.scan(
        body_fn, (x, aux_total),
        (params["layers"], windows[n_prefix:], scan_caches),
    )

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    unembed = params.get("unembed", params["embed"])
    logits = sc(x @ unembed.astype(dt).T, "logits")
    new_caches = None
    if caches is not None:
        new_caches = {"prefix": new_prefix_caches, "layers": new_layer_caches}
    return logits, new_caches, aux_total


# ------------------------------------------------------------ entrypoints

def lm_loss(params, tokens, cfg: TransformerConfig, mesh=None, batch_axes=("data",), shard_fn=None):
    """Next-token cross entropy (+ MoE aux)."""
    logits, _, aux = forward(
        params, tokens[:, :-1], cfg, mesh=mesh, batch_axes=batch_axes, shard_fn=shard_fn
    )
    loss = cross_entropy_loss(logits, tokens[:, 1:])
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_loss_weight * aux / max(cfg.n_scan_layers, 1)
    return loss


def init_cache(cfg: TransformerConfig, batch: int, max_len: int, window_cap: bool = True):
    """Decode caches.  Sliding-window layers cap their cache at the window
    size + 1... conservatively we keep full length for correctness of the
    oracle; the windowed-cache variant is a §Perf memory optimization
    applied in the serving configs (see configs/gemma3_12b.py)."""
    dt = jnp.dtype(cfg.dtype)
    n_prefix = cfg.first_dense_layers if cfg.moe else 0

    def one(length):
        if cfg.attention == "mla":
            return {
                "ckv": jnp.zeros((batch, length, cfg.mla.kv_lora), dt),
                "kr": jnp.zeros((batch, length, cfg.mla.d_rope), dt),
            }
        return {
            "k": jnp.zeros((batch, length, cfg.n_kv_heads, cfg.d_head), dt),
            "v": jnp.zeros((batch, length, cfg.n_kv_heads, cfg.d_head), dt),
        }

    prefix = [one(max_len) for _ in range(n_prefix)]
    stacked = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_scan_layers,) + a.shape),
        one(max_len),
    )
    return {"prefix": prefix, "layers": stacked}


def prefill(params, tokens, cfg, caches, mesh=None, batch_axes=("data",), shard_fn=None):
    """Run the prompt through the stack, filling caches; returns last-token
    logits + caches (inference-prefill shape cells)."""
    logits, caches, _ = forward(
        params, tokens, cfg, mesh=mesh, batch_axes=batch_axes,
        caches=caches, cache_index=jnp.int32(0), shard_fn=shard_fn,
    )
    return logits[:, -1], caches


def decode_step(params, token, cfg, caches, cache_index, mesh=None, batch_axes=("data",), shard_fn=None):
    """One new token against an existing KV cache (serve_step)."""
    logits, caches, _ = forward(
        params, token, cfg, mesh=mesh, batch_axes=batch_axes,
        caches=caches, cache_index=cache_index, shard_fn=shard_fn,
    )
    return logits[:, -1], caches
