"""DLRM (MLPerf config, Criteo 1TB) [arXiv:1906.00091].

bottom-MLP(13 dense) -> 26 embedding-bag lookups (HyTM row engines,
models/embedding.py) -> pairwise-dot feature interaction -> top-MLP.

The embedding lookup is the hot path; tables are row-sharded across the
mesh (dist/sharding.py) and the per-table engine choice is the HyTM cost
model over batch index statistics.  ``retrieval_score`` covers the
`retrieval_cand` shape cell: one query against 10^6 candidates as one
blocked matmul (not a loop).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.common import mlp_apply, mlp_init
from repro.models.embedding import embedding_bag

# MLPerf DLRM vocab sizes (Criteo Terabyte, day-sampled), 26 sparse fields.
MLPERF_VOCAB_SIZES = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)


@dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-mlperf"
    n_dense: int = 13
    vocab_sizes: tuple = MLPERF_VOCAB_SIZES
    embed_dim: int = 128
    bot_mlp: tuple = (512, 256, 128)
    top_mlp: tuple = (1024, 1024, 512, 256, 1)
    multi_hot: int = 1            # lookups per field
    interaction: str = "dot"
    table_engine: str = "auto"
    dtype: str = "float32"

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)

    @property
    def n_interact_features(self) -> int:
        f = self.n_sparse + 1
        return f * (f - 1) // 2

    def replace(self, **kw):
        import dataclasses
        return dataclasses.replace(self, **kw)


def init_dlrm(key, cfg: DLRMConfig) -> dict:
    ks = jax.random.split(key, cfg.n_sparse + 2)
    tables = [
        (jax.random.normal(ks[i], (v, cfg.embed_dim), jnp.float32)
         / jnp.sqrt(jnp.float32(cfg.embed_dim)))
        for i, v in enumerate(cfg.vocab_sizes)
    ]
    return {
        "tables": tables,
        "bot": mlp_init(ks[-2], [cfg.n_dense, *cfg.bot_mlp]),
        "top": mlp_init(ks[-1], [cfg.embed_dim + cfg.n_interact_features, *cfg.top_mlp]),
    }


def abstract_dlrm_params(cfg: DLRMConfig) -> dict:
    return jax.eval_shape(lambda: init_dlrm(jax.random.PRNGKey(0), cfg))


def _dot_interaction(z: jax.Array) -> jax.Array:
    """z: (B, F, D) -> upper-triangle pairwise dots (B, F*(F-1)/2)."""
    B, F, D = z.shape
    zz = jnp.einsum("bfd,bgd->bfg", z, z)
    iu, ju = jnp.triu_indices(F, k=1)
    return zz[:, iu, ju]


def dlrm_forward(params: dict, dense: jax.Array, sparse: jax.Array, cfg: DLRMConfig) -> jax.Array:
    """dense: (B, 13) f32; sparse: (B, 26) or (B, 26, L) int32 -> (B,) logits."""
    if sparse.ndim == 2:
        sparse = sparse[..., None]
    x0 = mlp_apply(params["bot"], dense, act=jax.nn.relu, final_act=jax.nn.relu)
    embs = [
        embedding_bag(params["tables"][i], sparse[:, i], mode="sum", engine=cfg.table_engine)
        for i in range(cfg.n_sparse)
    ]
    z = jnp.stack([x0] + embs, axis=1)  # (B, 27, D)
    tri = _dot_interaction(z)
    top_in = jnp.concatenate([x0, tri], axis=-1)
    return mlp_apply(params["top"], top_in)[:, 0]


def dlrm_loss(params, dense, sparse, labels, cfg: DLRMConfig) -> jax.Array:
    logits = dlrm_forward(params, dense, sparse, cfg).astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0.0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def retrieval_score(params, dense_query: jax.Array, cand_embs: jax.Array, top_k: int = 100):
    """`retrieval_cand` cell: query tower -> blocked dot against (N, D)
    candidate embeddings -> top-k.  One matmul, N = 10^6."""
    q = mlp_apply(params["bot"], dense_query, act=jax.nn.relu, final_act=jax.nn.relu)  # (B, D)
    scores = q @ cand_embs.T  # (B, N)
    return jax.lax.top_k(scores, top_k)
