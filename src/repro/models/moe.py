"""Mixture-of-Experts FFN with HyTM-style dispatch engines.

Beyond-paper mapping of HyTGraph's insight (DESIGN.md §4): token->expert
routing is an active-subset transfer problem — experts are partitions,
routed tokens the active set.  Three dispatch engines mirror the paper's
three transfer engines:

* ``dense``  (≙ ExpTM-filter): every expert processes every token, the
  top-k combine mask discards the redundant work.  No dispatch machinery
  at all; wins only when E is tiny or nearly all (token, expert) pairs
  are live — exactly the paper's high-activeness regime.
* ``sorted`` (≙ ExpTM-compaction): tokens argsorted by expert id into
  dense contiguous groups, processed as capacity-padded chunks (grouped
  GEMM), then unsorted.  Extra compaction pass (the sort), minimal
  redundant compute.
* ``gather`` (≙ ImpTM-zero-copy): tokens scattered straight into per-
  expert capacity buffers via cumulative-rank slots — fine-grained
  random access, no sort pass.

Distributed (EP) execution shard_maps over the ``data`` axis: the
dispatch buffer is exchanged with ``all_to_all`` (compacted frontier
exchange — the two-level HyTM of DESIGN.md §2), expert FFNs are
tensor-parallel over ``model`` with one psum.

Engine selection: ``dispatch='auto'`` resolves at trace time from config
shape statistics (E, top_k, expected load) via ``select_dispatch_engine``;
runtime per-batch selection is available in the eager path.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.models.common import dense_init, swiglu


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                  # per-expert hidden dim
    n_shared: int = 0
    d_ff_shared: int = 0       # defaults to n_shared * d_ff
    capacity_factor: float = 1.25
    dispatch: str = "auto"     # 'dense' | 'sorted' | 'gather' | 'auto'
    chunk_tokens: int = 0      # >0: process tokens in chunks (memory bound)
    aux_loss_weight: float = 0.001

    @property
    def shared_hidden(self) -> int:
        return self.d_ff_shared or self.n_shared * self.d_ff

    def replace(self, **kw) -> "MoEConfig":
        import dataclasses
        return dataclasses.replace(self, **kw)


def select_dispatch_engine(cfg: MoEConfig, n_tokens: int) -> str:
    """Trace-time engine choice (HyTM cost model, §4 of DESIGN.md).

    dense cost   ~ E * T * D * F            (all pairs)
    sorted cost  ~ T*K * D * F + sort(T*K)  (compaction pass)
    gather cost  ~ T*K * D * F + T*E slots  (fine-grained scatter)
    dense wins iff E is within ~2x of top_k (nearly-all-active regime);
    gather beats sorted when the slot matrix T*E is cheaper than the sort
    — i.e. for small E.  Mirrors Algorithm 1's tier structure.
    """
    if cfg.dispatch != "auto":
        return cfg.dispatch
    if cfg.n_experts <= 2 * cfg.top_k:
        return "dense"
    if cfg.n_experts <= 32:
        return "gather"
    return "sorted"


def init_moe(key: jax.Array, d_model: int, cfg: MoEConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 7)
    E, F = cfg.n_experts, cfg.d_ff
    p = {
        "router": dense_init(ks[0], d_model, E, jnp.float32),
        "w_gate": jax.random.normal(ks[1], (E, d_model, F), jnp.float32).astype(dtype) / (d_model ** 0.5),
        "w_up": jax.random.normal(ks[2], (E, d_model, F), jnp.float32).astype(dtype) / (d_model ** 0.5),
        "w_down": jax.random.normal(ks[3], (E, F, d_model), jnp.float32).astype(dtype) / (F ** 0.5),
    }
    if cfg.n_shared > 0:
        Fs = cfg.shared_hidden
        p["shared_gate"] = dense_init(ks[4], d_model, Fs, dtype)
        p["shared_up"] = dense_init(ks[5], d_model, Fs, dtype)
        p["shared_down"] = dense_init(ks[6], Fs, d_model, dtype)
    return p


def _route(x: jax.Array, router: jax.Array, cfg: MoEConfig):
    """fp32 router -> normalized top-k weights + aux load-balance loss."""
    logits = x.astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_ids = jax.lax.top_k(probs, cfg.top_k)
    topk_w = topk_w / jnp.maximum(jnp.sum(topk_w, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux: E * mean_e(frac_tokens_e * mean_prob_e)
    E = cfg.n_experts
    counts = jnp.zeros(E).at[topk_ids.reshape(-1)].add(1.0)
    frac_tokens = counts / jnp.maximum(jnp.sum(counts), 1.0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * mean_prob)
    return topk_ids.astype(jnp.int32), topk_w.astype(x.dtype), aux


def _expert_ffn(params: dict, xb: jax.Array) -> jax.Array:
    """xb: (E_local, C, D) -> (E_local, C, D_partial) (TP-partial if sharded)."""
    dt = xb.dtype
    h = swiglu(
        jnp.einsum("ecd,edf->ecf", xb, params["w_gate"].astype(dt)),
        jnp.einsum("ecd,edf->ecf", xb, params["w_up"].astype(dt)),
    )
    return jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(dt))


def _capacity(n_assign: int, n_experts: int, cf: float) -> int:
    c = max(int(n_assign / max(n_experts, 1) * cf), 8)
    return -(-c // 8) * 8


# --------------------------------------------------------------- engines

def _slots_gather(flat_e: jax.Array, E: int, C: int):
    """Zero-copy analogue: per-expert slot via cumulative one-hot rank —
    fine-grained, no sort pass."""
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    ranks = jnp.cumsum(onehot, axis=0) - onehot
    slot = jnp.take_along_axis(ranks, flat_e[:, None], axis=1)[:, 0]
    keep = slot < C
    return slot, keep


def _slots_sorted(flat_e: jax.Array, E: int, C: int):
    """Compaction analogue: argsort by expert id (the compaction pass),
    slot = rank within the sorted run."""
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # position within expert group = index - start_of_group
    counts = jnp.zeros(E, dtype=jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(flat_e.shape[0], dtype=jnp.int32) - starts[sorted_e]
    slot = jnp.zeros_like(flat_e).at[order].set(pos)
    keep = slot < C
    return slot, keep


def _moe_core(
    x: jax.Array,            # (T_local, D)
    params: dict,            # local shards when inside shard_map
    cfg: MoEConfig,
    engine: str,
    data_axis: str | None = None,
    model_axis: str | None = None,
):
    """One MoE FFN application. Works standalone (axes None) or inside a
    shard_map region (EP over data_axis, TP over model_axis).

    ``chunk_tokens`` bounds the dispatch-buffer memory: local tokens are
    padded to a chunk multiple and processed under ``lax.map`` — each
    chunk's all_to_all is small, and XLA overlaps chunk k's collective
    with chunk k+1's dispatch compute (multi-stream philosophy)."""
    if cfg.chunk_tokens and x.shape[0] > cfg.chunk_tokens:
        T0, D = x.shape
        c = cfg.chunk_tokens
        n_chunks = -(-T0 // c)
        pad = n_chunks * c - T0
        xp = jnp.pad(x, ((0, pad), (0, 0))).reshape(n_chunks, c, D)
        inner_cfg = cfg.replace(chunk_tokens=0)
        ys, auxs = jax.lax.map(
            lambda xc: _moe_core(xc, params, inner_cfg, engine, data_axis, model_axis),
            xp,
        )
        return ys.reshape(n_chunks * c, D)[:T0], jnp.mean(auxs)
    T, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    topk_ids, topk_w, aux = _route(x, params["router"], cfg)

    if engine == "dense":
        assert data_axis is None, "dense engine is single-shard (filter analogue)"
        # every expert processes every token (redundant), mask-combine.
        def per_expert(carry, e):
            w_g = params["w_gate"][e]
            w_u = params["w_up"][e]
            w_d = params["w_down"][e]
            h = swiglu(x @ w_g.astype(x.dtype), x @ w_u.astype(x.dtype))
            y_e = h @ w_d.astype(x.dtype)
            gate = jnp.sum(
                jnp.where(topk_ids == e, topk_w, 0.0), axis=-1, keepdims=True
            )
            return carry + y_e * gate, None

        y, _ = jax.lax.scan(per_expert, jnp.zeros_like(x), jnp.arange(E))
    else:
        flat_e = topk_ids.reshape(-1)                       # (T*K,)
        tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)  # (T*K,)
        C = _capacity(T * K, E, cfg.capacity_factor)
        slot, keep = (_slots_sorted if engine == "sorted" else _slots_gather)(flat_e, E, C)

        buf = jnp.zeros((E, C, D), dtype=x.dtype)
        buf = buf.at[flat_e, jnp.where(keep, slot, C - 1)].add(
            jnp.where(keep[:, None], x[tok], 0.0)
        )

        if data_axis is not None:
            # (E, C, D) -> each device keeps its E/n experts, gathering the
            # slices every peer built for them (compacted frontier exchange).
            buf = jax.lax.all_to_all(buf, data_axis, split_axis=0, concat_axis=1, tiled=True)

        y_buf = _expert_ffn(params, buf)

        if cfg.n_shared > 0:
            shared = swiglu(
                x @ params["shared_gate"].astype(x.dtype),
                x @ params["shared_up"].astype(x.dtype),
            ) @ params["shared_down"].astype(x.dtype)
        else:
            shared = None

        if model_axis is not None:
            # single fused reduction for routed (+ shared) TP partials
            if shared is not None:
                y_buf, shared = jax.lax.psum((y_buf, shared), model_axis)
            else:
                y_buf = jax.lax.psum(y_buf, model_axis)

        if data_axis is not None:
            y_buf = jax.lax.all_to_all(y_buf, data_axis, split_axis=1, concat_axis=0, tiled=True)

        gathered = y_buf[flat_e, jnp.where(keep, slot, C - 1)]
        contrib = jnp.where(keep[:, None], gathered, 0.0) * topk_w.reshape(-1)[:, None]
        y = jnp.zeros_like(x).at[tok].add(contrib)
        if shared is not None:
            y = y + shared
        return y, aux

    # dense path: shared experts + no collectives
    if cfg.n_shared > 0:
        y = y + swiglu(
            x @ params["shared_gate"].astype(x.dtype),
            x @ params["shared_up"].astype(x.dtype),
        ) @ params["shared_down"].astype(x.dtype)
    return y, aux


def moe_ffn(
    params: dict,
    x: jax.Array,             # (T, D) flattened tokens
    cfg: MoEConfig,
    mesh: jax.sharding.Mesh | None = None,
    batch_axes: tuple[str, ...] = ("pod", "data"),
    expert_axis: str | tuple | None = None,
    tp_axis: str = "model",
) -> tuple[jax.Array, jax.Array]:
    """Apply the MoE FFN, optionally distributed via shard_map (EP + TP).

    Experts shard over ALL batch axes by default (('pod','data') on the
    multi-pod mesh): a trillion-param expert bank must not be replicated
    per pod — EP width == DP width keeps the a2a local-per-token while
    fully sharding expert weights (DESIGN.md §5)."""
    if expert_axis is None:
        expert_axis = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    engine = select_dispatch_engine(cfg, x.shape[0])

    def run(xc):
        if mesh is None:
            return _moe_core(xc, params, cfg, engine)
        all_axes = tuple(mesh.axis_names)

        def core(xl, pl):
            y, aux = _moe_core(xl, pl, cfg, engine,
                               data_axis=expert_axis, model_axis=tp_axis)
            return y, jnp.reshape(aux, (1,))

        pspec = {
            "router": P(),
            "w_gate": P(expert_axis, None, tp_axis),
            "w_up": P(expert_axis, None, tp_axis),
            "w_down": P(expert_axis, tp_axis, None),
        }
        if cfg.n_shared > 0:
            pspec.update({
                "shared_gate": P(None, tp_axis),
                "shared_up": P(None, tp_axis),
                "shared_down": P(tp_axis, None),
            })
        fn = shard_map(
            core,
            mesh=mesh,
            in_specs=(P(batch_axes, None), pspec),
            out_specs=(P(batch_axes, None), P(all_axes)),
            check_rep=False,
        )
        y, aux = fn(xc, params)
        return y, jnp.mean(aux)

    return run(x)
