"""Resilience plane: fault injection, checkpoint/recovery, supervision.

Exactness-under-faults contract: under any seeded
:class:`~repro.resilience.faults.FaultPlan`, every request that
completes returns answers bit-identical to the fault-free run for
MIN-combine programs (tolerance-bounded for SUM), quota and device-byte
budgets still hold, and recovery cost is bounded and observable (obs
``faults`` track + ``faults.*`` counters).  With ``faults=None`` every
hook is zero-overhead — bit-identical to a build without this package.
Gate: ``benchmarks/chaos_bench.py --selfcheck``.
"""

from repro.resilience.checkpoint import (
    CheckpointError,
    CheckpointHook,
    RunCheckpoint,
    calibrator_state,
    load_reports,
    migrate_state_layout,
    restore,
    restore_calibrator,
    resume_run,
    save,
    save_reports,
    stitch,
)
from repro.resilience.faults import (
    DeviceOOM,
    DispatchFault,
    DispatchTimeout,
    FaultError,
    FaultEvent,
    FaultPlan,
    FaultSpec,
    UpdateLost,
    plan_of,
)
from repro.resilience.supervisor import (
    RetriesExhausted,
    RetryPolicy,
    Supervisor,
    deliver_update,
    guarded_dispatch,
    next_rung,
    record_fault_event,
    run_supervised,
)

__all__ = [
    "CheckpointError", "CheckpointHook", "RunCheckpoint",
    "calibrator_state", "load_reports", "migrate_state_layout",
    "restore", "restore_calibrator",
    "resume_run", "save", "save_reports", "stitch",
    "DeviceOOM", "DispatchFault", "DispatchTimeout", "FaultError",
    "FaultEvent", "FaultPlan", "FaultSpec", "UpdateLost", "plan_of",
    "RetriesExhausted", "RetryPolicy", "Supervisor", "deliver_update",
    "guarded_dispatch", "next_rung", "record_fault_event",
    "run_supervised",
]
