"""Versioned checkpoint/restore for chunked HyTM runs.

A checkpoint captures everything a killed run needs to resume from its
last chunk boundary **bit-identically** for MIN programs: the
``HyTMState`` arrays, the drained history rows up to that boundary, the
iteration cursor, the :class:`~repro.autotune.feedback.OnlineCalibrator`
normal equations, and the graph anchor ``(graph_version,
layout_version)`` the state was computed against.  A second codec
(:func:`save_reports`/:func:`load_reports`) persists the DeltaCSR
version/report log so a restarted serving process can resume
incremental replay from the same anchor.

Format: a single ``.npz`` written atomically (tmp + ``os.replace``).
Metadata travels as a JSON blob embedded as a ``uint8`` array under
``__meta__`` and carries a per-array ``crc32`` table; :func:`restore`
re-verifies every checksum (and ``zipfile`` independently verifies
entry CRCs on read), so any byte flip surfaces as a typed
:class:`CheckpointError` rather than silently corrupt state.

Resume contract (what "bit-identical" requires):

* the kill happens at a chunk boundary strictly before convergence —
  :class:`CheckpointHook` only ever writes at boundaries, so this holds
  by construction when the dispatch itself failed;
* MIN combine (values are a fixpoint of improvements; SUM resumes are
  tolerance-bounded because delta draining is order-sensitive);
* autotune off, or the calibrator restored via the checkpoint — with a
  warm jit cache the resumed process re-compiles, so the warm-signature
  skip schedule matches only when the calibrator state travels too.
"""

from __future__ import annotations

import dataclasses
import json
import os
import zlib
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

# v2 adds the vertex-state layout fields (state_layout, n_nodes) for
# owner-sharded runs; v1 checkpoints still load (implicitly replicated)
SCHEMA_VERSION = 2
_SUPPORTED_SCHEMAS = (1, 2)
_META_KEY = "__meta__"


class CheckpointError(RuntimeError):
    """Raised when a checkpoint is missing, corrupt, or mismatched."""


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def calibrator_state(calib) -> dict | None:
    """Serialize an ``OnlineCalibrator`` (or ``None``) to plain JSON."""
    if calib is None:
        return None
    return {
        "decay": float(calib.decay),
        "ridge": float(calib.ridge),
        "clip": [float(c) for c in calib.clip],
        "n_updates": int(calib.n_updates),
        "A": np.asarray(calib._A, dtype=float).tolist(),
        "b": np.asarray(calib._b, dtype=float).tolist(),
    }


def restore_calibrator(state: dict | None):
    """Rebuild an ``OnlineCalibrator`` from :func:`calibrator_state`."""
    if state is None:
        return None
    from repro.autotune.feedback import OnlineCalibrator

    calib = OnlineCalibrator(decay=state["decay"], ridge=state["ridge"],
                             clip=tuple(state["clip"]))
    calib._A = np.asarray(state["A"], dtype=float)
    calib._b = np.asarray(state["b"], dtype=float)
    calib.n_updates = int(state["n_updates"])
    return calib


@dataclass
class RunCheckpoint:
    """One resumable chunk-boundary snapshot of a ``run_hytm`` call."""

    program: str
    iterations: int
    graph_version: int = 0
    layout_version: int = 0
    values: np.ndarray | None = None
    delta: np.ndarray | None = None
    frontier: np.ndarray | None = None
    history: dict[str, np.ndarray] = field(default_factory=dict)
    calibrator: dict | None = None
    # vertex-state layout the snapshot was taken under ("replicated" |
    # "owner").  Owner snapshots hold the gathered (n_pad,) arrays;
    # n_nodes records the real vertex count so resume/migration can
    # slice the ghost pads off.  v1 checkpoints restore as
    # ("replicated", 0).
    state_layout: str = "replicated"
    n_nodes: int = 0

    @property
    def anchor(self) -> tuple[int, int]:
        return (self.graph_version, self.layout_version)


def save(ckpt: RunCheckpoint, path: str | os.PathLike) -> Path:
    """Atomically write ``ckpt`` to ``path`` (single ``.npz``).

    The write goes to a sibling tmp file first and is published with
    ``os.replace``, so a crash mid-save leaves the previous checkpoint
    intact — the invariant recovery depends on."""
    path = Path(path)
    arrays: dict[str, np.ndarray] = {}
    for name in ("values", "delta", "frontier"):
        arr = getattr(ckpt, name)
        if arr is not None:
            arrays[name] = np.asarray(arr)
    for key, arr in ckpt.history.items():
        arrays[f"hist::{key}"] = np.asarray(arr)
    meta = {
        "schema": SCHEMA_VERSION,
        "program": ckpt.program,
        "iterations": int(ckpt.iterations),
        "graph_version": int(ckpt.graph_version),
        "layout_version": int(ckpt.layout_version),
        "state_layout": ckpt.state_layout,
        "n_nodes": int(ckpt.n_nodes),
        "calibrator": ckpt.calibrator,
        "crc": {k: _crc(v) for k, v in arrays.items()},
    }
    arrays[_META_KEY] = np.frombuffer(
        json.dumps(meta).encode(), dtype=np.uint8)
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)
    return path


def restore(path: str | os.PathLike,
            expect_anchor: tuple[int, int] | None = None,
            program: str | None = None) -> RunCheckpoint:
    """Load and verify a checkpoint written by :func:`save`.

    Every failure mode — missing file, truncated/bit-flipped zip
    payload, schema drift, checksum mismatch, anchor or program
    mismatch — raises :class:`CheckpointError` so callers have exactly
    one thing to catch before falling back to a cold start."""
    path = Path(path)
    try:
        with np.load(path) as z:
            arrays = {k: z[k] for k in z.files}
    except FileNotFoundError as e:
        raise CheckpointError(f"checkpoint missing: {path}") from e
    except Exception as e:  # BadZipFile, zlib.error, ValueError, OSError
        raise CheckpointError(f"checkpoint unreadable: {path}: {e}") from e
    blob = arrays.pop(_META_KEY, None)
    if blob is None:
        raise CheckpointError(f"checkpoint has no metadata: {path}")
    try:
        meta = json.loads(bytes(blob.tobytes()).decode())
    except (ValueError, UnicodeDecodeError) as e:
        raise CheckpointError(f"checkpoint metadata corrupt: {path}") from e
    if meta.get("schema") not in _SUPPORTED_SCHEMAS:
        raise CheckpointError(
            f"checkpoint schema {meta.get('schema')!r} not in "
            f"{_SUPPORTED_SCHEMAS}")
    for k, want in meta.get("crc", {}).items():
        if k not in arrays:
            raise CheckpointError(f"checkpoint array missing: {k}")
        got = _crc(arrays[k])
        if got != want:
            raise CheckpointError(
                f"checkpoint checksum mismatch on {k}: {got} != {want}")
    if program is not None and meta["program"] != program:
        raise CheckpointError(
            f"checkpoint is for program {meta['program']!r}, not "
            f"{program!r}")
    ckpt = RunCheckpoint(
        program=meta["program"],
        iterations=int(meta["iterations"]),
        graph_version=int(meta["graph_version"]),
        layout_version=int(meta["layout_version"]),
        values=arrays.get("values"),
        delta=arrays.get("delta"),
        frontier=arrays.get("frontier"),
        history={k[len("hist::"):]: v for k, v in arrays.items()
                 if k.startswith("hist::")},
        calibrator=meta.get("calibrator"),
        state_layout=meta.get("state_layout", "replicated"),
        n_nodes=int(meta.get("n_nodes", 0)),
    )
    if expect_anchor is not None and ckpt.anchor != tuple(expect_anchor):
        raise CheckpointError(
            f"checkpoint anchored at {ckpt.anchor}, run expects "
            f"{tuple(expect_anchor)} — graph/layout changed underneath")
    return ckpt


class CheckpointHook:
    """``on_chunk`` consumer for ``run_hytm(..., on_chunk=hook)``.

    Called at every chunk boundary with the live (still on-device)
    state; snapshots it to host *before* the next dispatch donates the
    buffers, and persists every ``every``-th boundary via :func:`save`.
    """

    def __init__(self, path: str | os.PathLike, *, program: str = "",
                 anchor: tuple[int, int] = (0, 0), every: int = 1,
                 base_iterations: int = 0,
                 state_layout: str = "replicated", n_nodes: int = 0):
        self.path = Path(path)
        self.program = program
        self.anchor = (int(anchor[0]), int(anchor[1]))
        self.every = max(int(every), 1)
        self.base_iterations = int(base_iterations)
        # owner-sharded runs pass state_layout="owner" + the real vertex
        # count: np.asarray gathers the (n_pad,) sharded arrays, and the
        # snapshot records both so restore can slice the pads off
        self.state_layout = state_layout
        self.n_nodes = int(n_nodes)
        self.n_chunks = 0
        self.saved = 0

    def __call__(self, *, state, iterations: int, rows: dict,
                 calibrator=None, last_active: int | None = None) -> None:
        self.n_chunks += 1
        if self.n_chunks % self.every:
            return
        ckpt = RunCheckpoint(
            program=self.program,
            iterations=self.base_iterations + int(iterations),
            graph_version=self.anchor[0],
            layout_version=self.anchor[1],
            values=np.asarray(state.values),
            delta=np.asarray(state.delta),
            frontier=np.asarray(state.frontier),
            history={k: (np.concatenate(v) if v else np.zeros((0,)))
                     for k, v in rows.items()},
            calibrator=calibrator_state(calibrator),
            state_layout=self.state_layout,
            n_nodes=self.n_nodes,
        )
        save(ckpt, self.path)
        self.saved += 1


def migrate_state_layout(ckpt: RunCheckpoint, to_layout: str, *,
                         n_devices: int = 1,
                         program=None) -> RunCheckpoint:
    """Convert a checkpoint's vertex-state arrays between layouts.

    ``owner -> replicated`` slices the gathered ``(n_pad,)`` arrays down
    to the recorded ``n_nodes``; ``replicated -> owner`` pads them with
    the program's inert fills (``graph_shard.owner_state_pad_values``)
    up to ``ceil(n/D)*D`` for ``n_devices``.  The real-vertex bytes are
    untouched either way, so migrate -> resume stays bit-identical to a
    same-layout resume.  ``program`` (a ``VertexProgram``) is needed for
    ``-> owner`` to pick the fills; omitted, it is looked up by the
    checkpoint's program name in ``repro.graph.algorithms.ALGORITHMS``.
    """
    if to_layout not in ("replicated", "owner"):
        raise ValueError(f"unknown state layout {to_layout!r}")
    if ckpt.state_layout == to_layout:
        return ckpt
    if ckpt.values is None:
        raise CheckpointError("checkpoint holds no state arrays to migrate")
    if to_layout == "replicated":
        if not ckpt.n_nodes:
            raise CheckpointError(
                "owner-layout checkpoint lacks n_nodes; cannot slice pads")
        n = ckpt.n_nodes
        return dataclasses.replace(
            ckpt, values=ckpt.values[:n], delta=ckpt.delta[:n],
            frontier=ckpt.frontier[:n], state_layout="replicated",
            n_nodes=n)
    from repro.dist.graph_shard import owner_state_pad_values

    if program is None:
        from repro.graph.algorithms import ALGORITHMS

        program = ALGORITHMS.get(ckpt.program)
        if program is None:
            raise CheckpointError(
                f"cannot infer pad fills for unknown program "
                f"{ckpt.program!r}; pass program= explicitly")
    n = ckpt.values.shape[0]
    n_pad = -(-n // max(int(n_devices), 1)) * max(int(n_devices), 1)
    pad_v, pad_d = owner_state_pad_values(program)

    def _pad(arr, fill):
        extra = n_pad - arr.shape[0]
        if extra <= 0:
            return arr
        return np.concatenate(
            [arr, np.full(extra, fill, dtype=arr.dtype)])

    return dataclasses.replace(
        ckpt, values=_pad(ckpt.values, pad_v),
        delta=_pad(ckpt.delta, pad_d),
        frontier=_pad(ckpt.frontier, False),
        state_layout="owner", n_nodes=n)


def stitch(ckpt: RunCheckpoint, result):
    """Compose a resumed ``HyTMResult`` with its checkpoint prefix so
    the caller sees one run: history concatenated, iteration and
    transfer totals re-summed over the combined rows."""
    from repro.core.cost_model import (
        KEY_MISPREDICTIONS,
        KEY_TRANSFER_BYTES,
        KEY_TRANSFER_TIME,
    )

    history = {}
    for k, tail in result.history.items():
        head = ckpt.history.get(k)
        if head is None or head.size == 0:
            history[k] = tail
        elif tail.size == 0:
            history[k] = head
        else:
            history[k] = np.concatenate([head, tail])
    return dataclasses.replace(
        result,
        iterations=ckpt.iterations + result.iterations,
        history=history,
        modeled_seconds=float(np.sum(history[KEY_TRANSFER_TIME])),
        total_transfer_bytes=float(np.sum(history[KEY_TRANSFER_BYTES])),
        total_mispredictions=int(np.sum(history[KEY_MISPREDICTIONS])),
    )


def resume_run(path: str | os.PathLike, g, program, *, config, source=0,
               n_hubs: int = 0, runtime=None, mesh=None,
               expect_anchor: tuple[int, int] | None = None, obs=None,
               faults=None, retry=None, checkpoint=None):
    """Restore the checkpoint at ``path`` and continue the run.

    Re-enters ``run_hytm`` with the restored state, the restored
    calibrator, and the *remaining* iteration budget, then stitches the
    checkpoint prefix back on — for MIN programs without autotune the
    composed result is bit-identical (values, iterations, transfer
    bytes, engine picks) to the uninterrupted run, because the engine
    choice is a pure function of the state at each chunk boundary."""
    import jax.numpy as jnp

    from repro.core.hytm import HyTMState, run_hytm

    ckpt = restore(path, expect_anchor=expect_anchor, program=program.name)
    if config.sync_every < 2:
        raise ValueError("resume_run requires the chunked driver "
                         "(sync_every >= 2)")
    run_layout = getattr(config, "vertex_sharding", "replicated")
    if ckpt.state_layout != run_layout:
        raise CheckpointError(
            f"checkpoint state_layout={ckpt.state_layout!r} does not match "
            f"the run's vertex_sharding={run_layout!r}; convert it with "
            f"migrate_state_layout first")
    remaining = config.max_iters - ckpt.iterations
    if remaining <= 0:
        raise CheckpointError(
            f"checkpoint already holds {ckpt.iterations} iterations >= "
            f"max_iters={config.max_iters}")
    values, delta, frontier = ckpt.values, ckpt.delta, ckpt.frontier
    if ckpt.state_layout == "owner" and ckpt.n_nodes:
        # drop the gathered ghost pads: run_hytm_sharded re-pads and
        # owner-shards the triple for the *current* mesh, so a resume on
        # a different device count still lands bit-identically
        values = values[:ckpt.n_nodes]
        delta = delta[:ckpt.n_nodes]
        frontier = frontier[:ckpt.n_nodes]
    state = HyTMState(values=jnp.asarray(values),
                      delta=jnp.asarray(delta),
                      frontier=jnp.asarray(frontier))
    if checkpoint is not None:
        checkpoint.base_iterations = ckpt.iterations
    result = run_hytm(
        g, program, source=source,
        config=dataclasses.replace(config, max_iters=remaining),
        n_hubs=n_hubs, runtime=runtime, mesh=mesh, initial_state=state,
        calibrator=restore_calibrator(ckpt.calibrator), obs=obs,
        faults=faults, retry=retry, on_chunk=checkpoint)
    return stitch(ckpt, result)


# --- DeltaCSR report-log persistence -----------------------------------


def _pack_adj(adj: dict) -> dict[str, np.ndarray]:
    keys = np.asarray(sorted(adj), dtype=np.int64)
    offs = np.zeros(keys.size + 1, dtype=np.int64)
    dsts, ws = [], []
    for i, u in enumerate(keys):
        d, w = adj[int(u)]
        offs[i + 1] = offs[i] + len(d)
        dsts.append(np.asarray(d, dtype=np.int64))
        ws.append(np.asarray(w, dtype=np.float32))
    cat = (lambda xs, dt: np.concatenate(xs) if xs
           else np.zeros((0,), dtype=dt))
    return {"keys": keys, "offs": offs,
            "dst": cat(dsts, np.int64), "w": cat(ws, np.float32)}


def _unpack_adj(keys, offs, dst, w) -> dict:
    return {int(u): (dst[offs[i]:offs[i + 1]].copy(),
                     w[offs[i]:offs[i + 1]].copy())
            for i, u in enumerate(keys)}


def save_reports(reports, path: str | os.PathLike,
                 graph_version: int, layout_version: int) -> Path:
    """Persist a list of ``UpdateReport`` (the DeltaCSR version/report
    log) with the same anchor + checksum discipline as :func:`save`."""
    path = Path(path)
    arrays: dict[str, np.ndarray] = {}
    meta_rows = []
    for i, r in enumerate(reports):
        p = f"r{i}::"
        arrays[p + "dirty"] = np.asarray(r.dirty_partitions, dtype=np.int64)
        for nm in ("ins_src", "ins_dst", "del_src", "del_dst"):
            arrays[p + nm] = np.asarray(getattr(r, nm), dtype=np.int64)
        for nm in ("ins_w", "del_w"):
            arrays[p + nm] = np.asarray(getattr(r, nm), dtype=np.float32)
        for side in ("pre_adj", "post_adj"):
            for nm, arr in _pack_adj(getattr(r, side)).items():
                arrays[f"{p}{side}::{nm}"] = arr
        meta_rows.append({"version": int(r.version), "merged": bool(r.merged)})
    meta = {
        "schema": SCHEMA_VERSION,
        "graph_version": int(graph_version),
        "layout_version": int(layout_version),
        "reports": meta_rows,
        "crc": {k: _crc(v) for k, v in arrays.items()},
    }
    arrays[_META_KEY] = np.frombuffer(
        json.dumps(meta).encode(), dtype=np.uint8)
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)
    return path


def load_reports(path: str | os.PathLike,
                 expect_anchor: tuple[int, int] | None = None):
    """Restore :func:`save_reports` output: ``(reports, anchor)``."""
    from repro.stream.delta_csr import UpdateReport

    path = Path(path)
    try:
        with np.load(path) as z:
            arrays = {k: z[k] for k in z.files}
    except FileNotFoundError as e:
        raise CheckpointError(f"report log missing: {path}") from e
    except Exception as e:
        raise CheckpointError(f"report log unreadable: {path}: {e}") from e
    blob = arrays.pop(_META_KEY, None)
    if blob is None:
        raise CheckpointError(f"report log has no metadata: {path}")
    meta = json.loads(bytes(blob.tobytes()).decode())
    if meta.get("schema") not in _SUPPORTED_SCHEMAS:
        raise CheckpointError(
            f"report log schema {meta.get('schema')!r} not in "
            f"{_SUPPORTED_SCHEMAS}")
    for k, want in meta.get("crc", {}).items():
        if k not in arrays or _crc(arrays[k]) != want:
            raise CheckpointError(f"report log checksum mismatch on {k}")
    anchor = (int(meta["graph_version"]), int(meta["layout_version"]))
    if expect_anchor is not None and anchor != tuple(expect_anchor):
        raise CheckpointError(
            f"report log anchored at {anchor}, expected "
            f"{tuple(expect_anchor)}")
    reports = []
    for i, row in enumerate(meta["reports"]):
        p = f"r{i}::"
        adj = {}
        for side in ("pre_adj", "post_adj"):
            adj[side] = _unpack_adj(
                arrays[f"{p}{side}::keys"], arrays[f"{p}{side}::offs"],
                arrays[f"{p}{side}::dst"], arrays[f"{p}{side}::w"])
        reports.append(UpdateReport(
            version=row["version"],
            dirty_partitions=arrays[p + "dirty"],
            merged=row["merged"],
            ins_src=arrays[p + "ins_src"], ins_dst=arrays[p + "ins_dst"],
            ins_w=arrays[p + "ins_w"],
            del_src=arrays[p + "del_src"], del_dst=arrays[p + "del_dst"],
            del_w=arrays[p + "del_w"],
            pre_adj=adj["pre_adj"], post_adj=adj["post_adj"],
        ))
    return reports, anchor
