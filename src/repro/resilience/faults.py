"""Deterministic, seeded fault-injection plane.

A :class:`FaultPlan` is the single source of injected failure for a run.
It is threaded through the stack exactly like ``obs=None``: every
instrumented site takes an optional ``faults`` handle and pays **zero
overhead when it is absent** — the no-faults code path is byte-for-byte
the unhooked one, so a run with ``faults=None`` is bit-identical to a
run on a build without the resilience plane at all.

Sites (the strings passed to :meth:`FaultPlan.fire`):

========================  ==================  =============================
site                      kinds               where it is checked
========================  ==================  =============================
``chunk_dispatch``        fail, timeout       ``core.hytm`` / ``dist.graph_shard``
                                              chunk drivers, before the jit
                                              dispatch
``lane_dispatch``         fail, timeout       ``serve.scheduler`` batched
                                              lane dispatch
``lane_alloc``            oom                 ``serve.scheduler`` batch
                                              formation (halves capacity)
``cache_promote``         oom                 ``serve.warm_cache`` host→
                                              device promotion
``host_spill``            corrupt             ``serve.warm_cache`` device→
                                              host spill
``update_delivery``       drop                ``stream.delta_csr.apply``
                                              (batch never arrives)
``update_redeliver``      duplicate           ``resilience.supervisor.
                                              deliver_update`` (batch
                                              arrives twice)
========================  ==================  =============================

Determinism: each site draws from its own ``numpy`` Generator seeded
from ``[plan.seed, crc32(site)]`` — *not* Python ``hash()``, which is
process-salted — so the same plan produces the same fault schedule in
any process, which is what makes the chaos gates replayable.  Faults
always fire *before* the real dispatch: donated device buffers from the
previous chunk are still intact, so retrying the identical dispatch is
bit-exact.
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass

import numpy as np


class FaultError(RuntimeError):
    """Base class for injected failures (never raised by real code)."""

    def __init__(self, site: str, occurrence: int, msg: str | None = None):
        super().__init__(msg or f"injected fault at {site}#{occurrence}")
        self.site = site
        self.occurrence = occurrence


class DispatchFault(FaultError):
    """Injected ``fail``: the dispatch is lost before it starts."""


class DispatchTimeout(FaultError):
    """Injected ``timeout``: the dispatch hangs past its deadline."""


class DeviceOOM(FaultError):
    """Injected ``oom``: a device allocation request is refused."""


class UpdateLost(FaultError):
    """Injected ``drop``: an update batch never reaches the target."""


_ERRORS = {
    "fail": DispatchFault,
    "timeout": DispatchTimeout,
    "oom": DeviceOOM,
    "drop": UpdateLost,
}


def error_for(kind: str, site: str, occurrence: int) -> FaultError:
    """The exception modelling an injected ``kind`` at ``site``."""
    cls = _ERRORS.get(kind, FaultError)
    return cls(site, occurrence, f"injected {kind} at {site}#{occurrence}")


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled failure mode at one site.

    ``at`` lists explicit 0-based occurrence indices (attempt-granular:
    ``at=(0,)`` fails the first attempt, the retry succeeds); ``p`` adds
    an independent per-occurrence probability on top.  ``max_fires``
    bounds the total injections from this spec; ``when`` restricts
    firing to occurrences whose call-site context matches every listed
    key (e.g. ``when={"kernels": True}`` stops firing once the ladder
    has degraded to the oracle path)."""

    site: str
    kind: str
    p: float = 0.0
    at: tuple[int, ...] = ()
    max_fires: int | None = None
    when: dict | None = None


@dataclass(frozen=True)
class FaultEvent:
    """One injected fault, as recorded in :attr:`FaultPlan.events`."""

    site: str
    kind: str
    occurrence: int


@dataclass
class _SiteState:
    rng: np.random.Generator
    occurrences: int = 0


class FaultPlan:
    """A seeded schedule of injected failures.

    Instrumented sites call :meth:`fire` once per attempt; it returns
    the fault ``kind`` to inject (or ``None``).  :meth:`check` is the
    raising convenience used by dispatch sites.  The plan records every
    injection in :attr:`events` so tests and the chaos bench can assert
    recovery cost is bounded *and observable*.
    """

    def __init__(self, specs: list[FaultSpec] | tuple = (), seed: int = 0):
        self.seed = int(seed)
        self.specs = tuple(specs)
        self.events: list[FaultEvent] = []
        self._sites: dict[str, _SiteState] = {}
        self._fires: dict[int, int] = {i: 0 for i in range(len(self.specs))}
        self._by_site: dict[str, list[int]] = {}
        for i, s in enumerate(self.specs):
            self._by_site.setdefault(s.site, []).append(i)

    def _site(self, site: str) -> _SiteState:
        st = self._sites.get(site)
        if st is None:
            # crc32, not hash(): stable across processes for replayable
            # cross-process chaos schedules
            st = _SiteState(np.random.default_rng(
                [self.seed, zlib.crc32(site.encode())]))
            self._sites[site] = st
        return st

    def fire(self, site: str, **ctx) -> str | None:
        """Advance ``site``'s occurrence counter; return the fault kind
        to inject at this occurrence, or ``None``."""
        st = self._site(site)
        occ = st.occurrences
        st.occurrences += 1
        for i in self._by_site.get(site, ()):
            spec = self.specs[i]
            if spec.max_fires is not None and self._fires[i] >= spec.max_fires:
                continue
            if spec.when is not None and any(
                    ctx.get(k) != v for k, v in spec.when.items()):
                continue
            hit = occ in spec.at
            if not hit and spec.p > 0.0:
                hit = float(st.rng.random()) < spec.p
            if hit:
                self._fires[i] += 1
                self.events.append(FaultEvent(site, spec.kind, occ))
                return spec.kind
        return None

    def check(self, site: str, **ctx) -> None:
        """:meth:`fire`, raising the matching :class:`FaultError`."""
        kind = self.fire(site, **ctx)
        if kind is not None:
            raise error_for(kind, site, self._site(site).occurrences - 1)

    def corrupt(self, arr: np.ndarray) -> np.ndarray:
        """A copy of ``arr`` with one deterministically chosen bit
        flipped (the host-spill corruption model)."""
        rng = self._site("__corrupt__").rng
        buf = np.array(arr, copy=True)
        flat = buf.reshape(-1).view(np.uint8)
        flat[int(rng.integers(0, flat.size))] ^= 0x80
        return buf

    @property
    def injected(self) -> int:
        """Total faults injected so far."""
        return len(self.events)

    def counts(self) -> dict[tuple[str, str], int]:
        """``{(site, kind): n_injected}`` summary."""
        out: dict[tuple[str, str], int] = {}
        for e in self.events:
            out[(e.site, e.kind)] = out.get((e.site, e.kind), 0) + 1
        return out

    def replace(self, **kw) -> "FaultPlan":
        """A fresh plan (zeroed counters) with fields overridden."""
        return FaultPlan(kw.get("specs", self.specs),
                         seed=kw.get("seed", self.seed))


def plan_of(*specs: FaultSpec, seed: int = 0) -> FaultPlan:
    """Convenience constructor: ``plan_of(FaultSpec(...), seed=3)``."""
    return FaultPlan(list(specs), seed=seed)
