"""Supervised execution: retries, deadlines, and the degradation ladder.

:func:`guarded_dispatch` wraps one dispatch site with the fault plane
and a :class:`RetryPolicy` — exponential backoff, deadline-aware
timeout accounting, every attempt observable on the ``faults`` obs
track.  Injected faults fire **before** the real dispatch (donated
buffers are still intact), so a retry re-issues the bit-identical
dispatch; a real exception out of the dispatch itself is *not* retried
in place — donation may have consumed the inputs — that path recovers
through checkpoint/restore (:mod:`repro.resilience.checkpoint`).

:class:`Supervisor` adds the explicit degradation ladder on top.  Each
rung trades capability for an execution path whose *answers are
unchanged* — degradation here means slower, never wronger:

1. ``kernels -> oracle``: drop Pallas kernels for the lax oracle path
   (bit-identical by the kernel equivalence contract);
2. ``mesh -> single-device``: replay on one device with
   ``async_sweep=False`` (bit-identical for MIN by the sharded
   equivalence contract);
3. ``cache-promote -> full recompute``: a warm entry that fails
   promotion (corrupt or OOM) is dropped and the request recomputes
   from scratch (handled in ``serve.warm_cache``/``serve.scheduler``);
4. ``load-shed``: under sustained allocation pressure the lowest-tier
   tenants' pending requests are shed (mode ``"shed"``) so admitted
   work still meets quota/budget invariants.

Every transition is emitted as a ``repro.obs`` instant on the
``faults`` track plus ``faults.*`` metric counters, so recovery cost is
bounded *and observable*.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

from repro.resilience.faults import (
    DispatchTimeout,
    FaultError,
    FaultPlan,
    error_for,
)


@dataclass(frozen=True)
class RetryPolicy:
    """Retry/backoff/deadline policy for one dispatch site.

    ``max_attempts`` bounds total tries (first attempt included).
    Backoff for attempt ``i`` (0-based failure count) is
    ``min(backoff_s * factor**i, max_backoff_s)``.  ``deadline_s``, when
    set, is a wall budget for the whole site: injected timeouts charge
    ``timeout_charge_s`` of simulated elapsed time against it, and a
    retry that cannot fit before the deadline raises
    :class:`RetriesExhausted` immediately — deadline-aware, not just
    count-bounded."""

    max_attempts: int = 4
    backoff_s: float = 0.0
    factor: float = 2.0
    max_backoff_s: float = 2.0
    deadline_s: float | None = None
    timeout_charge_s: float = 0.0

    def backoff_for(self, failures: int) -> float:
        return min(self.backoff_s * self.factor ** failures,
                   self.max_backoff_s)


class RetriesExhausted(RuntimeError):
    """A guarded dispatch ran out of attempts (or deadline)."""

    def __init__(self, site: str, attempts: int, last: BaseException,
                 reason: str = "attempts"):
        super().__init__(
            f"{site}: gave up after {attempts} attempt(s) ({reason}); "
            f"last: {last}")
        self.site = site
        self.attempts = attempts
        self.last = last
        self.reason = reason


def record_fault_event(obs, name: str, **args) -> None:
    """Emit one fault-plane event on the ``faults`` obs track (no-op
    when ``obs`` is None)."""
    if obs is None:
        return
    from repro.obs.export import CAT_FAULTS

    obs.instant(name, cat=CAT_FAULTS, track="faults", **args)
    obs.metrics.counter(f"faults.{name}").inc()


def guarded_dispatch(fn, *, site: str, faults: FaultPlan | None = None,
                     policy: RetryPolicy | None = None, obs=None,
                     stats: dict | None = None, sleep=time.sleep,
                     clock=time.monotonic, **ctx):
    """Run ``fn()`` under the fault plane with retry/backoff/deadline.

    With ``faults=None`` this is exactly ``fn()`` — the zero-overhead
    contract.  With a plan but no ``policy``, an injected fault raises
    straight out (single attempt).  ``ctx`` is matched against each
    spec's ``when`` filter.  ``sleep``/``clock`` are injectable for
    deterministic tests."""
    if faults is None:
        return fn()
    attempts = policy.max_attempts if policy is not None else 1
    t0 = clock()
    elapsed_charge = 0.0
    failures = 0
    last: FaultError | None = None
    while True:
        kind = faults.fire(site, **ctx)
        if kind is None or kind not in ("fail", "timeout"):
            if kind is not None:
                # non-dispatch kind injected at a dispatch site (e.g.
                # oom): surface it, retrying would not help here
                raise error_for(kind, site, 0)
            return fn()
        occ = faults._site(site).occurrences - 1
        last = error_for(kind, site, occ)
        failures += 1
        if isinstance(last, DispatchTimeout) and policy is not None:
            elapsed_charge += policy.timeout_charge_s
        record_fault_event(obs, "injected", site=site, kind=kind,
                           occurrence=occ, attempt=failures)
        if stats is not None:
            stats["faults"] = stats.get("faults", 0) + 1
        if failures >= attempts:
            raise RetriesExhausted(site, failures, last)
        if policy is not None and policy.deadline_s is not None:
            spent = (clock() - t0) + elapsed_charge
            if spent + policy.backoff_for(failures - 1) >= policy.deadline_s:
                raise RetriesExhausted(site, failures, last,
                                       reason="deadline")
        backoff = policy.backoff_for(failures - 1) if policy else 0.0
        if backoff > 0.0:
            sleep(backoff)
        record_fault_event(obs, "retry", site=site, attempt=failures + 1)
        if stats is not None:
            stats["retries"] = stats.get("retries", 0) + 1


def next_rung(config):
    """The next degradation rung for ``config``: ``(label, degraded
    config)`` or ``None`` when the ladder is exhausted.  Each rung keeps
    answers bit-identical for MIN programs (see module docstring)."""
    import dataclasses

    from repro.kernels.runtime import resolve_use_kernels

    if resolve_use_kernels(config.use_kernels):
        return ("kernels->oracle",
                dataclasses.replace(config, use_kernels=False))
    if config.mesh_axis is not None:
        return ("mesh->single-device",
                dataclasses.replace(config, mesh_axis=None,
                                    async_sweep=False))
    return None


class Supervisor:
    """Shared retry policy + degradation/shedding state for a serving
    stack (one per ``GraphService``/``LaneScheduler``)."""

    def __init__(self, policy: RetryPolicy | None = None,
                 faults: FaultPlan | None = None, obs=None,
                 tenant_tiers: dict[str, int] | None = None,
                 shed_after: int = 3):
        self.policy = policy if policy is not None else RetryPolicy()
        self.faults = faults
        self.obs = obs
        # higher tier = more protected; unknown tenants get tier 0
        self.tenant_tiers = dict(tenant_tiers or {})
        self.shed_after = max(int(shed_after), 1)
        self.counters = {"faults": 0, "retries": 0, "degradations": 0,
                         "shed": 0}
        self.degradations: list[tuple[str, str]] = []
        self._oom_streak = 0

    def dispatch(self, fn, *, site: str, **ctx):
        return guarded_dispatch(fn, site=site, faults=self.faults,
                                policy=self.policy, obs=self.obs,
                                stats=self.counters, **ctx)

    def degrade(self, rung: str, reason: str) -> None:
        self.degradations.append((rung, reason))
        self.counters["degradations"] += 1
        record_fault_event(self.obs, "degrade", rung=rung, reason=reason)

    # --- load shedding ---------------------------------------------------
    def note_alloc_pressure(self, oom: bool) -> bool:
        """Track consecutive allocation failures; True when the streak
        has been sustained long enough to shed."""
        self._oom_streak = self._oom_streak + 1 if oom else 0
        return self._oom_streak >= self.shed_after

    def tier(self, tenant: str) -> int:
        return self.tenant_tiers.get(tenant, 0)

    def shed_candidates(self, pending) -> list:
        """Pending requests to shed: everything from tenants strictly
        below the highest tier currently waiting.  A uniform-tier queue
        sheds nothing (pressure resolves through smaller batches)."""
        if not pending:
            return []
        top = max(self.tier(r.tenant) for r in pending)
        return [r for r in pending if self.tier(r.tenant) < top]

    def record_shed(self, request) -> None:
        self.counters["shed"] += 1
        record_fault_event(self.obs, "shed", tenant=request.tenant,
                           source=int(request.source))


def run_supervised(g, program, source=0, config=None, *, n_hubs: int = 0,
                   runtime=None, mesh=None, supervisor: Supervisor | None = None,
                   faults: FaultPlan | None = None,
                   policy: RetryPolicy | None = None,
                   ckpt_path=None, anchor: tuple[int, int] = (0, 0),
                   checkpoint_every: int = 1, obs=None, calibrator=None,
                   initial_state=None):
    """``run_hytm`` under supervision: guarded dispatches, checkpoint at
    chunk boundaries, and the degradation ladder on retry exhaustion.

    When retries at a dispatch site are exhausted, the run restores from
    the last checkpoint (cold restart if none) and re-enters one rung
    down the ladder; the final answer is bit-identical for MIN programs
    at every rung.  Raises :class:`RetriesExhausted` only once the
    ladder itself is exhausted."""
    from repro.core.hytm import HyTMConfig, run_hytm
    from repro.resilience.checkpoint import CheckpointHook, resume_run

    cfg = config if config is not None else HyTMConfig()
    sup = supervisor if supervisor is not None else Supervisor(
        policy=policy, faults=faults, obs=obs)
    rt = runtime
    have_ckpt = False
    while True:
        hook = None
        if ckpt_path is not None and cfg.sync_every > 1:
            # owner-sharded runs snapshot gathered (n_pad,) arrays — the
            # hook records the layout + real vertex count so restore can
            # slice the pads and reject cross-layout resumes typed-ly
            n_nodes = (g.n_nodes if g is not None
                       else getattr(rt, "n_nodes", 0))
            hook = CheckpointHook(
                ckpt_path, program=program.name, anchor=anchor,
                every=checkpoint_every,
                state_layout=getattr(cfg, "vertex_sharding", "replicated"),
                n_nodes=n_nodes)
        try:
            if have_ckpt:
                return resume_run(
                    ckpt_path, g, program, config=cfg, source=source,
                    n_hubs=n_hubs, runtime=rt, mesh=mesh,
                    expect_anchor=anchor, obs=obs, faults=sup.faults,
                    retry=sup.policy, checkpoint=hook)
            return run_hytm(
                g, program, source=source, config=cfg, n_hubs=n_hubs,
                runtime=rt, mesh=mesh, initial_state=initial_state,
                calibrator=calibrator, obs=obs, faults=sup.faults,
                retry=sup.policy, on_chunk=hook)
        except RetriesExhausted as e:
            rung = next_rung(cfg)
            if rung is None:
                raise
            if hook is not None and hook.saved > 0:
                have_ckpt = True
            label, degraded = rung
            if "mesh" in label:
                # the runtime was built for the mesh; the single-device
                # replay rebuilds its own view
                rt = None
            sup.degrade(label, str(e))
            cfg = degraded


def deliver_update(target, batch, *, batch_id, faults: FaultPlan | None = None,
                   policy: RetryPolicy | None = None, obs=None,
                   sleep=time.sleep):
    """At-least-once update delivery with idempotent redelivery.

    ``target`` is a ``GraphService`` (``.update``) or ``DeltaCSR``
    (``.apply``).  An injected ``drop`` (site ``update_delivery``, fired
    inside the target before any mutation) is retried under ``policy``;
    an injected ``duplicate`` (site ``update_redeliver``) re-sends the
    same ``batch_id`` after success — the target's dedup cache returns
    the original report without bumping ``version``, which is the
    exactly-once guarantee the chaos gate checks."""
    from repro.resilience.faults import UpdateLost

    apply_fn = target.update if hasattr(target, "update") else target.apply
    attempts = policy.max_attempts if policy is not None else 1
    failures = 0
    while True:
        try:
            report = apply_fn(batch, batch_id=batch_id, faults=faults)
        except UpdateLost as e:
            failures += 1
            record_fault_event(obs, "injected", site="update_delivery",
                              kind="drop", attempt=failures)
            if failures >= attempts:
                raise RetriesExhausted("update_delivery", failures, e)
            backoff = policy.backoff_for(failures - 1) if policy else 0.0
            if backoff > 0.0:
                sleep(backoff)
            continue
        if faults is not None and faults.fire("update_redeliver") == "duplicate":
            record_fault_event(obs, "injected", site="update_redeliver",
                              kind="duplicate", batch_id=str(batch_id))
            dup = apply_fn(batch, batch_id=batch_id)
            assert dup.version == report.version, (
                "redelivery bumped the version — dedup broken")
        return report
