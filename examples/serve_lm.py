"""Serve a small LM with batched requests: prefill + decode loop with a
KV cache, continuous batched generation (the serving-side e2e driver).

    PYTHONPATH=src python examples/serve_lm.py [--requests 16 --gen 32]
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.models.transformer import (
    TransformerConfig,
    decode_step,
    init_cache,
    init_transformer,
    prefill,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = TransformerConfig(
        name="serve-demo", n_layers=6, d_model=256, n_heads=8, n_kv_heads=4,
        d_head=32, d_ff=1024, vocab=32_000, window_pattern=(256, 256, 0),
        dtype="float32", param_dtype="float32", remat=False,
    )
    params = init_transformer(jax.random.PRNGKey(0), cfg)
    n_params = sum(l.size for l in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params, batch={args.requests}")

    B, P, G = args.requests, args.prompt_len, args.gen
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab)
    caches = init_cache(cfg, B, P + G)

    jit_prefill = jax.jit(lambda p, t, c: prefill(p, t, cfg, c))
    jit_decode = jax.jit(lambda p, t, c, i: decode_step(p, t, cfg, c, i))

    t0 = time.monotonic()
    logits, caches = jit_prefill(params, prompts, caches)
    jax.block_until_ready(logits)
    t_prefill = time.monotonic() - t0
    print(f"prefill: {B}x{P} tokens in {t_prefill*1e3:.1f} ms "
          f"({B*P/t_prefill:.0f} tok/s)")

    tokens = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    generated = [tokens]
    t0 = time.monotonic()
    for step in range(G - 1):
        logits, caches = jit_decode(params, tokens, caches, jnp.int32(P + step))
        tokens = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        generated.append(tokens)
    jax.block_until_ready(tokens)
    t_dec = time.monotonic() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"decode: {B}x{G-1} tokens in {t_dec*1e3:.1f} ms "
          f"({B*(G-1)/t_dec:.0f} tok/s, {t_dec/(G-1)*1e3:.1f} ms/step)")
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab)))
    print("sample continuation ids:", out[0, :12].tolist())


if __name__ == "__main__":
    main()
