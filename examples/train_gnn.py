"""End-to-end GNN training driver: GraphSAGE on a synthetic reddit-like
power-law graph with real neighbour sampling, fault-tolerant loop with
async checkpointing, a few hundred steps.

    PYTHONPATH=src python examples/train_gnn.py [--steps 300]
"""

import argparse
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import GraphBatches
from repro.graph.generators import rmat_graph
from repro.graph.sampler import sample_neighbors
from repro.models.gnn import GNNConfig, graphsage_minibatch_forward, init_gnn
from repro.train.fault_tolerance import FaultInjector, FaultTolerantLoop
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--nodes", type=int, default=50_000)
    ap.add_argument("--edges", type=int, default=1_000_000)
    args = ap.parse_args()

    g = rmat_graph(args.nodes, args.edges, seed=0)
    n_classes, d_feat = 16, 64
    rng = np.random.default_rng(0)
    feats = jnp.asarray(rng.standard_normal((args.nodes, d_feat)), jnp.float32)
    # planted labels so the loss is learnable: class = f(feature clusters)
    proj = rng.standard_normal((d_feat, n_classes))
    labels_np = np.argmax(np.asarray(feats) @ proj, axis=1)
    labels = jnp.asarray(labels_np, jnp.int32)

    cfg = GNNConfig(name="sage", arch="graphsage", n_layers=2, d_hidden=128,
                    d_in=d_feat, d_out=n_classes, sample_sizes=(15, 10))
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    oc = OptimizerConfig(learning_rate=3e-3, warmup_steps=20, total_steps=args.steps)

    fan = cfg.sample_sizes
    batch_nodes = 512

    def loss_fn(p, batch):
        sizes = [batch_nodes, batch_nodes * fan[0], batch_nodes * fan[0] * fan[1]]
        lf = [feats[batch[f"hop{k}"]] for k in range(3)]
        logits = graphsage_minibatch_forward(p, lf, cfg)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], axis=-1))

    pipe = GraphBatches(n_nodes=args.nodes, batch_nodes=batch_nodes, n_classes=n_classes)

    def batch_fn(step):
        seeds = pipe.make(step)["seeds"]
        hops = sample_neighbors(g, seeds, fan, seed=step)
        return {
            **{f"hop{k}": jnp.asarray(h, jnp.int32) for k, h in enumerate(hops)},
            "y": labels[jnp.asarray(seeds)],
        }

    step_fn = jax.jit(make_train_step(loss_fn, oc))
    state = init_train_state(params, oc)

    with tempfile.TemporaryDirectory() as td:
        loop = FaultTolerantLoop(
            step_fn=step_fn, batch_fn=batch_fn, ckpt_dir=td, ckpt_every=50,
            injector=FaultInjector(fail_at_steps=(args.steps // 2,)),
        )
        state, log, restarts = loop.run(state, args.steps)

    first = np.mean([m["loss"] for m in log[:20]])
    last = np.mean([m["loss"] for m in log[-20:]])
    print(f"steps={args.steps} restarts={restarts} (injected fault survived)")
    print(f"loss: {first:.4f} -> {last:.4f}  ({'improved' if last < first else 'NO IMPROVEMENT'})")
    assert last < first, "training did not reduce loss"


if __name__ == "__main__":
    main()
