"""Train a ~20M-param LM (MoE, with HyTM sorted dispatch) for a few
hundred steps with gradient compression + fault-tolerant checkpointing.
CPU-sized; pass --wide for a ~100M dense model if you have the cycles.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.data.pipeline import LMBatches
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig, init_transformer, lm_loss
from repro.train.compression import CompressionConfig
from repro.train.fault_tolerance import FaultInjector, FaultTolerantLoop
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--wide", action="store_true")
    args = ap.parse_args()

    if args.wide:
        cfg = TransformerConfig(
            name="lm-100m", n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
            d_head=64, d_ff=2048, vocab=32_000,
            dtype="float32", param_dtype="float32")
    else:
        cfg = TransformerConfig(
            name="lm-20m-moe", n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
            d_head=32, d_ff=512, vocab=8_192,
            moe=MoEConfig(n_experts=8, top_k=2, d_ff=512, capacity_factor=2.0,
                          dispatch="sorted"),
            dtype="float32", param_dtype="float32")

    params = init_transformer(jax.random.PRNGKey(0), cfg)
    n = sum(l.size for l in jax.tree.leaves(params))
    print(f"model: {n/1e6:.1f}M params ({'dense' if cfg.moe is None else 'MoE sorted-dispatch'})")

    oc = OptimizerConfig(learning_rate=1e-3, warmup_steps=20, total_steps=args.steps)
    cc = CompressionConfig(kind="int8")
    pipe = LMBatches(vocab=cfg.vocab, batch=8, seq_len=128)

    step_fn = jax.jit(make_train_step(
        lambda p, b: lm_loss(p, b["tokens"], cfg), oc, cc))
    state = init_train_state(params, oc, cc)

    def batch_fn(step):
        return {"tokens": pipe.make(step)["tokens"]}

    with tempfile.TemporaryDirectory() as td:
        loop = FaultTolerantLoop(
            step_fn=step_fn, batch_fn=batch_fn, ckpt_dir=td, ckpt_every=50,
            injector=FaultInjector(fail_at_steps=(args.steps // 2,)),
        )
        state, log, restarts = loop.run(state, args.steps)

    first = np.mean([m["loss"] for m in log[:10]])
    last = np.mean([m["loss"] for m in log[-10:]])
    print(f"steps={args.steps} restarts={restarts} (int8-compressed grads + EF)")
    print(f"loss: {first:.4f} -> {last:.4f}  ({'improved' if last < first else 'NO IMPROVEMENT'})")
    assert last < first


if __name__ == "__main__":
    main()
