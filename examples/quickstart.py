"""Quickstart: the paper's workload end-to-end.

Generates an RMAT graph, hub-sorts it, and runs SSSP + Δ-PageRank through
the full HyTM pipeline (cost-aware engine selection + contribution-driven
scheduling), printing the per-iteration engine mix — the Fig. 7
"execution path" — and validating against the numpy references.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.constants import PCIE3
from repro.core.cost_model import ENGINE_NAMES
from repro.core.hytm import HyTMConfig, run_hytm
from repro.graph.algorithms import PAGERANK, SSSP, reference_pagerank, reference_sssp
from repro.graph.generators import rmat_graph
from repro.graph.hub_sort import hub_sort


def main():
    print("== HyTGraph quickstart ==")
    g = rmat_graph(50_000, 800_000, seed=0)
    print(f"graph: {g.n_nodes:,} vertices / {g.n_edges:,} edges (RMAT)")

    hs = hub_sort(g)
    print(f"hub-sorted: top {hs.n_hubs:,} vertices (8%) moved to CSR front")

    cfg = HyTMConfig(
        link=PCIE3.with_(mr=4.0), n_partitions=64, cds_mode="hub",
    )

    # ---------------- SSSP
    res = run_hytm(hs.graph, SSSP, source=int(hs.perm[0]), config=cfg, n_hubs=hs.n_hubs)
    ref = reference_sssp(g, 0)
    ok = np.allclose(hs.values_to_old(res.values), ref)
    print(f"\nSSSP: {res.iterations} iterations, correct={ok}")
    print(f"  modeled transfer: {res.total_transfer_bytes/2**20:.1f} MiB "
          f"({res.total_transfer_bytes/(g.n_edges*4):.2f}x edge bytes)")
    print(f"  modeled PCIe time: {res.modeled_seconds*1e3:.2f} ms | wall: {res.wall_seconds:.2f}s")
    _print_path(res)

    # ---------------- Δ-PageRank with Δ-driven scheduling
    prog = dataclasses.replace(PAGERANK, tolerance=1e-5)
    cfg_pr = dataclasses.replace(cfg, cds_mode="delta")
    res = run_hytm(hs.graph, prog, source=None, config=cfg_pr, n_hubs=hs.n_hubs)
    ref = reference_pagerank(g)
    err = np.max(np.abs(hs.values_to_old(res.values + res.delta) - ref))
    print(f"\nPageRank: {res.iterations} iterations, max err {err:.2e}")
    print(f"  modeled transfer: {res.total_transfer_bytes/2**20:.1f} MiB")
    _print_path(res)


def _print_path(res, max_iters=10):
    print("  engine mix per iteration (paper Fig. 7):")
    eng = res.history["engines"]
    for i in range(min(max_iters, eng.shape[0])):
        row = eng[i]
        mix = {ENGINE_NAMES[e]: int((row == e).sum()) for e in (-1, 0, 1, 2)}
        print(f"    iter {i:2d}: " + "  ".join(f"{k}={v}" for k, v in mix.items()))
    if eng.shape[0] > max_iters:
        print(f"    ... ({eng.shape[0] - max_iters} more)")


if __name__ == "__main__":
    main()
