"""Graph substrate: CSR, generators, hub sort, partitioning, sampling."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.partition import partition_graph
from repro.graph.csr import CSRGraph, csr_from_edges, to_device_csr
from repro.graph.generators import (
    batched_molecule_graphs,
    grid_mesh_graph,
    rmat_graph,
    uniform_graph,
)
from repro.graph.hub_sort import hub_scores, hub_sort
from repro.graph.sampler import sample_neighbors


def test_csr_from_edges_roundtrip():
    src = np.array([0, 0, 1, 2, 2, 2])
    dst = np.array([1, 2, 2, 0, 1, 3])
    w = np.arange(6, dtype=np.float32)
    g = csr_from_edges(4, src, dst, w)
    g.validate()
    assert g.n_nodes == 4 and g.n_edges == 6
    assert list(g.out_degrees) == [2, 1, 3, 0]
    assert list(g.in_degrees) == [1, 2, 2, 1]
    # edges recovered as a set
    got = set(zip(g.edge_sources().tolist(), g.indices.tolist(), g.weights.tolist()))
    assert got == set(zip(src.tolist(), dst.tolist(), w.tolist()))


def test_generators_valid():
    for g in [
        rmat_graph(500, 4000, seed=0),
        uniform_graph(300, 1000, seed=1),
        grid_mesh_graph(8, 9),
        batched_molecule_graphs(4, n_nodes=30, n_edges=64),
    ]:
        g.validate()
        assert g.n_edges > 0


def test_rmat_power_law_skew():
    g = rmat_graph(2048, 40000, seed=3)
    deg = np.sort(g.out_degrees)[::-1]
    # RMAT should concentrate mass: top 1% of vertices own >10% of edges
    top = deg[: max(1, g.n_nodes // 100)].sum()
    assert top > 0.1 * g.n_edges


def test_symmetrize_is_symmetric():
    g = rmat_graph(200, 1000, seed=4)
    s = g.symmetrize()
    fwd = set(zip(s.edge_sources().tolist(), s.indices.tolist()))
    assert all((b, a) in fwd for a, b in fwd)


def test_hub_sort_places_hubs_first():
    g = rmat_graph(1000, 8000, seed=5)
    res = hub_sort(g, hub_fraction=0.08)
    res.graph.validate()
    scores = hub_scores(g)
    new_scores = scores[res.inv_perm]
    # every hub (first n_hubs new ids) has score >= every non-hub
    assert new_scores[: res.n_hubs].min() >= new_scores[res.n_hubs :].max()


def test_hub_sort_preserves_graph_semantics():
    g = rmat_graph(300, 2000, seed=6)
    res = hub_sort(g)
    h = res.graph
    orig = set(zip(g.edge_sources().tolist(), g.indices.tolist(), g.weights.tolist()))
    remap = set(
        zip(
            res.inv_perm[h.edge_sources()].tolist(),
            res.inv_perm[h.indices].tolist(),
            h.weights.tolist(),
        )
    )
    assert orig == remap


@settings(deadline=None, max_examples=25)
@given(
    n=st.integers(8, 300),
    m=st.integers(1, 2000),
    p=st.integers(1, 32),
    seed=st.integers(0, 10_000),
)
def test_partition_edge_balance_property(n, m, p, seed):
    g = uniform_graph(n, m, seed=seed)
    table = partition_graph(g, n_partitions=p)
    # partitions tile the vertex/edge space exactly
    assert table.vertex_start[0] == 0 and table.vertex_start[-1] == n
    assert table.edge_start[-1] == g.n_edges
    assert np.all(np.diff(table.vertex_start) >= 0)
    # every partition within max-degree slack of the ideal edge share
    epp = table.edges_per_partition
    ideal = g.n_edges / table.n_partitions
    slack = g.out_degrees.max(initial=0) + 1
    assert epp.max(initial=0) <= ideal + slack


def test_device_csr_padding_safe():
    g = rmat_graph(100, 500, seed=7)
    d = to_device_csr(g, capacity=1024)
    assert d.capacity == 1024
    assert not bool(d.edge_valid[g.n_edges:].any())
    assert bool(d.edge_valid[: g.n_edges].all())


def test_sampler_shapes_and_fallback():
    g = rmat_graph(200, 600, seed=8)
    layers = sample_neighbors(g, np.arange(16), (5, 3), seed=0)
    assert [len(l) for l in layers] == [16, 80, 240]
    # isolated vertices sample themselves
    iso = np.nonzero(g.out_degrees == 0)[0]
    if len(iso):
        ls = sample_neighbors(g, iso[:1], (4,), seed=0)
        assert np.all(ls[1] == iso[0])
