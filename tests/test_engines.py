"""The three transfer engines must produce identical relax results
(property-tested), and the full HyTM runs must be engine-invariant."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.cost_model import COMPACT, FILTER, ZEROCOPY
from repro.core.engines import (
    ENGINE_FNS,
    EdgeBlock,
    relax_compact,
    relax_filter,
    relax_with_engine,
    relax_zerocopy,
)
from repro.core.hytm import HyTMConfig, run_hytm
from repro.graph.algorithms import PAGERANK, SSSP, reference_pagerank, reference_sssp
from repro.graph.generators import rmat_graph


@settings(deadline=None, max_examples=30)
@given(
    n=st.integers(2, 64),
    b=st.integers(1, 256),
    seed=st.integers(0, 1000),
    combine_min=st.booleans(),
)
def test_engines_identical_property(n, b, seed, combine_min):
    rng = np.random.default_rng(seed)
    block = EdgeBlock(
        src=jnp.asarray(rng.integers(0, n, b), jnp.int32),
        dst=jnp.asarray(rng.integers(0, n, b), jnp.int32),
        weight=jnp.asarray(rng.random(b), jnp.float32),
        active=jnp.asarray(rng.random(b) < 0.5),
    )
    operand = jnp.asarray(rng.random(n), jnp.float32)
    prog = SSSP if combine_min else PAGERANK
    outs = [
        fn(block, operand, n, prog)
        for fn in (relax_filter, relax_compact, relax_zerocopy)
    ]
    for o in outs[1:]:
        assert jnp.allclose(outs[0].agg, o.agg, atol=1e-5, equal_nan=True)
        assert jnp.array_equal(outs[0].touched, o.touched)


@settings(deadline=None, max_examples=20)
@given(
    n=st.integers(2, 48),
    b=st.integers(1, 128),
    seed=st.integers(0, 1000),
    combine_min=st.booleans(),
)
def test_lax_switch_dispatch_matches_direct(n, b, seed, combine_min):
    """``relax_with_engine`` (the traced lax.switch used inside the jitted
    sweep) must route each engine id to exactly the direct function."""
    rng = np.random.default_rng(seed)
    block = EdgeBlock(
        src=jnp.asarray(rng.integers(0, n, b), jnp.int32),
        dst=jnp.asarray(rng.integers(0, n, b), jnp.int32),
        weight=jnp.asarray(rng.random(b), jnp.float32),
        active=jnp.asarray(rng.random(b) < 0.5),
    )
    operand = jnp.asarray(rng.random(n), jnp.float32)
    prog = SSSP if combine_min else PAGERANK
    for eng in (FILTER, COMPACT, ZEROCOPY):
        switched = jax.jit(
            lambda e: relax_with_engine(e, block, operand, n, prog)
        )(jnp.int32(eng))
        direct = ENGINE_FNS[eng](block, operand, n, prog)
        assert jnp.allclose(switched.agg, direct.agg, atol=1e-6, equal_nan=True)
        assert jnp.array_equal(switched.touched, direct.touched)


def _converges_to_reference(g, engine):
    cfg = HyTMConfig(n_partitions=8, forced_engine=engine)
    res = run_hytm(g, SSSP, source=0, config=cfg)
    ref = reference_sssp(g, 0)
    return np.allclose(res.values, ref, equal_nan=False)


def test_full_run_engine_invariant():
    g = rmat_graph(500, 4000, seed=11)
    for eng in (FILTER, COMPACT, ZEROCOPY, None):
        cfg = HyTMConfig(n_partitions=8, forced_engine=eng)
        res = run_hytm(g, SSSP, source=0, config=cfg)
        ref = reference_sssp(g, 0)
        assert np.allclose(res.values, ref), f"engine {eng} diverged"


def test_pagerank_engine_invariant():
    g = rmat_graph(400, 3000, seed=12)
    prog = dataclasses.replace(PAGERANK, tolerance=1e-7)
    ref = reference_pagerank(g)
    for eng in (FILTER, COMPACT, ZEROCOPY, None):
        cfg = HyTMConfig(n_partitions=8, forced_engine=eng, cds_mode="delta")
        res = run_hytm(g, prog, source=None, config=cfg)
        assert np.max(np.abs(res.values + res.delta - ref)) < 1e-3


def test_transfer_bytes_ordering():
    """Modeled transfer (Table VI): filter moves the most (whole
    partitions); compaction the least; zero-copy sits above compaction —
    its request-granularity rounding on low-degree vertices is the
    paper's Fig-3(d) 'redundant ZC transfer'."""
    g = rmat_graph(2000, 16000, seed=13)
    bytes_by_engine = {}
    for eng in (FILTER, COMPACT, ZEROCOPY):
        cfg = HyTMConfig(n_partitions=16, forced_engine=eng, recompute_once=False)
        res = run_hytm(g, SSSP, source=0, config=cfg)
        bytes_by_engine[eng] = res.total_transfer_bytes
    assert bytes_by_engine[FILTER] >= bytes_by_engine[COMPACT]
    assert bytes_by_engine[ZEROCOPY] >= bytes_by_engine[COMPACT]


def test_kernel_engines_match_oracles():
    """Each kernel-backed engine (use_kernels=True) vs its pure-JAX oracle:
    MIN bit-exact, SUM tolerance-bounded with a bit-exact touched mask —
    the `HyTMConfig.use_kernels` contract."""
    for seed in (0, 1, 2):
        rng = np.random.default_rng(seed)
        n, b = 64, 300
        block = EdgeBlock(
            src=jnp.asarray(rng.integers(0, n, b), jnp.int32),
            dst=jnp.asarray(rng.integers(0, n, b), jnp.int32),
            weight=jnp.asarray(rng.random(b), jnp.float32),
            active=jnp.asarray(rng.random(b) < 0.5),
        )
        operand = jnp.asarray(rng.random(n), jnp.float32)
        for fn in ENGINE_FNS:
            for prog in (SSSP, PAGERANK):
                ref = fn(block, operand, n, prog, use_kernels=False)
                ker = fn(block, operand, n, prog, use_kernels=True)
                if prog is SSSP:
                    assert jnp.array_equal(ref.agg, ker.agg), (fn.__name__, seed)
                else:
                    assert jnp.allclose(ref.agg, ker.agg, atol=1e-5), (fn.__name__, seed)
                assert jnp.array_equal(ref.touched, ker.touched), (fn.__name__, seed)


def test_use_kernels_end_to_end_bit_exact():
    """Full MIN runs with use_kernels on vs off: values, iteration count,
    transfer accounting, and per-iteration engine picks all bit-identical —
    across the single-dispatch (K=1) and chunked (K=4) drivers."""
    g = rmat_graph(400, 3000, seed=21)
    for K in (1, 4):
        cfg = HyTMConfig(n_partitions=8, sync_every=K)
        off = run_hytm(g, SSSP, source=0,
                       config=dataclasses.replace(cfg, use_kernels=False))
        on = run_hytm(g, SSSP, source=0,
                      config=dataclasses.replace(cfg, use_kernels=True))
        np.testing.assert_array_equal(off.values, on.values)
        assert off.iterations == on.iterations
        assert off.total_transfer_bytes == on.total_transfer_bytes
        np.testing.assert_array_equal(
            off.history["engines"], on.history["engines"])


def test_use_kernels_pagerank_tolerance():
    """SUM combiner: the tiled kernel accumulation reassociates float adds,
    so values are tolerance-bounded; the engine trajectory stays identical
    (selection consumes exact activity stats, not the summed values)."""
    g = rmat_graph(300, 2400, seed=22)
    prog = dataclasses.replace(PAGERANK, tolerance=1e-7)
    cfg = HyTMConfig(n_partitions=8, cds_mode="delta")
    off = run_hytm(g, prog, source=None,
                   config=dataclasses.replace(cfg, use_kernels=False))
    on = run_hytm(g, prog, source=None,
                  config=dataclasses.replace(cfg, use_kernels=True))
    assert np.max(np.abs(off.values - on.values)) < 1e-4
    np.testing.assert_array_equal(off.history["engines"], on.history["engines"])


def test_hybrid_never_worse_than_worst_engine():
    g = rmat_graph(1500, 12000, seed=14)
    times = {}
    for eng in (FILTER, COMPACT, ZEROCOPY, None):
        cfg = HyTMConfig(n_partitions=16, forced_engine=eng, recompute_once=False)
        res = run_hytm(g, SSSP, source=0, config=cfg)
        times[eng] = res.modeled_seconds
    assert times[None] <= max(times[FILTER], times[COMPACT], times[ZEROCOPY]) + 1e-9
