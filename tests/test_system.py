"""End-to-end system behaviour: the full HyTGraph pipeline (preprocess ->
hub sort -> partition -> hybrid iterate -> converge) and its interaction
with scheduling options — the paper's Fig. 5 loop as one test surface."""

import dataclasses

import numpy as np

from repro.core.hytm import HyTMConfig, build_runtime, run_hytm
from repro.graph.algorithms import PAGERANK, SSSP, reference_pagerank, reference_sssp
from repro.graph.generators import rmat_graph
from repro.graph.hub_sort import hub_sort


def test_end_to_end_pipeline():
    """Generate -> hub-sort -> run all scheduling variants -> validate."""
    g = rmat_graph(3000, 24000, seed=99)
    hs = hub_sort(g)
    src_new = int(hs.perm[0])
    ref = reference_sssp(g, 0)

    variants = {
        "full": HyTMConfig(n_partitions=24, cds_mode="hub", recompute_once=True),
        "no-cds": HyTMConfig(n_partitions=24, cds_mode="none", recompute_once=False),
        "no-tc": HyTMConfig(n_partitions=24, enable_task_combination=False),
        "sync": HyTMConfig(n_partitions=24, async_sweep=False),
    }
    stats = {}
    for name, cfg in variants.items():
        res = run_hytm(hs.graph, SSSP, source=src_new, config=cfg, n_hubs=hs.n_hubs)
        assert np.allclose(hs.values_to_old(res.values), ref), name
        stats[name] = res
    # task combining reduces scheduled tasks
    assert stats["full"].history["n_tasks"].sum() <= stats["no-tc"].history["n_tasks"].sum()
    # async converges in <= sync iterations (paper §VI)
    assert stats["full"].iterations <= stats["sync"].iterations


def test_runtime_reuse_across_algorithms():
    """Preprocessing (partition/upload) happens once; algorithms share it
    (paper: hub sorting is done once in data preparation)."""
    g = rmat_graph(1000, 8000, seed=100)
    cfg = HyTMConfig(n_partitions=8)
    rt = build_runtime(g, cfg)
    r1 = run_hytm(g, SSSP, source=0, config=cfg, runtime=rt)
    prog = dataclasses.replace(PAGERANK, tolerance=1e-7)
    r2 = run_hytm(g, prog, source=None, config=cfg, runtime=rt)
    assert np.allclose(r1.values, reference_sssp(g, 0))
    assert np.max(np.abs(r2.values + r2.delta - reference_pagerank(g))) < 1e-3


def test_execution_path_follows_frontier_density():
    """Fig. 7: when nearly everything is active (PR start) the scheduler
    leans on filter; on sparse frontiers (SSSP start) zerocopy/compaction
    dominate.  mr is shrunk so transaction-group rounding doesn't tie the
    costs at CPU-test scale (the paper's partitions are 32 MB)."""
    from repro.core.constants import PCIE3
    from repro.core.cost_model import FILTER, ZEROCOPY

    link = PCIE3.with_(mr=4.0)
    g = rmat_graph(4000, 64000, seed=101)
    pr = run_hytm(g, PAGERANK, source=None, config=HyTMConfig(n_partitions=32, link=link))
    first_iter = pr.history["engines"][0]
    assert (first_iter == FILTER).sum() >= (first_iter == ZEROCOPY).sum()

    ss = run_hytm(g, SSSP, source=0, config=HyTMConfig(n_partitions=32, link=link))
    early = ss.history["engines"][0]
    assert (early == ZEROCOPY).sum() + (early == -1).sum() >= (early == FILTER).sum()
