"""repro.resilience acceptance contract.

The exactness-under-faults guarantee, property-tested where cheap:

* the fault plane is deterministic (seeded per-site RNG streams) and
  zero-overhead when absent — an *empty* plan threaded through every
  guarded path still yields bit-identical runs;
* ``guarded_dispatch`` retries injected dispatch failures with bounded
  backoff and deadline-aware timeout accounting (injectable clock);
* checkpoints round-trip ``HyTMState`` + history + calibrator state with
  integrity checksums, and a run killed at any seeded chunk boundary
  resumes bit-identically (values, iterations, transfer bytes, engine
  picks) — single-device and on 4 forced-host devices;
* a corrupted host-spilled warm-cache entry is detected by checksum,
  counted, evicted, and the request recomputes correctly;
* an invalid update batch is rejected atomically (version, edge log, and
  device buffers bit-identical before/after);
* the degradation ladder (kernels -> oracle, tiered load shedding) and
  exactly-once update delivery keep answers unchanged;
* a corrupt autotune registry profile warns and falls back to shipped
  constants.
"""

import dataclasses
import os
import warnings

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from _forced_devices import run_forced_devices
from repro.core.cost_model import KEY_ENGINES, KEY_TRANSFER_BYTES
from repro.core.hytm import HyTMConfig, run_hytm
from repro.graph.algorithms import SSSP
from repro.graph.generators import rmat_graph
from repro.resilience import (
    CheckpointError,
    CheckpointHook,
    DispatchFault,
    FaultPlan,
    FaultSpec,
    RetriesExhausted,
    RetryPolicy,
    RunCheckpoint,
    Supervisor,
    deliver_update,
    guarded_dispatch,
    plan_of,
    restore,
    resume_run,
    run_supervised,
    save,
)
from repro.serve import Request, RequestQueue
from repro.stream import (
    EdgeBatch,
    GraphService,
    InvalidBatchError,
    random_batch,
)
from repro.stream.delta_csr import OP_DELETE, OP_INSERT, OP_REWEIGHT

CFG = HyTMConfig(n_partitions=6, sync_every=2)
_G = {}


def _graph():
    if "g" not in _G:
        _G["g"] = rmat_graph(300, 2400, seed=7)
        _G["base"] = run_hytm(_G["g"], SSSP, source=0, config=CFG)
    return _G["g"], _G["base"]


# --------------------------------------------------------------------------
# fault plane: determinism + zero overhead
def test_fault_plan_deterministic():
    spec = FaultSpec("chunk_dispatch", "fail", p=0.5)
    a = [plan_of(spec, seed=3).fire("chunk_dispatch") for _ in range(1)]
    p1, p2 = plan_of(spec, seed=3), plan_of(spec, seed=3)
    seq1 = [p1.fire("chunk_dispatch") for _ in range(50)]
    seq2 = [p2.fire("chunk_dispatch") for _ in range(50)]
    assert seq1 == seq2
    assert any(k == "fail" for k in seq1) and any(k is None for k in seq1)
    # sites draw independent streams: firing another site between calls
    # must not perturb the first site's schedule
    p3 = plan_of(spec, FaultSpec("lane_alloc", "oom", p=0.5), seed=3)
    seq3 = []
    for _ in range(50):
        p3.fire("lane_alloc")
        seq3.append(p3.fire("chunk_dispatch"))
    assert seq3 == seq1


def test_fault_plan_at_and_when():
    plan = plan_of(FaultSpec("s", "fail", at=(1, 3)), seed=0)
    assert [plan.fire("s") for _ in range(5)] == [
        None, "fail", None, "fail", None]
    gated = plan_of(FaultSpec("s", "fail", p=1.0, when={"kernels": True}),
                    seed=0)
    assert gated.fire("s", kernels=False) is None
    assert gated.fire("s", kernels=True) == "fail"


def test_empty_plan_zero_overhead():
    g, base = _graph()
    res = run_hytm(g, SSSP, source=0, config=CFG, faults=FaultPlan(seed=1),
                   retry=RetryPolicy())
    np.testing.assert_array_equal(base.values, res.values)
    assert res.iterations == base.iterations
    assert res.total_transfer_bytes == base.total_transfer_bytes
    np.testing.assert_array_equal(base.history[KEY_ENGINES],
                                  res.history[KEY_ENGINES])


# --------------------------------------------------------------------------
# guarded_dispatch: retry / backoff / deadline (fake clock, no wall time)
def test_guarded_dispatch_retries_then_succeeds():
    plan = plan_of(FaultSpec("site", "fail", at=(0, 1)), seed=2)
    slept = []
    calls = []
    out = guarded_dispatch(
        lambda: calls.append(1) or 42, site="site", faults=plan,
        policy=RetryPolicy(max_attempts=4, backoff_s=0.5, factor=2.0),
        sleep=slept.append, clock=lambda: 0.0)
    assert out == 42 and len(calls) == 1
    assert slept == [0.5, 1.0]  # exponential backoff per failure


def test_guarded_dispatch_exhausts_attempts():
    plan = plan_of(FaultSpec("site", "fail", p=1.0), seed=2)
    try:
        guarded_dispatch(lambda: 0, site="site", faults=plan,
                         policy=RetryPolicy(max_attempts=3, backoff_s=0.0))
        raise AssertionError("expected RetriesExhausted")
    except RetriesExhausted as e:
        assert e.attempts == 3 and e.reason == "attempts"
        assert isinstance(e.last, DispatchFault)


def test_guarded_dispatch_deadline_counts_timeout_charge():
    plan = plan_of(FaultSpec("site", "timeout", p=1.0), seed=2)
    policy = RetryPolicy(max_attempts=10, backoff_s=0.0, deadline_s=1.0,
                         timeout_charge_s=0.4)
    try:
        guarded_dispatch(lambda: 0, site="site", faults=plan, policy=policy,
                         sleep=lambda s: None, clock=lambda: 0.0)
        raise AssertionError("expected RetriesExhausted")
    except RetriesExhausted as e:
        # 3 timeouts charge 1.2s of simulated elapsed > 1.0s deadline
        assert e.reason == "deadline" and e.attempts == 3


# --------------------------------------------------------------------------
# checkpoint: round trip, integrity, anchors
def test_checkpoint_round_trip(tmp_path):
    g, base = _graph()
    path = tmp_path / "run.ckpt.npz"
    ckpt = RunCheckpoint(
        program=SSSP.name, iterations=int(base.iterations),
        graph_version=3, layout_version=1,
        values=np.asarray(base.values), delta=np.asarray(base.delta),
        frontier=np.zeros(g.n_nodes, bool),
        history={k: np.asarray(v) for k, v in base.history.items()},
    )
    save(ckpt, path)
    back = restore(path, expect_anchor=(3, 1), program=SSSP.name)
    np.testing.assert_array_equal(back.values, np.asarray(base.values))
    assert back.iterations == base.iterations and back.anchor == (3, 1)
    np.testing.assert_array_equal(back.history[KEY_TRANSFER_BYTES],
                                  np.asarray(base.history[KEY_TRANSFER_BYTES]))


def test_checkpoint_rejects_corruption_and_mismatch(tmp_path):
    g, base = _graph()
    path = tmp_path / "run.ckpt.npz"
    save(RunCheckpoint(program="sssp", iterations=4,
                       values=np.asarray(base.values)), path)
    for expect, prog in (((1, 0), None), (None, "bfs")):
        try:
            restore(path, expect_anchor=expect, program=prog)
            raise AssertionError("expected CheckpointError")
        except CheckpointError:
            pass
    blob = bytearray(path.read_bytes())
    blob[len(blob) // 2] ^= 0xFF
    path.write_bytes(bytes(blob))
    try:
        restore(path)
        raise AssertionError("expected CheckpointError on corrupt file")
    except CheckpointError:
        pass
    try:
        restore(tmp_path / "absent.npz")
        raise AssertionError("expected CheckpointError on missing file")
    except CheckpointError:
        pass


# --------------------------------------------------------------------------
# crash recovery: kill at a seeded chunk boundary, resume bit-identically
@settings(max_examples=4, deadline=None)
@given(kill_at=st.integers(min_value=1, max_value=3))
def test_kill_resume_bit_identical(kill_at):
    g, base = _graph()
    import tempfile

    ck = os.path.join(tempfile.mkdtemp(prefix="resil_"), "run.ckpt.npz")
    hook = CheckpointHook(ck, program=SSSP.name, anchor=(0, 0))
    plan = plan_of(FaultSpec("chunk_dispatch", "fail", at=(kill_at,)),
                   seed=kill_at)
    try:
        run_hytm(g, SSSP, source=0, config=CFG, faults=plan, on_chunk=hook)
        raise AssertionError("injected kill did not fire")
    except RetriesExhausted:
        pass
    res = resume_run(ck, g, SSSP, config=CFG, source=0,
                     expect_anchor=(0, 0))
    np.testing.assert_array_equal(base.values, res.values)
    assert res.iterations == base.iterations
    assert res.total_transfer_bytes == base.total_transfer_bytes
    np.testing.assert_array_equal(base.history[KEY_ENGINES],
                                  res.history[KEY_ENGINES])
    np.testing.assert_array_equal(base.history[KEY_TRANSFER_BYTES],
                                  res.history[KEY_TRANSFER_BYTES])


def test_on_chunk_requires_chunked_driver():
    g, _ = _graph()
    cfg1 = dataclasses.replace(CFG, sync_every=1)
    try:
        run_hytm(g, SSSP, source=0, config=cfg1, on_chunk=lambda **kw: None)
        raise AssertionError("expected ValueError")
    except ValueError as e:
        assert "sync_every" in str(e)


_SHARDED_RESUME_SCRIPT = """
import os, tempfile
import numpy as np
from repro.core.hytm import HyTMConfig, run_hytm
from repro.graph.algorithms import SSSP
from repro.graph.generators import rmat_graph
from repro.resilience import (CheckpointHook, FaultSpec, plan_of,
                              resume_run, RetriesExhausted)

g = rmat_graph(300, 2400, seed=7)
cfg = HyTMConfig(n_partitions=6, sync_every=2, async_sweep=False,
                 mesh_axis="graph")
base = run_hytm(g, SSSP, source=0, config=cfg)
ck = os.path.join(tempfile.mkdtemp(), "m.ckpt.npz")
hook = CheckpointHook(ck, program=SSSP.name, anchor=(0, 0))
plan = plan_of(FaultSpec("chunk_dispatch", "fail", at=(2,)), seed=5)
try:
    run_hytm(g, SSSP, source=0, config=cfg, faults=plan, on_chunk=hook)
    raise SystemExit("injected kill did not fire")
except RetriesExhausted:
    pass
res = resume_run(ck, g, SSSP, config=cfg, source=0, expect_anchor=(0, 0))
np.testing.assert_array_equal(base.values, res.values)
assert res.iterations == base.iterations
assert res.total_transfer_bytes == base.total_transfer_bytes
print("OK", base.iterations)
"""


def test_kill_resume_forced_devices():
    out = run_forced_devices(_SHARDED_RESUME_SCRIPT, devices=4)
    assert "OK" in out


_OWNER_RESUME_SCRIPT = """
import dataclasses, os, tempfile
import numpy as np
from repro.core.hytm import HyTMConfig, run_hytm
from repro.graph.algorithms import SSSP
from repro.graph.generators import rmat_graph
from repro.resilience import (CheckpointError, CheckpointHook, FaultSpec,
                              RetriesExhausted, migrate_state_layout,
                              plan_of, restore, resume_run, save)

g = rmat_graph(300, 2400, seed=7)
cfg = HyTMConfig(n_partitions=6, sync_every=2, async_sweep=False,
                 mesh_axis="graph", vertex_sharding="owner")
base = run_hytm(g, SSSP, source=0, config=cfg)
ck = os.path.join(tempfile.mkdtemp(), "m.ckpt.npz")
hook = CheckpointHook(ck, program=SSSP.name, anchor=(0, 0),
                      state_layout="owner", n_nodes=g.n_nodes)
plan = plan_of(FaultSpec("chunk_dispatch", "fail", at=(2,)), seed=5)
try:
    run_hytm(g, SSSP, source=0, config=cfg, faults=plan, on_chunk=hook)
    raise SystemExit("injected kill did not fire")
except RetriesExhausted:
    pass
res = resume_run(ck, g, SSSP, config=cfg, source=0, expect_anchor=(0, 0))
np.testing.assert_array_equal(base.values, res.values)
assert res.iterations == base.iterations
assert res.total_transfer_bytes == base.total_transfer_bytes
print("OK-RESUME", res.iterations)

# layout mismatch is a typed CheckpointError naming the converter, not a
# shape crash deep inside the sharded driver
cfg_rep = dataclasses.replace(cfg, vertex_sharding="replicated")
try:
    resume_run(ck, g, SSSP, config=cfg_rep, source=0, expect_anchor=(0, 0))
    raise SystemExit("expected CheckpointError")
except CheckpointError as e:
    assert "migrate_state_layout" in str(e), e
print("OK-TYPED")

# owner -> replicated -> owner migration round trip is bit-exact (pads
# are deterministic fills), and the migrated replicated checkpoint
# resumes to the same answer
ckpt = restore(ck)
assert ckpt.state_layout == "owner" and ckpt.n_nodes == 300
rep = migrate_state_layout(ckpt, "replicated")
assert rep.values.shape == (300,)
back = migrate_state_layout(rep, "owner", n_devices=4)
np.testing.assert_array_equal(back.values, ckpt.values)
np.testing.assert_array_equal(back.delta, ckpt.delta)
np.testing.assert_array_equal(back.frontier, ckpt.frontier)
ck2 = ck + ".rep.npz"
save(rep, ck2)
res2 = resume_run(ck2, g, SSSP, config=cfg_rep, source=0,
                  expect_anchor=(0, 0))
np.testing.assert_array_equal(base.values, res2.values)
assert res2.iterations == base.iterations
print("OK-MIGRATE")
"""


def test_owner_kill_resume_and_migration_forced_devices():
    """Owner-sharded kill+resume is bit-identical; resuming an
    owner-layout checkpoint into a replicated run raises a typed
    CheckpointError pointing at ``migrate_state_layout``; the migration
    round-trips bit-exactly and the migrated checkpoint resumes to the
    same answer on the replicated path."""
    out = run_forced_devices(_OWNER_RESUME_SCRIPT, devices=4)
    for marker in ("OK-RESUME", "OK-TYPED", "OK-MIGRATE"):
        assert marker in out, out


def test_migrate_state_layout_host_side():
    """The layout converter needs no mesh: replicated -> owner pads with
    the program's inert fills (+inf values / 0 delta / False frontier
    for SSSP's MIN), owner -> replicated slices them back off, real
    vertex bytes untouched; degenerate inputs raise typed errors."""
    from repro.resilience import migrate_state_layout

    n = 10
    rng = np.random.default_rng(0)
    ck = RunCheckpoint(
        program="sssp", iterations=3,
        values=rng.random(n).astype(np.float32),
        delta=rng.random(n).astype(np.float32),
        frontier=rng.random(n) > 0.5, n_nodes=n)
    own = migrate_state_layout(ck, "owner", n_devices=4)
    assert own.state_layout == "owner" and own.n_nodes == n
    assert own.values.shape == (12,)  # ceil(10/4)*4
    np.testing.assert_array_equal(own.values[:n], ck.values)
    assert np.all(np.isinf(own.values[n:]))
    assert not own.delta[n:].any() and not own.frontier[n:].any()
    back = migrate_state_layout(own, "replicated")
    np.testing.assert_array_equal(back.values, ck.values)
    np.testing.assert_array_equal(back.delta, ck.delta)
    np.testing.assert_array_equal(back.frontier, ck.frontier)
    assert migrate_state_layout(ck, "replicated") is ck  # no-op
    try:
        migrate_state_layout(ck, "sharded")
        raise AssertionError("expected ValueError on unknown layout")
    except ValueError:
        pass
    try:
        migrate_state_layout(dataclasses.replace(own, n_nodes=0),
                             "replicated")
        raise AssertionError("expected CheckpointError without n_nodes")
    except CheckpointError:
        pass
    try:
        migrate_state_layout(
            dataclasses.replace(ck, program="nope"), "owner", n_devices=2)
        raise AssertionError("expected CheckpointError on unknown program")
    except CheckpointError:
        pass


def test_checkpoint_schema_v1_still_restores(tmp_path):
    """A pre-owner-sharding (schema 1) checkpoint — no ``state_layout``
    or ``n_nodes`` metadata — still restores, defaulting to the
    replicated layout, so old checkpoints keep resuming on replicated
    runs after the schema bump."""
    import json
    import zlib

    vals = np.arange(5, dtype=np.float32)
    crc = zlib.crc32(np.ascontiguousarray(vals).tobytes())
    meta = {"schema": 1, "program": "sssp", "iterations": 2,
            "graph_version": 0, "layout_version": 0, "calibrator": None,
            "crc": {"values": crc}}
    path = tmp_path / "v1.ckpt.npz"
    np.savez(path, values=vals,
             __meta__=np.frombuffer(json.dumps(meta).encode(), np.uint8))
    back = restore(path, expect_anchor=(0, 0), program="sssp")
    assert back.state_layout == "replicated" and back.n_nodes == 0
    np.testing.assert_array_equal(back.values, vals)


# --------------------------------------------------------------------------
# warm cache: corrupt spilled entry -> detected, evicted, recomputed
def test_warm_cache_bit_flip_detected():
    g, base = _graph()
    n = g.n_nodes
    svc = GraphService(g, CFG, max_lanes=2, device_budget_bytes=2 * 9 * n)
    svc.query(SSSP, [0, 3, 77, 210])
    from repro.serve.warm_cache import HOST

    spilled = [(k, e) for k, e in svc.cache.items() if e.tier == HOST]
    assert spilled, "budget did not force a spill"
    key, entry = spilled[0]
    entry.values = entry.values.copy()
    entry.values.reshape(-1).view(np.uint8)[5] ^= 0x80
    before = svc.cache.stats.corrupt
    r = svc.query(SSSP, [key[1]])[0]
    assert svc.cache.stats.corrupt == before + 1
    assert key not in svc.cache or svc.cache.peek(key).tier != HOST
    solo = run_hytm(g, SSSP, source=key[1], config=CFG)
    np.testing.assert_array_equal(r.values, solo.values)


def test_injected_spill_corruption_recovers():
    g, base = _graph()
    n = g.n_nodes
    plan = plan_of(FaultSpec("host_spill", "corrupt", at=(0,)), seed=9)
    svc = GraphService(g, CFG, max_lanes=2, device_budget_bytes=2 * 9 * n,
                       faults=plan)
    svc.query(SSSP, [0, 3, 77, 210])
    r = svc.query(SSSP, [0])[0]
    np.testing.assert_array_equal(r.values, base.values)
    assert plan.counts().get(("host_spill", "corrupt")) == 1
    assert svc.cache.stats.corrupt + svc.cache.stats.promote_failures >= 0


# --------------------------------------------------------------------------
# delta_csr: atomic rejection of invalid batches
def _snapshot(dcsr):
    return (dcsr.version, dcsr.layout_version,
            dcsr._src.copy(), dcsr._dst.copy(), dcsr._w.copy(),
            dcsr.counts.copy(), set(dcsr.dirty))


def _assert_snapshot_equal(dcsr, snap):
    v, lv, src, dst, w, counts, dirty = snap
    assert dcsr.version == v and dcsr.layout_version == lv
    np.testing.assert_array_equal(dcsr._src, src)
    np.testing.assert_array_equal(dcsr._dst, dst)
    np.testing.assert_array_equal(dcsr._w, w)
    np.testing.assert_array_equal(dcsr.counts, counts)
    assert dcsr.dirty == dirty


@settings(max_examples=8, deadline=None)
@given(bad_kind=st.integers(min_value=0, max_value=4),
       salt=st.integers(min_value=0, max_value=10**6))
def test_invalid_batch_rejected_atomically(bad_kind, salt):
    g, _ = _graph()
    from repro.stream import DeltaCSR

    dcsr = DeltaCSR(g, CFG)
    rng = np.random.default_rng(salt)
    good = random_batch(dcsr, rng, n_insert=4, n_delete=2)
    n = dcsr.n_nodes
    bad = {
        0: EdgeBatch(np.array([OP_INSERT]), np.array([1]),
                     np.array([n + 5]), np.array([1.0], np.float32)),
        1: EdgeBatch(np.array([OP_INSERT]), np.array([-2]),
                     np.array([1]), np.array([1.0], np.float32)),
        2: EdgeBatch(np.array([OP_INSERT]), np.array([0]), np.array([1]),
                     np.array([np.nan], np.float32)),
        3: EdgeBatch(np.array([OP_REWEIGHT]), np.array([0]), np.array([1]),
                     np.array([np.inf], np.float32)),
        4: EdgeBatch(np.array([99]), np.array([0]), np.array([1]),
                     np.array([1.0], np.float32)),
    }[bad_kind]
    mixed = EdgeBatch(
        np.concatenate([good.op, bad.op]),
        np.concatenate([good.src, bad.src]),
        np.concatenate([good.dst, bad.dst]),
        np.concatenate([good.weight, bad.weight]),
    )
    snap = _snapshot(dcsr)
    for batch in (bad, mixed):
        try:
            dcsr.apply(batch)
            raise AssertionError("expected InvalidBatchError")
        except InvalidBatchError as e:
            assert e.index >= 0
        _assert_snapshot_equal(dcsr, snap)
    dcsr.apply(good)  # the good prefix alone still applies
    assert dcsr.version == snap[0] + 1


def test_delete_of_absent_rejected_sequence_aware():
    g, _ = _graph()
    from repro.stream import DeltaCSR

    dcsr = DeltaCSR(g, CFG)
    s, d, _ = dcsr.live_edges()
    live = {(int(u), int(v)) for u, v in zip(s, d)}
    absent = next((u, v) for u in range(g.n_nodes) for v in range(3)
                  if (u, v) not in live and u != v)
    ops = EdgeBatch(np.array([OP_DELETE]), np.array([absent[0]]),
                    np.array([absent[1]]), np.array([0.0], np.float32))
    snap = _snapshot(dcsr)
    try:
        dcsr.apply(ops)
        raise AssertionError("expected InvalidBatchError")
    except InvalidBatchError:
        pass
    _assert_snapshot_equal(dcsr, snap)
    # insert-then-delete of the same absent edge in ONE batch is valid
    ok = EdgeBatch(np.array([OP_INSERT, OP_DELETE]),
                   np.array([absent[0], absent[0]]),
                   np.array([absent[1], absent[1]]),
                   np.array([1.0, 0.0], np.float32))
    dcsr.apply(ok)
    assert dcsr.version == snap[0] + 1


# --------------------------------------------------------------------------
# exactly-once update delivery
def test_deliver_update_drop_and_duplicate():
    g, _ = _graph()
    svc = GraphService(g, CFG, max_lanes=2)
    rng = np.random.default_rng(1)
    batch = random_batch(svc.dcsr, rng, n_insert=6, n_delete=6)
    plan = plan_of(FaultSpec("update_delivery", "drop", at=(0,)),
                   FaultSpec("update_redeliver", "duplicate", at=(0,)),
                   seed=2)
    rep = deliver_update(svc, batch, batch_id="b0", faults=plan,
                         policy=RetryPolicy(max_attempts=3, backoff_s=0.0))
    assert svc.dcsr.version == 1 and rep.version == 1
    assert plan.counts() == {("update_delivery", "drop"): 1,
                             ("update_redeliver", "duplicate"): 1}
    # explicit redelivery of the same batch_id: cached report, no bump
    rep2 = svc.update(batch, batch_id="b0")
    assert rep2.version == 1 and svc.dcsr.version == 1
    # drop with no retry budget surfaces as RetriesExhausted
    plan2 = plan_of(FaultSpec("update_delivery", "drop", p=1.0), seed=3)
    try:
        deliver_update(svc, batch, batch_id="b1", faults=plan2,
                       policy=RetryPolicy(max_attempts=2, backoff_s=0.0))
        raise AssertionError("expected RetriesExhausted")
    except RetriesExhausted as e:
        assert e.site == "update_delivery"
    assert svc.dcsr.version == 1


# --------------------------------------------------------------------------
# degradation ladder + load shedding
def test_supervisor_kernels_rung_degrade():
    g, base = _graph()
    plan = plan_of(FaultSpec("chunk_dispatch", "fail", p=1.0, max_fires=64,
                             when={"kernels": True}), seed=11)
    cfgk = dataclasses.replace(CFG, use_kernels=True)
    sup = Supervisor(policy=RetryPolicy(max_attempts=2, backoff_s=0.0),
                     faults=plan)
    res = run_supervised(g, SSSP, source=0, config=cfgk, supervisor=sup)
    np.testing.assert_array_equal(base.values, res.values)
    assert [r for r, _ in sup.degradations] == ["kernels->oracle"]
    # the when= filter stopped firing once the oracle path took over
    fires = sum(plan.counts().values())
    assert 0 < fires < 64


def test_lane_alloc_oom_sheds_lowest_tier_only():
    g, _ = _graph()
    plan = plan_of(FaultSpec("lane_alloc", "oom", p=1.0, max_fires=100),
                   seed=4)
    sup = Supervisor(policy=RetryPolicy(max_attempts=2, backoff_s=0.0),
                     faults=plan, tenant_tiers={"gold": 2, "bronze": 0},
                     shed_after=2)
    svc = GraphService(g, CFG, max_lanes=4, faults=plan, supervisor=sup)
    q = RequestQueue(quota=1)
    for i, s in enumerate([0, 3, 77, 210, 9, 15]):
        q.submit(Request(tenant=["gold", "bronze"][i % 2], program=SSSP,
                         source=s, deadline=float(i)))
    served = svc.scheduler.pump(q)
    assert len(served) == 6 and q.stats.quota_violations == 0
    shed = [r for r in served if r.mode == "shed"]
    assert shed and all(r.request.tenant == "bronze" for r in shed)
    assert sup.counters["shed"] == len(shed) == q.stats.shed
    for r in served:
        if r.mode != "shed":
            solo = run_hytm(g, SSSP, source=r.request.source, config=CFG)
            np.testing.assert_array_equal(r.values, solo.values)


# --------------------------------------------------------------------------
# autotune registry: corrupt profile falls back to shipped constants
def test_registry_corrupt_profile_falls_back(tmp_path, monkeypatch):
    from repro.autotune.registry import load_profile_or_default
    from repro.core.constants import PCIE3

    monkeypatch.setenv("REPRO_AUTOTUNE_REGISTRY", str(tmp_path))
    kind = "fakedev"
    # missing: silent fallback
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert load_profile_or_default(kind) is PCIE3
    for garbage in ("{not json",
                    '{"schema": 1, "profile": {"name": "x"}}',
                    '{"schema": 99, "profile": {}}',
                    '[]'):
        (tmp_path / f"{kind}.json").write_text(garbage)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            link = load_profile_or_default(kind)
        assert link is PCIE3
        assert any(issubclass(w.category, RuntimeWarning) for w in caught), (
            garbage)
