"""Sharded warm-start equivalence suite.

The contract under test (the PR that lifted the single-device asserts):

* ``run_hytm(initial_state=...)`` with ``config.mesh_axis`` set resumes
  the shard_mapped chunked driver from an arbitrary ``HyTMState`` — and
  the warm sharded run is **bit-identical** to the warm single-device
  ``async_sweep=False`` run for MIN programs: values, iteration count,
  modeled transfer bytes, per-iteration engine picks (padding partitions
  stay NONE).  Tolerance-bounded for SUM programs.
* ``DeltaCSR.sharded_runtime_for`` keeps the device-sharded (P_pad, B)
  edge grid in lock-step with the single-device buffers across
  insert/delete batches (patched by scatter, no re-blocking), so the
  equivalence above holds across ≥3 sequential random update batches,
  K ∈ {1, 4}, autotune on and off.
* warm-started sharded recomputation takes strictly fewer iterations
  than a cold sharded restart on ≤1% update batches.
* the sharded ICI accounting of a warm run is chunk-size invariant
  (K=1 == K=4 ici_bytes rows, autotune off).
* ``GraphService`` with ``config.mesh_axis`` serves from the mesh:
  lane-batched queries, cache hits, and incremental refreshes are
  bit-identical to the single-device service.
* the unsupported-path guards raise real exceptions, not bare asserts —
  they must still fire under ``python -O`` (assertions stripped).
"""

import numpy as np
import pytest

from _forced_devices import run_forced_devices
from repro.core.hytm import HyTMConfig, run_hytm
from repro.graph.algorithms import SSSP
from repro.graph.generators import rmat_graph
from repro.stream import DeltaCSR, random_batch, run_incremental


def test_sharded_warm_start_smoke_single_device_mesh():
    """In-process smoke (1-device mesh): the sharded warm path accepts an
    initial_state and matches the plain single-device warm run bit-exactly
    — collection-time coverage without a forced-host subprocess."""
    g = rmat_graph(300, 2400, seed=2)
    cfg1 = HyTMConfig(n_partitions=4, async_sweep=False)
    cfgS = HyTMConfig(n_partitions=4, async_sweep=False, mesh_axis="graph")
    dc = DeltaCSR(g, cfg1)
    warm = run_hytm(None, SSSP, source=0, config=cfg1,
                    runtime=dc.runtime_for(SSSP))
    rep = dc.apply(random_batch(dc, np.random.default_rng(2), n_insert=8,
                                n_delete=8))
    inc1 = run_incremental(dc, SSSP, [rep], warm.values, warm.delta,
                           source=0, config=cfg1)
    incS = run_incremental(dc, SSSP, [rep], warm.values, warm.delta,
                           source=0, config=cfgS)
    np.testing.assert_array_equal(inc1.values, incS.values)
    assert inc1.iterations == incS.iterations
    assert inc1.total_transfer_bytes == incS.total_transfer_bytes
    np.testing.assert_array_equal(
        inc1.history["engines"],
        incS.history["engines"][:, :dc.n_partitions])


_SHARDED_WARM_SCRIPT = """
    import dataclasses
    import numpy as np
    from repro.core.hytm import HyTMConfig, run_hytm
    from repro.graph.algorithms import PAGERANK, SSSP
    from repro.graph.generators import rmat_graph
    from repro.stream import DeltaCSR, GraphService, random_batch, \\
        run_incremental

    g = rmat_graph(400, 3200, seed=11)
    results = {}

    # ---- MIN: warm sharded == warm single-device, K x autotune grid ----
    for K in (1, 4):
        for autotune in (False, True):
            cfg1 = HyTMConfig(n_partitions=8, async_sweep=False,
                              sync_every=K, autotune=autotune)
            cfgS = dataclasses.replace(cfg1, mesh_axis="graph")
            dc = DeltaCSR(g, cfg1)
            rtS = dc.sharded_runtime_for(SSSP, axis="graph")
            warm = run_hytm(None, SSSP, source=0, config=cfg1,
                            runtime=dc.runtime_for(SSSP))
            rng = np.random.default_rng(100)  # same batches for every cfg
            for b in range(3):
                rep = dc.apply(random_batch(dc, rng, n_insert=8, n_delete=8))
                n_changed = len(rep.ins_src) + len(rep.del_src)
                assert n_changed <= 0.01 * 2 * g.n_edges, n_changed
                inc1 = run_incremental(dc, SSSP, [rep], warm.values,
                                       warm.delta, source=0, config=cfg1)
                incS = run_incremental(dc, SSSP, [rep], warm.values,
                                       warm.delta, source=0, config=cfgS)
                # MIN fixpoints are unique: values bit-exact even under
                # autotune (corrections only resteer engine choices)
                np.testing.assert_array_equal(inc1.values, incS.values)
                if not autotune:
                    assert inc1.iterations == incS.iterations
                    assert (inc1.total_transfer_bytes
                            == incS.total_transfer_bytes)
                    np.testing.assert_array_equal(
                        inc1.history["engines"],
                        incS.history["engines"][:, : dc.n_partitions])
                    assert (incS.history["engines"][:, dc.n_partitions:]
                            == -1).all()  # padding rows stay NONE
                    results[(K, b)] = incS
                # strictly fewer iterations than a cold sharded restart
                cold = run_hytm(None, SSSP, source=0, config=cfgS,
                                runtime=rtS)
                np.testing.assert_array_equal(cold.values, incS.values)
                assert incS.iterations < cold.iterations, \\
                    (incS.iterations, cold.iterations)
                warm = inc1
            print("OK-MIN", K, "autotune" if autotune else "plain")

    # ---- ICI accounting of the warm run is chunk-size invariant ----
    for b in range(3):
        a, c = results[(1, b)], results[(4, b)]
        assert a.iterations == c.iterations
        np.testing.assert_array_equal(
            a.history["ici_bytes"], c.history["ici_bytes"])
        assert a.total_ici_bytes == c.total_ici_bytes
        assert a.total_ici_bytes > 0  # the merge really is charged
    print("OK-ICI")

    # ---- SUM: tolerance-bounded warm equivalence ----
    pr = dataclasses.replace(PAGERANK, tolerance=1e-6)
    cfg1 = HyTMConfig(n_partitions=8, async_sweep=False, sync_every=4,
                      cds_mode="delta")
    cfgS = dataclasses.replace(cfg1, mesh_axis="graph")
    dc = DeltaCSR(g, cfg1)
    warm = run_hytm(None, pr, source=None, config=cfg1,
                    runtime=dc.runtime_for(pr))
    rng = np.random.default_rng(7)
    rep = dc.apply(random_batch(dc, rng, n_insert=8, n_delete=8))
    inc1 = run_incremental(dc, pr, [rep], warm.values, warm.delta,
                           source=None, config=cfg1)
    incS = run_incremental(dc, pr, [rep], warm.values, warm.delta,
                           source=None, config=cfgS)
    np.testing.assert_allclose(inc1.values + inc1.delta,
                               incS.values + incS.delta, rtol=0, atol=1e-5)
    fs = run_hytm(dc.to_host_graph(), pr, source=None, config=cfg1)
    np.testing.assert_allclose(incS.values + incS.delta,
                               fs.values + fs.delta, rtol=0, atol=1e-3)
    print("OK-SUM")

    # ---- GraphService on the mesh == single-device service ----
    cfg1 = HyTMConfig(n_partitions=8, async_sweep=False, sync_every=4)
    cfgS = dataclasses.replace(cfg1, mesh_axis="graph")
    s1 = GraphService(g, cfg1, max_lanes=2)
    sS = GraphService(g, cfgS, max_lanes=2)
    sources = [0, 7, 33]
    for a, b in zip(s1.query(SSSP, sources), sS.query(SSSP, sources)):
        np.testing.assert_array_equal(a.values, b.values)
        assert a.iterations == b.iterations
    rng1, rngS = np.random.default_rng(5), np.random.default_rng(5)
    s1.update(random_batch(s1.dcsr, rng1, n_insert=10, n_delete=10))
    sS.update(random_batch(sS.dcsr, rngS, n_insert=10, n_delete=10))
    post1, postS = s1.query(SSSP, sources), sS.query(SSSP, sources)
    assert all(r.mode == "incremental" for r in postS)
    for a, b in zip(post1, postS):
        np.testing.assert_array_equal(a.values, b.values)
        assert a.iterations == b.iterations
    assert all(r.cache_hit for r in sS.query(SSSP, sources))
    print("OK-SERVICE")
"""


def test_sharded_warm_equivalence_4dev():
    """The full contract on 4 forced-host devices (see module
    docstring): MIN bit-exact x {K, autotune} x 3 batches, fewer
    iterations than cold restart, chunk-size-invariant ICI accounting,
    SUM tolerance-bounded, service parity."""
    out = run_forced_devices(_SHARDED_WARM_SCRIPT, devices=4)
    assert out.count("OK-MIN") == 4, out
    for marker in ("OK-ICI", "OK-SUM", "OK-SERVICE"):
        assert marker in out, out


_OWNER_SERVE_SCRIPT = """
    import dataclasses
    import numpy as np
    import jax
    assert len(jax.devices()) == {devices}, jax.devices()
    from repro.core.hytm import HyTMConfig
    from repro.graph.algorithms import ALGORITHMS, BFS, SSSP
    from repro.graph.generators import rmat_graph
    from repro.stream import GraphService, random_batch

    KCORE = ALGORITHMS["kcore"]
    g = rmat_graph(600, 5000, seed=11)
    n_dev = len(jax.devices())
    n_loc = -(-g.n_nodes // n_dev)

    cfg_owner = HyTMConfig(n_partitions=16, async_sweep=False,
                           mesh_axis="graph", sync_every=4,
                           vertex_sharding="owner")
    cfg_rep = dataclasses.replace(cfg_owner, vertex_sharding="replicated")
    cfg_solo = dataclasses.replace(cfg_owner, mesh_axis=None,
                                   vertex_sharding="replicated")

    svc_o = GraphService(g, config=cfg_owner, max_lanes=4)
    svc_r = GraphService(g, config=cfg_rep, max_lanes=4)
    svc_s = GraphService(g, config=cfg_solo, max_lanes=4)

    # ---- cold lane-batched queries: owner == replicated == solo ----
    srcs = [0, 5, 9, 17, 23, 31]
    ro, rr, rs = (s.query(BFS, srcs) for s in (svc_o, svc_r, svc_s))
    for a, b, c in zip(ro, rr, rs):
        assert a.values.shape == (600,), a.values.shape
        np.testing.assert_array_equal(a.values, b.values)
        np.testing.assert_array_equal(a.values, c.values)
        assert a.iterations == b.iterations == c.iterations
    print("OK-COLD", [r.iterations for r in ro])

    # repeat = cache hits (host_values + owner placement round trip)
    ro2 = svc_o.query(BFS, srcs)
    assert all(r.cache_hit for r in ro2)
    for a, b in zip(ro2, ro):
        np.testing.assert_array_equal(a.values, b.values)
    print("OK-HITS")

    # ---- update batches + incremental warm recompute ----
    rng = np.random.default_rng(3)
    batch = random_batch(svc_s.dcsr, rng, n_insert=120, n_delete=60)
    for svc in (svc_o, svc_r, svc_s):
        svc.update(batch)
    for a, c in zip(svc_o.query(SSSP, srcs), svc_s.query(SSSP, srcs)):
        np.testing.assert_array_equal(a.values, c.values)
    svc_o.query(SSSP, srcs)  # warm the cache for the incremental path
    batch2 = random_batch(svc_s.dcsr, rng, n_insert=80, n_delete=40)
    for svc in (svc_o, svc_s):
        svc.update(batch2)
    ro5, rs5 = svc_o.query(SSSP, srcs), svc_s.query(SSSP, srcs)
    modes = sorted(set(r.mode for r in ro5))
    assert "incremental" in modes, modes
    for a, c in zip(ro5, rs5):
        np.testing.assert_array_equal(a.values, c.values)
    print("OK-INCREMENTAL", modes)

    # ---- peeling routes down the global path (lanes would call
    # init_state, which peel programs forbid) ----
    ko = svc_o.query(KCORE, [None])
    ks = svc_s.query(KCORE, [None])
    np.testing.assert_array_equal(ko[0].values, ks[0].values)
    assert ko[0].mode == "batched"
    assert svc_o.query(KCORE, [3])[0].cache_hit  # source collapses to None
    print("OK-KCORE", ko[0].iterations)

    # ---- lane_bytes is the per-device owned slice ----
    assert svc_o.scheduler.lane_bytes == 9 * n_loc, \\
        (svc_o.scheduler.lane_bytes, n_loc)
    assert svc_s.scheduler.lane_bytes == 9 * 600
    print("OK-LANE-BYTES", svc_o.scheduler.lane_bytes)

    # ---- tiny budget: owner-entry spill -> promote round trip ----
    # per entry the device tier holds 8*n_loc bytes (values+delta f32,
    # owned slice), two lanes pin 2*9*n_loc: 40*n_loc holds the lanes
    # plus ~2.7 of the 6 entries, forcing spills, then promotes on reuse
    svc_t = GraphService(g, config=cfg_owner, max_lanes=2,
                         device_budget_bytes=40 * n_loc)
    svc_u = GraphService(g, config=cfg_solo, max_lanes=2)
    svc_t.query(BFS, srcs)
    assert svc_t.cache.stats.spills > 0, svc_t.cache.stats.as_dict()
    b = random_batch(svc_u.dcsr, np.random.default_rng(9),
                     n_insert=50, n_delete=30)
    svc_t.update(b); svc_u.update(b)
    rt2, ru2 = svc_t.query(BFS, srcs), svc_u.query(BFS, srcs)
    assert svc_t.cache.stats.promotions > 0, svc_t.cache.stats.as_dict()
    for a, c in zip(rt2, ru2):
        np.testing.assert_array_equal(a.values, c.values)
    print("OK-SPILL-PROMOTE", svc_t.cache.stats.spills,
          svc_t.cache.stats.promotions)
"""


@pytest.mark.parametrize("devices", [16])
def test_owner_sharded_service_16dev(devices):
    """The 16-device owner-sharding leg: ``GraphService`` with
    ``vertex_sharding="owner"`` serves cold lane batches, cache hits,
    update batches, and incremental warm recomputes bit-identically to
    both the replicated mesh service and the single-device service,
    while lane state and warm-cache entries are budgeted at the owned
    ``ceil(n/D)`` slice; spilled owner entries promote back bit-exactly
    and peel programs route down the global (non-lane) path."""
    out = run_forced_devices(_OWNER_SERVE_SCRIPT.format(devices=devices),
                             devices=devices)
    for marker in ("OK-COLD", "OK-HITS", "OK-INCREMENTAL", "OK-KCORE",
                   "OK-LANE-BYTES", "OK-SPILL-PROMOTE"):
        assert marker in out, out


_GUARDS_SCRIPT = """
    import numpy as np
    from repro.core.hytm import HyTMConfig, run_hytm
    from repro.graph.algorithms import BFS
    from repro.graph.generators import rmat_graph
    from repro.stream import DeltaCSR, EdgeBatch

    def expect(fn, exc):
        try:
            fn()
        except exc:
            return
        raise SystemExit(f"guard did not fire: {fn}")

    g = rmat_graph(50, 200, seed=0)

    # sync_every guard, single-device driver
    expect(lambda: run_hytm(g, BFS, source=0,
                            config=HyTMConfig(sync_every=0)), ValueError)
    # sync_every guard, sharded driver
    expect(lambda: run_hytm(
        g, BFS, source=0,
        config=HyTMConfig(sync_every=0, async_sweep=False,
                          mesh_axis="graph")), ValueError)
    # no graph and no runtime
    expect(lambda: run_hytm(None, BFS, source=0, config=HyTMConfig()),
           ValueError)
    # ragged EdgeBatch
    expect(lambda: EdgeBatch(np.zeros(2, np.int32), np.zeros(1, np.int64),
                             np.zeros(2, np.int64), np.zeros(2, np.float32)),
           ValueError)
    # sharded view without a mesh axis
    expect(lambda: DeltaCSR(g, HyTMConfig()).sharded_runtime_for(BFS),
           ValueError)
    # mesh without the configured axis
    from repro.dist.graph_shard import build_sharded_runtime
    from repro.launch.mesh import make_graph_mesh
    mesh = make_graph_mesh(axis="graph")
    expect(lambda: build_sharded_runtime(
        g, HyTMConfig(mesh_axis="nope"), mesh), ValueError)
    print("GUARDS-OK", __debug__)
"""


def test_guards_fire_with_assertions_disabled():
    """The unsupported-path guards are raised exceptions, not bare
    asserts: under ``python -O`` (assertions stripped, ``__debug__`` is
    False) every guard still fires."""
    out = run_forced_devices(_GUARDS_SCRIPT, devices=1, python_flags=("-O",),
                          timeout=240)
    assert "GUARDS-OK False" in out, out
