"""Per-assigned-architecture smoke tests: a REDUCED config of the same
family (same structural features, small dims) runs one train step on CPU;
asserts output shapes + no NaNs.  Full configs are exercised only via the
dry-run (abstract lowering, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.models import gnn as gnn_mod
from repro.models import transformer as tf_mod
from repro.models.dlrm import DLRMConfig, dlrm_loss, init_dlrm
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import init_train_state, make_train_step

OPT = OptimizerConfig(learning_rate=1e-3, warmup_steps=0, schedule="constant")


from repro.configs.common import reduce_lm_config as _reduce_lm


LM_ARCHS = [
    "kimi-k2-1t-a32b", "deepseek-v2-lite-16b", "internlm2-1.8b",
    "granite-20b", "gemma3-12b",
]


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_arch_smoke(name):
    arch = get_arch(name)
    cfg = _reduce_lm(arch.model_config)
    # structural features preserved
    assert (cfg.moe is None) == (arch.model_config.moe is None)
    assert cfg.attention == arch.model_config.attention
    assert (cfg.n_kv_heads == 1) == (arch.model_config.n_kv_heads == 1)
    params = tf_mod.init_transformer(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    state = init_train_state(params, OPT)
    step = jax.jit(make_train_step(lambda p, b: tf_mod.lm_loss(p, b["tokens"], cfg), OPT))
    state, metrics = step(state, {"tokens": toks})
    assert bool(jnp.isfinite(metrics["loss"]))
    logits, _, _ = tf_mod.forward(params, toks, cfg)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


GNN_ARCHS = ["graphsage-reddit", "pna", "gatedgcn", "meshgraphnet"]


@pytest.mark.parametrize("name", GNN_ARCHS)
def test_gnn_arch_smoke(name):
    arch = get_arch(name)
    base: gnn_mod.GNNConfig = arch.model_config
    cfg = base.replace(n_layers=min(base.n_layers, 3), d_hidden=24, d_in=12,
                       d_out=5 if base.task != "regression" else 3)
    assert cfg.arch == base.arch and cfg.aggregator == base.aggregator
    from repro.graph.generators import rmat_graph

    g = rmat_graph(128, 700, seed=41)
    src, dst = jnp.asarray(g.edge_sources()), jnp.asarray(g.indices)
    feats = jax.random.normal(jax.random.PRNGKey(0), (128, 12))
    if cfg.task == "regression":
        labels = jax.random.normal(jax.random.PRNGKey(1), (128, 3))
    else:
        labels = jax.random.randint(jax.random.PRNGKey(1), (128,), 0, 5)
    params = gnn_mod.init_gnn(jax.random.PRNGKey(2), cfg)
    state = init_train_state(params, OPT)
    step = jax.jit(make_train_step(
        lambda p, b: gnn_mod.gnn_loss(p, cfg, b["f"], b["s"], b["d"], b["y"]), OPT))
    state, metrics = step(state, {"f": feats, "s": src, "d": dst, "y": labels})
    assert bool(jnp.isfinite(metrics["loss"]))
    out = gnn_mod.gnn_forward(params, cfg, feats, src, dst)
    assert out.shape == (128, cfg.d_out) and bool(jnp.all(jnp.isfinite(out)))


def test_dlrm_arch_smoke():
    base: DLRMConfig = get_arch("dlrm-mlperf").model_config
    cfg = base.replace(vocab_sizes=(64, 3, 50, 7, 100), embed_dim=16,
                       bot_mlp=(32, 16), top_mlp=(32, 1))
    assert cfg.interaction == base.interaction and cfg.n_dense == 13
    params = init_dlrm(jax.random.PRNGKey(0), cfg)
    dense = jax.random.normal(jax.random.PRNGKey(1), (16, 13))
    sparse = jax.random.randint(jax.random.PRNGKey(2), (16, 5), 0, 3)
    labels = jax.random.bernoulli(jax.random.PRNGKey(3), 0.25, (16,))
    state = init_train_state(params, OPT)
    step = jax.jit(make_train_step(
        lambda p, b: dlrm_loss(p, b["d"], b["s"], b["y"], cfg), OPT))
    state, metrics = step(state, {"d": dense, "s": sparse, "y": labels})
    assert bool(jnp.isfinite(metrics["loss"]))


def test_registry_covers_assignment():
    archs = set(list_archs())
    required = set(LM_ARCHS + GNN_ARCHS + ["dlrm-mlperf"])
    assert required <= archs
    # 40 assigned cells: every arch enumerates 4 shapes (cells + skips)
    total = 0
    for a in required:
        spec = get_arch(a)
        assert len(spec.shapes()) == 4, a
        total += len(spec.shapes())
    assert total == 40
