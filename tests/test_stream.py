"""repro.stream — container semantics + the incremental equivalence
contract: warm-start recomputation after random insert/delete batches
matches from-scratch ``run_hytm`` on the post-update graph (bit-exact
for MIN programs, tolerance-bounded for SUM), across ≥3 sequential
update batches."""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.hytm import HyTMConfig, run_hytm
from repro.graph.algorithms import ALGORITHMS, PAGERANK, SSSP
from repro.graph.generators import rmat_graph
from repro.stream import (
    DeltaCSR,
    EdgeBatch,
    InvalidBatchError,
    random_batch,
    run_incremental,
)

CFG = HyTMConfig(n_partitions=6)
PR = dataclasses.replace(PAGERANK, tolerance=1e-7)


# --------------------------------------------------------------------------
# DeltaCSR container semantics
# --------------------------------------------------------------------------

def _edge_multiset(g_or_dcsr):
    if isinstance(g_or_dcsr, DeltaCSR):
        s, d, w = g_or_dcsr.live_edges()
    else:
        s, d, w = (
            g_or_dcsr.edge_sources(),
            g_or_dcsr.indices,
            g_or_dcsr.weights,
        )
    return sorted(zip(s.tolist(), d.tolist(), w.tolist()))


def test_delta_csr_patch_and_versioning():
    g = rmat_graph(200, 1600, seed=4)
    dc = DeltaCSR(g, CFG)
    assert dc.version == 0 and dc.layout_version == 0
    assert _edge_multiset(dc) == _edge_multiset(g)

    ref = _edge_multiset(g)
    # insert two edges, delete one known edge, reweight another — pick
    # (src, dst) pairs without parallel duplicates so the reference
    # multiset model is unambiguous about which edge the op matched
    from collections import Counter
    pair_counts = Counter((s, d) for s, d, _ in ref)
    uniq = [t for t in ref if pair_counts[(t[0], t[1])] == 1]
    s0, d0, w0 = uniq[0]
    s1, d1, _ = uniq[1]
    batch = EdgeBatch(
        op=np.array([0, 0, 1, 2]),
        src=np.array([5, 9, s0, s1]),
        dst=np.array([6, 2, d0, d1]),
        weight=np.array([3.0, 4.0, 0.0, 9.5], np.float32),
    )
    rep = dc.apply(batch)
    assert dc.version == 1 and not rep.merged and dc.layout_version == 0
    assert set(rep.dirty_partitions) <= set(range(dc.n_partitions))
    ref.remove((s0, d0, w0))
    old = next(t for t in ref if t[0] == s1 and t[1] == d1)
    ref.remove(old)
    ref += [(5, 6, 3.0), (9, 2, 4.0), (s1, d1, 9.5)]
    assert _edge_multiset(dc) == sorted(ref)
    # device mirror agrees with the host log
    assert _edge_multiset(dc.to_host_graph()) == sorted(ref)
    np.testing.assert_array_equal(
        np.asarray(dc.parts.part_edges), dc.counts
    )
    # degrees track the live multiset
    assert int(np.asarray(dc.csr.out_degree)[5]) == sum(
        1 for t in ref if t[0] == 5
    )

    # deleting a non-existent edge is rejected atomically: typed error,
    # no version bump, edge multiset untouched
    with pytest.raises(InvalidBatchError):
        dc.apply(EdgeBatch.deletes([s0], [d0]))
    assert dc.version == 1
    assert _edge_multiset(dc) == sorted(ref)


def test_delta_csr_overflow_merges():
    g = rmat_graph(100, 800, seed=5)
    dc = DeltaCSR(g, HyTMConfig(n_partitions=2), slack=0.0, min_slack=1)
    # flood one source vertex until its partition block overflows
    k = dc.block_size + 8
    batch = EdgeBatch.inserts(
        np.zeros(k, np.int64), np.arange(k) % 100, np.ones(k, np.float32)
    )
    rep = dc.apply(batch)
    assert rep.merged and dc.layout_version == 1
    assert dc.n_edges == 800 + k
    assert len(rep.dirty_partitions) == dc.n_partitions
    # converges correctly on the rebuilt layout
    res = run_hytm(None, SSSP, source=0, config=CFG,
                   runtime=dc.runtime_for(SSSP))
    fs = run_hytm(dc.to_host_graph(), SSSP, source=0, config=CFG)
    np.testing.assert_array_equal(res.values, fs.values)


# --------------------------------------------------------------------------
# Incremental equivalence (property)
# --------------------------------------------------------------------------

def _sequential_batches(dc, program, source, seed, n_batches=3, scale=10):
    """Apply ``n_batches`` random batches; after each, incremental must
    match from-scratch on the post-update graph."""
    rng = np.random.default_rng(seed)
    warm = run_hytm(None, program, source=source, config=CFG,
                    runtime=dc.runtime_for(program))
    for _ in range(n_batches):
        rep = dc.apply(random_batch(
            dc, rng,
            n_insert=int(rng.integers(1, scale)),
            n_delete=int(rng.integers(1, scale)),
            n_reweight=int(rng.integers(0, scale // 2 + 1)),
        ))
        inc = run_incremental(dc, program, [rep], warm.values, warm.delta,
                              source=source, config=CFG)
        fs = run_hytm(dc.to_host_graph(), program, source=source, config=CFG)
        if program.combine == 0:
            np.testing.assert_array_equal(inc.values, fs.values)
        else:
            np.testing.assert_allclose(
                inc.values + inc.delta, fs.values + fs.delta, atol=1e-3
            )
        warm = inc


@settings(max_examples=3, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    prog=st.sampled_from(["sssp", "bfs"]),
)
def test_incremental_matches_scratch_min(seed, prog):
    g = rmat_graph(300, 2400, seed=seed % 3)
    dc = DeltaCSR(g, CFG)
    _sequential_batches(dc, ALGORITHMS[prog], 0, seed)


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_incremental_matches_scratch_sum(seed):
    g = rmat_graph(300, 2400, seed=seed % 3)
    dc = DeltaCSR(g, CFG)
    _sequential_batches(dc, PR, None, seed)


# --------------------------------------------------------------------------
# Regression: small batches must win
# --------------------------------------------------------------------------

# --------------------------------------------------------------------------
# seg_start refresh: Eq. 3 alignment drift
# --------------------------------------------------------------------------

def _aligned_zc_req(dc):
    """The zero-copy request counts of the layout the next
    merge-compaction would realize: every partition's segments packed
    dense in vertex order (live-degree prefix-sum) — the drift oracle."""
    import jax.numpy as jnp

    from repro.core.cost_model import zc_request_counts

    seg = np.empty(dc.n_nodes, np.int64)
    B = dc.block_size
    for p in range(dc.n_partitions):
        v0, v1 = int(dc.vertex_start[p]), int(dc.vertex_start[p + 1])
        if v1 <= v0:
            continue
        deg = dc.out_deg[v0:v1].astype(np.int64)
        seg[v0:v1] = p * B + np.concatenate(([0], np.cumsum(deg[:-1])))
    return np.asarray(zc_request_counts(
        jnp.asarray(dc.out_deg, jnp.int32), jnp.asarray(seg, jnp.int32),
        dc.config.link,
    ))


def test_seg_start_refresh_removes_cost_model_drift():
    """Delete-heavy batch sequences drift the frozen seg_start away from
    the live layout: the Eq. 3 alignment term then mispredicts zero-copy
    requests.  ``refresh_seg_start=True`` (default) re-derives dirty
    partitions per patch and must track the aligned oracle exactly;
    the frozen flag reproduces (and quantifies) the historical drift."""
    g = rmat_graph(400, 3200, seed=6)
    cfg = HyTMConfig(n_partitions=6)
    fresh = DeltaCSR(g, cfg)  # refresh_seg_start=True
    frozen = DeltaCSR(g, cfg, refresh_seg_start=False)
    rng_a, rng_b = np.random.default_rng(6), np.random.default_rng(6)
    drift_fresh = drift_frozen = 0.0
    for _ in range(4):
        ba = random_batch(fresh, rng_a, n_insert=2, n_delete=60)
        bb = random_batch(frozen, rng_b, n_insert=2, n_delete=60)
        np.testing.assert_array_equal(ba.src, bb.src)  # same sequence
        ra, rb = fresh.apply(ba), frozen.apply(bb)
        assert not ra.merged and not rb.merged
        drift_fresh += float(np.abs(
            np.asarray(fresh.zc_req) - _aligned_zc_req(fresh)).sum())
        drift_frozen += float(np.abs(
            np.asarray(frozen.zc_req) - _aligned_zc_req(frozen)).sum())
    # identical edge multisets — only the alignment model differs
    assert _edge_multiset(fresh) == _edge_multiset(frozen)
    assert drift_fresh == 0.0, drift_fresh
    assert drift_frozen > 0.0  # the drift the refresh removes


def test_incremental_fewer_iterations_on_small_batches():
    """On update batches of <=1% of the edges, the warm-started run must
    take strictly fewer sweep iterations than from-scratch."""
    g = rmat_graph(800, 8000, seed=9)
    dc = DeltaCSR(g, HyTMConfig(n_partitions=8))
    cfg = dc.config
    rng = np.random.default_rng(9)
    warm = run_hytm(None, SSSP, source=0, config=cfg,
                    runtime=dc.runtime_for(SSSP))
    for _ in range(3):
        rep = dc.apply(random_batch(dc, rng, n_insert=40, n_delete=40))
        assert len(rep.ins_src) + len(rep.del_src) <= 0.01 * 2 * g.n_edges
        inc = run_incremental(dc, SSSP, [rep], warm.values, warm.delta,
                              source=0, config=cfg)
        fs = run_hytm(dc.to_host_graph(), SSSP, source=0, config=cfg)
        np.testing.assert_array_equal(inc.values, fs.values)
        assert inc.iterations < fs.iterations, (inc.iterations, fs.iterations)
        warm = inc
