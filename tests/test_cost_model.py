"""HyTM cost model (Eqs. 1-3), Algorithm-1 selection, task combination."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.constants import PCIE3, TPU_V5E_HBM
from repro.core.cost_model import (
    COMPACT,
    FILTER,
    NONE,
    ZEROCOPY,
    PartitionStats,
    engine_costs,
    modeled_transfer_bytes,
    select_engines,
)
from repro.core.task_generation import _merged_filter_tasks, forced_engine_plan, generate_tasks


def _stats(E, Ea, A, req):
    return PartitionStats(
        active_edges=jnp.asarray(Ea, jnp.float32),
        active_vertices=jnp.asarray(A, jnp.float32),
        zc_requests=jnp.asarray(req, jnp.float32),
        total_edges=jnp.asarray(E, jnp.float32),
    )


def test_inactive_partitions_skipped():
    s = _stats([1000, 1000], [0, 10], [0, 5], [0, 5])
    eng = select_engines(s, engine_costs(s, PCIE3), PCIE3)
    assert int(eng[0]) == NONE and int(eng[1]) != NONE


def test_high_activeness_prefers_filter():
    # nearly all edges active: filter (paper §III-C "Prefer" curve)
    s = _stats([100_000], [95_000], [5_000], [95_000 / 32 + 2_000])
    eng = select_engines(s, engine_costs(s, PCIE3), PCIE3)
    assert int(eng[0]) == FILTER


def test_sparse_high_degree_prefers_zerocopy():
    # few active vertices with large degree: EMOGI's regime (Table III)
    s = _stats([1_000_000], [3200], [10], [110])
    eng = select_engines(s, engine_costs(s, PCIE3), PCIE3)
    assert int(eng[0]) == ZEROCOPY


def test_sparse_low_degree_prefers_compaction():
    # many active vertices, small average degree: compaction's regime.
    # Each vertex needs its own (unsaturated) zc request: req ~ A.
    s = _stats([1_000_000], [6000], [3000], [3000.0])
    eng = select_engines(s, engine_costs(s, PCIE3), PCIE3)
    assert int(eng[0]) == COMPACT


def test_fig4_toy_graph_zerocopy_instability():
    """Paper Fig. 4: same active-edge ratio, different active-vertex
    counts => different zero-copy cost (6 requests vs 3)."""
    # 128-edge graph, two 64-edge subsets; d1=4, m=128 -> 32 nbrs/request
    green = _stats([128], [64], [6], [6.0])   # 6 small-degree vertices
    gray = _stats([128], [64], [3], [3.0])    # 3 large-degree vertices
    cg = engine_costs(green, PCIE3)
    cy = engine_costs(gray, PCIE3)
    assert float(cg.tiz[0]) >= float(cy.tiz[0])
    # filter cost identical (whole-subset transfer)
    assert float(cg.tef[0]) == float(cy.tef[0])


@settings(deadline=None, max_examples=40)
@given(
    E=st.integers(1, 10**7),
    frac=st.floats(0.0, 1.0),
    A=st.integers(0, 10**5),
    seed=st.integers(0, 100),
)
def test_cost_monotonicity_property(E, frac, A, seed):
    Ea = int(E * frac)
    req = max(A, Ea * 4 // 128) if Ea > 0 else 0
    s = _stats([E], [Ea], [min(A, Ea)], [req])
    c = engine_costs(s, PCIE3)
    # compaction transfer never exceeds filter transfer + index overhead
    # (+2 transaction groups of slack for fp32 ceil interplay)
    group = PCIE3.m * PCIE3.mr
    idx_overhead = (min(A, Ea) * PCIE3.d2 / group + 2) * PCIE3.rtt
    assert float(c.tec[0]) <= float(c.tef[0]) + idx_overhead
    # all costs nonnegative, zero-activeness costs zero for tec/tiz
    assert float(c.tec[0]) >= 0 and float(c.tiz[0]) >= 0
    if Ea == 0:
        assert float(c.tiz[0]) == 0.0


def test_merged_filter_tasks_k4():
    # runs of consecutive FILTER partitions merge into ceil(len/4) tasks
    is_f = jnp.asarray([1, 1, 1, 1, 1, 0, 1, 1, 0, 1, 1, 1, 1, 1], bool)
    # runs: 5 -> 2 tasks; 2 -> 1; 5 -> 2  == 5 tasks
    assert int(_merged_filter_tasks(is_f, 4)) == 5


def test_task_combination_reduces_tasks():
    link = PCIE3.with_(mr=4.0)  # fine groups: no rounding ties at toy scale
    E = [1000] * 8
    Ea = [900] * 8  # all filter
    s = _stats(E, Ea, [100] * 8, [100] * 8)
    with_tc = generate_tasks(s, link, enable_combination=True)
    without = generate_tasks(s, link, enable_combination=False)
    assert int(with_tc.n_tasks) == 2  # 8 consecutive filter / k=4
    assert int(without.n_tasks) == 8


def test_forced_engine_plan_matches_table6_accounting():
    s = _stats([1000, 1000], [100, 100], [10, 10], [12, 12])
    for eng, expected in [
        (FILTER, 2 * 1000 * PCIE3.d1),
        (COMPACT, 2 * (100 * PCIE3.d1 + 10 * PCIE3.d2)),
        (ZEROCOPY, 2 * 12 * PCIE3.m),
    ]:
        plan = forced_engine_plan(s, PCIE3, eng)
        assert float(jnp.sum(plan.transfer_bytes)) == pytest.approx(expected)


def test_dense_partitions_never_zerocopy():
    """Regression (Eqs. 1 vs 3 at full activeness): with every vertex
    active, the per-vertex request rounding + misalignment terms make
    REQ_i * rtt_zc strictly exceed the dense stream, so ``generate_tasks``
    must never select ZEROCOPY for any partition of a real graph.

    Uses fine transaction groups (mr=4, as the CPU-scale benchmarks do):
    the paper-scale mr=256 rounds toy partitions to a single group for
    every engine, and at an exact Tef == Tiz tie Algorithm 1 legitimately
    returns ZC."""
    from repro.core.cost_model import partition_stats, zc_request_counts
    from repro.core.partition import partition_graph, to_device_partitions
    from repro.graph.csr import to_device_csr
    from repro.graph.generators import rmat_graph

    link = PCIE3.with_(mr=4.0)
    for seed in (3, 17, 99):
        g = rmat_graph(1200, 9000, seed=seed)
        table = partition_graph(g, n_partitions=12)
        csr = to_device_csr(g)
        parts = to_device_partitions(table, g.n_nodes, csr.capacity)
        zc_req = zc_request_counts(csr.out_degree, csr.seg_start, link)
        frontier = jnp.ones(g.n_nodes, bool)  # all vertices active
        stats = partition_stats(frontier, csr.out_degree, zc_req, parts)
        plan = generate_tasks(stats, link)
        engines = np.asarray(plan.engines)
        assert not np.any(engines == ZEROCOPY), engines
        # every non-empty partition is processed
        assert np.all((engines != NONE) == (np.asarray(stats.active_edges) > 0))


def test_sparse_never_filter_when_zc_models_cheaper():
    """Algorithm-1 regression at the Tef/Tiz decision boundary: whenever
    the modeled zero-copy time is at or below the modeled filter time the
    selection must not be FILTER (it picks ZEROCOPY, or COMPACT when the
    compaction thresholds fire)."""
    rng = np.random.default_rng(0)
    for _ in range(200):
        E = int(rng.integers(1_000, 5_000_000))
        Ea = int(rng.integers(1, E + 1))
        A = int(rng.integers(1, Ea + 1))
        req = float(rng.integers(1, max(2, Ea // 8)))
        s = _stats([E], [Ea], [A], [req])
        c = engine_costs(s, PCIE3)
        eng = int(select_engines(s, c, PCIE3)[0])
        if float(c.tiz[0]) <= float(c.tef[0]):
            assert eng != FILTER, (E, Ea, A, req, c)


def test_selection_monotone_in_zc_requests():
    """Sweeping REQ_i upward through the boundary (all else fixed) the
    selection flips ZEROCOPY -> FILTER exactly once — Eq. 3 is monotone
    in the request count, so there is a single crossing.  Ea is kept close
    to E so the compaction bytes track the filter bytes and Algorithm 1's
    COMPACT thresholds stay out of the picture."""
    E, Ea, A = 200_000, 190_000, 50_000
    picked = []
    for req in np.linspace(1, 4 * E * PCIE3.d1 / PCIE3.m, 80):
        s = _stats([E], [Ea], [A], [float(req)])
        eng = int(select_engines(s, engine_costs(s, PCIE3), PCIE3)[0])
        picked.append(eng)
    assert picked[0] == ZEROCOPY and picked[-1] == FILTER
    assert COMPACT not in picked
    flips = sum(1 for a, b in zip(picked, picked[1:]) if a != b)
    assert flips == 1, picked


def test_tpu_link_model_compaction_pass_charged():
    s = _stats([100_000], [50_000], [1000], [2000])
    c_tpu = engine_costs(s, TPU_V5E_HBM)
    c_free = engine_costs(s, TPU_V5E_HBM.with_(compaction_bandwidth=0.0))
    assert float(c_tpu.tec[0]) > float(c_free.tec[0])
