"""GraphService acceptance contract: lane-batched queries equal
independent single-source runs, cached repeats cost zero sweep
iterations, and update batches invalidate the cache into warm
incremental recomputes."""

import dataclasses

import numpy as np

from repro.core.hytm import HyTMConfig, run_hytm
from repro.graph.algorithms import BFS, PAGERANK, SSSP
from repro.graph.generators import rmat_graph
from repro.stream import EdgeBatch, GraphService, random_batch

CFG = HyTMConfig(n_partitions=8)


def _service(seed=13, n=400, m=3200, lanes=3):
    g = rmat_graph(n, m, seed=seed)
    return g, GraphService(g, CFG, max_lanes=lanes)


def test_batched_queries_match_independent_runs():
    """Q multiplexed sources == Q standalone runs, bit-exact — including
    a source count that does not divide the lane width."""
    g, svc = _service()
    sources = [0, 11, 42, 123, 250]  # 5 sources over 3 lanes -> 2 chunks
    res = svc.query(SSSP, sources)
    assert [r.source for r in res] == sources
    for s, r in zip(sources, res):
        solo = run_hytm(g, SSSP, source=s, config=CFG)
        np.testing.assert_array_equal(r.values, solo.values)
        assert r.mode == "batched" and not r.cache_hit


def test_cached_repeat_is_zero_iterations():
    g, svc = _service()
    first = svc.query(BFS, [0, 7])
    assert all(r.iterations > 0 for r in first)
    again = svc.query(BFS, [7, 0])
    for r in again:
        assert r.cache_hit and r.iterations == 0 and r.mode == "cache"
    for a, b in zip(first, reversed(again)):
        np.testing.assert_array_equal(a.values, b.values)
    assert svc.stats.n_cache_hits == 2


def test_duplicate_sources_share_one_computation():
    _, svc = _service()
    res = svc.query(SSSP, [5, 5, 5])
    np.testing.assert_array_equal(res[0].values, res[2].values)
    assert svc.stats.n_full == 1


def test_update_invalidates_and_incremental_matches():
    g, svc = _service()
    sources = [0, 33]
    svc.query(SSSP, sources)
    rng = np.random.default_rng(3)
    rep = svc.update(random_batch(svc.dcsr, rng, n_insert=10, n_delete=10))
    assert svc.version == 1 and rep.version == 1

    post = svc.query(SSSP, sources)
    g2 = svc.dcsr.to_host_graph()
    for s, r in zip(sources, post):
        assert r.mode == "incremental" and not r.cache_hit
        fs = run_hytm(g2, SSSP, source=s, config=CFG)
        np.testing.assert_array_equal(r.values, fs.values)

    # and the refreshed results are cached at the new version
    again = svc.query(SSSP, sources)
    assert all(r.cache_hit for r in again)


def test_accumulative_program_is_global_and_incremental():
    pr = dataclasses.replace(PAGERANK, tolerance=1e-7)
    g, svc = _service()
    r1 = svc.query(pr, None)[0]
    # any requested source keys to the same global entry
    r2 = svc.query(pr, [17])[0]
    assert r2.cache_hit and r2.iterations == 0
    np.testing.assert_array_equal(r1.values, r2.values)

    rng = np.random.default_rng(5)
    svc.update(random_batch(svc.dcsr, rng, n_insert=6, n_delete=6))
    r3 = svc.query(pr, None)[0]
    assert r3.mode == "incremental"
    fs = run_hytm(svc.dcsr.to_host_graph(), pr, source=None, config=CFG)
    assert np.max(np.abs(r3.values - fs.values)) < 1e-3
    assert r3.iterations < fs.iterations


def test_program_variants_do_not_share_cache_entries():
    """Two programs differing only in parameters (e.g. tolerance) must
    not serve each other's converged results as cache hits."""
    _, svc = _service()
    loose = dataclasses.replace(PAGERANK, tolerance=1e-3)
    tight = dataclasses.replace(PAGERANK, tolerance=1e-7)
    r_loose = svc.query(loose, None)[0]
    r_tight = svc.query(tight, None)[0]
    assert not r_tight.cache_hit and r_tight.iterations > r_loose.iterations
    # each variant still hits its own entry
    assert svc.query(loose, None)[0].cache_hit
    assert svc.query(tight, None)[0].cache_hit


def test_reports_are_pruned_once_warm_states_catch_up():
    _, svc = _service()
    rng = np.random.default_rng(7)
    svc.query(SSSP, [0])
    for _ in range(4):
        svc.update(random_batch(svc.dcsr, rng, n_insert=4, n_delete=4))
    assert len(svc._reports) == 4
    svc.query(SSSP, [0])  # incremental refresh raises the floor to v4
    assert len(svc._reports) == 0


def test_abandoned_entry_cannot_grow_report_memory():
    """One stale cache entry that is never re-queried must not pin the
    report list forever: past ``max_reports`` the oldest reports drop and
    entries too stale to replay the retained suffix are evicted — their
    next query falls back to a correct full recompute, while a
    periodically refreshed entry keeps its incremental path."""
    g = rmat_graph(300, 2400, seed=8)
    svc = GraphService(g, CFG, max_lanes=2, max_reports=4)
    svc.query(SSSP, [0, 7])  # both cached at v0; source 0 then abandoned
    rng = np.random.default_rng(8)
    for _ in range(3):
        svc.update(random_batch(svc.dcsr, rng, n_insert=3, n_delete=3))
    refreshed = svc.query(SSSP, [7])[0]  # source 7 stays warm (v3)
    assert refreshed.mode == "incremental"
    for _ in range(4):  # reports v4..v7; v1..v3 (needed only by v0) age out
        svc.update(random_batch(svc.dcsr, rng, n_insert=3, n_delete=3))
    assert len(svc._reports) <= 4
    assert (SSSP, 0) not in svc._cache   # evicted: floor no longer pinned
    assert (SSSP, 7) in svc._cache       # still replayable from v3

    g2 = svc.dcsr.to_host_graph()
    q7 = svc.query(SSSP, [7])[0]
    assert q7.mode == "incremental"
    q0 = svc.query(SSSP, [0])[0]
    assert q0.mode == "batched" and not q0.cache_hit
    for s, r in ((7, q7), (0, q0)):
        fs = run_hytm(g2, SSSP, source=s, config=CFG)
        np.testing.assert_array_equal(r.values, fs.values)


def test_incremental_disabled_falls_back_to_full():
    g = rmat_graph(300, 2400, seed=2)
    svc = GraphService(g, CFG, max_lanes=2, incremental=False)
    svc.query(SSSP, [0])
    svc.update(EdgeBatch.inserts([0], [5], [2.0]))
    r = svc.query(SSSP, [0])[0]
    assert r.mode == "batched"
    fs = run_hytm(svc.dcsr.to_host_graph(), SSSP, source=0, config=CFG)
    np.testing.assert_array_equal(r.values, fs.values)
