"""Graph algorithms vs numpy references + scheduling behaviour."""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.hytm import HyTMConfig, run_hytm
from repro.graph.algorithms import (
    BFS,
    CC,
    KCORE,
    PAGERANK,
    PHP,
    SSSP,
    WCC,
    reference_bfs,
    reference_cc,
    reference_kcore,
    reference_pagerank,
    reference_sssp,
    reference_wcc,
)
from repro.graph.csr import csr_from_edges
from repro.graph.generators import grid_mesh_graph, rmat_graph, uniform_graph
from repro.graph.hub_sort import hub_sort

GRAPHS = [
    ("rmat", lambda: rmat_graph(800, 6000, seed=21)),
    ("uniform", lambda: uniform_graph(500, 3000, seed=22)),
    ("mesh", lambda: grid_mesh_graph(16, 16, seed=23)),
]


@pytest.mark.parametrize("name,make", GRAPHS)
def test_sssp(name, make):
    g = make()
    res = run_hytm(g, SSSP, source=0, config=HyTMConfig(n_partitions=12))
    assert np.allclose(res.values, reference_sssp(g, 0))


@pytest.mark.parametrize("name,make", GRAPHS)
def test_bfs(name, make):
    g = make()
    res = run_hytm(g, BFS, source=0, config=HyTMConfig(n_partitions=12))
    assert np.allclose(res.values, reference_bfs(g, 0))


@pytest.mark.parametrize("name,make", GRAPHS)
def test_cc(name, make):
    g = make()
    res = run_hytm(g.symmetrize(), CC, source=None, config=HyTMConfig(n_partitions=12))
    assert np.allclose(res.values, reference_cc(g))


@pytest.mark.parametrize("name,make", GRAPHS)
def test_wcc(name, make):
    """WCC runs on the *directed* graph directly (program.symmetrize
    makes run_hytm build the runtime over the undirected edge set) and
    matches the union-find oracle."""
    g = make()
    res = run_hytm(g, WCC, source=None, config=HyTMConfig(n_partitions=12))
    ref = reference_wcc(g)
    assert np.array_equal(np.asarray(res.values, np.int64), ref)
    # labels are the min vertex id of each component
    assert np.all(ref <= np.arange(g.n_nodes))


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(4, 120),
    m=st.integers(0, 600),
    seed=st.integers(0, 10_000),
)
def test_wcc_oracle_matches_label_propagation(n, m, seed):
    """Property: the union-find WCC oracle agrees with the independent
    min-label-propagation CC oracle (which symmetrizes internally) on
    random graphs — two different fixpoint constructions, same labels."""
    g = uniform_graph(n, max(m, 1), seed=seed)
    assert np.array_equal(reference_wcc(g), reference_cc(g))


@pytest.mark.parametrize("name,make", GRAPHS)
def test_kcore(name, make):
    """k-core peeling (k=2): Δ is the removed flag, values the remaining
    effective degree — bit-identical to the synchronous NumPy oracle
    (unit removal counts are exact integers in f32)."""
    g = make()
    res = run_hytm(g, KCORE, source=None, config=HyTMConfig(n_partitions=12))
    removed, deg = reference_kcore(g, 2.0)
    np.testing.assert_array_equal(np.asarray(res.delta) > 0.5, removed)
    np.testing.assert_array_equal(res.values, deg)


def test_kcore_cascade_peels_path_graph():
    """A path graph is the worst-case cascade: only the endpoints start
    below k=2, and each round's removal exposes the next vertex in, so
    peeling takes ~n/2 rounds and ends with every vertex removed."""
    n = 40
    src = np.arange(n - 1, dtype=np.int64)
    g = csr_from_edges(n, src, src + 1, None)
    res = run_hytm(g, KCORE, source=None, config=HyTMConfig(n_partitions=4))
    removed, deg = reference_kcore(g, 2.0)
    assert removed.all()
    assert res.iterations >= n // 2 - 1  # multi-round cascade, not one shot
    np.testing.assert_array_equal(np.asarray(res.delta) > 0.5, removed)
    np.testing.assert_array_equal(res.values, deg)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(4, 100),
    m=st.integers(0, 500),
    seed=st.integers(0, 10_000),
    k=st.integers(1, 5),
)
def test_kcore_oracle_property(n, m, seed, k):
    """Property: the device peeling program matches the NumPy oracle for
    random graphs across k — removal set and remaining degrees both —
    and the survivors really form a k-core (alive ⇒ alive-degree ≥ k on
    the symmetrized graph)."""
    g = uniform_graph(n, max(m, 1), seed=seed)
    prog = dataclasses.replace(KCORE, peel_k=float(k))
    res = run_hytm(g, prog, source=None, config=HyTMConfig(n_partitions=4))
    removed, deg = reference_kcore(g, float(k))
    got_removed = np.asarray(res.delta) > 0.5
    np.testing.assert_array_equal(got_removed, removed)
    np.testing.assert_array_equal(res.values, deg)
    # independent invariant check: count alive neighbors directly
    sym = g.symmetrize()
    alive = ~removed
    alive_deg = np.zeros(g.n_nodes)
    es, ed = sym.edge_sources(), sym.indices
    keep = alive[es] & alive[ed]
    np.add.at(alive_deg, ed[keep], 1.0)
    assert np.all(alive_deg[alive] >= k)


@pytest.mark.parametrize("name,make", GRAPHS)
def test_pagerank(name, make):
    g = make()
    prog = dataclasses.replace(PAGERANK, tolerance=1e-7)
    res = run_hytm(g, prog, source=None, config=HyTMConfig(n_partitions=12))
    ref = reference_pagerank(g)
    assert np.max(np.abs(res.values + res.delta - ref)) < 1e-3


def test_php_converges():
    g = rmat_graph(300, 2000, seed=24)
    prog = dataclasses.replace(PHP, tolerance=1e-6)
    res = run_hytm(g, prog, source=None, config=HyTMConfig(n_partitions=8))
    assert res.iterations < HyTMConfig().max_iters
    assert np.all(np.isfinite(res.values))


def test_hub_sort_run_maps_back():
    g = rmat_graph(600, 5000, seed=25)
    hs = hub_sort(g)
    cfg = HyTMConfig(n_partitions=12, cds_mode="hub")
    src_new = int(hs.perm[0])
    res = run_hytm(hs.graph, SSSP, source=src_new, config=cfg, n_hubs=hs.n_hubs)
    back = hs.values_to_old(res.values)
    assert np.allclose(back, reference_sssp(g, 0))


def test_delta_cds_reduces_iterations():
    g = rmat_graph(2000, 16000, seed=26)
    prog = dataclasses.replace(PAGERANK, tolerance=1e-6)
    base = run_hytm(g, prog, source=None,
                    config=HyTMConfig(n_partitions=16, cds_mode="none", recompute_once=False))
    cds = run_hytm(g, prog, source=None,
                   config=HyTMConfig(n_partitions=16, cds_mode="delta", recompute_once=True))
    ref = reference_pagerank(g)
    assert np.max(np.abs(cds.values + cds.delta - ref)) < 1e-2
    assert cds.iterations <= base.iterations  # Fig-8 CDS effect


def test_history_records_engine_mix():
    g = rmat_graph(1000, 8000, seed=27)
    res = run_hytm(g, SSSP, source=0, config=HyTMConfig(n_partitions=16))
    eng = res.history["engines"]
    assert eng.shape == (res.iterations, 16)
    assert set(np.unique(eng)).issubset({-1, 0, 1, 2})
    assert res.total_transfer_bytes > 0
