"""repro.obs: recorder semantics, chrome-trace schema, zero-overhead
no-op contract, and exact metrics-vs-HyTMResult reconciliation."""

import json

import numpy as np
import pytest

from repro.core.hytm import HyTMConfig, run_hytm
from repro.graph.algorithms import SSSP
from repro.graph.generators import rmat_graph
from repro.obs import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NullRecorder,
    TraceRecorder,
    reconcile,
    summary,
    to_chrome_trace,
    validate_chrome_trace,
    write_chrome_trace,
    write_jsonl,
)

CFG = HyTMConfig(n_partitions=8, sync_every=4)
CFG1 = HyTMConfig(n_partitions=8, sync_every=1)


@pytest.fixture(scope="module")
def graph():
    return rmat_graph(600, 4_800, seed=9)


# --------------------------------------------------------------------------
# recorder primitives
# --------------------------------------------------------------------------

def test_recorder_ring_is_bounded():
    rec = TraceRecorder(capacity=4)
    for i in range(10):
        rec.instant("e", vt=float(i))
    assert len(rec) == 4
    assert rec.dropped == 6
    # oldest events fell off the ring; the survivors are the newest
    assert [e.vt for e in rec.events] == [6.0, 7.0, 8.0, 9.0]


def test_recorder_drain_empties_and_preserves_order():
    rec = TraceRecorder()
    rec.span("s", wall=0.1, wall_dur=0.2)
    rec.instant("i", vt=1.0)
    rec.counter("c", 3.0)
    drained = rec.drain()
    assert [e.name for e in drained] == ["s", "i", "c"]
    assert [e.ph for e in drained] == ["X", "i", "C"]
    assert len(rec) == 0


def test_null_recorder_is_inert():
    rec = NullRecorder()
    rec.span("s", wall=0.0)
    rec.instant("i")
    rec.counter("c", 1.0)
    with rec.timed("t"):
        pass
    assert len(rec) == 0 and not rec.enabled
    assert rec.drain() == []


def test_metrics_registry():
    m = MetricsRegistry()
    c = m.counter("bytes", "transferred bytes")
    c.inc(10, engine="filter")
    c.inc(5, engine="filter")
    c.inc(7, engine="compact")
    assert c.value(engine="filter") == 15
    assert c.total() == 22
    g = m.gauge("occ", "occupancy")
    g.set(0.5)
    g.set(0.25)
    assert g.value() == 0.25 and g.max() == 0.5
    h = m.histogram("frontier", "active vertices")
    for v in (1, 10, 100):
        h.observe(v)
    assert h.count() == 3 and h.sum() == 111
    # same name resolves to the same instrument; type mismatch raises
    assert m.counter("bytes", "") is c
    with pytest.raises(TypeError):
        m.gauge("bytes", "")
    snap = m.snapshot()
    assert set(snap) == {"bytes", "occ", "frontier"}
    assert isinstance(Counter("x", ""), Counter)
    assert isinstance(Gauge("x", ""), Gauge)
    assert isinstance(Histogram("x", ""), Histogram)


# --------------------------------------------------------------------------
# chrome trace schema
# --------------------------------------------------------------------------

def test_chrome_trace_schema_and_tracks():
    rec = TraceRecorder()
    rec.span("run", cat="run", track="device0", wall=0.0, wall_dur=1.0,
             vt=0.0, vt_dur=5.0)
    rec.instant("it", cat="iteration", track="device0", vt=1.0)
    rec.counter("frontier", 42.0, track="device0", vt=1.0)
    rec.span("request:batched", cat="serve", track="tenant:gold",
             wall=0.1, wall_dur=0.2)
    doc = to_chrome_trace(rec)
    validate_chrome_trace(doc)
    events = doc["traceEvents"]
    # per-track thread metadata + stable tid assignment
    meta = [e for e in events if e["ph"] == "M"]
    names = {m["args"]["name"] for m in meta if m["name"] == "thread_name"}
    assert {"device0", "tenant:gold"} <= names
    tids = {e["tid"] for e in events if e["ph"] != "M"}
    assert len(tids) == 2
    # ts is microseconds of the wall clock; vt rides in args
    run_ev = next(e for e in events if e["name"] == "run")
    assert run_ev["ts"] == 0.0 and run_ev["dur"] == pytest.approx(1e6)
    assert run_ev["args"]["vt_dur"] == 5.0
    # serialized form is valid JSON end to end
    json.loads(json.dumps(doc))


def test_validate_rejects_malformed():
    doc = to_chrome_trace(TraceRecorder())
    doc["traceEvents"].append({"name": "bad", "ph": "Z", "pid": 1,
                               "tid": 1, "ts": 0.0})
    with pytest.raises(ValueError):
        validate_chrome_trace(doc)
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [
            {"name": "x", "ph": "X", "pid": 1, "tid": 1,
             "ts": float("nan"), "dur": 0.0}]})


def test_write_chrome_trace_and_jsonl(tmp_path):
    rec = TraceRecorder()
    rec.instant("e", vt=1.0, note="hello")
    p = tmp_path / "trace.json"
    write_chrome_trace(rec, str(p))
    doc = json.loads(p.read_text())
    validate_chrome_trace(doc)
    pj = tmp_path / "trace.jsonl"
    write_jsonl(rec, str(pj))
    lines = [json.loads(l) for l in pj.read_text().splitlines()]
    assert lines and lines[0]["name"] == "e"


# --------------------------------------------------------------------------
# engine integration: no-op exactness, nesting, reconciliation
# --------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", [CFG, CFG1], ids=["chunked", "K=1"])
def test_traced_run_bit_identical_and_reconciles(graph, cfg):
    base = run_hytm(graph, SSSP, source=0, config=cfg)
    rec = TraceRecorder()
    traced = run_hytm(graph, SSSP, source=0, config=cfg, obs=rec)
    # obs=None vs obs=recorder: identical jit programs, identical outputs
    np.testing.assert_array_equal(base.values, traced.values)
    assert base.iterations == traced.iterations
    assert base.total_transfer_bytes == traced.total_transfer_bytes
    np.testing.assert_array_equal(base.history["engines"],
                                  traced.history["engines"])
    # exact reconciliation: trace totals == HyTMResult accounting
    rep = reconcile(rec, traced)
    assert rep["ok"], rep
    assert rep["checks"]["iterations"]["trace"] == traced.iterations
    assert (rep["checks"]["transfer_bytes"]["trace"]
            == traced.total_transfer_bytes)


def test_null_recorder_matches_none(graph):
    a = run_hytm(graph, SSSP, source=0, config=CFG, obs=None)
    b = run_hytm(graph, SSSP, source=0, config=CFG, obs=NullRecorder())
    np.testing.assert_array_equal(a.values, b.values)
    assert a.iterations == b.iterations


def test_span_nesting_invariants(graph):
    """Chunk spans nest inside the run span on both clocks, and the
    per-iteration instants tile the run's virtual-clock interval."""
    rec = TraceRecorder()
    res = run_hytm(graph, SSSP, source=0, config=CFG, obs=rec)
    runs = [e for e in rec.events if e.name == "hytm_run"]
    assert len(runs) == 1
    run_ev = runs[0]
    eps = 1e-9
    chunks = [e for e in rec.events if e.name == "chunk"]
    assert chunks and all(c.track == run_ev.track for c in chunks)
    for c in chunks:
        assert c.wall >= run_ev.wall - eps
        assert c.wall + c.wall_dur <= run_ev.wall + run_ev.wall_dur + eps
        assert c.vt >= run_ev.vt
        assert c.vt + c.vt_dur <= run_ev.vt + run_ev.vt_dur
    # chunk vt intervals are disjoint and cover exactly [0, iterations)
    ivs = sorted((c.vt, c.vt + c.vt_dur) for c in chunks)
    assert ivs[0][0] == 0 and ivs[-1][1] == res.iterations
    for (_, a_end), (b_start, _) in zip(ivs, ivs[1:]):
        assert a_end == b_start
    its = sorted(e.vt for e in rec.events if e.cat == "iteration")
    assert its == list(np.arange(res.iterations, dtype=float))


def test_metrics_match_result_accounting(graph):
    rec = TraceRecorder()
    res = run_hytm(graph, SSSP, source=0, config=CFG, obs=rec)
    m = rec.metrics
    assert m.get("engine.iterations").total() == res.iterations
    # per-engine byte counters sum to the result's transfer total
    # (float64 row-sum accumulation; exact for these magnitudes)
    assert m.get("engine.bytes").total() == res.total_transfer_bytes
    assert (m.get("engine.mispredictions").total()
            == res.total_mispredictions)
    picks = m.get("engine.picks")
    assert picks.total() == np.sum(
        np.asarray(res.history["engines"]) >= 0)
    s = summary(rec)
    assert s["events"] == len(rec) and s["dropped"] == 0
    assert "device0" in s["tracks"]


def test_reconcile_detects_mismatch(graph):
    rec = TraceRecorder()
    res = run_hytm(graph, SSSP, source=0, config=CFG, obs=rec)
    # a second run into the same recorder doubles the trace-side totals
    run_hytm(graph, SSSP, source=0, config=CFG, obs=rec)
    rep = reconcile(rec, res)
    assert not rep["ok"]
