import os
import sys

# tests run against the source tree; single CPU device (the dry-run and
# the distributed tests manage their own device counts via subprocesses)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# ----------------------------------------------------------------------
# hypothesis guard: several modules property-test with hypothesis.  When
# it is genuinely unavailable (hermetic containers without the package)
# install the deterministic fallback sampler so those modules still
# collect and run; if even that fails, skip them with a clear message
# instead of erroring the whole collection.
_HYPOTHESIS_MODE = "real"
try:
    import hypothesis  # noqa: F401
except ImportError:
    try:
        sys.path.insert(0, os.path.dirname(__file__))
        import _hypothesis_fallback

        _hypothesis_fallback.install()
        _HYPOTHESIS_MODE = "fallback"
    except Exception:
        _HYPOTHESIS_MODE = "missing"
        # hypothesis unavailable and the fallback shim broke: skip the
        # property-based modules rather than failing collection.
        collect_ignore = [
            "test_autotune.py",
            "test_cost_model.py",
            "test_engines.py",
            "test_graph.py",
            "test_serve.py",
            "test_stream.py",
        ]


def pytest_report_header(config):
    if _HYPOTHESIS_MODE == "fallback":
        return (
            "hypothesis: not installed — property tests run via the "
            "deterministic fixed-seed fallback (tests/_hypothesis_fallback.py); "
            "install hypothesis for real property testing"
        )
    if _HYPOTHESIS_MODE == "missing":
        return (
            "hypothesis: not installed and fallback unavailable — "
            "skipping property-based test modules (test_autotune, "
            "test_cost_model, test_engines, test_graph, test_stream)"
        )
    return None
