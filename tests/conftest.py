import os
import sys

# tests run against the source tree; single CPU device (the dry-run and
# the distributed tests manage their own device counts via subprocesses)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
