"""repro.autotune: calibration, registry round-trip, regret contract,
and the online feedback loop (ISSUE 3 acceptance criteria)."""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.autotune import (
    OnlineCalibrator,
    calibrate,
    default_grid,
    fit_link,
    load_profile,
    model_probe,
    observation_matrix,
    save_profile,
    selection_on_grid,
    stats_for,
    total_regret,
)
from repro.core.constants import PCIE3, TPU_V5E_HBM, LinkModel
from repro.core.cost_model import (
    NONE,
    engine_costs,
    modeled_best_engines,
    select_engines,
)

GRID = default_grid()


# ----------------------------------------------------------------- validation

def test_linkmodel_validation_d1_divides_m():
    with pytest.raises(ValueError, match="divide"):
        LinkModel(name="bad", d1=3.0, m=128.0)


def test_linkmodel_validation_unit_interval():
    for field in ("alpha", "beta", "gamma"):
        with pytest.raises(ValueError, match=field):
            LinkModel(name="bad", **{field: 0.0})
        with pytest.raises(ValueError, match=field):
            LinkModel(name="bad", **{field: 1.5})


def test_linkmodel_validation_positive():
    with pytest.raises(ValueError, match="bandwidth"):
        LinkModel(name="bad", bandwidth=0.0)
    with pytest.raises(ValueError, match="launch_overhead_s"):
        LinkModel(name="bad", launch_overhead_s=-1e-6)
    # shipped profiles are all valid (construction is the check)
    assert PCIE3.rtt > 0 and TPU_V5E_HBM.rtt > 0


# ------------------------------------------------------------------ registry

def test_profile_json_roundtrip_identical_selection(tmp_path):
    obs = model_probe(GRID, TPU_V5E_HBM)
    rep = calibrate(GRID, obs, PCIE3)
    save_profile(rep.profile, device_kind="test", base=tmp_path,
                 meta={"static_regret": rep.static_regret})
    loaded, meta = load_profile(device_kind="test", base=tmp_path, with_meta=True)
    assert loaded == rep.profile
    assert meta["static_regret"] == rep.static_regret
    np.testing.assert_array_equal(
        selection_on_grid(GRID, loaded), selection_on_grid(GRID, rep.profile))


def test_load_missing_profile_raises(tmp_path):
    with pytest.raises(FileNotFoundError, match="repro.launch.calibrate"):
        load_profile(device_kind="absent", base=tmp_path)


def test_corrupt_profile_rejected_by_validation(tmp_path):
    import json

    def write(profile):
        (tmp_path / "ed.json").write_text(json.dumps(
            {"schema": 1, "device_kind": "ed", "profile": profile, "meta": {}}))

    write(dataclasses.asdict(PCIE3) | {"gamma": 7.0})
    with pytest.raises(ValueError, match="gamma"):
        load_profile(device_kind="ed", base=tmp_path)
    # truncated profiles fail loudly instead of inheriting defaults
    truncated = dataclasses.asdict(PCIE3)
    del truncated["bandwidth"]
    write(truncated)
    with pytest.raises(ValueError, match="missing.*bandwidth"):
        load_profile(device_kind="ed", base=tmp_path)


# ------------------------------------------------------- calibration contract

def test_misspecified_profile_calibrates_strictly_better():
    """Acceptance: PCIe profile on the TPU link — calibrated regret vs
    the measured-best oracle strictly below static regret."""
    obs = model_probe(GRID, TPU_V5E_HBM)
    rep = calibrate(GRID, obs, PCIE3)
    assert rep.calibrated_regret < rep.static_regret
    # the fit recovers the true smooth-model parameters
    assert rep.profile.bandwidth == pytest.approx(TPU_V5E_HBM.bandwidth, rel=0.05)
    assert rep.profile.compaction_bandwidth == pytest.approx(
        TPU_V5E_HBM.compaction_bandwidth, rel=0.05)


def test_correct_profile_calibration_is_noop():
    """Acceptance: correctly-specified profile — selection decisions
    unchanged on the probe grid.  Uses the TPU profile, whose selection
    models the full compaction cost; PCIE3's selection deliberately
    omits the CPU pass (paper §V-A), so its thresholds are always
    tunable against physical measurements."""
    obs = model_probe(GRID, TPU_V5E_HBM)
    rep = calibrate(GRID, obs, TPU_V5E_HBM)
    np.testing.assert_array_equal(
        selection_on_grid(GRID, rep.profile),
        selection_on_grid(GRID, TPU_V5E_HBM))
    assert rep.calibrated_regret <= rep.static_regret


def test_regret_never_worse_regression():
    """Calibrated thresholds achieve <= the static thresholds' regret on
    the probe set — across profile pairs and under measurement noise."""
    for initial, truth, noise in [
        (PCIE3, TPU_V5E_HBM, 0.0),
        (PCIE3, TPU_V5E_HBM, 0.05),
        (TPU_V5E_HBM, PCIE3, 0.0),
        (PCIE3, PCIE3, 0.1),
    ]:
        obs = model_probe(GRID, truth, noise=noise, seed=11)
        rep = calibrate(GRID, obs, initial)
        assert rep.calibrated_regret <= rep.static_regret + 1e-12, (
            initial.name, truth.name, noise)


def test_fit_link_keeps_topology_constants():
    obs = model_probe(GRID, TPU_V5E_HBM)
    fitted = fit_link(GRID, obs, PCIE3)
    for f in ("m", "mr", "d1", "d2", "selection_uses_full_compaction_cost"):
        assert getattr(fitted, f) == getattr(PCIE3, f)
    # model probes carry no per-task dispatch signal, so the overhead is
    # inherited, not zeroed (wall probes opt in via fit_overhead=True)
    assert fitted.launch_overhead_s == PCIE3.launch_overhead_s


def test_registry_rejects_path_escaping_device_kind(tmp_path):
    from repro.autotune import profile_path

    for bad in ("../../etc/x", "a/b", "..", ""):
        with pytest.raises(ValueError, match="device kind"):
            profile_path(device_kind=bad, base=tmp_path)


def test_total_regret_zero_for_oracle_selection():
    obs = model_probe(GRID, TPU_V5E_HBM)
    measured = observation_matrix(GRID, obs)
    oracle_engines = np.argmin(measured, axis=1)
    assert total_regret(oracle_engines, measured) == 0.0


# ------------------------------------------------------------- property tests

@settings(deadline=None, max_examples=30)
@given(
    bw_exp=st.floats(8.0, 12.5),
    gamma=st.floats(0.01, 1.0),
    alpha=st.floats(0.05, 1.0),
    beta=st.floats(0.05, 1.0),
    granule=st.integers(1, 9),
    mr=st.integers(1, 512),
    full_cost=st.booleans(),
)
def test_any_valid_profile_skips_inactive_partitions(
    bw_exp, gamma, alpha, beta, granule, mr, full_cost
):
    """Selection under ANY valid profile maps zero-active partitions to
    NONE — the invariant every engine family relies on."""
    link = LinkModel(
        name="prop", d1=4.0, m=4.0 * (2 ** granule), mr=float(mr),
        bandwidth=10.0 ** bw_exp, gamma=gamma, alpha=alpha, beta=beta,
        compaction_bandwidth=10.0 ** (bw_exp - 1),
        selection_uses_full_compaction_cost=full_cost,
    )
    from repro.core.cost_model import PartitionStats
    import jax.numpy as jnp

    stats = PartitionStats(
        active_edges=jnp.asarray([0.0, 100.0, 0.0], jnp.float32),
        active_vertices=jnp.asarray([0.0, 10.0, 0.0], jnp.float32),
        zc_requests=jnp.asarray([0.0, 12.0, 0.0], jnp.float32),
        total_edges=jnp.asarray([1000.0, 1000.0, 0.0], jnp.float32),
    )
    eng = np.asarray(select_engines(stats, engine_costs(stats, link), link))
    assert eng[0] == NONE and eng[2] == NONE and eng[1] != NONE
    best = np.asarray(modeled_best_engines(stats, engine_costs(stats, link)))
    assert best[0] == NONE and best[2] == NONE and best[1] != NONE


@settings(deadline=None, max_examples=20)
@given(scale=st.floats(1e-6, 1e3), ratio=st.floats(1.0, 2000.0))
def test_online_calibrator_learns_relative_ratio(scale, ratio):
    """Feeding measured = scale * (c_f*T_f + c_z*T_z) with c_z/c_f =
    ratio, the solved correction reproduces the *relative* ratio
    regardless of the absolute scale (wall units need not match model
    units)."""
    cal = OnlineCalibrator(decay=0.2, ridge=1e-4)
    rng = np.random.default_rng(0)
    for _ in range(60):
        t = np.array([rng.uniform(0.5, 2.0), 0.0, rng.uniform(0.5, 2.0)])
        measured = scale * (t[0] + ratio * t[2])
        cal.update(t, measured)
    c = cal.correction()
    assert c[1] == 1.0  # COMPACT never observed: stays at identity
    assert c[2] / c[0] == pytest.approx(min(ratio, 400.0), rel=0.25) or (
        # both ends clipped when the ratio exceeds the safety range
        c[2] / c[0] == pytest.approx(cal.clip[1] / cal.clip[0], rel=1e-6))


def test_online_calibrator_ignores_degenerate_updates():
    cal = OnlineCalibrator()
    cal.update(np.zeros(3), 1.0)          # no modeled mass
    cal.update(np.ones(3), -1.0)          # negative wall
    cal.update(np.ones(3), float("nan"))  # NaN wall
    assert cal.n_updates == 0
    np.testing.assert_array_equal(cal.correction(), np.ones(3))


# ------------------------------------------------------------- online feedback

def test_run_hytm_autotune_traversal_bit_identical():
    from repro.core.hytm import HyTMConfig, run_hytm
    from repro.graph.algorithms import SSSP
    from repro.graph.generators import rmat_graph

    g = rmat_graph(1000, 12_000, seed=21)
    cfg = HyTMConfig(n_partitions=8)
    base = run_hytm(g, SSSP, source=0, config=cfg)
    tuned = run_hytm(g, SSSP, source=0,
                     config=dataclasses.replace(cfg, autotune=True))
    np.testing.assert_array_equal(base.values, tuned.values)
    assert tuned.engine_corrections is not None
    assert tuned.engine_corrections.shape == (3,)
    assert np.all(tuned.engine_corrections > 0)
    assert tuned.history["mispredictions"].shape == (tuned.iterations,)
    assert tuned.total_mispredictions >= 0
    # the default path reports diagnostics too, with no corrections
    assert base.engine_corrections is None
    assert "mispredictions" in base.history


def test_run_hytm_autotune_accumulative_tolerance_bounded():
    from repro.core.hytm import HyTMConfig, run_hytm
    from repro.graph.algorithms import PAGERANK
    from repro.graph.generators import rmat_graph

    g = rmat_graph(800, 8_000, seed=4)
    pr = dataclasses.replace(PAGERANK, tolerance=1e-7)
    cfg = HyTMConfig(n_partitions=8)
    base = run_hytm(g, pr, source=None, config=cfg)
    tuned = run_hytm(g, pr, source=None,
                     config=dataclasses.replace(cfg, autotune=True))
    # engine choices may legitimately differ (that is the point); results
    # agree to the program tolerance (FP summation order + second-pass
    # trajectory differences are tolerance-bounded, not bit-exact)
    assert np.max(np.abs(
        (base.values + base.delta) - (tuned.values + tuned.delta))) < 1e-3


def test_graph_service_autotune_matches_plain():
    from repro.core.hytm import HyTMConfig
    from repro.graph.generators import rmat_graph
    from repro.graph.algorithms import SSSP
    from repro.stream import GraphService, random_batch

    g = rmat_graph(500, 4_000, seed=9)
    plain = GraphService(g, HyTMConfig(n_partitions=8), max_lanes=4)
    tuned = GraphService(g, HyTMConfig(n_partitions=8, autotune=True),
                         max_lanes=4)
    sources = [0, 7, 33]
    r_plain = plain.query(SSSP, sources)
    r_tuned = tuned.query(SSSP, sources)
    for a, b in zip(r_plain, r_tuned):
        np.testing.assert_array_equal(a.values, b.values)
    assert "engine_corrections" in tuned.stats.extra
    assert len(tuned.stats.extra["engine_corrections"]) == 3
    assert "engine_corrections" not in plain.stats.extra

    # the incremental path after an update learns into the SAME
    # service-lifetime calibrator (no throwaway per-run ones)
    n_before = tuned._calibrator.n_updates
    rng = np.random.default_rng(9)
    batch = random_batch(tuned.dcsr, rng, n_insert=32, n_delete=32)
    plain.update(batch)
    tuned.update(batch)
    r_plain2 = plain.query(SSSP, sources)
    r_tuned2 = tuned.query(SSSP, sources)
    assert all(r.mode == "incremental" for r in r_tuned2)
    for a, b in zip(r_plain2, r_tuned2):
        np.testing.assert_array_equal(a.values, b.values)
    assert tuned._calibrator.n_updates > n_before
