"""repro.dist unit tests that run in the main (1-device) process.

The sharding rule DSL and the sharded HyTM machinery are both exercised
on 1-device meshes here (mesh semantics are size-independent); the real
multi-device equivalence runs live in test_distributed.py subprocesses.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P
from jax.tree_util import tree_flatten_with_path, tree_leaves

from repro.configs import get_arch, list_archs
from repro.dist.sharding import (
    batch_axes,
    fit_spec,
    lm_batch_spec,
    lm_cache_rule,
    lm_rule,
    path_str,
    spec_for,
    tree_shardings,
)
from repro.launch.mesh import make_debug_mesh, make_graph_mesh


@pytest.fixture(scope="module")
def mesh11():
    return make_debug_mesh(1, 1)


# ------------------------------------------------------------- rule DSL

def test_batch_axes_subsets(mesh11):
    assert batch_axes(mesh11) == ("data",)
    pod = make_debug_mesh(1, 1, pods=1)
    assert batch_axes(pod) == ("pod", "data")
    graph = make_graph_mesh(1)
    assert batch_axes(graph) == ()
    assert lm_batch_spec(mesh11) == P(("data",), None)


def test_fit_spec_right_aligns_and_pads(mesh11):
    # stacked scan-layer weight: rank-2 rule onto a rank-3 leaf
    assert fit_spec(P(None, "model"), (4, 64, 128), mesh11) == P(None, None, "model")
    # rule longer than the leaf keeps the trailing entries
    assert fit_spec(P("data", None, "model"), (64, 128), mesh11) == P(None, "model")
    # scalars always replicate
    assert fit_spec(P("model"), (), mesh11) == P()


def test_fit_spec_divisibility_fallback():
    # divisibility is checked against mesh axis *sizes*, so a shaped stub
    # exercises the multi-device fallback without allocating devices
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 8}

    # 30 % 8 != 0 -> that dim replicates; 32 % 8 == 0 -> sharded
    assert fit_spec(P(None, "model"), (16, 30), FakeMesh()) == P()
    assert fit_spec(P(None, "model"), (16, 32), FakeMesh()) == P(None, "model")


def test_first_matching_rule_wins(mesh11):
    rule = lm_rule(mesh11)
    # moe w_gate (expert-banked) and ffn w_gate (dense) hit different rules
    moe = spec_for("layers/moe/w_gate", (2, 8, 64, 32), mesh11, rule)
    ffn = spec_for("layers/ffn/w_gate", (2, 64, 128), mesh11, rule)
    assert moe[-1] == "model" and moe != ffn
    # optimizer moment trees mirror the param paths
    m = spec_for("0/m/layers/ffn/w_gate", (2, 64, 128), mesh11, rule)
    assert m == ffn
    # unmatched -> replicated
    assert spec_for("final_norm", (64,), mesh11, rule) == P()


def test_tree_shardings_covers_every_leaf(mesh11):
    from repro.models import transformer as tf
    from repro.train.optimizer import OptimizerConfig
    from repro.train.train_step import init_train_state

    cfg = tf.TransformerConfig(
        name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab=128, dtype="float32", param_dtype="float32",
    )
    oc = OptimizerConfig(learning_rate=1e-3, warmup_steps=0, schedule="constant")
    state = jax.eval_shape(
        lambda: init_train_state(tf.abstract_params(cfg), oc)
    )
    sh = tree_shardings(state, mesh11, lm_rule(mesh11))
    flat_state = tree_flatten_with_path(state)[0]
    flat_sh = tree_leaves(sh, is_leaf=lambda x: hasattr(x, "spec"))
    assert len(flat_state) == len(flat_sh)
    for (path, leaf), s in zip(flat_state, flat_sh):
        assert len(s.spec) <= leaf.ndim, (path_str(path), leaf.shape, s.spec)


def test_cache_rule_kv_heads_vs_sequence():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 2, "model": 4}

    # kv=8 divides model=4 -> heads shard; kv=1 (MQA) -> sequence shards
    heads = dict(lm_cache_rule(FakeMesh(), 8))
    seq = dict(lm_cache_rule(FakeMesh(), 1))
    assert list(heads[r"(^|/)[kv]$"]) == [("data",), None, "model", None]
    assert list(seq[r"(^|/)[kv]$"]) == [("data",), "model", None, None]


def test_all_arch_cells_build_on_debug_mesh(mesh11):
    """Every registered (arch x shape) cell resolves its shardings: the
    rule DSL must never crash on any real parameter/optimizer/cache tree
    (cells build abstractly — no allocation)."""
    built = 0
    for name in list_archs():
        arch = get_arch(name)
        for shape, builder in arch.cells.items():
            cell = builder(mesh11)
            assert cell.fn is not None, (name, shape)
            # in_shardings mirror the args pytree structure
            for args_leaf, sh_leaf in zip(
                tree_leaves(cell.args),
                tree_leaves(cell.in_shardings, is_leaf=lambda x: hasattr(x, "spec")),
            ):
                assert len(sh_leaf.spec) <= args_leaf.ndim
            built += 1
    assert built >= 30  # 10 archs x ~3-4 cells


# ------------------------------------------------- sharded HyTM, 1 device

def _oracle(cfg):
    return dataclasses.replace(cfg, mesh_axis=None)


def test_sharded_hytm_single_device_mesh_exact():
    """mesh_axis over a 1-device mesh must equal the single-device
    synchronous run bit-for-bit (the full shard_map machinery runs)."""
    from repro.core.hytm import HyTMConfig, run_hytm
    from repro.graph.algorithms import BFS, SSSP, reference_sssp
    from repro.graph.generators import rmat_graph

    g = rmat_graph(400, 3000, seed=21)
    for prog in (BFS, SSSP):
        cfg = HyTMConfig(n_partitions=8, async_sweep=False, mesh_axis="graph")
        a = run_hytm(g, prog, source=0, config=cfg)
        b = run_hytm(g, prog, source=0, config=_oracle(cfg))
        assert a.iterations == b.iterations
        np.testing.assert_array_equal(a.values, b.values)
        assert a.total_transfer_bytes == b.total_transfer_bytes
    ref = reference_sssp(g, 0)
    res = run_hytm(
        g, SSSP, source=0,
        config=HyTMConfig(n_partitions=8, async_sweep=False, mesh_axis="graph"),
    )
    assert np.allclose(res.values, ref)


def test_sharded_hytm_pagerank_single_device_mesh():
    from repro.core.hytm import HyTMConfig, run_hytm
    from repro.graph.algorithms import PAGERANK
    from repro.graph.generators import rmat_graph

    g = rmat_graph(300, 2400, seed=22)
    cfg = HyTMConfig(
        n_partitions=8, async_sweep=False, mesh_axis="graph", cds_mode="delta",
    )
    a = run_hytm(g, PAGERANK, source=None, config=cfg)
    b = run_hytm(g, PAGERANK, source=None, config=_oracle(cfg))
    assert a.iterations == b.iterations
    np.testing.assert_allclose(a.values, b.values, rtol=0, atol=1e-5)
    np.testing.assert_allclose(
        a.total_transfer_bytes, b.total_transfer_bytes, rtol=1e-6
    )


def test_blocked_runtime_matches_csr_slices():
    """The (P, B) blocked edge grid holds exactly each partition's edge
    segment (padding masked), including the empty padding partitions that
    round P up to the device count."""
    from repro.core.hytm import HyTMConfig
    from repro.dist.graph_shard import build_sharded_runtime
    from repro.graph.generators import rmat_graph

    g = rmat_graph(200, 1500, seed=23)
    cfg = HyTMConfig(n_partitions=5, mesh_axis="graph")  # 5 -> pads to n_dev
    mesh = make_graph_mesh(1)
    rt = build_sharded_runtime(g, cfg, mesh)
    assert rt.n_partitions % int(mesh.shape["graph"]) == 0
    src_all = g.edge_sources()
    es = np.asarray(rt.parts.edge_start)
    blocks_src = np.asarray(rt.blocks.src)
    in_range = np.asarray(rt.blocks.in_range)
    for p in range(rt.n_partitions):
        k = int(es[p + 1] - es[p])
        assert in_range[p, :k].all() and not in_range[p, k:].any()
        np.testing.assert_array_equal(
            blocks_src[p, :k], src_all[es[p]:es[p + 1]]
        )
    # padded partitions are empty and own no vertices
    part_edges = np.asarray(rt.parts.part_edges)
    assert (part_edges >= 0).all()
    assert int(part_edges.sum()) == g.n_edges


def test_forced_engines_match_on_sharded_path():
    """Engine forcing (baseline systems) flows through the sharded
    selection identically."""
    from repro.core.cost_model import COMPACT, FILTER, ZEROCOPY
    from repro.core.hytm import HyTMConfig, run_hytm
    from repro.graph.algorithms import SSSP
    from repro.graph.generators import rmat_graph

    g = rmat_graph(300, 2000, seed=24)
    for eng in (FILTER, COMPACT, ZEROCOPY):
        cfg = HyTMConfig(
            n_partitions=8, async_sweep=False, mesh_axis="graph",
            forced_engine=eng,
        )
        a = run_hytm(g, SSSP, source=0, config=cfg)
        b = run_hytm(g, SSSP, source=0, config=_oracle(cfg))
        np.testing.assert_array_equal(a.values, b.values)
        np.testing.assert_array_equal(
            a.history["engines"], b.history["engines"]
        )
