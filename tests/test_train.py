"""Training substrate: optimizers, microbatching, compression,
checkpointing, fault tolerance, data pipeline determinism."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.train.optimizer as opt_mod
from repro.data.pipeline import LMBatches, RecSysBatches
from repro.train.checkpoint import latest_steps, restore_checkpoint, save_checkpoint
from repro.train.compression import CompressionConfig, compress_grads, init_error_state, wire_bytes
from repro.train.fault_tolerance import FaultInjector, FaultTolerantLoop, StragglerMonitor
from repro.train.optimizer import OptimizerConfig, apply_updates, init_opt_state
from repro.train.train_step import init_train_state, make_train_step


@pytest.fixture
def quad():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
    y = x @ (0.5 * jnp.eye(64))
    params = {"w": jnp.ones((64, 64)), "b": jnp.zeros((64,))}

    def loss_fn(p, batch):
        return jnp.mean(jnp.square(batch["x"] @ p["w"] + p["b"] - batch["y"]))

    return params, loss_fn, {"x": x, "y": y}


@pytest.mark.parametrize("name,lr,factor", [
    ("adamw", 1e-2, 0.1), ("adafactor", 1e-2, 0.1), ("sgd", 1e-2, 0.75),
])
def test_optimizers_reduce_loss(name, lr, factor, quad):
    params, loss_fn, batch = quad
    oc = OptimizerConfig(name=name, learning_rate=lr, warmup_steps=0, schedule="constant")
    st = init_train_state(params, oc)
    step = jax.jit(make_train_step(loss_fn, oc))
    l0 = float(loss_fn(st.params, batch))
    for _ in range(120):
        st, m = step(st, batch)
    assert float(m["loss"]) < factor * l0


def test_microbatch_equals_full_batch(quad):
    params, loss_fn, batch = quad
    oc = OptimizerConfig(learning_rate=1e-2, warmup_steps=0, schedule="constant", grad_clip=1e9)
    s1 = init_train_state(params, oc)
    s2 = init_train_state(params, oc)
    s1, _ = jax.jit(make_train_step(loss_fn, oc))(s1, batch)
    s2, _ = jax.jit(make_train_step(loss_fn, oc, microbatches=4))(s2, batch)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), s1.params, s2.params)
    assert max(jax.tree.leaves(d)) < 1e-5


def test_chunked_leaf_update_matches_unchunked(quad):
    params = {"w": jnp.ones((8, 16, 16))}
    grads = {"w": jnp.full((8, 16, 16), 0.1)}
    oc = OptimizerConfig(name="adamw", learning_rate=1e-2, warmup_steps=0, schedule="constant")
    st = init_opt_state(oc, params)
    p1, _ = apply_updates(oc, params, grads, st, jnp.int32(0))
    old = opt_mod._CHUNKED_LEAF_ELEMS
    try:
        opt_mod._CHUNKED_LEAF_ELEMS = 16  # force the lax.map path
        st2 = init_opt_state(oc, params)
        p2, _ = apply_updates(oc, params, grads, st2, jnp.int32(0))
    finally:
        opt_mod._CHUNKED_LEAF_ELEMS = old
    assert jnp.allclose(p1["w"], p2["w"], atol=1e-6)


@pytest.mark.parametrize("kind", ["int8", "topk"])
def test_compression_error_feedback_unbiased(kind, quad):
    params, loss_fn, batch = quad
    cc = CompressionConfig(kind=kind, topk_fraction=0.25)
    err = init_error_state(params)
    g = jax.grad(lambda p: loss_fn(p, batch))(params)
    # accumulated wire grads + final residual == accumulated true grads
    total_wire = jax.tree.map(jnp.zeros_like, g)
    for _ in range(10):
        wire, err = compress_grads(cc, g, err)
        total_wire = jax.tree.map(lambda a, b: a + b, total_wire, wire)
    total_true = jax.tree.map(lambda a: 10.0 * a, g)
    resid = jax.tree.map(lambda tw, tt, e: jnp.max(jnp.abs(tw + e - tt)), total_wire, total_true, err)
    assert max(jax.tree.leaves(resid)) < 1e-3
    assert wire_bytes(cc, g) < wire_bytes(CompressionConfig(kind="none"), g)


def test_compressed_training_converges(quad):
    params, loss_fn, batch = quad
    oc = OptimizerConfig(learning_rate=1e-2, warmup_steps=0, schedule="constant")
    cc = CompressionConfig(kind="int8")
    st = init_train_state(params, oc, cc)
    step = jax.jit(make_train_step(loss_fn, oc, cc))
    for _ in range(60):
        st, m = step(st, batch)
    assert float(m["loss"]) < 5.0


def test_checkpoint_roundtrip_and_atomicity(quad):
    params, loss_fn, batch = quad
    oc = OptimizerConfig()
    st = init_train_state(params, oc)
    with tempfile.TemporaryDirectory() as td:
        save_checkpoint(td, 7, st)
        # stale tmp dirs are ignored + cleaned
        os.makedirs(os.path.join(td, "step_00000099.tmp"))
        assert latest_steps(td) == [7]
        step, restored = restore_checkpoint(td, st)
        assert step == 7
        same = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)), st.params, restored.params)
        assert all(jax.tree.leaves(same))


def test_fault_tolerant_loop_replays_deterministically(quad):
    params, loss_fn, batch = quad
    oc = OptimizerConfig(learning_rate=1e-2, warmup_steps=0, schedule="constant")
    pipe = LMBatches(vocab=50, batch=8, seq_len=4)

    def batch_fn(step):
        # deterministic stream keyed on step
        rng = np.random.default_rng(step)
        x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
        return {"x": x, "y": x @ (0.5 * jnp.eye(64))}

    step_fn = jax.jit(make_train_step(loss_fn, oc))
    with tempfile.TemporaryDirectory() as td:
        loop = FaultTolerantLoop(
            step_fn=step_fn, batch_fn=batch_fn, ckpt_dir=td, ckpt_every=5,
            injector=FaultInjector(fail_at_steps=(7, 13)), async_ckpt=True,
        )
        st = init_train_state(params, oc)
        final, log, restarts = loop.run(st, 20)
        assert restarts == 2
        assert int(final.step) == 20

    # no-fault run reaches identical params (deterministic replay)
    with tempfile.TemporaryDirectory() as td:
        loop2 = FaultTolerantLoop(step_fn=step_fn, batch_fn=batch_fn, ckpt_dir=td, ckpt_every=5)
        st2 = init_train_state(params, oc)
        final2, _, _ = loop2.run(st2, 20)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), final.params, final2.params)
    assert max(jax.tree.leaves(d)) < 1e-6


def test_straggler_monitor_flags():
    mon = StragglerMonitor(factor=3.0)
    for i in range(10):
        mon.record(i, 0.01)
    assert mon.record(10, 0.5) is True
    assert 10 in mon.flagged


def test_pipelines_deterministic_and_sharded():
    lm = LMBatches(vocab=100, batch=16, seq_len=8, n_shards=4)
    a = lm.make(3, shard=1)["tokens"]
    b = lm.make(3, shard=1)["tokens"]
    c = lm.make(3, shard=2)["tokens"]
    assert np.array_equal(a, b) and not np.array_equal(a, c)
    assert a.shape == (4, 8)

    rs = RecSysBatches(vocab_sizes=(100, 50), batch=32)
    batch = rs.make(0)
    assert batch["sparse"].shape == (32, 2)
    # Zipf ids are heavy-headed: plenty of duplicates (dedup engine regime)
    assert len(np.unique(batch["sparse"][:, 0])) < 20
