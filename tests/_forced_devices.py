"""Shared forced-host-device subprocess harness for the test suite.

jax locks the device count at the first backend init, so any test that
needs N (fake CPU) devices must run its script in a fresh subprocess.
The environment recipe itself is ``repro.launch.mesh.forced_host_device_env``
(one definition, shared with the device-sweep benchmarks); this module
adds the test-side plumbing — dedent, run, assert exit 0 — used by
``test_distributed``, ``test_chunked``, and ``test_stream_sharded``.
"""

import subprocess
import sys
import textwrap

from repro.launch.mesh import forced_host_device_env


def run_forced_devices(script: str, devices: int = 8, python_flags=(),
                       timeout: int = 560) -> str:
    """Run ``script`` (dedented) under ``devices`` forced-host CPU
    devices; assert it exits 0 and return its stdout."""
    out = subprocess.run(
        [sys.executable, *python_flags, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout,
        env=forced_host_device_env(devices),
    )
    assert out.returncode == 0, (
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-4000:]}")
    return out.stdout
