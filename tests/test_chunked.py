"""Chunked device-resident convergence driver (HyTMConfig.sync_every).

The contract under test (core.hytm.hytm_chunk and its consumers):

* ``sync_every = K > 1`` runs K iterations per compiled
  ``lax.while_loop`` dispatch and must be **bit-identical** to the
  legacy ``K = 1`` per-iteration loop for min-combine programs — values,
  iteration count, modeled transfer bytes, per-iteration engine picks —
  and tolerance-bounded for sum-combine (XLA may fuse the loop body
  differently than the standalone iteration);
* the early exit (while-condition on the previous iteration's
  ``next_active``) means a converged run never executes an iteration
  past convergence, so iteration counts match K=1 exactly even when
  K >> iterations;
* the loop really batches: driver dispatches drop from O(iterations) to
  O(iterations / K) (monkeypatch-counted regression below);
* the same holds through every consumer: ``run_hytm``,
  ``run_hytm_sharded`` (subprocess on forced-host devices), and
  ``GraphService`` lane sweeps — autotune on and off.
"""

import dataclasses

import numpy as np
import pytest

from _forced_devices import run_forced_devices
from repro.core.hytm import HyTMConfig, run_hytm
from repro.graph.algorithms import BFS, CC, PAGERANK, SSSP
from repro.graph.generators import grid_mesh_graph, rmat_graph


def _assert_min_bit_exact(a, b):
    np.testing.assert_array_equal(a.values, b.values)
    assert a.iterations == b.iterations
    assert a.total_transfer_bytes == b.total_transfer_bytes
    np.testing.assert_array_equal(a.history["engines"], b.history["engines"])
    np.testing.assert_array_equal(
        a.history["transfer_bytes"], b.history["transfer_bytes"])


@pytest.mark.parametrize("cds_mode", ["hub", "delta"])
def test_chunked_min_bit_exact_vs_k1(cds_mode):
    """MIN programs: K in {4, 64} reproduces K=1 bit-for-bit (values,
    iterations, transfer bytes, engine picks) — including with the
    'delta' CDS schedule, whose |Δ| segment-sum the iteration now skips
    for min-combine programs (Δ is identically zero)."""
    g = rmat_graph(800, 8_000, seed=3)
    for prog in (SSSP, CC):
        base_cfg = HyTMConfig(n_partitions=8, sync_every=1, cds_mode=cds_mode)
        base = run_hytm(g, prog, source=0, config=base_cfg)
        assert base.iterations > 1
        for K in (4, 64):
            chunked = run_hytm(
                g, prog, source=0,
                config=dataclasses.replace(base_cfg, sync_every=K),
            )
            _assert_min_bit_exact(base, chunked)
            # per_engine_time rides in history for the calibrator
            assert chunked.history["per_engine_time"].shape == (
                chunked.iterations, 3)


def test_chunked_sum_tolerance_bounded():
    """SUM programs: chunked results agree with K=1 within the program
    tolerance (same iteration count on this CPU backend)."""
    g = rmat_graph(800, 8_000, seed=3)
    pr = dataclasses.replace(PAGERANK, tolerance=1e-6)
    base_cfg = HyTMConfig(n_partitions=8, sync_every=1, cds_mode="delta")
    base = run_hytm(g, pr, source=None, config=base_cfg)
    for K in (4, 64):
        chunked = run_hytm(
            g, pr, source=None,
            config=dataclasses.replace(base_cfg, sync_every=K),
        )
        assert chunked.iterations == base.iterations
        np.testing.assert_allclose(
            base.values + base.delta, chunked.values + chunked.delta,
            rtol=0, atol=1e-5,
        )
        np.testing.assert_allclose(
            base.total_transfer_bytes, chunked.total_transfer_bytes,
            rtol=1e-6,
        )


def test_chunked_autotune_min_values_identical():
    """With online feedback on, corrections may resteer engine choices
    and sweep order, but min-combine fixpoints are unique: final values
    match the untuned K=1 run bit-for-bit at every K."""
    g = rmat_graph(800, 8_000, seed=5)
    base = run_hytm(g, SSSP, source=0,
                    config=HyTMConfig(n_partitions=8, sync_every=1))
    for K in (1, 4, 64):
        tuned = run_hytm(
            g, SSSP, source=0,
            config=HyTMConfig(n_partitions=8, sync_every=K, autotune=True),
        )
        np.testing.assert_array_equal(base.values, tuned.values)
        assert tuned.engine_corrections is not None
        assert tuned.engine_corrections.shape == (3,)


def test_chunked_early_exit_on_empty_frontier():
    """A run that is converged at iteration 1 (source with no out-edges)
    executes exactly one iteration whatever K — the chunk's early exit
    never overshoots convergence."""
    from repro.graph.csr import csr_from_edges

    # directed chain 0 -> 1 -> ... -> 19: the chain *end* has no
    # out-edges by construction, so BFS from it converges immediately
    n = 20
    g = csr_from_edges(n, np.arange(n - 1), np.arange(1, n),
                       np.ones(n - 1, np.float32))
    assert g.out_degrees[n - 1] == 0
    for K in (1, 8):
        res = run_hytm(g, BFS, source=n - 1,
                       config=HyTMConfig(n_partitions=2, sync_every=K))
        assert res.iterations == 1, K
        # ...while a run from the chain head needs the full diameter,
        # identically at any K
        res_head = run_hytm(g, BFS, source=0,
                            config=HyTMConfig(n_partitions=2, sync_every=K))
        if K == 1:
            base_iters = res_head.iterations
        else:
            assert res_head.iterations == base_iters
    assert base_iters > 8  # diameter-bound: the chunked run early-exits


def test_chunked_dispatch_count_regression():
    """The chunked loop really batches: ceil(iters/K) hytm_chunk
    dispatches and ZERO hytm_iteration dispatches, vs exactly
    ``iterations`` single-iteration dispatches for K=1 (counted through
    the shared ``count_driver_dispatches`` monkeypatch seam)."""
    from repro.core.hytm import count_driver_dispatches

    g = grid_mesh_graph(120, 3, seed=0)  # diameter-bound: many iterations
    K = 16
    with count_driver_dispatches() as counts:
        res1 = run_hytm(g, BFS, source=0,
                        config=HyTMConfig(n_partitions=4, sync_every=1))
    assert counts["iteration"] == res1.iterations
    assert counts["chunk"] == 0
    assert res1.iterations > 2 * K  # the workload is dispatch-bound

    with count_driver_dispatches() as counts:
        resK = run_hytm(g, BFS, source=0,
                        config=HyTMConfig(n_partitions=4, sync_every=K))
    _assert_min_bit_exact(res1, resK)
    assert counts["iteration"] == 0
    assert counts["chunk"] == -(-resK.iterations // K)  # == ceil(iters/K)
    assert counts["chunk"] <= resK.iterations // K + 1  # the CI gate bound


def test_chunked_service_lanes_match_k1():
    """GraphService lane sweeps through the chunked driver: query results
    (batched lanes, cache, incremental after an update) are bit-identical
    to a sync_every=1 service, autotune on or off."""
    from repro.stream import GraphService, random_batch

    g = rmat_graph(500, 4_000, seed=9)
    sources = [0, 7, 33]
    results = {}
    for K in (1, 8):
        for tuned in (False, True):
            svc = GraphService(
                g, HyTMConfig(n_partitions=8, sync_every=K, autotune=tuned),
                max_lanes=4,
            )
            first = svc.query(SSSP, sources)
            rng = np.random.default_rng(9)
            svc.update(random_batch(svc.dcsr, rng, n_insert=24, n_delete=24))
            second = svc.query(SSSP, sources)
            assert all(r.mode == "incremental" for r in second)
            results[(K, tuned)] = (first, second)
    ref_first, ref_second = results[(1, False)]
    for key, (first, second) in results.items():
        for a, b in zip(ref_first, first):
            np.testing.assert_array_equal(a.values, b.values, err_msg=str(key))
        for a, b in zip(ref_second, second):
            np.testing.assert_array_equal(a.values, b.values, err_msg=str(key))


_SHARDED_CHUNK_SCRIPT = """
    import dataclasses
    import numpy as np
    from repro.core.hytm import HyTMConfig, run_hytm
    from repro.graph.algorithms import PAGERANK, SSSP
    from repro.graph.generators import rmat_graph

    g = rmat_graph(500, 4000, seed=7)
    pr = dataclasses.replace(PAGERANK, tolerance=1e-6)
    for prog, src, autotune in (
        (SSSP, 0, False), (SSSP, 0, True), (pr, None, False),
    ):
        cfg1 = HyTMConfig(
            n_partitions=8, async_sweep=False, mesh_axis="graph",
            sync_every=1, autotune=autotune,
            cds_mode="delta" if prog.combine else "hub",
        )
        cfgK = dataclasses.replace(cfg1, sync_every=4)
        a = run_hytm(g, prog, source=src, config=cfg1)
        b = run_hytm(g, prog, source=src, config=cfgK)
        if prog.combine == 0:
            np.testing.assert_array_equal(a.values, b.values)
            if not autotune:  # feedback timing is nondeterministic
                assert a.iterations == b.iterations
                assert a.total_transfer_bytes == b.total_transfer_bytes
                np.testing.assert_array_equal(
                    a.history["ici_bytes"], b.history["ici_bytes"])
        else:
            np.testing.assert_allclose(
                a.values + a.delta, b.values + b.delta, rtol=0, atol=1e-5)
            assert a.iterations == b.iterations
        # the chunked sharded run still matches the single-device oracle
        oracle = run_hytm(g, prog, source=src,
                          config=dataclasses.replace(cfgK, mesh_axis=None))
        if prog.combine == 0:
            np.testing.assert_array_equal(b.values, oracle.values)
        else:
            np.testing.assert_allclose(
                b.values, oracle.values, rtol=0, atol=1e-5)
        print("OK", prog.name, "autotune" if autotune else "plain",
              b.iterations)
"""


def test_chunked_sharded_matches_k1_and_oracle():
    """Sharded path on 4 forced-host devices: one shard_mapped chunk per
    dispatch reproduces the per-iteration sharded run (bit-exact MIN with
    identical ICI accounting; tolerance-bounded SUM) and the
    single-device oracle, autotune on and off."""
    out = run_forced_devices(_SHARDED_CHUNK_SCRIPT, devices=4)
    assert out.count("OK") == 3
