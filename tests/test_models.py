"""Model zoo: tiny-config correctness for every family."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.attention as attention_mod
from repro.models.attention import MLAConfig
from repro.models.dlrm import DLRMConfig, dlrm_loss, init_dlrm, retrieval_score
from repro.models.embedding import embedding_bag, select_row_engine
from repro.models.gnn import (
    GNNConfig,
    gnn_loss,
    graphsage_minibatch_forward,
    init_gnn,
)
from repro.models.moe import MoEConfig, _moe_core, init_moe, select_dispatch_engine
from repro.models.transformer import (
    TransformerConfig,
    decode_step,
    forward,
    init_cache,
    init_transformer,
    lm_loss,
    prefill,
)

TINY = TransformerConfig(
    name="tiny", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=101, window_pattern=(8, 8, 0), dtype="float32",
    param_dtype="float32",
)

TINY_MLA_MOE = TransformerConfig(
    name="tiny-mla-moe", n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
    d_head=16, d_ff=96, vocab=101, attention="mla",
    mla=MLAConfig(kv_lora=32, d_nope=16, d_rope=8, d_v=16),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=64, n_shared=1,
                  capacity_factor=8.0, dispatch="sorted"),
    first_dense_layers=1, d_ff_dense=128, dtype="float32", param_dtype="float32",
)


@pytest.fixture(scope="module")
def toks():
    return jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, 101)


@pytest.mark.parametrize("cfg", [TINY, TINY_MLA_MOE], ids=["gqa", "mla+moe"])
def test_lm_loss_and_grads_finite(cfg, toks):
    p = init_transformer(jax.random.PRNGKey(0), cfg)
    loss, g = jax.value_and_grad(lambda q: lm_loss(q, toks, cfg))(p)
    assert bool(jnp.isfinite(loss))
    gsum = jax.tree.reduce(lambda a, b: a + jnp.sum(jnp.abs(b)), g, 0.0)
    assert bool(jnp.isfinite(gsum)) and float(gsum) > 0


@pytest.mark.parametrize("cfg", [TINY, TINY_MLA_MOE], ids=["gqa", "mla+moe"])
def test_prefill_decode_consistency(cfg, toks):
    p = init_transformer(jax.random.PRNGKey(0), cfg)
    full, _, _ = forward(p, toks, cfg)
    caches = init_cache(cfg, toks.shape[0], 32)
    last, caches = prefill(p, toks, cfg, caches)
    assert jnp.allclose(last, full[:, -1], atol=1e-4)
    nxt = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    dec, _ = decode_step(p, nxt, cfg, caches, jnp.int32(toks.shape[1]))
    ext, _, _ = forward(p, jnp.concatenate([toks, nxt], 1), cfg)
    assert jnp.allclose(dec, ext[:, -1], atol=1e-4)


def test_flash_oracle_matches_dense(toks):
    p = init_transformer(jax.random.PRNGKey(0), TINY)
    old = attention_mod._FLASH_THRESHOLD
    try:
        attention_mod._FLASH_THRESHOLD = 1
        lf, gf = jax.value_and_grad(lambda q: lm_loss(q, toks, TINY))(p)
        attention_mod._FLASH_THRESHOLD = 10**18
        ld, gd = jax.value_and_grad(lambda q: lm_loss(q, toks, TINY))(p)
    finally:
        attention_mod._FLASH_THRESHOLD = old
    assert jnp.allclose(lf, ld, atol=1e-5)
    md = max(jax.tree.leaves(jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), gf, gd)))
    assert md < 1e-4


def test_moe_engines_agree():
    cfg = MoEConfig(n_experts=8, top_k=2, d_ff=64, capacity_factor=16.0)
    p = init_moe(jax.random.PRNGKey(3), 64, cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (32, 64))
    ys = {e: _moe_core(x, p, cfg, e)[0] for e in ("dense", "sorted", "gather")}
    assert jnp.allclose(ys["sorted"], ys["gather"], atol=1e-5)
    assert jnp.allclose(ys["sorted"], ys["dense"], atol=1e-5)


def test_moe_chunking_matches_unchunked():
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff=32, capacity_factor=8.0, dispatch="sorted")
    p = init_moe(jax.random.PRNGKey(5), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(6), (48, 16))
    full, _ = _moe_core(x, p, cfg, "sorted")
    chunked, _ = _moe_core(x, p, cfg.replace(chunk_tokens=16), "sorted")
    assert jnp.allclose(full, chunked, atol=1e-5)


def test_moe_auto_engine_tiers():
    assert select_dispatch_engine(MoEConfig(4, 2, 8), 100) == "dense"
    assert select_dispatch_engine(MoEConfig(16, 2, 8), 100) == "gather"
    assert select_dispatch_engine(MoEConfig(384, 8, 8), 100) == "sorted"


@pytest.mark.parametrize(
    "arch,kw",
    [
        ("graphsage", {}),
        ("pna", {}),
        ("gatedgcn", {"d_edge_in": 4}),
        ("meshgraphnet", {"n_layers": 3, "d_edge_in": 4, "task": "regression"}),
    ],
)
def test_gnn_archs(arch, kw):
    from repro.graph.generators import rmat_graph

    g = rmat_graph(300, 2000, seed=31)
    src = jnp.asarray(g.edge_sources())
    dst = jnp.asarray(g.indices)
    feats = jax.random.normal(jax.random.PRNGKey(0), (300, 16))
    n_layers = kw.pop("n_layers", 2)
    cfg = GNNConfig(name=arch, arch=arch, n_layers=n_layers, d_hidden=32,
                    d_in=16, d_out=5, **kw)
    p = init_gnn(jax.random.PRNGKey(1), cfg)
    if cfg.task == "regression":
        labels = jax.random.normal(jax.random.PRNGKey(2), (300, 5))
    else:
        labels = jax.random.randint(jax.random.PRNGKey(2), (300,), 0, 5)
    loss, g_ = jax.value_and_grad(lambda q: gnn_loss(q, cfg, feats, src, dst, labels))(p)
    assert bool(jnp.isfinite(loss))
    gsum = jax.tree.reduce(lambda a, b: a + jnp.sum(jnp.abs(b)), g_, 0.0)
    assert bool(jnp.isfinite(gsum))


def test_graphsage_minibatch():
    cfg = GNNConfig(name="s", arch="graphsage", n_layers=2, d_hidden=32,
                    d_in=16, d_out=5, sample_sizes=(5, 3))
    p = init_gnn(jax.random.PRNGKey(0), cfg)
    lf = [jax.random.normal(jax.random.PRNGKey(i), (s, 16)) for i, s in enumerate((8, 40, 120))]
    out = graphsage_minibatch_forward(p, lf, cfg)
    assert out.shape == (8, 5) and bool(jnp.all(jnp.isfinite(out)))


def test_embedding_engines_agree():
    t = jax.random.normal(jax.random.PRNGKey(0), (50, 8))
    idx = jax.random.randint(jax.random.PRNGKey(1), (16, 4), 0, 50)
    outs = {e: embedding_bag(t, idx, engine=e) for e in ("gather", "dedup", "onehot")}
    assert jnp.allclose(outs["gather"], outs["dedup"], atol=1e-5)
    assert jnp.allclose(outs["gather"], outs["onehot"], atol=1e-4)


def test_row_engine_selection():
    assert select_row_engine(vocab=3, n_lookups=1000) == "onehot"
    assert select_row_engine(vocab=10**7, n_lookups=1000) == "gather"
    # hot-row regime: expected unique << lookups
    assert select_row_engine(vocab=1000, n_lookups=100_000) == "dedup"


def test_dlrm_loss_and_retrieval():
    cfg = DLRMConfig(vocab_sizes=(100, 3, 50, 7), embed_dim=16,
                     bot_mlp=(32, 16), top_mlp=(32, 1))
    p = init_dlrm(jax.random.PRNGKey(0), cfg)
    dense = jax.random.normal(jax.random.PRNGKey(1), (32, 13))
    sparse = jax.random.randint(jax.random.PRNGKey(2), (32, 4), 0, 3)
    labels = jax.random.bernoulli(jax.random.PRNGKey(3), 0.3, (32,))
    loss, g = jax.value_and_grad(lambda q: dlrm_loss(q, dense, sparse, labels, cfg))(p)
    assert bool(jnp.isfinite(loss))
    cand = jax.random.normal(jax.random.PRNGKey(4), (1000, 16))
    scores, ids = retrieval_score(p, dense[:1], cand, top_k=10)
    assert scores.shape == (1, 10)
    assert bool(jnp.all(scores[:, :-1] >= scores[:, 1:]))  # sorted desc
