"""Deterministic stand-in for the `hypothesis` API surface this suite uses.

The property-based tests only need ``given`` / ``settings`` and the
``integers`` / ``floats`` / ``booleans`` strategies.  When the real
hypothesis package is unavailable (hermetic containers), ``conftest.py``
installs this module as ``sys.modules['hypothesis']`` so the property
tests still *run* — each ``@given`` test executes ``max_examples``
fixed-seed samples drawn uniformly from the declared strategies — instead
of the whole module failing at collection.

This is NOT a hypothesis replacement: no shrinking, no example database,
no adaptive generation.  Install the real package (``pip install -e
.[test]``) for proper property testing; CI does.
"""

from __future__ import annotations

import functools
import random
import sys
import types
import zlib


class _Strategy:
    def example(self, rng: random.Random):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, min_value, max_value):
        self.min_value, self.max_value = min_value, max_value

    def example(self, rng):
        return rng.randint(self.min_value, self.max_value)


class _Floats(_Strategy):
    def __init__(self, min_value=0.0, max_value=1.0, **_):
        self.min_value, self.max_value = min_value, max_value

    def example(self, rng):
        return rng.uniform(self.min_value, self.max_value)


class _Booleans(_Strategy):
    def example(self, rng):
        return rng.random() < 0.5


class _SampledFrom(_Strategy):
    def __init__(self, elements):
        self.elements = list(elements)

    def example(self, rng):
        return rng.choice(self.elements)


def integers(min_value, max_value):
    return _Integers(min_value, max_value)


def floats(min_value=0.0, max_value=1.0, **kw):
    return _Floats(min_value, max_value, **kw)


def booleans():
    return _Booleans()


def sampled_from(elements):
    return _SampledFrom(elements)


_DEFAULT_MAX_EXAMPLES = 20


def given(**strategies):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper():
            n = getattr(wrapper, "_fallback_max_examples", _DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for _ in range(n):
                kwargs = {k: s.example(rng) for k, s in strategies.items()}
                try:
                    fn(**kwargs)
                except Exception as e:  # pragma: no cover - failure reporting
                    raise AssertionError(
                        f"fallback property test failed with example {kwargs!r}"
                    ) from e

        # pytest resolves fixture params through __wrapped__; the sampled
        # strategy args must not look like fixtures, so hide the original.
        del wrapper.__wrapped__
        return wrapper

    return decorate


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_):
    def decorate(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return decorate


def install() -> None:
    """Register this module as ``hypothesis`` (+ ``.strategies``)."""
    hyp = types.ModuleType("hypothesis")
    hyp.__doc__ = __doc__
    hyp.given = given
    hyp.settings = settings
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from"):
        setattr(st, name, globals()[name])
    hyp.strategies = st
    hyp.HealthCheck = types.SimpleNamespace(all=staticmethod(lambda: []))
    hyp.__fallback__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
