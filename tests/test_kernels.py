"""Per-kernel allclose sweeps (shapes x dtypes) against the ref.py oracles,
all in interpret mode (the kernel body executes in Python on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.frontier_compact.ops import frontier_compact
from repro.kernels.frontier_compact.ref import frontier_compact_ref
from repro.kernels.grouped_matmul.ops import grouped_matmul
from repro.kernels.grouped_matmul.ref import grouped_matmul_ref
from repro.kernels.hyb_gather.ops import hyb_gather
from repro.kernels.hyb_gather.ref import hyb_gather_ref
from repro.kernels.segment_spmm.ops import segment_spmm
from repro.kernels.segment_spmm.ref import segment_spmm_ref

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("m,d,n", [(100, 8, 40), (513, 1, 129), (2048, 64, 511), (1000, 200, 77)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_spmm_sweep(m, d, n, dtype):
    msg = jnp.asarray(RNG.standard_normal((m, d)), dtype)
    seg = jnp.asarray(RNG.integers(0, n, m), jnp.int32)
    valid = jnp.asarray(RNG.random(m) < 0.8)
    got = segment_spmm(msg, seg, n, valid)
    want = segment_spmm_ref(msg.astype(jnp.float32), seg, n, valid).astype(dtype)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("m,c,density", [(100, 1, 0.5), (1024, 4, 0.1), (700, 2, 0.9), (512, 3, 0.0)])
def test_frontier_compact_sweep(m, c, density):
    vals = jnp.asarray(RNG.standard_normal((m, c)), jnp.float32)
    mask = jnp.asarray(RNG.random(m) < density)
    got, cnt = frontier_compact(vals, mask)
    want, wcnt = frontier_compact_ref(vals, mask)
    assert int(cnt) == int(wcnt)
    k = int(cnt)
    np.testing.assert_allclose(got[:k], want[:k])


@pytest.mark.parametrize("m,c,a", [(300, 1, 8), (1000, 3, 33), (64, 2, 4)])
def test_hyb_gather_sweep(m, c, a):
    edges = jnp.asarray(RNG.standard_normal((m, c)), jnp.float32)
    starts = jnp.asarray(RNG.integers(0, m, a), jnp.int32)
    degs = jnp.asarray(RNG.integers(0, 120, a), jnp.int32)
    got = hyb_gather(edges, starts, degs)
    want = hyb_gather_ref(edges, starts, degs)
    np.testing.assert_allclose(got, want)


@pytest.mark.parametrize("S,L,dh,window", [(128, 128, 64, 0), (300, 300, 64, 64), (257, 257, 128, 0), (64, 512, 32, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, L, dh, window, dtype):
    if S > L:
        pytest.skip("decode-style only")
    q = jnp.asarray(RNG.standard_normal((2, S, dh)), dtype)
    # causal masking over the shared position space needs S == L here
    k = jnp.asarray(RNG.standard_normal((2, L, dh)), dtype)[:, :S]
    v = jnp.asarray(RNG.standard_normal((2, L, dh)), dtype)[:, :S]
    got = flash_attention(q, k, v, window=window)
    want = flash_attention_ref(q, k, v, 1.0 / dh**0.5, window=window)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("V,D,B,L", [(100, 16, 8, 1), (500, 48, 40, 4), (64, 128, 16, 8)])
@pytest.mark.parametrize("mode", ["sum", "mean", "max"])
def test_embedding_bag_sweep(V, D, B, L, mode):
    t = jnp.asarray(RNG.standard_normal((V, D)), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, V, (B, L)), jnp.int32)
    got = embedding_bag(t, idx, mode=mode)
    want = embedding_bag_ref(t, idx, mode=mode)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("E,D,F", [(4, 32, 48), (8, 64, 128), (3, 16, 16)])
def test_grouped_matmul_sweep(E, D, F):
    counts = jnp.asarray(RNG.integers(0, 200, E), jnp.int32)
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1]])
    T = int(jnp.sum(counts)) + 13
    x = jnp.asarray(RNG.standard_normal((T, D)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((E, D, F)), jnp.float32)
    got = grouped_matmul(x, w, starts, counts)
    want = grouped_matmul_ref(x, w, starts, counts)
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)


def test_segment_spmm_empty_and_full_valid():
    msg = jnp.ones((64, 4), jnp.float32)
    seg = jnp.zeros(64, jnp.int32)
    none = segment_spmm(msg, seg, 4, jnp.zeros(64, bool))
    assert float(jnp.abs(none).sum()) == 0.0
    full = segment_spmm(msg, seg, 4, jnp.ones(64, bool))
    assert float(full[0, 0]) == 64.0


# ---------------------------------------------------------------- min mode

@pytest.mark.parametrize("m,d,n", [(100, 8, 40), (513, 1, 129), (1000, 16, 77)])
def test_segment_spmm_min_sweep(m, d, n):
    """combine='min' must be BIT-exact vs segment_min (the FILTER-engine
    contract: min of a fixed multiset is order-independent)."""
    msg = jnp.asarray(RNG.standard_normal((m, d)), jnp.float32)
    seg = jnp.asarray(RNG.integers(0, n, m), jnp.int32)
    valid = jnp.asarray(RNG.random(m) < 0.8)
    got = segment_spmm(msg, seg, n, valid, combine="min")
    want = segment_spmm_ref(msg, seg, n, valid, combine="min")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_segment_spmm_min_inf_messages():
    """±inf messages (the MIN identity rides real frontiers) must survive
    the masked-select path — the 0*inf=NaN trap that rules out the matmul."""
    msg = jnp.asarray([jnp.inf, 1.0, -jnp.inf, jnp.inf], jnp.float32)[:, None]
    seg = jnp.asarray([0, 0, 1, 2], jnp.int32)
    got = segment_spmm(msg, seg, 4, combine="min")[:, 0]
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray([1.0, -np.inf, np.inf, np.inf], np.float32))


# -------------------------------------------- degenerate shapes (regressions)

def test_segment_spmm_empty_edge_stream():
    """m==0 previously exploded in BlockSpec slicing; it must return the
    combiner identity for every segment."""
    out = segment_spmm(jnp.zeros((0, 3), jnp.float32), jnp.zeros((0,), jnp.int32), 5)
    assert out.shape == (5, 3) and float(jnp.abs(out).sum()) == 0.0
    out = segment_spmm(jnp.zeros((0,), jnp.float32), jnp.zeros((0,), jnp.int32), 4,
                       combine="min")
    assert out.shape == (4,) and bool(jnp.all(jnp.isinf(out)))


def test_frontier_compact_empty_input():
    """m==0 regression: a zero-step grid would leave count uninitialized."""
    for shape in ((0,), (0, 2)):
        vals, cnt = frontier_compact(jnp.zeros(shape, jnp.float32),
                                     jnp.zeros((0,), bool))
        assert vals.shape == shape and int(cnt) == 0


def test_frontier_compact_nothing_survives():
    vals, cnt = frontier_compact(jnp.arange(8, dtype=jnp.float32),
                                 jnp.zeros(8, bool))
    assert int(cnt) == 0 and vals.shape == (8,)


def test_hyb_gather_no_requests():
    """a==0 regression (an iteration with an empty ZC window list)."""
    out = hyb_gather(jnp.ones((10, 4), jnp.float32),
                     jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.int32))
    assert out.shape[0] == 0 and out.ndim == 3


def test_segment_spmm_unobserved_segments():
    """n_segments far beyond any observed dst: tail segments must hold the
    identity, not garbage from the padded one-hot tiles."""
    msg = jnp.ones((4, 2), jnp.float32)
    seg = jnp.asarray([0, 0, 1, 1], jnp.int32)
    out = np.asarray(segment_spmm(msg, seg, 300))
    assert out.shape == (300, 2)
    np.testing.assert_array_equal(out[:2], np.full((2, 2), 2.0, np.float32))
    assert not out[2:].any()
    mn = np.asarray(segment_spmm(msg, seg, 300, combine="min"))
    np.testing.assert_array_equal(mn[:2], np.ones((2, 2), np.float32))
    assert np.isinf(mn[2:]).all()


def test_segment_spmm_1d_squeeze():
    """1-D messages route through the (m, 1) kernel and squeeze back."""
    msg = jnp.asarray(RNG.standard_normal(200), jnp.float32)
    seg = jnp.asarray(RNG.integers(0, 30, 200), jnp.int32)
    for combine in ("sum", "min"):
        got = segment_spmm(msg, seg, 30, combine=combine)
        assert got.shape == (30,)
        want = segment_spmm_ref(msg[:, None], seg, 30, combine=combine)[:, 0]
        tol = {} if combine == "min" else dict(atol=2e-5, rtol=2e-5)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol)


# ------------------------------------------- tracing contexts (vmap / loop)

def test_segment_spmm_under_vmap():
    """The engine kernels run inside vmapped service lanes: batched
    min-SpMM must stay bit-exact vs the batched oracle."""
    B, m, n = 3, 257, 40
    msgs = jnp.asarray(RNG.standard_normal((B, m)), jnp.float32)
    seg = jnp.asarray(RNG.integers(0, n, m), jnp.int32)
    got = jax.vmap(lambda mm: segment_spmm(mm, seg, n, combine="min"))(msgs)
    want = jax.vmap(
        lambda mm: segment_spmm_ref(mm[:, None], seg, n, combine="min")[:, 0]
    )(msgs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_frontier_compact_under_vmap():
    B, m = 3, 300
    vals = jnp.asarray(RNG.standard_normal((B, m)), jnp.float32)
    masks = jnp.asarray(RNG.random((B, m)) < 0.4)
    got, cnt = jax.vmap(frontier_compact)(vals, masks)
    want, wcnt = jax.vmap(frontier_compact_ref)(vals, masks)
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(wcnt))
    for i in range(B):
        k = int(cnt[i])
        np.testing.assert_array_equal(np.asarray(got[i, :k]),
                                      np.asarray(want[i, :k]))


def test_segment_spmm_inside_while_loop():
    """The chunked driver calls the kernels from a lax.while_loop body;
    the loop-carried relaxation must match the oracle's loop bit-exactly."""
    m, n = 300, 64
    src = jnp.asarray(RNG.integers(0, n, m), jnp.int32)
    dst = jnp.asarray(RNG.integers(0, n, m), jnp.int32)
    w = jnp.asarray(RNG.random(m), jnp.float32) + 0.5

    def step(kernel):
        def body(state):
            i, x = state
            msg = x[src] + w
            agg = (segment_spmm(msg, dst, n, combine="min") if kernel
                   else segment_spmm_ref(msg[:, None], dst, n, combine="min")[:, 0])
            return i + 1, jnp.minimum(x, agg)

        x0 = jnp.full((n,), jnp.inf, jnp.float32).at[0].set(0.0)
        return jax.lax.while_loop(lambda s: s[0] < 5, body, (jnp.int32(0), x0))[1]

    np.testing.assert_array_equal(np.asarray(step(True)), np.asarray(step(False)))
