"""Per-kernel allclose sweeps (shapes x dtypes) against the ref.py oracles,
all in interpret mode (the kernel body executes in Python on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.frontier_compact.ops import frontier_compact
from repro.kernels.frontier_compact.ref import frontier_compact_ref
from repro.kernels.grouped_matmul.ops import grouped_matmul
from repro.kernels.grouped_matmul.ref import grouped_matmul_ref
from repro.kernels.hyb_gather.ops import hyb_gather
from repro.kernels.hyb_gather.ref import hyb_gather_ref
from repro.kernels.segment_spmm.ops import segment_spmm
from repro.kernels.segment_spmm.ref import segment_spmm_ref

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("m,d,n", [(100, 8, 40), (513, 1, 129), (2048, 64, 511), (1000, 200, 77)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_spmm_sweep(m, d, n, dtype):
    msg = jnp.asarray(RNG.standard_normal((m, d)), dtype)
    seg = jnp.asarray(RNG.integers(0, n, m), jnp.int32)
    valid = jnp.asarray(RNG.random(m) < 0.8)
    got = segment_spmm(msg, seg, n, valid)
    want = segment_spmm_ref(msg.astype(jnp.float32), seg, n, valid).astype(dtype)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("m,c,density", [(100, 1, 0.5), (1024, 4, 0.1), (700, 2, 0.9), (512, 3, 0.0)])
def test_frontier_compact_sweep(m, c, density):
    vals = jnp.asarray(RNG.standard_normal((m, c)), jnp.float32)
    mask = jnp.asarray(RNG.random(m) < density)
    got, cnt = frontier_compact(vals, mask)
    want, wcnt = frontier_compact_ref(vals, mask)
    assert int(cnt) == int(wcnt)
    k = int(cnt)
    np.testing.assert_allclose(got[:k], want[:k])


@pytest.mark.parametrize("m,c,a", [(300, 1, 8), (1000, 3, 33), (64, 2, 4)])
def test_hyb_gather_sweep(m, c, a):
    edges = jnp.asarray(RNG.standard_normal((m, c)), jnp.float32)
    starts = jnp.asarray(RNG.integers(0, m, a), jnp.int32)
    degs = jnp.asarray(RNG.integers(0, 120, a), jnp.int32)
    got = hyb_gather(edges, starts, degs)
    want = hyb_gather_ref(edges, starts, degs)
    np.testing.assert_allclose(got, want)


@pytest.mark.parametrize("S,L,dh,window", [(128, 128, 64, 0), (300, 300, 64, 64), (257, 257, 128, 0), (64, 512, 32, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, L, dh, window, dtype):
    if S > L:
        pytest.skip("decode-style only")
    q = jnp.asarray(RNG.standard_normal((2, S, dh)), dtype)
    # causal masking over the shared position space needs S == L here
    k = jnp.asarray(RNG.standard_normal((2, L, dh)), dtype)[:, :S]
    v = jnp.asarray(RNG.standard_normal((2, L, dh)), dtype)[:, :S]
    got = flash_attention(q, k, v, window=window)
    want = flash_attention_ref(q, k, v, 1.0 / dh**0.5, window=window)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("V,D,B,L", [(100, 16, 8, 1), (500, 48, 40, 4), (64, 128, 16, 8)])
@pytest.mark.parametrize("mode", ["sum", "mean", "max"])
def test_embedding_bag_sweep(V, D, B, L, mode):
    t = jnp.asarray(RNG.standard_normal((V, D)), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, V, (B, L)), jnp.int32)
    got = embedding_bag(t, idx, mode=mode)
    want = embedding_bag_ref(t, idx, mode=mode)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("E,D,F", [(4, 32, 48), (8, 64, 128), (3, 16, 16)])
def test_grouped_matmul_sweep(E, D, F):
    counts = jnp.asarray(RNG.integers(0, 200, E), jnp.int32)
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1]])
    T = int(jnp.sum(counts)) + 13
    x = jnp.asarray(RNG.standard_normal((T, D)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((E, D, F)), jnp.float32)
    got = grouped_matmul(x, w, starts, counts)
    want = grouped_matmul_ref(x, w, starts, counts)
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)


def test_segment_spmm_empty_and_full_valid():
    msg = jnp.ones((64, 4), jnp.float32)
    seg = jnp.zeros(64, jnp.int32)
    none = segment_spmm(msg, seg, 4, jnp.zeros(64, bool))
    assert float(jnp.abs(none).sum()) == 0.0
    full = segment_spmm(msg, seg, 4, jnp.ones(64, bool))
    assert float(full[0, 0]) == 64.0
