"""Distributed correctness on fake multi-device meshes.

Device count is locked at first jax init, so these tests run in
subprocesses with XLA_FLAGS set (the main pytest process stays at 1
device, as the harness requires)."""

import pytest

from _forced_devices import run_forced_devices as _run


def test_moe_shard_map_matches_single_device():
    """EP+TP shard_map MoE == single-device oracle (fwd and grads)."""
    _run("""
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_debug_mesh
        from repro.models.moe import MoEConfig, init_moe, moe_ffn, _moe_core

        mesh = make_debug_mesh(2, 2, pods=2)  # (2,2,2) = 8 devices
        cfg = MoEConfig(n_experts=8, top_k=2, d_ff=32, n_shared=1,
                        capacity_factor=16.0, dispatch="sorted")
        p = init_moe(jax.random.PRNGKey(0), 64, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (32, 64))

        def single(p, x):
            y, aux = _moe_core(x, p, cfg, "sorted")
            return jnp.sum(y * y) + 0.0 * aux

        def dist(p, x):
            with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") else mesh:
                y, aux = moe_ffn(p, x, cfg, mesh=mesh, batch_axes=("pod", "data"))
            return jnp.sum(y * y) + 0.0 * aux

        l1, g1 = jax.value_and_grad(single)(p, x)
        with mesh:
            l2, g2 = jax.jit(jax.value_and_grad(dist))(p, x)
        assert jnp.allclose(l1, l2, rtol=1e-4), (l1, l2)
        diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), g1, g2)
        md = max(jax.tree.leaves(diffs))
        assert md < 1e-3, diffs
        print("OK moe dist", float(l1), float(l2), md)
    """)


def test_lm_train_step_on_debug_mesh():
    """A sharded tiny-LM train step runs and matches single-device loss."""
    _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_debug_mesh
        from repro.models import transformer as tf
        from repro.dist.sharding import lm_rule, tree_shardings, batch_axes
        from repro.train.optimizer import OptimizerConfig
        from repro.train.train_step import init_train_state, make_train_step

        mesh = make_debug_mesh(2, 4)
        cfg = tf.TransformerConfig(
            name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
            d_ff=128, vocab=128, dtype="float32", param_dtype="float32")
        oc = OptimizerConfig(learning_rate=1e-3, warmup_steps=0, schedule="constant")
        params = tf.init_transformer(jax.random.PRNGKey(0), cfg)
        state = init_train_state(params, oc)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 128)
        ba = batch_axes(mesh)
        loss_fn = lambda p, b: tf.lm_loss(p, b["tokens"], cfg, mesh=mesh, batch_axes=ba)
        step = make_train_step(loss_fn, oc)
        st_sh = tree_shardings(state, mesh, lm_rule(mesh))
        b_sh = {"tokens": NamedSharding(mesh, P(ba, None))}
        with mesh:
            jstep = jax.jit(step, in_shardings=(st_sh, b_sh))
            new_state, metrics = jstep(state, {"tokens": toks})
        l_dist = float(metrics["loss"])
        # single-device reference
        st2 = init_train_state(params, oc)
        _, m2 = jax.jit(make_train_step(lambda p, b: tf.lm_loss(p, b["tokens"], cfg), oc))(st2, {"tokens": toks})
        assert abs(l_dist - float(m2["loss"])) < 1e-4, (l_dist, float(m2["loss"]))
        print("OK lm dist", l_dist)
    """)


def test_sharded_ce_matches_unsharded():
    _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_debug_mesh
        from repro.models.common import cross_entropy_loss

        mesh = make_debug_mesh(2, 4)
        logits = jax.random.normal(jax.random.PRNGKey(0), (16, 64))
        labels = jax.random.randint(jax.random.PRNGKey(1), (16,), 0, 64)
        base = float(cross_entropy_loss(logits, labels))
        with mesh:
            sh = jax.device_put(logits, NamedSharding(mesh, P("data", "model")))
            dist = float(jax.jit(cross_entropy_loss)(sh, labels))
        assert abs(base - dist) < 1e-5, (base, dist)
        print("OK ce", base, dist)
    """)


_SHARDED_HYTM_SCRIPT = """
    import dataclasses
    import numpy as np
    import jax
    assert len(jax.devices()) == {devices}
    from repro.core.hytm import HyTMConfig, run_hytm
    from repro.graph.algorithms import (
        BFS, PAGERANK, SSSP, reference_bfs, reference_pagerank, reference_sssp,
    )
    from repro.graph.generators import rmat_graph

    g = rmat_graph(600, 5000, seed=7)
    # tolerance tightened so the converged Δ residual is small enough for
    # the numpy-reference comparison (the equivalence checks don't care)
    pr = dataclasses.replace(PAGERANK, tolerance=1e-6)
    for prog, src, name in ((BFS, 0, "bfs"), (SSSP, 0, "sssp"),
                            (pr, None, "pagerank")):
        cfg = HyTMConfig(
            n_partitions=16, async_sweep=False, mesh_axis="graph",
            cds_mode="delta" if prog.combine else "hub",
        )
        sharded = run_hytm(g, prog, source=src, config=cfg)
        oracle = run_hytm(
            g, prog, source=src, config=dataclasses.replace(cfg, mesh_axis=None)
        )
        # the acceptance triple: values, iteration count, transfer bytes
        assert sharded.iterations == oracle.iterations, name
        if prog.combine == 0:  # min-combine: bit-exact
            np.testing.assert_array_equal(sharded.values, oracle.values)
            assert sharded.total_transfer_bytes == oracle.total_transfer_bytes
        else:  # sum-combine: exact up to FP summation order of the psum
            np.testing.assert_allclose(
                sharded.values, oracle.values, rtol=0, atol=1e-5)
            np.testing.assert_allclose(
                sharded.total_transfer_bytes, oracle.total_transfer_bytes,
                rtol=1e-6)
        np.testing.assert_array_equal(
            sharded.history["engines"], oracle.history["engines"])
        # ...and against the numpy references
        finite = lambda x: np.where(np.isfinite(x), x, -1.0)
        if name == "bfs":
            np.testing.assert_array_equal(
                finite(sharded.values), finite(reference_bfs(g, 0)))
        elif name == "sssp":
            assert np.allclose(
                finite(sharded.values), finite(reference_sssp(g, 0)))
        else:
            ref = reference_pagerank(g)
            assert np.max(np.abs(sharded.values + sharded.delta - ref)) < 1e-2
        print("OK", name, sharded.iterations)
"""


@pytest.mark.parametrize("devices", [4, 8])
def test_sharded_hytm_matches_single_device_oracle(devices):
    """BFS/SSSP/PageRank through the shard_mapped sweep on forced-host
    meshes must reproduce the single-device run: same values, same
    iteration count, same modeled transfer bytes, same engine picks."""
    _run(_SHARDED_HYTM_SCRIPT.format(devices=devices), devices=devices)


_OWNER_SHARDED_SCRIPT = """
    import dataclasses
    import numpy as np
    import jax
    assert len(jax.devices()) == {devices}, jax.devices()
    from repro.core.hytm import HyTMConfig, run_hytm
    from repro.graph.algorithms import (ALGORITHMS, BFS, PAGERANK, SSSP,
                                        reference_kcore)
    from repro.graph.generators import rmat_graph

    g = rmat_graph(600, 5000, seed=7)
    pr = dataclasses.replace(PAGERANK, tolerance=1e-6)
    KCORE = ALGORITHMS["kcore"]
    for prog, src, name in ((BFS, 0, "bfs"), (SSSP, 0, "sssp"),
                            (pr, None, "pagerank"), (KCORE, None, "kcore")):
        cfg = HyTMConfig(
            n_partitions=16, async_sweep=False, mesh_axis="graph",
            cds_mode="delta" if (prog.combine and prog.peel_k is None)
            else "hub",
            vertex_sharding="owner",
        )
        sharded = run_hytm(g, prog, source=src, config=cfg)
        oracle = run_hytm(g, prog, source=src,
                          config=dataclasses.replace(
                              cfg, mesh_axis=None,
                              vertex_sharding="replicated"))
        assert sharded.iterations == oracle.iterations, name
        assert sharded.values.shape == (600,), sharded.values.shape
        if prog.combine == 0 or prog.peel_k is not None:
            # MIN family + peeling: bit-identical to the oracle
            np.testing.assert_array_equal(sharded.values, oracle.values)
            assert (sharded.total_transfer_bytes
                    == oracle.total_transfer_bytes), name
        else:
            np.testing.assert_allclose(sharded.values, oracle.values,
                                       rtol=0, atol=1e-5)
            np.testing.assert_allclose(sharded.total_transfer_bytes,
                                       oracle.total_transfer_bytes,
                                       rtol=1e-6)
        np.testing.assert_array_equal(sharded.history["engines"],
                                      oracle.history["engines"])
        if name == "kcore":
            ref_removed, ref_deg = reference_kcore(g, 2.0)
            np.testing.assert_array_equal(sharded.delta > 0.5, ref_removed)
            np.testing.assert_allclose(sharded.values, ref_deg)
        print("OK", name, sharded.iterations)

    # chunked driver under the owner layout (K > 1 lane through
    # make_sharded_batched_chunk)
    cfg = HyTMConfig(n_partitions=16, async_sweep=False, mesh_axis="graph",
                     sync_every=4, vertex_sharding="owner")
    sharded = run_hytm(g, SSSP, source=0, config=cfg)
    oracle = run_hytm(g, SSSP, source=0,
                      config=dataclasses.replace(cfg, mesh_axis=None,
                                                 vertex_sharding="replicated"))
    np.testing.assert_array_equal(sharded.values, oracle.values)
    assert sharded.iterations == oracle.iterations
    print("OK chunked", sharded.iterations)
"""


@pytest.mark.parametrize("devices", [4, 16])
def test_owner_sharded_matches_single_device_oracle(devices):
    """``vertex_sharding="owner"`` (owner-sharded ``(n/D,)`` state with a
    compacted halo exchange) reproduces the single-device oracle for
    BFS/SSSP/k-core bit-exactly (MIN family + peeling) and PageRank
    within tolerance — values, iterations, transfer bytes, engine picks
    — on 4 and 16 forced-host devices, iteration and chunked drivers."""
    out = _run(_OWNER_SHARDED_SCRIPT.format(devices=devices),
               devices=devices)
    assert out.count("OK") == 5, out


def test_sharded_hytm_padding_and_forced_engines():
    """Partition counts that do not divide the device count pad with
    empty partitions; forced single-engine baselines stay correct."""
    _run("""
        import dataclasses
        import numpy as np
        from repro.core.cost_model import COMPACT, FILTER, ZEROCOPY
        from repro.core.hytm import HyTMConfig, run_hytm
        from repro.graph.algorithms import SSSP, reference_sssp
        from repro.graph.generators import rmat_graph

        g = rmat_graph(500, 4000, seed=11)
        ref = reference_sssp(g, 0)
        for eng in (FILTER, COMPACT, ZEROCOPY, None):
            cfg = HyTMConfig(n_partitions=10, async_sweep=False,
                             mesh_axis="graph", forced_engine=eng)
            sharded = run_hytm(g, SSSP, source=0, config=cfg)
            oracle = run_hytm(g, SSSP, source=0,
                              config=dataclasses.replace(cfg, mesh_axis=None))
            np.testing.assert_array_equal(sharded.values, oracle.values)
            assert sharded.iterations == oracle.iterations
            assert np.allclose(sharded.values, ref), f"engine {eng}"
        print("OK padded+forced")
    """, devices=8)


def test_sharded_hytm_recompute_once_and_hubs():
    """The recompute-once second pass (global priority mask) agrees with
    the single-device schedule when hub partitions are designated."""
    _run("""
        import dataclasses
        import numpy as np
        from repro.core.hytm import HyTMConfig, run_hytm
        from repro.graph.algorithms import SSSP
        from repro.graph.generators import rmat_graph
        from repro.graph.hub_sort import hub_sort

        g = rmat_graph(800, 7000, seed=5)
        hs = hub_sort(g, hub_fraction=0.1)
        g2, n_hubs = hs.graph, hs.n_hubs
        cfg = HyTMConfig(n_partitions=16, async_sweep=False,
                         mesh_axis="graph", cds_mode="hub", recompute_once=True)
        sharded = run_hytm(g2, SSSP, source=0, config=cfg, n_hubs=n_hubs)
        oracle = run_hytm(g2, SSSP, source=0, n_hubs=n_hubs,
                          config=dataclasses.replace(cfg, mesh_axis=None))
        np.testing.assert_array_equal(sharded.values, oracle.values)
        assert sharded.iterations == oracle.iterations
        assert sharded.total_transfer_bytes == oracle.total_transfer_bytes
        print("OK hubs", sharded.iterations)
    """, devices=4)


def test_checkpoint_elastic_reshard():
    """Save on a (2,4) mesh, restore onto (4,2) — topology-elastic."""
    _run("""
        import tempfile
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_debug_mesh
        from repro.train.checkpoint import save_checkpoint, restore_checkpoint

        m1 = make_debug_mesh(2, 4)
        m2 = make_debug_mesh(4, 2)
        x = jnp.arange(64.0).reshape(8, 8)
        tree = {"w": jax.device_put(x, NamedSharding(m1, P("data", "model")))}
        with tempfile.TemporaryDirectory() as td:
            save_checkpoint(td, 1, tree)
            new_sh = {"w": NamedSharding(m2, P("data", "model"))}
            step, restored = restore_checkpoint(td, tree, shardings=new_sh)
        assert step == 1
        assert restored["w"].sharding.mesh.shape == m2.shape
        assert jnp.array_equal(restored["w"], x)
        print("OK elastic")
    """)
