"""repro.serve acceptance contract.

The serving stack's invariants, property-tested where cheap:

* admission respects per-tenant quotas and orders by deadline
  (queue-level, no engine);
* partial batches pad to static lane buckets — one compile per bucket,
  never one per request count (the recompile regression the bucket set
  exists to prevent);
* lane backfill never changes any result vs the standalone run
  (``jax.vmap`` lane independence);
* spill → promote → replay is bit-identical to never-evicted for MIN
  programs and tolerance-bounded for SUM programs (the warm-cache tier
  equivalence guarantee).
"""

import dataclasses

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.hytm import HyTMConfig, hytm_batched_chunk, run_hytm
from repro.graph.algorithms import BFS, PPR, SSSP
from repro.graph.generators import rmat_graph
from repro.serve import (
    LaneScheduler,
    Request,
    RequestQueue,
    TierPolicy,
    WarmCache,
    default_buckets,
)
from repro.stream import GraphService, random_batch

CFG = HyTMConfig(n_partitions=8, sync_every=4)


# --------------------------------------------------------------------------
# queue: quotas + deadline order (no engine)
# --------------------------------------------------------------------------

@settings(max_examples=30)
@given(
    n_requests=st.integers(min_value=1, max_value=24),
    n_tenants=st.integers(min_value=1, max_value=4),
    quota=st.integers(min_value=0, max_value=3),
    n_slots=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=999),
)
def test_admission_respects_quotas(n_requests, n_tenants, quota, n_slots, seed):
    """However requests arrive, no admission pass ever pushes a tenant
    past its quota (counting lanes already in flight), and zero-quota
    tenants are rejected rather than deferred forever."""
    rng = np.random.default_rng(seed)
    q = RequestQueue(quota=quota)
    for _ in range(n_requests):
        q.submit(Request(
            tenant=f"t{rng.integers(n_tenants)}", program=SSSP,
            source=int(rng.integers(100)),
            deadline=float(rng.integers(1000)),
        ))
    in_flight: dict[str, int] = {}
    rejected: list = []
    while q:
        before = len(q)
        admitted = q.admit(n_slots, in_flight, program=SSSP,
                           on_reject=rejected.append)
        for r in admitted:
            in_flight[r.tenant] = in_flight.get(r.tenant, 0) + 1
            assert in_flight[r.tenant] <= quota or quota == 0
        if len(q) == before:
            break
        # model lanes converging: one tenant's lane frees per round
        for t in list(in_flight):
            in_flight[t] -= 1
            if in_flight[t] == 0:
                del in_flight[t]
    assert q.stats.quota_violations == 0
    if quota == 0:
        assert len(rejected) == n_requests  # never admissible -> rejected
    else:
        assert not rejected


@settings(max_examples=30)
@given(
    n_requests=st.integers(min_value=1, max_value=24),
    n_slots=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=999),
)
def test_admission_is_deadline_ordered(n_requests, n_slots, seed):
    """With no quota/budget constraint the admitted prefix is exactly the
    (deadline, arrival)-sorted head of the pending set."""
    rng = np.random.default_rng(seed)
    q = RequestQueue()
    reqs = [Request(tenant="t", program=SSSP, source=i,
                    deadline=float(rng.integers(10)))
            for i in range(n_requests)]
    for r in reqs:
        q.submit(r)
    admitted = q.admit(n_slots, {})
    expected = sorted(reqs, key=lambda r: (r.deadline, r.arrival))
    assert admitted == expected[:min(n_slots, n_requests)]
    keys = [(r.deadline, r.arrival) for r in admitted]
    assert keys == sorted(keys)


def test_admission_rejects_unfittable_and_defers_over_budget():
    q = RequestQueue()
    for i in range(3):
        q.submit(Request(tenant="t", program=SSSP, source=i))
    rejected = []
    # lane bigger than the whole budget: reject outright, never defer
    out = q.admit(8, {}, bytes_per_lane=100, total_budget=50,
                  on_reject=rejected.append)
    assert out == [] and len(rejected) == 3 and len(q) == 0
    # lane fits the budget but not the current free bytes: defer, keep
    for i in range(3):
        q.submit(Request(tenant="t", program=SSSP, source=i))
    out = q.admit(8, {}, free_bytes=150, bytes_per_lane=100,
                  total_budget=1000)
    assert len(out) == 1 and len(q) == 2
    assert q.stats.deferred == 2


# --------------------------------------------------------------------------
# scheduler: static buckets — one compile per bucket, results solo-exact
# --------------------------------------------------------------------------

def test_lane_buckets_one_compile_per_bucket():
    """Partial batches pad up to a static bucket: driving every request
    count 1..5 through a max_lanes=4 service compiles the batched chunk
    at most once per bucket {1, 2, 4} — NOT once per request count (the
    regression the old ``sources[i:i+max_lanes]`` chunking had)."""
    g = rmat_graph(300, 2400, seed=13)
    svc = GraphService(g, CFG, max_lanes=4)
    assert svc.scheduler.buckets == (1, 2, 4)
    c0 = hytm_batched_chunk._cache_size()
    all_sources = [[0], [1, 2], [3, 4, 5], [6, 7, 8, 9], [10, 11, 12, 13, 14]]
    for sources in all_sources:
        res = svc.query(SSSP, sources)
        for s, r in zip(sources, res):
            solo = run_hytm(g, SSSP, source=s, config=CFG)
            np.testing.assert_array_equal(r.values, solo.values)
    compiles = hytm_batched_chunk._cache_size() - c0
    assert compiles <= len(svc.scheduler.buckets), (
        f"{compiles} compiles for buckets {svc.scheduler.buckets}")


def test_backfill_never_changes_results():
    """7 sources through 2 lanes: converged lanes are backfilled
    mid-flight, and every lane's result stays bit-identical to its
    standalone run (vmap lane independence + dead-lane padding)."""
    g = rmat_graph(400, 3200, seed=17)
    svc = GraphService(g, CFG, max_lanes=2)
    sources = [0, 11, 42, 123, 250, 301, 77]
    res = svc.query(SSSP, sources)
    assert svc.scheduler.stats.backfills > 0
    for s, r in zip(sources, res):
        solo = run_hytm(g, SSSP, source=s, config=CFG)
        np.testing.assert_array_equal(r.values, solo.values)


def test_default_buckets():
    assert default_buckets(1) == (1,)
    assert default_buckets(3) == (1, 2, 3)
    assert default_buckets(8) == (1, 2, 4, 8)
    assert default_buckets(12) == (1, 2, 4, 8, 12)


# --------------------------------------------------------------------------
# scheduler: multi-tenant pump — quotas honored end to end
# --------------------------------------------------------------------------

def test_pump_honors_quotas_and_serves_everyone():
    g = rmat_graph(300, 2400, seed=19)
    svc = GraphService(g, CFG, max_lanes=4)
    sched = svc.scheduler
    q = RequestQueue(quota=1)   # each tenant: at most one lane in flight
    for i, t in enumerate(["a", "b", "a", "c", "b", "a"]):
        q.submit(Request(tenant=t, program=BFS, source=i,
                         deadline=float(i)))

    peak: dict[str, int] = {}
    orig = LaneScheduler._dispatch

    def spying(self, *a, **k):
        for t, c in self.in_flight.items():
            peak[t] = max(peak.get(t, 0), c)
        return orig(self, *a, **k)

    LaneScheduler._dispatch = spying
    try:
        served = sched.pump(q)
    finally:
        LaneScheduler._dispatch = orig
    assert len(served) == 6 and not q
    assert all(c <= 1 for c in peak.values()), peak
    assert q.stats.quota_violations == 0
    by_src = {r.request.source: r for r in served}
    for i in range(6):
        solo = run_hytm(g, BFS, source=i, config=CFG)
        np.testing.assert_array_equal(by_src[i].values, solo.values)


# --------------------------------------------------------------------------
# warm cache: tiers, budget, spill -> promote -> replay equivalence
# --------------------------------------------------------------------------

def test_warm_cache_lru_spill_and_promote_roundtrip():
    cache = WarmCache(TierPolicy(device_budget_bytes=2 * 80))
    a = np.arange(10, dtype=np.float32)
    z = np.zeros(10, dtype=np.float32)
    cache.put("k1", 0, a, z)          # 80 bytes
    cache.put("k2", 0, a + 1, z)      # 160 total: at budget
    cache.get("k1")                   # k1 now hotter than k2
    cache.put("k3", 0, a + 2, z)      # over budget -> spill LRU (k2)
    tiers = {k: e.tier for k, e in cache.items()}
    assert tiers == {"k1": "device", "k2": "host", "k3": "device"}
    assert cache.device_bytes <= 160
    assert isinstance(cache._entries["k2"].values, np.ndarray)
    promoted = cache.promote("k2")
    assert promoted.tier == "device"
    np.testing.assert_array_equal(np.asarray(promoted.values), a + 1)
    assert cache.device_bytes <= 160  # someone else spilled to make room
    assert cache.stats.spills >= 2 and cache.stats.promotions == 1


@settings(max_examples=5, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=99),
    budget_lanes=st.integers(min_value=1, max_value=2),
)
def test_spill_promote_replay_equals_never_evicted_min(seed, budget_lanes):
    """MIN programs: a service whose warm states bounce through the host
    tier answers every query bit-identically to one whose device tier is
    unbounded.  (The entry state round-trips exactly; the replay is the
    same incremental path either way.)  The budget must hold at least one
    in-flight lane (9n bytes) — below that admission rejects."""
    g = rmat_graph(200, 1400, seed=5)
    lane_bytes = 9 * 200
    tiny = GraphService(g, CFG, max_lanes=2,
                        device_budget_bytes=budget_lanes * lane_bytes)
    unbounded = GraphService(g, CFG, max_lanes=2)
    rng_t = np.random.default_rng(seed)
    rng_u = np.random.default_rng(seed)
    sources = [0, 7, 19, 33]
    for round_ in range(3):
        for svc, rng in ((tiny, rng_t), (unbounded, rng_u)):
            svc.update(random_batch(svc.dcsr, rng, n_insert=5, n_delete=5))
        qs = [int(rng_t.integers(len(sources)))]
        rs_t = tiny.query(SSSP, [sources[i] for i in qs])
        rng_u.integers(len(sources))  # keep generators aligned
        rs_u = unbounded.query(SSSP, [sources[i] for i in qs])
        for a, b in zip(rs_t, rs_u):
            np.testing.assert_array_equal(a.values, b.values)
        # refresh the rest so there are warm states to spill
        rs_t = tiny.query(SSSP, sources)
        rs_u = unbounded.query(SSSP, sources)
        for a, b in zip(rs_t, rs_u):
            np.testing.assert_array_equal(a.values, b.values)
    assert tiny.cache.stats.spills > 0


def test_spill_promote_replay_tolerance_sum():
    """SUM programs (Δ-PPR): the spilled-and-promoted service tracks the
    unbounded one within the program tolerance after updates."""
    ppr = dataclasses.replace(PPR, tolerance=1e-7)
    g = rmat_graph(200, 1400, seed=7)
    # exactly one lane fits: serving works, but the cache always spills
    tiny = GraphService(g, CFG, max_lanes=2, device_budget_bytes=9 * 200)
    unbounded = GraphService(g, CFG, max_lanes=2)
    rng_t = np.random.default_rng(3)
    rng_u = np.random.default_rng(3)
    sources = [0, 11, 23]
    tiny.query(ppr, sources)
    unbounded.query(ppr, sources)
    for _ in range(2):
        tiny.update(random_batch(tiny.dcsr, rng_t, n_insert=4, n_delete=4))
        unbounded.update(random_batch(unbounded.dcsr, rng_u,
                                      n_insert=4, n_delete=4))
        rs_t = tiny.query(ppr, sources)
        rs_u = unbounded.query(ppr, sources)
        for a, b in zip(rs_t, rs_u):
            assert np.max(np.abs(a.values - b.values)) < 1e-4
    assert tiny.cache.stats.spills > 0
    assert tiny.cache.stats.promotions > 0


def test_device_budget_is_never_exceeded():
    """Peak device-resident bytes (in-flight lanes + device tier) stay
    under the budget whenever the budget can hold the bucket at all."""
    g = rmat_graph(300, 2400, seed=23)
    lane = 9 * 300
    budget = 2 * lane + 4 * 300 * 2  # 2 lanes + about one cached entry
    svc = GraphService(g, CFG, max_lanes=4, device_budget_bytes=budget)
    svc.query(SSSP, [0, 7, 19, 33, 41])
    assert svc.scheduler.stats.max_device_bytes <= budget
    # bucket 4 would not fit: admission degrades to bucket 2
    assert svc.scheduler.stats.batches >= 1
    assert svc.cache.device_bytes + svc.scheduler.pinned_bytes <= budget
